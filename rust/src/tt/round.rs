//! TT-rounding (rank recompression) — Oseledets 2011, Alg. 2.
//!
//! Right-to-left orthogonalization (LQ via QR of the transpose) followed by
//! a left-to-right truncated-SVD sweep.  After orthogonalization the whole
//! tensor's Frobenius norm concentrates in the first core, which gives the
//! exact error budget for the truncation sweep.

use crate::error::Result;
use crate::linalg::{qr, truncated_svd};
use crate::tensor::{matmul, Tensor};
use crate::tt::{TtMatrix, TtShape};

impl TtMatrix {
    /// Recompress to `max_rank` and/or relative tolerance `eps`.
    ///
    /// Guarantees `‖W − round(W)‖_F ≤ eps · ‖W‖_F` when the rank cap does
    /// not bind.  Rounding after TT arithmetic (`add`, `hadamard`, TT-by-TT
    /// products) is what keeps ranks from blowing up — the paper's §3
    /// "operations increase ranks" caveat.
    pub fn round(&self, max_rank: Option<usize>, eps: f64) -> Result<TtMatrix> {
        let d = self.d();
        let ms = self.shape().ms().to_vec();
        let ns = self.shape().ns().to_vec();
        let mut cores: Vec<Tensor> = self.cores().to_vec();
        let mut ranks: Vec<usize> = self.shape().ranks().to_vec();

        if d == 1 {
            return Ok(self.clone()); // single core: ranks are already (1,1)
        }

        // ---- right-to-left orthogonalization ------------------------------
        for k in (1..d).rev() {
            let s_k = ms[k] * ns[k];
            let (r0, r1) = (ranks[k], ranks[k + 1]);
            // unfold (r0, s_k*r1); LQ: unfold^T = Q R  =>  unfold = R^T Q^T
            let unfold_t = cores[k].reshaped(&[r0, s_k * r1])?.t2()?; // (s_k r1, r0)
            let (q, r) = qr(&unfold_t)?; // q: (s_k r1, p), r: (p, r0), p = min
            let p = q.shape()[1];
            // new core k = Q^T reshaped (p, m, n, r1)
            cores[k] = q.t2()?.reshape(&[p, ms[k], ns[k], r1])?;
            // fold R^T into core k-1: (.., r0) x (r0, p)
            let rt = r.t2()?; // (r0, p)
            let left_rows = ranks[k - 1] * ms[k - 1] * ns[k - 1];
            let prev = cores[k - 1].reshaped(&[left_rows, r0])?;
            cores[k - 1] = matmul(&prev, &rt)?.reshape(&[ranks[k - 1], ms[k - 1], ns[k - 1], p])?;
            ranks[k] = p;
        }

        // norm now lives in core 0
        let norm = cores[0].norm() as f64;
        let delta = if d > 1 { eps * norm / ((d - 1) as f64).sqrt() } else { 0.0 };

        // ---- left-to-right truncation sweep -------------------------------
        for k in 0..d - 1 {
            let s_k = ms[k] * ns[k];
            let (r0, r1) = (ranks[k], ranks[k + 1]);
            let unfold = cores[k].reshaped(&[r0 * s_k, r1])?;
            let tsvd = truncated_svd(&unfold, max_rank, delta)?;
            let p = tsvd.s.len();
            cores[k] = tsvd.u.reshape(&[r0, ms[k], ns[k], p])?;
            // carry diag(s)·Vt into core k+1
            let mut carry = tsvd.vt; // (p, r1)
            for (i, &sv) in tsvd.s.iter().enumerate() {
                let cols = carry.shape()[1];
                for x in &mut carry.data_mut()[i * cols..(i + 1) * cols] {
                    *x *= sv;
                }
            }
            let next = cores[k + 1].reshaped(&[r1, ms[k + 1] * ns[k + 1] * ranks[k + 2]])?;
            cores[k + 1] =
                matmul(&carry, &next)?.reshape(&[p, ms[k + 1], ns[k + 1], ranks[k + 2]])?;
            ranks[k + 1] = p;
        }

        let shape = TtShape::new(&ms, &ns, &ranks)?;
        TtMatrix::from_cores(shape, cores)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn rounding_exact_when_rank_suffices() {
        let shape = TtShape::uniform(&[3, 3, 3], &[3, 3, 3], 3).unwrap();
        let tt = TtMatrix::random(&shape, &mut Rng::new(1)).unwrap();
        let rounded = tt.round(Some(9), 0.0).unwrap();
        let w = tt.to_dense().unwrap();
        assert!(rounded.rel_error_vs(&w).unwrap() < 1e-5);
    }

    #[test]
    fn rounding_reduces_inflated_ranks() {
        // A + A has doubled ranks but represents 2A: rounding must recover
        // the original ranks exactly.
        let shape = TtShape::uniform(&[3, 3, 3], &[3, 3, 3], 2).unwrap();
        let tt = TtMatrix::random(&shape, &mut Rng::new(2)).unwrap();
        let doubled = tt.add(&tt).unwrap();
        assert!(doubled.shape().max_rank() == 4);
        let rounded = doubled.round(None, 1e-10).unwrap();
        assert!(rounded.shape().max_rank() <= 2, "ranks {:?}", rounded.shape().ranks());
        let mut want = tt.to_dense().unwrap();
        want.scale(2.0);
        assert!(rounded.rel_error_vs(&want).unwrap() < 1e-5);
    }

    #[test]
    fn rounding_respects_eps() {
        let shape = TtShape::uniform(&[4, 4], &[4, 4], 4).unwrap();
        let tt = TtMatrix::random(&shape, &mut Rng::new(3)).unwrap();
        let w = tt.to_dense().unwrap();
        for &eps in &[0.05f64, 0.2, 0.5] {
            let r = tt.round(None, eps).unwrap();
            let err = r.rel_error_vs(&w).unwrap();
            assert!(err <= eps + 1e-6, "err {err} > eps {eps}");
        }
    }

    #[test]
    fn rank_cap_binds() {
        let shape = TtShape::uniform(&[4, 4, 4], &[4, 4, 4], 6).unwrap();
        let tt = TtMatrix::random(&shape, &mut Rng::new(4)).unwrap();
        let r = tt.round(Some(2), 0.0).unwrap();
        assert!(r.shape().max_rank() <= 2);
        assert_eq!(r.m_total(), tt.m_total());
    }

    #[test]
    fn d1_noop() {
        let shape = TtShape::uniform(&[5], &[7], 1).unwrap();
        let tt = TtMatrix::random(&shape, &mut Rng::new(5)).unwrap();
        let r = tt.round(Some(1), 0.1).unwrap();
        assert!(r.rel_error_vs(&tt.to_dense().unwrap()).unwrap() < 1e-6);
    }
}
