//! Tensor-Train format (S3 in DESIGN.md) — the paper's §3 substrate,
//! built from scratch (a TT-Toolbox replacement).
//!
//! * [`TtShape`] — static shape/rank bookkeeping + parameter accounting
//!   (the paper's compression ratios are pure arithmetic over this).
//! * [`TtMatrix`] — a matrix `W (M x N)` stored as `d` cores
//!   `G_k (r_{k-1}, m_k, n_k, r_k)`; supports densification, fast
//!   matrix-by-batch products ([`TtMatrix::matvec`]), TT arithmetic
//!   (add / hadamard / scale / TT-by-TT matmul), decomposition of a dense
//!   matrix ([`TtMatrix::from_dense`], TT-SVD) and rank recompression
//!   ([`TtMatrix::round`]).
//! * [`TtVector`] — the analogous compressed vector (paper §3.1), used by
//!   the future-work path where layer inputs also live in TT format.
//!
//! Index convention is row-major everywhere (DESIGN.md §6).

mod init;
mod matvec;
mod ops;
mod round;
mod shape;
mod ttmat;
mod ttsvd;
mod ttvec;

pub use matvec::MatvecScratch;
pub use shape::TtShape;
pub use ttmat::TtMatrix;
pub use ttvec::TtVector;
