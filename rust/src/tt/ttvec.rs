//! TT-vectors (paper §3.1) and the TT-matrix-by-TT-vector product — the
//! machinery behind the paper's §7 future-work direction (layer inputs and
//! outputs kept in TT format, removing the `max{M, N}` dependency).

use crate::error::{shape_err, Result};
use crate::linalg::truncated_svd;
use crate::tensor::Tensor;
use crate::tt::TtMatrix;

/// A vector `b (N,)`, `N = Π n_k`, stored as `d` cores of shape
/// `(r_{k-1}, n_k, r_k)`; element `b(l) = B_1[j_1] ... B_d[j_d]`.
#[derive(Clone, Debug)]
pub struct TtVector {
    ns: Vec<usize>,
    ranks: Vec<usize>,
    cores: Vec<Tensor>,
}

impl TtVector {
    pub fn from_cores(cores: Vec<Tensor>) -> Result<TtVector> {
        if cores.is_empty() {
            return shape_err("TtVector needs at least one core");
        }
        let mut ns = Vec::with_capacity(cores.len());
        let mut ranks = vec![0usize; cores.len() + 1];
        for (k, c) in cores.iter().enumerate() {
            if c.ndim() != 3 {
                return shape_err(format!("vector core {k} must be 3-D, got {:?}", c.shape()));
            }
            if k == 0 {
                ranks[0] = c.shape()[0];
            } else if c.shape()[0] != ranks[k] {
                return shape_err(format!("rank chain broken at core {k}"));
            }
            ns.push(c.shape()[1]);
            ranks[k + 1] = c.shape()[2];
        }
        if ranks[0] != 1 || ranks[cores.len()] != 1 {
            return shape_err("boundary ranks must be 1");
        }
        Ok(TtVector { ns, ranks, cores })
    }

    pub fn ns(&self) -> &[usize] {
        &self.ns
    }

    pub fn ranks(&self) -> &[usize] {
        &self.ranks
    }

    pub fn cores(&self) -> &[Tensor] {
        &self.cores
    }

    pub fn d(&self) -> usize {
        self.ns.len()
    }

    pub fn n_total(&self) -> usize {
        self.ns.iter().product()
    }

    pub fn num_params(&self) -> usize {
        self.cores.iter().map(|c| c.numel()).sum()
    }

    /// TT-SVD of an explicit vector viewed as a `ns`-shaped tensor
    /// (row-major).
    pub fn from_dense(x: &Tensor, ns: &[usize], max_rank: Option<usize>, eps: f64) -> Result<TtVector> {
        let n_total: usize = ns.iter().product();
        if x.numel() != n_total {
            return shape_err(format!("vector len {} != prod {:?}", x.numel(), ns));
        }
        let d = ns.len();
        let norm = x.norm() as f64;
        let delta = if d > 1 { eps * norm / ((d - 1) as f64).sqrt() } else { 0.0 };
        let mut cores = Vec::with_capacity(d);
        let mut ranks = vec![1usize; d + 1];
        let mut rest = n_total;
        let mut c = x.reshaped(&[ns[0], rest / ns[0]])?;
        for k in 0..d - 1 {
            let tsvd = truncated_svd(&c, max_rank, delta)?;
            let rk = tsvd.s.len();
            ranks[k + 1] = rk;
            cores.push(tsvd.u.reshape(&[ranks[k], ns[k], rk])?);
            let mut carry = tsvd.vt;
            for (i, &sv) in tsvd.s.iter().enumerate() {
                let cols = carry.shape()[1];
                for v in &mut carry.data_mut()[i * cols..(i + 1) * cols] {
                    *v *= sv;
                }
            }
            rest /= ns[k];
            c = carry.reshape(&[rk * ns[k + 1], rest / ns[k + 1]])?;
        }
        cores.push(c.reshape(&[ranks[d - 1], ns[d - 1], 1])?);
        TtVector::from_cores(cores)
    }

    /// Densify to an explicit `(N,)` tensor.
    pub fn to_dense(&self) -> Result<Tensor> {
        // acc: (Na, r)
        let mut acc = self.cores[0].reshaped(&[self.ns[0], self.ranks[1]])?;
        for k in 1..self.d() {
            let (r0, n, r1) = (self.ranks[k], self.ns[k], self.ranks[k + 1]);
            let na = acc.shape()[0];
            let accd = acc.data();
            let core = self.cores[k].data();
            let mut out = vec![0.0f32; na * n * r1];
            for x in 0..na {
                for j in 0..n {
                    let obase = (x * n + j) * r1;
                    for r in 0..r0 {
                        let a = accd[x * r0 + r];
                        if a != 0.0 {
                            let cbase = (r * n + j) * r1;
                            for s in 0..r1 {
                                out[obase + s] += a * core[cbase + s];
                            }
                        }
                    }
                }
            }
            acc = Tensor::from_vec(&[na * n, r1], out)?;
        }
        acc.reshape(&[self.n_total()])
    }

    /// Inner product without densifying.
    pub fn dot(&self, other: &TtVector) -> Result<f64> {
        if self.ns != other.ns {
            return shape_err(format!("dot: {:?} vs {:?}", self.ns, other.ns));
        }
        let mut v = vec![1.0f64];
        for k in 0..self.d() {
            let (a0, n, a1) = (self.ranks[k], self.ns[k], self.ranks[k + 1]);
            let (b0, b1) = (other.ranks[k], other.ranks[k + 1]);
            let ca = self.cores[k].data();
            let cb = other.cores[k].data();
            let mut nv = vec![0.0f64; a1 * b1];
            for j in 0..n {
                let mut w = vec![0.0f64; a0 * b1];
                for a in 0..a0 {
                    for b in 0..b0 {
                        let vv = v[a * b0 + b];
                        if vv != 0.0 {
                            let bbase = (b * n + j) * b1;
                            for sb in 0..b1 {
                                w[a * b1 + sb] += vv * cb[bbase + sb] as f64;
                            }
                        }
                    }
                }
                for a in 0..a0 {
                    let abase = (a * n + j) * a1;
                    for sa in 0..a1 {
                        let av = ca[abase + sa] as f64;
                        if av != 0.0 {
                            for sb in 0..b1 {
                                nv[sa * b1 + sb] += av * w[a * b1 + sb];
                            }
                        }
                    }
                }
            }
            v = nv;
        }
        Ok(v[0])
    }

    pub fn norm(&self) -> Result<f64> {
        Ok(self.dot(self)?.max(0.0).sqrt())
    }

    /// `alpha * b`.
    pub fn scale(&self, alpha: f32) -> Result<TtVector> {
        let mut cores = self.cores.clone();
        cores[0].scale(alpha);
        TtVector::from_cores(cores)
    }

    /// `b + c` (ranks add).
    pub fn add(&self, other: &TtVector) -> Result<TtVector> {
        if self.ns != other.ns {
            return shape_err(format!("add: {:?} vs {:?}", self.ns, other.ns));
        }
        let d = self.d();
        let mut cores = Vec::with_capacity(d);
        for k in 0..d {
            let (a0, n, a1) = (self.ranks[k], self.ns[k], self.ranks[k + 1]);
            let (b0, b1) = (other.ranks[k], other.ranks[k + 1]);
            let c0 = if k == 0 { 1 } else { a0 + b0 };
            let c1 = if k == d - 1 { 1 } else { a1 + b1 };
            let mut core = Tensor::zeros(&[c0, n, c1]);
            let ca = self.cores[k].data();
            let cb = other.cores[k].data();
            let cd = core.data_mut();
            for r in 0..a0 {
                for j in 0..n {
                    let src = (r * n + j) * a1;
                    let dst = (r * n + j) * c1;
                    for s in 0..a1 {
                        cd[dst + s] += ca[src + s];
                    }
                }
            }
            let (off0, off1) = (c0 - b0, c1 - b1);
            for r in 0..b0 {
                for j in 0..n {
                    let src = (r * n + j) * b1;
                    let dst = ((r + off0) * n + j) * c1 + off1;
                    for s in 0..b1 {
                        cd[dst + s] += cb[src + s];
                    }
                }
            }
            cores.push(core);
        }
        TtVector::from_cores(cores)
    }
}

impl TtMatrix {
    /// `W · b` with both operands in TT format: the result is a TT-vector
    /// with ranks `r_k(W) · r_k(b)` — the "even more efficient" case of
    /// §3.1 and the §7 future-work building block.
    pub fn matvec_tt(&self, b: &TtVector) -> Result<TtVector> {
        if self.shape().ns() != b.ns() {
            return shape_err(format!("matvec_tt: {} x {:?}", self.shape(), b.ns()));
        }
        let d = self.d();
        let mut cores = Vec::with_capacity(d);
        for k in 0..d {
            let [a0, m, n, a1] = self.shape().core_shape(k);
            let (b0, b1) = (b.ranks()[k], b.ranks()[k + 1]);
            let ca = self.cores()[k].data();
            let cb = b.cores()[k].data();
            let mut core = Tensor::zeros(&[a0 * b0, m, a1 * b1]);
            let cd = core.data_mut();
            let c1 = a1 * b1;
            for ra in 0..a0 {
                for rb in 0..b0 {
                    let r = ra * b0 + rb;
                    for i in 0..m {
                        let dbase = (r * m + i) * c1;
                        for j in 0..n {
                            let abase = ((ra * m + i) * n + j) * a1;
                            let bbase = (rb * n + j) * b1;
                            for sa in 0..a1 {
                                let av = ca[abase + sa];
                                if av != 0.0 {
                                    for sb in 0..b1 {
                                        cd[dbase + sa * b1 + sb] += av * cb[bbase + sb];
                                    }
                                }
                            }
                        }
                    }
                }
            }
            cores.push(core);
        }
        TtVector::from_cores(cores)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::matvec as dense_matvec;
    use crate::tt::TtShape;
    use crate::util::rng::Rng;

    #[test]
    fn from_dense_roundtrip() {
        let mut rng = Rng::new(1);
        let x = Tensor::randn(&[24], 1.0, &mut rng);
        let v = TtVector::from_dense(&x, &[2, 3, 4], None, 0.0).unwrap();
        let back = v.to_dense().unwrap();
        for (a, b) in back.data().iter().zip(x.data()) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn dot_and_norm_match_dense() {
        let mut rng = Rng::new(2);
        let x = Tensor::randn(&[36], 1.0, &mut rng);
        let y = Tensor::randn(&[36], 1.0, &mut rng);
        let vx = TtVector::from_dense(&x, &[3, 3, 4], None, 0.0).unwrap();
        let vy = TtVector::from_dense(&y, &[3, 3, 4], None, 0.0).unwrap();
        let want = x.dot(&y).unwrap() as f64;
        assert!((vx.dot(&vy).unwrap() - want).abs() < 1e-4 * (1.0 + want.abs()));
        assert!((vx.norm().unwrap() - x.norm() as f64).abs() < 1e-4);
    }

    #[test]
    fn add_scale_match_dense() {
        let mut rng = Rng::new(3);
        let x = Tensor::randn(&[12], 1.0, &mut rng);
        let y = Tensor::randn(&[12], 1.0, &mut rng);
        let vx = TtVector::from_dense(&x, &[3, 4], None, 0.0).unwrap();
        let vy = TtVector::from_dense(&y, &[3, 4], None, 0.0).unwrap();
        let sum = vx.add(&vy.scale(-2.0).unwrap()).unwrap().to_dense().unwrap();
        for i in 0..12 {
            let want = x.data()[i] - 2.0 * y.data()[i];
            assert!((sum.data()[i] - want).abs() < 1e-5);
        }
    }

    #[test]
    fn matvec_tt_matches_dense() {
        let mut rng = Rng::new(4);
        let shape = TtShape::uniform(&[2, 3], &[3, 4], 2).unwrap();
        let w = TtMatrix::random(&shape, &mut rng).unwrap();
        let x = Tensor::randn(&[12], 1.0, &mut rng);
        let vx = TtVector::from_dense(&x, &[3, 4], None, 0.0).unwrap();
        let got = w.matvec_tt(&vx).unwrap().to_dense().unwrap();
        let want = dense_matvec(&w.to_dense().unwrap(), &x).unwrap();
        for (a, b) in got.data().iter().zip(want.data()) {
            assert!((a - b).abs() < 1e-4 * (1.0 + b.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn truncation_compresses_smooth_vector() {
        // low "TT-rank" signal: rank-1 separable tensor
        let mut data = vec![0.0f32; 64];
        for i in 0..4 {
            for j in 0..4 {
                for k in 0..4 {
                    data[(i * 4 + j) * 4 + k] = ((i + 1) * (j + 2)) as f32 * (k as f32).sin();
                }
            }
        }
        let x = Tensor::from_vec(&[64], data).unwrap();
        let v = TtVector::from_dense(&x, &[4, 4, 4], None, 1e-6).unwrap();
        assert!(v.ranks().iter().all(|&r| r <= 2), "ranks {:?}", v.ranks());
    }

    #[test]
    fn validation_errors() {
        assert!(TtVector::from_cores(vec![]).is_err());
        assert!(TtVector::from_cores(vec![Tensor::zeros(&[2, 3, 1])]).is_err()); // r0 != 1
        let ok = TtVector::from_cores(vec![Tensor::zeros(&[1, 3, 1])]);
        assert!(ok.is_ok());
    }
}
