//! Random TT initialization (paper §6.4: i.i.d. Gaussian cores).

use crate::error::Result;
use crate::tensor::Tensor;
use crate::tt::{TtMatrix, TtShape, TtVector};
use crate::util::rng::Rng;

impl TtMatrix {
    /// Gaussian cores with the variance-preserving std of
    /// [`TtShape::init_std`] — the reconstructed `W` has He-style scale.
    pub fn random(shape: &TtShape, rng: &mut Rng) -> Result<TtMatrix> {
        let std = shape.init_std();
        let cores = (0..shape.d())
            .map(|k| Tensor::randn(&shape.core_shape(k), std, rng))
            .collect();
        TtMatrix::from_cores(shape.clone(), cores)
    }

    /// Gaussian cores with an explicit per-core std (ablations).
    pub fn random_with_std(shape: &TtShape, std: f32, rng: &mut Rng) -> Result<TtMatrix> {
        let cores = (0..shape.d())
            .map(|k| Tensor::randn(&shape.core_shape(k), std, rng))
            .collect();
        TtMatrix::from_cores(shape.clone(), cores)
    }
}

impl TtVector {
    /// Gaussian TT-vector with unit-ish element scale.
    pub fn random(ns: &[usize], ranks: &[usize], rng: &mut Rng) -> Result<TtVector> {
        let d = ns.len();
        let paths: f64 = ranks[1..d].iter().product::<usize>() as f64;
        let std = ((1.0 / paths).powf(1.0 / (2.0 * d as f64))) as f32;
        let cores = (0..d)
            .map(|k| Tensor::randn(&[ranks[k], ns[k], ranks[k + 1]], std, rng))
            .collect();
        TtVector::from_cores(cores)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_matrix_scale() {
        // Var of reconstructed elements should be ~2/N
        let shape = TtShape::uniform(&[4, 4, 4], &[4, 4, 4], 4).unwrap();
        let mut rng = Rng::new(0);
        let tt = TtMatrix::random(&shape, &mut rng).unwrap();
        let w = tt.to_dense().unwrap();
        let n = w.numel() as f64;
        let var = w.data().iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>() / n;
        let want = 2.0 / 64.0;
        assert!(
            var > want * 0.25 && var < want * 4.0,
            "var {var} vs target {want}"
        );
    }

    #[test]
    fn random_is_deterministic_per_seed() {
        let shape = TtShape::uniform(&[2, 2], &[2, 2], 2).unwrap();
        let a = TtMatrix::random(&shape, &mut Rng::new(7)).unwrap();
        let b = TtMatrix::random(&shape, &mut Rng::new(7)).unwrap();
        assert_eq!(a.cores()[0], b.cores()[0]);
        assert_eq!(a.cores()[1], b.cores()[1]);
    }

    #[test]
    fn random_vector_shapes() {
        let v = TtVector::random(&[3, 4, 5], &[1, 2, 2, 1], &mut Rng::new(1)).unwrap();
        assert_eq!(v.n_total(), 60);
        assert_eq!(v.cores()[1].shape(), &[2, 4, 2]);
    }
}
