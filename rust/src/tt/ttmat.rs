//! The TT-matrix: storage, densification, element access.

use crate::error::{shape_err, Error, Result};
use crate::tensor::Tensor;
use crate::tt::TtShape;

/// A matrix `W (M x N)` in Tensor-Train format (paper §3.1): `d` cores
/// `G_k` of shape `(r_{k-1}, m_k, n_k, r_k)` with
/// `W(t, l) = G_1[i_1, j_1] · ... · G_d[i_d, j_d]` for the row-major
/// multi-indices `t = (i_1..i_d)`, `l = (j_1..j_d)`.
#[derive(Clone, Debug)]
pub struct TtMatrix {
    shape: TtShape,
    cores: Vec<Tensor>,
    /// cached GEMM operands: core k flattened to `(r_{k-1}·n_k, m_k·r_k)`
    /// with K ordered `(r_{k-1}, n_k)` — same layout as the Pallas kernel's
    /// `core_to_matrix` (python/compile/kernels/tt_contract.py).
    core_mats: Vec<Tensor>,
}

impl TtMatrix {
    /// Build from cores; validates every core against `shape`.
    pub fn from_cores(shape: TtShape, cores: Vec<Tensor>) -> Result<Self> {
        if cores.len() != shape.d() {
            return shape_err(format!("{} cores for d={}", cores.len(), shape.d()));
        }
        for (k, core) in cores.iter().enumerate() {
            let want = shape.core_shape(k);
            if core.shape() != want {
                return shape_err(format!("core {k}: shape {:?}, want {:?}", core.shape(), want));
            }
        }
        let core_mats = cores
            .iter()
            .map(|c| core_to_matrix(c))
            .collect::<Result<Vec<_>>>()?;
        Ok(TtMatrix { shape, cores, core_mats })
    }

    pub fn shape(&self) -> &TtShape {
        &self.shape
    }

    pub fn cores(&self) -> &[Tensor] {
        &self.cores
    }

    pub fn core_mats(&self) -> &[Tensor] {
        &self.core_mats
    }

    pub fn d(&self) -> usize {
        self.shape.d()
    }

    pub fn m_total(&self) -> usize {
        self.shape.m_total()
    }

    pub fn n_total(&self) -> usize {
        self.shape.n_total()
    }

    pub fn num_params(&self) -> usize {
        self.shape.num_params()
    }

    pub fn compression(&self) -> f64 {
        self.shape.compression()
    }

    /// Replace core `k` (used by the training engine's SGD update).
    pub fn set_core(&mut self, k: usize, core: Tensor) -> Result<()> {
        let want = self.shape.core_shape(k);
        if core.shape() != want {
            return shape_err(format!("set_core {k}: {:?}, want {:?}", core.shape(), want));
        }
        self.core_mats[k] = core_to_matrix(&core)?;
        self.cores[k] = core;
        Ok(())
    }

    /// The transposed TT-matrix `Wᵀ (N x M)`: every core swaps its row and
    /// column mode — no arithmetic, just permutes.  Used by backprop for
    /// `dL/dx = Wᵀ · dL/dy` (paper eq. 6).
    pub fn transpose(&self) -> Result<TtMatrix> {
        let shape = TtShape::new(self.shape.ns(), self.shape.ms(), self.shape.ranks())?;
        let cores = self
            .cores
            .iter()
            .map(|c| c.permute(&[0, 2, 1, 3]))
            .collect::<Result<Vec<_>>>()?;
        TtMatrix::from_cores(shape, cores)
    }

    /// Single element `W(t, l)` by multiplying the core chain — `O(d r^2)`.
    pub fn element(&self, t: usize, l: usize) -> Result<f32> {
        if t >= self.m_total() || l >= self.n_total() {
            return shape_err(format!("element ({t},{l}) out of range"));
        }
        let d = self.d();
        // decompose row-major multi-indices
        let mut iks = vec![0usize; d];
        let mut jks = vec![0usize; d];
        let (mut tt, mut ll) = (t, l);
        for k in (0..d).rev() {
            iks[k] = tt % self.shape.ms()[k];
            tt /= self.shape.ms()[k];
            jks[k] = ll % self.shape.ns()[k];
            ll /= self.shape.ns()[k];
        }
        // v (1 x r) running product
        let mut v = vec![1.0f64];
        for k in 0..d {
            let [r0, _m, n, r1] = self.shape.core_shape(k);
            let core = self.cores[k].data();
            let (i, j) = (iks[k], jks[k]);
            let mut nv = vec![0.0f64; r1];
            for (a, &va) in v.iter().enumerate() {
                if va != 0.0 {
                    let base = ((a * self.shape.ms()[k] + i) * n + j) * r1;
                    for (b, slot) in nv.iter_mut().enumerate() {
                        *slot += va * core[base + b] as f64;
                    }
                }
            }
            debug_assert_eq!(v.len(), r0);
            v = nv;
        }
        Ok(v[0] as f32)
    }

    /// Densify to the explicit `(M, N)` matrix.
    ///
    /// Cost `O(M · N · max r^2)` — fine for the experiment sizes that need
    /// it (tests, MR baselines, Fig. 1 reconstructions).
    pub fn to_dense(&self) -> Result<Tensor> {
        // acc: (Ma, Na, r) with Ma/Na the products of processed modes
        let [_, m0, n0, r1] = self.shape.core_shape(0);
        let mut acc = self.cores[0].reshaped(&[m0, n0, r1])?;
        for k in 1..self.d() {
            let [r0, m, n, r1] = self.shape.core_shape(k);
            let (ma, na) = (acc.shape()[0], acc.shape()[1]);
            let core = self.cores[k].data();
            let accd = acc.data();
            let mut out = vec![0.0f32; ma * m * na * n * r1];
            let out_cols = na * n * r1;
            for x in 0..ma {
                for y in 0..na {
                    let acc_base = (x * na + y) * r0;
                    for i in 0..m {
                        for j in 0..n {
                            let out_base = (x * m + i) * out_cols + (y * n + j) * r1;
                            for r in 0..r0 {
                                let a = accd[acc_base + r];
                                if a != 0.0 {
                                    let core_base = ((r * m + i) * n + j) * r1;
                                    for s in 0..r1 {
                                        out[out_base + s] += a * core[core_base + s];
                                    }
                                }
                            }
                        }
                    }
                }
            }
            acc = Tensor::from_vec(&[ma * m, na * n, r1], out)?;
        }
        if acc.shape()[2] != 1 {
            return Err(Error::Shape("boundary rank != 1".into()));
        }
        acc.reshape(&[self.m_total(), self.n_total()])
    }
}

/// Flatten a core `(r0, m, n, r1)` to the GEMM operand `(r0·n, m·r1)`,
/// K axis ordered `(r0, n)` — mirrors the L1 kernel layout exactly.
pub(crate) fn core_to_matrix(core: &Tensor) -> Result<Tensor> {
    if core.ndim() != 4 {
        return shape_err(format!("core must be 4-D, got {:?}", core.shape()));
    }
    let s = core.shape().to_vec();
    core.permute(&[0, 2, 1, 3])?.reshape(&[s[0] * s[2], s[1] * s[3]])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tt::TtMatrix;
    use crate::util::rng::Rng;

    fn random_tt(ms: &[usize], ns: &[usize], r: usize, seed: u64) -> TtMatrix {
        let shape = TtShape::uniform(ms, ns, r).unwrap();
        TtMatrix::random(&shape, &mut Rng::new(seed)).unwrap()
    }

    #[test]
    fn from_cores_validates() {
        let shape = TtShape::uniform(&[2, 2], &[3, 3], 2).unwrap();
        let bad = vec![Tensor::zeros(&[1, 2, 3, 2]), Tensor::zeros(&[2, 2, 2, 1])];
        assert!(TtMatrix::from_cores(shape.clone(), bad).is_err());
        let good = vec![Tensor::zeros(&[1, 2, 3, 2]), Tensor::zeros(&[2, 2, 3, 1])];
        assert!(TtMatrix::from_cores(shape, good).is_ok());
    }

    #[test]
    fn element_matches_dense() {
        let tt = random_tt(&[2, 3, 2], &[3, 2, 2], 3, 1);
        let w = tt.to_dense().unwrap();
        for &(t, l) in &[(0, 0), (5, 7), (11, 11), (3, 0)] {
            let e = tt.element(t, l).unwrap();
            assert!((e - w.at(&[t, l])).abs() < 1e-5, "({t},{l})");
        }
    }

    #[test]
    fn rank1_tt_is_kronecker() {
        // rank-1: W = A ⊗ B for 1x1 cores... use d=2, r=1:
        // W((i1,i2),(j1,j2)) = G1[i1,j1] * G2[i2,j2]
        let shape = TtShape::uniform(&[2, 2], &[2, 2], 1).unwrap();
        let mut rng = Rng::new(2);
        let g1 = Tensor::randn(&[1, 2, 2, 1], 1.0, &mut rng);
        let g2 = Tensor::randn(&[1, 2, 2, 1], 1.0, &mut rng);
        let tt = TtMatrix::from_cores(shape, vec![g1.clone(), g2.clone()]).unwrap();
        let w = tt.to_dense().unwrap();
        for i1 in 0..2 {
            for i2 in 0..2 {
                for j1 in 0..2 {
                    for j2 in 0..2 {
                        let want = g1.at(&[0, i1, j1, 0]) * g2.at(&[0, i2, j2, 0]);
                        let got = w.at(&[i1 * 2 + i2, j1 * 2 + j2]);
                        assert!((want - got).abs() < 1e-6);
                    }
                }
            }
        }
    }

    #[test]
    fn transpose_matches_dense_transpose() {
        let tt = random_tt(&[2, 3], &[4, 2], 2, 3);
        let wt = tt.transpose().unwrap().to_dense().unwrap();
        let w = tt.to_dense().unwrap();
        assert_eq!(wt.shape(), &[8, 6]);
        for t in 0..6 {
            for l in 0..8 {
                assert!((w.at(&[t, l]) - wt.at(&[l, t])).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn set_core_updates_cached_mat() {
        let mut tt = random_tt(&[2, 2], &[2, 2], 2, 4);
        let before = tt.core_mats()[0].clone();
        let mut rng = Rng::new(5);
        let new_core = Tensor::randn(&[1, 2, 2, 2], 1.0, &mut rng);
        tt.set_core(0, new_core).unwrap();
        assert_ne!(&before, &tt.core_mats()[0]);
        assert!(tt.set_core(0, Tensor::zeros(&[2, 2, 2, 2])).is_err());
    }

    #[test]
    fn core_to_matrix_layout() {
        // element (a0*n + j, i*r1 + a1) == core[a0, i, j, a1]
        let (r0, m, n, r1) = (2usize, 3usize, 4usize, 2usize);
        let data: Vec<f32> = (0..r0 * m * n * r1).map(|x| x as f32).collect();
        let core = Tensor::from_vec(&[r0, m, n, r1], data).unwrap();
        let cm = core_to_matrix(&core).unwrap();
        assert_eq!(cm.shape(), &[r0 * n, m * r1]);
        for a0 in 0..r0 {
            for i in 0..m {
                for j in 0..n {
                    for a1 in 0..r1 {
                        assert_eq!(cm.at(&[a0 * n + j, i * r1 + a1]), core.at(&[a0, i, j, a1]));
                    }
                }
            }
        }
    }
}
