//! TT shape / rank bookkeeping (rust mirror of `python/compile/shapes.py`).

use crate::error::{shape_err, Result};

/// Static description of a TT-matrix: row modes, column modes, ranks.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TtShape {
    ms: Vec<usize>,
    ns: Vec<usize>,
    ranks: Vec<usize>,
}

impl TtShape {
    /// Validated constructor. `ranks` has length `d + 1` with boundary 1s.
    pub fn new(ms: &[usize], ns: &[usize], ranks: &[usize]) -> Result<Self> {
        if ms.len() != ns.len() || ms.is_empty() {
            return shape_err(format!("ms/ns mismatch: {:?} vs {:?}", ms, ns));
        }
        if ranks.len() != ms.len() + 1 {
            return shape_err(format!("need d+1 ranks, got {:?}", ranks));
        }
        if ranks[0] != 1 || ranks[ranks.len() - 1] != 1 {
            return shape_err("boundary TT-ranks must be 1");
        }
        if ms.iter().chain(ns).chain(ranks).any(|&x| x == 0) {
            return shape_err("zero mode size or rank");
        }
        Ok(TtShape { ms: ms.to_vec(), ns: ns.to_vec(), ranks: ranks.to_vec() })
    }

    /// Uniform ranks `(1, r, ..., r, 1)` — the paper's `TT<r>` notation.
    pub fn uniform(ms: &[usize], ns: &[usize], r: usize) -> Result<Self> {
        let d = ms.len();
        let mut ranks = vec![r; d + 1];
        ranks[0] = 1;
        ranks[d] = 1;
        TtShape::new(ms, ns, &ranks)
    }

    pub fn d(&self) -> usize {
        self.ms.len()
    }

    pub fn ms(&self) -> &[usize] {
        &self.ms
    }

    pub fn ns(&self) -> &[usize] {
        &self.ns
    }

    pub fn ranks(&self) -> &[usize] {
        &self.ranks
    }

    pub fn m_total(&self) -> usize {
        self.ms.iter().product()
    }

    pub fn n_total(&self) -> usize {
        self.ns.iter().product()
    }

    pub fn max_rank(&self) -> usize {
        *self.ranks.iter().max().unwrap()
    }

    /// Shape of core `k`: `(r_{k-1}, m_k, n_k, r_k)`.
    pub fn core_shape(&self, k: usize) -> [usize; 4] {
        [self.ranks[k], self.ms[k], self.ns[k], self.ranks[k + 1]]
    }

    /// Number of parameters in the cores (the paper's compression numerator
    /// is `dense_params / num_params`).
    pub fn num_params(&self) -> usize {
        (0..self.d()).map(|k| self.core_shape(k).iter().product::<usize>()).sum()
    }

    pub fn dense_params(&self) -> usize {
        self.m_total() * self.n_total()
    }

    pub fn compression(&self) -> f64 {
        self.dense_params() as f64 / self.num_params() as f64
    }

    /// Per-core init std giving the reconstructed W He-style variance 2/N
    /// (same formula as `python/compile/shapes.py::TtShape.init_std`).
    pub fn init_std(&self) -> f32 {
        let paths: f64 = self.ranks[1..self.d()].iter().product::<usize>() as f64;
        let target = 2.0 / self.n_total() as f64;
        ((target / paths).powf(1.0 / (2.0 * self.d() as f64))) as f32
    }

    /// Clamp every internal rank to `cap` (boundaries stay 1); used to
    /// express "all TT-ranks equal r" configurations from Table 2.
    pub fn with_rank_cap(&self, cap: usize) -> TtShape {
        let d = self.d();
        let mut ranks = self.ranks.clone();
        for r in ranks.iter_mut().take(d).skip(1) {
            *r = (*r).min(cap).max(1);
        }
        TtShape { ms: self.ms.clone(), ns: self.ns.clone(), ranks }
    }

    /// Maximal representable ranks for these modes (any tensor of this
    /// matrix shape admits a TT-decomposition within these ranks —
    /// Oseledets Th. 2.1).
    pub fn full_ranks(ms: &[usize], ns: &[usize]) -> Vec<usize> {
        let d = ms.len();
        let mut ranks = vec![1usize; d + 1];
        for k in 1..d {
            let left: usize = (0..k).map(|i| ms[i] * ns[i]).product();
            let right: usize = (k..d).map(|i| ms[i] * ns[i]).product();
            ranks[k] = left.min(right);
        }
        ranks
    }
}

impl std::fmt::Display for TtShape {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "TT[{}x{}; modes {:?}x{:?}; ranks {:?}; params {}]",
            self.m_total(),
            self.n_total(),
            self.ms,
            self.ns,
            self.ranks,
            self.num_params()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validation() {
        assert!(TtShape::new(&[2, 2], &[2], &[1, 2, 1]).is_err());
        assert!(TtShape::new(&[2, 2], &[2, 2], &[1, 2]).is_err());
        assert!(TtShape::new(&[2, 2], &[2, 2], &[2, 2, 1]).is_err());
        assert!(TtShape::new(&[2, 0], &[2, 2], &[1, 2, 1]).is_err());
        assert!(TtShape::new(&[2, 2], &[2, 2], &[1, 2, 1]).is_ok());
    }

    #[test]
    fn param_accounting() {
        let s = TtShape::new(&[2, 3, 4], &[5, 6, 7], &[1, 3, 2, 1]).unwrap();
        assert_eq!(s.num_params(), 2 * 5 * 3 + 3 * 3 * 6 * 2 + 2 * 4 * 7);
        assert_eq!(s.dense_params(), 24 * 210);
    }

    #[test]
    fn paper_mnist_rank8_params() {
        let s = TtShape::uniform(&[4; 5], &[4; 5], 8).unwrap();
        assert_eq!(s.num_params(), 3328);
        assert_eq!(s.dense_params(), 1024 * 1024);
    }

    #[test]
    fn paper_table2_tt2_compression() {
        // vgg fc6, rank 2: 25088x4096 -> 528 params (Table 2 row TT2)
        let s = TtShape::uniform(&[4, 4, 4, 4, 4, 4], &[2, 7, 8, 8, 7, 4], 2).unwrap();
        assert_eq!(s.num_params(), 528);
        assert!((s.compression() - 194_621.0).abs() / 194_621.0 < 0.01);
    }

    #[test]
    fn full_ranks_bound() {
        let r = TtShape::full_ranks(&[2, 2, 2], &[2, 2, 2]);
        assert_eq!(r, vec![1, 4, 4, 1]);
    }

    #[test]
    fn rank_cap() {
        let s = TtShape::new(&[2, 2, 2], &[2, 2, 2], &[1, 4, 4, 1]).unwrap();
        let c = s.with_rank_cap(2);
        assert_eq!(c.ranks(), &[1, 2, 2, 1]);
    }
}
