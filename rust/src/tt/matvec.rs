//! The TT-layer forward product `Y = X Wᵀ` for a batch of rows — the
//! paper's eq. (5), `O(d r² m max{M, N})` per sample instead of `O(MN)`.
//!
//! Mirrors the L2 jax sweep exactly (python/compile/model.py
//! `tt_layer_forward`): the state tensor starts as `(B, 1, N, 1)` and after
//! core `k` has shape `(B, M_done, N_rest, r_k)`; every step is one GEMM
//! against the cached `(r·n, m·r')` core matrix.
//!
//! The permutations around each GEMM are fused into custom pack/unpack
//! loops (no `Tensor::permute` allocations on the hot path), and
//! [`MatvecScratch`] lets a serving worker reuse its buffers across calls.
//!
//! Large batches take the COOPERATIVE path (perf pass iteration #10):
//! instead of pack → one big GEMM → unpack as three global passes, the
//! per-(batch·M_done) groups — which are fully independent — are sliced
//! across `parallel_chunks_mut` workers, each fusing gather → contract →
//! scatter for its run of groups.  One hot batch is thereby worked on by
//! every kernel thread the caller's [`thread_budget`] allows (an
//! executor-pool worker no longer runs a whole batch alone while sibling
//! threads idle), and the two full pack/unpack copies of the state
//! tensor disappear.  Small batches keep the original path, whose
//! batch-1 column-parallel GEMM is the tuned Table-3 latency case.

use crate::error::{shape_err, Result};
use crate::tensor::simd::{kernels, Kernels};
use crate::tensor::{Gemm, Tensor};
use crate::tt::TtMatrix;
use crate::util::threads::{parallel_chunks_mut, parallel_chunks_mut2, thread_budget};

/// Reusable buffers for [`TtMatrix::matvec_with`].
///
/// Three buffers cycle through the sweep: `a` seeds the state buffer
/// (recycled from a previous call's spent buffer), `b` holds the packed
/// GEMM operand (small-batch path) or the fused path's output, which
/// swaps with the state buffer per core, `c` the GEMM output.  In steady
/// state a serving worker calling with a fixed input shape performs
/// exactly ONE heap allocation per call — the buffer that leaves inside
/// the returned tensor — everything else retains capacity across calls,
/// and (since every element is overwritten before it is read) the
/// buffers are resized without re-zeroing, so same-shape calls also do
/// no per-call memset (see [`resize_for_overwrite`]).
#[derive(Default, Clone, Debug)]
pub struct MatvecScratch {
    /// sweep-state buffer; capacity retained across calls
    a: Vec<f32>,
    /// packed GEMM operand `(rows, r0·n)` / fused-path output
    b: Vec<f32>,
    /// GEMM output `(rows, m·r1)`; donated to `a` at the end of each call
    c: Vec<f32>,
    /// fused-path contract accumulators: one `m·r1` slab per worker
    /// chunk, grow-only high-water pool (`contract_group` zeroes its
    /// slab per group-column, so the pool itself is never re-zeroed)
    acc: Vec<f32>,
}

impl TtMatrix {
    /// `Y (B, M) = X (B, N) · Wᵀ` — apply the TT linear map to each row.
    pub fn matvec(&self, x: &Tensor) -> Result<Tensor> {
        let mut scratch = MatvecScratch::default();
        self.matvec_with(x, &mut scratch)
    }

    /// [`TtMatrix::matvec`] with caller-owned scratch buffers.
    pub fn matvec_with(&self, x: &Tensor, scratch: &mut MatvecScratch) -> Result<Tensor> {
        if x.ndim() != 2 || x.shape()[1] != self.n_total() {
            return shape_err(format!(
                "matvec: input {:?}, want (B, {})",
                x.shape(),
                self.n_total()
            ));
        }
        let b = x.shape()[0];
        let d = self.d();
        let gemm = Gemm::default();

        // state: logically (B, M_done, N_rest, r); stored flat in `cur`.
        // The first pack reads straight from `x`, so the input is never
        // copied into a staging buffer.
        let mut m_done = 1usize;
        let mut n_rest = self.n_total();
        let mut r = 1usize;
        let mut cur = std::mem::take(&mut scratch.a);

        for k in 0..d {
            let [r0, m, n, r1] = self.shape().core_shape(k);
            debug_assert_eq!(r, r0);
            let rest = n_rest / n;
            let rows = b * m_done * rest;
            let groups = b * m_done;
            let in_block = n * rest * r0;
            let out_block = rest * m * r1;

            let src: &[f32] = if k == 0 { x.data() } else { &cur };
            if groups >= 4 && groups * in_block.max(out_block) >= (1 << 16) {
                // cooperative fused path: each group's gather → contract →
                // scatter is independent, so slice the group range across
                // the kernel thread budget.  Output goes into `scratch.b`
                // (free here — no pack operand is materialized) and swaps
                // with `cur` afterwards, because `cur` IS the input and
                // in_block ≠ out_block in general (in-place would let one
                // group's output clobber another's unread input).
                let core = self.core_mats()[k].data();
                let kern = kernels();
                resize_for_overwrite(&mut scratch.b, groups * out_block);
                let gpt = groups.div_ceil(thread_budget().min(groups));
                // one contract accumulator slab per worker chunk, pooled
                // in scratch (grow-only: cores of one sweep want
                // different m·r1, and shrinking would re-zero the grown
                // tail every call)
                let n_chunks = groups.div_ceil(gpt);
                if scratch.acc.len() < n_chunks * m * r1 {
                    scratch.acc.resize(n_chunks * m * r1, 0.0);
                }
                parallel_chunks_mut2(
                    &mut scratch.b,
                    gpt * out_block,
                    &mut scratch.acc,
                    m * r1,
                    |start, dst, acc| {
                        let g0 = start / out_block;
                        for (gi, dst_g) in dst.chunks_mut(out_block).enumerate() {
                            let g = g0 + gi;
                            contract_group(
                                &src[g * in_block..(g + 1) * in_block],
                                core,
                                n,
                                rest,
                                r0,
                                r1,
                                acc,
                                dst_g,
                                kern,
                            );
                        }
                    },
                );
                std::mem::swap(&mut cur, &mut scratch.b);
            } else {
                // pack: (B, M, n, rest, r0) -> (B, M, rest, r0, n)
                // flattened as the GEMM operand (rows, r0*n)
                let packed = pack_a(src, groups, n, rest, r0, &mut scratch.b);

                // GEMM against cached core matrix (r0*n, m*r1), written
                // into the retained scratch buffer — no allocation once
                // warm
                let a_t = Tensor::from_vec(&[rows, r0 * n], std::mem::take(packed))?;
                gemm.matmul_into(&a_t, &self.core_mats()[k], &mut scratch.c)?;
                scratch.b = a_t.into_vec(); // return buffer for reuse

                // unpack: (B, M, rest, m, r1) -> (B, M, m, rest, r1)
                cur = unpack_out(&scratch.c, groups, rest, m, r1, &mut cur);
            }

            m_done *= m;
            n_rest = rest;
            r = r1;
        }
        debug_assert_eq!(r, 1);
        debug_assert_eq!(n_rest, 1);
        let y = Tensor::from_vec(&[b, self.m_total()], cur)?;
        // `cur`'s allocation leaves inside `y`; recycle the spent GEMM
        // buffer as the next call's state buffer so capacity survives
        // across serving-worker invocations (this used to be
        // `scratch.a = Vec::new()`, reallocating every call)
        scratch.a = std::mem::take(&mut scratch.c);
        if scratch.a.capacity() == 0 {
            // an all-fused sweep never touches the GEMM output buffer;
            // recycle the fused path's spent input buffer instead so
            // steady state stays at one allocation per call
            scratch.a = std::mem::take(&mut scratch.b);
        }
        Ok(y)
    }
}

/// Size `buf` to exactly `want` elements WITHOUT re-zeroing retained
/// memory: shrinking truncates, growing zero-fills only the grown tail,
/// and the steady-state same-length case does nothing at all.  Only for
/// buffers whose every element is overwritten before it is read (the
/// pack/unpack/fused loops below cover their output exactly) — the old
/// `clear(); resize(n, 0.0)` idiom memset the full buffer on every
/// call, a pure waste on the serving hot path where the shape never
/// changes.
fn resize_for_overwrite(buf: &mut Vec<f32>, want: usize) {
    if want <= buf.len() {
        buf.truncate(want);
    } else {
        buf.resize(want, 0.0);
    }
}

/// Fused gather → contract → scatter for ONE `(n, rest, r0)` state group
/// against the `(r0·n, m·r1)` core matrix — the same arithmetic as
/// pack_a + GEMM row + unpack_one, without materializing either
/// intermediate.  For each `t < rest`: `acc[(i,s)] = Σ_{j,a}
/// src[j,t,a] · core[(a,j),(i,s)]` via the axpy kernel over the
/// contiguous core row, then `acc` scatters into `dst[i,t,s]`.
#[allow(clippy::too_many_arguments)]
fn contract_group(
    src: &[f32],
    core: &[f32],
    n: usize,
    rest: usize,
    r0: usize,
    r1: usize,
    acc: &mut [f32],
    dst: &mut [f32],
    kern: &Kernels,
) {
    let mr1 = acc.len(); // m * r1
    let m = mr1 / r1;
    for t in 0..rest {
        acc.fill(0.0);
        for j in 0..n {
            let s_base = (j * rest + t) * r0;
            for a in 0..r0 {
                let v = src[s_base + a];
                // same sparsity skip as the GEMM kernel (one-hot /
                // padded inputs make zero entries common)
                if v != 0.0 {
                    let row = (a * n + j) * mr1;
                    (kern.axpy)(v, &core[row..row + mr1], acc);
                }
            }
        }
        for i in 0..m {
            let d = (i * rest + t) * r1;
            dst[d..d + r1].copy_from_slice(&acc[i * r1..(i + 1) * r1]);
        }
    }
}

/// `(BM, n, rest, r0) -> (BM, rest, r0, n)` flattened.  Returns `buf`.
fn pack_a<'a>(
    src: &[f32],
    bm: usize,
    n: usize,
    rest: usize,
    r0: usize,
    buf: &'a mut Vec<f32>,
) -> &'a mut Vec<f32> {
    resize_for_overwrite(buf, bm * n * rest * r0);
    let block = n * rest * r0;
    if bm >= 4 && bm * block >= 1 << 16 {
        parallel_chunks_mut(buf, block, |start, chunk| {
            let g = start / block;
            pack_a_one(&src[g * block..(g + 1) * block], n, rest, r0, chunk);
        });
    } else {
        for g in 0..bm {
            pack_a_one(
                &src[g * block..(g + 1) * block],
                n,
                rest,
                r0,
                &mut buf[g * block..(g + 1) * block],
            );
        }
    }
    buf
}

#[inline]
fn pack_a_one(src: &[f32], n: usize, rest: usize, r0: usize, dst: &mut [f32]) {
    // src[j, t, a] -> dst[t, a, j]
    for j in 0..n {
        for t in 0..rest {
            let s_base = (j * rest + t) * r0;
            let d_base = t * r0 * n;
            for a in 0..r0 {
                dst[d_base + a * n + j] = src[s_base + a];
            }
        }
    }
}

/// `(BM, rest, m, r1) -> (BM, m, rest, r1)` flattened.  Reuses `out`.
fn unpack_out(
    src: &[f32],
    bm: usize,
    rest: usize,
    m: usize,
    r1: usize,
    out: &mut Vec<f32>,
) -> Vec<f32> {
    resize_for_overwrite(out, bm * rest * m * r1);
    let block = rest * m * r1;
    if bm >= 4 && bm * block >= 1 << 16 {
        parallel_chunks_mut(out, block, |start, chunk| {
            let g = start / block;
            unpack_one(&src[g * block..(g + 1) * block], rest, m, r1, chunk);
        });
    } else {
        for g in 0..bm {
            unpack_one(
                &src[g * block..(g + 1) * block],
                rest,
                m,
                r1,
                &mut out[g * block..(g + 1) * block],
            );
        }
    }
    std::mem::take(out)
}

#[inline]
fn unpack_one(src: &[f32], rest: usize, m: usize, r1: usize, dst: &mut [f32]) {
    // src[t, i, s] -> dst[i, t, s]
    for t in 0..rest {
        for i in 0..m {
            let s_base = (t * m + i) * r1;
            let d_base = (i * rest + t) * r1;
            dst[d_base..d_base + r1].copy_from_slice(&src[s_base..s_base + r1]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::matmul_bt;
    use crate::tt::TtShape;
    use crate::util::rng::Rng;

    fn check_matches_dense(ms: &[usize], ns: &[usize], r: usize, batch: usize, seed: u64) {
        let shape = TtShape::uniform(ms, ns, r).unwrap();
        let mut rng = Rng::new(seed);
        let tt = TtMatrix::random(&shape, &mut rng).unwrap();
        let x = Tensor::randn(&[batch, shape.n_total()], 1.0, &mut rng);
        let got = tt.matvec(&x).unwrap();
        let w = tt.to_dense().unwrap();
        let want = matmul_bt(&x, &w).unwrap(); // X W^T
        assert_eq!(got.shape(), want.shape());
        for (g, w) in got.data().iter().zip(want.data()) {
            assert!((g - w).abs() < 1e-4 * (1.0 + w.abs()), "{g} vs {w}");
        }
    }

    #[test]
    fn matvec_matches_dense_various() {
        check_matches_dense(&[2, 3], &[4, 5], 3, 1, 1);
        check_matches_dense(&[4, 4, 4], &[4, 4, 4], 2, 7, 2);
        check_matches_dense(&[2, 2, 2, 2], &[3, 3, 3, 3], 4, 5, 3);
        check_matches_dense(&[7], &[9], 1, 3, 4); // d=1 degenerate
        check_matches_dense(&[3, 5, 2], &[2, 5, 3], 5, 2, 5);
    }

    #[test]
    fn matvec_rejects_bad_input() {
        let shape = TtShape::uniform(&[2, 2], &[3, 3], 2).unwrap();
        let tt = TtMatrix::random(&shape, &mut Rng::new(0)).unwrap();
        assert!(tt.matvec(&Tensor::zeros(&[1, 7])).is_err());
        assert!(tt.matvec(&Tensor::zeros(&[9])).is_err());
    }

    #[test]
    fn matvec_linear() {
        let shape = TtShape::uniform(&[2, 3, 2], &[3, 2, 3], 3).unwrap();
        let mut rng = Rng::new(6);
        let tt = TtMatrix::random(&shape, &mut rng).unwrap();
        let x = Tensor::randn(&[2, 18], 1.0, &mut rng);
        let y = Tensor::randn(&[2, 18], 1.0, &mut rng);
        let mut xy = x.clone();
        xy.scale(2.0);
        xy.axpy(-3.0, &y).unwrap();
        let lhs = tt.matvec(&xy).unwrap();
        let mut rhs = tt.matvec(&x).unwrap();
        rhs.scale(2.0);
        rhs.axpy(-3.0, &tt.matvec(&y).unwrap()).unwrap();
        for (a, b) in lhs.data().iter().zip(rhs.data()) {
            assert!((a - b).abs() < 1e-3 * (1.0 + b.abs()));
        }
    }

    #[test]
    fn scratch_reuse_consistent() {
        let shape = TtShape::uniform(&[4, 4], &[4, 4], 3).unwrap();
        let mut rng = Rng::new(7);
        let tt = TtMatrix::random(&shape, &mut rng).unwrap();
        let mut scratch = MatvecScratch::default();
        let x1 = Tensor::randn(&[3, 16], 1.0, &mut rng);
        let x2 = Tensor::randn(&[5, 16], 1.0, &mut rng);
        let a1 = tt.matvec_with(&x1, &mut scratch).unwrap();
        let _ = tt.matvec_with(&x2, &mut scratch).unwrap();
        let a1_again = tt.matvec_with(&x1, &mut scratch).unwrap();
        assert_eq!(a1, a1_again);

        // allocation-regression guard: the original bug reset `scratch.a`
        // to `Vec::new()` on every call, so the state buffer was
        // reallocated per serving-worker invocation.  After a warm call
        // the recycled buffers must hold capacity, and repeated
        // same-shape calls must leave every capacity unchanged (steady
        // state allocates only the returned tensor's buffer).
        assert!(scratch.a.capacity() > 0, "state buffer lost its capacity");
        assert!(scratch.b.capacity() > 0, "pack buffer lost its capacity");
        let caps = (
            scratch.a.capacity(),
            scratch.b.capacity(),
            scratch.c.capacity(),
            scratch.acc.capacity(),
        );
        for _ in 0..4 {
            let _ = tt.matvec_with(&x1, &mut scratch).unwrap();
            let now = (
                scratch.a.capacity(),
                scratch.b.capacity(),
                scratch.c.capacity(),
                scratch.acc.capacity(),
            );
            assert_eq!(caps, now, "scratch capacities drifted across same-shape calls");
        }

        // no-memset pin: buffers resized via `resize_for_overwrite` keep
        // stale contents across shrink/grow cycles, so correctness after
        // batch-size alternation proves every element really is
        // overwritten before being read (a refill would mask a gap)
        let big = tt.matvec_with(&x2, &mut scratch).unwrap();
        let small = tt.matvec_with(&x1, &mut scratch).unwrap(); // shrink: stale tail retained
        let big_again = tt.matvec_with(&x2, &mut scratch).unwrap(); // grow over stale data
        assert_eq!(big, big_again, "stale scratch contents leaked into the output");
        assert_eq!(small, a1, "shrunken-buffer call diverged");
    }

    #[test]
    fn resize_for_overwrite_skips_the_fill() {
        let mut buf = vec![3.0f32; 8];
        // same length: must be a no-op, not a clear+refill
        resize_for_overwrite(&mut buf, 8);
        assert_eq!(buf, vec![3.0; 8], "same-length resize must not touch contents");
        // shrink: prefix untouched, no fill
        resize_for_overwrite(&mut buf, 5);
        assert_eq!(buf, vec![3.0; 5]);
        // grow: retained prefix untouched, only the new tail is zeroed
        resize_for_overwrite(&mut buf, 7);
        assert_eq!(buf, vec![3.0, 3.0, 3.0, 3.0, 3.0, 0.0, 0.0]);
    }

    #[test]
    fn fused_large_batch_matches_small_batch_path() {
        // a batch big enough to cross the cooperative-path gate
        // (groups · block ≥ 2¹⁶ at every core) must agree with the
        // small-batch pack→GEMM→unpack path row for row, and stay
        // deterministic call-to-call
        let shape = TtShape::uniform(&[4, 4, 4], &[4, 4, 4], 4).unwrap();
        let mut rng = Rng::new(9);
        let tt = TtMatrix::random(&shape, &mut rng).unwrap();
        let batch = 1200;
        let x = Tensor::randn(&[batch, shape.n_total()], 1.0, &mut rng);
        let mut scratch = MatvecScratch::default();
        let got = tt.matvec_with(&x, &mut scratch).unwrap();
        assert_eq!(got.shape(), &[batch, shape.m_total()]);
        // reference: the same rows one at a time (batch 1 stays on the
        // GEMM path); the two paths sum in different orders → tolerance
        let n = shape.n_total();
        let m = shape.m_total();
        for i in (0..batch).step_by(97) {
            let row = Tensor::from_vec(&[1, n], x.data()[i * n..(i + 1) * n].to_vec()).unwrap();
            let want = tt.matvec(&row).unwrap();
            for (g, w) in got.data()[i * m..(i + 1) * m].iter().zip(want.data()) {
                assert!((g - w).abs() < 1e-4 * (1.0 + w.abs()), "row {i}: {g} vs {w}");
            }
        }
        // per-path determinism: identical input + scratch reuse ⇒
        // bitwise identical output
        let again = tt.matvec_with(&x, &mut scratch).unwrap();
        assert_eq!(got, again);
        // steady state keeps its one-allocation-per-call contract: warm
        // capacities must not drift across repeated same-shape calls
        let caps = (
            scratch.a.capacity(),
            scratch.b.capacity(),
            scratch.c.capacity(),
            scratch.acc.capacity(),
        );
        for _ in 0..3 {
            let _ = tt.matvec_with(&x, &mut scratch).unwrap();
            let now = (
                scratch.a.capacity(),
                scratch.b.capacity(),
                scratch.c.capacity(),
                scratch.acc.capacity(),
            );
            assert_eq!(caps, now, "fused-path scratch capacities drifted");
        }
        assert!(scratch.acc.capacity() > 0, "fused path must have pooled its accumulators");
    }

    #[test]
    fn transpose_matvec_is_wt() {
        let shape = TtShape::uniform(&[2, 4], &[3, 3], 2).unwrap();
        let mut rng = Rng::new(8);
        let tt = TtMatrix::random(&shape, &mut rng).unwrap();
        let ttt = tt.transpose().unwrap();
        let g = Tensor::randn(&[4, 8], 1.0, &mut rng);
        let got = ttt.matvec(&g).unwrap(); // (4, 9) = g W
        let w = tt.to_dense().unwrap();
        let want = crate::tensor::matmul(&g, &w).unwrap();
        for (a, b) in got.data().iter().zip(want.data()) {
            assert!((a - b).abs() < 1e-4 * (1.0 + b.abs()));
        }
    }
}
