//! TT-SVD: decompose an explicit matrix into TT cores (Oseledets 2011,
//! Alg. 1, adapted to the matrix-TT interleaved mode ordering of §3.1).

use crate::error::{shape_err, Result};
use crate::linalg::truncated_svd;
use crate::tensor::{matmul, Tensor};
use crate::tt::{TtMatrix, TtShape};

impl TtMatrix {
    /// Decompose a dense `W (M x N)` into TT format with the given mode
    /// factorizations, rank cap and relative Frobenius tolerance `eps`:
    /// the result satisfies `‖W − TT(W)‖_F ≤ eps · ‖W‖_F` when `max_rank`
    /// does not bind.
    pub fn from_dense(
        w: &Tensor,
        ms: &[usize],
        ns: &[usize],
        max_rank: Option<usize>,
        eps: f64,
    ) -> Result<TtMatrix> {
        if w.ndim() != 2 {
            return shape_err(format!("from_dense: want 2-D, got {:?}", w.shape()));
        }
        let d = ms.len();
        if d != ns.len() || d == 0 {
            return shape_err(format!("bad modes {:?} / {:?}", ms, ns));
        }
        let m_total: usize = ms.iter().product();
        let n_total: usize = ns.iter().product();
        if w.shape() != [m_total, n_total] {
            return shape_err(format!(
                "modes {:?}x{:?} don't factor {:?}",
                ms,
                ns,
                w.shape()
            ));
        }

        // interleave: (m_1..m_d, n_1..n_d) -> (m_1, n_1, m_2, n_2, ...)
        let mut full_shape: Vec<usize> = ms.to_vec();
        full_shape.extend_from_slice(ns);
        let mut perm = Vec::with_capacity(2 * d);
        for k in 0..d {
            perm.push(k);
            perm.push(d + k);
        }
        let interleaved = w.reshaped(&full_shape)?.permute(&perm)?;
        let s_modes: Vec<usize> = (0..d).map(|k| ms[k] * ns[k]).collect();

        // error budget per truncation step
        let norm = w.norm() as f64;
        let delta = if d > 1 { eps * norm / ((d - 1) as f64).sqrt() } else { 0.0 };

        // sweep left to right
        let mut cores: Vec<Tensor> = Vec::with_capacity(d);
        let mut ranks = vec![1usize; d + 1];
        let mut rest: usize = s_modes.iter().product();
        let mut c = interleaved.reshape(&[rest, 1])?; // placeholder reshape below
        c = c.reshape(&[s_modes[0], rest / s_modes[0]])?;
        for k in 0..d - 1 {
            // c: (r_{k-1} * s_k, rest)
            let tsvd = truncated_svd(&c, max_rank, delta)?;
            let rk = tsvd.s.len();
            ranks[k + 1] = rk;
            cores.push(tsvd.u.reshape(&[ranks[k], ms[k], ns[k], rk])?);
            // carry = diag(s) * Vt, reshape for the next step
            let mut carry = tsvd.vt;
            for (i, &sv) in tsvd.s.iter().enumerate() {
                let cols = carry.shape()[1];
                let row = &mut carry.data_mut()[i * cols..(i + 1) * cols];
                for x in row.iter_mut() {
                    *x *= sv;
                }
            }
            rest /= s_modes[k];
            let next_rest = rest / s_modes[k + 1];
            c = carry.reshape(&[rk * s_modes[k + 1], next_rest])?;
        }
        // last core
        cores.push(c.reshape(&[ranks[d - 1], ms[d - 1], ns[d - 1], 1])?);

        let shape = TtShape::new(ms, ns, &ranks)?;
        TtMatrix::from_cores(shape, cores)
    }

    /// Exact decomposition (no truncation beyond numerically-zero values).
    pub fn from_dense_exact(w: &Tensor, ms: &[usize], ns: &[usize]) -> Result<TtMatrix> {
        TtMatrix::from_dense(w, ms, ns, None, 0.0)
    }

    /// Relative Frobenius reconstruction error `‖W − TT‖ / ‖W‖`.
    pub fn rel_error_vs(&self, w: &Tensor) -> Result<f64> {
        let rec = self.to_dense()?;
        let mut diff = rec;
        diff.axpy(-1.0, w)?;
        Ok(diff.norm() as f64 / (w.norm() as f64).max(f64::MIN_POSITIVE))
    }
}

/// Convenience: densify `tt`, multiply two dense matrices (used in tests).
#[allow(dead_code)]
pub(crate) fn dense_product(a: &Tensor, b: &Tensor) -> Result<Tensor> {
    matmul(a, b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn exact_roundtrip_small() {
        let mut rng = Rng::new(1);
        let w = Tensor::randn(&[6, 6], 1.0, &mut rng);
        let tt = TtMatrix::from_dense_exact(&w, &[2, 3], &[3, 2]).unwrap();
        assert!(tt.rel_error_vs(&w).unwrap() < 1e-5);
    }

    #[test]
    fn exact_roundtrip_3d() {
        let mut rng = Rng::new(2);
        let w = Tensor::randn(&[24, 24], 1.0, &mut rng);
        let tt = TtMatrix::from_dense_exact(&w, &[2, 3, 4], &[4, 3, 2]).unwrap();
        assert!(tt.rel_error_vs(&w).unwrap() < 1e-5);
        // ranks bounded by the theoretical maximum
        let full = TtShape::full_ranks(&[2, 3, 4], &[4, 3, 2]);
        for (got, cap) in tt.shape().ranks().iter().zip(&full) {
            assert!(got <= cap);
        }
    }

    #[test]
    fn low_tt_rank_matrix_recovers_rank() {
        // build a TT-matrix of rank 3, densify, re-decompose exactly:
        // the recovered ranks must not exceed 3.
        let shape = TtShape::uniform(&[3, 3, 3], &[3, 3, 3], 3).unwrap();
        let tt = TtMatrix::random(&shape, &mut Rng::new(3)).unwrap();
        let w = tt.to_dense().unwrap();
        // eps at f32-noise scale: truncates the numerically-zero tail that
        // densification rounding introduces, recovering the true ranks
        let back = TtMatrix::from_dense(&w, &[3, 3, 3], &[3, 3, 3], None, 1e-5).unwrap();
        assert!(back.rel_error_vs(&w).unwrap() < 1e-4);
        for (&r, &orig) in back.shape().ranks().iter().zip(shape.ranks()) {
            assert!(r <= orig, "rank {r} exceeds original {orig}");
        }
    }

    #[test]
    fn rank_cap_produces_requested_ranks() {
        let mut rng = Rng::new(4);
        let w = Tensor::randn(&[16, 16], 1.0, &mut rng);
        let tt = TtMatrix::from_dense(&w, &[4, 4], &[4, 4], Some(2), 0.0).unwrap();
        assert!(tt.shape().max_rank() <= 2);
        // a random matrix truncated to rank 2 has real error
        let err = tt.rel_error_vs(&w).unwrap();
        assert!(err > 0.01 && err < 1.0);
    }

    #[test]
    fn eps_controls_error() {
        let mut rng = Rng::new(5);
        // noisy low-rank-ish matrix
        let shape = TtShape::uniform(&[4, 4], &[4, 4], 2).unwrap();
        let base = TtMatrix::random(&shape, &mut Rng::new(6)).unwrap().to_dense().unwrap();
        let mut noisy = base.clone();
        let noise = Tensor::randn(&[16, 16], 0.01 * base.norm() / 16.0, &mut rng);
        noisy.axpy(1.0, &noise).unwrap();
        let tt = TtMatrix::from_dense(&noisy, &[4, 4], &[4, 4], None, 0.1).unwrap();
        let err = tt.rel_error_vs(&noisy).unwrap();
        assert!(err <= 0.1 + 1e-6, "err {err} exceeds eps");
    }

    #[test]
    fn rejects_bad_modes() {
        let w = Tensor::zeros(&[6, 6]);
        assert!(TtMatrix::from_dense_exact(&w, &[2, 2], &[3, 2]).is_err());
        assert!(TtMatrix::from_dense_exact(&w, &[2, 3], &[3, 3]).is_err());
        assert!(TtMatrix::from_dense_exact(&w, &[], &[]).is_err());
    }

    #[test]
    fn d1_is_plain_truncated_svd() {
        let mut rng = Rng::new(7);
        let w = Tensor::randn(&[8, 10], 1.0, &mut rng);
        let tt = TtMatrix::from_dense_exact(&w, &[8], &[10]).unwrap();
        assert_eq!(tt.d(), 1);
        assert!(tt.rel_error_vs(&w).unwrap() < 1e-5);
    }
}
