//! TT arithmetic (the paper's §3 "supported operations"): scaling, sum,
//! Hadamard product, dot / Frobenius norm, and TT-by-TT matrix product.
//! Sums and products increase ranks (additively / multiplicatively);
//! callers recompress with [`TtMatrix::round`].

use crate::error::{shape_err, Result};
use crate::tensor::Tensor;
use crate::tt::{TtMatrix, TtShape};

impl TtMatrix {
    /// `alpha * W` — scales the first core only.
    pub fn scale(&self, alpha: f32) -> Result<TtMatrix> {
        let mut cores = self.cores().to_vec();
        cores[0].scale(alpha);
        TtMatrix::from_cores(self.shape().clone(), cores)
    }

    /// `W + V` in TT format.  Ranks add: `r_k(W+V) = r_k(W) + r_k(V)` for
    /// interior k (block-diagonal core stacking).
    pub fn add(&self, other: &TtMatrix) -> Result<TtMatrix> {
        if self.shape().ms() != other.shape().ms() || self.shape().ns() != other.shape().ns() {
            return shape_err(format!("add: {} vs {}", self.shape(), other.shape()));
        }
        let d = self.d();
        let ra = self.shape().ranks();
        let rb = other.shape().ranks();
        let mut ranks = vec![1usize; d + 1];
        for k in 1..d {
            ranks[k] = ra[k] + rb[k];
        }
        let mut cores = Vec::with_capacity(d);
        for k in 0..d {
            let [a0, m, n, a1] = self.shape().core_shape(k);
            let [b0, _, _, b1] = other.shape().core_shape(k);
            let (c0, c1) = (ranks[k], ranks[k + 1]);
            let mut core = Tensor::zeros(&[c0, m, n, c1]);
            let ca = self.cores()[k].data();
            let cb = other.cores()[k].data();
            let cd = core.data_mut();
            // A block at (0..a0, 0..a1); B block at (c0-b0.., c1-b1..)
            for r in 0..a0 {
                for i in 0..m {
                    for j in 0..n {
                        let src = ((r * m + i) * n + j) * a1;
                        let dst = ((r * m + i) * n + j) * c1;
                        cd[dst..dst + a1].copy_from_slice(&ca[src..src + a1]);
                    }
                }
            }
            // B block accumulates (+=): for d == 1 both blocks coincide at
            // (0,0) and the sum of the two cores IS the TT sum.
            let (off0, off1) = (c0 - b0, c1 - b1);
            for r in 0..b0 {
                for i in 0..m {
                    for j in 0..n {
                        let src = ((r * m + i) * n + j) * b1;
                        let dst = (((r + off0) * m + i) * n + j) * c1 + off1;
                        for s in 0..b1 {
                            cd[dst + s] += cb[src + s];
                        }
                    }
                }
            }
            cores.push(core);
        }
        let shape = TtShape::new(self.shape().ms(), self.shape().ns(), &ranks)?;
        TtMatrix::from_cores(shape, cores)
    }

    /// `W - V`.
    pub fn sub(&self, other: &TtMatrix) -> Result<TtMatrix> {
        self.add(&other.scale(-1.0)?)
    }

    /// Elementwise (Hadamard) product.  Ranks multiply.
    pub fn hadamard(&self, other: &TtMatrix) -> Result<TtMatrix> {
        if self.shape().ms() != other.shape().ms() || self.shape().ns() != other.shape().ns() {
            return shape_err(format!("hadamard: {} vs {}", self.shape(), other.shape()));
        }
        let d = self.d();
        let mut ranks = vec![1usize; d + 1];
        for k in 0..=d {
            ranks[k] = self.shape().ranks()[k] * other.shape().ranks()[k];
        }
        let mut cores = Vec::with_capacity(d);
        for k in 0..d {
            let [a0, m, n, a1] = self.shape().core_shape(k);
            let [b0, _, _, b1] = other.shape().core_shape(k);
            let mut core = Tensor::zeros(&[a0 * b0, m, n, a1 * b1]);
            let ca = self.cores()[k].data();
            let cb = other.cores()[k].data();
            let cd = core.data_mut();
            let c1 = a1 * b1;
            for ra in 0..a0 {
                for rb in 0..b0 {
                    let r = ra * b0 + rb;
                    for i in 0..m {
                        for j in 0..n {
                            let abase = ((ra * m + i) * n + j) * a1;
                            let bbase = ((rb * m + i) * n + j) * b1;
                            let dbase = ((r * m + i) * n + j) * c1;
                            for sa in 0..a1 {
                                let av = ca[abase + sa];
                                if av != 0.0 {
                                    for sb in 0..b1 {
                                        cd[dbase + sa * b1 + sb] = av * cb[bbase + sb];
                                    }
                                }
                            }
                        }
                    }
                }
            }
            cores.push(core);
        }
        let shape = TtShape::new(self.shape().ms(), self.shape().ns(), &ranks)?;
        TtMatrix::from_cores(shape, cores)
    }

    /// Inner product `<W, V> = Σ_{t,l} W(t,l) V(t,l)` without densifying —
    /// contract core-by-core, `O(d · s · r^4)`-ish.
    pub fn dot(&self, other: &TtMatrix) -> Result<f64> {
        if self.shape().ms() != other.shape().ms() || self.shape().ns() != other.shape().ns() {
            return shape_err(format!("dot: {} vs {}", self.shape(), other.shape()));
        }
        // v[(a, b)] running contraction, starts 1x1
        let mut v = vec![1.0f64];
        for k in 0..self.d() {
            let [a0, m, n, a1] = self.shape().core_shape(k);
            let [b0, _, _, b1] = other.shape().core_shape(k);
            let ca = self.cores()[k].data();
            let cb = other.cores()[k].data();
            let mut nv = vec![0.0f64; a1 * b1];
            // nv[a', b'] = sum_{a, b, i, j} v[a,b] * A[a,i,j,a'] * B[b,i,j,b']
            // factor: for each (i,j): t[a'] per a via A, u[b'] per b via B
            for i in 0..m {
                for j in 0..n {
                    // w[a, b'] = sum_b v[a,b] B[b,i,j,b']
                    let mut w = vec![0.0f64; a0 * b1];
                    for a in 0..a0 {
                        for b in 0..b0 {
                            let vv = v[a * b0 + b];
                            if vv != 0.0 {
                                let bbase = ((b * m + i) * n + j) * b1;
                                for sb in 0..b1 {
                                    w[a * b1 + sb] += vv * cb[bbase + sb] as f64;
                                }
                            }
                        }
                    }
                    // nv[a', b'] += sum_a A[a,i,j,a'] w[a, b']
                    for a in 0..a0 {
                        let abase = ((a * m + i) * n + j) * a1;
                        for sa in 0..a1 {
                            let av = ca[abase + sa] as f64;
                            if av != 0.0 {
                                for sb in 0..b1 {
                                    nv[sa * b1 + sb] += av * w[a * b1 + sb];
                                }
                            }
                        }
                    }
                }
            }
            v = nv;
        }
        Ok(v[0])
    }

    /// Frobenius norm via `sqrt(<W, W>)`.
    pub fn norm(&self) -> Result<f64> {
        Ok(self.dot(self)?.max(0.0).sqrt())
    }

    /// TT-by-TT matrix product `W (M x N) · V (N x P)`: cores contract over
    /// the shared column/row modes; ranks multiply.
    pub fn matmul_tt(&self, other: &TtMatrix) -> Result<TtMatrix> {
        if self.shape().ns() != other.shape().ms() {
            return shape_err(format!("matmul_tt: {} x {}", self.shape(), other.shape()));
        }
        let d = self.d();
        let mut ranks = vec![1usize; d + 1];
        for k in 0..=d {
            ranks[k] = self.shape().ranks()[k] * other.shape().ranks()[k];
        }
        let mut cores = Vec::with_capacity(d);
        for k in 0..d {
            let [a0, m, n, a1] = self.shape().core_shape(k);
            let [b0, _, p, b1] = other.shape().core_shape(k);
            let mut core = Tensor::zeros(&[a0 * b0, m, p, a1 * b1]);
            let ca = self.cores()[k].data();
            let cb = other.cores()[k].data();
            let cd = core.data_mut();
            let c1 = a1 * b1;
            for ra in 0..a0 {
                for rb in 0..b0 {
                    let r = ra * b0 + rb;
                    for i in 0..m {
                        for l in 0..p {
                            let dbase = ((r * m + i) * p + l) * c1;
                            for j in 0..n {
                                let abase = ((ra * m + i) * n + j) * a1;
                                let bbase = ((rb * n + j) * p + l) * b1;
                                for sa in 0..a1 {
                                    let av = ca[abase + sa];
                                    if av != 0.0 {
                                        for sb in 0..b1 {
                                            cd[dbase + sa * b1 + sb] += av * cb[bbase + sb];
                                        }
                                    }
                                }
                            }
                        }
                    }
                }
            }
            cores.push(core);
        }
        let shape = TtShape::new(self.shape().ms(), other.shape().ns(), &ranks)?;
        TtMatrix::from_cores(shape, cores)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::matmul;
    use crate::util::rng::Rng;

    fn rand_tt(ms: &[usize], ns: &[usize], r: usize, seed: u64) -> TtMatrix {
        TtMatrix::random(&TtShape::uniform(ms, ns, r).unwrap(), &mut Rng::new(seed)).unwrap()
    }

    fn close(a: &Tensor, b: &Tensor, tol: f32) {
        assert_eq!(a.shape(), b.shape());
        for (x, y) in a.data().iter().zip(b.data()) {
            assert!((x - y).abs() <= tol * (1.0 + y.abs()), "{x} vs {y}");
        }
    }

    #[test]
    fn scale_matches_dense() {
        let tt = rand_tt(&[2, 3], &[3, 2], 2, 1);
        let mut want = tt.to_dense().unwrap();
        want.scale(-2.5);
        close(&tt.scale(-2.5).unwrap().to_dense().unwrap(), &want, 1e-5);
    }

    #[test]
    fn add_matches_dense() {
        let a = rand_tt(&[2, 3, 2], &[2, 2, 3], 2, 2);
        let b = rand_tt(&[2, 3, 2], &[2, 2, 3], 3, 3);
        let want = a.to_dense().unwrap().add(&b.to_dense().unwrap()).unwrap();
        let sum = a.add(&b).unwrap();
        close(&sum.to_dense().unwrap(), &want, 1e-5);
        assert_eq!(sum.shape().ranks()[1], 5); // 2 + 3
    }

    #[test]
    fn sub_is_zero_for_self() {
        let a = rand_tt(&[2, 2], &[3, 3], 2, 4);
        let z = a.sub(&a).unwrap();
        assert!(z.norm().unwrap() < 1e-5);
    }

    #[test]
    fn hadamard_matches_dense() {
        let a = rand_tt(&[2, 2], &[3, 2], 2, 5);
        let b = rand_tt(&[2, 2], &[3, 2], 2, 6);
        let want = a.to_dense().unwrap().hadamard(&b.to_dense().unwrap()).unwrap();
        let got = a.hadamard(&b).unwrap();
        close(&got.to_dense().unwrap(), &want, 1e-5);
        assert_eq!(got.shape().ranks()[1], 4); // 2 * 2
    }

    #[test]
    fn dot_matches_dense() {
        let a = rand_tt(&[2, 3], &[2, 2], 2, 7);
        let b = rand_tt(&[2, 3], &[2, 2], 3, 8);
        let want = a.to_dense().unwrap().dot(&b.to_dense().unwrap()).unwrap() as f64;
        let got = a.dot(&b).unwrap();
        assert!((got - want).abs() < 1e-4 * (1.0 + want.abs()), "{got} vs {want}");
    }

    #[test]
    fn norm_matches_dense() {
        let a = rand_tt(&[3, 2, 2], &[2, 2, 2], 2, 9);
        let want = a.to_dense().unwrap().norm() as f64;
        assert!((a.norm().unwrap() - want).abs() < 1e-4 * (1.0 + want));
    }

    #[test]
    fn matmul_tt_matches_dense() {
        // W: 6x8 modes (2,3)x(2,4); V: 8x9 modes (2,4)x(3,3)
        let a = rand_tt(&[2, 3], &[2, 4], 2, 10);
        let b = rand_tt(&[2, 4], &[3, 3], 2, 11);
        let got = a.matmul_tt(&b).unwrap();
        let want = matmul(&a.to_dense().unwrap(), &b.to_dense().unwrap()).unwrap();
        close(&got.to_dense().unwrap(), &want, 1e-4);
        assert_eq!(got.m_total(), 6);
        assert_eq!(got.n_total(), 9);
    }

    #[test]
    fn shape_mismatches_rejected() {
        let a = rand_tt(&[2, 2], &[2, 2], 2, 12);
        let b = rand_tt(&[2, 3], &[2, 2], 2, 13);
        assert!(a.add(&b).is_err());
        assert!(a.hadamard(&b).is_err());
        assert!(a.dot(&b).is_err());
        assert!(a.matmul_tt(&b).is_err());
    }

    #[test]
    fn add_then_round_recovers() {
        let a = rand_tt(&[2, 2, 2], &[2, 2, 2], 2, 14);
        let sum = a.add(&a).unwrap().round(None, 1e-9).unwrap();
        let mut want = a.to_dense().unwrap();
        want.scale(2.0);
        close(&sum.to_dense().unwrap(), &want, 1e-4);
        assert!(sum.shape().max_rank() <= 2);
    }
}
