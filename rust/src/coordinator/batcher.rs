//! Dynamic batching: group waiting requests **per model** up to
//! `max_batch`, never holding a group's first request longer than
//! `max_delay`.
//!
//! The decision logic lives in the pure [`BatchAssembler`] (unit- and
//! property-tested without threads or clocks); the thread loop in
//! `server.rs` just feeds it wall-clock events.
//!
//! Guarantees (pinned by `rust/tests/proptests.rs`):
//!
//! * **No cross-model batch** — every emitted [`Batch`] holds requests
//!   for exactly one model; traffic for other models never flushes it.
//! * **FIFO within a model** — requests for one model are emitted in
//!   arrival order, batch after batch.
//! * **Bounded hold** — each group's deadline is its first request's
//!   arrival + `max_delay`; [`BatchAssembler::poll`] emits *every*
//!   group whose deadline has passed (oldest deadline first), and
//!   [`BatchAssembler::deadline`] reports the minimum deadline across
//!   groups so the batcher thread always wakes in time.
//! * **No request lost or duplicated** — `push`/`poll`/`flush` together
//!   emit each request exactly once.

use crate::coordinator::request::InferRequest;
use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// Batching policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_delay: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 32, max_delay: Duration::from_millis(2) }
    }
}

/// A formed batch for one model.
#[derive(Debug)]
pub struct Batch {
    pub model: String,
    pub requests: Vec<InferRequest>,
}

/// Pure batching state machine: a keyed map of pending groups, one per
/// model, each with its own deadline.  Interleaved multi-model traffic
/// accumulates per model instead of flushing on every model switch —
/// the head-of-line-blocking fix that keeps mixed-tenant batches full.
///
/// Map entries persist after a flush (the drained `Vec` stays keyed
/// under its model, empty); an empty group is invisible to
/// `deadline`/`poll`/`flush` and costs one map entry per model name
/// ever seen.  The TCP front-end validates names against the served
/// lineup before admission (`coordinator::net`), so remote peers
/// cannot grow this map; in-process callers are the same trust domain
/// as the code.
#[derive(Debug)]
pub struct BatchAssembler {
    policy: BatchPolicy,
    /// model → FIFO of waiting requests; a non-empty group's deadline
    /// is its first request's arrival + `max_delay`
    pending: BTreeMap<String, Vec<InferRequest>>,
}

impl BatchAssembler {
    pub fn new(policy: BatchPolicy) -> Self {
        BatchAssembler { policy, pending: BTreeMap::new() }
    }

    /// Total waiting requests across all model groups.
    pub fn pending_len(&self) -> usize {
        self.pending.values().map(|g| g.len()).sum()
    }

    /// Number of models with at least one waiting request.
    pub fn pending_models(&self) -> usize {
        self.pending.values().filter(|g| !g.is_empty()).count()
    }

    /// Offer a request: it joins its model's pending group (created on
    /// first arrival; the group's deadline is this request's arrival +
    /// `max_delay`).  Returns the full batch iff this request filled
    /// its group to `max_batch` — no other group is touched, so a model
    /// switch in the arrival stream never flushes anyone early.
    pub fn push(&mut self, req: InferRequest) -> Option<Batch> {
        if !self.pending.contains_key(&req.model) {
            self.pending.insert(req.model.clone(), Vec::new());
        }
        let cap = self.policy.max_batch;
        let group = self.pending.get_mut(&req.model).expect("inserted above");
        group.push(req);
        if group.len() >= cap {
            let requests = std::mem::take(group);
            return Some(Batch { model: requests[0].model.clone(), requests });
        }
        None
    }

    /// The earliest deadline across all pending groups (each group's is
    /// its first request's arrival + `max_delay`), if any — the instant
    /// the batcher thread must wake by.
    pub fn deadline(&self) -> Option<Instant> {
        self.pending
            .values()
            .filter_map(|g| g.first().map(|r| r.enqueued + self.policy.max_delay))
            .min()
    }

    /// Emit **every** group whose deadline has passed at `now`, oldest
    /// deadline first.  (A single-group poll could only ever flush one
    /// model per wakeup, starving the rest under mixed traffic.)
    pub fn poll(&mut self, now: Instant) -> Vec<Batch> {
        self.drain_due(Some(now))
    }

    /// Unconditionally emit every pending group (shutdown path), oldest
    /// deadline first.
    pub fn flush(&mut self) -> Vec<Batch> {
        self.drain_due(None)
    }

    /// Drain every group whose deadline is `<= cutoff` (`None` = all),
    /// oldest deadline first.
    fn drain_due(&mut self, cutoff: Option<Instant>) -> Vec<Batch> {
        let mut due: Vec<(Instant, String)> = self
            .pending
            .iter()
            .filter_map(|(m, g)| {
                // cutoff check before the name clone: the common
                // nothing-due poll allocates nothing
                let d = g.first()?.enqueued + self.policy.max_delay;
                if cutoff.is_some_and(|now| d > now) {
                    return None;
                }
                Some((d, m.clone()))
            })
            .collect();
        due.sort_by_key(|(d, _)| *d);
        due.into_iter().filter_map(|(_, m)| self.take(&m)).collect()
    }

    /// Drain one model's group into a batch; `None` if it has nothing
    /// waiting.
    fn take(&mut self, model: &str) -> Option<Batch> {
        let group = self.pending.get_mut(model)?;
        if group.is_empty() {
            return None;
        }
        let requests = std::mem::take(group);
        Some(Batch { model: requests[0].model.clone(), requests })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    fn req(id: u64, model: &str, t: Instant) -> InferRequest {
        let (tx, _rx) = channel();
        InferRequest { id, model: model.into(), input: vec![0.0], enqueued: t, reply: tx }
    }

    fn policy(max_batch: usize, ms: u64) -> BatchPolicy {
        BatchPolicy { max_batch, max_delay: Duration::from_millis(ms) }
    }

    #[test]
    fn fills_to_max_batch() {
        let mut a = BatchAssembler::new(policy(3, 100));
        let t = Instant::now();
        assert!(a.push(req(1, "tt", t)).is_none());
        assert!(a.push(req(2, "tt", t)).is_none());
        let batch = a.push(req(3, "tt", t)).expect("third request fills the group");
        assert_eq!(batch.requests.len(), 3);
        assert_eq!(a.pending_len(), 0);
    }

    #[test]
    fn deadline_flushes() {
        let mut a = BatchAssembler::new(policy(10, 5));
        let t0 = Instant::now();
        a.push(req(1, "tt", t0));
        assert!(a.poll(t0).is_empty()); // too early
        let late = t0 + Duration::from_millis(6);
        let batches = a.poll(late);
        assert_eq!(batches.len(), 1);
        assert_eq!(batches[0].requests.len(), 1);
        assert!(a.poll(late).is_empty()); // nothing left
    }

    #[test]
    fn interleaved_models_accumulate_independently() {
        // the head-of-line-blocking regression: an a/b/a/b arrival
        // stream must NOT flush a group on every model switch
        let mut a = BatchAssembler::new(policy(3, 100));
        let t = Instant::now();
        assert!(a.push(req(1, "tt", t)).is_none());
        assert!(a.push(req(2, "fc", t)).is_none(), "model switch must not flush");
        assert!(a.push(req(3, "tt", t)).is_none());
        assert!(a.push(req(4, "fc", t)).is_none());
        let batch = a.push(req(5, "tt", t)).expect("tt group filled to 3");
        assert_eq!(batch.model, "tt");
        assert_eq!(
            batch.requests.iter().map(|r| r.id).collect::<Vec<_>>(),
            vec![1, 3, 5]
        );
        assert_eq!(a.pending_len(), 2); // both fc requests still waiting
        assert_eq!(a.pending_models(), 1);
    }

    #[test]
    fn no_batch_ever_mixes_models() {
        let mut a = BatchAssembler::new(policy(2, 100));
        let t = Instant::now();
        let mut batches = Vec::new();
        for (id, m) in [(1, "x"), (2, "y"), (3, "x"), (4, "y")] {
            batches.extend(a.push(req(id, m, t)));
        }
        batches.extend(a.flush());
        assert_eq!(batches.len(), 2);
        for b in &batches {
            assert!(b.requests.iter().all(|r| r.model == b.model), "{b:?}");
        }
    }

    #[test]
    fn deadline_is_min_across_groups() {
        let mut a = BatchAssembler::new(policy(10, 10));
        let t0 = Instant::now();
        a.push(req(1, "late", t0 + Duration::from_millis(5)));
        a.push(req(2, "early", t0));
        assert_eq!(a.deadline(), Some(t0 + Duration::from_millis(10)));
        // polling at the early group's deadline flushes only that group
        let batches = a.poll(t0 + Duration::from_millis(10));
        assert_eq!(batches.len(), 1);
        assert_eq!(batches[0].model, "early");
        assert_eq!(a.deadline(), Some(t0 + Duration::from_millis(15)));
    }

    #[test]
    fn poll_emits_every_expired_group_oldest_first() {
        let mut a = BatchAssembler::new(policy(10, 10));
        let t0 = Instant::now();
        a.push(req(1, "b_second", t0 + Duration::from_millis(2)));
        a.push(req(2, "a_first", t0));
        let batches = a.poll(t0 + Duration::from_millis(20));
        assert_eq!(batches.len(), 2, "one wakeup must flush every expired group");
        assert_eq!(batches[0].model, "a_first"); // oldest deadline first
        assert_eq!(batches[1].model, "b_second");
        assert_eq!(a.pending_len(), 0);
    }

    #[test]
    fn fifo_within_model_across_batches() {
        let mut a = BatchAssembler::new(policy(2, 100));
        let t = Instant::now();
        let mut emitted = Vec::new();
        for id in 1..=5 {
            emitted.extend(a.push(req(id, "tt", t)));
        }
        emitted.extend(a.flush());
        let ids: Vec<u64> =
            emitted.iter().flat_map(|b| b.requests.iter().map(|r| r.id)).collect();
        assert_eq!(ids, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn flush_emits_all_groups() {
        let mut a = BatchAssembler::new(policy(10, 1));
        let t = Instant::now();
        a.push(req(1, "tt", t));
        a.push(req(2, "fc", t));
        a.push(req(3, "tt", t));
        let batches = a.flush();
        assert_eq!(batches.len(), 2);
        let total: usize = batches.iter().map(|b| b.requests.len()).sum();
        assert_eq!(total, 3);
        assert!(a.flush().is_empty());
    }

    #[test]
    fn empty_flush_is_empty() {
        let mut a = BatchAssembler::new(policy(4, 1));
        assert!(a.flush().is_empty());
        assert!(a.deadline().is_none());
        assert_eq!(a.pending_len(), 0);
        assert_eq!(a.pending_models(), 0);
    }
}
