//! Dynamic batching: group waiting requests **per model**, emitting up
//! to `max_batch` at a time and never holding a group's first request
//! longer than `max_delay`.
//!
//! The decision logic lives in the pure [`BatchAssembler`] (unit- and
//! property-tested without threads or clocks); the thread loop in
//! `server.rs` just feeds it wall-clock events.  Since the admission
//! rework (DESIGN.md §14) the assembler *is* the pipeline's backlog —
//! groups may hold more than `max_batch` requests (tickets, not a
//! bounded channel, bound the total) — which is what makes the
//! overload [`QueueMode`] physically possible: the drain order over a
//! real backlog is a policy choice, not a channel artifact.
//!
//! Guarantees (pinned by `rust/tests/proptests.rs`):
//!
//! * **No cross-model batch** — every emitted [`Batch`] holds requests
//!   for exactly one model; traffic for other models never flushes it.
//! * **FIFO within a model in FIFO mode** — requests for one model are
//!   emitted in arrival order, batch after batch.  In LIFO mode
//!   ([`QueueMode::Lifo`], sustained overload) each drain takes the
//!   *newest* `max_batch` waiters instead — bounding the tail latency
//!   of the requests that complete — while the group's first (oldest)
//!   request still anchors the deadline, so a starved old request
//!   keeps the group eligible on every pass and everything admitted is
//!   still delivered exactly once.
//! * **Bounded hold** — each group's deadline is its first request's
//!   arrival + `max_delay`; [`BatchAssembler::pop_ready`] considers
//!   every group that is full or expired, oldest deadline first, and
//!   [`BatchAssembler::deadline`] reports the minimum deadline across
//!   groups so the batcher thread always wakes in time.
//! * **No request lost or duplicated** — `push`/`pop_ready`/`flush`
//!   together emit each request exactly once, in either mode.

use crate::coordinator::admission::QueueMode;
use crate::coordinator::request::InferRequest;
use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// Batching policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_delay: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 32, max_delay: Duration::from_millis(2) }
    }
}

/// A formed batch for one model.
#[derive(Debug)]
pub struct Batch {
    pub model: String,
    pub requests: Vec<InferRequest>,
}

/// Pure batching state machine: a keyed map of pending groups, one per
/// model, each with its own deadline.  Interleaved multi-model traffic
/// accumulates per model instead of flushing on every model switch —
/// the head-of-line-blocking fix that keeps mixed-tenant batches full.
///
/// Map entries persist after a drain (the emptied `Vec` stays keyed
/// under its model); an empty group is invisible to
/// `deadline`/`pop_ready`/`flush` and costs one map entry per model
/// name ever seen.  The TCP front-end validates names against the
/// served lineup before admission (`coordinator::net`), so remote
/// peers cannot grow this map; in-process callers are the same trust
/// domain as the code.
#[derive(Debug)]
pub struct BatchAssembler {
    policy: BatchPolicy,
    /// model → arrival-ordered waiting requests; a non-empty group's
    /// deadline is its first (oldest) request's arrival + `max_delay`
    pending: BTreeMap<String, Vec<InferRequest>>,
}

impl BatchAssembler {
    pub fn new(policy: BatchPolicy) -> Self {
        BatchAssembler { policy, pending: BTreeMap::new() }
    }

    /// Total waiting requests across all model groups.
    pub fn pending_len(&self) -> usize {
        self.pending.values().map(|g| g.len()).sum()
    }

    /// Number of models with at least one waiting request.
    pub fn pending_models(&self) -> usize {
        self.pending.values().filter(|g| !g.is_empty()).count()
    }

    /// Offer a request: it joins its model's group in arrival order
    /// (created on first arrival; the group's deadline is its oldest
    /// request's arrival + `max_delay`).  Never emits — draining is
    /// [`BatchAssembler::pop_ready`]'s job, so the caller controls the
    /// order (mode) and the pace (batch-queue backpressure).
    pub fn push(&mut self, req: InferRequest) {
        self.pending.entry(req.model.clone()).or_default().push(req);
    }

    /// The earliest deadline across all pending groups (each group's is
    /// its first request's arrival + `max_delay`), if any — the instant
    /// the batcher thread must wake by.  A full group's deadline is
    /// *now*: it is ready regardless of age.
    pub fn deadline(&self) -> Option<Instant> {
        self.pending
            .values()
            .filter_map(|g| g.first().map(|r| r.enqueued + self.policy.max_delay))
            .min()
    }

    /// Emit the next ready batch at `now`, or `None` when no group is
    /// full or expired.  Among ready groups the oldest deadline wins
    /// (no model waits on another's traffic — call again to drain the
    /// rest).  `mode` picks which end of the group a batch comes from:
    /// FIFO takes the oldest `max_batch` waiters, LIFO the newest.
    /// Either way the group keeps arrival order internally, and in
    /// LIFO the oldest request stays put anchoring the deadline — so
    /// an overloaded group is re-eligible on every pass and nothing is
    /// ever stranded.
    pub fn pop_ready(&mut self, now: Instant, mode: QueueMode) -> Option<Batch> {
        let model = self
            .pending
            .iter()
            .filter_map(|(m, g)| {
                let first = g.first()?;
                let deadline = first.enqueued + self.policy.max_delay;
                if g.len() >= self.policy.max_batch || deadline <= now {
                    Some((deadline, m))
                } else {
                    None
                }
            })
            .min()
            .map(|(_, m)| m.clone())?;
        let group = self.pending.get_mut(&model).expect("ready group exists");
        let take = self.policy.max_batch.min(group.len()).max(1);
        let requests = match mode {
            // oldest-first: split the tail off, keep it pending
            QueueMode::Fifo => {
                let rest = group.split_off(take);
                std::mem::replace(group, rest)
            }
            // newest-first: take the tail, the old backlog keeps waiting
            // (and keeps the group's deadline expired)
            QueueMode::Lifo => {
                let at = group.len() - take;
                group.split_off(at)
            }
        };
        Some(Batch { model, requests })
    }

    /// Unconditionally drain every pending group (shutdown path) into
    /// `max_batch`-sized FIFO batches.
    pub fn flush(&mut self) -> Vec<Batch> {
        let mut out = Vec::new();
        for (model, group) in self.pending.iter_mut() {
            while !group.is_empty() {
                let take = self.policy.max_batch.min(group.len());
                let rest = group.split_off(take);
                let requests = std::mem::replace(group, rest);
                out.push(Batch { model: model.clone(), requests });
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    fn req(id: u64, model: &str, t: Instant) -> InferRequest {
        let (tx, _rx) = channel();
        InferRequest {
            id,
            model: model.into(),
            input: vec![0.0],
            enqueued: t,
            reply: tx,
            ticket: None,
        }
    }

    fn policy(max_batch: usize, ms: u64) -> BatchPolicy {
        BatchPolicy { max_batch, max_delay: Duration::from_millis(ms) }
    }

    /// Drain everything ready at `now` (what one batcher wakeup does).
    fn drain(a: &mut BatchAssembler, now: Instant, mode: QueueMode) -> Vec<Batch> {
        let mut out = Vec::new();
        while let Some(b) = a.pop_ready(now, mode) {
            out.push(b);
        }
        out
    }

    #[test]
    fn full_group_is_ready_immediately() {
        let mut a = BatchAssembler::new(policy(3, 100));
        let t = Instant::now();
        a.push(req(1, "tt", t));
        a.push(req(2, "tt", t));
        assert!(a.pop_ready(t, QueueMode::Fifo).is_none(), "2 < max_batch and not expired");
        a.push(req(3, "tt", t));
        let batch = a.pop_ready(t, QueueMode::Fifo).expect("third request fills the group");
        assert_eq!(batch.requests.len(), 3);
        assert_eq!(a.pending_len(), 0);
    }

    #[test]
    fn deadline_flushes() {
        let mut a = BatchAssembler::new(policy(10, 5));
        let t0 = Instant::now();
        a.push(req(1, "tt", t0));
        assert!(a.pop_ready(t0, QueueMode::Fifo).is_none()); // too early
        let late = t0 + Duration::from_millis(6);
        let batch = a.pop_ready(late, QueueMode::Fifo).expect("expired group is ready");
        assert_eq!(batch.requests.len(), 1);
        assert!(a.pop_ready(late, QueueMode::Fifo).is_none()); // nothing left
    }

    #[test]
    fn interleaved_models_accumulate_independently() {
        // the head-of-line-blocking regression: an a/b/a/b arrival
        // stream must NOT flush a group on every model switch
        let mut a = BatchAssembler::new(policy(3, 100));
        let t = Instant::now();
        for (id, m) in [(1, "tt"), (2, "fc"), (3, "tt"), (4, "fc"), (5, "tt")] {
            a.push(req(id, m, t));
        }
        let batch = a.pop_ready(t, QueueMode::Fifo).expect("tt group filled to 3");
        assert_eq!(batch.model, "tt");
        assert_eq!(batch.requests.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 3, 5]);
        assert_eq!(a.pending_len(), 2); // both fc requests still waiting
        assert_eq!(a.pending_models(), 1);
    }

    #[test]
    fn no_batch_ever_mixes_models() {
        let mut a = BatchAssembler::new(policy(2, 100));
        let t = Instant::now();
        for (id, m) in [(1, "x"), (2, "y"), (3, "x"), (4, "y")] {
            a.push(req(id, m, t));
        }
        let mut batches = drain(&mut a, t, QueueMode::Fifo);
        batches.extend(a.flush());
        assert_eq!(batches.len(), 2);
        for b in &batches {
            assert!(b.requests.iter().all(|r| r.model == b.model), "{b:?}");
        }
    }

    #[test]
    fn deadline_is_min_across_groups() {
        let mut a = BatchAssembler::new(policy(10, 10));
        let t0 = Instant::now();
        a.push(req(1, "late", t0 + Duration::from_millis(5)));
        a.push(req(2, "early", t0));
        assert_eq!(a.deadline(), Some(t0 + Duration::from_millis(10)));
        // popping at the early group's deadline drains only that group
        let batch = a.pop_ready(t0 + Duration::from_millis(10), QueueMode::Fifo).unwrap();
        assert_eq!(batch.model, "early");
        assert!(a.pop_ready(t0 + Duration::from_millis(10), QueueMode::Fifo).is_none());
        assert_eq!(a.deadline(), Some(t0 + Duration::from_millis(15)));
    }

    #[test]
    fn drain_emits_every_expired_group_oldest_first() {
        let mut a = BatchAssembler::new(policy(10, 10));
        let t0 = Instant::now();
        a.push(req(1, "b_second", t0 + Duration::from_millis(2)));
        a.push(req(2, "a_first", t0));
        let batches = drain(&mut a, t0 + Duration::from_millis(20), QueueMode::Fifo);
        assert_eq!(batches.len(), 2, "one wakeup must drain every expired group");
        assert_eq!(batches[0].model, "a_first"); // oldest deadline first
        assert_eq!(batches[1].model, "b_second");
        assert_eq!(a.pending_len(), 0);
    }

    #[test]
    fn fifo_within_model_across_batches() {
        let mut a = BatchAssembler::new(policy(2, 100));
        let t = Instant::now();
        for id in 1..=5 {
            a.push(req(id, "tt", t));
        }
        let mut emitted = drain(&mut a, t, QueueMode::Fifo);
        emitted.extend(a.flush());
        let ids: Vec<u64> =
            emitted.iter().flat_map(|b| b.requests.iter().map(|r| r.id)).collect();
        assert_eq!(ids, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn lifo_takes_the_newest_and_strands_nobody() {
        // 5 backlogged requests, max_batch 2: LIFO drains newest-first
        // — [4,5], [2,3], [1] — each batch internally arrival-ordered,
        // every request delivered exactly once
        let mut a = BatchAssembler::new(policy(2, 0));
        let t = Instant::now();
        for id in 1..=5 {
            a.push(req(id, "tt", t));
        }
        let now = t + Duration::from_millis(1);
        let batches = drain(&mut a, now, QueueMode::Lifo);
        let ids: Vec<Vec<u64>> = batches
            .iter()
            .map(|b| b.requests.iter().map(|r| r.id).collect())
            .collect();
        assert_eq!(ids, vec![vec![4, 5], vec![2, 3], vec![1]]);
        assert_eq!(a.pending_len(), 0);
    }

    #[test]
    fn lifo_keeps_the_oldest_request_anchoring_the_deadline() {
        let mut a = BatchAssembler::new(policy(2, 10));
        let t0 = Instant::now();
        for id in 1..=4 {
            a.push(req(id, "tt", t0));
        }
        // group is full → ready now; LIFO takes the newest two
        let b = a.pop_ready(t0, QueueMode::Lifo).unwrap();
        assert_eq!(b.requests.iter().map(|r| r.id).collect::<Vec<_>>(), vec![3, 4]);
        // the remainder [1,2] is only half-full, but request 1 still
        // holds the original deadline — it cannot be starved past it
        assert_eq!(a.deadline(), Some(t0 + Duration::from_millis(10)));
        assert!(a.pop_ready(t0, QueueMode::Lifo).is_none());
        let b = a.pop_ready(t0 + Duration::from_millis(10), QueueMode::Lifo).unwrap();
        assert_eq!(b.requests.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 2]);
    }

    #[test]
    fn backlogged_group_drains_in_max_batch_chunks() {
        // groups can exceed max_batch now (tickets bound the pipeline,
        // not the group): a 7-deep backlog drains 3+3+1, never more
        // than max_batch per batch
        let mut a = BatchAssembler::new(policy(3, 100));
        let t = Instant::now();
        for id in 1..=7 {
            a.push(req(id, "tt", t));
        }
        assert_eq!(a.pending_len(), 7);
        let batches = drain(&mut a, t + Duration::from_millis(200), QueueMode::Fifo);
        assert_eq!(
            batches.iter().map(|b| b.requests.len()).collect::<Vec<_>>(),
            vec![3, 3, 1]
        );
    }

    #[test]
    fn flush_emits_all_groups_in_chunks() {
        let mut a = BatchAssembler::new(policy(2, 1));
        let t = Instant::now();
        a.push(req(1, "tt", t));
        a.push(req(2, "fc", t));
        a.push(req(3, "tt", t));
        a.push(req(4, "tt", t));
        let batches = a.flush();
        assert_eq!(batches.len(), 3, "tt (3 deep) chunks into 2+1 at max_batch=2");
        let total: usize = batches.iter().map(|b| b.requests.len()).sum();
        assert_eq!(total, 4);
        assert!(batches.iter().all(|b| b.requests.len() <= 2));
        assert!(a.flush().is_empty());
    }

    #[test]
    fn empty_flush_is_empty() {
        let mut a = BatchAssembler::new(policy(4, 1));
        assert!(a.flush().is_empty());
        assert!(a.deadline().is_none());
        assert_eq!(a.pending_len(), 0);
        assert_eq!(a.pending_models(), 0);
    }
}
