//! Dynamic batching: group waiting requests up to `max_batch`, never
//! holding the first request longer than `max_delay`.
//!
//! The decision logic lives in the pure [`BatchAssembler`] (unit- and
//! property-tested without threads or clocks); the thread loop in
//! `server.rs` just feeds it wall-clock events.

use crate::coordinator::request::InferRequest;
use std::time::{Duration, Instant};

/// Batching policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_delay: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 32, max_delay: Duration::from_millis(2) }
    }
}

/// A formed batch for one model.
#[derive(Debug)]
pub struct Batch {
    pub model: String,
    pub requests: Vec<InferRequest>,
}

/// Pure batching state machine.  Requests for different models never share
/// a batch; each model keys its own pending group.
#[derive(Debug)]
pub struct BatchAssembler {
    policy: BatchPolicy,
    pending: Vec<InferRequest>, // all same model
}

impl BatchAssembler {
    pub fn new(policy: BatchPolicy) -> Self {
        BatchAssembler { policy, pending: Vec::new() }
    }

    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    /// Offer a request.  Returns a full batch if this request completed
    /// one (or if it belongs to a different model than the pending group,
    /// which flushes the group first — in that case the request is queued
    /// for the next batch).
    pub fn push(&mut self, req: InferRequest) -> Vec<Batch> {
        let mut out = Vec::new();
        if let Some(first) = self.pending.first() {
            if first.model != req.model {
                out.push(self.flush().expect("non-empty pending"));
            }
        }
        self.pending.push(req);
        if self.pending.len() >= self.policy.max_batch {
            out.push(self.flush().expect("full batch"));
        }
        out
    }

    /// Deadline of the currently-pending group (first-request arrival +
    /// max_delay), if any.
    pub fn deadline(&self) -> Option<Instant> {
        self.pending.first().map(|r| r.enqueued + self.policy.max_delay)
    }

    /// Flush if `now` has passed the pending group's deadline.
    pub fn poll(&mut self, now: Instant) -> Option<Batch> {
        match self.deadline() {
            Some(d) if now >= d => self.flush(),
            _ => None,
        }
    }

    /// Unconditionally emit whatever is pending (shutdown path).
    pub fn flush(&mut self) -> Option<Batch> {
        if self.pending.is_empty() {
            return None;
        }
        let requests = std::mem::take(&mut self.pending);
        Some(Batch { model: requests[0].model.clone(), requests })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    fn req(id: u64, model: &str, t: Instant) -> InferRequest {
        let (tx, _rx) = channel();
        InferRequest { id, model: model.into(), input: vec![0.0], enqueued: t, reply: tx }
    }

    fn policy(max_batch: usize, ms: u64) -> BatchPolicy {
        BatchPolicy { max_batch, max_delay: Duration::from_millis(ms) }
    }

    #[test]
    fn fills_to_max_batch() {
        let mut a = BatchAssembler::new(policy(3, 100));
        let t = Instant::now();
        assert!(a.push(req(1, "tt", t)).is_empty());
        assert!(a.push(req(2, "tt", t)).is_empty());
        let batches = a.push(req(3, "tt", t));
        assert_eq!(batches.len(), 1);
        assert_eq!(batches[0].requests.len(), 3);
        assert_eq!(a.pending_len(), 0);
    }

    #[test]
    fn deadline_flushes() {
        let mut a = BatchAssembler::new(policy(10, 5));
        let t0 = Instant::now();
        a.push(req(1, "tt", t0));
        assert!(a.poll(t0).is_none()); // too early
        let late = t0 + Duration::from_millis(6);
        let b = a.poll(late).expect("deadline passed");
        assert_eq!(b.requests.len(), 1);
        assert!(a.poll(late).is_none()); // nothing left
    }

    #[test]
    fn model_switch_flushes_group() {
        let mut a = BatchAssembler::new(policy(10, 100));
        let t = Instant::now();
        a.push(req(1, "tt", t));
        a.push(req(2, "tt", t));
        let batches = a.push(req(3, "fc", t));
        assert_eq!(batches.len(), 1);
        assert_eq!(batches[0].model, "tt");
        assert_eq!(batches[0].requests.len(), 2);
        assert_eq!(a.pending_len(), 1); // the fc request waits
    }

    #[test]
    fn fifo_within_batch() {
        let mut a = BatchAssembler::new(policy(4, 100));
        let t = Instant::now();
        for id in 1..=3 {
            a.push(req(id, "tt", t));
        }
        let b = a.flush().unwrap();
        let ids: Vec<u64> = b.requests.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![1, 2, 3]);
    }

    #[test]
    fn empty_flush_is_none() {
        let mut a = BatchAssembler::new(policy(4, 1));
        assert!(a.flush().is_none());
        assert!(a.deadline().is_none());
    }
}
