//! Serving coordinator (S8 in DESIGN.md).
//!
//! Table 3 of the paper is a *serving* measurement — per-request latency
//! of a TT-layer vs its dense counterpart at batch 1 and batch 100.  This
//! module is the production driver around that: a request router over
//! model variants, a dynamic batcher (max-batch / max-delay policy, the
//! vLLM-style knobs), an executor worker pool, bounded queues for
//! backpressure, and latency histograms.  Two serving backends share the
//! [`BatchExecutor`] trait: [`NativeExecutor`] runs real in-process
//! TT/dense models (the default — fully functional offline), and
//! [`PjrtExecutor`] runs AOT artifacts (stubbed offline).
//!
//! Thread model (no async runtime in the offline build — plain OS threads
//! and channels, which is the right shape for CPU inference anyway):
//!
//! ```text
//!                                                        ┌► executor-0 ─┐
//! caller ── bounded queue ──► batcher thread ── batch ────┼► executor-1 ─┼─► reply
//!              (admission)      (max_batch /    queue     └► executor-N ─┘
//!                                max_delay)            (each worker owns its
//!                                                       executor + scratch)
//! ```

mod batcher;
mod native;
mod request;
mod router;
mod server;
mod worker;

pub use batcher::{Batch, BatchAssembler, BatchPolicy};
pub use native::{ModelRegistry, ModelSpec, NativeExecutor};
pub use request::{InferRequest, InferResponse};
pub use router::{choose_variant, Router};
pub use server::{Server, ServerConfig, ServerStats};
pub use worker::{BatchExecutor, EchoExecutor, PjrtExecutor};
