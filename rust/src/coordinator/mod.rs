//! Serving coordinator (S8 in DESIGN.md).
//!
//! Table 3 of the paper is a *serving* measurement — per-request latency
//! of a TT-layer vs its dense counterpart at batch 1 and batch 100.  This
//! module is the production driver around that: a request router over
//! model variants, a dynamic batcher (max-batch / max-delay policy, the
//! vLLM-style knobs), a thread-confined executor that owns the PJRT
//! artifacts, bounded queues for backpressure, and latency histograms.
//!
//! Thread model (no async runtime in the offline build — plain OS threads
//! and channels, which is the right shape for CPU inference anyway):
//!
//! ```text
//! caller ── bounded queue ──► batcher thread ──► executor thread ──► reply
//!              (admission)      (max_batch /        (owns PJRT,
//!                                max_delay)          not Send)
//! ```

mod batcher;
mod request;
mod router;
mod server;
mod worker;

pub use batcher::{Batch, BatchAssembler, BatchPolicy};
pub use request::{InferRequest, InferResponse};
pub use router::{choose_variant, Router};
pub use server::{Server, ServerConfig, ServerStats};
pub use worker::{BatchExecutor, EchoExecutor, PjrtExecutor};
