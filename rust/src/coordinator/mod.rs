//! Serving coordinator (S8 in DESIGN.md).
//!
//! Table 3 of the paper is a *serving* measurement — per-request latency
//! of a TT-layer vs its dense counterpart at batch 1 and batch 100.  This
//! module is the production driver around that: a dynamic batcher
//! (per-model batch groups under a max-batch / max-delay policy, the
//! vLLM-style knobs — interleaved multi-model traffic batches per model
//! instead of flushing on every model switch), an executor worker pool,
//! bounded queues for backpressure, and latency histograms (aggregate +
//! per-model).  Two serving backends share the [`BatchExecutor`] trait:
//! [`NativeExecutor`] runs real in-process TT/dense models (the default
//! — fully functional offline), and [`PjrtExecutor`] runs AOT artifacts
//! (stubbed offline; its variant [`Router`] lives with it in `worker`).
//!
//! Thread model (no async runtime in the offline build — plain OS threads
//! and channels, which is the right shape for CPU inference anyway):
//!
//! ```text
//! remote   ── tn-net-accept ── tn-net-io-{k} ─┐          ┌► executor-0 ─┐
//! clients      (listener)      reactor sweeps ├► bounded ─► batcher ────┼► executor-1 ─┼─► reply
//!                              all conns      │  queue      (max_batch/ └► executor-N ─┘
//! in-process callers (infer / try_infer) ─────┘ (admission)  max_delay)  (each worker owns
//!                                                                        executor + scratch)
//! ```
//!
//! Admission is transport-agnostic (S12, S14 in DESIGN.md): the TCP
//! front-end ([`NetServer`], wire protocol in [`wire`], blocking client
//! in [`Client`]) and in-process callers draw tickets from the same
//! [`AdmissionController`] — dynamic capacity, per-model quotas,
//! FIFO→LIFO overload scheduling — and share backpressure
//! ([`Admission::Busy`], typed capacity-vs-quota sheds with retry
//! hints) and [`ServerStats`].
//!
//! Above a single process, [`ShardRouter`] (DESIGN.md §13) fronts N
//! `serve --listen` daemons over the same wire protocol: placement is
//! discovered from each shard's advertised [`Frame::ModelList`],
//! replicated models dispatch least-loaded, and a dead shard fails over
//! with typed errors while survivors keep serving.

mod admission;
mod batcher;
mod client;
mod native;
mod net;
mod request;
mod router;
mod server;
pub mod wire;
mod worker;

pub use admission::{
    AdmissionConfig, AdmissionController, AdmissionSnapshot, AdmissionTicket, QueueMode, ShedInfo,
    ShedKind,
};
pub use batcher::{Batch, BatchAssembler, BatchPolicy};
pub use client::{is_busy, Client, RemoteResponse, RemoteStats};
pub use native::{ModelRegistry, ModelSpec, NativeExecutor};
pub use net::NetServer;
pub use router::{RouterConfig, ShardRouter, ShardSnapshot};
pub use request::{InferRequest, InferResponse};
pub use server::{Admission, ModelStats, ReplyReceiver, Server, ServerConfig, ServerStats};
pub use wire::{ErrCode, Frame, ModelInfo, ModelStatsEntry};
pub use worker::{choose_variant, BatchExecutor, EchoExecutor, PjrtExecutor, Router};
