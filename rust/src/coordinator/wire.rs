//! The binary wire protocol spoken between [`crate::coordinator::net`]
//! (the TCP front-end) and [`crate::coordinator::client`] (DESIGN.md §12).
//!
//! Every frame is length-prefixed and checksummed:
//!
//! ```text
//! offset  size  field
//! 0       2     magic  b"TN"
//! 2       1     protocol version (= VERSION)
//! 3       1     frame type
//! 4       4     payload length, u32 LE  (hard cap: MAX_PAYLOAD)
//! 8       4     CRC32 (IEEE) over bytes [version, type, len, payload], u32 LE
//! 12      len   payload
//! ```
//!
//! Decoding hard-rejects anything malformed — wrong magic or version,
//! unknown frame type, oversized length, truncated payload, checksum
//! mismatch, trailing payload bytes — with a clean [`Error::Wire`],
//! never a panic and never a silently wrong payload (the CRC covers the
//! type byte and the length, so any single corrupted bit anywhere in a
//! frame is detected; `rust/tests/proptests.rs` flips bits to prove it).
//!
//! All integers are little-endian; `f32` values travel as their LE bit
//! pattern, so an inference round-trip over TCP is bitwise exact
//! (`rust/tests/remote_serving.rs` asserts remote == in-process).

use crate::error::{Error, Result};
use std::io::{Read, Write};

/// First two bytes of every frame.
pub const MAGIC: [u8; 2] = *b"TN";
/// Protocol version; bumped on any layout change (decoders hard-reject
/// other versions).  v2 added the per-model block to `StatsReply`; v3
/// added the admission fields — a trailing retry-after-ms hint on
/// `InferErr` (optional on decode: a v3 frame without it reads as hint
/// 0), `quota_shed` + per-model `shed` in `StatsReply`, and the
/// `Quota` error code.
pub const VERSION: u8 = 3;
/// Hard cap on a frame's payload (16 MiB) — an admission bound, not a
/// tuning knob: a header announcing more than this is rejected before
/// any allocation.
pub const MAX_PAYLOAD: u32 = 1 << 24;
/// Bytes in the fixed frame header.
pub const HEADER_LEN: usize = 12;

/// Machine-readable failure class carried by [`Frame::InferErr`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrCode {
    /// Global admission capacity exhausted — load shed; retry later
    /// (maps to `ServerStats::rejected` on the server).
    Busy = 1,
    /// The request itself was malformed (bad frame, unexpected type).
    BadRequest = 2,
    /// Admission succeeded but execution failed (unknown model, dim
    /// mismatch, executor error).
    Exec = 3,
    /// This model spent its admission quota and the borrowable free
    /// pool (v3) — load shed like `Busy`, but the overload is the
    /// model's own traffic, not the server's: other tenants are fine.
    Quota = 4,
}

impl ErrCode {
    fn from_u8(v: u8) -> Result<ErrCode> {
        match v {
            1 => Ok(ErrCode::Busy),
            2 => Ok(ErrCode::BadRequest),
            3 => Ok(ErrCode::Exec),
            4 => Ok(ErrCode::Quota),
            other => Err(Error::Wire(format!("unknown error code {other}"))),
        }
    }
}

/// One served model as advertised by [`Frame::ModelList`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ModelInfo {
    pub name: String,
    pub input_dim: u32,
    pub output_dim: u32,
}

/// One model's counter snapshot inside [`Frame::StatsReply`] — the wire
/// image of the server's per-model `ModelStats`, so remote operators
/// can see per-model batch efficiency (`batched_rows / batches`)
/// without shell access to the server.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ModelStatsEntry {
    pub name: String,
    pub completed: u64,
    pub errors: u64,
    pub batches: u64,
    pub batched_rows: u64,
    /// admission sheds for this model (v3) — capacity and quota kinds
    /// combined, so asymmetric-overload fairness is visible per tenant
    pub shed: u64,
}

impl ModelStatsEntry {
    /// Mean rows per executed batch of this model.
    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batched_rows as f64 / self.batches as f64
        }
    }
}

/// A typed protocol frame.  Requests flow client → server (`Infer`,
/// `Stats`, `ListModels`, `Shutdown`); replies flow server → client.
/// Replies on one connection arrive in request order.
#[derive(Clone, Debug, PartialEq)]
pub enum Frame {
    /// Run `input` through `model`; `id` is echoed in the reply.
    Infer { id: u64, model: String, input: Vec<f32> },
    /// Successful inference reply (server-side timings included).
    InferOk { id: u64, queue_us: u64, exec_us: u64, batch_size: u32, output: Vec<f32> },
    /// Failed inference reply; `code` distinguishes load-shedding
    /// ([`ErrCode::Busy`] / [`ErrCode::Quota`]) from real failures.
    /// `retry_after_ms` (v3, trailing-optional: decodes as 0 when a
    /// writer omits it) hints how long a shed caller should back off —
    /// ≈ one observed service time; meaningless (0) on non-shed codes.
    InferErr { id: u64, code: ErrCode, message: String, retry_after_ms: u32 },
    /// Request a [`Frame::StatsReply`] snapshot.
    Stats,
    /// Counter snapshot of the server's shared `ServerStats`, including
    /// the per-model block (v2) and admission shed counters (v3).
    StatsReply {
        completed: u64,
        rejected: u64,
        errors: u64,
        failed_workers: u64,
        batches: u64,
        batched_rows: u64,
        /// subset of `rejected` that was per-model quota sheds (v3)
        quota_shed: u64,
        per_model: Vec<ModelStatsEntry>,
    },
    /// Request the served model lineup.
    ListModels,
    /// The served model lineup.
    ModelList { models: Vec<ModelInfo> },
    /// Ask the server process to shut down (acknowledged first).
    Shutdown,
    /// Acknowledges [`Frame::Shutdown`]; the listener stops accepting
    /// after this is written.
    ShutdownOk,
}

const T_INFER: u8 = 1;
const T_INFER_OK: u8 = 2;
const T_INFER_ERR: u8 = 3;
const T_STATS: u8 = 4;
const T_STATS_REPLY: u8 = 5;
const T_LIST_MODELS: u8 = 6;
const T_MODEL_LIST: u8 = 7;
const T_SHUTDOWN: u8 = 8;
const T_SHUTDOWN_OK: u8 = 9;

/// Byte-at-a-time CRC32 lookup table, built at compile time (std-only:
/// a const block, no build script).
const CRC_TABLE: [u32; 256] = {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
};

/// CRC32 (IEEE 802.3, reflected polynomial 0xEDB88320), table-driven —
/// the checksum runs twice per frame per direction, so it must stay
/// well under the transport cost it guards.  CRC32 detects every
/// single-bit and every burst-≤32 error, which is exactly the guarantee
/// the corruption proptests pin down.
pub fn crc32(chunks: &[&[u8]]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for chunk in chunks {
        for &b in *chunk {
            crc = CRC_TABLE[((crc ^ b as u32) & 0xFF) as usize] ^ (crc >> 8);
        }
    }
    !crc
}

/// A validated frame header.  [`Header::decode`] checks magic, version
/// and the length bound; the CRC and frame type are checked against the
/// payload by [`decode_body`] (the payload must be read first).
#[derive(Clone, Copy, Debug)]
pub struct Header {
    pub frame_type: u8,
    pub len: u32,
    crc: u32,
}

impl Header {
    pub fn decode(bytes: &[u8; HEADER_LEN]) -> Result<Header> {
        if bytes[0..2] != MAGIC {
            return Err(Error::Wire(format!(
                "bad magic {:02x}{:02x} (want {:02x}{:02x})",
                bytes[0], bytes[1], MAGIC[0], MAGIC[1]
            )));
        }
        if bytes[2] != VERSION {
            return Err(Error::Wire(format!(
                "protocol version {} (this build speaks {VERSION})",
                bytes[2]
            )));
        }
        let len = u32::from_le_bytes([bytes[4], bytes[5], bytes[6], bytes[7]]);
        if len > MAX_PAYLOAD {
            return Err(Error::Wire(format!("payload of {len} bytes exceeds cap {MAX_PAYLOAD}")));
        }
        let crc = u32::from_le_bytes([bytes[8], bytes[9], bytes[10], bytes[11]]);
        Ok(Header { frame_type: bytes[3], len, crc })
    }
}

/// Decode a payload against its validated header: CRC first (over
/// version + type + length + payload), then a strict type-directed parse
/// that must consume the payload exactly.
pub fn decode_body(header: &Header, payload: &[u8]) -> Result<Frame> {
    if payload.len() != header.len as usize {
        return Err(Error::Wire(format!(
            "payload is {} bytes, header announced {}",
            payload.len(),
            header.len
        )));
    }
    let covered = [VERSION, header.frame_type];
    let want = crc32(&[&covered, &header.len.to_le_bytes(), payload]);
    if want != header.crc {
        return Err(Error::Wire(format!(
            "checksum mismatch: header {:08x}, computed {want:08x}",
            header.crc
        )));
    }
    let mut r = Cursor { buf: payload, pos: 0 };
    let frame = match header.frame_type {
        T_INFER => {
            let id = r.u64()?;
            let model = r.short_string("model name")?;
            let input = r.f32_vec()?;
            Frame::Infer { id, model, input }
        }
        T_INFER_OK => {
            let id = r.u64()?;
            let queue_us = r.u64()?;
            let exec_us = r.u64()?;
            let batch_size = r.u32()?;
            let output = r.f32_vec()?;
            Frame::InferOk { id, queue_us, exec_us, batch_size, output }
        }
        T_INFER_ERR => {
            let id = r.u64()?;
            let code = ErrCode::from_u8(r.u8()?)?;
            let message = r.long_string("error message")?;
            // trailing-optional (v3): a writer that stops after the
            // message still decodes — the hint defaults to 0 (none)
            let retry_after_ms = if r.remaining() > 0 { r.u32()? } else { 0 };
            Frame::InferErr { id, code, message, retry_after_ms }
        }
        T_STATS => Frame::Stats,
        T_STATS_REPLY => {
            let completed = r.u64()?;
            let rejected = r.u64()?;
            let errors = r.u64()?;
            let failed_workers = r.u64()?;
            let batches = r.u64()?;
            let batched_rows = r.u64()?;
            let quota_shed = r.u64()?;
            let count = r.u16()? as usize;
            let mut per_model = Vec::new();
            for _ in 0..count {
                per_model.push(ModelStatsEntry {
                    name: r.short_string("model name")?,
                    completed: r.u64()?,
                    errors: r.u64()?,
                    batches: r.u64()?,
                    batched_rows: r.u64()?,
                    shed: r.u64()?,
                });
            }
            Frame::StatsReply {
                completed,
                rejected,
                errors,
                failed_workers,
                batches,
                batched_rows,
                quota_shed,
                per_model,
            }
        }
        T_LIST_MODELS => Frame::ListModels,
        T_MODEL_LIST => {
            let count = r.u16()? as usize;
            let mut models = Vec::new();
            for _ in 0..count {
                let name = r.short_string("model name")?;
                let input_dim = r.u32()?;
                let output_dim = r.u32()?;
                models.push(ModelInfo { name, input_dim, output_dim });
            }
            Frame::ModelList { models }
        }
        T_SHUTDOWN => Frame::Shutdown,
        T_SHUTDOWN_OK => Frame::ShutdownOk,
        other => return Err(Error::Wire(format!("unknown frame type {other}"))),
    };
    r.finish()?;
    Ok(frame)
}

impl Frame {
    /// Short name of the frame kind — for diagnostics; never includes
    /// the payload (a hostile frame can carry megabytes).
    pub fn kind(&self) -> &'static str {
        match self {
            Frame::Infer { .. } => "Infer",
            Frame::InferOk { .. } => "InferOk",
            Frame::InferErr { .. } => "InferErr",
            Frame::Stats => "Stats",
            Frame::StatsReply { .. } => "StatsReply",
            Frame::ListModels => "ListModels",
            Frame::ModelList { .. } => "ModelList",
            Frame::Shutdown => "Shutdown",
            Frame::ShutdownOk => "ShutdownOk",
        }
    }

    fn frame_type(&self) -> u8 {
        match self {
            Frame::Infer { .. } => T_INFER,
            Frame::InferOk { .. } => T_INFER_OK,
            Frame::InferErr { .. } => T_INFER_ERR,
            Frame::Stats => T_STATS,
            Frame::StatsReply { .. } => T_STATS_REPLY,
            Frame::ListModels => T_LIST_MODELS,
            Frame::ModelList { .. } => T_MODEL_LIST,
            Frame::Shutdown => T_SHUTDOWN,
            Frame::ShutdownOk => T_SHUTDOWN_OK,
        }
    }

    /// Append this frame's payload bytes to `w`.  Writing straight into
    /// the caller's buffer (instead of returning a fresh `Vec`) is what
    /// lets [`Frame::encode_into`] serialize a whole frame with zero
    /// allocations in steady state.
    fn write_payload(&self, w: &mut Vec<u8>) -> Result<()> {
        match self {
            Frame::Infer { id, model, input } => {
                w.extend_from_slice(&id.to_le_bytes());
                put_short_string(w, model, "model name")?;
                put_f32_vec(w, input);
            }
            Frame::InferOk { id, queue_us, exec_us, batch_size, output } => {
                w.extend_from_slice(&id.to_le_bytes());
                w.extend_from_slice(&queue_us.to_le_bytes());
                w.extend_from_slice(&exec_us.to_le_bytes());
                w.extend_from_slice(&batch_size.to_le_bytes());
                put_f32_vec(w, output);
            }
            Frame::InferErr { id, code, message, retry_after_ms } => {
                w.extend_from_slice(&id.to_le_bytes());
                w.push(*code as u8);
                put_long_string(w, message);
                // always written; decoders treat it as trailing-optional
                w.extend_from_slice(&retry_after_ms.to_le_bytes());
            }
            Frame::Stats | Frame::ListModels | Frame::Shutdown | Frame::ShutdownOk => {}
            Frame::StatsReply {
                completed,
                rejected,
                errors,
                failed_workers,
                batches,
                batched_rows,
                quota_shed,
                per_model,
            } => {
                for v in
                    [completed, rejected, errors, failed_workers, batches, batched_rows, quota_shed]
                {
                    w.extend_from_slice(&v.to_le_bytes());
                }
                let count = u16::try_from(per_model.len()).map_err(|_| {
                    Error::Wire(format!("{} models exceed the u16 stats cap", per_model.len()))
                })?;
                w.extend_from_slice(&count.to_le_bytes());
                for m in per_model {
                    put_short_string(w, &m.name, "model name")?;
                    for v in [m.completed, m.errors, m.batches, m.batched_rows, m.shed] {
                        w.extend_from_slice(&v.to_le_bytes());
                    }
                }
            }
            Frame::ModelList { models } => {
                let count = u16::try_from(models.len()).map_err(|_| {
                    Error::Wire(format!("{} models exceed the u16 lineup cap", models.len()))
                })?;
                w.extend_from_slice(&count.to_le_bytes());
                for m in models {
                    put_short_string(w, &m.name, "model name")?;
                    w.extend_from_slice(&m.input_dim.to_le_bytes());
                    w.extend_from_slice(&m.output_dim.to_le_bytes());
                }
            }
        }
        Ok(())
    }

    /// Serialize into one contiguous header + payload buffer.
    ///
    /// Convenience wrapper over [`Frame::encode_into`]; hot paths (the
    /// reactor's reply writer, `Client`) reuse a persistent buffer via
    /// `encode_into` instead so steady state allocates nothing per frame.
    pub fn encode(&self) -> Result<Vec<u8>> {
        let mut out = Vec::new();
        self.encode_into(&mut out)?;
        Ok(out)
    }

    /// Append one encoded frame (header + payload) to `out`.
    ///
    /// Bytes already in `out` are left untouched, so a writer can encode
    /// straight onto the tail of its pending write buffer.  The payload
    /// is serialized in place and the header's length/CRC words are
    /// backfilled afterwards — no intermediate payload `Vec`, which is
    /// the whole point: with a reused buffer this path does zero heap
    /// allocation once the buffer has grown to working-set size.  On
    /// error `out` is restored to its original length.
    pub fn encode_into(&self, out: &mut Vec<u8>) -> Result<()> {
        let start = out.len();
        out.extend_from_slice(&MAGIC);
        out.push(VERSION);
        out.push(self.frame_type());
        // length + CRC are not known yet; reserve their bytes
        out.extend_from_slice(&[0u8; 8]);
        if let Err(e) = self.write_payload(out) {
            out.truncate(start);
            return Err(e);
        }
        let payload_len = out.len() - start - HEADER_LEN;
        if payload_len > MAX_PAYLOAD as usize {
            out.truncate(start);
            return Err(Error::Wire(format!(
                "frame payload of {payload_len} bytes exceeds cap {MAX_PAYLOAD}"
            )));
        }
        let len = (payload_len as u32).to_le_bytes();
        let crc = crc32(&[
            &[VERSION, self.frame_type()],
            &len,
            &out[start + HEADER_LEN..],
        ])
        .to_le_bytes();
        out[start + 4..start + 8].copy_from_slice(&len);
        out[start + 8..start + 12].copy_from_slice(&crc);
        Ok(())
    }

    /// Decode exactly one frame from `bytes` (the whole slice must be the
    /// frame — trailing bytes reject).  The buffer-level entry point the
    /// corruption proptests drive.
    pub fn decode(bytes: &[u8]) -> Result<Frame> {
        if bytes.len() < HEADER_LEN {
            return Err(Error::Wire(format!(
                "{} bytes is shorter than the {HEADER_LEN}-byte header",
                bytes.len()
            )));
        }
        let mut head = [0u8; HEADER_LEN];
        head.copy_from_slice(&bytes[..HEADER_LEN]);
        let header = Header::decode(&head)?;
        decode_body(&header, &bytes[HEADER_LEN..])
    }

    /// Write the encoded frame (no flush — callers batch then flush).
    pub fn write_to<W: Write>(&self, w: &mut W) -> Result<()> {
        let bytes = self.encode()?;
        w.write_all(&bytes).map_err(|e| Error::Net(format!("write frame: {e}")))
    }

    /// Read exactly one frame from a blocking reader.  EOF before the
    /// first header byte returns `Ok(None)` (clean close); EOF anywhere
    /// after is a truncation error.
    pub fn read_from<R: Read>(r: &mut R) -> Result<Option<Frame>> {
        match read_frame(r, || false)? {
            ReadOutcome::Frame(f) => Ok(Some(f)),
            // Stopped is unreachable with a constant-false should_stop;
            // fold it into the clean-close case rather than panic
            ReadOutcome::Eof | ReadOutcome::Stopped => Ok(None),
        }
    }
}

/// Outcome of one [`read_frame`] attempt.
pub enum ReadOutcome {
    Frame(Frame),
    /// clean EOF at a frame boundary (peer closed)
    Eof,
    /// `should_stop` returned true while the read was idle
    Stopped,
}

/// Read one frame from `r`, polling `should_stop` whenever the reader
/// reports a timeout (`WouldBlock`/`TimedOut` — how a socket with a
/// read timeout idles).  The single framed-read implementation: the
/// blocking client wraps it with a constant-false `should_stop`
/// ([`Frame::read_from`]) and the server's connection readers pass
/// their stop flag, so header/payload sequencing and truncation
/// handling cannot drift between the two sides.
pub fn read_frame<R: Read>(
    r: &mut R,
    mut should_stop: impl FnMut() -> bool,
) -> Result<ReadOutcome> {
    let mut head = [0u8; HEADER_LEN];
    match read_full(r, &mut head, &mut should_stop)? {
        Filled::Stopped => return Ok(ReadOutcome::Stopped),
        Filled::Eof(0) => return Ok(ReadOutcome::Eof),
        Filled::Eof(n) => {
            return Err(Error::Wire(format!(
                "connection closed after {n} of {HEADER_LEN} header bytes"
            )))
        }
        Filled::Full => {}
    }
    let header = Header::decode(&head)?;
    let mut payload = vec![0u8; header.len as usize];
    match read_full(r, &mut payload, &mut should_stop)? {
        Filled::Stopped => return Ok(ReadOutcome::Stopped),
        Filled::Eof(n) => {
            return Err(Error::Wire(format!(
                "connection closed after {n} of {} payload bytes",
                payload.len()
            )))
        }
        Filled::Full => {}
    }
    Ok(ReadOutcome::Frame(decode_body(&header, &payload)?))
}

/// Incremental frame decoder for non-blocking transports (the reactor
/// in [`crate::coordinator::net`]): buffer bytes exactly as the socket
/// delivers them ([`FrameDecoder::feed`]) and pull complete frames out
/// ([`FrameDecoder::next_frame`]) — one read may carry half a frame or
/// several pipelined ones, and the decoder owes a frame only once its
/// last byte has arrived.
///
/// Validation is byte-for-byte the blocking path's: headers go through
/// [`Header::decode`] (so a hostile length is rejected the moment the
/// 12th header byte lands, before any payload is buffered) and payloads
/// through [`decode_body`] (CRC, strict type-directed parse, no
/// trailing bytes).  A returned error poisons the stream — the caller
/// must answer `BadRequest` and close, same as the one-shot path
/// (`rust/tests/proptests.rs` feeds every frame byte-at-a-time and at
/// random split points to pin the two paths together).
#[derive(Default)]
pub struct FrameDecoder {
    buf: Vec<u8>,
    /// consumed prefix of `buf` (compacted on the next `feed`, so a
    /// burst of small pipelined frames doesn't memmove per frame)
    pos: usize,
    /// header of the frame currently being assembled, once its 12 bytes
    /// have arrived and validated
    header: Option<Header>,
}

impl FrameDecoder {
    pub fn new() -> FrameDecoder {
        FrameDecoder::default()
    }

    /// Buffer incoming bytes.  Call [`FrameDecoder::next_frame`] until
    /// it returns `Ok(None)` to drain every frame they completed.
    pub fn feed(&mut self, bytes: &[u8]) {
        if self.pos > 0 {
            self.buf.drain(..self.pos);
            self.pos = 0;
        }
        self.buf.extend_from_slice(bytes);
    }

    /// Try to decode the next complete frame from the buffered bytes.
    /// `Ok(None)` means "need more bytes"; an error is a protocol
    /// violation and the connection must close (decoder state is spent).
    pub fn next_frame(&mut self) -> Result<Option<Frame>> {
        let header = match self.header {
            Some(h) => h,
            None => {
                if self.buf.len() - self.pos < HEADER_LEN {
                    return Ok(None);
                }
                let mut head = [0u8; HEADER_LEN];
                head.copy_from_slice(&self.buf[self.pos..self.pos + HEADER_LEN]);
                // magic/version/length-cap errors fire HERE — an
                // announced 4 GiB payload rejects on its 12th byte, with
                // nothing buffered beyond what the socket already gave us
                let h = Header::decode(&head)?;
                self.pos += HEADER_LEN;
                self.header = Some(h);
                h
            }
        };
        let need = header.len as usize;
        if self.buf.len() - self.pos < need {
            return Ok(None);
        }
        let frame = decode_body(&header, &self.buf[self.pos..self.pos + need])?;
        self.pos += need;
        self.header = None;
        if self.pos == self.buf.len() {
            self.buf.clear();
            self.pos = 0;
        }
        Ok(Some(frame))
    }

    /// Bytes of an incomplete frame currently buffered — 0 exactly when
    /// the fed stream ended on a frame boundary.  Lets a transport tell
    /// a clean peer close from a mid-frame truncation.
    pub fn pending(&self) -> usize {
        (self.buf.len() - self.pos)
            + if self.header.is_some() { HEADER_LEN } else { 0 }
    }
}

enum Filled {
    Full,
    /// EOF after this many of the wanted bytes
    Eof(usize),
    Stopped,
}

/// `read_exact` that reports EOF position, treats timeouts as polls of
/// `should_stop`, and maps io errors to [`Error::Net`].
fn read_full<R: Read>(
    r: &mut R,
    buf: &mut [u8],
    should_stop: &mut impl FnMut() -> bool,
) -> Result<Filled> {
    let mut done = 0;
    while done < buf.len() {
        match r.read(&mut buf[done..]) {
            Ok(0) => return Ok(Filled::Eof(done)),
            Ok(n) => done += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                if should_stop() {
                    return Ok(Filled::Stopped);
                }
            }
            Err(e) => return Err(Error::Net(format!("read frame: {e}"))),
        }
    }
    Ok(Filled::Full)
}

fn put_short_string(w: &mut Vec<u8>, s: &str, what: &str) -> Result<()> {
    let len = u16::try_from(s.len())
        .map_err(|_| Error::Wire(format!("{what} of {} bytes exceeds the u16 cap", s.len())))?;
    w.extend_from_slice(&len.to_le_bytes());
    w.extend_from_slice(s.as_bytes());
    Ok(())
}

fn put_long_string(w: &mut Vec<u8>, s: &str) {
    // messages are server-generated; truncate rather than fail so an
    // error reply can always be delivered
    let bytes = s.as_bytes();
    let take = bytes.len().min(MAX_PAYLOAD as usize / 2);
    w.extend_from_slice(&(take as u32).to_le_bytes());
    w.extend_from_slice(&bytes[..take]);
}

fn put_f32_vec(w: &mut Vec<u8>, xs: &[f32]) {
    w.extend_from_slice(&(xs.len() as u32).to_le_bytes());
    for x in xs {
        w.extend_from_slice(&x.to_le_bytes());
    }
}

/// Bounds-checked payload reader; every draw past the end is a clean
/// [`Error::Wire`], and [`Cursor::finish`] rejects trailing bytes.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8]> {
        let end = self.pos.checked_add(n).filter(|&e| e <= self.buf.len()).ok_or_else(|| {
            Error::Wire(format!(
                "truncated payload: {what} needs {n} bytes at offset {}, have {}",
                self.pos,
                self.buf.len()
            ))
        })?;
        let out = &self.buf[self.pos..end];
        self.pos = end;
        Ok(out)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1, "u8")?[0])
    }

    fn u16(&mut self) -> Result<u16> {
        let b = self.take(2, "u16")?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4, "u32")?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8, "u64")?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    fn string(&mut self, len: usize, what: &str) -> Result<String> {
        let bytes = self.take(len, what)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| Error::Wire(format!("{what} is not valid utf-8")))
    }

    fn short_string(&mut self, what: &str) -> Result<String> {
        let len = self.u16()? as usize;
        self.string(len, what)
    }

    fn long_string(&mut self, what: &str) -> Result<String> {
        let len = self.u32()? as usize;
        self.string(len, what)
    }

    fn f32_vec(&mut self) -> Result<Vec<f32>> {
        let n = self.u32()? as usize;
        // checked: on 32-bit targets a hostile count would otherwise wrap
        // the multiply (debug panic / silently short vector)
        let byte_len = n
            .checked_mul(4)
            .ok_or_else(|| Error::Wire(format!("f32 count {n} overflows the byte length")))?;
        let bytes = self.take(byte_len, "f32 values")?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Unread payload bytes — how trailing-optional fields (the v3
    /// `InferErr` retry hint) test for presence before drawing.
    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn finish(&self) -> Result<()> {
        if self.pos != self.buf.len() {
            return Err(Error::Wire(format!(
                "{} trailing payload bytes after a complete frame",
                self.buf.len() - self.pos
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_frames() -> Vec<Frame> {
        vec![
            Frame::Infer { id: 7, model: "tt_layer".into(), input: vec![1.0, -2.5, 0.0] },
            Frame::InferOk {
                id: 7,
                queue_us: 120,
                exec_us: 340,
                batch_size: 4,
                output: vec![0.5; 6],
            },
            Frame::InferErr {
                id: 9,
                code: ErrCode::Busy,
                message: "admission queue full".into(),
                retry_after_ms: 7,
            },
            Frame::InferErr {
                id: 10,
                code: ErrCode::Quota,
                message: "model quota exceeded".into(),
                retry_after_ms: 12,
            },
            Frame::Stats,
            Frame::StatsReply {
                completed: 10,
                rejected: 2,
                errors: 1,
                failed_workers: 0,
                batches: 5,
                batched_rows: 10,
                quota_shed: 1,
                per_model: vec![
                    ModelStatsEntry {
                        name: "tt_layer".into(),
                        completed: 6,
                        errors: 0,
                        batches: 2,
                        batched_rows: 6,
                        shed: 2,
                    },
                    ModelStatsEntry {
                        name: "fc_mnist".into(),
                        completed: 4,
                        errors: 1,
                        batches: 3,
                        batched_rows: 4,
                        shed: 0,
                    },
                ],
            },
            Frame::StatsReply {
                completed: 0,
                rejected: 0,
                errors: 0,
                failed_workers: 0,
                batches: 0,
                batched_rows: 0,
                quota_shed: 0,
                per_model: vec![],
            },
            Frame::ListModels,
            Frame::ModelList {
                models: vec![
                    ModelInfo { name: "tt_layer".into(), input_dim: 1024, output_dim: 1024 },
                    ModelInfo { name: "mnist_net".into(), input_dim: 1024, output_dim: 10 },
                ],
            },
            Frame::Shutdown,
            Frame::ShutdownOk,
        ]
    }

    #[test]
    fn every_frame_kind_roundtrips() {
        for f in sample_frames() {
            let bytes = f.encode().unwrap();
            let back = Frame::decode(&bytes).unwrap();
            assert_eq!(back, f, "{f:?}");
            // and through the streaming reader
            let mut r = std::io::Cursor::new(bytes);
            assert_eq!(Frame::read_from(&mut r).unwrap(), Some(f));
            assert_eq!(Frame::read_from(&mut r).unwrap(), None, "clean EOF after one frame");
        }
    }

    #[test]
    fn infer_f32_payload_is_bitwise() {
        let input = vec![f32::MIN_POSITIVE, -0.0, 1.5e-42, f32::MAX];
        let f = Frame::Infer { id: 1, model: "m".into(), input: input.clone() };
        match Frame::decode(&f.encode().unwrap()).unwrap() {
            Frame::Infer { input: back, .. } => {
                let want: Vec<u32> = input.iter().map(|x| x.to_bits()).collect();
                let got: Vec<u32> = back.iter().map(|x| x.to_bits()).collect();
                assert_eq!(got, want);
            }
            other => panic!("decoded {other:?}"),
        }
    }

    #[test]
    fn infer_err_without_trailing_hint_decodes_as_zero() {
        // backward-decodability of the v3 retry hint: hand-assemble an
        // InferErr payload that STOPS after the message (what a v3
        // writer without the field would send) and check it decodes
        // with retry_after_ms == 0
        let msg = b"admission queue full";
        let mut payload = Vec::new();
        payload.extend_from_slice(&9u64.to_le_bytes()); // id
        payload.push(ErrCode::Busy as u8);
        payload.extend_from_slice(&(msg.len() as u32).to_le_bytes());
        payload.extend_from_slice(msg);
        let mut frame = Vec::new();
        frame.extend_from_slice(&MAGIC);
        frame.push(VERSION);
        frame.push(T_INFER_ERR);
        frame.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        let crc = crc32(&[
            &[VERSION, T_INFER_ERR],
            &(payload.len() as u32).to_le_bytes(),
            &payload,
        ]);
        frame.extend_from_slice(&crc.to_le_bytes());
        frame.extend_from_slice(&payload);
        match Frame::decode(&frame).unwrap() {
            Frame::InferErr { id, code, message, retry_after_ms } => {
                assert_eq!(id, 9);
                assert_eq!(code, ErrCode::Busy);
                assert_eq!(message, "admission queue full");
                assert_eq!(retry_after_ms, 0, "missing hint must read as none");
            }
            other => panic!("decoded {other:?}"),
        }
    }

    #[test]
    fn bad_magic_version_type_and_oversize_reject() {
        let good = Frame::Stats.encode().unwrap();
        let mut bad = good.clone();
        bad[0] = b'X';
        assert!(matches!(Frame::decode(&bad), Err(Error::Wire(m)) if m.contains("magic")));
        let mut bad = good.clone();
        bad[2] = VERSION + 1;
        assert!(matches!(Frame::decode(&bad), Err(Error::Wire(m)) if m.contains("version")));
        let mut bad = good.clone();
        bad[3] = 200; // unknown type (also breaks the crc; both are clean errors)
        assert!(Frame::decode(&bad).is_err());
        let mut bad = good;
        bad[4..8].copy_from_slice(&(MAX_PAYLOAD + 1).to_le_bytes());
        assert!(matches!(Frame::decode(&bad), Err(Error::Wire(m)) if m.contains("cap")));
    }

    #[test]
    fn truncations_and_trailing_bytes_reject() {
        let bytes = Frame::Infer { id: 3, model: "tt".into(), input: vec![1.0, 2.0] }
            .encode()
            .unwrap();
        for cut in 0..bytes.len() {
            assert!(Frame::decode(&bytes[..cut]).is_err(), "cut {cut}");
        }
        let mut padded = bytes;
        padded.push(0);
        assert!(Frame::decode(&padded).is_err(), "trailing byte");
    }

    #[test]
    fn checksum_catches_payload_corruption() {
        let bytes =
            Frame::Infer { id: 3, model: "tt".into(), input: vec![1.0, 2.0] }.encode().unwrap();
        // flip one payload bit: the value would still parse, so only the
        // crc stands between this and a silently wrong input vector
        let mut bad = bytes;
        let last = bad.len() - 1;
        bad[last] ^= 0x40;
        assert!(
            matches!(Frame::decode(&bad), Err(Error::Wire(m)) if m.contains("checksum")),
            "payload bit flip must fail the crc"
        );
    }

    #[test]
    fn streaming_reader_reports_mid_frame_eof() {
        let bytes = Frame::Stats.encode().unwrap();
        let mut r = std::io::Cursor::new(&bytes[..HEADER_LEN - 3]);
        let err = Frame::read_from(&mut r).unwrap_err();
        assert!(format!("{err}").contains("header"), "{err}");
    }

    #[test]
    fn incremental_decoder_byte_at_a_time_matches_one_shot() {
        for f in sample_frames() {
            let bytes = f.encode().unwrap();
            let mut dec = FrameDecoder::new();
            for (i, b) in bytes.iter().enumerate() {
                dec.feed(std::slice::from_ref(b));
                let got = dec.next_frame().unwrap();
                if i + 1 < bytes.len() {
                    assert!(got.is_none(), "{f:?}: frame surfaced at byte {}", i + 1);
                    assert!(dec.pending() > 0);
                } else {
                    assert_eq!(got, Some(f.clone()), "{f:?}");
                }
            }
            assert_eq!(dec.pending(), 0, "{f:?}: boundary after the last byte");
            assert_eq!(dec.next_frame().unwrap(), None);
        }
    }

    #[test]
    fn incremental_decoder_drains_pipelined_frames_from_one_chunk() {
        let frames = sample_frames();
        let stream: Vec<u8> =
            frames.iter().flat_map(|f| f.encode().unwrap()).collect();
        let mut dec = FrameDecoder::new();
        dec.feed(&stream);
        let mut got = Vec::new();
        while let Some(f) = dec.next_frame().unwrap() {
            got.push(f);
        }
        assert_eq!(got, frames);
        assert_eq!(dec.pending(), 0);
    }

    #[test]
    fn incremental_decoder_rejects_oversize_at_the_header() {
        // the hostile length must reject as soon as the 12th byte lands,
        // with no payload ever buffered
        let mut head = Vec::new();
        head.extend_from_slice(&MAGIC);
        head.push(VERSION);
        head.push(T_INFER);
        head.extend_from_slice(&(MAX_PAYLOAD + 1).to_le_bytes());
        head.extend_from_slice(&0u32.to_le_bytes());
        let mut dec = FrameDecoder::new();
        dec.feed(&head[..HEADER_LEN - 1]);
        assert!(dec.next_frame().unwrap().is_none());
        dec.feed(&head[HEADER_LEN - 1..]);
        let err = dec.next_frame().unwrap_err();
        assert!(format!("{err}").contains("cap"), "{err}");
    }

    #[test]
    fn incremental_decoder_rejects_garbage_and_corruption() {
        let mut dec = FrameDecoder::new();
        dec.feed(&[0xFF; HEADER_LEN]);
        assert!(dec.next_frame().is_err(), "wrong magic");

        let mut bad = Frame::Stats.encode().unwrap();
        let last = bad.len() - 1;
        bad[last] ^= 1; // corrupt the CRC byte
        let mut dec = FrameDecoder::new();
        dec.feed(&bad);
        assert!(dec.next_frame().is_err(), "checksum mismatch");
    }

    #[test]
    fn oversized_encode_rejects() {
        let f = Frame::Infer {
            id: 1,
            model: "m".into(),
            input: vec![0.0; MAX_PAYLOAD as usize / 4 + 8],
        };
        assert!(f.encode().is_err());
    }

    #[test]
    fn encode_into_appends_bytes_identical_to_encode() {
        let mut buf = vec![0xAB, 0xCD, 0xEF]; // pre-existing tail must survive
        for f in sample_frames() {
            let prefix = buf.clone();
            f.encode_into(&mut buf).unwrap();
            assert_eq!(&buf[..prefix.len()], &prefix[..], "{f:?}: prefix clobbered");
            assert_eq!(&buf[prefix.len()..], &f.encode().unwrap()[..], "{f:?}");
        }
    }

    #[test]
    fn encode_into_restores_buffer_on_error() {
        let mut buf = b"keep".to_vec();
        let oversize = Frame::Infer {
            id: 1,
            model: "m".into(),
            input: vec![0.0; MAX_PAYLOAD as usize / 4 + 8],
        };
        assert!(oversize.encode_into(&mut buf).is_err());
        assert_eq!(buf, b"keep", "failed encode must not leave partial bytes");
        // a payload-stage failure (name over the u16 cap) must restore too
        let bad_name = Frame::Infer { id: 1, model: "x".repeat(70_000), input: vec![] };
        assert!(bad_name.encode_into(&mut buf).is_err());
        assert_eq!(buf, b"keep");
    }
}
