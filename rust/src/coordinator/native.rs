//! Native serving backend: real TT/dense models executed in-process.
//!
//! Table 3 of the paper is a *serving* measurement, but the PJRT path is
//! stubbed in the offline build (DESIGN.md §Substitutions), so until this
//! module existed the batcher/router/metrics stack had never executed a
//! real TT matvec.  [`NativeExecutor`] closes that gap: a
//! [`ModelRegistry`] of named, deterministic model specs is instantiated
//! lazily *inside each executor worker* (the [`crate::coordinator::Server`]
//! factory runs on the worker thread), so every worker owns its models and
//! its [`MatvecScratch`] — on the TT path the only allocations per served
//! batch are the batch-gather buffer (which becomes the input tensor
//! without a copy) and the output, exactly like the direct
//! [`TtMatrix::matvec_with`] hot loop.
//!
//! Model construction is deterministic per `seed` (the in-tree
//! xoshiro256++ [`Rng`]), which is what makes a multi-worker pool
//! coherent: every worker materializes bitwise-identical weights, so a
//! request's reply does not depend on which worker drained its batch.
//! Tests rely on the same property to build an out-of-band oracle (see
//! `rust/tests/native_serving.rs`).
//!
//! Beyond the seed-deterministic zoo, [`ModelSpec::Checkpoint`] serves
//! *trained* artifacts: [`ModelRegistry::from_dir`] scans a directory of
//! `runtime::Checkpoint`s (what `tensornet train --save` and `tensornet
//! compress` write) and registers each one by name — determinism across
//! workers comes from every worker loading the same bytes.

use crate::coordinator::worker::BatchExecutor;
use crate::error::{Error, Result};
use crate::nn::{Layer, Sequential};
use crate::runtime::Checkpoint;
use crate::tensor::{matmul_bt, Tensor};
use crate::tt::{MatvecScratch, TtMatrix, TtShape};
use crate::util::rng::Rng;
use std::collections::BTreeMap;
use std::path::Path;

/// How to build one named inference-ready model.  Pure data — `Clone` +
/// `Send` — so a registry can be moved into the server's executor factory
/// and instantiated independently on every worker thread.
#[derive(Clone, Debug)]
pub enum ModelSpec {
    /// A bare TT matrix `W (Πms x Πns)` applied as `y = x Wᵀ` — the
    /// paper's TT-layer matvec (weights `TtMatrix::random` at `seed`).
    TtLayer { ms: Vec<usize>, ns: Vec<usize>, rank: usize, seed: u64 },
    /// The dense counterpart: an explicit `(n_out, n_in)` matrix applied
    /// as `y = x Wᵀ` (the Table-3 baseline row).
    DenseLayer { n_out: usize, n_in: usize, seed: u64 },
    /// The full MNIST TensorNet of `nn::zoo`:
    /// `TT(4^5/4^5, rank) -> ReLU -> FC(1024 -> 10)`.
    MnistTensorNet { rank: usize, seed: u64 },
    /// The conv-MNIST net of `nn::zoo`: a TT-format convolution (Garipov
    /// reshape) over the 1x32x32 input, then `ReLU -> FC(2048 -> 10)` —
    /// a second FLOP/byte profile for the whole serving stack.
    ConvMnist { rank: usize, seed: u64 },
    /// A bare block-term layer `W = Σ_b A_b G_b B_b` applied as
    /// `y = x Wᵀ + bias` (BT-Nets) — the third weight-storage family.
    BtLayer { n_out: usize, n_in: usize, blocks: usize, rank: usize, seed: u64 },
    /// A trained model persisted by `runtime::Checkpoint` — the lifecycle
    /// endpoint: whatever `tensornet train --save` / `tensornet compress`
    /// wrote is served as-is.  Dims are captured at registration time
    /// (`Checkpoint::peek`) so admission checks never touch the blob;
    /// every worker loads the same files, so the pool stays coherent.
    Checkpoint { dir: String, n_in: usize, n_out: usize },
}

impl ModelSpec {
    /// Per-row input dimension — pure arithmetic, no model construction.
    pub fn input_dim(&self) -> usize {
        match self {
            ModelSpec::TtLayer { ns, .. } => ns.iter().product(),
            ModelSpec::DenseLayer { n_in, .. } => *n_in,
            ModelSpec::MnistTensorNet { .. } => 1024,
            ModelSpec::ConvMnist { .. } => 1024,
            ModelSpec::BtLayer { n_in, .. } => *n_in,
            ModelSpec::Checkpoint { n_in, .. } => *n_in,
        }
    }

    /// Per-row output dimension.
    pub fn output_dim(&self) -> usize {
        match self {
            ModelSpec::TtLayer { ms, .. } => ms.iter().product(),
            ModelSpec::DenseLayer { n_out, .. } => *n_out,
            ModelSpec::MnistTensorNet { .. } => 10,
            ModelSpec::ConvMnist { .. } => 10,
            ModelSpec::BtLayer { n_out, .. } => *n_out,
            ModelSpec::Checkpoint { n_out, .. } => *n_out,
        }
    }

    /// Materialize the model.  Deterministic: the same spec always yields
    /// bitwise-identical weights, on any thread.
    fn build(&self) -> Result<NativeModel> {
        match self {
            ModelSpec::TtLayer { ms, ns, rank, seed } => {
                let shape = TtShape::uniform(ms, ns, *rank)?;
                let tt = TtMatrix::random(&shape, &mut Rng::new(*seed))?;
                Ok(NativeModel::Tt { tt, scratch: MatvecScratch::default() })
            }
            ModelSpec::DenseLayer { n_out, n_in, seed } => {
                let w = Tensor::randn(&[*n_out, *n_in], 0.02, &mut Rng::new(*seed));
                Ok(NativeModel::Dense { w })
            }
            ModelSpec::MnistTensorNet { rank, seed } => {
                let net = crate::nn::mnist_tensornet(*rank, &mut Rng::new(*seed))?;
                Ok(NativeModel::Net(net))
            }
            ModelSpec::ConvMnist { rank, seed } => {
                let net = crate::nn::mnist_tt_convnet(*rank, &mut Rng::new(*seed))?;
                Ok(NativeModel::Net(net))
            }
            ModelSpec::BtLayer { n_out, n_in, blocks, rank, seed } => {
                let bt =
                    crate::nn::BtLinear::new(*n_out, *n_in, *blocks, *rank, &mut Rng::new(*seed))?;
                Ok(NativeModel::Loaded(Box::new(bt)))
            }
            ModelSpec::Checkpoint { dir, .. } => {
                Ok(NativeModel::Loaded(Checkpoint::load(Path::new(dir))?.build()?))
            }
        }
    }
}

/// An instantiated model plus its per-worker reusable state.
enum NativeModel {
    Tt { tt: TtMatrix, scratch: MatvecScratch },
    Dense { w: Tensor },
    Net(Sequential),
    /// A checkpoint-restored model of arbitrary structure.
    Loaded(Box<dyn Layer>),
}

/// Named inference-ready model specs.  Cheap to clone; the server's
/// executor factory clones it into every worker.
#[derive(Clone, Debug, Default)]
pub struct ModelRegistry {
    specs: BTreeMap<String, ModelSpec>,
}

impl ModelRegistry {
    pub fn new() -> Self {
        ModelRegistry::default()
    }

    /// The stock serving lineup at the paper's Table-3 MNIST geometry:
    ///
    /// * `tt_layer`   — TT 1024x1024 (4^5 modes, rank 8), in/out 1024
    /// * `fc_mnist`   — dense 1024x1024 counterpart, in/out 1024
    /// * `mnist_net`  — full MNIST TensorNet, in 1024 / out 10
    /// * `conv_mnist` — TT-conv MNIST net (Garipov reshape), in 1024 / out 10
    /// * `bt_layer`   — block-term 1024x1024 (4 blocks, rank 8), in/out 1024
    pub fn standard() -> Self {
        let mut r = ModelRegistry::new();
        r.register(
            "tt_layer",
            ModelSpec::TtLayer { ms: vec![4; 5], ns: vec![4; 5], rank: 8, seed: 0x7e50_0001 },
        );
        r.register("fc_mnist", ModelSpec::DenseLayer { n_out: 1024, n_in: 1024, seed: 0x7e50_0002 });
        r.register("mnist_net", ModelSpec::MnistTensorNet { rank: 8, seed: 0x7e50_0003 });
        r.register("conv_mnist", ModelSpec::ConvMnist { rank: 4, seed: 0x7e50_0004 });
        r.register(
            "bt_layer",
            ModelSpec::BtLayer { n_out: 1024, n_in: 1024, blocks: 4, rank: 8, seed: 0x7e50_0005 },
        );
        r
    }

    /// Register every checkpoint under `dir`: the directory itself if it
    /// is one, otherwise each immediate subdirectory containing a
    /// checkpoint, named after the subdirectory.  This is what
    /// `tensornet serve --models <dir>` builds its lineup from.
    pub fn from_dir(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref();
        let mut r = ModelRegistry::new();
        if Checkpoint::exists(dir) {
            let name = dir
                .file_name()
                .map(|n| n.to_string_lossy().into_owned())
                .unwrap_or_else(|| "model".to_string());
            r.register_checkpoint(&name, dir)?;
            return Ok(r);
        }
        let entries = std::fs::read_dir(dir)
            .map_err(|e| Error::Coordinator(format!("reading {}: {e}", dir.display())))?;
        // sort for a deterministic registry regardless of readdir order
        let mut paths: Vec<_> = entries
            .filter_map(|e| e.ok().map(|e| e.path()))
            .filter(|p| Checkpoint::exists(p))
            .collect();
        paths.sort();
        for p in &paths {
            let name = p
                .file_name()
                .map(|n| n.to_string_lossy().into_owned())
                .unwrap_or_default();
            r.register_checkpoint(&name, p)?;
        }
        if r.specs.is_empty() {
            return Err(Error::Coordinator(format!(
                "no checkpoints under {} (expected <dir>/checkpoint.json or \
                 <dir>/<model>/checkpoint.json)",
                dir.display()
            )));
        }
        Ok(r)
    }

    /// Register one checkpoint directory under `name`.  Reads only the
    /// header ([`Checkpoint::peek`]) — the blob loads lazily per worker.
    pub fn register_checkpoint(&mut self, name: &str, dir: impl AsRef<Path>) -> Result<()> {
        let dir = dir.as_ref();
        let info = Checkpoint::peek(dir)?;
        self.register(
            name,
            ModelSpec::Checkpoint {
                dir: dir.to_string_lossy().into_owned(),
                n_in: info.input_dim,
                n_out: info.output_dim,
            },
        );
        Ok(())
    }

    pub fn register(&mut self, name: &str, spec: ModelSpec) {
        self.specs.insert(name.to_string(), spec);
    }

    pub fn names(&self) -> Vec<&str> {
        self.specs.keys().map(|s| s.as_str()).collect()
    }

    pub fn spec(&self, name: &str) -> Result<&ModelSpec> {
        self.specs.get(name).ok_or_else(|| {
            Error::Coordinator(format!(
                "unknown model '{name}' (registered: {})",
                self.names().join(", ")
            ))
        })
    }

    /// Per-row input dimension of a registered model.
    pub fn input_dim(&self, name: &str) -> Result<usize> {
        Ok(self.spec(name)?.input_dim())
    }
}

/// [`BatchExecutor`] over a [`ModelRegistry`]: the fully-working native
/// stack behind the batcher.  Models build lazily on first use, so a
/// worker only pays for the models its traffic actually routes to.  The
/// batch buffer arrives owned from the server and is wrapped into the
/// input tensor without a copy; every TT sweep — the bare
/// [`ModelSpec::TtLayer`] path and any `TtLinear` inside a
/// checkpoint-restored model — retains its [`MatvecScratch`] capacity
/// across batches.  (Multi-layer `Loaded`/`Net` models still allocate
/// each layer's output tensor per batch — inherent to
/// `Sequential::forward`.)
pub struct NativeExecutor {
    registry: ModelRegistry,
    models: BTreeMap<String, NativeModel>,
}

impl NativeExecutor {
    pub fn new(registry: ModelRegistry) -> Self {
        NativeExecutor { registry, models: BTreeMap::new() }
    }

    /// Executor over [`ModelRegistry::standard`].
    pub fn standard() -> Self {
        NativeExecutor::new(ModelRegistry::standard())
    }

    pub fn registry(&self) -> &ModelRegistry {
        &self.registry
    }

    /// Resolve `name` to its (lazily built) model and per-row input
    /// dimension with a single registry lookup.
    fn model_for(&mut self, name: &str) -> Result<(&mut NativeModel, usize)> {
        let spec = self.registry.spec(name)?;
        let dim = spec.input_dim();
        if !self.models.contains_key(name) {
            let built = spec.build()?;
            self.models.insert(name.to_string(), built);
        }
        Ok((self.models.get_mut(name).expect("inserted above"), dim))
    }
}

impl BatchExecutor for NativeExecutor {
    fn execute(&mut self, model: &str, x: Vec<f32>, rows: usize) -> Result<(Vec<f32>, usize)> {
        let (m, dim) = self.model_for(model)?;
        if x.len() != rows * dim {
            return Err(Error::Coordinator(format!(
                "{model}: {} elems for {rows} rows of {dim}",
                x.len()
            )));
        }
        // the owned batch buffer becomes the input tensor as-is — the
        // only per-batch allocation on this path is the output
        let xt = Tensor::from_vec(&[rows, dim], x)?;
        let y = match m {
            NativeModel::Tt { tt, scratch } => tt.matvec_with(&xt, scratch)?,
            NativeModel::Dense { w } => matmul_bt(&xt, w)?,
            NativeModel::Net(net) => net.forward(&xt, false)?,
            NativeModel::Loaded(model) => model.forward(&xt, false)?,
        };
        let out_dim = y.shape()[1];
        Ok((y.into_vec(), out_dim))
    }

    fn input_dim(&self, model: &str) -> Result<usize> {
        self.registry.input_dim(model)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_registry() -> ModelRegistry {
        let mut r = ModelRegistry::new();
        r.register(
            "tt",
            ModelSpec::TtLayer { ms: vec![2, 3], ns: vec![3, 2], rank: 2, seed: 11 },
        );
        r.register("fc", ModelSpec::DenseLayer { n_out: 4, n_in: 6, seed: 12 });
        r
    }

    #[test]
    fn standard_registry_has_the_serving_lineup() {
        let r = ModelRegistry::standard();
        assert_eq!(
            r.names(),
            vec!["bt_layer", "conv_mnist", "fc_mnist", "mnist_net", "tt_layer"]
        );
        assert_eq!(r.input_dim("tt_layer").unwrap(), 1024);
        assert_eq!(r.input_dim("fc_mnist").unwrap(), 1024);
        assert_eq!(r.input_dim("mnist_net").unwrap(), 1024);
        assert_eq!(r.input_dim("conv_mnist").unwrap(), 1024);
        assert_eq!(r.input_dim("bt_layer").unwrap(), 1024);
        assert_eq!(r.spec("tt_layer").unwrap().output_dim(), 1024);
        assert_eq!(r.spec("mnist_net").unwrap().output_dim(), 10);
        assert_eq!(r.spec("conv_mnist").unwrap().output_dim(), 10);
        assert_eq!(r.spec("bt_layer").unwrap().output_dim(), 1024);
    }

    #[test]
    fn conv_and_bt_specs_execute_bitwise_vs_in_process_builds() {
        let mut exec = NativeExecutor::new(ModelRegistry::standard());
        let mut rng = Rng::new(77);
        let x: Vec<f32> = (0..2 * 1024).map(|_| rng.normal_f32(1.0)).collect();

        let (y, od) = exec.execute("conv_mnist", x.clone(), 2).unwrap();
        assert_eq!(od, 10);
        let mut net = crate::nn::mnist_tt_convnet(4, &mut Rng::new(0x7e50_0004)).unwrap();
        let want = net
            .forward(&Tensor::from_vec(&[2, 1024], x.clone()).unwrap(), false)
            .unwrap();
        assert_eq!(y, want.data());

        let (y, od) = exec.execute("bt_layer", x.clone(), 2).unwrap();
        assert_eq!(od, 1024);
        let mut bt =
            crate::nn::BtLinear::new(1024, 1024, 4, 8, &mut Rng::new(0x7e50_0005)).unwrap();
        let want = bt
            .forward(&Tensor::from_vec(&[2, 1024], x).unwrap(), false)
            .unwrap();
        assert_eq!(y, want.data());
    }

    #[test]
    fn unknown_model_lists_registered_names() {
        let e = ModelRegistry::standard().input_dim("nope").unwrap_err();
        let msg = format!("{e}");
        assert!(msg.contains("unknown model 'nope'"), "{msg}");
        assert!(msg.contains("tt_layer"), "{msg}");
    }

    #[test]
    fn tt_path_matches_direct_matvec_bitwise() {
        let mut exec = NativeExecutor::new(tiny_registry());
        let mut rng = Rng::new(3);
        let x: Vec<f32> = (0..3 * 6).map(|_| rng.normal_f32(1.0)).collect();
        let (y, od) = exec.execute("tt", x.clone(), 3).unwrap();
        assert_eq!(od, 6);

        let shape = TtShape::uniform(&[2, 3], &[3, 2], 2).unwrap();
        let tt = TtMatrix::random(&shape, &mut Rng::new(11)).unwrap();
        let want = tt.matvec(&Tensor::from_vec(&[3, 6], x).unwrap()).unwrap();
        assert_eq!(y, want.data());
    }

    #[test]
    fn dense_path_matches_matmul_bt() {
        let mut exec = NativeExecutor::new(tiny_registry());
        let mut rng = Rng::new(4);
        let x: Vec<f32> = (0..2 * 6).map(|_| rng.normal_f32(1.0)).collect();
        let (y, od) = exec.execute("fc", x.clone(), 2).unwrap();
        assert_eq!(od, 4);

        let w = Tensor::randn(&[4, 6], 0.02, &mut Rng::new(12));
        let want = matmul_bt(&Tensor::from_vec(&[2, 6], x).unwrap(), &w).unwrap();
        assert_eq!(y, want.data());
    }

    #[test]
    fn mnist_net_serves_ten_logits() {
        let mut exec = NativeExecutor::standard();
        let (y, od) = exec.execute("mnist_net", vec![0.1f32; 2 * 1024], 2).unwrap();
        assert_eq!(od, 10);
        assert_eq!(y.len(), 20);
        assert!(y.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn rejects_bad_row_count_and_unknown_model() {
        let mut exec = NativeExecutor::new(tiny_registry());
        assert!(exec.execute("tt", vec![0.0; 5], 1).is_err());
        assert!(exec.execute("ghost", vec![0.0; 6], 1).is_err());
        assert_eq!(exec.input_dim("tt").unwrap(), 6);
        assert!(exec.input_dim("ghost").is_err());
    }

    #[test]
    fn checkpoint_spec_serves_saved_model_bitwise() {
        use crate::nn::{Dense, Relu, Sequential};
        let dir = std::env::temp_dir()
            .join(format!("tensornet_native_ckpt_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut rng = Rng::new(21);
        let mut net = Sequential::new(vec![
            Box::new(Dense::new(6, 8, &mut rng)),
            Box::new(Relu::new()),
            Box::new(Dense::new(8, 3, &mut rng)),
        ]);
        Checkpoint::save(dir.join("toy"), &net).unwrap();

        let registry = ModelRegistry::from_dir(&dir).unwrap();
        assert_eq!(registry.names(), vec!["toy"]);
        assert_eq!(registry.input_dim("toy").unwrap(), 6);
        assert_eq!(registry.spec("toy").unwrap().output_dim(), 3);

        let mut exec = NativeExecutor::new(registry);
        let x: Vec<f32> = (0..2 * 6).map(|_| rng.normal_f32(1.0)).collect();
        let (y, od) = exec.execute("toy", x.clone(), 2).unwrap();
        assert_eq!(od, 3);
        let want = net
            .forward(&Tensor::from_vec(&[2, 6], x).unwrap(), false)
            .unwrap();
        assert_eq!(y, want.data(), "served output must match the trained model bitwise");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn from_dir_on_a_single_checkpoint_and_empty_dir() {
        use crate::nn::{Dense, Sequential};
        let dir = std::env::temp_dir()
            .join(format!("tensornet_native_single_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut rng = Rng::new(22);
        let net = Sequential::new(vec![Box::new(Dense::new(4, 2, &mut rng))]);
        Checkpoint::save(dir.join("solo"), &net).unwrap();
        // pointing at the checkpoint itself registers it under its dirname
        let r = ModelRegistry::from_dir(dir.join("solo")).unwrap();
        assert_eq!(r.names(), vec!["solo"]);
        // a directory with no checkpoints is an error, not an empty lineup
        let empty = dir.join("empty");
        std::fs::create_dir_all(&empty).unwrap();
        assert!(ModelRegistry::from_dir(&empty).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn build_failure_surfaces_and_executor_stays_usable() {
        let mut r = tiny_registry();
        // passes input_dim (= 4) but fails to build: ms/ns length mismatch
        r.register("broken", ModelSpec::TtLayer { ms: vec![2], ns: vec![2, 2], rank: 1, seed: 0 });
        let mut exec = NativeExecutor::new(r);
        assert!(exec.execute("broken", vec![0.0; 4], 1).is_err());
        // a failing model must not poison the worker for other models
        let (y, od) = exec.execute("tt", vec![0.0; 6], 1).unwrap();
        assert_eq!((y.len(), od), (6, 6));
    }
}
