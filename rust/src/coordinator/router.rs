//! Routing: map a logical model name + batch size to a concrete artifact
//! variant (the AOT pipeline emits fixed-batch executables, e.g. `b1` and
//! `b32`; the router picks the smallest variant that fits and the worker
//! pads the remainder).

use crate::error::{Error, Result};
use std::collections::BTreeMap;

/// Pick the smallest variant size `>= batch`, or the largest available if
/// none fits (the worker will then split the batch).
pub fn choose_variant(sizes: &[usize], batch: usize) -> Option<usize> {
    let mut best: Option<usize> = None;
    for &s in sizes {
        if s >= batch {
            best = Some(match best {
                Some(b) => b.min(s),
                None => s,
            });
        }
    }
    best.or_else(|| sizes.iter().copied().max())
}

/// Maps logical model names (`"tt"`, `"fc"`, ...) to their artifact
/// variants (`batch size -> artifact name`).
#[derive(Clone, Debug, Default)]
pub struct Router {
    models: BTreeMap<String, BTreeMap<usize, String>>,
}

impl Router {
    pub fn new() -> Self {
        Router::default()
    }

    /// Register an artifact as the `batch`-sized variant of `model`.
    pub fn register(&mut self, model: &str, batch: usize, artifact: &str) {
        self.models.entry(model.to_string()).or_default().insert(batch, artifact.to_string());
    }

    /// Auto-register from manifest naming convention `<model>_b<batch>`.
    pub fn register_convention(&mut self, artifact_names: &[String]) {
        for name in artifact_names {
            if let Some(pos) = name.rfind("_b") {
                if let Ok(batch) = name[pos + 2..].parse::<usize>() {
                    self.register(&name[..pos], batch, name);
                }
            }
        }
    }

    pub fn models(&self) -> Vec<&str> {
        self.models.keys().map(|s| s.as_str()).collect()
    }

    pub fn variants(&self, model: &str) -> Option<Vec<usize>> {
        self.models.get(model).map(|v| v.keys().copied().collect())
    }

    /// Resolve `(artifact_name, variant_batch)` for a request batch size.
    pub fn route(&self, model: &str, batch: usize) -> Result<(String, usize)> {
        let variants = self
            .models
            .get(model)
            .ok_or_else(|| Error::Coordinator(format!("unknown model '{model}'")))?;
        let sizes: Vec<usize> = variants.keys().copied().collect();
        let size = choose_variant(&sizes, batch)
            .ok_or_else(|| Error::Coordinator(format!("model '{model}' has no variants")))?;
        Ok((variants[&size].clone(), size))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn choose_smallest_fitting() {
        assert_eq!(choose_variant(&[1, 32, 100], 1), Some(1));
        assert_eq!(choose_variant(&[1, 32, 100], 2), Some(32));
        assert_eq!(choose_variant(&[1, 32, 100], 32), Some(32));
        assert_eq!(choose_variant(&[1, 32, 100], 99), Some(100));
        // nothing fits: take the largest (worker splits)
        assert_eq!(choose_variant(&[1, 32], 50), Some(32));
        assert_eq!(choose_variant(&[], 1), None);
    }

    #[test]
    fn convention_registration() {
        let mut r = Router::new();
        r.register_convention(&[
            "tt_layer_b1".into(),
            "tt_layer_b32".into(),
            "fc_mnist_b1".into(),
            "weird-name".into(),
        ]);
        assert_eq!(r.variants("tt_layer"), Some(vec![1, 32]));
        assert_eq!(r.variants("fc_mnist"), Some(vec![1]));
        assert!(r.variants("weird-name").is_none());
        let (art, size) = r.route("tt_layer", 7).unwrap();
        assert_eq!((art.as_str(), size), ("tt_layer_b32", 32));
    }

    #[test]
    fn unknown_model_errors() {
        let r = Router::new();
        assert!(r.route("nope", 1).is_err());
    }
}
