//! Router tier: sharded multi-process serving (DESIGN.md §13).
//!
//! One process, one registry is the single-box throughput ceiling the
//! ROADMAP names after PRs 6–7 — the batcher, executor pool and kernels
//! all live inside one address space, so aggregate rows/s tops out at
//! what one process can sustain.  [`ShardRouter`] lifts that ceiling by
//! scale-out: a front-end that speaks the *existing* wire protocol on
//! both sides, fronting N independent `tensornet serve --listen` shard
//! daemons and multiplying aggregate throughput near-linearly with the
//! shard count (`sharded_tt` in BENCH_coordinator.json).
//!
//! ```text
//! clients ──► tn-router-accept ──round-robin──► tn-router-io-{k}
//!                (listener)                        │  sweeps DownConn state machines
//!                                                  │  (FrameDecoder → dispatch → in-order outbound)
//!                                                  │        │ least-loaded pick over placement
//!                                                  │        ▼
//!                                                  └── ShardLink per shard (pipelined,
//!                                                      non-blocking, one per io thread)
//!                                                           │ Infer (rewritten id)  ▲ replies
//!                                                           ▼                       │ in order
//!                                                  shard 0 .. shard N-1  (`serve --listen`)
//! ```
//!
//! The router is the PR 6 reactor idiom applied twice: downstream
//! connections are swept exactly like `net.rs` conns (non-blocking
//! reads through [`wire::FrameDecoder`], an in-order outbound queue
//! where only the head settles, partial-write-aware flushing), and each
//! upstream shard link is the same shape in reverse — a pipelined
//! non-blocking connection whose in-flight queue settles strictly in
//! send order (the shard's reactor guarantees in-order replies per
//! connection).  Every I/O thread owns its own links to every shard, so
//! the router adds one hop, not one thread per connection, and no lock
//! sits on the forward path.
//!
//! **Placement** is discovered at startup: each shard is probed for its
//! advertised [`Frame::ModelList`] and the union becomes the router's
//! lineup.  A model served by several shards is *replicated* — capped
//! by `--replicas M` (rotated by model index so hot models don't all
//! pile on shard 0).  **Dispatch** is least-loaded: among a model's
//! placed shards with a live link, pick the one with the fewest
//! router-tracked in-flight requests (a shared atomic per shard, exact
//! and instantaneous); per-model `StatsReply` polling (~200ms, io
//! thread 0) refreshes each shard's batch counters for the merged
//! stats the router serves downstream.
//!
//! **Failure containment**: a dead shard link fails over — every
//! in-flight request on it is answered with a typed `Exec` error
//! (never a hang), the shard is marked unhealthy, survivors keep
//! serving, and the link redials every ~500ms (a bounded ~50ms
//! connect attempt; the one place this reactor may stall, chosen over
//! a dedicated dialer thread).  Requests are never silently re-sent:
//! an in-flight request on a dead shard may or may not have executed,
//! so re-dispatching it could double-apply — the client owns the
//! retry decision.

use crate::coordinator::client::{Client, RemoteStats};
use crate::coordinator::wire::{self, ErrCode, Frame, ModelInfo, ModelStatsEntry};
use crate::error::{Error, Result};
use crate::metrics::Counter;
use std::cell::RefCell;
use std::collections::{BTreeMap, VecDeque};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::rc::Rc;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

// The reactor tuning constants mirror net.rs — same readiness model,
// same tradeoffs (see the long comments there).
const POLL: Duration = Duration::from_millis(25);
const IDLE_TICK: Duration = Duration::from_micros(500);
const READ_CHUNK: usize = 64 * 1024;
const WBUF_SOFT_CAP: usize = 1 << 20;
const FIN_DRAIN: Duration = Duration::from_millis(200);
const STOP_DRAIN: Duration = Duration::from_secs(5);

/// Period of the per-shard `Stats` poll (io thread 0 only).
const STATS_POLL: Duration = Duration::from_millis(200);
/// How long a dead link waits before the next redial attempt.
const REDIAL: Duration = Duration::from_millis(500);
/// Bound on one blocking redial `connect` — the only place a router
/// I/O thread may stall; kept small so a down shard costs at most this
/// per [`REDIAL`] period.
const CONNECT_TIMEOUT: Duration = Duration::from_millis(50);

/// Router startup configuration (CLI: `tensornet router`).
#[derive(Clone, Debug)]
pub struct RouterConfig {
    /// Shard addresses (`host:port` of running `serve --listen` daemons).
    pub shards: Vec<String>,
    /// Cap on how many shards serve one model (`0` = every shard that
    /// advertises it).  Replica sets are rotated by model index so
    /// different models land on different shard subsets.
    pub replicas: usize,
    /// Reactor threads sweeping downstream connections; each owns its
    /// own pipelined link to every shard.
    pub io_threads: usize,
    /// Bound on the startup placement probe per shard (startup *fails*
    /// if any configured shard is unreachable — a fleet with a silently
    /// missing shard is a misconfiguration, not a degraded mode).
    pub connect_timeout: Duration,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            shards: Vec::new(),
            replicas: 0,
            io_threads: 1,
            connect_timeout: Duration::from_secs(5),
        }
    }
}

/// Shared per-shard state: identity, health, the least-loaded signal
/// and forwarding counters.  One per shard, shared by every io thread's
/// link to it (so least-loaded dispatch sees cross-thread load).
struct ShardInfo {
    addr: String,
    /// models the router PLACED here (the shard may advertise more)
    models: Vec<String>,
    healthy: AtomicBool,
    /// router-tracked outstanding requests — the least-loaded key
    in_flight: AtomicU64,
    forwarded: Counter,
    completed: Counter,
    errors: Counter,
    busy: Counter,
    /// link-death events (each fails over its in-flight requests)
    failovers: Counter,
    /// the shard's most recent `retry_after_ms` backoff hint (0 = none);
    /// set on every Busy/Quota reply, cleared when the shard completes a
    /// request again — forwarded sheds carry the max over a model's
    /// placed replicas so clients don't retry into a still-backed-up set
    retry_hint_ms: AtomicU32,
    /// latest polled `StatsReply`, for the merged downstream stats
    last_poll: Mutex<Option<RemoteStats>>,
}

/// Point-in-time copy of one shard's router-side state — the
/// provenance block benches and the CLI summary print.
#[derive(Clone, Debug)]
pub struct ShardSnapshot {
    pub addr: String,
    pub models: Vec<String>,
    pub healthy: bool,
    pub in_flight: u64,
    pub forwarded: u64,
    pub completed: u64,
    pub errors: u64,
    pub busy: u64,
    pub failovers: u64,
}

/// Per-model router counters (created lazily on first traffic, same
/// discipline as `ServerStats`: only placed model names ever get an
/// entry — the lineup check runs before attribution).
#[derive(Default)]
pub struct RouterModelStats {
    pub completed: Counter,
    pub errors: Counter,
    pub busy: Counter,
}

/// Aggregate router counters, shared across io threads.
#[derive(Default)]
pub struct RouterStats {
    pub completed: Counter,
    /// non-retryable failures: shard `Exec`/`BadRequest` replies,
    /// unknown models, failed-over in-flight requests
    pub errors: Counter,
    /// retryable shard `Busy` replies forwarded to clients
    pub busy: Counter,
    per_model: RwLock<BTreeMap<String, Arc<RouterModelStats>>>,
}

impl RouterStats {
    /// Get-or-create the per-model counters for `model` (read-lock fast
    /// path; the write lock is taken only on first-ever traffic).
    fn model(&self, model: &str) -> Arc<RouterModelStats> {
        if let Some(m) = self.per_model.read().unwrap().get(model) {
            return m.clone();
        }
        self.per_model.write().unwrap().entry(model.to_string()).or_default().clone()
    }

    fn per_model_snapshot(&self) -> Vec<(String, Arc<RouterModelStats>)> {
        self.per_model.read().unwrap().iter().map(|(k, v)| (k.clone(), v.clone())).collect()
    }
}

/// Model → placed shard indices.  Built once at startup; placement is
/// static (shards don't come and go, they only die and redial).
fn place(
    lineups: &[Vec<ModelInfo>],
    replicas: usize,
) -> Result<(Vec<ModelInfo>, BTreeMap<String, Vec<usize>>)> {
    let mut union: BTreeMap<String, ModelInfo> = BTreeMap::new();
    let mut serving: BTreeMap<String, Vec<usize>> = BTreeMap::new();
    for (si, lineup) in lineups.iter().enumerate() {
        for m in lineup {
            match union.get(&m.name) {
                None => {
                    union.insert(m.name.clone(), m.clone());
                }
                Some(seen) if seen.input_dim != m.input_dim || seen.output_dim != m.output_dim => {
                    // same name, different tensor shapes: routing a
                    // request to "whichever replica is idle" would give
                    // shape-dependent answers — refuse to start
                    return Err(Error::Coordinator(format!(
                        "model '{}' advertised with conflicting dims: {}x{} vs {}x{}",
                        m.name, seen.input_dim, seen.output_dim, m.input_dim, m.output_dim
                    )));
                }
                Some(_) => {}
            }
            serving.entry(m.name.clone()).or_default().push(si);
        }
    }
    let mut placement = BTreeMap::new();
    for (mi, (name, shards)) in serving.into_iter().enumerate() {
        let placed = if replicas == 0 || shards.len() <= replicas {
            shards
        } else {
            // rotate the replica window by model index so consecutive
            // models spread over different shard subsets
            let start = mi % shards.len();
            (0..replicas).map(|k| shards[(start + k) % shards.len()]).collect()
        };
        placement.insert(name, placed);
    }
    Ok((union.into_values().collect(), placement))
}

/// One inference awaiting its upstream reply: the shard link fills the
/// slot (down-side id already rewritten back in) and the downstream
/// connection's in-order promote drains it.  `Rc`: both ends live on
/// the same io thread — slots never cross threads.
type Slot = Rc<RefCell<Option<Frame>>>;

/// One queued downstream reply, in request order.
enum Outbound {
    Ready(Frame),
    /// forwarded upstream; settles when the link fills the slot
    Forwarded(Slot),
}

/// Downstream connection lifecycle — same machine as net.rs `Phase`.
enum Phase {
    Open,
    PeerClosed,
    Closing,
    Draining { since: Instant },
}

struct Sweep {
    progress: bool,
    keep: bool,
}

/// An in-flight entry on a shard link, settled strictly in send order.
enum UpEntry {
    Infer { up_id: u64, down_id: u64, model: String, slot: Slot },
    /// router-issued `Stats` poll (no downstream waiter)
    Poll,
}

/// Live socket state of one link; `None` in [`ShardLink`] = link down.
struct LinkIo {
    stream: TcpStream,
    decoder: wire::FrameDecoder,
    pending: VecDeque<UpEntry>,
    wbuf: Vec<u8>,
    wpos: usize,
}

/// One io thread's pipelined connection to one shard.
struct ShardLink {
    shard: Arc<ShardInfo>,
    io: Option<LinkIo>,
    next_redial: Instant,
    next_id: u64,
}

impl ShardLink {
    fn new(shard: Arc<ShardInfo>) -> ShardLink {
        // dial immediately on the first sweep
        ShardLink { shard, io: None, next_redial: Instant::now(), next_id: 1 }
    }

    fn alive(&self) -> bool {
        self.io.is_some()
    }

    /// Redial a down link, at most once per [`REDIAL`] period.  The
    /// bounded blocking connect is this reactor's one deliberate stall
    /// (see [`CONNECT_TIMEOUT`]).
    fn ensure_connected(&mut self) {
        if self.io.is_some() || Instant::now() < self.next_redial {
            return;
        }
        self.next_redial = Instant::now() + REDIAL;
        let addrs: Vec<SocketAddr> = match self.shard.addr.to_socket_addrs() {
            Ok(a) => a.collect(),
            Err(_) => return,
        };
        for sa in &addrs {
            let Ok(stream) = TcpStream::connect_timeout(sa, CONNECT_TIMEOUT) else { continue };
            if stream.set_nonblocking(true).is_err() {
                continue;
            }
            let _ = stream.set_nodelay(true);
            self.io = Some(LinkIo {
                stream,
                decoder: wire::FrameDecoder::new(),
                pending: VecDeque::new(),
                wbuf: Vec::new(),
                wpos: 0,
            });
            self.shard.healthy.store(true, Ordering::SeqCst);
            return;
        }
    }

    /// Forward one inference: encode with a rewritten (per-link) id
    /// straight onto the link's write buffer and queue the reply slot.
    /// Returns false when the link is down (caller re-picks or errors).
    fn send_infer(&mut self, down_id: u64, model: String, input: Vec<f32>, slot: Slot) -> bool {
        let Some(io) = self.io.as_mut() else { return false };
        let up_id = self.next_id;
        let frame = Frame::Infer { id: up_id, model: model.clone(), input };
        // can't exceed the payload cap: the downstream frame this came
        // from carried the same payload and decoded under it
        if frame.encode_into(&mut io.wbuf).is_err() {
            return false;
        }
        self.next_id += 1;
        io.pending.push_back(UpEntry::Infer { up_id, down_id, model, slot });
        self.shard.in_flight.fetch_add(1, Ordering::Relaxed);
        self.shard.forwarded.inc();
        true
    }

    /// Enqueue a `Stats` poll unless one is already outstanding.
    fn send_poll(&mut self) {
        let Some(io) = self.io.as_mut() else { return };
        if io.pending.iter().any(|e| matches!(e, UpEntry::Poll)) {
            return;
        }
        if Frame::Stats.encode_into(&mut io.wbuf).is_ok() {
            io.pending.push_back(UpEntry::Poll);
        }
    }

    /// Flush queued upstream bytes until the socket pushes back.
    fn pump_writes(&mut self, progress: &mut bool, stats: &RouterStats) {
        let mut failure: Option<String> = None;
        if let Some(io) = self.io.as_mut() {
            while io.wpos < io.wbuf.len() {
                match io.stream.write(&io.wbuf[io.wpos..]) {
                    Ok(0) => {
                        failure = Some("write: connection closed".into());
                        break;
                    }
                    Ok(n) => {
                        io.wpos += n;
                        *progress = true;
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                    Err(e) => {
                        failure = Some(format!("write: {e}"));
                        break;
                    }
                }
            }
            if failure.is_none() && io.wpos > 0 && io.wpos == io.wbuf.len() {
                io.wbuf.clear();
                io.wpos = 0;
            }
        }
        if let Some(why) = failure {
            self.fail(&why, stats);
        }
    }

    /// Pull one [`READ_CHUNK`] off the link and settle every reply it
    /// completes, strictly head-of-queue.  `shards`/`placement` feed the
    /// cross-replica retry-hint lookup on shed replies.
    fn pump_reads(
        &mut self,
        progress: &mut bool,
        stats: &RouterStats,
        shards: &[Arc<ShardInfo>],
        placement: &BTreeMap<String, Vec<usize>>,
    ) {
        let mut chunk = [0u8; READ_CHUNK];
        let read = match self.io.as_mut() {
            Some(io) => io.stream.read(&mut chunk),
            None => return,
        };
        let mut failure: Option<String> = None;
        match read {
            Ok(0) => failure = Some("shard closed the connection".into()),
            Ok(n) => {
                *progress = true;
                let io = self.io.as_mut().expect("checked above");
                io.decoder.feed(&chunk[..n]);
                loop {
                    match io.decoder.next_frame() {
                        Ok(Some(frame)) => {
                            if let Err(why) = settle(
                                &mut io.pending,
                                &self.shard,
                                frame,
                                stats,
                                shards,
                                placement,
                            ) {
                                failure = Some(why);
                                break;
                            }
                        }
                        Ok(None) => break,
                        Err(e) => {
                            failure = Some(format!("bad frame from shard: {e}"));
                            break;
                        }
                    }
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {}
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => failure = Some(format!("read: {e}")),
        }
        if let Some(why) = failure {
            self.fail(&why, stats);
        }
    }

    /// The link died: answer every in-flight request with a typed
    /// `Exec` error (never a hang, never a silent re-send — the shard
    /// may have executed it), mark the shard unhealthy and schedule a
    /// redial.  Survivor shards keep serving untouched.
    fn fail(&mut self, why: &str, stats: &RouterStats) {
        let Some(io) = self.io.take() else { return };
        self.shard.failovers.inc();
        let in_flight = io.pending.iter().filter(|e| matches!(e, UpEntry::Infer { .. })).count();
        eprintln!(
            "tn-router: shard {} failed: {why} ({in_flight} in-flight answered with errors)",
            self.shard.addr
        );
        for entry in io.pending {
            if let UpEntry::Infer { down_id, model, slot, .. } = entry {
                self.shard.in_flight.fetch_sub(1, Ordering::Relaxed);
                self.shard.errors.inc();
                stats.errors.inc();
                stats.model(&model).errors.inc();
                slot.borrow_mut().replace(Frame::InferErr {
                    id: down_id,
                    code: ErrCode::Exec,
                    message: format!("shard {} failed mid-request: {why}", self.shard.addr),
                    retry_after_ms: 0,
                });
            }
        }
        self.shard.healthy.store(false, Ordering::SeqCst);
        self.next_redial = Instant::now() + REDIAL;
    }

    /// Quiet teardown on reactor exit: release the in-flight gauge
    /// without counting errors (the waiting connections are being torn
    /// down too — there is no one left to answer).
    fn abandon(&mut self) {
        if let Some(io) = self.io.take() {
            for e in io.pending {
                if matches!(e, UpEntry::Infer { .. }) {
                    self.shard.in_flight.fetch_sub(1, Ordering::Relaxed);
                }
            }
        }
    }
}

/// Match one shard reply against the head of the link's in-flight
/// queue; returns the failure reason if the shard broke protocol.
/// `shards`/`placement` let a forwarded shed carry the max backoff hint
/// over the model's placed replicas.
fn settle(
    pending: &mut VecDeque<UpEntry>,
    shard: &ShardInfo,
    frame: Frame,
    stats: &RouterStats,
    shards: &[Arc<ShardInfo>],
    placement: &BTreeMap<String, Vec<usize>>,
) -> std::result::Result<(), String> {
    match pending.pop_front() {
        None => Err(format!("unsolicited {} with nothing in flight", frame.kind())),
        Some(UpEntry::Poll) => match frame {
            Frame::StatsReply {
                completed,
                rejected,
                errors,
                failed_workers,
                batches,
                batched_rows,
                quota_shed,
                per_model,
            } => {
                *shard.last_poll.lock().unwrap() = Some(RemoteStats {
                    completed,
                    rejected,
                    errors,
                    failed_workers,
                    batches,
                    batched_rows,
                    quota_shed,
                    per_model,
                });
                Ok(())
            }
            other => {
                pending.push_front(UpEntry::Poll);
                Err(format!("expected StatsReply to a poll, shard sent {}", other.kind()))
            }
        },
        Some(UpEntry::Infer { up_id, down_id, model, slot }) => {
            let reorder = |pending: &mut VecDeque<UpEntry>, got: &Frame, up_id, down_id, model, slot| {
                let kind = got.kind();
                pending.push_front(UpEntry::Infer { up_id, down_id, model, slot });
                format!("out-of-order reply from shard: {kind} did not match head id {up_id}")
            };
            match frame {
                Frame::InferOk { id, queue_us, exec_us, batch_size, output } => {
                    if id != up_id {
                        let f = Frame::InferOk { id, queue_us, exec_us, batch_size, output };
                        return Err(reorder(pending, &f, up_id, down_id, model, slot));
                    }
                    shard.in_flight.fetch_sub(1, Ordering::Relaxed);
                    shard.completed.inc();
                    // the shard is admitting work again — a stale backoff
                    // hint must not keep inflating forwarded sheds
                    shard.retry_hint_ms.store(0, Ordering::Relaxed);
                    stats.completed.inc();
                    stats.model(&model).completed.inc();
                    slot.borrow_mut().replace(Frame::InferOk {
                        id: down_id,
                        queue_us,
                        exec_us,
                        batch_size,
                        output,
                    });
                    Ok(())
                }
                Frame::InferErr { id, code, message, retry_after_ms } => {
                    // id 0 = the shard couldn't attribute the error
                    if id != 0 && id != up_id {
                        let f = Frame::InferErr { id, code, message, retry_after_ms };
                        return Err(reorder(pending, &f, up_id, down_id, model, slot));
                    }
                    shard.in_flight.fetch_sub(1, Ordering::Relaxed);
                    let retry_after_ms = match code {
                        // both shed kinds are retryable backpressure, not
                        // failures.  The forwarded hint is the max over
                        // the model's placed replicas' latest hints, not
                        // just this shard's: the client's retry will be
                        // dispatched least-loaded over the SAME candidate
                        // set, so backing off less than the slowest-
                        // recovering replica advertises just bounces the
                        // retry off another saturated candidate
                        ErrCode::Busy | ErrCode::Quota => {
                            shard.busy.inc();
                            stats.busy.inc();
                            stats.model(&model).busy.inc();
                            shard.retry_hint_ms.store(retry_after_ms, Ordering::Relaxed);
                            placement
                                .get(&model)
                                .and_then(|placed| {
                                    placed
                                        .iter()
                                        .filter_map(|&i| shards.get(i))
                                        .map(|s| s.retry_hint_ms.load(Ordering::Relaxed))
                                        .max()
                                })
                                .unwrap_or(retry_after_ms)
                                .max(retry_after_ms)
                        }
                        _ => {
                            shard.errors.inc();
                            stats.errors.inc();
                            stats.model(&model).errors.inc();
                            retry_after_ms
                        }
                    };
                    slot.borrow_mut().replace(Frame::InferErr {
                        id: down_id,
                        code,
                        message,
                        retry_after_ms,
                    });
                    Ok(())
                }
                other => Err(reorder(pending, &other, up_id, down_id, model, slot)),
            }
        }
    }
}

/// Everything a downstream sweep needs to dispatch: the io thread's own
/// links plus the shared routing tables.  Rebuilt per loop iteration —
/// it's all borrows.
struct Ctx<'a> {
    links: &'a mut [ShardLink],
    shards: &'a [Arc<ShardInfo>],
    placement: &'a BTreeMap<String, Vec<usize>>,
    lineup: &'a [ModelInfo],
    stats: &'a RouterStats,
    shutdown_requested: &'a AtomicBool,
}

/// Least-loaded pick: among `model`'s placed shards with a live link on
/// THIS thread, the one with the fewest router-wide in-flight requests.
fn pick_shard(ctx: &Ctx, model: &str) -> Option<usize> {
    ctx.placement
        .get(model)?
        .iter()
        .copied()
        .filter(|&i| ctx.links[i].alive())
        .min_by_key(|&i| ctx.shards[i].in_flight.load(Ordering::Relaxed))
}

/// Handle one decoded downstream frame; false = close the connection.
fn dispatch(frame: Frame, outbound: &mut VecDeque<Outbound>, ctx: &mut Ctx) -> bool {
    match frame {
        Frame::Infer { id, model, input } => {
            // same pre-attribution lineup check as net.rs: unknown names
            // are client-controlled bytes and must not plant stats
            // entries or reach a shard
            if !ctx.lineup.iter().any(|m| m.name == model) {
                ctx.stats.errors.inc();
                let served: Vec<&str> = ctx.lineup.iter().map(|m| m.name.as_str()).collect();
                outbound.push_back(Outbound::Ready(Frame::InferErr {
                    id,
                    code: ErrCode::Exec,
                    message: format!("unknown model '{model}' (served: {})", served.join(", ")),
                    retry_after_ms: 0,
                }));
                return true;
            }
            let Some(si) = pick_shard(ctx, &model) else {
                // placed shards all dead right now: typed error, the
                // redial loop may revive them for the client's retry
                ctx.stats.errors.inc();
                ctx.stats.model(&model).errors.inc();
                outbound.push_back(Outbound::Ready(Frame::InferErr {
                    id,
                    code: ErrCode::Exec,
                    message: format!("no live shard serves '{model}'"),
                    retry_after_ms: 0,
                }));
                return true;
            };
            let slot: Slot = Rc::new(RefCell::new(None));
            if ctx.links[si].send_infer(id, model, input, slot.clone()) {
                outbound.push_back(Outbound::Forwarded(slot));
            } else {
                outbound.push_back(Outbound::Ready(Frame::InferErr {
                    id,
                    code: ErrCode::Exec,
                    message: format!("forward to shard {} failed", ctx.shards[si].addr),
                    retry_after_ms: 0,
                }));
            }
            true
        }
        Frame::Stats => {
            let s = stats_snapshot(ctx.stats, ctx.shards);
            outbound.push_back(Outbound::Ready(Frame::StatsReply {
                completed: s.completed,
                rejected: s.rejected,
                errors: s.errors,
                failed_workers: s.failed_workers,
                batches: s.batches,
                batched_rows: s.batched_rows,
                quota_shed: s.quota_shed,
                per_model: s.per_model,
            }));
            true
        }
        Frame::ListModels => {
            outbound.push_back(Outbound::Ready(Frame::ModelList { models: ctx.lineup.to_vec() }));
            true
        }
        Frame::Shutdown => {
            // acknowledge, then stop the ROUTER only — the fleet
            // launcher owns shard lifecycle
            outbound.push_back(Outbound::Ready(Frame::ShutdownOk));
            ctx.shutdown_requested.store(true, Ordering::SeqCst);
            false
        }
        other @ (Frame::InferOk { .. }
        | Frame::InferErr { .. }
        | Frame::StatsReply { .. }
        | Frame::ModelList { .. }
        | Frame::ShutdownOk) => {
            outbound.push_back(Outbound::Ready(Frame::InferErr {
                id: 0,
                code: ErrCode::BadRequest,
                message: format!("unexpected reply-type frame {} sent to router", other.kind()),
                retry_after_ms: 0,
            }));
            false
        }
    }
}

/// The merged stats picture the router serves downstream: router-side
/// counters for request outcomes, shard-poll sums for batching depth
/// and admission sheds.  `rejected` is the router's own observation
/// (Busy/Quota replies it forwarded); `quota_shed` and per-model `shed`
/// come from the shard polls only — the shards' admission controllers
/// are the source of truth for *why* a request was shed, and a shard
/// may also shed traffic that arrived around the router.
fn stats_snapshot(stats: &RouterStats, shards: &[Arc<ShardInfo>]) -> RemoteStats {
    let mut per: BTreeMap<String, ModelStatsEntry> = BTreeMap::new();
    for (name, m) in stats.per_model_snapshot() {
        per.insert(
            name.clone(),
            ModelStatsEntry {
                name,
                completed: m.completed.get(),
                errors: m.errors.get(),
                batches: 0,
                batched_rows: 0,
                shed: 0,
            },
        );
    }
    let mut batches = 0u64;
    let mut batched_rows = 0u64;
    let mut quota_shed = 0u64;
    let mut failed_workers = 0u64;
    for sh in shards {
        if !sh.healthy.load(Ordering::SeqCst) {
            // surfaced in the same StatsReply field a degraded
            // executor pool uses: "how many of my workers are gone"
            failed_workers += 1;
        }
        if let Some(poll) = sh.last_poll.lock().unwrap().as_ref() {
            batches += poll.batches;
            batched_rows += poll.batched_rows;
            quota_shed += poll.quota_shed;
            for pm in &poll.per_model {
                let e = per.entry(pm.name.clone()).or_insert_with(|| ModelStatsEntry {
                    name: pm.name.clone(),
                    ..Default::default()
                });
                e.batches += pm.batches;
                e.batched_rows += pm.batched_rows;
                e.shed += pm.shed;
            }
        }
    }
    RemoteStats {
        completed: stats.completed.get(),
        rejected: stats.busy.get(),
        errors: stats.errors.get(),
        failed_workers,
        batches,
        batched_rows,
        quota_shed,
        per_model: per.into_values().collect(),
    }
}

/// Downstream connection state machine — net.rs `Conn` with forwarding
/// instead of local admission.
struct DownConn {
    stream: TcpStream,
    peer: SocketAddr,
    decoder: wire::FrameDecoder,
    outbound: VecDeque<Outbound>,
    wbuf: Vec<u8>,
    wpos: usize,
    phase: Phase,
}

impl DownConn {
    fn new(stream: TcpStream, peer: SocketAddr) -> Option<DownConn> {
        if stream.set_nonblocking(true).is_err() {
            return None;
        }
        let _ = stream.set_nodelay(true);
        Some(DownConn {
            stream,
            peer,
            decoder: wire::FrameDecoder::new(),
            outbound: VecDeque::new(),
            wbuf: Vec::new(),
            wpos: 0,
            phase: Phase::Open,
        })
    }

    fn begin_close(&mut self) {
        if matches!(self.phase, Phase::Open | Phase::PeerClosed) {
            self.phase = Phase::Closing;
        }
    }

    fn sweep(&mut self, ctx: &mut Ctx) -> Sweep {
        let mut progress = false;
        if matches!(self.phase, Phase::Open) && !self.read_ready(&mut progress, ctx) {
            return Sweep { progress: true, keep: false };
        }
        if !self.promote(&mut progress) {
            return Sweep { progress: true, keep: false };
        }
        if !self.write_ready(&mut progress) {
            return Sweep { progress: true, keep: false };
        }
        let flushed = self.outbound.is_empty() && self.wpos == self.wbuf.len();
        match self.phase {
            Phase::Open => {}
            Phase::PeerClosed => {
                if flushed {
                    return Sweep { progress: true, keep: false };
                }
            }
            Phase::Closing => {
                if flushed {
                    let _ = self.stream.shutdown(std::net::Shutdown::Write);
                    self.phase = Phase::Draining { since: Instant::now() };
                    progress = true;
                }
            }
            Phase::Draining { since } => {
                if !self.drain_reads(&mut progress) || since.elapsed() >= FIN_DRAIN {
                    return Sweep { progress: true, keep: false };
                }
            }
        }
        Sweep { progress, keep: true }
    }

    fn read_ready(&mut self, progress: &mut bool, ctx: &mut Ctx) -> bool {
        let mut chunk = [0u8; READ_CHUNK];
        match self.stream.read(&mut chunk) {
            Ok(0) => {
                *progress = true;
                if self.decoder.pending() > 0 {
                    self.outbound.push_back(Outbound::Ready(Frame::InferErr {
                        id: 0,
                        code: ErrCode::BadRequest,
                        message: format!(
                            "connection closed mid-frame with {} bytes buffered",
                            self.decoder.pending()
                        ),
                        retry_after_ms: 0,
                    }));
                    self.phase = Phase::Closing;
                } else {
                    self.phase = Phase::PeerClosed;
                }
                true
            }
            Ok(n) => {
                *progress = true;
                self.decoder.feed(&chunk[..n]);
                loop {
                    match self.decoder.next_frame() {
                        Ok(Some(frame)) => {
                            if !dispatch(frame, &mut self.outbound, ctx) {
                                self.phase = Phase::Closing;
                                break;
                            }
                        }
                        Ok(None) => break,
                        Err(e) => {
                            self.outbound.push_back(Outbound::Ready(Frame::InferErr {
                                id: 0,
                                code: ErrCode::BadRequest,
                                message: format!("{e}"),
                                retry_after_ms: 0,
                            }));
                            self.phase = Phase::Closing;
                            break;
                        }
                    }
                }
                true
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => true,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => true,
            Err(e) => {
                eprintln!("tn-router-io {}: read: {e}", self.peer);
                false
            }
        }
    }

    /// Settle the head of the in-order outbound queue.  Only the head:
    /// replies must leave in request order even when they came back
    /// from different shards at different speeds.
    fn promote(&mut self, progress: &mut bool) -> bool {
        loop {
            if self.wbuf.len() - self.wpos >= WBUF_SOFT_CAP {
                return true;
            }
            // take the forwarded reply (if any) in its own statement so
            // the slot borrow of the front entry ends before the pop
            let taken: Option<Frame> = match self.outbound.front() {
                None => return true,
                Some(Outbound::Forwarded(slot)) => {
                    let got = slot.borrow_mut().take();
                    match got {
                        None => return true, // shard still working on it
                        some => some,
                    }
                }
                Some(Outbound::Ready(_)) => None,
            };
            let frame = match taken {
                Some(f) => {
                    self.outbound.pop_front();
                    f
                }
                None => match self.outbound.pop_front() {
                    Some(Outbound::Ready(f)) => f,
                    _ => unreachable!("front() said Ready"),
                },
            };
            // zero-allocation reply path, same as net.rs promote
            match frame.encode_into(&mut self.wbuf) {
                Ok(()) => *progress = true,
                Err(e) => {
                    eprintln!("tn-router-io {}: encode reply: {e}", self.peer);
                    return false;
                }
            }
        }
    }

    fn write_ready(&mut self, progress: &mut bool) -> bool {
        while self.wpos < self.wbuf.len() {
            match self.stream.write(&self.wbuf[self.wpos..]) {
                Ok(0) => return false,
                Ok(n) => {
                    self.wpos += n;
                    *progress = true;
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => {
                    eprintln!("tn-router-io {}: write: {e}", self.peer);
                    return false;
                }
            }
        }
        if self.wpos > 0 && self.wpos == self.wbuf.len() {
            self.wbuf.clear();
            self.wpos = 0;
        }
        true
    }

    fn drain_reads(&mut self, progress: &mut bool) -> bool {
        let mut chunk = [0u8; 4096];
        for _ in 0..8 {
            match self.stream.read(&mut chunk) {
                Ok(0) => return false,
                Ok(_) => *progress = true,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return true,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(_) => return false,
            }
        }
        true
    }
}

/// A running router: listener + reactor threads fronting the shard
/// fleet.  Dropping (or [`ShardRouter::shutdown`]) stops accepting,
/// drains downstream connections (bounded by [`STOP_DRAIN`]) and joins
/// all threads; the shard daemons are untouched.
pub struct ShardRouter {
    local_addr: SocketAddr,
    stop: Arc<AtomicBool>,
    shutdown_requested: Arc<AtomicBool>,
    threads: Vec<std::thread::JoinHandle<()>>,
    io_threads: usize,
    shards: Vec<Arc<ShardInfo>>,
    stats: Arc<RouterStats>,
    lineup: Arc<Vec<ModelInfo>>,
}

impl ShardRouter {
    /// Probe every configured shard, build the placement, bind `addr`
    /// and start routing.  Fails if any shard is unreachable or the
    /// advertised lineups conflict.
    pub fn start(cfg: RouterConfig, addr: &str) -> Result<ShardRouter> {
        if cfg.shards.is_empty() {
            return Err(Error::Net("router needs at least one shard address".into()));
        }
        // startup placement probe over the blocking client
        let mut lineups = Vec::with_capacity(cfg.shards.len());
        for shard_addr in &cfg.shards {
            let mut probe =
                Client::connect_timeout(shard_addr, cfg.connect_timeout).map_err(|e| {
                    Error::Net(format!("shard {shard_addr} unreachable at startup: {e}"))
                })?;
            lineups.push(probe.list_models().map_err(|e| {
                Error::Net(format!("shard {shard_addr}: ListModels failed: {e}"))
            })?);
        }
        let (lineup, placement) = place(&lineups, cfg.replicas)?;
        if lineup.is_empty() {
            return Err(Error::Coordinator("shards advertise no models".into()));
        }
        let shards: Vec<Arc<ShardInfo>> = cfg
            .shards
            .iter()
            .enumerate()
            .map(|(si, a)| {
                let models = placement
                    .iter()
                    .filter(|(_, placed)| placed.contains(&si))
                    .map(|(name, _)| name.clone())
                    .collect();
                Arc::new(ShardInfo {
                    addr: a.clone(),
                    models,
                    healthy: AtomicBool::new(true),
                    in_flight: AtomicU64::new(0),
                    forwarded: Counter::new(),
                    completed: Counter::new(),
                    errors: Counter::new(),
                    busy: Counter::new(),
                    failovers: Counter::new(),
                    retry_hint_ms: AtomicU32::new(0),
                    last_poll: Mutex::new(None),
                })
            })
            .collect();

        let io_threads = cfg.io_threads.max(1);
        let listener =
            TcpListener::bind(addr).map_err(|e| Error::Net(format!("bind {addr}: {e}")))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| Error::Net(format!("set_nonblocking: {e}")))?;
        let local_addr =
            listener.local_addr().map_err(|e| Error::Net(format!("local_addr: {e}")))?;
        let stop = Arc::new(AtomicBool::new(false));
        let shutdown_requested = Arc::new(AtomicBool::new(false));
        let stats = Arc::new(RouterStats::default());
        let lineup = Arc::new(lineup);
        let placement = Arc::new(placement);

        let mut threads = Vec::with_capacity(io_threads + 1);
        let mut txs: Vec<Sender<(TcpStream, SocketAddr)>> = Vec::with_capacity(io_threads);
        for k in 0..io_threads {
            let (tx, rx) = channel();
            let handle = {
                let shards = shards.clone();
                let placement = placement.clone();
                let lineup = lineup.clone();
                let stats = stats.clone();
                let stop = stop.clone();
                let shutdown_requested = shutdown_requested.clone();
                std::thread::Builder::new().name(format!("tn-router-io-{k}")).spawn(move || {
                    io_loop(
                        rx,
                        shards,
                        placement,
                        lineup,
                        stats,
                        stop,
                        shutdown_requested,
                        k == 0, // only one thread polls shard stats
                    )
                })
            };
            match handle {
                Ok(h) => {
                    threads.push(h);
                    txs.push(tx);
                }
                Err(e) => {
                    stop.store(true, Ordering::SeqCst);
                    drop(txs);
                    for h in threads {
                        let _ = h.join();
                    }
                    return Err(Error::Net(format!("spawn router io thread {k}: {e}")));
                }
            }
        }
        let accept = {
            let stop = stop.clone();
            std::thread::Builder::new()
                .name("tn-router-accept".into())
                .spawn(move || accept_loop(listener, stop, txs))
        };
        match accept {
            Ok(h) => threads.push(h),
            Err(e) => {
                stop.store(true, Ordering::SeqCst);
                for h in threads {
                    let _ = h.join();
                }
                return Err(Error::Net(format!("spawn router accept loop: {e}")));
            }
        }

        Ok(ShardRouter {
            local_addr,
            stop,
            shutdown_requested,
            threads,
            io_threads,
            shards,
            stats,
            lineup,
        })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    pub fn io_threads(&self) -> usize {
        self.io_threads
    }

    /// Reactor threads + the accept thread — constant in both the
    /// connection count and the shard count.
    pub fn transport_threads(&self) -> usize {
        self.threads.len()
    }

    /// The union lineup the router advertises.
    pub fn lineup(&self) -> &[ModelInfo] {
        &self.lineup
    }

    /// The merged router-side stats (same shape a `Client::stats` call
    /// against the router returns).
    pub fn remote_stats(&self) -> RemoteStats {
        stats_snapshot(&self.stats, &self.shards)
    }

    /// Per-shard provenance: who served what, how much, and how it went.
    pub fn shard_snapshots(&self) -> Vec<ShardSnapshot> {
        self.shards
            .iter()
            .map(|s| ShardSnapshot {
                addr: s.addr.clone(),
                models: s.models.clone(),
                healthy: s.healthy.load(Ordering::SeqCst),
                in_flight: s.in_flight.load(Ordering::Relaxed),
                forwarded: s.forwarded.get(),
                completed: s.completed.get(),
                errors: s.errors.get(),
                busy: s.busy.get(),
                failovers: s.failovers.get(),
            })
            .collect()
    }

    /// True once a client's `Shutdown` frame has been acknowledged.
    pub fn shutdown_requested(&self) -> bool {
        self.shutdown_requested.load(Ordering::SeqCst)
    }

    /// Block until a wire `Shutdown` arrives (daemon mode of
    /// `tensornet router`).
    pub fn wait_for_shutdown(&self) {
        while !self.shutdown_requested() {
            std::thread::sleep(POLL);
        }
    }

    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        for h in self.threads.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for ShardRouter {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

fn accept_loop(
    listener: TcpListener,
    stop: Arc<AtomicBool>,
    txs: Vec<Sender<(TcpStream, SocketAddr)>>,
) {
    let mut next = 0usize;
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, peer)) => {
                if txs[next % txs.len()].send((stream, peer)).is_err() {
                    return;
                }
                next = next.wrapping_add(1);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(POLL);
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => {
                eprintln!("tn-router-accept: {e}");
                std::thread::sleep(POLL);
            }
        }
    }
}

/// One router reactor thread: sweep the shard links (redial, poll,
/// settle replies), then every downstream connection (which dispatches
/// onto the links), then flush upstream writes — never blocking on any
/// single socket.
#[allow(clippy::too_many_arguments)]
fn io_loop(
    rx_new: Receiver<(TcpStream, SocketAddr)>,
    shards: Vec<Arc<ShardInfo>>,
    placement: Arc<BTreeMap<String, Vec<usize>>>,
    lineup: Arc<Vec<ModelInfo>>,
    stats: Arc<RouterStats>,
    stop: Arc<AtomicBool>,
    shutdown_requested: Arc<AtomicBool>,
    poll_stats: bool,
) {
    let mut links: Vec<ShardLink> = shards.iter().map(|s| ShardLink::new(s.clone())).collect();
    let mut conns: Vec<DownConn> = Vec::new();
    let mut stop_deadline: Option<Instant> = None;
    let mut next_poll = Instant::now();
    'reactor: loop {
        let stopping = stop.load(Ordering::SeqCst);
        if stopping && stop_deadline.is_none() {
            stop_deadline = Some(Instant::now() + STOP_DRAIN);
            for c in conns.iter_mut() {
                c.begin_close();
            }
        }

        // intake: when there are no connections the park on the channel
        // doubles as the link-maintenance tick (25ms redial/poll cadence
        // is plenty)
        if conns.is_empty() && !stopping {
            match rx_new.recv_timeout(POLL) {
                Ok((s, peer)) => {
                    if let Some(c) = DownConn::new(s, peer) {
                        conns.push(c);
                    }
                }
                Err(RecvTimeoutError::Timeout) => {} // fall through: links still tick
                Err(RecvTimeoutError::Disconnected) => break 'reactor,
            }
        }
        while let Ok((s, peer)) = rx_new.try_recv() {
            if stopping {
                continue;
            }
            if let Some(c) = DownConn::new(s, peer) {
                conns.push(c);
            }
        }
        if stopping {
            if conns.is_empty() {
                break 'reactor;
            }
            if stop_deadline.is_some_and(|d| Instant::now() >= d) {
                break 'reactor;
            }
        }

        let mut progress = false;

        // upstream first: redial dead links, issue the periodic stats
        // poll, flush pending writes, settle arrived replies into slots
        let now = Instant::now();
        let do_poll = poll_stats && !stopping && now >= next_poll;
        if do_poll {
            next_poll = now + STATS_POLL;
        }
        for link in links.iter_mut() {
            if !stopping {
                link.ensure_connected();
            }
            if do_poll {
                link.send_poll();
            }
            link.pump_writes(&mut progress, &stats);
            link.pump_reads(&mut progress, &stats, &shards, &placement);
        }

        // downstream: read + dispatch (fills link wbufs), settle slots
        // in order, write
        let mut ctx = Ctx {
            links: &mut links,
            shards: &shards,
            placement: &placement,
            lineup: &lineup,
            stats: &stats,
            shutdown_requested: &shutdown_requested,
        };
        let mut i = 0;
        while i < conns.len() {
            let s = conns[i].sweep(&mut ctx);
            progress |= s.progress;
            if s.keep {
                i += 1;
            } else {
                conns.swap_remove(i);
            }
        }

        // push what dispatch just encoded so forwarded requests leave
        // this sweep, not the next
        for link in links.iter_mut() {
            link.pump_writes(&mut progress, &stats);
        }

        if !progress && !conns.is_empty() {
            std::thread::sleep(IDLE_TICK);
        }
    }
    for link in links.iter_mut() {
        link.abandon();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mi(name: &str, din: u32, dout: u32) -> ModelInfo {
        ModelInfo { name: name.into(), input_dim: din, output_dim: dout }
    }

    #[test]
    fn placement_unions_and_replicates() {
        let lineups = vec![
            vec![mi("a", 4, 4), mi("b", 8, 2)],
            vec![mi("a", 4, 4)],
            vec![mi("b", 8, 2), mi("c", 2, 2)],
        ];
        let (lineup, placement) = place(&lineups, 0).unwrap();
        let names: Vec<&str> = lineup.iter().map(|m| m.name.as_str()).collect();
        assert_eq!(names, vec!["a", "b", "c"]);
        assert_eq!(placement["a"], vec![0, 1]);
        assert_eq!(placement["b"], vec![0, 2]);
        assert_eq!(placement["c"], vec![2]);
    }

    #[test]
    fn placement_caps_replicas_with_rotation() {
        let everywhere = vec![mi("a", 4, 4), mi("b", 4, 4), mi("c", 4, 4)];
        let lineups = vec![everywhere.clone(), everywhere.clone(), everywhere];
        let (_, placement) = place(&lineups, 1).unwrap();
        // model index rotates the single replica across shards
        assert_eq!(placement["a"], vec![0]);
        assert_eq!(placement["b"], vec![1]);
        assert_eq!(placement["c"], vec![2]);
        for placed in placement.values() {
            assert_eq!(placed.len(), 1);
        }
    }

    #[test]
    fn placement_rejects_conflicting_dims() {
        let lineups = vec![vec![mi("a", 4, 4)], vec![mi("a", 4, 8)]];
        let err = place(&lineups, 0).unwrap_err();
        assert!(format!("{err}").contains("conflicting dims"), "{err}");
    }

    #[test]
    fn stats_snapshot_merges_router_counters_and_shard_polls() {
        let stats = RouterStats::default();
        stats.completed.add(10);
        stats.busy.add(2);
        stats.errors.add(1);
        stats.model("a").completed.add(7);
        stats.model("a").errors.add(1);
        stats.model("b").completed.add(3);
        let shard = Arc::new(ShardInfo {
            addr: "x:1".into(),
            models: vec!["a".into()],
            healthy: AtomicBool::new(false),
            in_flight: AtomicU64::new(0),
            forwarded: Counter::new(),
            completed: Counter::new(),
            errors: Counter::new(),
            busy: Counter::new(),
            failovers: Counter::new(),
            retry_hint_ms: AtomicU32::new(0),
            last_poll: Mutex::new(Some(RemoteStats {
                completed: 9,
                rejected: 0,
                errors: 0,
                failed_workers: 0,
                batches: 4,
                batched_rows: 9,
                quota_shed: 3,
                per_model: vec![ModelStatsEntry {
                    name: "a".into(),
                    completed: 9,
                    errors: 0,
                    batches: 4,
                    batched_rows: 9,
                    shed: 5,
                }],
            })),
        });
        let s = stats_snapshot(&stats, &[shard]);
        assert_eq!(s.completed, 10, "request outcomes come from ROUTER counters");
        assert_eq!(s.rejected, 2, "upstream Busy maps to rejected");
        assert_eq!(s.errors, 1);
        assert_eq!(s.failed_workers, 1, "one unhealthy shard");
        assert_eq!(s.batches, 4, "batch depth comes from shard polls");
        assert_eq!(s.batched_rows, 9);
        assert_eq!(s.quota_shed, 3, "quota sheds come from shard polls");
        let a = s.per_model.iter().find(|m| m.name == "a").unwrap();
        assert_eq!((a.completed, a.errors, a.batches, a.batched_rows), (7, 1, 4, 9));
        assert_eq!(a.shed, 5, "per-model sheds come from shard polls");
        let b = s.per_model.iter().find(|m| m.name == "b").unwrap();
        assert_eq!((b.completed, b.batches, b.shed), (3, 0, 0));
    }

    /// A test shard with all-zero counters.
    fn test_shard(addr: &str, models: &[&str], in_flight: u64) -> Arc<ShardInfo> {
        Arc::new(ShardInfo {
            addr: addr.into(),
            models: models.iter().map(|m| m.to_string()).collect(),
            healthy: AtomicBool::new(true),
            in_flight: AtomicU64::new(in_flight),
            forwarded: Counter::new(),
            completed: Counter::new(),
            errors: Counter::new(),
            busy: Counter::new(),
            failovers: Counter::new(),
            retry_hint_ms: AtomicU32::new(0),
            last_poll: Mutex::new(None),
        })
    }

    #[test]
    fn settle_fills_slots_in_order_and_rewrites_ids() {
        let stats = RouterStats::default();
        let shard = test_shard("x:1", &["m"], 2);
        let placement: BTreeMap<String, Vec<usize>> = [("m".to_string(), vec![0])].into();
        let shards = [shard.clone()];
        let s1: Slot = Rc::new(RefCell::new(None));
        let s2: Slot = Rc::new(RefCell::new(None));
        let mut pending = VecDeque::new();
        pending.push_back(UpEntry::Infer {
            up_id: 1,
            down_id: 41,
            model: "m".into(),
            slot: s1.clone(),
        });
        pending.push_back(UpEntry::Infer {
            up_id: 2,
            down_id: 99,
            model: "m".into(),
            slot: s2.clone(),
        });
        settle(
            &mut pending,
            &shard,
            Frame::InferOk { id: 1, queue_us: 5, exec_us: 6, batch_size: 1, output: vec![1.0] },
            &stats,
            &shards,
            &placement,
        )
        .unwrap();
        match s1.borrow().as_ref() {
            Some(Frame::InferOk { id, output, .. }) => {
                assert_eq!(*id, 41, "reply id rewritten to the downstream id");
                assert_eq!(output, &vec![1.0]);
            }
            other => panic!("slot 1: {other:?}"),
        }
        settle(
            &mut pending,
            &shard,
            Frame::InferErr { id: 2, code: ErrCode::Busy, message: "full".into(), retry_after_ms: 9 },
            &stats,
            &shards,
            &placement,
        )
        .unwrap();
        match s2.borrow().as_ref() {
            Some(Frame::InferErr { id, code, retry_after_ms, .. }) => {
                assert_eq!(*id, 99);
                assert_eq!(*code, ErrCode::Busy);
                assert_eq!(*retry_after_ms, 9, "the shard's retry hint passes through");
            }
            other => panic!("slot 2: {other:?}"),
        }
        assert_eq!(shard.in_flight.load(Ordering::Relaxed), 0);
        assert_eq!(stats.completed.get(), 1);
        assert_eq!(stats.busy.get(), 1);
        assert_eq!(shard.completed.get(), 1);
        assert_eq!(shard.busy.get(), 1);
    }

    #[test]
    fn settle_rejects_out_of_order_ids_without_losing_the_entry() {
        let stats = RouterStats::default();
        let shard = test_shard("x:1", &[], 1);
        let slot: Slot = Rc::new(RefCell::new(None));
        let mut pending = VecDeque::new();
        pending.push_back(UpEntry::Infer { up_id: 7, down_id: 1, model: "m".into(), slot });
        let err = settle(
            &mut pending,
            &shard,
            Frame::InferOk { id: 8, queue_us: 0, exec_us: 0, batch_size: 1, output: vec![] },
            &stats,
            &[shard.clone()],
            &BTreeMap::new(),
        )
        .unwrap_err();
        assert!(err.contains("out-of-order"), "{err}");
        // the entry is back at the head so fail() can error its slot
        assert_eq!(pending.len(), 1, "mismatched entry must be reinstated for failover");
    }

    #[test]
    fn forwarded_sheds_carry_the_max_retry_hint_over_placed_replicas() {
        let stats = RouterStats::default();
        let s0 = test_shard("x:1", &["m"], 0);
        let s1 = test_shard("x:2", &["m"], 0);
        let shards = [s0.clone(), s1.clone()];
        let placement: BTreeMap<String, Vec<usize>> = [("m".to_string(), vec![0, 1])].into();

        // replica 1 shed earlier and advertised a 12ms backoff
        s1.retry_hint_ms.store(12, Ordering::Relaxed);

        // replica 0 sheds with a 5ms hint: the forwarded reply carries
        // the max over both placed replicas, not just the answering one
        let slot: Slot = Rc::new(RefCell::new(None));
        let mut pending = VecDeque::new();
        pending.push_back(UpEntry::Infer { up_id: 1, down_id: 7, model: "m".into(), slot: slot.clone() });
        s0.in_flight.fetch_add(1, Ordering::Relaxed);
        settle(
            &mut pending,
            &s0,
            Frame::InferErr { id: 1, code: ErrCode::Busy, message: "full".into(), retry_after_ms: 5 },
            &stats,
            &shards,
            &placement,
        )
        .unwrap();
        match slot.borrow().as_ref() {
            Some(Frame::InferErr { retry_after_ms, .. }) => assert_eq!(*retry_after_ms, 12),
            other => panic!("expected forwarded shed, got {other:?}"),
        }
        assert_eq!(s0.retry_hint_ms.load(Ordering::Relaxed), 5, "own hint recorded");

        // replica 1 completes a request: its stale hint clears, so the
        // next shed forwards replica 0's own 5ms hint
        let ok_slot: Slot = Rc::new(RefCell::new(None));
        pending.push_back(UpEntry::Infer { up_id: 9, down_id: 8, model: "m".into(), slot: ok_slot });
        s1.in_flight.fetch_add(1, Ordering::Relaxed);
        settle(
            &mut pending,
            &s1,
            Frame::InferOk { id: 9, queue_us: 0, exec_us: 0, batch_size: 1, output: vec![0.0] },
            &stats,
            &shards,
            &placement,
        )
        .unwrap();
        assert_eq!(s1.retry_hint_ms.load(Ordering::Relaxed), 0, "completion clears the hint");

        let slot2: Slot = Rc::new(RefCell::new(None));
        pending.push_back(UpEntry::Infer { up_id: 2, down_id: 9, model: "m".into(), slot: slot2.clone() });
        s0.in_flight.fetch_add(1, Ordering::Relaxed);
        settle(
            &mut pending,
            &s0,
            Frame::InferErr { id: 2, code: ErrCode::Quota, message: "quota".into(), retry_after_ms: 5 },
            &stats,
            &shards,
            &placement,
        )
        .unwrap();
        match slot2.borrow().as_ref() {
            Some(Frame::InferErr { retry_after_ms, .. }) => assert_eq!(*retry_after_ms, 5),
            other => panic!("expected forwarded shed, got {other:?}"),
        }
    }
}
