//! Batch execution backends.
//!
//! [`BatchExecutor`] abstracts "run a (rows x dim) batch through a model"
//! so the coordinator can be tested without PJRT ([`EchoExecutor`]),
//! served natively ([`crate::coordinator::NativeExecutor`] — real TT and
//! dense models, fully functional offline) or served over AOT artifacts
//! ([`PjrtExecutor`]).  The PJRT executor pads each batch up to the
//! routed artifact variant and slices the padding back off.
//!
//! The variant [`Router`] lives here (not as its own module) because the
//! AOT artifact path is its *only* consumer: the native serving path has
//! exactly one implementation per model name, so there is nothing to
//! route.  Keeping it next to [`PjrtExecutor`] makes that scope visible.

use crate::error::{Error, Result};
use crate::runtime::{CompiledModel, Manifest, PjrtClient, RuntimeInput};
use std::collections::BTreeMap;

/// Something that can run one batch for a logical model.
pub trait BatchExecutor {
    /// `x` is `rows` concatenated feature vectors; returns `rows`
    /// concatenated output vectors and the per-row output dimension.
    /// Takes the batch buffer by value so executors can wrap it directly
    /// (the native path turns it into the input `Tensor` with zero copies).
    fn execute(&mut self, model: &str, x: Vec<f32>, rows: usize) -> Result<(Vec<f32>, usize)>;

    /// Per-row input dimension expected by `model`.
    fn input_dim(&self, model: &str) -> Result<usize>;
}

/// Test/bench executor: output = input scaled by a constant (dim
/// preserved).  Deterministic and instant.
pub struct EchoExecutor {
    pub dim: usize,
    pub scale: f32,
}

impl BatchExecutor for EchoExecutor {
    fn execute(&mut self, _model: &str, mut x: Vec<f32>, rows: usize) -> Result<(Vec<f32>, usize)> {
        if x.len() != rows * self.dim {
            return Err(Error::Coordinator(format!(
                "echo: {} elems for {rows} rows of {}",
                x.len(),
                self.dim
            )));
        }
        for v in &mut x {
            *v *= self.scale;
        }
        Ok((x, self.dim))
    }

    fn input_dim(&self, _model: &str) -> Result<usize> {
        Ok(self.dim)
    }
}

/// Pick the smallest variant size `>= batch`, or the largest available if
/// none fits (the worker will then split the batch).
pub fn choose_variant(sizes: &[usize], batch: usize) -> Option<usize> {
    let mut best: Option<usize> = None;
    for &s in sizes {
        if s >= batch {
            best = Some(match best {
                Some(b) => b.min(s),
                None => s,
            });
        }
    }
    best.or_else(|| sizes.iter().copied().max())
}

/// Maps logical model names (`"tt"`, `"fc"`, ...) to their AOT artifact
/// variants (`batch size -> artifact name`) — the pipeline emits
/// fixed-batch executables (e.g. `b1` and `b32`); the router picks the
/// smallest variant that fits and [`PjrtExecutor`] pads the remainder.
/// Used only by the AOT artifact path; native serving needs no routing.
#[derive(Clone, Debug, Default)]
pub struct Router {
    models: BTreeMap<String, BTreeMap<usize, String>>,
}

impl Router {
    pub fn new() -> Self {
        Router::default()
    }

    /// Register an artifact as the `batch`-sized variant of `model`.
    pub fn register(&mut self, model: &str, batch: usize, artifact: &str) {
        self.models.entry(model.to_string()).or_default().insert(batch, artifact.to_string());
    }

    /// Auto-register from manifest naming convention `<model>_b<batch>`.
    pub fn register_convention(&mut self, artifact_names: &[String]) {
        for name in artifact_names {
            if let Some(pos) = name.rfind("_b") {
                if let Ok(batch) = name[pos + 2..].parse::<usize>() {
                    self.register(&name[..pos], batch, name);
                }
            }
        }
    }

    pub fn models(&self) -> Vec<&str> {
        self.models.keys().map(|s| s.as_str()).collect()
    }

    pub fn variants(&self, model: &str) -> Option<Vec<usize>> {
        self.models.get(model).map(|v| v.keys().copied().collect())
    }

    /// Resolve `(artifact_name, variant_batch)` for a request batch size.
    pub fn route(&self, model: &str, batch: usize) -> Result<(String, usize)> {
        let variants = self
            .models
            .get(model)
            .ok_or_else(|| Error::Coordinator(format!("unknown model '{model}'")))?;
        let sizes: Vec<usize> = variants.keys().copied().collect();
        let size = choose_variant(&sizes, batch)
            .ok_or_else(|| Error::Coordinator(format!("model '{model}' has no variants")))?;
        Ok((variants[&size].clone(), size))
    }
}

/// The production executor: routes to AOT artifact variants, lazily
/// compiling each on first use.  Thread-confined (PJRT handles).  In the
/// offline std-only build [`crate::runtime::cpu_client`] fails, so
/// [`PjrtExecutor::new`] returns a clear `Error::Xla` and servers built
/// over it fail every request with "executor init failed" instead of
/// crashing (see `runtime::executable` for the gating rationale).
pub struct PjrtExecutor {
    client: PjrtClient,
    manifest: Manifest,
    router: Router,
    compiled: BTreeMap<String, CompiledModel>,
    /// padding staging buffer, retained across batches (resized per
    /// routed variant; no steady-state allocation)
    staging: Vec<f32>,
}

impl PjrtExecutor {
    /// Build over an artifacts directory; registers every artifact that
    /// follows the `<model>_b<batch>` naming convention.
    pub fn new(artifacts_dir: &str) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let mut router = Router::new();
        let names: Vec<String> = manifest.artifacts.iter().map(|a| a.name.clone()).collect();
        router.register_convention(&names);
        let client = crate::runtime::cpu_client()?;
        Ok(PjrtExecutor { client, manifest, router, compiled: BTreeMap::new(), staging: Vec::new() })
    }

    pub fn router(&self) -> &Router {
        &self.router
    }

    fn model_for(&mut self, artifact: &str) -> Result<&CompiledModel> {
        if !self.compiled.contains_key(artifact) {
            let m = CompiledModel::load(&self.client, &self.manifest, artifact)?;
            self.compiled.insert(artifact.to_string(), m);
        }
        Ok(&self.compiled[artifact])
    }
}

impl BatchExecutor for PjrtExecutor {
    fn execute(&mut self, model: &str, x: Vec<f32>, rows: usize) -> Result<(Vec<f32>, usize)> {
        let dim = self.input_dim(model)?;
        if x.len() != rows * dim {
            return Err(Error::Coordinator(format!(
                "{model}: {} elems for {rows} rows of {dim}",
                x.len()
            )));
        }
        let (artifact, variant) = self.router.route(model, rows)?;
        // the padding staging buffer is a retained field (it used to be
        // reallocated and re-zeroed for every chunk of every batch); it
        // travels inside a RuntimeInput for the duration of the call and
        // is recovered afterwards, even when a chunk fails
        let mut buf = std::mem::take(&mut self.staging);
        let compiled = match self.model_for(&artifact) {
            Ok(c) => c,
            Err(e) => {
                self.staging = buf; // keep the buffer through load failures
                return Err(e);
            }
        };
        let out_dim = compiled.spec().outputs[0].shape[1];

        let mut outputs = Vec::with_capacity(rows * out_dim);
        let mut done = 0usize;
        // resize only adjusts the length (steady state: no-op, no
        // re-zeroing) — every chunk iteration overwrites the full buffer
        buf.resize(variant * dim, 0.0);
        let mut staged = RuntimeInput::F32(buf);
        let mut failure = None;
        while done < rows {
            let take = (rows - done).min(variant);
            if let RuntimeInput::F32(padded) = &mut staged {
                padded[..take * dim].copy_from_slice(&x[done * dim..(done + take) * dim]);
                padded[take * dim..].fill(0.0);
            }
            match compiled.run(std::slice::from_ref(&staged)) {
                Ok(result) => {
                    outputs.extend_from_slice(&result[0].data()[..take * out_dim]);
                    done += take;
                }
                Err(e) => {
                    failure = Some(e);
                    break;
                }
            }
        }
        if let RuntimeInput::F32(buf) = staged {
            self.staging = buf;
        }
        match failure {
            Some(e) => Err(e),
            None => Ok((outputs, out_dim)),
        }
    }

    fn input_dim(&self, model: &str) -> Result<usize> {
        let (artifact, _) = self.router.route(model, 1)?;
        let spec = self.manifest.artifact(&artifact)?;
        let rt = spec
            .runtime_inputs()
            .first()
            .ok_or_else(|| Error::Coordinator(format!("{model}: no runtime input")))?
            .shape
            .clone();
        Ok(rt[1])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn choose_smallest_fitting() {
        assert_eq!(choose_variant(&[1, 32, 100], 1), Some(1));
        assert_eq!(choose_variant(&[1, 32, 100], 2), Some(32));
        assert_eq!(choose_variant(&[1, 32, 100], 32), Some(32));
        assert_eq!(choose_variant(&[1, 32, 100], 99), Some(100));
        // nothing fits: take the largest (worker splits)
        assert_eq!(choose_variant(&[1, 32], 50), Some(32));
        assert_eq!(choose_variant(&[], 1), None);
    }

    #[test]
    fn convention_registration() {
        let mut r = Router::new();
        r.register_convention(&[
            "tt_layer_b1".into(),
            "tt_layer_b32".into(),
            "fc_mnist_b1".into(),
            "weird-name".into(),
        ]);
        assert_eq!(r.variants("tt_layer"), Some(vec![1, 32]));
        assert_eq!(r.variants("fc_mnist"), Some(vec![1]));
        assert!(r.variants("weird-name").is_none());
        let (art, size) = r.route("tt_layer", 7).unwrap();
        assert_eq!((art.as_str(), size), ("tt_layer_b32", 32));
    }

    #[test]
    fn unknown_model_errors() {
        let r = Router::new();
        assert!(r.route("nope", 1).is_err());
    }

    #[test]
    fn echo_roundtrip() {
        let mut e = EchoExecutor { dim: 3, scale: 2.0 };
        let (y, od) = e.execute("any", vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], 2).unwrap();
        assert_eq!(od, 3);
        assert_eq!(y, vec![2.0, 4.0, 6.0, 8.0, 10.0, 12.0]);
        assert!(e.execute("any", vec![1.0], 2).is_err());
    }
}
