//! The serving front-end: admission tickets → batcher thread → executor
//! worker pool → per-request replies, with latency/throughput metrics.
//!
//! ```text
//!          tickets                                      ┌► tn-executor-0 ─┐
//! callers ── admission ──► tn-batcher ────── batch ──────┼► tn-executor-1 ─┼─► replies
//!            controller     (max_batch / max_delay, queue └► tn-executor-N ─┘   (ticket
//!            (capacity /     FIFO or LIFO drain                                released
//!             quotas; sheds  under overload)                                   on drop)
//!             when out of tickets)
//! ```
//!
//! Admission is transport-agnostic: in-process callers ([`Server::infer`]
//! / [`Server::try_infer`]) and the TCP front-end's per-connection
//! readers (`coordinator::net`) acquire tickets from the same
//! [`AdmissionController`] (DESIGN.md §14) through [`Server::admit`],
//! so backpressure ([`Admission::Busy`] → a typed `Busy` wire reply
//! with a retry hint instead of a hang) and [`ServerStats`] are shared
//! across every way into the server.  The ticket rides inside the
//! request and is released by RAII when the request is dropped — after
//! the reply send, on failure, or when discarded at shutdown — so the
//! outstanding-ticket count bounds the *whole* pipeline (the admission
//! channel itself is unbounded).
//!
//! The batch queue is a single `mpsc` receiver shared by all workers
//! behind a mutex (the std-only stand-in for a multi-consumer channel).
//! Each worker constructs its own executor through the `Fn` factory *on
//! its own thread*, so non-`Send` executors (PJRT handles) stay
//! thread-confined and every worker owns its scratch buffers.

use crate::coordinator::admission::{
    AdmissionConfig, AdmissionController, AdmissionTicket, ShedInfo, ShedKind,
};
use crate::coordinator::batcher::{Batch, BatchAssembler, BatchPolicy};
use crate::coordinator::request::{InferRequest, InferResponse};
use crate::coordinator::worker::BatchExecutor;
use crate::error::{Error, Result};
use crate::metrics::{Counter, Histogram, Meter};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{
    channel, sync_channel, Receiver, RecvTimeoutError, Sender, SyncSender, TryRecvError,
    TrySendError,
};
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

/// Server wiring knobs.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub policy: BatchPolicy,
    /// initial admission-ticket capacity — the bound on requests in
    /// flight anywhere in the pipeline (queue + batcher backlog +
    /// executing); beyond it `try_infer`/`admit` shed (backpressure
    /// instead of unbounded memory growth).  With
    /// `admission.latency_target_ms` set this is only the starting
    /// point: capacity then tracks observed latency.
    pub queue_capacity: usize,
    /// bound on formed batches waiting for the executor pool
    pub batch_queue_capacity: usize,
    /// executor worker threads draining the shared batch queue.  Each
    /// worker builds its own executor via the `Fn` factory, so model
    /// state is never shared across workers.  Clamped to at least 1.
    pub executor_threads: usize,
    /// kernel threads EACH executor worker may fan out to for one
    /// batch's tensor work (the intra-batch parallelism of
    /// `tt/matvec.rs` / `tensor/matmul.rs`).  `0` = auto:
    /// `num_threads() / executor_threads`, at least 1 — so pool
    /// parallelism × kernel parallelism never oversubscribes the box.
    pub kernel_threads: usize,
    /// adaptive-admission knobs (latency target, quotas, overload
    /// flip).  The default is behaviorally the fixed bounded queue.
    pub admission: AdmissionConfig,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            policy: BatchPolicy::default(),
            queue_capacity: 1024,
            batch_queue_capacity: 8,
            executor_threads: 1,
            kernel_threads: 0,
            admission: AdmissionConfig::default(),
        }
    }
}

impl ServerConfig {
    /// The per-worker kernel thread budget this config resolves to:
    /// `kernel_threads` if set, else `num_threads() / executor_threads`
    /// clamped to ≥ 1.  Recorded in bench provenance next to each
    /// throughput number.
    pub fn effective_kernel_threads(&self) -> usize {
        let workers = self.executor_threads.max(1);
        if self.kernel_threads > 0 {
            self.kernel_threads
        } else {
            (crate::util::threads::num_threads() / workers).max(1)
        }
    }
}

/// Per-model serving metrics, keyed by model name inside
/// [`ServerStats`].  The aggregate counters can hide one model
/// batching at `max_batch` while another degenerates to batch-size-1;
/// these are what `stats()` printing, the wire `StatsReply` and
/// `Client::stats` surface so per-model batch efficiency is observable.
#[derive(Debug, Default)]
pub struct ModelStats {
    pub completed: Counter,
    pub errors: Counter,
    pub batches: Counter,
    pub batched_rows: Counter,
    /// admission sheds for this model — both kinds: out of global
    /// capacity, or past its quota with the free pool exhausted
    pub shed: Counter,
    /// wall-clock enqueue → reply receipt for this model's requests
    pub e2e: Histogram,
}

impl ModelStats {
    /// Mean rows per executed batch of this model.
    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.get();
        if b == 0 {
            0.0
        } else {
            self.batched_rows.get() as f64 / b as f64
        }
    }
}

/// Shared serving metrics.
#[derive(Debug, Default)]
pub struct ServerStats {
    /// wall-clock enqueue → reply receipt (recorded by `infer`/`await_reply`)
    pub e2e: Histogram,
    /// batch execution time
    pub exec: Histogram,
    /// enqueue → execution start (admission + batching + batch-queue wait)
    pub queue: Histogram,
    pub completed: Counter,
    /// total admission sheds (every kind; `quota_shed` is the subset)
    pub rejected: Counter,
    /// sheds typed [`ShedKind::Quota`]: the model exhausted its
    /// reservation AND the free pool — other tenants' reservations are
    /// what stopped it (subset of `rejected`)
    pub quota_shed: Counter,
    pub errors: Counter,
    /// executor workers whose init failed (pool running degraded if
    /// fewer than `executor_threads` remain)
    pub failed_workers: Counter,
    pub throughput: Meter,
    pub batches: Counter,
    pub batched_rows: Counter,
    /// per-model counters/histograms, created lazily on first traffic
    /// for models the executor actually resolves (arbitrary unknown
    /// names never plant entries — see `run_batch`); behind an RwLock
    /// so concurrent reply threads share a read lock and only a
    /// first-ever-traffic miss takes the write lock
    per_model: RwLock<BTreeMap<String, Arc<ModelStats>>>,
}

impl ServerStats {
    /// Mean rows per executed batch.
    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.get();
        if b == 0 {
            0.0
        } else {
            self.batched_rows.get() as f64 / b as f64
        }
    }

    /// Get-or-create the stats for `model`.  Steady state is a shared
    /// read lock + map lookup + `Arc` clone (concurrent reply threads
    /// don't serialize); only the first traffic a model ever sees takes
    /// the write lock.  Recording happens on the returned handle — the
    /// executor takes one per *batch*.
    pub fn model(&self, model: &str) -> Arc<ModelStats> {
        {
            let guard = match self.per_model.read() {
                Ok(g) => g,
                Err(poisoned) => poisoned.into_inner(),
            };
            if let Some(s) = guard.get(model) {
                return s.clone();
            }
        }
        let mut guard = match self.per_model.write() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        // re-check under the write lock: another thread may have won
        // the race between our read miss and here
        guard
            .entry(model.to_string())
            .or_insert_with(|| Arc::new(ModelStats::default()))
            .clone()
    }

    /// Snapshot of every model's stats, sorted by name (stable print
    /// and wire order).
    pub fn per_model(&self) -> Vec<(String, Arc<ModelStats>)> {
        let guard = match self.per_model.read() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        guard.iter().map(|(k, v)| (k.clone(), v.clone())).collect()
    }
}

/// Where a request's reply arrives; `Err` carries a failure message.
pub type ReplyReceiver = Receiver<std::result::Result<InferResponse, String>>;

/// Outcome of a non-blocking [`Server::admit`].
pub enum Admission {
    /// Admitted — await the receiver (via [`Server::await_reply`], which
    /// also records true e2e latency).
    Queued(ReplyReceiver),
    /// Out of tickets: load shed (already counted in
    /// [`ServerStats::rejected`] and the model's
    /// [`ModelStats::shed`]).  The [`ShedInfo`] says which kind —
    /// global capacity vs this model's quota — and how long to back
    /// off.  Transports turn this into a typed `Busy`/`Quota` wire
    /// reply; in-process callers into [`Error::Busy`].
    Busy(ShedInfo),
}

/// A running coordinator.  Dropping (or calling [`Server::shutdown`])
/// closes the admission queue, drains in-flight work and joins the
/// batcher plus every executor worker.
pub struct Server {
    /// unbounded on purpose: the admission controller's tickets bound
    /// everything in flight, so the channel never holds more than
    /// `capacity` requests
    tx: Option<Sender<InferRequest>>,
    next_id: AtomicU64,
    stats: Arc<ServerStats>,
    admission: Arc<AdmissionController>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Start the batcher thread and `cfg.executor_threads` executor
    /// workers.  `make_executor` runs once *on each* worker thread (PJRT
    /// handles are not `Send`, so executors must be constructed where
    /// they run) — hence `Fn`, not `FnOnce`.
    pub fn start<E, F>(cfg: ServerConfig, make_executor: F) -> Result<Server>
    where
        E: BatchExecutor,
        F: Fn() -> Result<E> + Send + Sync + 'static,
    {
        let workers = cfg.executor_threads.max(1);
        let kernel_budget = cfg.effective_kernel_threads();
        let admission = AdmissionController::new(cfg.queue_capacity, &cfg.admission);
        let (tx, rx) = channel::<InferRequest>();
        let (btx, brx) = sync_channel::<Batch>(cfg.batch_queue_capacity);
        let stats = Arc::new(ServerStats::default());

        let policy = cfg.policy;
        let ctl = admission.clone();
        let batcher = std::thread::Builder::new()
            .name("tn-batcher".into())
            .spawn(move || batcher_loop(rx, btx, policy, ctl))
            .map_err(|e| Error::Coordinator(format!("spawn batcher: {e}")))?;
        let mut threads = vec![batcher];

        let shared = Arc::new(Mutex::new(brx));
        let factory = Arc::new(make_executor);
        let failed_inits = Arc::new(AtomicUsize::new(0));
        for w in 0..workers {
            let shared = shared.clone();
            let factory = factory.clone();
            let stats = stats.clone();
            let failed_inits = failed_inits.clone();
            let handle = std::thread::Builder::new()
                .name(format!("tn-executor-{w}"))
                .spawn(move || {
                    // cap this worker's kernel fan-out BEFORE building the
                    // executor (model construction already runs tensor
                    // code): with W workers each budgeted cores/W, a full
                    // pool saturates the box without oversubscribing it
                    crate::util::threads::set_thread_budget(kernel_budget);
                    let mut exec = match (factory.as_ref())() {
                        Ok(e) => e,
                        Err(e) => {
                            // A worker whose executor fails to construct
                            // exits while healthy siblings keep serving —
                            // but not silently: the pool would otherwise
                            // run degraded with no signal.  The LAST
                            // failure (no healthy worker can exist) stays
                            // behind to fail queued batches so callers
                            // get an error instead of hanging.
                            let msg = format!("executor init failed: {e}");
                            stats.failed_workers.inc();
                            let down = failed_inits.fetch_add(1, Ordering::SeqCst) + 1;
                            eprintln!("tn-executor-{w}: {msg} ({down}/{workers} workers down)");
                            if down == workers {
                                while let Some(batch) = recv_shared(&shared) {
                                    fail_batch(batch, &msg, &stats);
                                }
                            }
                            return;
                        }
                    };
                    while let Some(batch) = recv_shared(&shared) {
                        run_batch(batch, &mut exec, &stats);
                    }
                })
                .map_err(|e| Error::Coordinator(format!("spawn executor {w}: {e}")))?;
            threads.push(handle);
        }

        Ok(Server { tx: Some(tx), next_id: AtomicU64::new(1), stats, admission, threads })
    }

    pub fn stats(&self) -> &ServerStats {
        &self.stats
    }

    /// The admission controller — for the net reactor's doze gate
    /// (release epoch), the serve summary and bench provenance
    /// (snapshot).
    pub fn admission(&self) -> &Arc<AdmissionController> {
        &self.admission
    }

    /// Build one pipeline entry + its reply receiver.  The single place
    /// an `InferRequest` is constructed, shared by the blocking and
    /// non-blocking paths so ids, timestamps, ticket and reply plumbing
    /// cannot drift between transports.
    fn new_request(
        &self,
        model: &str,
        input: Vec<f32>,
        ticket: AdmissionTicket,
    ) -> (InferRequest, ReplyReceiver) {
        let (reply_tx, reply_rx) = channel();
        let req = InferRequest {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            model: model.to_string(),
            input,
            enqueued: Instant::now(),
            reply: reply_tx,
            ticket: Some(ticket),
        };
        (req, reply_rx)
    }

    /// Blocking inference: wait for an admission ticket if none is
    /// free, then wait for the reply.  Never sheds (mirrors the old
    /// blocking send into the bounded queue).
    pub fn infer(&self, model: &str, input: Vec<f32>) -> Result<InferResponse> {
        let ticket = self.admission.admit_blocking(model);
        let (req, reply_rx) = self.new_request(model, input, ticket);
        self.tx
            .as_ref()
            .ok_or_else(|| Error::Coordinator("server shut down".into()))?
            .send(req)
            .map_err(|_| Error::Coordinator("admission queue closed".into()))?;
        self.receive(reply_rx)
    }

    /// Non-blocking, transport-agnostic admission: acquire a ticket or
    /// shed ([`Admission::Busy`] with the typed [`ShedInfo`], counted
    /// in [`ServerStats::rejected`] / `quota_shed` / the model's
    /// `shed`) instead of waiting when capacity is out.  Every
    /// transport — in-process `try_infer` and the TCP front-end — goes
    /// through here, so backpressure and stats stay shared.
    ///
    /// (Per-model shed accounting keys stats by the caller's name; the
    /// TCP front-end validates names against the served lineup before
    /// admission, and in-process callers are the code's own trust
    /// domain, so arbitrary names cannot grow the map.)
    pub fn admit(&self, model: &str, input: Vec<f32>) -> Result<Admission> {
        match self.admission.try_admit(model) {
            Ok(ticket) => {
                let (req, reply_rx) = self.new_request(model, input, ticket);
                self.tx
                    .as_ref()
                    .ok_or_else(|| Error::Coordinator("server shut down".into()))?
                    .send(req)
                    .map_err(|_| Error::Coordinator("admission queue closed".into()))?;
                Ok(Admission::Queued(reply_rx))
            }
            Err(info) => {
                self.stats.rejected.inc();
                if info.kind == ShedKind::Quota {
                    self.stats.quota_shed.inc();
                }
                self.stats.model(model).shed.inc();
                Ok(Admission::Busy(info))
            }
        }
    }

    /// Non-blocking admission for in-process callers: rejects with a
    /// retryable [`Error::Busy`] instead of waiting when out of
    /// capacity (returns the reply receiver to await later).
    pub fn try_infer(&self, model: &str, input: Vec<f32>) -> Result<ReplyReceiver> {
        match self.admit(model, input)? {
            Admission::Queued(rx) => Ok(rx),
            Admission::Busy(info) => Err(Error::Busy {
                message: match info.kind {
                    ShedKind::Capacity => "admission queue full".into(),
                    ShedKind::Quota => "model quota exceeded".into(),
                },
                retry_after_ms: info.retry_after_ms,
            }),
        }
    }

    /// Await a receiver from [`Server::try_infer`] / [`Server::admit`].
    pub fn await_reply(&self, rx: ReplyReceiver) -> Result<InferResponse> {
        self.receive(rx)
    }

    /// Non-blocking counterpart of [`Server::await_reply`] for
    /// reactor-style transports that multiplex many connections on one
    /// thread and therefore may never block on a single reply.  `None`
    /// means still pending — poll again later; `Some` is the settled
    /// reply, with e2e latency recorded exactly like the blocking path
    /// (both funnel through the same settling point, so remote requests
    /// land in the same histograms however they are delivered).
    pub fn try_reply(&self, rx: &ReplyReceiver) -> Option<Result<InferResponse>> {
        match rx.try_recv() {
            Ok(res) => Some(self.settle(res)),
            Err(TryRecvError::Empty) => None,
            Err(TryRecvError::Disconnected) => {
                Some(Err(Error::Coordinator("reply channel dropped".into())))
            }
        }
    }

    fn receive(&self, rx: ReplyReceiver) -> Result<InferResponse> {
        match rx.recv() {
            Ok(res) => self.settle(res),
            Err(_) => Err(Error::Coordinator("reply channel dropped".into())),
        }
    }

    /// Record stats and map failures for one delivered reply — the
    /// single settling point shared by the blocking (`await_reply`) and
    /// non-blocking (`try_reply`) delivery paths, so latency accounting
    /// cannot drift between them.
    fn settle(&self, res: std::result::Result<InferResponse, String>) -> Result<InferResponse> {
        match res {
            Ok(resp) => {
                // true end-to-end latency: wall clock from enqueue to
                // reply receipt.  (This used to be queue_us + exec_us,
                // which silently dropped batch-queue wait and the reply
                // hop.)
                let e2e = resp.enqueued.elapsed();
                self.stats.e2e.record(e2e);
                self.stats.model(&resp.model).e2e.record(e2e);
                Ok(resp)
            }
            Err(msg) => Err(Error::Coordinator(msg)),
        }
    }

    /// Drain and join: in-flight requests complete, then the batcher and
    /// every executor worker exit.
    pub fn shutdown(mut self) {
        self.tx.take(); // close admission queue
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.tx.take();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// Pop the next batch off the pool's shared queue; `None` once the
/// batcher has exited and the queue is drained.  One worker at a time
/// blocks inside `recv` holding the lock; the lock is released before
/// the batch executes, so model execution overlaps across workers.
fn recv_shared(shared: &Mutex<Receiver<Batch>>) -> Option<Batch> {
    let rx = match shared.lock() {
        Ok(guard) => guard,
        // a worker that panicked mid-recv poisons the mutex; the queue
        // itself is still coherent, so keep serving
        Err(poisoned) => poisoned.into_inner(),
    };
    rx.recv().ok()
}

/// Feed wall-clock events into the per-model [`BatchAssembler`]: wake
/// at the MIN deadline across groups, drain every arrival, then emit
/// ready batches in the admission controller's current [`QueueMode`]
/// (FIFO normally, newest-first under sustained overload) for as long
/// as the batch queue accepts them.
///
/// The batch queue is bounded but the admission channel no longer is
/// (tickets bound the pipeline), so a full batch queue must NOT block
/// this thread — a blocked batcher couldn't ingest arrivals, and the
/// backlog ordering decision would be frozen at the wrong moment.
/// Instead a batch refused by `try_send` is stashed in `stuck` and
/// retried on a short tick; the assembler keeps accumulating (and
/// re-ordering, if the mode flips) behind it.
fn batcher_loop(
    rx: Receiver<InferRequest>,
    btx: SyncSender<Batch>,
    policy: BatchPolicy,
    ctl: Arc<AdmissionController>,
) {
    let mut asm = BatchAssembler::new(policy);
    let mut stuck: Option<Batch> = None;
    loop {
        let timeout = if stuck.is_some() {
            // executor backpressure: retry the stashed batch soon
            Duration::from_millis(1)
        } else {
            asm.deadline()
                .map(|d| d.saturating_duration_since(Instant::now()))
                .unwrap_or(Duration::from_millis(50))
                .min(Duration::from_millis(50))
        };
        match rx.recv_timeout(timeout) {
            Ok(req) => {
                asm.push(req);
                // drain the burst in one pass — ordering decisions see
                // the whole backlog, not one arrival at a time
                while let Ok(req) = rx.try_recv() {
                    asm.push(req);
                }
            }
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => {
                // shutdown: blocking sends are safe now (no more
                // arrivals to ingest) and must not drop work
                if let Some(batch) = stuck.take() {
                    if btx.send(batch).is_err() {
                        return;
                    }
                }
                for batch in asm.flush() {
                    if btx.send(batch).is_err() {
                        return;
                    }
                }
                return;
            }
        }
        loop {
            let batch = match stuck.take() {
                Some(b) => b,
                None => match asm.pop_ready(Instant::now(), ctl.mode()) {
                    Some(b) => b,
                    None => break,
                },
            };
            match btx.try_send(batch) {
                Ok(()) => {}
                Err(TrySendError::Full(b)) => {
                    stuck = Some(b);
                    break;
                }
                Err(TrySendError::Disconnected(_)) => return,
            }
        }
    }
}

/// Execute one batch on this worker's executor and reply per request.
fn run_batch(batch: Batch, exec: &mut dyn BatchExecutor, stats: &ServerStats) {
    let rows = batch.requests.len();
    let dim = match exec.input_dim(&batch.model) {
        Ok(d) => d,
        Err(e) => {
            // model unknown to the executor: aggregate errors only — a
            // per-model entry here would let arbitrary (in-process)
            // names grow the stats map without bound
            fail_batch(batch, &format!("input_dim: {e}"), stats);
            return;
        }
    };
    // the executor resolved the model, so it's safe to key stats by it:
    // one per-model lookup per batch; counters below record on the Arc
    let mstats = stats.model(&batch.model);
    // assemble the batch matrix; reject rows with bad dims individually
    let mut x = Vec::with_capacity(rows * dim);
    let mut ok_requests = Vec::with_capacity(rows);
    for req in batch.requests {
        if req.input.len() == dim {
            x.extend_from_slice(&req.input);
            ok_requests.push(req);
        } else {
            stats.errors.inc();
            mstats.errors.inc();
            let _ = req.reply.send(Err(format!(
                "input dim {} != expected {dim}",
                req.input.len()
            )));
        }
    }
    if ok_requests.is_empty() {
        return;
    }
    let t0 = Instant::now();
    match exec.execute(&batch.model, x, ok_requests.len()) {
        Ok((y, out_dim)) => {
            if y.len() != ok_requests.len() * out_dim {
                let msg = format!(
                    "executor returned {} values for {} rows of {out_dim}",
                    y.len(),
                    ok_requests.len()
                );
                for req in ok_requests {
                    stats.errors.inc();
                    mstats.errors.inc();
                    let _ = req.reply.send(Err(msg.clone()));
                }
                return;
            }
            let exec_us = t0.elapsed().as_micros() as u64;
            stats.exec.record(t0.elapsed());
            stats.batches.inc();
            stats.batched_rows.add(ok_requests.len() as u64);
            mstats.batches.inc();
            mstats.batched_rows.add(ok_requests.len() as u64);
            stats.throughput.mark(ok_requests.len() as u64);
            let bs = ok_requests.len();
            for (i, req) in ok_requests.into_iter().enumerate() {
                let queue_us = t0.saturating_duration_since(req.enqueued).as_micros() as u64;
                stats.queue.record(Duration::from_micros(queue_us));
                let resp = InferResponse {
                    id: req.id,
                    model: req.model,
                    output: y[i * out_dim..(i + 1) * out_dim].to_vec(),
                    queue_us,
                    exec_us,
                    batch_size: bs,
                    enqueued: req.enqueued,
                };
                // count BEFORE replying: callers may read stats the
                // instant their reply lands
                stats.completed.inc();
                mstats.completed.inc();
                let _ = req.reply.send(Ok(resp));
            }
        }
        Err(e) => {
            let msg = format!("execute failed: {e}");
            for req in ok_requests {
                stats.errors.inc();
                mstats.errors.inc();
                let _ = req.reply.send(Err(msg.clone()));
            }
        }
    }
}

/// Fail every request of a batch whose model never resolved (executor
/// init failure, unknown model).  Aggregate errors only: keying stats
/// by an unresolved, caller-controlled name would create a permanent
/// map entry per unique garbage name.
fn fail_batch(batch: Batch, msg: &str, stats: &ServerStats) {
    for req in batch.requests {
        stats.errors.inc();
        let _ = req.reply.send(Err(msg.to_string()));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::worker::EchoExecutor;

    fn echo_server(max_batch: usize, delay_ms: u64) -> Server {
        let cfg = ServerConfig {
            policy: BatchPolicy {
                max_batch,
                max_delay: Duration::from_millis(delay_ms),
            },
            ..Default::default()
        };
        Server::start(cfg, || Ok(EchoExecutor { dim: 4, scale: 3.0 })).unwrap()
    }

    #[test]
    fn single_request_roundtrip() {
        let server = echo_server(8, 1);
        let resp = server.infer("m", vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        assert_eq!(resp.output, vec![3.0, 6.0, 9.0, 12.0]);
        assert!(resp.batch_size >= 1);
        assert_eq!(server.stats().completed.get(), 1);
        server.shutdown();
    }

    #[test]
    fn concurrent_requests_get_batched() {
        let server = std::sync::Arc::new(echo_server(16, 20));
        let mut handles = Vec::new();
        for i in 0..16 {
            let s = server.clone();
            handles.push(std::thread::spawn(move || {
                s.infer("m", vec![i as f32; 4]).unwrap()
            }));
        }
        let resps: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        for (i, r) in resps.iter().enumerate() {
            assert_eq!(r.output, vec![i as f32 * 3.0; 4]);
        }
        // at least one multi-row batch must have formed
        assert!(server.stats().mean_batch_size() > 1.0, "mean batch {}", server.stats().mean_batch_size());
    }

    #[test]
    fn pool_processes_all_requests() {
        let cfg = ServerConfig {
            policy: BatchPolicy { max_batch: 4, max_delay: Duration::from_millis(1) },
            executor_threads: 4,
            ..Default::default()
        };
        let server = Server::start(cfg, || Ok(EchoExecutor { dim: 4, scale: 2.0 })).unwrap();
        std::thread::scope(|s| {
            for c in 0..8 {
                let server = &server;
                s.spawn(move || {
                    for i in 0..25 {
                        let tag = (c * 100 + i) as f32;
                        let resp = server.infer("m", vec![tag; 4]).unwrap();
                        assert_eq!(resp.output, vec![tag * 2.0; 4]);
                    }
                });
            }
        });
        assert_eq!(server.stats().completed.get(), 200);
        assert_eq!(server.stats().errors.get(), 0);
        server.shutdown(); // drains and joins all 4 workers + batcher
    }

    #[test]
    fn e2e_latency_covers_the_whole_round_trip() {
        // Two max_batch=1 requests enqueued back-to-back against a single
        // slow worker: the second one's batch waits in the batch queue for
        // the full 20ms of the first one's execution, so its true e2e is
        // ~40ms.  The accounting this guards against (summing the
        // response's own exec time) would report only ~20ms — the
        // regression is a max_us below the serialized total.
        struct Sleepy;
        impl BatchExecutor for Sleepy {
            fn execute(&mut self, _m: &str, x: Vec<f32>, _r: usize) -> Result<(Vec<f32>, usize)> {
                std::thread::sleep(Duration::from_millis(20));
                let n = x.len();
                Ok((x, n))
            }
            fn input_dim(&self, _m: &str) -> Result<usize> {
                Ok(2)
            }
        }
        let cfg = ServerConfig {
            policy: BatchPolicy { max_batch: 1, max_delay: Duration::from_millis(1) },
            ..Default::default()
        };
        let server = Server::start(cfg, || Ok(Sleepy)).unwrap();
        let rx1 = server.try_infer("m", vec![1.0, 2.0]).unwrap();
        let rx2 = server.try_infer("m", vec![3.0, 4.0]).unwrap();
        server.await_reply(rx1).unwrap();
        server.await_reply(rx2).unwrap();
        let e2e = server.stats().e2e.max_us();
        assert!(
            e2e >= 35_000.0,
            "e2e max {e2e}µs must include the second request's batch-queue wait (~40ms)"
        );
    }

    #[test]
    fn try_reply_polls_without_blocking_and_records_e2e() {
        // Reactor transports poll replies instead of parking a thread
        // per request: while the executor is still sleeping, try_reply
        // must return None immediately; once the reply lands it must
        // settle it with the same e2e accounting as await_reply.
        struct Slow;
        impl BatchExecutor for Slow {
            fn execute(&mut self, _m: &str, x: Vec<f32>, _r: usize) -> Result<(Vec<f32>, usize)> {
                std::thread::sleep(Duration::from_millis(30));
                let n = x.len();
                Ok((x, n))
            }
            fn input_dim(&self, _m: &str) -> Result<usize> {
                Ok(2)
            }
        }
        let cfg = ServerConfig {
            policy: BatchPolicy { max_batch: 1, max_delay: Duration::from_millis(0) },
            ..Default::default()
        };
        let server = Server::start(cfg, || Ok(Slow)).unwrap();
        let rx = server.try_infer("m", vec![5.0, 6.0]).unwrap();
        assert!(
            server.try_reply(&rx).is_none(),
            "reply cannot have settled before the 30ms execution"
        );
        let deadline = Instant::now() + Duration::from_secs(5);
        let resp = loop {
            match server.try_reply(&rx) {
                Some(res) => break res.unwrap(),
                None => {
                    assert!(Instant::now() < deadline, "reply never settled");
                    std::thread::sleep(Duration::from_millis(1));
                }
            }
        };
        assert_eq!(resp.output, vec![5.0, 6.0]);
        assert_eq!(server.stats().e2e.count(), 1, "try_reply must record e2e latency");
    }

    #[test]
    fn wrong_dim_is_rejected_individually() {
        let server = echo_server(4, 1);
        let err = server.infer("m", vec![1.0, 2.0]).unwrap_err();
        assert!(format!("{err}").contains("input dim"));
        // server still healthy
        let ok = server.infer("m", vec![0.0; 4]).unwrap();
        assert_eq!(ok.output, vec![0.0; 4]);
    }

    #[test]
    fn executor_init_failure_fails_requests() {
        let cfg = ServerConfig::default();
        let server = Server::start(cfg, || {
            Err::<EchoExecutor, _>(Error::Coordinator("boom".into()))
        })
        .unwrap();
        let err = server.infer("m", vec![0.0; 4]).unwrap_err();
        assert!(format!("{err}").contains("boom") || format!("{err}").contains("init"));
    }

    #[test]
    fn pool_wide_init_failure_fails_requests() {
        // every worker fails init: requests must error, not hang
        let cfg = ServerConfig { executor_threads: 3, ..Default::default() };
        let server = Server::start(cfg, || {
            Err::<EchoExecutor, _>(Error::Coordinator("boom".into()))
        })
        .unwrap();
        for _ in 0..5 {
            let err = server.infer("m", vec![0.0; 4]).unwrap_err();
            assert!(format!("{err}").contains("boom") || format!("{err}").contains("init"));
        }
        // a reply can only have come from the last-failed drainer, so by
        // now every worker has recorded its init failure
        assert_eq!(server.stats().failed_workers.get(), 3);
        server.shutdown(); // must not hang
    }

    #[test]
    fn admit_sheds_load_when_queue_full_and_counts_rejections() {
        // one admission ticket bounds the whole pipeline: a burst of 16
        // non-blocking admissions gets exactly 1 in and sheds 15 — and
        // every shed lands in stats.rejected + the model's shed counter
        struct Stall;
        impl BatchExecutor for Stall {
            fn execute(&mut self, _m: &str, x: Vec<f32>, _r: usize) -> Result<(Vec<f32>, usize)> {
                std::thread::sleep(Duration::from_millis(30));
                let n = x.len();
                Ok((x, n))
            }
            fn input_dim(&self, _m: &str) -> Result<usize> {
                Ok(2)
            }
        }
        let cfg = ServerConfig {
            policy: BatchPolicy { max_batch: 1, max_delay: Duration::from_millis(0) },
            queue_capacity: 1,
            batch_queue_capacity: 1,
            executor_threads: 1,
            ..Default::default()
        };
        let server = Server::start(cfg, || Ok(Stall)).unwrap();
        let mut queued = Vec::new();
        let mut busy = 0u64;
        for _ in 0..16 {
            match server.admit("m", vec![1.0, 2.0]).unwrap() {
                Admission::Queued(rx) => queued.push(rx),
                Admission::Busy(info) => {
                    assert_eq!(info.kind, ShedKind::Capacity, "no quotas configured");
                    busy += 1;
                }
            }
        }
        assert!(busy >= 1, "16 instant admissions against 1 ticket must shed");
        assert_eq!(server.stats().rejected.get(), busy);
        assert_eq!(server.stats().quota_shed.get(), 0);
        assert_eq!(server.stats().model("m").shed.get(), busy);
        // the admitted ones all complete — shedding never drops a queued reply
        for rx in queued {
            server.await_reply(rx).unwrap();
        }
        server.shutdown();
    }

    #[test]
    fn quota_shed_is_typed_and_counted_separately() {
        // capacity 2, "hot" quota 1 → free pool 1.  A stalled executor
        // holds tickets; "hot" can take its reservation + borrow the
        // free ticket, then sheds Quota while quota_shed counts it.
        struct Stall;
        impl BatchExecutor for Stall {
            fn execute(&mut self, _m: &str, x: Vec<f32>, _r: usize) -> Result<(Vec<f32>, usize)> {
                std::thread::sleep(Duration::from_millis(50));
                let n = x.len();
                Ok((x, n))
            }
            fn input_dim(&self, _m: &str) -> Result<usize> {
                Ok(2)
            }
        }
        let cfg = ServerConfig {
            policy: BatchPolicy { max_batch: 1, max_delay: Duration::from_millis(0) },
            queue_capacity: 2,
            batch_queue_capacity: 1,
            executor_threads: 1,
            admission: AdmissionConfig {
                quotas: vec![("hot".into(), 1)],
                ..Default::default()
            },
            ..Default::default()
        };
        let server = Server::start(cfg, || Ok(Stall)).unwrap();
        let mut queued = Vec::new();
        for _ in 0..2 {
            match server.admit("hot", vec![1.0, 2.0]).unwrap() {
                Admission::Queued(rx) => queued.push(rx),
                Admission::Busy(_) => panic!("reservation + free pool hold 2"),
            }
        }
        match server.admit("hot", vec![1.0, 2.0]).unwrap() {
            Admission::Queued(_) => panic!("capacity 2 is out"),
            Admission::Busy(info) => assert_eq!(info.kind, ShedKind::Quota),
        }
        // an unquota'd tenant sheds Capacity, not Quota
        match server.admit("bg", vec![1.0, 2.0]).unwrap() {
            Admission::Queued(_) => panic!("free pool is borrowed away"),
            Admission::Busy(info) => assert_eq!(info.kind, ShedKind::Capacity),
        }
        assert_eq!(server.stats().rejected.get(), 2);
        assert_eq!(server.stats().quota_shed.get(), 1);
        assert_eq!(server.stats().model("hot").shed.get(), 1);
        assert_eq!(server.stats().model("bg").shed.get(), 1);
        for rx in queued {
            server.await_reply(rx).unwrap();
        }
        server.shutdown();
    }

    #[test]
    fn try_infer_shed_is_a_retryable_busy_error() {
        struct Stall;
        impl BatchExecutor for Stall {
            fn execute(&mut self, _m: &str, x: Vec<f32>, _r: usize) -> Result<(Vec<f32>, usize)> {
                std::thread::sleep(Duration::from_millis(30));
                let n = x.len();
                Ok((x, n))
            }
            fn input_dim(&self, _m: &str) -> Result<usize> {
                Ok(2)
            }
        }
        let cfg = ServerConfig {
            policy: BatchPolicy { max_batch: 1, max_delay: Duration::from_millis(0) },
            queue_capacity: 1,
            ..Default::default()
        };
        let server = Server::start(cfg, || Ok(Stall)).unwrap();
        let rx = server.try_infer("m", vec![1.0, 2.0]).unwrap();
        match server.try_infer("m", vec![1.0, 2.0]) {
            Err(Error::Busy { message, retry_after_ms }) => {
                assert!(message.contains("admission queue full"));
                assert!(retry_after_ms >= 1);
            }
            other => panic!("expected Busy, got {other:?}"),
        }
        server.await_reply(rx).unwrap();
        server.shutdown();
    }

    #[test]
    fn requests_complete_in_lifo_mode_too() {
        // force LIFO: everything admitted must still be answered
        // exactly once (delivery, not order, is the contract)
        let server = std::sync::Arc::new(echo_server(4, 1));
        server.admission().force_mode(crate::coordinator::admission::QueueMode::Lifo);
        let mut handles = Vec::new();
        for i in 0..12 {
            let s = server.clone();
            handles.push(std::thread::spawn(move || {
                s.infer("m", vec![i as f32; 4]).unwrap()
            }));
        }
        for (i, h) in handles.into_iter().enumerate() {
            let resp = h.join().unwrap();
            assert_eq!(resp.output, vec![i as f32 * 3.0; 4]);
        }
        assert_eq!(server.stats().completed.get(), 12);
        assert_eq!(server.stats().errors.get(), 0);
    }

    #[test]
    fn per_model_stats_split_interleaved_traffic() {
        let server = echo_server(8, 1);
        for i in 0..6 {
            let model = if i % 2 == 0 { "a" } else { "b" };
            server.infer(model, vec![0.0; 4]).unwrap();
        }
        let per_model = server.stats().per_model();
        let names: Vec<&str> = per_model.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, vec!["a", "b"], "sorted snapshot");
        for (name, m) in &per_model {
            assert_eq!(m.completed.get(), 3, "{name}");
            assert_eq!(m.errors.get(), 0, "{name}");
            assert!(m.batches.get() >= 1, "{name}");
            assert_eq!(m.batched_rows.get(), 3, "{name}");
            assert_eq!(m.e2e.count(), 3, "{name}");
            assert!(m.mean_batch_size() >= 1.0, "{name}");
        }
        // aggregate and per-model views agree
        assert_eq!(server.stats().completed.get(), 6);
        assert_eq!(
            per_model.iter().map(|(_, m)| m.batched_rows.get()).sum::<u64>(),
            server.stats().batched_rows.get()
        );
        server.shutdown();
    }

    #[test]
    fn per_model_errors_are_counted() {
        let server = echo_server(4, 1);
        // dim 2 != EchoExecutor dim 4 → per-request rejection
        let _ = server.infer("bad", vec![1.0, 2.0]).unwrap_err();
        let per_model = server.stats().per_model();
        let (name, m) = &per_model[0];
        assert_eq!(name, "bad");
        assert_eq!(m.errors.get(), 1);
        assert_eq!(m.completed.get(), 0);
        server.shutdown();
    }

    #[test]
    fn kernel_thread_budget_math() {
        use crate::util::threads::num_threads;
        // explicit knob wins
        let cfg = ServerConfig { executor_threads: 2, kernel_threads: 3, ..Default::default() };
        assert_eq!(cfg.effective_kernel_threads(), 3);
        // auto: cores / workers, at least 1 — the no-oversubscription
        // invariant is workers × budget ≤ cores (modulo the ≥1 floor)
        for workers in [1, 2, 4, 1024] {
            let cfg =
                ServerConfig { executor_threads: workers, kernel_threads: 0, ..Default::default() };
            let budget = cfg.effective_kernel_threads();
            assert!(budget >= 1);
            assert!(budget == 1 || workers * budget <= num_threads(), "{workers}x{budget}");
        }
        // executor_threads 0 is clamped like Server::start clamps it
        let cfg = ServerConfig { executor_threads: 0, kernel_threads: 0, ..Default::default() };
        assert_eq!(cfg.effective_kernel_threads(), num_threads());
    }

    #[test]
    fn shutdown_drains() {
        let server = echo_server(64, 50);
        let resp = server.infer("m", vec![0.0; 4]).unwrap();
        assert_eq!(resp.output.len(), 4);
        server.shutdown(); // must not hang
    }
}
