//! The serving front-end: admission queue → batcher thread → executor
//! thread → per-request replies, with latency/throughput metrics.

use crate::coordinator::batcher::{Batch, BatchAssembler, BatchPolicy};
use crate::coordinator::request::{InferRequest, InferResponse};
use crate::coordinator::worker::BatchExecutor;
use crate::error::{Error, Result};
use crate::metrics::{Counter, Histogram, Meter};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, sync_channel, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Server wiring knobs.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub policy: BatchPolicy,
    /// admission queue bound — beyond this, `try_infer` rejects
    /// (backpressure instead of unbounded memory growth)
    pub queue_capacity: usize,
    /// bound on formed batches waiting for the executor
    pub batch_queue_capacity: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { policy: BatchPolicy::default(), queue_capacity: 1024, batch_queue_capacity: 8 }
    }
}

/// Shared serving metrics.
#[derive(Debug, Default)]
pub struct ServerStats {
    pub e2e: Histogram,
    pub exec: Histogram,
    pub queue: Histogram,
    pub completed: Counter,
    pub rejected: Counter,
    pub errors: Counter,
    pub throughput: Meter,
    pub batches: Counter,
    pub batched_rows: Counter,
}

impl ServerStats {
    /// Mean rows per executed batch.
    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.get();
        if b == 0 {
            0.0
        } else {
            self.batched_rows.get() as f64 / b as f64
        }
    }
}

/// A running coordinator.  Dropping (or calling [`Server::shutdown`])
/// closes the admission queue, drains in-flight work and joins threads.
pub struct Server {
    tx: Option<SyncSender<InferRequest>>,
    next_id: AtomicU64,
    stats: Arc<ServerStats>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Start the batcher + executor threads.  `make_executor` runs *on*
    /// the executor thread (PJRT handles are not `Send`, so the executor
    /// must be constructed there).
    pub fn start<E, F>(cfg: ServerConfig, make_executor: F) -> Result<Server>
    where
        E: BatchExecutor,
        F: FnOnce() -> Result<E> + Send + 'static,
    {
        let (tx, rx) = sync_channel::<InferRequest>(cfg.queue_capacity);
        let (btx, brx) = sync_channel::<Batch>(cfg.batch_queue_capacity);
        let stats = Arc::new(ServerStats::default());

        let policy = cfg.policy;
        let batcher = std::thread::Builder::new()
            .name("tn-batcher".into())
            .spawn(move || batcher_loop(rx, btx, policy))
            .map_err(|e| Error::Coordinator(format!("spawn batcher: {e}")))?;

        let stats_exec = stats.clone();
        let executor = std::thread::Builder::new()
            .name("tn-executor".into())
            .spawn(move || {
                let mut exec = match make_executor() {
                    Ok(e) => e,
                    Err(e) => {
                        // fail every batch that arrives
                        let msg = format!("executor init failed: {e}");
                        for batch in brx.iter() {
                            fail_batch(batch, &msg, &stats_exec);
                        }
                        return;
                    }
                };
                executor_loop(brx, &mut exec, &stats_exec);
            })
            .map_err(|e| Error::Coordinator(format!("spawn executor: {e}")))?;

        Ok(Server {
            tx: Some(tx),
            next_id: AtomicU64::new(1),
            stats,
            threads: vec![batcher, executor],
        })
    }

    pub fn stats(&self) -> &ServerStats {
        &self.stats
    }

    /// Blocking inference: enqueue and wait for the reply.
    pub fn infer(&self, model: &str, input: Vec<f32>) -> Result<InferResponse> {
        let (reply_tx, reply_rx) = channel();
        let req = InferRequest {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            model: model.to_string(),
            input,
            enqueued: Instant::now(),
            reply: reply_tx,
        };
        self.tx
            .as_ref()
            .ok_or_else(|| Error::Coordinator("server shut down".into()))?
            .send(req)
            .map_err(|_| Error::Coordinator("admission queue closed".into()))?;
        match reply_rx.recv() {
            Ok(Ok(resp)) => {
                self.stats.e2e.record(resp_latency(&resp));
                Ok(resp)
            }
            Ok(Err(msg)) => Err(Error::Coordinator(msg)),
            Err(_) => Err(Error::Coordinator("reply channel dropped".into())),
        }
    }

    /// Non-blocking admission: rejects instead of waiting when the queue
    /// is full (returns the reply receiver to await later).
    pub fn try_infer(
        &self,
        model: &str,
        input: Vec<f32>,
    ) -> Result<Receiver<std::result::Result<InferResponse, String>>> {
        let (reply_tx, reply_rx) = channel();
        let req = InferRequest {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            model: model.to_string(),
            input,
            enqueued: Instant::now(),
            reply: reply_tx,
        };
        match self.tx.as_ref().ok_or_else(|| Error::Coordinator("server shut down".into()))?.try_send(req)
        {
            Ok(()) => Ok(reply_rx),
            Err(TrySendError::Full(_)) => {
                self.stats.rejected.inc();
                Err(Error::Coordinator("admission queue full".into()))
            }
            Err(TrySendError::Disconnected(_)) => {
                Err(Error::Coordinator("admission queue closed".into()))
            }
        }
    }

    /// Await a receiver from [`Server::try_infer`].
    pub fn await_reply(
        &self,
        rx: Receiver<std::result::Result<InferResponse, String>>,
    ) -> Result<InferResponse> {
        match rx.recv() {
            Ok(Ok(resp)) => {
                self.stats.e2e.record(resp_latency(&resp));
                Ok(resp)
            }
            Ok(Err(msg)) => Err(Error::Coordinator(msg)),
            Err(_) => Err(Error::Coordinator("reply channel dropped".into())),
        }
    }

    /// Drain and join.
    pub fn shutdown(mut self) {
        self.tx.take(); // close admission queue
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.tx.take();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

fn resp_latency(resp: &InferResponse) -> Duration {
    Duration::from_micros(resp.queue_us + resp.exec_us)
}

fn batcher_loop(rx: Receiver<InferRequest>, btx: SyncSender<Batch>, policy: BatchPolicy) {
    let mut asm = BatchAssembler::new(policy);
    loop {
        let timeout = asm
            .deadline()
            .map(|d| d.saturating_duration_since(Instant::now()))
            .unwrap_or(Duration::from_millis(50));
        match rx.recv_timeout(timeout) {
            Ok(req) => {
                for batch in asm.push(req, Instant::now()) {
                    if btx.send(batch).is_err() {
                        return;
                    }
                }
                if let Some(batch) = asm.poll(Instant::now()) {
                    if btx.send(batch).is_err() {
                        return;
                    }
                }
            }
            Err(RecvTimeoutError::Timeout) => {
                if let Some(batch) = asm.poll(Instant::now()) {
                    if btx.send(batch).is_err() {
                        return;
                    }
                }
            }
            Err(RecvTimeoutError::Disconnected) => {
                // flush and exit
                if let Some(batch) = asm.flush(Instant::now()) {
                    let _ = btx.send(batch);
                }
                return;
            }
        }
    }
}

fn executor_loop(brx: Receiver<Batch>, exec: &mut dyn BatchExecutor, stats: &ServerStats) {
    for batch in brx.iter() {
        let rows = batch.requests.len();
        let dim = match exec.input_dim(&batch.model) {
            Ok(d) => d,
            Err(e) => {
                fail_batch(batch, &format!("input_dim: {e}"), stats);
                continue;
            }
        };
        // assemble the batch matrix; reject rows with bad dims individually
        let mut x = Vec::with_capacity(rows * dim);
        let mut ok_requests = Vec::with_capacity(rows);
        for req in batch.requests {
            if req.input.len() == dim {
                x.extend_from_slice(&req.input);
                ok_requests.push(req);
            } else {
                stats.errors.inc();
                let _ = req.reply.send(Err(format!(
                    "input dim {} != expected {dim}",
                    req.input.len()
                )));
            }
        }
        if ok_requests.is_empty() {
            continue;
        }
        let t0 = Instant::now();
        match exec.execute(&batch.model, &x, ok_requests.len()) {
            Ok((y, out_dim)) => {
                let exec_us = t0.elapsed().as_micros() as u64;
                stats.exec.record(t0.elapsed());
                stats.batches.inc();
                stats.batched_rows.add(ok_requests.len() as u64);
                stats.throughput.mark(ok_requests.len() as u64);
                let bs = ok_requests.len();
                for (i, req) in ok_requests.into_iter().enumerate() {
                    let queue_us = batch
                        .formed_at
                        .saturating_duration_since(req.enqueued)
                        .as_micros() as u64;
                    stats.queue.record(Duration::from_micros(queue_us));
                    let resp = InferResponse {
                        id: req.id,
                        output: y[i * out_dim..(i + 1) * out_dim].to_vec(),
                        queue_us,
                        exec_us,
                        batch_size: bs,
                    };
                    // count BEFORE replying: callers may read stats the
                    // instant their reply lands
                    stats.completed.inc();
                    let _ = req.reply.send(Ok(resp));
                }
            }
            Err(e) => {
                let msg = format!("execute failed: {e}");
                for req in ok_requests {
                    stats.errors.inc();
                    let _ = req.reply.send(Err(msg.clone()));
                }
            }
        }
    }
}

fn fail_batch(batch: Batch, msg: &str, stats: &ServerStats) {
    for req in batch.requests {
        stats.errors.inc();
        let _ = req.reply.send(Err(msg.to_string()));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::worker::EchoExecutor;

    fn echo_server(max_batch: usize, delay_ms: u64) -> Server {
        let cfg = ServerConfig {
            policy: BatchPolicy {
                max_batch,
                max_delay: Duration::from_millis(delay_ms),
            },
            ..Default::default()
        };
        Server::start(cfg, || Ok(EchoExecutor { dim: 4, scale: 3.0 })).unwrap()
    }

    #[test]
    fn single_request_roundtrip() {
        let server = echo_server(8, 1);
        let resp = server.infer("m", vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        assert_eq!(resp.output, vec![3.0, 6.0, 9.0, 12.0]);
        assert!(resp.batch_size >= 1);
        assert_eq!(server.stats().completed.get(), 1);
        server.shutdown();
    }

    #[test]
    fn concurrent_requests_get_batched() {
        let server = std::sync::Arc::new(echo_server(16, 20));
        let mut handles = Vec::new();
        for i in 0..16 {
            let s = server.clone();
            handles.push(std::thread::spawn(move || {
                s.infer("m", vec![i as f32; 4]).unwrap()
            }));
        }
        let resps: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        for (i, r) in resps.iter().enumerate() {
            assert_eq!(r.output, vec![i as f32 * 3.0; 4]);
        }
        // at least one multi-row batch must have formed
        assert!(server.stats().mean_batch_size() > 1.0, "mean batch {}", server.stats().mean_batch_size());
    }

    #[test]
    fn wrong_dim_is_rejected_individually() {
        let server = echo_server(4, 1);
        let err = server.infer("m", vec![1.0, 2.0]).unwrap_err();
        assert!(format!("{err}").contains("input dim"));
        // server still healthy
        let ok = server.infer("m", vec![0.0; 4]).unwrap();
        assert_eq!(ok.output, vec![0.0; 4]);
    }

    #[test]
    fn executor_init_failure_fails_requests() {
        let cfg = ServerConfig::default();
        let server = Server::start(cfg, || {
            Err::<EchoExecutor, _>(Error::Coordinator("boom".into()))
        })
        .unwrap();
        let err = server.infer("m", vec![0.0; 4]).unwrap_err();
        assert!(format!("{err}").contains("boom") || format!("{err}").contains("init"));
    }

    #[test]
    fn shutdown_drains() {
        let server = echo_server(64, 50);
        let resp = server.infer("m", vec![0.0; 4]).unwrap();
        assert_eq!(resp.output.len(), 4);
        server.shutdown(); // must not hang
    }
}
