//! Blocking client for the TCP serving front-end (DESIGN.md §12).
//!
//! One [`Client`] owns one connection.  [`Client::infer`] is the simple
//! request/reply call; [`Client::send`] + [`Client::recv`] expose the
//! same pipelining the transport supports — many in-flight requests per
//! connection, replies arriving in request order (the server's reactor
//! settles each connection's reply queue in order, and `recv` verifies
//! the id).
//!
//! f32 payloads travel as LE bit patterns, so a remote inference is
//! bitwise identical to the in-process call
//! (`rust/tests/remote_serving.rs` holds both against each other).

use crate::coordinator::wire::{self, ErrCode, Frame, ModelInfo, ModelStatsEntry, ReadOutcome};
use crate::error::{Error, Result};
use std::collections::VecDeque;
use std::io::{BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpStream, ToSocketAddrs};
use std::time::Duration;

/// True when `err` is the server's load-shed reply ([`ErrCode::Busy`]
/// or [`ErrCode::Quota`], i.e. admission capacity or this model's quota
/// was exhausted) — retryable, unlike real failures.  The carried
/// `retry_after_ms` (0 = none) is the server's backoff hint.
pub fn is_busy(err: &Error) -> bool {
    matches!(err, Error::Busy { .. })
}

/// A completed remote inference (the wire image of
/// [`crate::coordinator::InferResponse`], with server-side timings).
#[derive(Clone, Debug)]
pub struct RemoteResponse {
    pub id: u64,
    pub output: Vec<f32>,
    /// server-side enqueue → execution start
    pub queue_us: u64,
    /// server-side batch execution time
    pub exec_us: u64,
    /// how many requests shared the batch
    pub batch_size: usize,
}

/// Counter snapshot returned by [`Client::stats`].  `per_model` breaks
/// the aggregates down by model name (sorted), so a remote operator can
/// read each model's batch efficiency
/// ([`ModelStatsEntry::mean_batch_size`]) straight off the wire.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct RemoteStats {
    pub completed: u64,
    pub rejected: u64,
    pub errors: u64,
    pub failed_workers: u64,
    pub batches: u64,
    pub batched_rows: u64,
    /// subset of `rejected` shed against a per-model quota (v3)
    pub quota_shed: u64,
    pub per_model: Vec<ModelStatsEntry>,
}

/// One blocking connection to a `tensornet serve --listen` front-end.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    peer: SocketAddr,
    next_id: u64,
    /// ids of sent-but-unanswered `Infer`s, oldest first (replies are
    /// in request order per connection)
    in_flight: VecDeque<u64>,
    /// when set, `recv` and the control calls give up after this long
    /// without reply bytes instead of blocking forever
    read_timeout: Option<Duration>,
    /// reused encode buffer: after the first few sends it has grown to
    /// working-set size and every outgoing frame serializes with zero
    /// heap allocation (`Frame::encode_into`)
    ebuf: Vec<u8>,
}

impl Client {
    /// Connect to `addr` (as printed by `serve --listen`, e.g.
    /// `127.0.0.1:7070`).  No timeouts: calls block until the server
    /// answers or closes.  Use [`Client::connect_timeout`] when the
    /// server may be unreachable or hung.
    pub fn connect(addr: &str) -> Result<Client> {
        let stream =
            TcpStream::connect(addr).map_err(|e| Error::Net(format!("connect {addr}: {e}")))?;
        Client::from_stream(stream, addr)
    }

    /// Like [`Client::connect`] but bounded: connection establishment
    /// gives up after `timeout`, and the same bound is installed as the
    /// read timeout for every subsequent reply wait (a hung server
    /// surfaces as [`Error::Net`] instead of blocking the caller
    /// forever).
    pub fn connect_timeout(addr: &str, timeout: Duration) -> Result<Client> {
        // TcpStream::connect_timeout wants a resolved SocketAddr; try
        // every resolution like TcpStream::connect does
        let addrs: Vec<SocketAddr> = addr
            .to_socket_addrs()
            .map_err(|e| Error::Net(format!("resolve {addr}: {e}")))?
            .collect();
        if addrs.is_empty() {
            return Err(Error::Net(format!("resolve {addr}: no addresses")));
        }
        let mut last_err = None;
        let mut stream = None;
        for sa in &addrs {
            match TcpStream::connect_timeout(sa, timeout) {
                Ok(s) => {
                    stream = Some(s);
                    break;
                }
                Err(e) => last_err = Some(e),
            }
        }
        let stream = match stream {
            Some(s) => s,
            None => {
                let e = last_err.expect("at least one address was tried");
                return Err(Error::Net(format!("connect {addr} (timeout {timeout:?}): {e}")));
            }
        };
        let mut client = Client::from_stream(stream, addr)?;
        client.set_read_timeout(Some(timeout))?;
        Ok(client)
    }

    fn from_stream(stream: TcpStream, addr: &str) -> Result<Client> {
        let _ = stream.set_nodelay(true);
        let peer = stream
            .peer_addr()
            .map_err(|e| Error::Net(format!("peer_addr ({addr}): {e}")))?;
        let write_half =
            stream.try_clone().map_err(|e| Error::Net(format!("clone stream: {e}")))?;
        Ok(Client {
            reader: BufReader::new(stream),
            writer: BufWriter::new(write_half),
            peer,
            next_id: 1,
            in_flight: VecDeque::new(),
            read_timeout: None,
            ebuf: Vec::new(),
        })
    }

    /// Install (or clear, with `None`) a bound on how long a reply wait
    /// may block.  When it fires, the pending call fails with
    /// [`Error::Net`]; the connection's framing state is then unknown
    /// (the reply may arrive later, mid-stream), so callers should
    /// reconnect rather than keep using this client.
    pub fn set_read_timeout(&mut self, timeout: Option<Duration>) -> Result<()> {
        self.reader
            .get_ref()
            .set_read_timeout(timeout)
            .map_err(|e| Error::Net(format!("set_read_timeout: {e}")))?;
        self.read_timeout = timeout;
        Ok(())
    }

    pub fn peer_addr(&self) -> SocketAddr {
        self.peer
    }

    /// Sent-but-unanswered request count on this connection.
    pub fn in_flight(&self) -> usize {
        self.in_flight.len()
    }

    /// Pipelined enqueue: send one `Infer` without waiting for its
    /// reply.  Returns the request id; collect replies with
    /// [`Client::recv`] (in send order).
    pub fn send(&mut self, model: &str, input: &[f32]) -> Result<u64> {
        let id = self.next_id;
        self.next_id += 1;
        let frame = Frame::Infer { id, model: model.to_string(), input: input.to_vec() };
        self.write_frame(&frame)?;
        self.in_flight.push_back(id);
        Ok(id)
    }

    /// Serialize `frame` through the reused encode buffer and flush it.
    fn write_frame(&mut self, frame: &Frame) -> Result<()> {
        self.ebuf.clear();
        frame.encode_into(&mut self.ebuf)?;
        self.writer
            .write_all(&self.ebuf)
            .map_err(|e| Error::Net(format!("write frame: {e}")))?;
        self.writer.flush().map_err(|e| Error::Net(format!("flush: {e}")))
    }

    /// Await the oldest in-flight request's reply.  A `Busy` reply (load
    /// shed) surfaces as [`Error::Busy`] (see [`is_busy`]) — the
    /// connection stays usable; retry later.
    pub fn recv(&mut self) -> Result<RemoteResponse> {
        let want = self
            .in_flight
            .pop_front()
            .ok_or_else(|| Error::Net("recv with no request in flight".into()))?;
        match self.read_reply()? {
            Frame::InferOk { id, queue_us, exec_us, batch_size, output } => {
                if id != want {
                    return Err(Error::Wire(format!(
                        "out-of-order reply: got id {id}, expected {want}"
                    )));
                }
                Ok(RemoteResponse { id, output, queue_us, exec_us, batch_size: batch_size as usize })
            }
            Frame::InferErr { id, code, message, retry_after_ms } => {
                if id != 0 && id != want {
                    return Err(Error::Wire(format!(
                        "out-of-order error reply: got id {id}, expected {want}"
                    )));
                }
                match code {
                    // typed, so callers classify load shedding without
                    // parsing the display string (`is_busy`); both shed
                    // kinds are retryable — the wire code plus server
                    // stats carry the capacity-vs-quota distinction
                    ErrCode::Busy | ErrCode::Quota => {
                        Err(Error::Busy { message, retry_after_ms })
                    }
                    ErrCode::BadRequest => Err(Error::Wire(format!("rejected: {message}"))),
                    ErrCode::Exec => Err(Error::Coordinator(message)),
                }
            }
            other => Err(Error::Wire(format!("expected an inference reply, got {other:?}"))),
        }
    }

    /// Blocking request/reply inference.
    pub fn infer(&mut self, model: &str, input: &[f32]) -> Result<RemoteResponse> {
        if !self.in_flight.is_empty() {
            return Err(Error::Net(format!(
                "infer with {} pipelined requests in flight — drain with recv first",
                self.in_flight.len()
            )));
        }
        self.send(model, input)?;
        self.recv()
    }

    /// Snapshot the server's counters (aggregate + per-model).
    pub fn stats(&mut self) -> Result<RemoteStats> {
        self.control(Frame::Stats)?;
        match self.read_reply()? {
            Frame::StatsReply {
                completed,
                rejected,
                errors,
                failed_workers,
                batches,
                batched_rows,
                quota_shed,
                per_model,
            } => Ok(RemoteStats {
                completed,
                rejected,
                errors,
                failed_workers,
                batches,
                batched_rows,
                quota_shed,
                per_model,
            }),
            other => Err(Error::Wire(format!("expected StatsReply, got {other:?}"))),
        }
    }

    /// The served model lineup (name + per-row I/O dims).
    pub fn list_models(&mut self) -> Result<Vec<ModelInfo>> {
        self.control(Frame::ListModels)?;
        match self.read_reply()? {
            Frame::ModelList { models } => Ok(models),
            other => Err(Error::Wire(format!("expected ModelList, got {other:?}"))),
        }
    }

    /// Ask the server process to shut down; returns once acknowledged.
    pub fn shutdown_server(&mut self) -> Result<()> {
        self.control(Frame::Shutdown)?;
        match self.read_reply()? {
            Frame::ShutdownOk => Ok(()),
            other => Err(Error::Wire(format!("expected ShutdownOk, got {other:?}"))),
        }
    }

    fn control(&mut self, frame: Frame) -> Result<()> {
        if !self.in_flight.is_empty() {
            return Err(Error::Net(format!(
                "control frame with {} pipelined requests in flight — drain with recv first",
                self.in_flight.len()
            )));
        }
        self.write_frame(&frame)
    }

    fn read_reply(&mut self) -> Result<Frame> {
        // the shared framed reader treats a socket-level timeout as a
        // "should I stop?" poll; with a read timeout installed the
        // answer is always yes — one timeout means give up
        let timed = self.read_timeout.is_some();
        match wire::read_frame(&mut self.reader, || timed)? {
            ReadOutcome::Frame(f) => Ok(f),
            ReadOutcome::Eof => Err(Error::Net("server closed the connection".into())),
            ReadOutcome::Stopped => Err(Error::Net(format!(
                "read timed out after {:?} — connection state unknown, reconnect",
                self.read_timeout.expect("Stopped only with a timeout installed")
            ))),
        }
    }
}
