//! Adaptive admission control (S14 in DESIGN.md §14): ticket-based
//! in-flight bounding with dynamic capacity, overload-mode queueing and
//! per-model fairness.
//!
//! The old admission story was a fixed-depth `sync_channel` shared by
//! every model and caller: the only knob was reject-when-full, a burst
//! on one hot model starved every other tenant, and under sustained
//! overload FIFO ordering guaranteed every admitted request ate the
//! full queue delay before being served.  This module replaces the
//! bounded channel with an explicit [`AdmissionController`]:
//!
//! * **Tickets.** Every request acquires an [`AdmissionTicket`] before
//!   entering the (now unbounded) batch pipeline and releases it on
//!   drop — after the reply is sent, after a failure, or when the
//!   request is discarded at shutdown.  RAII makes release exactly-once
//!   on every path, and the outstanding-ticket count bounds the whole
//!   pipeline (queue + batcher backlog + executing), not just the front
//!   channel.
//! * **Dynamic capacity.** With `--latency-target-ms` set, the ticket
//!   ceiling becomes a measured quantity: ticket held-times (enqueue →
//!   reply, the server-side e2e) feed a sliding window; every
//!   `RESIZE_INTERVAL` the controller grows capacity additively
//!   (`+max(1, cap/8)`) while the window p95 is under target and halves
//!   it when the p95 overshoots (AIMD, like TCP).  Default off ⇒
//!   capacity is exactly the configured queue depth, reproducing the
//!   fixed bounded queue.
//! * **FIFO→LIFO under sustained overload.** When admission has been
//!   saturated (admitted == capacity) continuously for
//!   `overload_after`, the batcher switches to newest-first scheduling:
//!   for a queue that is doomed anyway, LIFO bounds the tail latency of
//!   the requests that *do* complete instead of timing everyone out
//!   equally.  Hysteresis: back to FIFO once admitted ≤ capacity/2.
//! * **Per-model quotas.** `--quota MODEL=N` reserves N tickets for a
//!   model; the remaining `capacity − Σ reservations` form a free pool
//!   any tenant may borrow from.  A quota'd model sheds (typed
//!   [`ShedKind::Quota`]) only once its reservation *and* the free pool
//!   are exhausted; an unquota'd model sheds [`ShedKind::Capacity`]
//!   when the free pool alone is gone.  Capacity never resizes below
//!   `Σ reservations`, so background tenants keep their guaranteed
//!   share no matter how hard a hot tenant pushes.
//!
//! Sheds carry a retry-after hint (the window's median held-time) that
//! travels in the wire `Busy` reply so remote clients back off for
//! roughly one service time instead of hot-looping.
//!
//! Modeled on the chroma `AdmissionControllerImpl` exemplar
//! (SNIPPETS.md §3): same ticket/release shape, same FIFO/LIFO mode
//! flag; the waiter ring is replaced by a `Condvar` (blocking callers
//! are in-process threads, not async tasks) and the rate controller by
//! the latency-target AIMD above.

use std::fmt;
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

/// Sliding window of ticket held-times (µs) the capacity controller and
/// retry hints are computed from.
const WINDOW: usize = 256;
/// Minimum samples before a resize decision (a p95 of three points is
/// noise).
const MIN_SAMPLES: usize = 16;
/// Capacity is re-evaluated at most this often.
const RESIZE_INTERVAL: Duration = Duration::from_millis(500);
/// Retry hint when no held-time samples exist yet.
const DEFAULT_RETRY_MS: u32 = 5;

/// Admission knobs, carried inside `ServerConfig`.  The default is
/// behaviorally identical to the pre-controller fixed bounded queue:
/// no resizing, no quotas, FIFO unless saturated for 2 s straight.
#[derive(Clone, Debug)]
pub struct AdmissionConfig {
    /// e2e latency target (ms) the capacity controller steers toward;
    /// `0` disables resizing (capacity stays the configured depth).
    pub latency_target_ms: u64,
    /// resize floor; `0` = auto (`max(1, initial/8)`, never below
    /// `Σ quotas`)
    pub min_capacity: usize,
    /// resize ceiling; `0` = auto (`initial × 4`)
    pub max_capacity: usize,
    /// how long admission must stay saturated before the batcher flips
    /// to newest-first (LIFO) scheduling
    pub overload_after: Duration,
    /// per-model reserved tickets: `(model, N)`; duplicates sum
    pub quotas: Vec<(String, usize)>,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        AdmissionConfig {
            latency_target_ms: 0,
            min_capacity: 0,
            max_capacity: 0,
            overload_after: Duration::from_secs(2),
            quotas: Vec::new(),
        }
    }
}

/// Scheduling order the batcher drains pending groups in (mirrors the
/// chroma exemplar's `FIFO_MODE`/`LIFO_MODE` flag).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum QueueMode {
    /// oldest-first (default): fair, optimal when the queue drains
    Fifo = 0,
    /// newest-first (sustained overload): bounds the tail latency of
    /// the requests that will complete, because the backlog is doomed
    /// to shed anyway
    Lifo = 1,
}

impl QueueMode {
    fn from_u8(v: u8) -> QueueMode {
        if v == QueueMode::Lifo as u8 { QueueMode::Lifo } else { QueueMode::Fifo }
    }
}

/// Why an admission attempt was shed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShedKind {
    /// global overload: the free ticket pool is exhausted
    Capacity,
    /// this model used up its reservation and the free pool — other
    /// tenants' reservations are protecting them from it
    Quota,
}

/// A shed decision: what kind, and how long the caller should wait
/// before retrying (≈ one observed service time).
#[derive(Clone, Copy, Debug)]
pub struct ShedInfo {
    pub kind: ShedKind,
    pub retry_after_ms: u32,
}

/// Which pool a ticket was admitted from — determines which counter
/// its release decrements.
#[derive(Clone, Copy, Debug)]
enum Pool {
    /// the shared borrowable pool (`capacity − Σ reservations`)
    Free,
    /// slot index into `Inner::slots`: a quota'd model's reservation
    Reserved(usize),
}

/// One quota'd model's reservation state.
#[derive(Debug)]
struct Slot {
    name: String,
    quota: usize,
    /// tickets currently held out of THIS reservation (borrowed free
    /// tickets count in `Inner::free_used` instead)
    admitted: usize,
}

#[derive(Debug)]
struct Inner {
    /// current ticket ceiling (fixed unless a latency target is set)
    capacity: usize,
    /// total outstanding tickets (= free_used + Σ slots.admitted)
    admitted: usize,
    /// outstanding tickets from the free pool
    free_used: usize,
    slots: Vec<Slot>,
    reserved_total: usize,
    /// last instant admission was observed below capacity — the
    /// overload clock for the FIFO→LIFO flip
    last_unsaturated: Instant,
    /// ring of recent ticket held-times in µs
    window: Vec<u64>,
    wpos: usize,
    wlen: usize,
    last_resize: Instant,
    /// provenance for bench entries / the serve summary
    cap_min: usize,
    cap_max: usize,
    mode_flips: u64,
}

/// Point-in-time view of the controller, for stats printing and bench
/// provenance.
#[derive(Clone, Copy, Debug)]
pub struct AdmissionSnapshot {
    pub capacity: usize,
    pub admitted: usize,
    pub mode: QueueMode,
    /// lowest/highest capacity ever reached (== capacity when resizing
    /// is off)
    pub capacity_min: usize,
    pub capacity_max: usize,
    /// total FIFO↔LIFO transitions
    pub mode_flips: u64,
}

/// The controller.  All admission paths (blocking `infer`, non-blocking
/// `admit`, the TCP front-end) funnel through one instance per
/// `Server`.
pub struct AdmissionController {
    inner: Mutex<Inner>,
    /// signaled on every release so blocked `admit_blocking` callers
    /// re-check
    available: Condvar,
    /// current `QueueMode`, readable without the lock (the batcher
    /// polls it on every drain)
    mode: AtomicU8,
    /// bumped on every ticket release; the net reactor skips its idle
    /// doze when this moved since the last sweep (a release means a
    /// reply is about to need settling)
    release_epoch: AtomicU64,
    latency_target: Option<Duration>,
    overload_after: Duration,
    cap_floor: usize,
    cap_ceil: usize,
}

impl fmt::Debug for AdmissionController {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let snap = self.snapshot();
        write!(f, "AdmissionController({snap:?})")
    }
}

impl AdmissionController {
    /// Build a controller with `initial` tickets (clamped up to
    /// `Σ quotas` so every reservation is honorable, and to ≥ 1).
    pub fn new(initial: usize, cfg: &AdmissionConfig) -> Arc<AdmissionController> {
        // fold duplicate quota names by summing
        let mut slots: Vec<Slot> = Vec::new();
        for (name, n) in &cfg.quotas {
            match slots.iter_mut().find(|s| s.name == *name) {
                Some(s) => s.quota += n,
                None => slots.push(Slot { name: name.clone(), quota: *n, admitted: 0 }),
            }
        }
        let reserved_total: usize = slots.iter().map(|s| s.quota).sum();
        let capacity = initial.max(reserved_total).max(1);
        let cap_floor = if cfg.min_capacity > 0 { cfg.min_capacity } else { (capacity / 8).max(1) }
            .max(reserved_total.max(1));
        let cap_ceil = if cfg.max_capacity > 0 { cfg.max_capacity } else { capacity * 4 }
            .max(cap_floor)
            .max(capacity);
        let now = Instant::now();
        Arc::new(AdmissionController {
            inner: Mutex::new(Inner {
                capacity,
                admitted: 0,
                free_used: 0,
                slots,
                reserved_total,
                last_unsaturated: now,
                window: vec![0; WINDOW],
                wpos: 0,
                wlen: 0,
                last_resize: now,
                cap_min: capacity,
                cap_max: capacity,
                mode_flips: 0,
            }),
            available: Condvar::new(),
            mode: AtomicU8::new(QueueMode::Fifo as u8),
            release_epoch: AtomicU64::new(0),
            latency_target: (cfg.latency_target_ms > 0)
                .then(|| Duration::from_millis(cfg.latency_target_ms)),
            overload_after: cfg.overload_after,
            cap_floor,
            cap_ceil,
        })
    }

    fn lock(&self) -> MutexGuard<'_, Inner> {
        match self.inner.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        }
    }

    /// Non-blocking admission: a ticket, or a typed shed with a retry
    /// hint.  Every attempt — shed or not — advances the overload
    /// clock, so a storm of rejected arrivals still flips the mode.
    pub fn try_admit(self: &Arc<Self>, model: &str) -> std::result::Result<AdmissionTicket, ShedInfo> {
        let mut inner = self.lock();
        let now = Instant::now();
        let res = Self::admit_inner(&mut inner, model);
        self.update_mode(&mut inner, now);
        match res {
            Ok(pool) => {
                Ok(AdmissionTicket { ctl: self.clone(), pool, acquired: now })
            }
            Err(kind) => {
                Err(ShedInfo { kind, retry_after_ms: Self::retry_hint_ms(&inner) })
            }
        }
    }

    /// Blocking admission: wait (forever — mirrors the old blocking
    /// send into the bounded queue) until a ticket frees up.  Used by
    /// in-process `Server::infer`; shutdown resolves naturally because
    /// draining requests release their tickets on drop.
    pub fn admit_blocking(self: &Arc<Self>, model: &str) -> AdmissionTicket {
        let mut inner = self.lock();
        loop {
            let now = Instant::now();
            let res = Self::admit_inner(&mut inner, model);
            self.update_mode(&mut inner, now);
            match res {
                Ok(pool) => {
                    return AdmissionTicket { ctl: self.clone(), pool, acquired: now };
                }
                Err(_) => {
                    inner = match self.available.wait(inner) {
                        Ok(g) => g,
                        Err(poisoned) => poisoned.into_inner(),
                    };
                }
            }
        }
    }

    /// The admission decision proper.  Quota'd models draw from their
    /// reservation first, then borrow free tickets; unquota'd models
    /// only ever use the free pool — that asymmetry is the fairness
    /// guarantee.
    fn admit_inner(inner: &mut Inner, model: &str) -> std::result::Result<Pool, ShedKind> {
        // free pool = capacity − Σ reservations (capacity never resizes
        // below reserved_total, so this cannot underflow by policy;
        // saturating_sub guards the force_capacity test hook)
        let free_pool = inner.capacity.saturating_sub(inner.reserved_total);
        match inner.slots.iter().position(|s| s.name == model) {
            Some(i) => {
                if inner.slots[i].admitted < inner.slots[i].quota {
                    inner.slots[i].admitted += 1;
                    inner.admitted += 1;
                    Ok(Pool::Reserved(i))
                } else if inner.free_used < free_pool {
                    inner.free_used += 1;
                    inner.admitted += 1;
                    Ok(Pool::Free)
                } else {
                    Err(ShedKind::Quota)
                }
            }
            None => {
                if inner.free_used < free_pool {
                    inner.free_used += 1;
                    inner.admitted += 1;
                    Ok(Pool::Free)
                } else {
                    Err(ShedKind::Capacity)
                }
            }
        }
    }

    /// Release path (only via `AdmissionTicket::drop`): return the
    /// ticket to its pool, feed the held-time window, re-evaluate mode
    /// and capacity, wake waiters and the reactor.
    fn release(&self, pool: Pool, acquired: Instant) {
        let now = Instant::now();
        {
            let mut inner = self.lock();
            match pool {
                Pool::Free => inner.free_used = inner.free_used.saturating_sub(1),
                Pool::Reserved(i) => {
                    inner.slots[i].admitted = inner.slots[i].admitted.saturating_sub(1)
                }
            }
            inner.admitted = inner.admitted.saturating_sub(1);
            let held_us = now.duration_since(acquired).as_micros() as u64;
            let wpos = inner.wpos;
            inner.window[wpos] = held_us;
            inner.wpos = (wpos + 1) % WINDOW;
            inner.wlen = (inner.wlen + 1).min(WINDOW);
            self.update_mode(&mut inner, now);
            self.maybe_resize(&mut inner, now, false);
        }
        self.release_epoch.fetch_add(1, Ordering::Release);
        // notify_all, not notify_one: a freed reserved ticket is only
        // usable by its own model, so the "wrong" single waiter waking
        // and going back to sleep would strand the right one
        self.available.notify_all();
    }

    /// Overload-mode state machine.  Enter LIFO after `overload_after`
    /// of continuous saturation; leave once admission drains to half
    /// capacity (hysteresis — a queue oscillating at the brim doesn't
    /// thrash the order).
    fn update_mode(&self, inner: &mut Inner, now: Instant) {
        let saturated = inner.admitted >= inner.capacity;
        if !saturated {
            inner.last_unsaturated = now;
        }
        match QueueMode::from_u8(self.mode.load(Ordering::Relaxed)) {
            QueueMode::Fifo => {
                if saturated
                    && now.duration_since(inner.last_unsaturated) >= self.overload_after
                {
                    self.mode.store(QueueMode::Lifo as u8, Ordering::Relaxed);
                    inner.mode_flips += 1;
                }
            }
            QueueMode::Lifo => {
                if inner.admitted * 2 <= inner.capacity {
                    self.mode.store(QueueMode::Fifo as u8, Ordering::Relaxed);
                    inner.mode_flips += 1;
                    inner.last_unsaturated = now;
                }
            }
        }
    }

    /// AIMD capacity controller: halve when the held-time p95
    /// overshoots the target, grow `+max(1, cap/8)` when it is under.
    /// The window is cleared after each decision so the next one is
    /// based on post-change observations only.
    fn maybe_resize(&self, inner: &mut Inner, now: Instant, forced: bool) {
        let target = match self.latency_target {
            Some(t) => t,
            None => return,
        };
        if !forced && now.duration_since(inner.last_resize) < RESIZE_INTERVAL {
            return;
        }
        if inner.wlen < MIN_SAMPLES {
            return;
        }
        let mut sorted = inner.window[..inner.wlen].to_vec();
        sorted.sort_unstable();
        let p95 = sorted[(sorted.len() * 95 / 100).min(sorted.len() - 1)];
        if p95 > target.as_micros() as u64 {
            inner.capacity = (inner.capacity / 2).max(self.cap_floor);
        } else {
            let grow = (inner.capacity / 8).max(1);
            inner.capacity = (inner.capacity + grow).min(self.cap_ceil);
        }
        inner.cap_min = inner.cap_min.min(inner.capacity);
        inner.cap_max = inner.cap_max.max(inner.capacity);
        inner.wpos = 0;
        inner.wlen = 0;
        inner.last_resize = now;
    }

    /// Median observed held-time as the shed retry hint, clamped to
    /// [1, 1000] ms; `DEFAULT_RETRY_MS` before any sample exists.
    fn retry_hint_ms(inner: &Inner) -> u32 {
        if inner.wlen == 0 {
            return DEFAULT_RETRY_MS;
        }
        let mut sorted = inner.window[..inner.wlen].to_vec();
        sorted.sort_unstable();
        let p50_us = sorted[sorted.len() / 2];
        (p50_us / 1000).clamp(1, 1000) as u32
    }

    /// Current scheduling order — lock-free; the batcher reads this on
    /// every drain pass.
    pub fn mode(&self) -> QueueMode {
        QueueMode::from_u8(self.mode.load(Ordering::Relaxed))
    }

    /// Monotonic count of ticket releases.  The net reactor compares
    /// this across sweeps: movement means replies are settling, so it
    /// skips the idle doze for one pass.
    pub fn release_epoch(&self) -> u64 {
        self.release_epoch.load(Ordering::Acquire)
    }

    pub fn snapshot(&self) -> AdmissionSnapshot {
        let inner = self.lock();
        AdmissionSnapshot {
            capacity: inner.capacity,
            admitted: inner.admitted,
            mode: self.mode(),
            capacity_min: inner.cap_min,
            capacity_max: inner.cap_max,
            mode_flips: inner.mode_flips,
        }
    }

    /// Ops/test hook: pin capacity (clamped to the floor — quotas stay
    /// honorable).  Tickets already out stay out; a shrink below the
    /// outstanding count just blocks new admissions until drained.
    pub fn force_capacity(&self, cap: usize) {
        let mut inner = self.lock();
        inner.capacity = cap.max(self.cap_floor);
        inner.cap_min = inner.cap_min.min(inner.capacity);
        inner.cap_max = inner.cap_max.max(inner.capacity);
        drop(inner);
        self.available.notify_all();
    }

    /// Ops/test hook: pin the queue mode (counted as a flip when it
    /// changes).
    pub fn force_mode(&self, mode: QueueMode) {
        let mut inner = self.lock();
        if self.mode() != mode {
            self.mode.store(mode as u8, Ordering::Relaxed);
            inner.mode_flips += 1;
            inner.last_unsaturated = Instant::now();
        }
    }

    /// Test hook: force a resize evaluation now, ignoring
    /// `RESIZE_INTERVAL` (still requires `MIN_SAMPLES` and a target).
    #[doc(hidden)]
    pub fn resize_now(&self) {
        let mut inner = self.lock();
        self.maybe_resize(&mut inner, Instant::now(), true);
    }
}

/// An admitted request's capacity claim.  Carried inside the
/// `InferRequest` through the batcher and executor; dropping it — after
/// the reply send, on failure, or when the request is discarded at
/// shutdown — releases the claim exactly once.
pub struct AdmissionTicket {
    ctl: Arc<AdmissionController>,
    pool: Pool,
    acquired: Instant,
}

impl fmt::Debug for AdmissionTicket {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "AdmissionTicket({:?})", self.pool)
    }
}

impl Drop for AdmissionTicket {
    fn drop(&mut self) {
        self.ctl.release(self.pool, self.acquired);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_flip() -> AdmissionConfig {
        AdmissionConfig { overload_after: Duration::from_millis(10), ..Default::default() }
    }

    #[test]
    fn default_is_fixed_capacity_fifo() {
        let ctl = AdmissionController::new(4, &AdmissionConfig::default());
        let snap = ctl.snapshot();
        assert_eq!(snap.capacity, 4);
        assert_eq!(snap.capacity_min, 4);
        assert_eq!(snap.capacity_max, 4);
        assert_eq!(snap.mode, QueueMode::Fifo);
        assert_eq!(snap.mode_flips, 0);
        // resizing disabled: even a forced evaluation never moves it
        let _tickets: Vec<_> = (0..4).map(|_| ctl.try_admit("m").unwrap()).collect();
        ctl.resize_now();
        assert_eq!(ctl.snapshot().capacity, 4);
    }

    #[test]
    fn tickets_release_on_drop() {
        let ctl = AdmissionController::new(4, &AdmissionConfig::default());
        let mut held: Vec<_> = (0..4).map(|_| ctl.try_admit("m").unwrap()).collect();
        let shed = ctl.try_admit("m").unwrap_err();
        assert_eq!(shed.kind, ShedKind::Capacity);
        assert!(shed.retry_after_ms >= 1);
        held.pop(); // drop one → one slot frees
        let again = ctl.try_admit("m").unwrap();
        assert_eq!(ctl.snapshot().admitted, 4);
        drop(again);
        drop(held);
        assert_eq!(ctl.snapshot().admitted, 0);
    }

    #[test]
    fn quota_reserves_and_borrows() {
        // capacity 4, "a" reserves 2 → free pool 2
        let cfg = AdmissionConfig {
            quotas: vec![("a".into(), 2)],
            ..Default::default()
        };
        let ctl = AdmissionController::new(4, &cfg);
        // an unquota'd tenant can only ever take the free pool
        let b1 = ctl.try_admit("b").unwrap();
        let b2 = ctl.try_admit("b").unwrap();
        let shed = ctl.try_admit("b").unwrap_err();
        assert_eq!(shed.kind, ShedKind::Capacity, "free pool gone, reservation untouchable");
        // "a" still has its full reservation
        let a1 = ctl.try_admit("a").unwrap();
        let a2 = ctl.try_admit("a").unwrap();
        let shed = ctl.try_admit("a").unwrap_err();
        assert_eq!(shed.kind, ShedKind::Quota, "reservation + free pool both exhausted");
        // a freed FREE ticket is borrowable by the quota'd model
        drop(b1);
        let a3 = ctl.try_admit("a").unwrap();
        assert_eq!(ctl.snapshot().admitted, 4);
        drop((a1, a2, a3, b2));
        assert_eq!(ctl.snapshot().admitted, 0);
    }

    #[test]
    fn reserved_release_returns_to_the_reservation() {
        let cfg = AdmissionConfig { quotas: vec![("a".into(), 1)], ..Default::default() };
        let ctl = AdmissionController::new(2, &cfg);
        let a1 = ctl.try_admit("a").unwrap(); // reserved
        let b1 = ctl.try_admit("b").unwrap(); // free
        assert_eq!(ctl.try_admit("b").unwrap_err().kind, ShedKind::Capacity);
        drop(a1); // frees the RESERVATION, not the free pool
        assert_eq!(
            ctl.try_admit("b").unwrap_err().kind,
            ShedKind::Capacity,
            "a released reserved ticket must not leak into the free pool"
        );
        let a2 = ctl.try_admit("a").unwrap();
        drop((a2, b1));
    }

    #[test]
    fn capacity_clamps_to_reservations() {
        let cfg = AdmissionConfig { quotas: vec![("a".into(), 8)], ..Default::default() };
        let ctl = AdmissionController::new(2, &cfg);
        assert_eq!(ctl.snapshot().capacity, 8, "capacity grows to honor reservations");
        ctl.force_capacity(1);
        assert_eq!(ctl.snapshot().capacity, 8, "floor keeps quotas honorable");
    }

    #[test]
    fn blocking_admit_waits_for_a_release() {
        let ctl = AdmissionController::new(1, &AdmissionConfig::default());
        let first = ctl.try_admit("m").unwrap();
        let ctl2 = ctl.clone();
        let flag = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let flag2 = flag.clone();
        let waiter = std::thread::spawn(move || {
            let t = ctl2.admit_blocking("m");
            flag2.store(true, Ordering::SeqCst);
            drop(t);
        });
        std::thread::sleep(Duration::from_millis(30));
        assert!(!flag.load(Ordering::SeqCst), "must block while the ticket is held");
        drop(first);
        waiter.join().unwrap();
        assert!(flag.load(Ordering::SeqCst));
        assert_eq!(ctl.snapshot().admitted, 0);
    }

    #[test]
    fn sustained_saturation_flips_to_lifo_and_back() {
        let ctl = AdmissionController::new(2, &quick_flip());
        let held: Vec<_> = (0..2).map(|_| ctl.try_admit("m").unwrap()).collect();
        assert!(ctl.try_admit("m").is_err());
        assert_eq!(ctl.mode(), QueueMode::Fifo, "not saturated long enough yet");
        std::thread::sleep(Duration::from_millis(20));
        // the next attempt observes >10ms of continuous saturation
        assert!(ctl.try_admit("m").is_err());
        assert_eq!(ctl.mode(), QueueMode::Lifo);
        assert_eq!(ctl.snapshot().mode_flips, 1);
        // draining to ≤ half capacity flips back (releases drive it)
        drop(held);
        assert_eq!(ctl.mode(), QueueMode::Fifo);
        assert_eq!(ctl.snapshot().mode_flips, 2);
    }

    #[test]
    fn resize_shrinks_on_overshoot_and_grows_under_target() {
        // target 1ms, every ticket held ~3ms → p95 overshoots → halve
        let cfg = AdmissionConfig { latency_target_ms: 1, ..Default::default() };
        let ctl = AdmissionController::new(8, &cfg);
        for _ in 0..MIN_SAMPLES {
            let t = ctl.try_admit("m").unwrap();
            std::thread::sleep(Duration::from_millis(3));
            drop(t);
        }
        ctl.resize_now();
        let snap = ctl.snapshot();
        assert_eq!(snap.capacity, 4, "p95 over target halves capacity");
        assert_eq!(snap.capacity_min, 4);

        // huge target, instant releases → additive growth, ceiling 4×
        let cfg = AdmissionConfig { latency_target_ms: 60_000, ..Default::default() };
        let ctl = AdmissionController::new(8, &cfg);
        for _ in 0..MIN_SAMPLES {
            drop(ctl.try_admit("m").unwrap());
        }
        ctl.resize_now();
        let snap = ctl.snapshot();
        assert_eq!(snap.capacity, 9, "additive increase: +max(1, 8/8)");
        assert_eq!(snap.capacity_max, 9);
        assert_eq!(snap.capacity_min, 8);
    }

    #[test]
    fn retry_hint_tracks_observed_service_time() {
        let ctl = AdmissionController::new(1, &AdmissionConfig::default());
        let held = ctl.try_admit("m").unwrap();
        // no samples yet → default hint
        assert_eq!(ctl.try_admit("m").unwrap_err().retry_after_ms, DEFAULT_RETRY_MS);
        drop(held);
        let t = ctl.try_admit("m").unwrap();
        std::thread::sleep(Duration::from_millis(8));
        drop(t);
        let held = ctl.try_admit("m").unwrap();
        let hint = ctl.try_admit("m").unwrap_err().retry_after_ms;
        assert!((1..=1000).contains(&hint), "hint {hint} out of range");
        assert!(hint >= 4, "median of one ~8ms sample should hint ≥4ms, got {hint}");
        drop(held);
    }

    #[test]
    fn force_mode_counts_flips() {
        let ctl = AdmissionController::new(4, &AdmissionConfig::default());
        ctl.force_mode(QueueMode::Lifo);
        ctl.force_mode(QueueMode::Lifo); // no-op
        ctl.force_mode(QueueMode::Fifo);
        assert_eq!(ctl.snapshot().mode_flips, 2);
    }
}
