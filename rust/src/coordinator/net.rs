//! TCP front-end over the coordinator (DESIGN.md §12): an accept loop
//! plus a reader/writer thread pair per connection, speaking the
//! [`crate::coordinator::wire`] protocol and feeding the *same* bounded
//! admission queue as in-process callers ([`Server::admit`]).
//!
//! ```text
//! tn-net-accept ──► tn-net-conn (reader)  ──admit──►  admission queue ──► batcher ──► pool
//!   (listener)        │  decode frames                     │
//!                     │  Busy/Stats/ListModels          reply rx
//!                     ▼                                     ▼
//!                  tn-net-write (writer) ◄── in-order outbound queue ◄── await_reply
//! ```
//!
//! The reader never blocks on a reply: admitted requests hand their
//! reply receiver to the writer through an in-order outbound queue, so a
//! connection can pipeline many in-flight requests while the reader
//! keeps admitting (or shedding — a full admission queue becomes an
//! immediate `Busy` reply, counted in `ServerStats::rejected` like every
//! other transport).  Replies are written strictly in request order; the
//! client relies on that.
//!
//! A malformed frame (bad magic/version/checksum, unknown type,
//! truncation) gets a best-effort `InferErr`/`BadRequest` reply and
//! closes *that* connection only — the listener and every other
//! connection keep serving (`rust/tests/remote_serving.rs`).  Model
//! names are validated against the advertised lineup before admission:
//! client-controlled garbage names are answered with an `InferErr`
//! instead of planting permanent batcher-group / per-model-stats
//! entries keyed by attacker-chosen bytes.

use crate::coordinator::server::{Admission, Server};
use crate::coordinator::wire::{self, ErrCode, Frame, ModelInfo, ReadOutcome};
use crate::error::{Error, Result};
use std::io::BufWriter;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// How long a blocked accept/read waits before re-checking the stop flag
/// (bounds shutdown latency, not throughput — a frame mid-flight is
/// never interrupted).
const POLL: Duration = Duration::from_millis(25);

/// What the reader hands the writer, in request order.
enum Outbound {
    /// A reply that is already known (Busy, stats, errors, ...).
    Ready(Frame),
    /// An admitted request: the writer awaits the coordinator's reply
    /// (through [`Server::await_reply`], so remote requests land in the
    /// same e2e histogram as in-process ones).
    Pending { id: u64, rx: crate::coordinator::server::ReplyReceiver },
}

/// A running TCP listener bound to a [`Server`].  Dropping (or calling
/// [`NetServer::shutdown`]) stops accepting, closes every connection at
/// its next poll tick and joins all transport threads; the `Server`
/// itself stays up (it may have other front-ends).
pub struct NetServer {
    local_addr: SocketAddr,
    stop: Arc<AtomicBool>,
    shutdown_requested: Arc<AtomicBool>,
    accept: Option<std::thread::JoinHandle<()>>,
    conns: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>>,
}

impl NetServer {
    /// Bind `addr` (e.g. `127.0.0.1:0` for an OS-assigned port) and
    /// start serving `server` over it.  `models` is the lineup
    /// advertised to `ListModels` clients.
    pub fn start(server: Arc<Server>, addr: &str, models: Vec<ModelInfo>) -> Result<NetServer> {
        let listener =
            TcpListener::bind(addr).map_err(|e| Error::Net(format!("bind {addr}: {e}")))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| Error::Net(format!("set_nonblocking: {e}")))?;
        let local_addr =
            listener.local_addr().map_err(|e| Error::Net(format!("local_addr: {e}")))?;
        let stop = Arc::new(AtomicBool::new(false));
        let shutdown_requested = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>> =
            Arc::new(Mutex::new(Vec::new()));

        let accept = {
            let stop = stop.clone();
            let shutdown_requested = shutdown_requested.clone();
            let conns = conns.clone();
            std::thread::Builder::new()
                .name("tn-net-accept".into())
                .spawn(move || {
                    accept_loop(listener, server, models, stop, shutdown_requested, conns)
                })
                .map_err(|e| Error::Net(format!("spawn accept loop: {e}")))?
        };

        Ok(NetServer { local_addr, stop, shutdown_requested, accept: Some(accept), conns })
    }

    /// The bound address — the port is meaningful when `start` was given
    /// port 0.
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// True once a client's `Shutdown` frame has been acknowledged.
    pub fn shutdown_requested(&self) -> bool {
        self.shutdown_requested.load(Ordering::SeqCst)
    }

    /// Block until a wire `Shutdown` arrives (the daemon mode of
    /// `tensornet serve --listen`).
    pub fn wait_for_shutdown(&self) {
        while !self.shutdown_requested() {
            std::thread::sleep(POLL);
        }
    }

    /// Stop accepting, close every connection at its next poll tick and
    /// join all transport threads.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        let handles: Vec<_> = match self.conns.lock() {
            Ok(mut g) => g.drain(..).collect(),
            Err(poisoned) => poisoned.into_inner().drain(..).collect(),
        };
        for h in handles {
            let _ = h.join();
        }
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

fn accept_loop(
    listener: TcpListener,
    server: Arc<Server>,
    models: Vec<ModelInfo>,
    stop: Arc<AtomicBool>,
    shutdown_requested: Arc<AtomicBool>,
    conns: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>>,
) {
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, peer)) => {
                // the listener is non-blocking so the stop flag stays
                // responsive; each accepted socket goes back to blocking
                // reads with a timeout (the reader's stop poll)
                if stream.set_nonblocking(false).is_err()
                    || stream.set_read_timeout(Some(POLL)).is_err()
                {
                    continue;
                }
                let _ = stream.set_nodelay(true);
                let server = server.clone();
                let models = models.clone();
                let stop = stop.clone();
                let shutdown_requested = shutdown_requested.clone();
                let handle = std::thread::Builder::new()
                    .name("tn-net-conn".into())
                    .spawn(move || {
                        connection_loop(stream, peer, server, models, stop, shutdown_requested)
                    });
                if let (Ok(h), Ok(mut guard)) = (handle, conns.lock()) {
                    // reap finished connections so a long-lived listener
                    // doesn't accumulate handles
                    guard.retain(|j| !j.is_finished());
                    guard.push(h);
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(POLL);
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => {
                eprintln!("tn-net-accept: {e}");
                std::thread::sleep(POLL);
            }
        }
    }
}

/// One connection: decode → dispatch loop, with the in-order writer on
/// its own thread so admitted requests pipeline.
fn connection_loop(
    mut stream: TcpStream,
    peer: SocketAddr,
    server: Arc<Server>,
    models: Vec<ModelInfo>,
    stop: Arc<AtomicBool>,
    shutdown_requested: Arc<AtomicBool>,
) {
    let write_stream = match stream.try_clone() {
        Ok(s) => s,
        Err(e) => {
            eprintln!("tn-net-conn {peer}: clone stream: {e}");
            return;
        }
    };
    let (out_tx, out_rx): (Sender<Outbound>, Receiver<Outbound>) = channel();
    let writer = {
        let server = server.clone();
        std::thread::Builder::new()
            .name("tn-net-write".into())
            .spawn(move || writer_loop(write_stream, server, out_rx))
    };
    let writer = match writer {
        Ok(w) => w,
        Err(e) => {
            eprintln!("tn-net-conn {peer}: spawn writer: {e}");
            return;
        }
    };

    // true when this side decided to close (protocol error, shutdown, …)
    // rather than the peer hanging up first
    let mut server_initiated_close = false;
    loop {
        // the shared framed reader (coordinator::wire): the 25ms socket
        // read timeout is its poll tick for our stop flag
        match wire::read_frame(&mut stream, || stop.load(Ordering::SeqCst)) {
            Ok(ReadOutcome::Eof) | Ok(ReadOutcome::Stopped) => break,
            Ok(ReadOutcome::Frame(frame)) => {
                if !dispatch(frame, &server, &models, &out_tx, &shutdown_requested) {
                    server_initiated_close = true;
                    break;
                }
            }
            Err(e) => {
                // protocol violation: reply (best-effort) and close this
                // connection; the listener keeps serving everyone else
                let _ = out_tx.send(Outbound::Ready(Frame::InferErr {
                    id: 0,
                    code: ErrCode::BadRequest,
                    message: format!("{e}"),
                }));
                server_initiated_close = true;
                break;
            }
        }
    }
    drop(out_tx); // writer drains pending replies, then exits
    let _ = writer.join();
    if server_initiated_close {
        // closing with unread bytes in the receive buffer makes the
        // kernel send RST, which can discard the error reply before the
        // peer reads it — half-close and briefly drain so the reply
        // survives the teardown
        drain_before_close(&mut stream);
    }
}

/// Send FIN, then swallow whatever the peer already has in flight
/// (bounded by a few poll ticks) so the final close is a FIN, not an
/// RST that would race the just-written reply off the peer's buffer.
fn drain_before_close(stream: &mut TcpStream) {
    use std::io::Read;
    let _ = stream.shutdown(std::net::Shutdown::Write);
    let mut buf = [0u8; 4096];
    for _ in 0..8 {
        match stream.read(&mut buf) {
            Ok(0) => return,  // peer closed too — clean
            Ok(_) => {}       // discard
            Err(_) => return, // timeout (buffer empty) or peer reset
        }
    }
}

/// Handle one decoded frame; returns false when the connection should
/// close (shutdown acknowledged or a reply-type frame arrived).
fn dispatch(
    frame: Frame,
    server: &Arc<Server>,
    models: &[ModelInfo],
    out_tx: &Sender<Outbound>,
    shutdown_requested: &AtomicBool,
) -> bool {
    match frame {
        Frame::Infer { id, model, input } => {
            // validate the name against the advertised lineup BEFORE
            // admission: model names are client-controlled bytes, and an
            // unknown one would otherwise plant a permanent batcher
            // group + stats entry per unique name (unbounded memory on a
            // long-lived listener, and past 65535 names every
            // StatsReply would fail its u16 cap)
            if !models.iter().any(|m| m.name == model) {
                // still a request error: the serve summary / StatsReply
                // must not read `errors 0` while a misconfigured client
                // gets a stream of failures (pre-admission, so there is
                // no per-model entry to attribute it to)
                server.stats().errors.inc();
                let served: Vec<&str> = models.iter().map(|m| m.name.as_str()).collect();
                return out_tx
                    .send(Outbound::Ready(Frame::InferErr {
                        id,
                        code: ErrCode::Exec,
                        message: format!(
                            "unknown model '{model}' (served: {})",
                            served.join(", ")
                        ),
                    }))
                    .is_ok();
            }
            let reply = match server.admit(&model, input) {
                Ok(Admission::Queued(rx)) => Outbound::Pending { id, rx },
                Ok(Admission::Busy) => Outbound::Ready(Frame::InferErr {
                    id,
                    code: ErrCode::Busy,
                    message: "admission queue full".into(),
                }),
                Err(e) => Outbound::Ready(Frame::InferErr {
                    id,
                    code: ErrCode::Exec,
                    message: format!("{e}"),
                }),
            };
            out_tx.send(reply).is_ok()
        }
        Frame::Stats => {
            let st = server.stats();
            // per-model block: remote operators see each model's batch
            // efficiency, not just the aggregate (which can hide one
            // model batching well while another runs at batch 1)
            let per_model = st
                .per_model()
                .into_iter()
                .map(|(name, m)| wire::ModelStatsEntry {
                    name,
                    completed: m.completed.get(),
                    errors: m.errors.get(),
                    batches: m.batches.get(),
                    batched_rows: m.batched_rows.get(),
                })
                .collect();
            out_tx
                .send(Outbound::Ready(Frame::StatsReply {
                    completed: st.completed.get(),
                    rejected: st.rejected.get(),
                    errors: st.errors.get(),
                    failed_workers: st.failed_workers.get(),
                    batches: st.batches.get(),
                    batched_rows: st.batched_rows.get(),
                    per_model,
                }))
                .is_ok()
        }
        Frame::ListModels => out_tx
            .send(Outbound::Ready(Frame::ModelList { models: models.to_vec() }))
            .is_ok(),
        Frame::Shutdown => {
            // acknowledge first so the client sees the accept before the
            // listener starts tearing down
            let _ = out_tx.send(Outbound::Ready(Frame::ShutdownOk));
            shutdown_requested.store(true, Ordering::SeqCst);
            false
        }
        // reply-type frames have no business arriving at the server;
        // name only the kind — Debug-printing the frame would let a
        // hostile 16 MiB payload amplify into a huge format allocation
        other @ (Frame::InferOk { .. }
        | Frame::InferErr { .. }
        | Frame::StatsReply { .. }
        | Frame::ModelList { .. }
        | Frame::ShutdownOk) => {
            let _ = out_tx.send(Outbound::Ready(Frame::InferErr {
                id: 0,
                code: ErrCode::BadRequest,
                message: format!("unexpected reply-type frame {} sent to server", other.kind()),
            }));
            false
        }
    }
}

/// Drain the outbound queue in order, awaiting each admitted request's
/// reply.  Exits when the reader hangs up (channel closes) or the socket
/// dies; either way remaining receivers just drop, which the coordinator
/// tolerates (a dropped reply sender is counted by the caller side only).
fn writer_loop(
    stream: TcpStream,
    server: Arc<Server>,
    out_rx: Receiver<Outbound>,
) {
    let mut w = BufWriter::new(stream);
    while let Ok(msg) = out_rx.recv() {
        let frame = match msg {
            Outbound::Ready(f) => f,
            Outbound::Pending { id, rx } => match server.await_reply(rx) {
                Ok(resp) => Frame::InferOk {
                    id,
                    queue_us: resp.queue_us,
                    exec_us: resp.exec_us,
                    batch_size: resp.batch_size as u32,
                    output: resp.output,
                },
                Err(e) => {
                    Frame::InferErr { id, code: ErrCode::Exec, message: format!("{e}") }
                }
            },
        };
        if frame.write_to(&mut w).is_err() {
            return;
        }
        // replies are latency-sensitive: flush per frame (pipelined
        // writes still coalesce inside the BufWriter between syscalls)
        if std::io::Write::flush(&mut w).is_err() {
            return;
        }
    }
}
