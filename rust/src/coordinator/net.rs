//! TCP front-end over the coordinator (DESIGN.md §12): a std-only
//! readiness reactor speaking the [`crate::coordinator::wire`] protocol
//! and feeding the *same* bounded admission queue as in-process callers
//! ([`Server::admit`]).
//!
//! ```text
//! tn-net-accept ──round-robin──► tn-net-io-{k}   (k < io_threads, default 1)
//!   (listener)                     │ sweeps Vec<Conn> state machines:
//!                                  │   read   socket → FrameDecoder → dispatch ──admit──► queue ──► batcher ──► pool
//!                                  │   settle head of in-order outbound queue ◄── Server::try_reply
//!                                  │   write  non-blocking, partial-write aware
//!                                  └── FIN-then-drain teardown per connection
//! ```
//!
//! Unlike the previous design (a reader/writer thread pair per
//! connection), *one* I/O thread carries every connection assigned to
//! it: all sockets are non-blocking, each connection is a state machine
//! owning a partial-frame read buffer ([`wire::FrameDecoder`]), an
//! in-order outbound reply queue, and a partially-written output
//! buffer.  The reactor never blocks on any single connection — reads
//! and writes stop at `WouldBlock`, and admitted requests are settled
//! by *polling* the coordinator's reply channel ([`Server::try_reply`])
//! instead of parking a thread in `await_reply` per request.  That is
//! what lets hundreds of connections share one or two transport
//! threads instead of costing two OS threads each.
//!
//! Replies are written strictly in request order per connection — only
//! the *head* of the outbound queue may settle, so a slow request holds
//! back later replies on its own connection (the client relies on
//! in-order delivery) but never any other connection.  An admission
//! shed becomes an immediate `Busy`/`Quota` reply carrying the
//! controller's retry-after hint, counted in `ServerStats::rejected`
//! (and `quota_shed` for the quota kind) like every other transport.
//!
//! A malformed frame (bad magic/version/checksum, unknown type,
//! truncation) gets a best-effort `InferErr`/`BadRequest` reply and
//! closes *that* connection only — the listener and every other
//! connection keep serving (`rust/tests/remote_serving.rs`).  Model
//! names are validated against the advertised lineup before admission:
//! client-controlled garbage names are answered with an `InferErr`
//! instead of planting permanent batcher-group / per-model-stats
//! entries keyed by attacker-chosen bytes.

use crate::coordinator::admission::ShedKind;
use crate::coordinator::server::{Admission, Server};
use crate::coordinator::wire::{self, ErrCode, Frame, ModelInfo};
use crate::error::{Error, Result};
use std::collections::VecDeque;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// How long a blocked accept (or an idle reactor with no connections)
/// waits before re-checking the stop flag.  Bounds shutdown latency,
/// not throughput — a frame mid-flight is never interrupted.
const POLL: Duration = Duration::from_millis(25);

/// Sleep between sweeps when no connection made progress.  This is the
/// price of a std-only reactor (no epoll): a short doze instead of a
/// readiness wakeup.  500µs keeps idle CPU negligible while adding at
/// most half a millisecond to request latency — well under the
/// batcher's own `max_delay`.  The doze is *skipped* when a connection
/// has undelivered replies and an admission ticket was released since
/// the last sweep (`AdmissionController::release_epoch`) — a release
/// means a reply just became settleable, so the next sweep should run
/// immediately instead of taxing every request with a stale half-
/// millisecond nap.
const IDLE_TICK: Duration = Duration::from_micros(500);

/// Most bytes pulled off one socket per sweep, so a firehosing client
/// cannot starve its neighbours on the same I/O thread.
const READ_CHUNK: usize = 64 * 1024;

/// Stop promoting replies into the write buffer once this many bytes
/// are already waiting on a slow socket; the queue keeps them until the
/// peer drains.  Purely a memory bound — order is unaffected.
const WBUF_SOFT_CAP: usize = 1 << 20;

/// After sending FIN, how long to keep swallowing the peer's in-flight
/// bytes so the final close is a FIN, not an RST that would race the
/// just-written error reply off the peer's buffer.
const FIN_DRAIN: Duration = Duration::from_millis(200);

/// Upper bound on reactor teardown: when [`NetServer::shutdown`] is
/// called, connections get this long to settle pending replies and
/// flush before being dropped.
const STOP_DRAIN: Duration = Duration::from_secs(5);

/// One queued reply, in request order.
enum Outbound {
    /// A reply that is already known (Busy, stats, errors, ...).
    Ready(Frame),
    /// An admitted request: the reactor polls the coordinator's reply
    /// channel (through [`Server::try_reply`], so remote requests land
    /// in the same e2e histogram as in-process ones).
    Pending { id: u64, rx: crate::coordinator::server::ReplyReceiver },
}

/// Connection lifecycle.  Every path out of `Open` flushes queued
/// replies before the socket dies.
enum Phase {
    /// Reading requests, settling and writing replies.
    Open,
    /// Peer sent a clean FIN: no more requests will arrive, but queued
    /// replies are still settled and written (the peer half-closed its
    /// write side and may well be reading).
    PeerClosed,
    /// We decided to close (protocol error, shutdown ack, reactor
    /// stop): settle + flush everything outbound, then FIN.
    Closing,
    /// FIN sent; swallowing whatever the peer still has in flight,
    /// bounded by [`FIN_DRAIN`].
    Draining { since: Instant },
}

/// What one sweep of one connection reported back to the reactor loop.
struct Sweep {
    /// Bytes moved or replies settled — the reactor skips its idle doze.
    progress: bool,
    /// False once the connection is finished (or broken) and must be
    /// removed from the sweep list.
    keep: bool,
}

/// Per-connection state machine.  All I/O is non-blocking; the owning
/// reactor thread calls [`Conn::sweep`] repeatedly and nothing here
/// ever blocks it.
struct Conn {
    stream: TcpStream,
    peer: SocketAddr,
    decoder: wire::FrameDecoder,
    /// Replies in request order; only the head may settle.
    outbound: VecDeque<Outbound>,
    /// Encoded-but-unwritten reply bytes, with `wpos` marking the
    /// partially-written prefix already accepted by the kernel.
    wbuf: Vec<u8>,
    wpos: usize,
    phase: Phase,
}

impl Conn {
    /// True while this connection still owes the peer bytes: queued
    /// replies (settled or in flight behind the executor) or a
    /// partially-written output buffer.  The reactor uses this to decide
    /// whether a released admission ticket warrants skipping the idle
    /// doze — an all-drained connection gains nothing from a re-sweep.
    fn has_pending_work(&self) -> bool {
        !self.outbound.is_empty() || self.wpos < self.wbuf.len()
    }

    fn new(stream: TcpStream, peer: SocketAddr) -> Option<Conn> {
        if stream.set_nonblocking(true).is_err() {
            return None;
        }
        let _ = stream.set_nodelay(true);
        Some(Conn {
            stream,
            peer,
            decoder: wire::FrameDecoder::new(),
            outbound: VecDeque::new(),
            wbuf: Vec::new(),
            wpos: 0,
            phase: Phase::Open,
        })
    }

    /// Reactor stop: finish what is queued, then FIN — never cut a
    /// connection with replies still owed.
    fn begin_close(&mut self) {
        if matches!(self.phase, Phase::Open | Phase::PeerClosed) {
            self.phase = Phase::Closing;
        }
    }

    /// One pass of the connection state machine: read what the socket
    /// has, settle what the coordinator finished, write what the peer
    /// will take, and advance teardown.
    fn sweep(
        &mut self,
        server: &Arc<Server>,
        models: &[ModelInfo],
        shutdown_requested: &AtomicBool,
    ) -> Sweep {
        let mut progress = false;
        if matches!(self.phase, Phase::Open)
            && !self.read_ready(&mut progress, server, models, shutdown_requested)
        {
            return Sweep { progress: true, keep: false };
        }
        if !self.promote(&mut progress, server) {
            return Sweep { progress: true, keep: false };
        }
        if !self.write_ready(&mut progress) {
            return Sweep { progress: true, keep: false };
        }
        let flushed = self.outbound.is_empty() && self.wpos == self.wbuf.len();
        match self.phase {
            Phase::Open => {}
            Phase::PeerClosed => {
                if flushed {
                    // both sides done; nothing unread, so close is a FIN
                    return Sweep { progress: true, keep: false };
                }
            }
            Phase::Closing => {
                if flushed {
                    let _ = self.stream.shutdown(std::net::Shutdown::Write);
                    self.phase = Phase::Draining { since: Instant::now() };
                    progress = true;
                }
            }
            Phase::Draining { since } => {
                if !self.drain_reads(&mut progress) || since.elapsed() >= FIN_DRAIN {
                    return Sweep { progress: true, keep: false };
                }
            }
        }
        Sweep { progress, keep: true }
    }

    /// Pull at most [`READ_CHUNK`] bytes and decode every complete
    /// frame they finish.  Returns false when the connection is broken
    /// beyond a reply (hard I/O error).
    fn read_ready(
        &mut self,
        progress: &mut bool,
        server: &Arc<Server>,
        models: &[ModelInfo],
        shutdown_requested: &AtomicBool,
    ) -> bool {
        let mut chunk = [0u8; READ_CHUNK];
        match self.stream.read(&mut chunk) {
            Ok(0) => {
                *progress = true;
                if self.decoder.pending() > 0 {
                    // mid-frame hangup: same contract as the old
                    // blocking read path — the truncation is answered,
                    // then the connection closes
                    self.outbound.push_back(Outbound::Ready(Frame::InferErr {
                        id: 0,
                        code: ErrCode::BadRequest,
                        message: format!(
                            "connection closed mid-frame with {} bytes buffered",
                            self.decoder.pending()
                        ),
                        retry_after_ms: 0,
                    }));
                    self.phase = Phase::Closing;
                } else {
                    self.phase = Phase::PeerClosed;
                }
                true
            }
            Ok(n) => {
                *progress = true;
                self.decoder.feed(&chunk[..n]);
                loop {
                    match self.decoder.next_frame() {
                        Ok(Some(frame)) => {
                            if !dispatch(
                                frame,
                                server,
                                models,
                                &mut self.outbound,
                                shutdown_requested,
                            ) {
                                self.phase = Phase::Closing;
                                break;
                            }
                        }
                        Ok(None) => break,
                        Err(e) => {
                            // protocol violation: reply (best-effort)
                            // and close this connection; the listener
                            // keeps serving everyone else
                            self.outbound.push_back(Outbound::Ready(Frame::InferErr {
                                id: 0,
                                code: ErrCode::BadRequest,
                                message: format!("{e}"),
                                retry_after_ms: 0,
                            }));
                            self.phase = Phase::Closing;
                            break;
                        }
                    }
                }
                true
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => true,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => true,
            Err(e) => {
                eprintln!("tn-net-io {}: read: {e}", self.peer);
                false
            }
        }
    }

    /// Settle replies at the head of the outbound queue into encoded
    /// bytes.  Only the head may settle — replies go out strictly in
    /// request order, so a later-finished reply waits behind an earlier
    /// pending one (on this connection only).
    fn promote(&mut self, progress: &mut bool, server: &Arc<Server>) -> bool {
        loop {
            if self.wbuf.len() - self.wpos >= WBUF_SOFT_CAP {
                return true; // slow peer: keep replies queued, not buffered
            }
            let settled = match self.outbound.front() {
                None => return true,
                Some(Outbound::Ready(_)) => None,
                Some(Outbound::Pending { id, rx }) => match server.try_reply(rx) {
                    None => return true, // head still in flight
                    Some(res) => Some(match res {
                        Ok(resp) => Frame::InferOk {
                            id: *id,
                            queue_us: resp.queue_us,
                            exec_us: resp.exec_us,
                            batch_size: resp.batch_size as u32,
                            output: resp.output,
                        },
                        Err(e) => Frame::InferErr {
                            id: *id,
                            code: ErrCode::Exec,
                            message: format!("{e}"),
                            retry_after_ms: 0,
                        },
                    }),
                },
            };
            let frame = match settled {
                Some(f) => {
                    self.outbound.pop_front();
                    f
                }
                None => match self.outbound.pop_front() {
                    Some(Outbound::Ready(f)) => f,
                    _ => return true,
                },
            };
            // encode straight onto the tail of the connection's write
            // buffer — steady state serializes every reply with zero
            // heap allocation (wire.rs `encode_into`); on error the
            // buffer is restored, so nothing partial ever hits the wire
            match frame.encode_into(&mut self.wbuf) {
                Ok(()) => *progress = true,
                Err(e) => {
                    eprintln!("tn-net-io {}: encode reply: {e}", self.peer);
                    return false;
                }
            }
        }
    }

    /// Push buffered reply bytes into the socket until it pushes back.
    fn write_ready(&mut self, progress: &mut bool) -> bool {
        while self.wpos < self.wbuf.len() {
            match self.stream.write(&self.wbuf[self.wpos..]) {
                Ok(0) => return false,
                Ok(n) => {
                    self.wpos += n;
                    *progress = true;
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => {
                    eprintln!("tn-net-io {}: write: {e}", self.peer);
                    return false;
                }
            }
        }
        if self.wpos > 0 && self.wpos == self.wbuf.len() {
            self.wbuf.clear();
            self.wpos = 0;
        }
        true
    }

    /// Post-FIN: discard the peer's in-flight bytes (bounded per sweep)
    /// so the close is graceful.  Returns false once the peer is done.
    fn drain_reads(&mut self, progress: &mut bool) -> bool {
        let mut chunk = [0u8; 4096];
        for _ in 0..8 {
            match self.stream.read(&mut chunk) {
                Ok(0) => return false,  // peer closed too — clean
                Ok(_) => *progress = true, // discard
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return true,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(_) => return false, // reset — nothing left to save
            }
        }
        true
    }
}

/// A running TCP listener bound to a [`Server`].  Dropping (or calling
/// [`NetServer::shutdown`]) stops accepting, drains every connection
/// (bounded by [`STOP_DRAIN`]) and joins all transport threads; the
/// `Server` itself stays up (it may have other front-ends).
pub struct NetServer {
    local_addr: SocketAddr,
    stop: Arc<AtomicBool>,
    shutdown_requested: Arc<AtomicBool>,
    threads: Vec<std::thread::JoinHandle<()>>,
    io_threads: usize,
}

impl NetServer {
    /// Bind `addr` (e.g. `127.0.0.1:0` for an OS-assigned port) and
    /// start serving `server` over it with a single I/O thread.
    /// `models` is the lineup advertised to `ListModels` clients.
    pub fn start(server: Arc<Server>, addr: &str, models: Vec<ModelInfo>) -> Result<NetServer> {
        NetServer::start_with(server, addr, models, 1)
    }

    /// Like [`NetServer::start`] but with `io_threads` reactor threads
    /// (clamped to at least 1); accepted connections are dealt
    /// round-robin across them.  Total transport threads =
    /// `io_threads` + 1 accept thread, independent of connection count.
    pub fn start_with(
        server: Arc<Server>,
        addr: &str,
        models: Vec<ModelInfo>,
        io_threads: usize,
    ) -> Result<NetServer> {
        let io_threads = io_threads.max(1);
        let listener =
            TcpListener::bind(addr).map_err(|e| Error::Net(format!("bind {addr}: {e}")))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| Error::Net(format!("set_nonblocking: {e}")))?;
        let local_addr =
            listener.local_addr().map_err(|e| Error::Net(format!("local_addr: {e}")))?;
        let stop = Arc::new(AtomicBool::new(false));
        let shutdown_requested = Arc::new(AtomicBool::new(false));

        let mut threads = Vec::with_capacity(io_threads + 1);
        let mut txs: Vec<Sender<(TcpStream, SocketAddr)>> = Vec::with_capacity(io_threads);
        for k in 0..io_threads {
            let (tx, rx) = channel();
            let handle = {
                let server = server.clone();
                let models = models.clone();
                let stop = stop.clone();
                let shutdown_requested = shutdown_requested.clone();
                std::thread::Builder::new()
                    .name(format!("tn-net-io-{k}"))
                    .spawn(move || io_loop(rx, server, models, stop, shutdown_requested))
            };
            match handle {
                Ok(h) => {
                    threads.push(h);
                    txs.push(tx);
                }
                Err(e) => {
                    stop.store(true, Ordering::SeqCst);
                    drop(txs);
                    for h in threads {
                        let _ = h.join();
                    }
                    return Err(Error::Net(format!("spawn io thread {k}: {e}")));
                }
            }
        }
        let accept = {
            let stop = stop.clone();
            std::thread::Builder::new()
                .name("tn-net-accept".into())
                .spawn(move || accept_loop(listener, stop, txs))
        };
        match accept {
            Ok(h) => threads.push(h),
            Err(e) => {
                stop.store(true, Ordering::SeqCst);
                for h in threads {
                    let _ = h.join();
                }
                return Err(Error::Net(format!("spawn accept loop: {e}")));
            }
        }

        Ok(NetServer { local_addr, stop, shutdown_requested, threads, io_threads })
    }

    /// The bound address — the port is meaningful when `start` was given
    /// port 0.
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Number of reactor (I/O) threads sweeping connections.
    pub fn io_threads(&self) -> usize {
        self.io_threads
    }

    /// Total OS threads owned by this transport: the reactor threads
    /// plus the accept thread.  Constant in the number of connections —
    /// the whole point of the reactor (the previous design spawned a
    /// reader/writer pair per connection).
    pub fn transport_threads(&self) -> usize {
        self.threads.len()
    }

    /// True once a client's `Shutdown` frame has been acknowledged.
    pub fn shutdown_requested(&self) -> bool {
        self.shutdown_requested.load(Ordering::SeqCst)
    }

    /// Block until a wire `Shutdown` arrives (the daemon mode of
    /// `tensornet serve --listen`).
    pub fn wait_for_shutdown(&self) {
        while !self.shutdown_requested() {
            std::thread::sleep(POLL);
        }
    }

    /// Stop accepting, drain and close every connection and join all
    /// transport threads.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        for h in self.threads.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

fn accept_loop(
    listener: TcpListener,
    stop: Arc<AtomicBool>,
    txs: Vec<Sender<(TcpStream, SocketAddr)>>,
) {
    let mut next = 0usize;
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, peer)) => {
                // hand off round-robin; the reactor thread makes the
                // socket non-blocking and owns it from here
                if txs[next % txs.len()].send((stream, peer)).is_err() {
                    return; // reactor gone — shutting down
                }
                next = next.wrapping_add(1);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(POLL);
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => {
                eprintln!("tn-net-accept: {e}");
                std::thread::sleep(POLL);
            }
        }
    }
}

/// One reactor thread: sweep every connection assigned to it, never
/// blocking on any single one.  Blocks on the intake channel only while
/// it has no connections at all (an idle reactor burns no CPU).
fn io_loop(
    rx_new: Receiver<(TcpStream, SocketAddr)>,
    server: Arc<Server>,
    models: Vec<ModelInfo>,
    stop: Arc<AtomicBool>,
    shutdown_requested: Arc<AtomicBool>,
) {
    let mut conns: Vec<Conn> = Vec::new();
    let mut stop_deadline: Option<Instant> = None;
    // admission release epoch observed by the previous sweep; a bump
    // means some ticket released (a reply became settleable) since then
    let mut last_epoch = server.admission().release_epoch();
    loop {
        let stopping = stop.load(Ordering::SeqCst);
        if stopping && stop_deadline.is_none() {
            stop_deadline = Some(Instant::now() + STOP_DRAIN);
            for c in conns.iter_mut() {
                c.begin_close();
            }
        }

        // intake: park on the channel when idle, otherwise just drain it
        if conns.is_empty() && !stopping {
            match rx_new.recv_timeout(POLL) {
                Ok((s, peer)) => {
                    if let Some(c) = Conn::new(s, peer) {
                        conns.push(c);
                    }
                }
                Err(RecvTimeoutError::Timeout) => continue,
                Err(RecvTimeoutError::Disconnected) => return,
            }
        }
        while let Ok((s, peer)) = rx_new.try_recv() {
            if stopping {
                continue; // refused: dropping the socket sends FIN/RST
            }
            if let Some(c) = Conn::new(s, peer) {
                conns.push(c);
            }
        }
        if stopping {
            if conns.is_empty() {
                return;
            }
            if stop_deadline.map_or(false, |d| Instant::now() >= d) {
                return; // drain cap hit: cut remaining connections
            }
        }

        // sweep every connection once; removal is swap_remove, order of
        // service across connections carries no guarantees
        let mut progress = false;
        let mut i = 0;
        while i < conns.len() {
            let s = conns[i].sweep(&server, &models, &shutdown_requested);
            progress |= s.progress;
            if s.keep {
                i += 1;
            } else {
                conns.swap_remove(i);
            }
        }
        if !progress && !conns.is_empty() {
            // doze only when nothing is about to become settleable: if a
            // connection still owes replies AND a ticket was released
            // since the last sweep, re-sweep immediately — the head of
            // some outbound queue is likely ready now.  With no pending
            // work (or no release), the doze costs nothing but bounds
            // the spin on slow peers and in-flight executions.
            let epoch = server.admission().release_epoch();
            let owed = conns.iter().any(Conn::has_pending_work);
            if !(owed && epoch != last_epoch) {
                std::thread::sleep(IDLE_TICK);
            }
            last_epoch = epoch;
        }
    }
}

/// Handle one decoded frame by queuing its reply; returns false when
/// the connection should close (shutdown acknowledged or a reply-type
/// frame arrived).
fn dispatch(
    frame: Frame,
    server: &Arc<Server>,
    models: &[ModelInfo],
    outbound: &mut VecDeque<Outbound>,
    shutdown_requested: &AtomicBool,
) -> bool {
    match frame {
        Frame::Infer { id, model, input } => {
            // validate the name against the advertised lineup BEFORE
            // admission: model names are client-controlled bytes, and an
            // unknown one would otherwise plant a permanent batcher
            // group + stats entry per unique name (unbounded memory on a
            // long-lived listener, and past 65535 names every
            // StatsReply would fail its u16 cap)
            if !models.iter().any(|m| m.name == model) {
                // still a request error: the serve summary / StatsReply
                // must not read `errors 0` while a misconfigured client
                // gets a stream of failures (pre-admission, so there is
                // no per-model entry to attribute it to)
                server.stats().errors.inc();
                let served: Vec<&str> = models.iter().map(|m| m.name.as_str()).collect();
                outbound.push_back(Outbound::Ready(Frame::InferErr {
                    id,
                    code: ErrCode::Exec,
                    message: format!("unknown model '{model}' (served: {})", served.join(", ")),
                    retry_after_ms: 0,
                }));
                return true;
            }
            let reply = match server.admit(&model, input) {
                Ok(Admission::Queued(rx)) => Outbound::Pending { id, rx },
                // typed shed: the wire code tells the client whether the
                // whole server was saturated (Busy) or only this model's
                // quota (Quota), and the hint tells it how long to back
                // off before retrying
                Ok(Admission::Busy(info)) => Outbound::Ready(Frame::InferErr {
                    id,
                    code: match info.kind {
                        ShedKind::Capacity => ErrCode::Busy,
                        ShedKind::Quota => ErrCode::Quota,
                    },
                    message: match info.kind {
                        ShedKind::Capacity => "admission queue full".into(),
                        ShedKind::Quota => "model quota exceeded".into(),
                    },
                    retry_after_ms: info.retry_after_ms,
                }),
                Err(e) => Outbound::Ready(Frame::InferErr {
                    id,
                    code: ErrCode::Exec,
                    message: format!("{e}"),
                    retry_after_ms: 0,
                }),
            };
            outbound.push_back(reply);
            true
        }
        Frame::Stats => {
            let st = server.stats();
            // per-model block: remote operators see each model's batch
            // efficiency, not just the aggregate (which can hide one
            // model batching well while another runs at batch 1)
            let per_model = st
                .per_model()
                .into_iter()
                .map(|(name, m)| wire::ModelStatsEntry {
                    name,
                    completed: m.completed.get(),
                    errors: m.errors.get(),
                    batches: m.batches.get(),
                    batched_rows: m.batched_rows.get(),
                    shed: m.shed.get(),
                })
                .collect();
            outbound.push_back(Outbound::Ready(Frame::StatsReply {
                completed: st.completed.get(),
                rejected: st.rejected.get(),
                errors: st.errors.get(),
                failed_workers: st.failed_workers.get(),
                batches: st.batches.get(),
                batched_rows: st.batched_rows.get(),
                quota_shed: st.quota_shed.get(),
                per_model,
            }));
            true
        }
        Frame::ListModels => {
            outbound.push_back(Outbound::Ready(Frame::ModelList { models: models.to_vec() }));
            true
        }
        Frame::Shutdown => {
            // acknowledge first so the client sees the accept before the
            // listener starts tearing down
            outbound.push_back(Outbound::Ready(Frame::ShutdownOk));
            shutdown_requested.store(true, Ordering::SeqCst);
            false
        }
        // reply-type frames have no business arriving at the server;
        // name only the kind — Debug-printing the frame would let a
        // hostile 16 MiB payload amplify into a huge format allocation
        other @ (Frame::InferOk { .. }
        | Frame::InferErr { .. }
        | Frame::StatsReply { .. }
        | Frame::ModelList { .. }
        | Frame::ShutdownOk) => {
            outbound.push_back(Outbound::Ready(Frame::InferErr {
                id: 0,
                code: ErrCode::BadRequest,
                message: format!("unexpected reply-type frame {} sent to server", other.kind()),
                retry_after_ms: 0,
            }));
            false
        }
    }
}
