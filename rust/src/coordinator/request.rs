//! Request / response types crossing the coordinator's queues.

use crate::coordinator::admission::AdmissionTicket;
use std::sync::mpsc::Sender;
use std::time::Instant;

/// One inference request: a feature vector bound for `model`.
#[derive(Debug)]
pub struct InferRequest {
    pub id: u64,
    /// logical model name, e.g. "tt" or "fc" (the router picks the
    /// concrete artifact variant)
    pub model: String,
    pub input: Vec<f32>,
    pub enqueued: Instant,
    /// per-request reply channel (`Err` carries a failure message)
    pub reply: Sender<Result<InferResponse, String>>,
    /// the admission claim this request holds; released (RAII) when the
    /// request is dropped — after the reply send, on failure, or when
    /// discarded at shutdown.  `None` only in unit tests that exercise
    /// the batcher without a controller.
    pub ticket: Option<AdmissionTicket>,
}

/// The response delivered back to the caller.
#[derive(Clone, Debug)]
pub struct InferResponse {
    pub id: u64,
    /// the model that served this request (routes the reply into the
    /// right per-model histogram in `ServerStats`)
    pub model: String,
    pub output: Vec<f32>,
    /// time from enqueue to execution start (admission + batching +
    /// batch-queue wait)
    pub queue_us: u64,
    /// execution time of the whole batch
    pub exec_us: u64,
    /// how many requests shared the batch
    pub batch_size: usize,
    /// when the request entered the admission queue; `Server` records
    /// true end-to-end latency as the wall clock from this instant to
    /// reply receipt (`queue_us + exec_us` alone would drop batch-queue
    /// wait and the reply hop)
    pub enqueued: Instant,
}
