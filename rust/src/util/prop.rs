//! Mini property-testing helper (proptest replacement for the offline
//! build).  Runs a property over `cases` randomized inputs drawn from a
//! seeded [`Rng`]; on failure it reports the case index and the seed so the
//! exact input can be replayed (no shrinking — inputs are kept small
//! instead).

use crate::util::rng::Rng;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 64, seed: 0xC0FFEE }
    }
}

/// Run `property(case_rng)` for `cfg.cases` independent cases.  The closure
/// returns `Err(msg)` to fail with a message; panics also fail the test.
pub fn check<F>(cfg: Config, name: &str, mut property: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let mut master = Rng::new(cfg.seed);
    for case in 0..cfg.cases {
        let mut rng = master.fork(case as u64);
        if let Err(msg) = property(&mut rng) {
            panic!(
                "property '{name}' failed at case {case}/{} (seed {:#x}): {msg}",
                cfg.cases, cfg.seed
            );
        }
    }
}

/// Convenience: default config.
pub fn check_default<F>(name: &str, property: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    check(Config::default(), name, property)
}

/// Draw helpers used by the property tests.
pub mod gen {
    use crate::util::rng::Rng;

    /// Integer in `[lo, hi]` inclusive.
    pub fn int(rng: &mut Rng, lo: usize, hi: usize) -> usize {
        lo + rng.below(hi - lo + 1)
    }

    /// Vector of `len` i.i.d. normals scaled by `std`.
    pub fn normal_vec(rng: &mut Rng, len: usize, std: f32) -> Vec<f32> {
        (0..len).map(|_| rng.normal_f32(std)).collect()
    }

    /// Random mode sizes whose product stays below `max_prod`.
    pub fn modes(rng: &mut Rng, d: usize, lo: usize, hi: usize, max_prod: usize) -> Vec<usize> {
        loop {
            let m: Vec<usize> = (0..d).map(|_| int(rng, lo, hi)).collect();
            if m.iter().product::<usize>() <= max_prod {
                return m;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check_default("tautology", |rng| {
            let x = rng.uniform();
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("{x} out of range"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-false'")]
    fn failing_property_panics_with_context() {
        check(Config { cases: 3, seed: 1 }, "always-false", |_| Err("nope".into()));
    }

    #[test]
    fn gen_int_inclusive() {
        let mut rng = Rng::new(9);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..500 {
            let x = gen::int(&mut rng, 2, 5);
            assert!((2..=5).contains(&x));
            lo_seen |= x == 2;
            hi_seen |= x == 5;
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn gen_modes_bounded() {
        let mut rng = Rng::new(10);
        for _ in 0..50 {
            let m = gen::modes(&mut rng, 4, 1, 6, 100);
            assert_eq!(m.len(), 4);
            assert!(m.iter().product::<usize>() <= 100);
        }
    }
}
