//! In-tree replacements for crates unavailable in the offline build
//! (DESIGN.md §Substitutions): deterministic RNG, JSON parsing, a scoped
//! thread pool, CLI parsing, a bench harness and a property-testing helper.

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod threads;

pub use rng::Rng;
