//! Tiny bench harness (criterion replacement for the offline build).
//!
//! Warmup + timed iterations with mean / p50 / min reporting, plus a
//! markdown-ish table printer the bench binaries use to regenerate the
//! paper's tables.  `black_box` prevents the optimizer from deleting the
//! measured work.
//!
//! [`Measurement::to_json`] makes every measurement machine-readable;
//! `tensornet bench` (experiments::perf) assembles them into the
//! `BENCH_*.json` perf-trajectory files described in EXPERIMENTS.md §Perf.

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// Re-export of the std optimizer barrier.
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// One benchmark measurement.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub min: Duration,
}

impl Measurement {
    pub fn mean_ms(&self) -> f64 {
        self.mean.as_secs_f64() * 1e3
    }
    pub fn mean_us(&self) -> f64 {
        self.mean.as_secs_f64() * 1e6
    }

    /// Machine-readable form for the `BENCH_*.json` perf trajectory.
    pub fn to_json(&self) -> Json {
        let mut obj = BTreeMap::new();
        obj.insert("name".to_string(), Json::Str(self.name.clone()));
        obj.insert("iters".to_string(), Json::Num(self.iters as f64));
        obj.insert("mean_ms".to_string(), Json::Num(self.mean.as_secs_f64() * 1e3));
        obj.insert("p50_ms".to_string(), Json::Num(self.p50.as_secs_f64() * 1e3));
        obj.insert("min_ms".to_string(), Json::Num(self.min.as_secs_f64() * 1e3));
        Json::Obj(obj)
    }
}

/// Bench runner: measures `f` until `target_time` is spent (after warmup),
/// at least `min_iters` iterations.
#[derive(Clone, Copy, Debug)]
pub struct Bencher {
    pub warmup: Duration,
    pub target_time: Duration,
    pub min_iters: usize,
    pub max_iters: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(200),
            target_time: Duration::from_secs(1),
            min_iters: 5,
            max_iters: 10_000,
        }
    }
}

impl Bencher {
    /// Quick profile for slow end-to-end cases.
    pub fn quick() -> Self {
        Bencher {
            warmup: Duration::from_millis(50),
            target_time: Duration::from_millis(300),
            min_iters: 3,
            max_iters: 1_000,
        }
    }

    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> Measurement {
        // warmup
        let w0 = Instant::now();
        while w0.elapsed() < self.warmup {
            f();
        }
        // measure; always take at least one sample so the percentile /
        // mean math below can never divide by (or index into) zero, even
        // under a pathological `min_iters: 0` profile
        let mut samples: Vec<Duration> = Vec::new();
        let t0 = Instant::now();
        while samples.is_empty()
            || ((t0.elapsed() < self.target_time || samples.len() < self.min_iters)
                && samples.len() < self.max_iters)
        {
            let s = Instant::now();
            f();
            samples.push(s.elapsed());
        }
        samples.sort();
        let total: Duration = samples.iter().sum();
        let m = Measurement {
            name: name.to_string(),
            iters: samples.len(),
            mean: total / samples.len() as u32,
            p50: samples[samples.len() / 2],
            min: samples[0],
        };
        println!(
            "{:<48} {:>10.3} ms/iter  (p50 {:>8.3} ms, min {:>8.3} ms, n={})",
            m.name,
            m.mean_ms(),
            m.p50.as_secs_f64() * 1e3,
            m.min.as_secs_f64() * 1e3,
            m.iters
        );
        m
    }
}

/// Print a paper-style table: header row + aligned value rows.
pub fn print_table(title: &str, header: &[&str], rows: &[Vec<String>]) {
    println!("\n## {title}\n");
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let fmt_row = |cells: Vec<String>| {
        let mut line = String::from("|");
        for (i, c) in cells.iter().enumerate() {
            line.push_str(&format!(" {:<w$} |", c, w = widths.get(i).copied().unwrap_or(c.len())));
        }
        line
    };
    println!("{}", fmt_row(header.iter().map(|s| s.to_string()).collect()));
    let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
    println!("{}", fmt_row(sep));
    for row in rows {
        println!("{}", fmt_row(row.clone()));
    }
    println!();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let b = Bencher {
            warmup: Duration::from_millis(1),
            target_time: Duration::from_millis(10),
            min_iters: 3,
            max_iters: 100,
        };
        let mut acc = 0u64;
        let m = b.run("noop-ish", || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(m.iters >= 3);
        assert!(m.min <= m.p50);
        assert!(m.p50 <= m.mean * 10);
    }

    #[test]
    fn measurement_serializes() {
        let m = Measurement {
            name: "x".into(),
            iters: 3,
            mean: Duration::from_millis(2),
            p50: Duration::from_millis(2),
            min: Duration::from_millis(1),
        };
        let j = m.to_json();
        assert_eq!(j.get("name").unwrap().as_str(), Some("x"));
        assert_eq!(j.get("iters").unwrap().as_usize(), Some(3));
        assert!((j.get("mean_ms").unwrap().as_f64().unwrap() - 2.0).abs() < 1e-9);
        // round-trips through the in-tree parser
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(back, j);
    }

    #[test]
    fn zero_min_iters_still_measures_once() {
        let b = Bencher {
            warmup: Duration::from_millis(0),
            target_time: Duration::from_millis(0),
            min_iters: 0,
            max_iters: 10,
        };
        let m = b.run("one-shot", || {
            black_box(1 + 1);
        });
        assert!(m.iters >= 1);
    }

    #[test]
    fn table_does_not_panic() {
        print_table(
            "t",
            &["a", "b"],
            &[vec!["1".into(), "2".into()], vec!["333".into(), "4".into()]],
        );
    }
}
