//! Minimal scoped data-parallelism (rayon replacement for the offline
//! build).  `parallel_chunks_mut` splits a mutable slice into per-thread
//! contiguous regions and runs the worker over `granularity`-item chunks;
//! static partitioning is the right shape for our GEMM row panels (uniform
//! cost per row), and it needs no locks at all.
//!
//! Two knobs bound the fan-out:
//!
//! * `TENSORNET_THREADS` caps the machine-wide pool size that
//!   [`num_threads`] reports (clamped ≥ 1, cached on first read — set it
//!   before the first parallel call).  Benches and the serve CLI use it
//!   to pin the kernel thread count for reproducible numbers.
//! * [`set_thread_budget`] caps the CALLING thread's fan-out only: an
//!   executor-pool worker sets its budget to `num_threads() /
//!   executor_threads` so pool parallelism × kernel parallelism never
//!   oversubscribes the box.  The budget is thread-local, and the scoped
//!   workers these helpers spawn start with an unset budget — but they
//!   never spawn further (the helpers are leaves), so there is no nested
//!   re-expansion to worry about.

use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Parse a `TENSORNET_THREADS` value: a thread count clamped to ≥ 1, or
/// `None` for unparsable input (which falls back to detection).
pub fn parse_thread_override(val: &str) -> Option<usize> {
    val.trim().parse::<usize>().ok().map(|n| n.max(1))
}

/// Number of worker threads to use: `TENSORNET_THREADS` if set (clamped
/// ≥ 1), else `available_parallelism`.  Cached on first call.
pub fn num_threads() -> usize {
    static N: AtomicUsize = AtomicUsize::new(0);
    let cached = N.load(Ordering::Relaxed);
    if cached != 0 {
        return cached;
    }
    let n = std::env::var("TENSORNET_THREADS")
        .ok()
        .and_then(|v| parse_thread_override(&v))
        .unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        });
    N.store(n, Ordering::Relaxed);
    n
}

thread_local! {
    /// 0 = unset (use `num_threads()`); otherwise the max fan-out for
    /// parallel helpers called FROM this thread.
    static BUDGET: Cell<usize> = const { Cell::new(0) };
}

/// Cap the fan-out of parallel helpers called from the current thread
/// (`0` clears the cap).  Thread-local: an executor-pool worker calls
/// this once at startup so `pool workers × kernel threads ≤ cores`.
pub fn set_thread_budget(n: usize) {
    BUDGET.with(|b| b.set(n));
}

/// Effective thread budget for the current thread: the value set by
/// [`set_thread_budget`] (never above `num_threads()`), or
/// `num_threads()` when unset.  Always ≥ 1.
pub fn thread_budget() -> usize {
    let b = BUDGET.with(|b| b.get());
    if b == 0 {
        num_threads()
    } else {
        b.min(num_threads()).max(1)
    }
}

/// Run `f(start_item, chunk)` over `granularity`-item chunks of `data`,
/// spread across up to [`thread_budget`] OS threads.
///
/// Each thread owns a contiguous run of whole chunks (no work stealing, no
/// locks).  The last chunk may be short.  Serial when one thread suffices.
pub fn parallel_chunks_mut<T: Send, F>(data: &mut [T], granularity: usize, f: F)
where
    F: Fn(usize, &mut [T]) + Sync,
{
    let g = granularity.max(1);
    let n_chunks = data.len().div_ceil(g);
    let threads = thread_budget().min(n_chunks);
    if threads <= 1 {
        for (ci, chunk) in data.chunks_mut(g).enumerate() {
            f(ci * g, chunk);
        }
        return;
    }
    // region size: whole chunks, balanced across threads
    let chunks_per_thread = n_chunks.div_ceil(threads);
    let region = chunks_per_thread * g;
    std::thread::scope(|s| {
        for (ri, region_slice) in data.chunks_mut(region).enumerate() {
            let f = &f;
            s.spawn(move || {
                for (ci, chunk) in region_slice.chunks_mut(g).enumerate() {
                    f(ri * region + ci * g, chunk);
                }
            });
        }
    });
}

/// [`parallel_chunks_mut`] with a second slice split in lockstep: chunk
/// `i` of `data` (`granularity` items) is paired with chunk `i` of
/// `aux` (`aux_granularity` items) and both are handed to
/// `f(start_item, chunk, aux_chunk)`.
///
/// `aux` must hold at least one full `aux_granularity` chunk per data
/// chunk; a longer tail is ignored.  This is how a caller threads
/// per-worker-chunk scratch (e.g. a contract accumulator) through the
/// helper without allocating inside the worker: the pool lives in the
/// caller's reusable storage and each chunk gets a disjoint slab, so
/// there are still no locks and no sharing.
pub fn parallel_chunks_mut2<T: Send, U: Send, F>(
    data: &mut [T],
    granularity: usize,
    aux: &mut [U],
    aux_granularity: usize,
    f: F,
) where
    F: Fn(usize, &mut [T], &mut [U]) + Sync,
{
    let g = granularity.max(1);
    let ga = aux_granularity.max(1);
    let n_chunks = data.len().div_ceil(g);
    assert!(
        aux.len() >= n_chunks * ga,
        "aux slice holds {} items, {} chunks of {ga} need {}",
        aux.len(),
        n_chunks,
        n_chunks * ga
    );
    let threads = thread_budget().min(n_chunks);
    if threads <= 1 {
        for (ci, (chunk, aux_chunk)) in data.chunks_mut(g).zip(aux.chunks_mut(ga)).enumerate() {
            f(ci * g, chunk, aux_chunk);
        }
        return;
    }
    // same balanced whole-chunk regions as `parallel_chunks_mut`; both
    // slices split at the same chunk multiples, so pairing survives the
    // region split
    let chunks_per_thread = n_chunks.div_ceil(threads);
    let region = chunks_per_thread * g;
    let aux_region = chunks_per_thread * ga;
    std::thread::scope(|s| {
        for (ri, (region_slice, aux_slice)) in
            data.chunks_mut(region).zip(aux.chunks_mut(aux_region)).enumerate()
        {
            let f = &f;
            s.spawn(move || {
                for (ci, (chunk, aux_chunk)) in
                    region_slice.chunks_mut(g).zip(aux_slice.chunks_mut(ga)).enumerate()
                {
                    f(ri * region + ci * g, chunk, aux_chunk);
                }
            });
        }
    });
}

/// Parallel map over indices `0..n`, collecting results in order.
///
/// Each thread maps one contiguous index region into its own local
/// `Vec` — no shared lock on the hot path, no index tagging, no final
/// sort (the old implementation took a results mutex once per item and
/// sorted the whole pair-vector afterwards).  The ordered-results
/// contract holds by construction: regions are concatenated in index
/// order.  Static partitioning matches `parallel_chunks_mut` and is the
/// right shape for our uniform-cost workloads.
pub fn parallel_map<R: Send, F>(n: usize, f: F) -> Vec<R>
where
    F: Fn(usize) -> R + Sync,
{
    let threads = thread_budget().min(n.max(1));
    if threads <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let per = n.div_ceil(threads);
    let mut out = Vec::with_capacity(n);
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let f = &f;
                let start = (t * per).min(n);
                let end = ((t + 1) * per).min(n);
                s.spawn(move || (start..end).map(f).collect::<Vec<R>>())
            })
            .collect();
        for h in handles {
            match h.join() {
                Ok(part) => out.extend(part),
                // rethrow with the original payload so a worker's panic
                // message survives (expect() would bury it in `Any`)
                Err(payload) => std::panic::resume_unwind(payload),
            }
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_cover_everything() {
        let mut data = vec![0u32; 1003];
        parallel_chunks_mut(&mut data, 17, |start, chunk| {
            for (i, x) in chunk.iter_mut().enumerate() {
                *x = (start + i) as u32;
            }
        });
        for (i, &x) in data.iter().enumerate() {
            assert_eq!(x, i as u32);
        }
    }

    #[test]
    fn exact_multiple() {
        let mut data = vec![0u32; 64];
        parallel_chunks_mut(&mut data, 8, |start, chunk| {
            assert_eq!(chunk.len(), 8);
            for (i, x) in chunk.iter_mut().enumerate() {
                *x = (start + i) as u32;
            }
        });
        for (i, &x) in data.iter().enumerate() {
            assert_eq!(x, i as u32);
        }
    }

    #[test]
    fn single_chunk_serial() {
        let mut data = vec![1u8; 5];
        parallel_chunks_mut(&mut data, 100, |start, chunk| {
            assert_eq!(start, 0);
            for x in chunk.iter_mut() {
                *x = 2;
            }
        });
        assert!(data.iter().all(|&x| x == 2));
    }

    #[test]
    fn chunks2_pairs_data_and_aux_in_lockstep() {
        // 1003 items in 17-item chunks = 59 chunks; each chunk records
        // its index into its 3-item aux slab, and the data gets the item
        // index — both must come out consistent for every chunk
        let mut data = vec![0u32; 1003];
        let n_chunks = data.len().div_ceil(17);
        let mut aux = vec![u32::MAX; n_chunks * 3];
        parallel_chunks_mut2(&mut data, 17, &mut aux, 3, |start, chunk, aux_chunk| {
            assert_eq!(aux_chunk.len(), 3, "aux chunks are always full length");
            aux_chunk.fill((start / 17) as u32);
            for (i, x) in chunk.iter_mut().enumerate() {
                *x = (start + i) as u32;
            }
        });
        for (i, &x) in data.iter().enumerate() {
            assert_eq!(x, i as u32);
        }
        for (ci, slab) in aux.chunks(3).enumerate() {
            assert!(slab.iter().all(|&v| v == ci as u32), "chunk {ci} got slab {slab:?}");
        }
    }

    #[test]
    fn chunks2_ignores_oversized_aux_tail() {
        // a high-water aux pool may be longer than this call needs; the
        // tail must be left alone
        let mut data = vec![0u8; 40];
        let mut aux = vec![7u8; 4 * 2 + 5]; // 4 chunks of 2 + spare tail
        parallel_chunks_mut2(&mut data, 10, &mut aux, 2, |_, chunk, aux_chunk| {
            chunk.fill(1);
            aux_chunk.fill(0);
        });
        assert!(data.iter().all(|&x| x == 1));
        assert!(aux[..8].iter().all(|&x| x == 0));
        assert!(aux[8..].iter().all(|&x| x == 7), "unused aux tail touched");
    }

    #[test]
    fn chunks2_serial_under_budget_one() {
        set_thread_budget(1);
        let mut data = vec![0u32; 30];
        let mut aux = vec![0u32; 3];
        parallel_chunks_mut2(&mut data, 10, &mut aux, 1, |start, chunk, aux_chunk| {
            aux_chunk[0] += 1; // each slab seen exactly once
            for (i, x) in chunk.iter_mut().enumerate() {
                *x = (start + i) as u32;
            }
        });
        set_thread_budget(0);
        assert_eq!(aux, vec![1, 1, 1]);
        for (i, &x) in data.iter().enumerate() {
            assert_eq!(x, i as u32);
        }
    }

    #[test]
    fn map_ordered() {
        let out = parallel_map(100, |i| i * i);
        for (i, &x) in out.iter().enumerate() {
            assert_eq!(x, i * i);
        }
    }

    #[test]
    fn map_order_preserved_under_skewed_work() {
        // early indices do far more work than late ones, so threads
        // finish out of order — results must still come back in index
        // order, for an n that doesn't divide evenly into regions
        let n = 257;
        let out = parallel_map(n, |i| {
            let mut acc = i as u64;
            for k in 0..((n - i) * 50) as u64 {
                acc = acc.wrapping_mul(6364136223846793005).wrapping_add(k);
            }
            std::hint::black_box(acc);
            i
        });
        assert_eq!(out, (0..n).collect::<Vec<_>>());
    }

    #[test]
    fn map_single_item() {
        assert_eq!(parallel_map(1, |i| i + 41), vec![41]);
    }

    #[test]
    fn map_empty() {
        let out: Vec<usize> = parallel_map(0, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn num_threads_positive() {
        assert!(num_threads() >= 1);
    }

    #[test]
    fn thread_override_parses_and_clamps() {
        assert_eq!(parse_thread_override("4"), Some(4));
        assert_eq!(parse_thread_override(" 2 "), Some(2));
        // clamped ≥ 1: `TENSORNET_THREADS=0` means "serial", not "none"
        assert_eq!(parse_thread_override("0"), Some(1));
        // garbage falls back to detection
        assert_eq!(parse_thread_override("lots"), None);
        assert_eq!(parse_thread_override(""), None);
        assert_eq!(parse_thread_override("-3"), None);
    }

    #[test]
    fn budget_defaults_to_num_threads_and_clamps() {
        // unset on a fresh test thread
        assert_eq!(thread_budget(), num_threads());
        set_thread_budget(1_000_000);
        assert_eq!(thread_budget(), num_threads(), "budget never exceeds the pool");
        set_thread_budget(1);
        assert_eq!(thread_budget(), 1);
        set_thread_budget(0); // clear for whatever runs next on this thread
        assert_eq!(thread_budget(), num_threads());
    }

    #[test]
    fn budget_one_keeps_work_on_the_caller_thread() {
        use std::sync::Mutex;
        set_thread_budget(1);
        let caller = std::thread::current().id();
        let seen = Mutex::new(Vec::new());
        let mut data = vec![0u32; 100];
        parallel_chunks_mut(&mut data, 10, |start, chunk| {
            seen.lock().unwrap().push(std::thread::current().id());
            for (i, x) in chunk.iter_mut().enumerate() {
                *x = (start + i) as u32;
            }
        });
        set_thread_budget(0);
        let seen = seen.into_inner().unwrap();
        assert_eq!(seen.len(), 10, "all chunks still processed");
        assert!(seen.iter().all(|&id| id == caller), "budget 1 must not spawn");
        for (i, &x) in data.iter().enumerate() {
            assert_eq!(x, i as u32);
        }
    }

    #[test]
    fn budget_is_thread_local() {
        set_thread_budget(1);
        let inner = std::thread::spawn(|| thread_budget()).join().unwrap();
        set_thread_budget(0);
        assert_eq!(inner, num_threads(), "spawned threads start with an unset budget");
    }
}
