//! Minimal scoped data-parallelism (rayon replacement for the offline
//! build).  `parallel_chunks_mut` splits a mutable slice into per-thread
//! contiguous regions and runs the worker over `granularity`-item chunks;
//! static partitioning is the right shape for our GEMM row panels (uniform
//! cost per row), and it needs no locks at all.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use (cached `available_parallelism`).
pub fn num_threads() -> usize {
    static N: AtomicUsize = AtomicUsize::new(0);
    let cached = N.load(Ordering::Relaxed);
    if cached != 0 {
        return cached;
    }
    let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    N.store(n, Ordering::Relaxed);
    n
}

/// Run `f(start_item, chunk)` over `granularity`-item chunks of `data`,
/// spread across up to `num_threads()` OS threads.
///
/// Each thread owns a contiguous run of whole chunks (no work stealing, no
/// locks).  The last chunk may be short.  Serial when one thread suffices.
pub fn parallel_chunks_mut<T: Send, F>(data: &mut [T], granularity: usize, f: F)
where
    F: Fn(usize, &mut [T]) + Sync,
{
    let g = granularity.max(1);
    let n_chunks = data.len().div_ceil(g);
    let threads = num_threads().min(n_chunks);
    if threads <= 1 {
        for (ci, chunk) in data.chunks_mut(g).enumerate() {
            f(ci * g, chunk);
        }
        return;
    }
    // region size: whole chunks, balanced across threads
    let chunks_per_thread = n_chunks.div_ceil(threads);
    let region = chunks_per_thread * g;
    std::thread::scope(|s| {
        for (ri, region_slice) in data.chunks_mut(region).enumerate() {
            let f = &f;
            s.spawn(move || {
                for (ci, chunk) in region_slice.chunks_mut(g).enumerate() {
                    f(ri * region + ci * g, chunk);
                }
            });
        }
    });
}

/// Parallel map over indices `0..n`, collecting results in order.
///
/// Each thread maps one contiguous index region into its own local
/// `Vec` — no shared lock on the hot path, no index tagging, no final
/// sort (the old implementation took a results mutex once per item and
/// sorted the whole pair-vector afterwards).  The ordered-results
/// contract holds by construction: regions are concatenated in index
/// order.  Static partitioning matches `parallel_chunks_mut` and is the
/// right shape for our uniform-cost workloads.
pub fn parallel_map<R: Send, F>(n: usize, f: F) -> Vec<R>
where
    F: Fn(usize) -> R + Sync,
{
    let threads = num_threads().min(n.max(1));
    if threads <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let per = n.div_ceil(threads);
    let mut out = Vec::with_capacity(n);
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let f = &f;
                let start = (t * per).min(n);
                let end = ((t + 1) * per).min(n);
                s.spawn(move || (start..end).map(f).collect::<Vec<R>>())
            })
            .collect();
        for h in handles {
            match h.join() {
                Ok(part) => out.extend(part),
                // rethrow with the original payload so a worker's panic
                // message survives (expect() would bury it in `Any`)
                Err(payload) => std::panic::resume_unwind(payload),
            }
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_cover_everything() {
        let mut data = vec![0u32; 1003];
        parallel_chunks_mut(&mut data, 17, |start, chunk| {
            for (i, x) in chunk.iter_mut().enumerate() {
                *x = (start + i) as u32;
            }
        });
        for (i, &x) in data.iter().enumerate() {
            assert_eq!(x, i as u32);
        }
    }

    #[test]
    fn exact_multiple() {
        let mut data = vec![0u32; 64];
        parallel_chunks_mut(&mut data, 8, |start, chunk| {
            assert_eq!(chunk.len(), 8);
            for (i, x) in chunk.iter_mut().enumerate() {
                *x = (start + i) as u32;
            }
        });
        for (i, &x) in data.iter().enumerate() {
            assert_eq!(x, i as u32);
        }
    }

    #[test]
    fn single_chunk_serial() {
        let mut data = vec![1u8; 5];
        parallel_chunks_mut(&mut data, 100, |start, chunk| {
            assert_eq!(start, 0);
            for x in chunk.iter_mut() {
                *x = 2;
            }
        });
        assert!(data.iter().all(|&x| x == 2));
    }

    #[test]
    fn map_ordered() {
        let out = parallel_map(100, |i| i * i);
        for (i, &x) in out.iter().enumerate() {
            assert_eq!(x, i * i);
        }
    }

    #[test]
    fn map_order_preserved_under_skewed_work() {
        // early indices do far more work than late ones, so threads
        // finish out of order — results must still come back in index
        // order, for an n that doesn't divide evenly into regions
        let n = 257;
        let out = parallel_map(n, |i| {
            let mut acc = i as u64;
            for k in 0..((n - i) * 50) as u64 {
                acc = acc.wrapping_mul(6364136223846793005).wrapping_add(k);
            }
            std::hint::black_box(acc);
            i
        });
        assert_eq!(out, (0..n).collect::<Vec<_>>());
    }

    #[test]
    fn map_single_item() {
        assert_eq!(parallel_map(1, |i| i + 41), vec![41]);
    }

    #[test]
    fn map_empty() {
        let out: Vec<usize> = parallel_map(0, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn num_threads_positive() {
        assert!(num_threads() >= 1);
    }
}
