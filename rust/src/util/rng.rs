//! Deterministic RNG: xoshiro256++ seeded through SplitMix64, plus the
//! distributions the library needs (uniform, Gaussian via Box–Muller,
//! Fisher–Yates shuffle).  Replaces `rand`/`rand_distr` in the offline
//! build; every experiment is reproducible from a `u64` seed.

/// xoshiro256++ PRNG (Blackman & Vigna). Not cryptographic — experiments
/// and initialization only.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Gaussian from Box–Muller
    spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)];
        Rng { s, spare: None }
    }

    /// Derive an independent stream (for per-thread / per-layer RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0].wrapping_add(s[3]).rotate_left(23).wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53-bit precision.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[0, 1)`.
    #[inline]
    pub fn uniform_f32(&mut self) -> f32 {
        self.uniform() as f32
    }

    /// Uniform integer in `[0, n)`; `n` must be positive.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // multiply-shift: unbiased enough for experiment workloads
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Standard Gaussian via Box–Muller (polar-free, cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        // u in (0,1] to avoid ln(0)
        let u = 1.0 - self.uniform();
        let v = self.uniform();
        let r = (-2.0 * u.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * v;
        self.spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Gaussian with the given std as `f32`.
    #[inline]
    pub fn normal_f32(&mut self, std: f32) -> f32 {
        (self.normal() * std as f64) as f32
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// `k` distinct indices from `[0, n)` (k <= n), in random order.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_in_range_and_spread() {
        let mut r = Rng::new(1);
        let xs: Vec<f64> = (0..10_000).map(|_| r.uniform()).collect();
        assert!(xs.iter().all(|&x| (0.0..1.0).contains(&x)));
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let x = r.below(7);
            assert!(x < 7);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(4);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(5);
        let s = r.sample_indices(20, 10);
        assert_eq!(s.len(), 10);
        let mut t = s.clone();
        t.sort();
        t.dedup();
        assert_eq!(t.len(), 10);
    }

    #[test]
    fn fork_streams_differ() {
        let mut r = Rng::new(6);
        let mut a = r.fork(1);
        let mut b = r.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
