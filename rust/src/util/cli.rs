//! Tiny CLI argument parser (clap replacement for the offline build).
//!
//! Grammar: `tensornet <subcommand> [--flag] [--key value] ...`.
//! Flags may also be written `--key=value`.

use crate::error::{Error, Result};
use std::collections::BTreeMap;

/// Parsed command line: subcommand + options.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Args> {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(rest) = arg.strip_prefix("--") {
                if rest.is_empty() {
                    return Err(Error::Config("bare '--' not supported".into()));
                }
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map_or(false, |n| !n.starts_with("--")) {
                    out.options.insert(rest.to_string(), it.next().unwrap());
                } else {
                    out.options.insert(rest.to_string(), "true".to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(arg);
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Args> {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.options.get(name).map_or(false, |v| v != "false")
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{name} expects an integer, got '{v}'"))),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{name} expects a number, got '{v}'"))),
        }
    }

    /// Comma-separated list of integers, e.g. `--ranks 1,2,4,8`.
    pub fn get_usize_list(&self, name: &str, default: &[usize]) -> Result<Vec<usize>> {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|x| {
                    x.trim()
                        .parse()
                        .map_err(|_| Error::Config(format!("--{name}: bad integer '{x}'")))
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("serve --model tt --batch 32 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.get("model"), Some("tt"));
        assert_eq!(a.get_usize("batch", 1).unwrap(), 32);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn eq_form_and_positional() {
        let a = parse("fig1 --ranks=1,2,4 out.json");
        assert_eq!(a.get_usize_list("ranks", &[]).unwrap(), vec![1, 2, 4]);
        assert_eq!(a.positional, vec!["out.json"]);
    }

    #[test]
    fn bad_numbers_error() {
        let a = parse("x --n abc");
        assert!(a.get_usize("n", 0).is_err());
        assert!(a.get_f64("n", 0.0).is_err());
    }

    #[test]
    fn defaults() {
        let a = parse("x");
        assert_eq!(a.get_usize("n", 7).unwrap(), 7);
        assert_eq!(a.get_or("s", "d"), "d");
        assert_eq!(a.get_usize_list("l", &[1, 2]).unwrap(), vec![1, 2]);
    }

    #[test]
    fn negative_number_as_value() {
        // "--lr -0.1" : '-0.1' doesn't start with '--' so it's a value
        let a = parse("train --lr -0.1");
        assert_eq!(a.get_f64("lr", 0.0).unwrap(), -0.1);
    }
}
