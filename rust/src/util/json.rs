//! Minimal JSON parser + writer (serde_json replacement for the offline
//! build).  Supports the full JSON grammar; `\uXXXX` escapes decode to the
//! corresponding scalar (surrogate pairs included).  Used to read the AOT
//! `manifest.json` and to emit experiment reports.

use crate::error::{Error, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| if x >= 0.0 && x.fract() == 0.0 { Some(x as usize) } else { None })
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// `get` that errors with context instead of returning None.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| Error::Artifact(format!("missing json field '{key}'")))
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Compact serialization; `json.to_string()` comes from the `ToString`
/// blanket impl over this.
impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut s = String::new();
        self.write(&mut s);
        f.write_str(&s)
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Artifact(format!("json parse error at byte {}: {msg}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{0008}'),
                    Some(b'f') => s.push('\u{000C}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        if (0xD800..0xDC00).contains(&cp) {
                            // high surrogate: require \uXXXX low surrogate
                            self.expect(b'\\')?;
                            self.expect(b'u')?;
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            s.push(char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?);
                        } else {
                            s.push(char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?);
                        }
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // multi-byte UTF-8: copy the full sequence
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        0xF0..=0xF7 => 4,
                        _ => return Err(self.err("invalid utf-8")),
                    };
                    let start = self.pos - 1;
                    for _ in 1..len {
                        self.bump().ok_or_else(|| self.err("truncated utf-8"))?;
                    }
                    let chunk = std::str::from_utf8(&self.bytes[start..self.pos])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-2.5e2").unwrap(), Json::Num(-250.0));
        assert_eq!(Json::parse(r#""hi""#).unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn nested_structures() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].as_usize(), Some(2));
        assert_eq!(arr[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn escapes() {
        let v = Json::parse(r#""a\n\t\"\\Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\t\"\\Aé"));
    }

    #[test]
    fn surrogate_pair() {
        let v = Json::parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str(), Some("😀"));
    }

    #[test]
    fn utf8_passthrough() {
        let v = Json::parse(r#""héllo — ok""#).unwrap();
        assert_eq!(v.as_str(), Some("héllo — ok"));
    }

    #[test]
    fn errors() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"s"],"b":false,"n":null}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn manifest_shape() {
        // the exact structure aot.py emits
        let src = r#"{"seed": 1, "artifacts": [{"name": "x", "inputs":
            [{"name": "core_0", "shape": [1, 4, 4, 8], "dtype": "float32",
              "source": "weights"}]}]}"#;
        let v = Json::parse(src).unwrap();
        let art = &v.get("artifacts").unwrap().as_arr().unwrap()[0];
        let inp = &art.get("inputs").unwrap().as_arr().unwrap()[0];
        let shape: Vec<usize> =
            inp.get("shape").unwrap().as_arr().unwrap().iter().map(|x| x.as_usize().unwrap()).collect();
        assert_eq!(shape, vec![1, 4, 4, 8]);
    }
}
