//! Counters and throughput meters.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Monotonic event counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn new() -> Self {
        Counter(AtomicU64::new(0))
    }

    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Throughput meter: events per second since construction/reset.
#[derive(Debug)]
pub struct Meter {
    count: Counter,
    started: Instant,
}

impl Default for Meter {
    fn default() -> Self {
        Self::new()
    }
}

impl Meter {
    pub fn new() -> Self {
        Meter { count: Counter::new(), started: Instant::now() }
    }

    pub fn mark(&self, n: u64) {
        self.count.add(n);
    }

    pub fn count(&self) -> u64 {
        self.count.get()
    }

    pub fn per_second(&self) -> f64 {
        let secs = self.started.elapsed().as_secs_f64();
        if secs <= 0.0 {
            return 0.0;
        }
        self.count.get() as f64 / secs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_adds() {
        let c = Counter::new();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
    }

    #[test]
    fn meter_rate_positive() {
        let m = Meter::new();
        m.mark(100);
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(m.per_second() > 0.0);
        assert_eq!(m.count(), 100);
    }
}
