//! Counters and throughput meters.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Monotonic event counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn new() -> Self {
        Counter(AtomicU64::new(0))
    }

    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Throughput meter: events per second since the FIRST `mark`.
///
/// The clock starts at the first event, not at construction — a meter
/// built before worker spawn / lazy model build would otherwise fold
/// that idle time into every rate it ever reports, silently deflating
/// serve/bench throughput.
#[derive(Debug)]
pub struct Meter {
    count: Counter,
    created: Instant,
    /// nanoseconds after `created` of the first `mark`; 0 = none yet
    /// (a real first mark in the construction nanosecond is clamped to
    /// 1ns so it never reads as "unset")
    first_mark_ns: AtomicU64,
}

impl Default for Meter {
    fn default() -> Self {
        Self::new()
    }
}

impl Meter {
    pub fn new() -> Self {
        Meter { count: Counter::new(), created: Instant::now(), first_mark_ns: AtomicU64::new(0) }
    }

    pub fn mark(&self, n: u64) {
        if self.first_mark_ns.load(Ordering::Relaxed) == 0 {
            let ns = (self.created.elapsed().as_nanos() as u64).max(1);
            // only the first marker wins; a concurrent earlier mark keeps
            // its (earlier) timestamp
            let _ = self.first_mark_ns.compare_exchange(
                0,
                ns,
                Ordering::Relaxed,
                Ordering::Relaxed,
            );
        }
        self.count.add(n);
    }

    pub fn count(&self) -> u64 {
        self.count.get()
    }

    /// Events per second over the window from the first `mark` to now;
    /// 0.0 before any event.
    pub fn per_second(&self) -> f64 {
        let first_ns = self.first_mark_ns.load(Ordering::Relaxed);
        if first_ns == 0 {
            return 0.0;
        }
        let elapsed_ns = (self.created.elapsed().as_nanos() as u64).saturating_sub(first_ns);
        if elapsed_ns == 0 {
            return 0.0;
        }
        self.count.get() as f64 / (elapsed_ns as f64 / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_adds() {
        let c = Counter::new();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
    }

    #[test]
    fn meter_rate_positive() {
        let m = Meter::new();
        m.mark(100);
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(m.per_second() > 0.0);
        assert_eq!(m.count(), 100);
    }

    #[test]
    fn meter_is_zero_before_any_mark() {
        let m = Meter::new();
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert_eq!(m.per_second(), 0.0);
        assert_eq!(m.count(), 0);
    }

    #[test]
    fn meter_clock_starts_at_first_mark_not_construction() {
        use std::time::Duration;
        // two meters with identical counts and identical post-mark
        // windows, but m_idle spends 400ms idle before its first mark.
        // With the clock at construction both rates would be equal
        // (same construction→measure span); with the clock at the first
        // mark, m_idle's window is ~400ms shorter, so its rate must be
        // clearly higher.  (Sleeps only overshoot; the 1.2 margin fails
        // only if the mark→measure gap stalls for over ~1.9s, far past
        // normal scheduler noise on a loaded CI box.)
        let m_fresh = Meter::new();
        m_fresh.mark(1000);
        let m_idle = Meter::new();
        std::thread::sleep(Duration::from_millis(400)); // worker-init style idle
        m_idle.mark(1000);
        std::thread::sleep(Duration::from_millis(100));
        let fresh = m_fresh.per_second(); // window ≈ 500ms
        let idle = m_idle.per_second(); // window ≈ 100ms — idle excluded
        assert!(
            idle > fresh * 1.2,
            "idle-before-first-mark must not deflate the rate: idle {idle:.0}/s vs fresh {fresh:.0}/s"
        );
    }
}
