//! Serving metrics (S10 in DESIGN.md): latency histograms with
//! p50/p95/p99, counters and throughput meters.  Lock-light: histograms
//! use atomic buckets.

mod histogram;
mod meter;

pub use histogram::Histogram;
pub use meter::{Counter, Meter};
