//! Log-bucketed latency histogram with percentile queries.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Buckets spaced by powers of `2^(1/4)` from 1µs to ~1100s: 124 buckets,
/// ≤ ~19% relative quantization error — plenty for latency reporting.
const BUCKETS: usize = 124;
const BASE_US: f64 = 1.0;

/// Concurrent histogram of durations.
#[derive(Debug)]
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

fn bucket_of(us: f64) -> usize {
    if us <= BASE_US {
        return 0;
    }
    let b = (us / BASE_US).log2() * 4.0;
    (b as usize).min(BUCKETS - 1)
}

fn bucket_upper_us(i: usize) -> f64 {
    BASE_US * 2f64.powf((i + 1) as f64 / 4.0)
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            buckets: (0..BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }

    pub fn record(&self, d: Duration) {
        let us = d.as_secs_f64() * 1e6;
        self.buckets[bucket_of(us)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us as u64, Ordering::Relaxed);
        self.max_us.fetch_max(us as u64, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_us(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            return 0.0;
        }
        self.sum_us.load(Ordering::Relaxed) as f64 / c as f64
    }

    pub fn max_us(&self) -> f64 {
        self.max_us.load(Ordering::Relaxed) as f64
    }

    /// Approximate quantile (`q` in [0,1]) in microseconds.
    pub fn quantile_us(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * total as f64).ceil() as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target.max(1) {
                return bucket_upper_us(i);
            }
        }
        bucket_upper_us(BUCKETS - 1)
    }

    /// One-line "p50/p95/p99/max (n)" summary in milliseconds.
    pub fn summary(&self) -> String {
        format!(
            "p50 {:.3}ms  p95 {:.3}ms  p99 {:.3}ms  max {:.3}ms  mean {:.3}ms  (n={})",
            self.quantile_us(0.50) / 1e3,
            self.quantile_us(0.95) / 1e3,
            self.quantile_us(0.99) / 1e3,
            self.max_us() / 1e3,
            self.mean_us() / 1e3,
            self.count()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_is_zero() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile_us(0.5), 0.0);
        assert_eq!(h.mean_us(), 0.0);
    }

    #[test]
    fn quantiles_ordered_and_rough() {
        let h = Histogram::new();
        for ms in 1..=100u64 {
            h.record(Duration::from_millis(ms));
        }
        let p50 = h.quantile_us(0.5);
        let p95 = h.quantile_us(0.95);
        let p99 = h.quantile_us(0.99);
        assert!(p50 <= p95 && p95 <= p99);
        // ~19% bucket error allowed
        assert!((40_000.0..70_000.0).contains(&p50), "p50 {p50}");
        assert!((80_000.0..130_000.0).contains(&p99), "p99 {p99}");
        assert!((h.mean_us() - 50_500.0).abs() < 2_000.0);
    }

    #[test]
    fn concurrent_records() {
        let h = std::sync::Arc::new(Histogram::new());
        std::thread::scope(|s| {
            for _ in 0..4 {
                let h = h.clone();
                s.spawn(move || {
                    for _ in 0..1000 {
                        h.record(Duration::from_micros(100));
                    }
                });
            }
        });
        assert_eq!(h.count(), 4000);
    }

    #[test]
    fn extreme_values_clamp() {
        let h = Histogram::new();
        h.record(Duration::from_nanos(1));
        h.record(Duration::from_secs(10_000));
        assert_eq!(h.count(), 2);
        assert!(h.quantile_us(1.0) > 0.0);
    }
}
