//! `tensornet` — the launcher.
//!
//! Subcommands regenerate every experiment in the paper (DESIGN.md §5):
//!
//! ```text
//! tensornet fig1       [--quick|--full]        Figure 1 sweep
//! tensornet hashednet  [--quick]               §6.1 HashedNet comparison
//! tensornet cifar      [--quick]               §6.2 CIFAR tails
//! tensornet wide       [--quick]               §6.2.1 wide & shallow net
//! tensornet table2     [--accuracy] [--quick]  Table 2 compression (+proxy)
//! tensornet table3     [--quick]               Table 3 inference timing
//! tensornet bench      [--quick] [--out-dir D] perf baseline -> BENCH_*.json
//! tensornet train      [--model tt|fc] [--rank 8] [--epochs 5]
//!                      [--save DIR] [--init-from CKPT]
//!                                              train (or fine-tune) on MNIST,
//!                                              optionally checkpointing
//! tensornet compress   --from CKPT --to DIR [--rank 8] [--eps 0]
//!                      [--ms 4,4,4,4,4] [--ns 4,4,4,4,4]
//!                                              TT-SVD a dense checkpoint
//! tensornet serve      [--backend native|pjrt] [--executor-threads N]
//!                      [--models DIR]          serve native zoo models,
//!                                              trained checkpoints, or AOT
//!                                              artifacts
//! tensornet inspect    [--artifacts DIR]       list artifacts + variants
//! ```
//!
//! `train --save` → `compress` → `serve --models` is the paper's full
//! train → compress(TT-SVD) → fine-tune → deploy lifecycle (§3.1, §5).

use std::path::Path;
use std::time::Duration;
use tensornet::coordinator::{
    BatchPolicy, ModelRegistry, NativeExecutor, PjrtExecutor, Server, ServerConfig,
};
use tensornet::data::{global_contrast_normalize, synth_mnist};
use tensornet::error::Result;
use tensornet::experiments::*;
use tensornet::nn::{Layer, SgdConfig, TrainConfig, Trainer};
use tensornet::runtime::{Checkpoint, Manifest};
use tensornet::util::bench::print_table;
use tensornet::util::cli::Args;
use tensornet::util::json::Json;
use tensornet::util::rng::Rng;

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    };
    let code = match run(args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: Args) -> Result<()> {
    match args.subcommand.as_deref() {
        Some("fig1") => cmd_fig1(&args),
        Some("hashednet") => cmd_hashednet(&args),
        Some("cifar") => cmd_cifar(&args),
        Some("wide") => cmd_wide(&args),
        Some("table2") => cmd_table2(&args),
        Some("table3") => cmd_table3(&args),
        Some("bench") => cmd_bench(&args),
        Some("train") => cmd_train(&args),
        Some("compress") => cmd_compress(&args),
        Some("serve") => cmd_serve(&args),
        Some("inspect") => cmd_inspect(&args),
        Some(other) => {
            eprintln!("unknown subcommand '{other}'");
            print_usage();
            std::process::exit(2);
        }
        None => {
            print_usage();
            Ok(())
        }
    }
}

fn print_usage() {
    println!(
        "tensornet — Tensorizing Neural Networks (NIPS 2015) reproduction\n\n\
         subcommands:\n\
         \u{20}  fig1 | hashednet | cifar | wide | table2 | table3   experiments\n\
         \u{20}  bench [--quick] [--out-dir DIR]                     perf baseline -> BENCH_*.json\n\
         \u{20}  train [--model tt|fc] [--rank 8] [--epochs 5]       train (or --init-from CKPT to\n\
         \u{20}        [--save DIR] [--init-from CKPT]                fine-tune); --save checkpoints\n\
         \u{20}  compress --from CKPT --to DIR [--rank 8] [--eps 0]  TT-SVD dense checkpoint layers\n\
         \u{20}        [--ms 4,4,4,4,4] [--ns 4,4,4,4,4]              into a TT checkpoint\n\
         \u{20}  serve [--backend native|pjrt] [--model tt_layer]    serve models behind the batcher\n\
         \u{20}        [--models DIR]                                 (native: zoo models or trained\n\
         \u{20}        [--executor-threads N] [--requests 200]        checkpoints from --models DIR;\n\
         \u{20}        [--max-batch 32] [--max-delay-ms 2]            pjrt: AOT artifacts)\n\
         \u{20}  inspect                                             list artifacts\n\
         common flags: --quick, --artifacts DIR (default ./artifacts)\n\
         lifecycle:  train --model fc --save c/dense  ->  compress --from c/dense --to c/tt\n\
         \u{20}           ->  train --init-from c/tt --save c/tt2  ->  serve --models c --model tt2"
    );
}

fn cmd_fig1(args: &Args) -> Result<()> {
    let spec = if args.flag("full") { Fig1Spec::full() } else { Fig1Spec::quick() };
    let points = run_fig1(&spec, true)?;
    let rows: Vec<Vec<String>> = points
        .iter()
        .map(|p| {
            vec![
                p.family.clone(),
                p.rank.to_string(),
                p.layer1_params.to_string(),
                format!("{:.3}", p.test_error),
            ]
        })
        .collect();
    print_table(
        "Figure 1 — error vs params of the compressed 1024x1024 layer",
        &["family", "rank", "layer1 params", "test error"],
        &rows,
    );
    Ok(())
}

fn cmd_hashednet(args: &Args) -> Result<()> {
    let rows = run_hashednet(!args.flag("full"), true)?;
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.label.clone(),
                r.total_params.to_string(),
                format!("{:.3}", r.test_error),
                format!("{:.0}x", r.compression_vs_dense),
            ]
        })
        .collect();
    print_table(
        "§6.1 HashedNet comparison (paper: TT8 12602 params / HashedNet 12720 @ 2.79%)",
        &["architecture", "params", "test error", "compression"],
        &table,
    );
    Ok(())
}

fn cmd_cifar(args: &Args) -> Result<()> {
    let rows = run_cifar(!args.flag("full"), true)?;
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| vec![r.label.clone(), r.tail_params.to_string(), format!("{:.3}", r.test_error)])
        .collect();
    print_table("§6.2 CIFAR tails", &["tail", "params", "test error"], &table);
    Ok(())
}

fn cmd_wide(args: &Args) -> Result<()> {
    let r = run_wide(!args.flag("full"), true)?;
    print_table(
        "§6.2.1 wide & shallow TensorNet",
        &["hidden units", "params", "dense equiv", "error before", "error after"],
        &[vec![
            r.hidden_units.to_string(),
            r.total_params.to_string(),
            r.dense_equivalent.to_string(),
            format!("{:.3}", r.initial_error),
            format!("{:.3}", r.test_error),
        ]],
    );
    Ok(())
}

fn cmd_table2(args: &Args) -> Result<()> {
    let rows = run_table2(args.flag("quick"), args.flag("accuracy"), true)?;
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.arch.clone(),
                format!("{:.0}", r.layer_compression),
                format!("{:.1}", r.vgg16_compression),
                format!("{:.1}", r.vgg19_compression),
                if r.proxy_error.is_nan() { "-".into() } else { format!("{:.3}", r.proxy_error) },
            ]
        })
        .collect();
    print_table(
        "Table 2 — vgg compression (exact) + proxy error ordering",
        &["architecture", "layer compr.", "vgg16 compr.", "vgg19 compr.", "proxy err"],
        &table,
    );
    Ok(())
}

fn cmd_table3(args: &Args) -> Result<()> {
    let rows = run_table3(args.flag("quick"), true)?;
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.kind.clone(),
                r.batch.to_string(),
                format!("{:.3}", r.mean_ms),
                format!("{:.2} MB", r.mem_bytes as f64 / 1048576.0),
            ]
        })
        .collect();
    print_table(
        "Table 3 — 25088x4096 inference (native hot paths)",
        &["layer", "batch", "time", "fwd memory"],
        &table,
    );
    Ok(())
}

fn cmd_bench(args: &Args) -> Result<()> {
    let quick = args.flag("quick");
    let out_dir = args.get_or("out-dir", ".");
    println!(
        "== perf baseline ({}; writing BENCH_*.json to {out_dir})",
        if quick { "quick profile" } else { "full profile" }
    );
    let paths = run_bench_suite(quick, std::path::Path::new(&out_dir), true)?;
    for p in &paths {
        println!("wrote {}", p.display());
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let rank = args.get_usize("rank", 8)?;
    let epochs = args.get_usize("epochs", 5)?;
    let n_train = args.get_usize("train-samples", 4000)?;
    let n_test = args.get_usize("test-samples", 1000)?;
    let lr = args.get_f64("lr", 0.03)? as f32;
    let seed = args.get_usize("seed", 7)? as u64;
    let arch = args.get_or("model", "tt");

    let mut all = synth_mnist(n_train + n_test, seed)?;
    global_contrast_normalize(&mut all.x)?;
    let (train, test) = all.split(n_train)?;

    let mut net: Box<dyn Layer> = match args.get("init-from") {
        Some(ckpt) => {
            // the architecture comes from the checkpoint — silently
            // ignoring --model/--rank would make a scripted sweep produce
            // identical runs that look distinct
            if args.get("model").is_some() || args.get("rank").is_some() {
                return Err(tensornet::error::Error::Config(
                    "--init-from restores the checkpointed architecture; \
                     drop --model/--rank (compress chooses the TT rank)"
                        .into(),
                ));
            }
            // the fine-tune half of compress-then-fine-tune (§5): resume
            // from whatever `train --save` or `compress` wrote
            println!("== fine-tuning from checkpoint {ckpt}");
            Checkpoint::load(ckpt)?.build()?
        }
        None => {
            let mut rng = Rng::new(seed);
            match arch.as_str() {
                "tt" => {
                    println!(
                        "== MNIST TensorNet: TT(1024->1024 4^5, rank {rank}) -> ReLU -> FC(10)"
                    );
                    Box::new(mnist_tensornet(rank, &mut rng)?)
                }
                "fc" => {
                    println!("== MNIST FC baseline: FC(1024->1024) -> ReLU -> FC(10)");
                    Box::new(mnist_fc_baseline(&mut rng))
                }
                other => {
                    return Err(tensornet::error::Error::Config(format!(
                        "--model must be 'tt' or 'fc', got '{other}'"
                    )))
                }
            }
        }
    };
    println!("{}  ({} params)", net.name(), net.num_params());

    let trainer = Trainer::new(TrainConfig {
        epochs,
        batch_size: args.get_usize("batch", 32)?,
        sgd: SgdConfig::with_lr(lr),
        lr_decay: 0.9,
        log_every: args.get_usize("log-every", 50)?,
        seed,
    });
    // for a fine-tune run, the pre-training eval IS the data point the
    // paper's compress-then-fine-tune curve needs (truncation-only error)
    let initial_eval = match args.get("init-from") {
        Some(_) => {
            let rep = trainer.evaluate(&mut *net, &test)?;
            println!(
                "initial:  test loss {:.4}, test error {:.3} (before fine-tuning)",
                rep.loss, rep.error
            );
            Some(rep)
        }
        None => None,
    };
    let hist = trainer.fit(&mut *net, &train, Some(&test))?;
    for (e, (loss, err)) in hist.epochs.iter().enumerate() {
        println!("epoch {:>2}: train loss {loss:.4}, test error {err:.3}", e + 1);
    }
    let final_eval = trainer.evaluate(&mut *net, &test)?;
    println!(
        "final:    test loss {:.4}, test error {:.3} ({} samples)",
        final_eval.loss, final_eval.error, final_eval.n
    );
    println!("wall time: {:.1}s", hist.wall_seconds);

    if let Some(dir) = args.get("save") {
        let dir = Path::new(dir);
        Checkpoint::save(dir, &*net)?;
        // convergence stays inspectable after the process exits
        let mut report = match hist.to_json() {
            Json::Obj(m) => m,
            _ => unreachable!("TrainHistory::to_json returns an object"),
        };
        if let Some(rep) = initial_eval {
            report.insert("initial_eval".to_string(), rep.to_json());
        }
        report.insert("final_eval".to_string(), final_eval.to_json());
        std::fs::write(dir.join("history.json"), Json::Obj(report).to_string())?;
        println!("saved checkpoint + history.json to {}", dir.display());
    }
    Ok(())
}

fn cmd_compress(args: &Args) -> Result<()> {
    let from = args.get("from").ok_or_else(|| {
        tensornet::error::Error::Config("compress needs --from <checkpoint dir>".into())
    })?;
    let to = args.get("to").ok_or_else(|| {
        tensornet::error::Error::Config("compress needs --to <output dir>".into())
    })?;
    let ms = args.get_usize_list("ms", &[4, 4, 4, 4, 4])?;
    let ns = args.get_usize_list("ns", &[4, 4, 4, 4, 4])?;
    let rank = args.get_usize("rank", 8)?;
    let eps = args.get_f64("eps", 0.0)?;
    let max_rank = if rank == 0 { None } else { Some(rank) };
    let m_total: usize = ms.iter().product();
    let n_total: usize = ns.iter().product();

    println!(
        "== compress: TT-SVD every dense {m_total}x{n_total} layer of {from} \
         (modes {ms:?}x{ns:?}, rank cap {}, eps {eps})",
        if rank == 0 { "none".to_string() } else { rank.to_string() }
    );
    let ck = Checkpoint::load(from)?;
    let dense_values = ck.info.num_values;
    let (state, converted) = ck.state.compress_dense(&ms, &ns, max_rank, eps)?;
    if converted == 0 {
        return Err(tensornet::error::Error::Config(format!(
            "no dense {m_total}x{n_total} layer in {from} — check --ms/--ns \
             against the checkpointed architecture"
        )));
    }
    Checkpoint::save_state(to, &state)?;
    let tt_values = state.num_values();
    println!(
        "converted {converted} layer(s): {dense_values} -> {tt_values} stored values \
         ({:.1}x smaller checkpoint)",
        dense_values as f64 / tt_values as f64
    );
    println!("wrote TT checkpoint to {to}  (fine-tune: tensornet train --init-from {to})");
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let backend = args.get_or("backend", "native");
    let dir = args.get_or("artifacts", "artifacts");
    let models_dir = args.get("models");
    let n_requests = args.get_usize("requests", 200)?;
    let concurrency = args.get_usize("concurrency", 8)?.max(1);
    let max_batch = args.get_usize("max-batch", 32)?;
    let max_delay_ms = args.get_usize("max-delay-ms", 2)?;
    let executor_threads = args.get_usize("executor-threads", 1)?;

    let cfg = ServerConfig {
        policy: BatchPolicy {
            max_batch,
            max_delay: Duration::from_millis(max_delay_ms as u64),
        },
        executor_threads,
        ..Default::default()
    };
    let (server, dim, model) = match backend.as_str() {
        "native" => {
            // --models DIR swaps the seed-deterministic zoo for trained
            // checkpoints; without an explicit --model the first (sorted)
            // checkpoint is served
            let registry = match models_dir {
                Some(d) => ModelRegistry::from_dir(d)?,
                None => ModelRegistry::standard(),
            };
            let model = match args.get("model") {
                Some(m) => m.to_string(),
                None if models_dir.is_some() => {
                    registry.names().first().expect("from_dir is non-empty").to_string()
                }
                None => "tt_layer".to_string(),
            };
            let source = models_dir.map_or_else(
                || "native backend".to_string(),
                |d| format!("checkpoints in {d}"),
            );
            println!(
                "== serving '{model}' ({source}) \
                 ({n_requests} requests, {concurrency} clients, {executor_threads} executor threads)"
            );
            // unknown --model errors here, listing the registered names
            let dim = registry.input_dim(&model)?;
            (Server::start(cfg, move || Ok(NativeExecutor::new(registry.clone())))?, dim, model)
        }
        "pjrt" => {
            if models_dir.is_some() {
                return Err(tensornet::error::Error::Config(
                    "--models serves native checkpoints; use --artifacts with --backend pjrt"
                        .into(),
                ));
            }
            let model = args.get_or("model", "tt_layer");
            println!(
                "== serving '{model}' from {dir} \
                 ({n_requests} requests, {concurrency} clients, {executor_threads} executor threads)"
            );
            // discover input dim from the manifest
            let manifest = Manifest::load(&dir)?;
            let spec = manifest
                .artifacts
                .iter()
                .find(|a| a.name.starts_with(&model))
                .ok_or_else(|| {
                    let names: Vec<&str> =
                        manifest.artifacts.iter().map(|a| a.name.as_str()).collect();
                    tensornet::error::Error::Config(format!(
                        "no artifacts match '{model}' (available: {})",
                        names.join(", ")
                    ))
                })?;
            let dim = spec.runtime_inputs()[0].shape[1];
            let dir2 = dir.clone();
            (Server::start(cfg, move || PjrtExecutor::new(&dir2))?, dim, model)
        }
        other => {
            return Err(tensornet::error::Error::Config(format!(
                "--backend must be 'native' or 'pjrt', got '{other}'"
            )))
        }
    };

    let wall = drive_clients(&server, &model, dim, n_requests, concurrency);
    let stats = server.stats();
    println!("completed:  {}", stats.completed.get());
    println!("errors:     {}", stats.errors.get());
    println!("throughput: {:.1} req/s (wall {:.2}s)", stats.completed.get() as f64 / wall, wall);
    println!("mean batch: {:.2}", stats.mean_batch_size());
    println!("e2e:   {}", stats.e2e.summary());
    println!("exec:  {}", stats.exec.summary());
    println!("queue: {}", stats.queue.summary());
    // gate on completions and pool health, not just counted errors: a
    // reply channel dropped by a dying worker fails the caller without
    // touching stats.errors, and a worker whose init failed leaves the
    // pool silently degraded — both must fail the run (CI smokes on this)
    if stats.errors.get() > 0
        || stats.completed.get() != n_requests as u64
        || stats.failed_workers.get() > 0
    {
        return Err(tensornet::error::Error::Coordinator(format!(
            "{} of {n_requests} requests completed, {} errored, {} workers failed init",
            stats.completed.get(),
            stats.errors.get(),
            stats.failed_workers.get()
        )));
    }
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let dir = args.get_or("artifacts", "artifacts");
    let manifest = Manifest::load(&dir)?;
    println!("artifacts in {dir} (seed {}):", manifest.seed);
    for a in &manifest.artifacts {
        let runtime: Vec<String> = a
            .runtime_inputs()
            .iter()
            .map(|i| format!("{}{:?}", i.name, i.shape))
            .collect();
        println!(
            "  {:<24} inputs: {:<3} runtime: {:<28} outputs: {:?}",
            a.name,
            a.inputs.len(),
            runtime.join(", "),
            a.outputs.iter().map(|o| format!("{:?}", o.shape)).collect::<Vec<_>>()
        );
    }
    for (name, g) in &manifest.weight_groups {
        let total: usize = g.layout.iter().map(|(_, _, _, l)| l).sum();
        println!("  weights '{name}': {} tensors, {} params", g.layout.len(), total);
    }
    Ok(())
}
