//! `tensornet` — the launcher.
//!
//! Subcommands regenerate every experiment in the paper (DESIGN.md §5):
//!
//! ```text
//! tensornet fig1       [--quick|--full]        Figure 1 sweep
//! tensornet hashednet  [--quick]               §6.1 HashedNet comparison
//! tensornet cifar      [--quick]               §6.2 CIFAR tails
//! tensornet wide       [--quick]               §6.2.1 wide & shallow net
//! tensornet table2     [--accuracy] [--quick]  Table 2 compression (+proxy)
//! tensornet table3     [--quick]               Table 3 inference timing
//! tensornet bench      [--quick] [--out-dir D] perf baseline -> BENCH_*.json
//! tensornet train      [--model tt|fc|conv|bt] [--rank 8] [--blocks 4]
//!                      [--epochs 5] [--save DIR] [--init-from CKPT]
//!                                              train (or fine-tune) on MNIST,
//!                                              optionally checkpointing
//! tensornet compress   --from CKPT --to DIR [--family tt|bt|tt-conv]
//!                      [--rank 8] [--eps 0] [--blocks 4]
//!                      [--ms 4,4,4,4,4] [--ns 4,4,4,4,4]
//!                                              SVD-compress a dense checkpoint
//! tensornet serve      [--backend native|pjrt] [--executor-threads N]
//!                      [--models DIR]          serve native zoo models,
//!                      [--listen ADDR]         trained checkpoints, or AOT
//!                      [--io-threads N]        artifacts; --listen exposes
//!                      [--kernel-threads K]    the server over TCP (N
//!                      [--max-queue N]         reactor threads, default 1);
//!                      [--latency-target-ms T] K caps each executor
//!                      [--quota M=N[,..]]      worker's intra-batch kernel
//!                      [--overload-after-ms W] fan-out (0 = cores/workers);
//!                                              admission: N tickets bound
//!                                              in-flight work, T > 0 adapts
//!                                              capacity to a p95 target,
//!                                              quotas reserve per-model
//!                                              slots, W ms of saturation
//!                                              flips the queue FIFO->LIFO
//! tensornet client     --connect ADDR [--model A[,B,..]] [--requests N]
//!                      [--connections C] [--pipeline P] [--shutdown]
//!                      [--timeout-ms T]        drive a remote server over
//!                                              the wire protocol; a comma-
//!                                              separated --model list
//!                                              interleaves models 1:1
//! tensornet router     --shards A,B,.. [--listen ADDR] [--replicas M]
//!                      [--io-threads N]        front N serve daemons:
//!                                              least-loaded dispatch over
//!                                              discovered placement, with
//!                                              failover (DESIGN.md §13)
//! tensornet fleet      [--shards N] [--listen ADDR] [--replicas M]
//!                                              launch N serve shards as
//!                                              child processes + a router
//!                                              in front, as one command
//! tensornet inspect    [--artifacts DIR]       list artifacts + variants
//! ```
//!
//! `train --save` → `compress` → `serve --models` is the paper's full
//! train → compress(TT-SVD) → fine-tune → deploy lifecycle (§3.1, §5);
//! `serve --listen` + `client --connect` is the same server reached over
//! the TCP wire protocol (DESIGN.md §12).

use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};
use tensornet::coordinator::{
    AdmissionConfig, BatchPolicy, Client, ModelInfo, ModelRegistry, NativeExecutor, NetServer,
    PjrtExecutor, QueueMode, RemoteStats, RouterConfig, Server, ServerConfig, ShardRouter,
    ShardSnapshot,
};
use tensornet::data::{global_contrast_normalize, synth_mnist};
use tensornet::error::Result;
use tensornet::experiments::*;
use tensornet::nn::{
    bt_classifier, mnist_convnet, Compression, Layer, SgdConfig, TrainConfig, Trainer,
};
use tensornet::runtime::{Checkpoint, Manifest};
use tensornet::util::bench::print_table;
use tensornet::util::cli::Args;
use tensornet::util::json::Json;
use tensornet::util::rng::Rng;

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    };
    let code = match run(args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: Args) -> Result<()> {
    match args.subcommand.as_deref() {
        Some("fig1") => cmd_fig1(&args),
        Some("hashednet") => cmd_hashednet(&args),
        Some("cifar") => cmd_cifar(&args),
        Some("wide") => cmd_wide(&args),
        Some("table2") => cmd_table2(&args),
        Some("table3") => cmd_table3(&args),
        Some("bench") => cmd_bench(&args),
        Some("train") => cmd_train(&args),
        Some("compress") => cmd_compress(&args),
        Some("serve") => cmd_serve(&args),
        Some("client") => cmd_client(&args),
        Some("router") => cmd_router(&args),
        Some("fleet") => cmd_fleet(&args),
        Some("inspect") => cmd_inspect(&args),
        Some(other) => {
            eprintln!("unknown subcommand '{other}'");
            print_usage();
            std::process::exit(2);
        }
        None => {
            print_usage();
            Ok(())
        }
    }
}

fn print_usage() {
    println!(
        "tensornet — Tensorizing Neural Networks (NIPS 2015) reproduction\n\n\
         subcommands:\n\
         \u{20}  fig1 | hashednet | cifar | wide | table2 | table3   experiments\n\
         \u{20}  bench [--quick] [--out-dir DIR]                     perf baseline -> BENCH_*.json\n\
         \u{20}  train [--model tt|fc|conv|bt] [--rank 8]            train (or --init-from CKPT to\n\
         \u{20}        [--blocks 4] [--epochs 5]                      fine-tune); --save checkpoints;\n\
         \u{20}        [--save DIR] [--init-from CKPT]                conv = dense conv-MNIST net,\n\
         \u{20}                                                       bt = block-term 1024x1024 layer\n\
         \u{20}  compress --from CKPT --to DIR [--rank 8] [--eps 0]  SVD-compress checkpoint layers:\n\
         \u{20}        [--family tt|bt|tt-conv] [--blocks 4]          tt: dense FC -> TT (TT-SVD),\n\
         \u{20}        [--ms 4,4,4,4,4] [--ns 4,4,4,4,4]              bt: dense FC -> block-term,\n\
         \u{20}                                                       tt-conv: conv kernel -> TT via\n\
         \u{20}                                                       the Garipov reshape; prints a\n\
         \u{20}                                                       per-layer compression report\n\
         \u{20}  serve [--backend native|pjrt] [--model tt_layer]    serve models behind the batcher\n\
         \u{20}        [--models DIR] [--listen ADDR]                 (native: zoo models or trained\n\
         \u{20}        [--executor-threads N] [--requests 200]        checkpoints from --models DIR;\n\
         \u{20}        [--max-batch 32] [--max-delay-ms 2]            pjrt: AOT artifacts); --listen\n\
         \u{20}        [--io-threads 1] [--kernel-threads 0]          serves TCP until a wire Shutdown\n\
         \u{20}        [--max-queue 1024]                             (reactor I/O threads, default 1);\n\
         \u{20}        [--latency-target-ms 0] [--quota M=N,..]       --kernel-threads caps per-worker\n\
         \u{20}        [--overload-after-ms 2000]                     tensor fan-out (0 = cores/workers;\n\
         \u{20}                                                       TENSORNET_THREADS caps the pool,\n\
         \u{20}                                                       TENSORNET_SIMD=off forces scalar);\n\
         \u{20}                                                       admission: --max-queue tickets\n\
         \u{20}                                                       bound in-flight work, a latency\n\
         \u{20}                                                       target adapts capacity to p95,\n\
         \u{20}                                                       --quota reserves per-model slots,\n\
         \u{20}                                                       sustained saturation goes LIFO\n\
         \u{20}  client --connect ADDR [--model A[,B,..]]            drive a remote server: N requests\n\
         \u{20}        [--requests 100] [--connections 1]             over C connections, P pipelined\n\
         \u{20}        [--pipeline 4] [--timeout-ms 30000]            each; a comma-separated --model\n\
         \u{20}        [--shutdown]                                   list interleaves models 1:1;\n\
         \u{20}                                                       --timeout-ms bounds connect+read\n\
         \u{20}                                                       (0 = no timeout); --shutdown\n\
         \u{20}                                                       stops the server\n\
         \u{20}  router --shards A,B,.. [--listen ADDR]              front running serve daemons:\n\
         \u{20}        [--replicas M] [--io-threads 1]                placement from each shard's\n\
         \u{20}        [--timeout-ms 5000]                            ModelList, least-loaded dispatch,\n\
         \u{20}                                                       failover with typed errors;\n\
         \u{20}                                                       --replicas caps copies per model\n\
         \u{20}                                                       (0 = every advertising shard)\n\
         \u{20}  fleet [--shards 2] [--listen ADDR] [--replicas M]   spawn N serve shards as children\n\
         \u{20}                                                       + a router in front (serve flags\n\
         \u{20}                                                       pass through to every shard);\n\
         \u{20}                                                       one wire Shutdown stops it all\n\
         \u{20}  inspect                                             list artifacts\n\
         common flags: --quick, --artifacts DIR (default ./artifacts)\n\
         lifecycle:  train --model fc --save c/dense  ->  compress --from c/dense --to c/tt\n\
         \u{20}           ->  train --init-from c/tt --save c/tt2  ->  serve --models c --model tt2\n\
         remote:     serve --listen 127.0.0.1:7070  ->  client --connect 127.0.0.1:7070\n\
         sharded:    fleet --shards 4  (or: N x serve --listen + router --shards A,B,..)"
    );
}

fn cmd_fig1(args: &Args) -> Result<()> {
    let spec = if args.flag("full") { Fig1Spec::full() } else { Fig1Spec::quick() };
    let points = run_fig1(&spec, true)?;
    let rows: Vec<Vec<String>> = points
        .iter()
        .map(|p| {
            vec![
                p.family.clone(),
                p.rank.to_string(),
                p.layer1_params.to_string(),
                format!("{:.3}", p.test_error),
            ]
        })
        .collect();
    print_table(
        "Figure 1 — error vs params of the compressed 1024x1024 layer",
        &["family", "rank", "layer1 params", "test error"],
        &rows,
    );
    Ok(())
}

fn cmd_hashednet(args: &Args) -> Result<()> {
    let rows = run_hashednet(!args.flag("full"), true)?;
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.label.clone(),
                r.total_params.to_string(),
                format!("{:.3}", r.test_error),
                format!("{:.0}x", r.compression_vs_dense),
            ]
        })
        .collect();
    print_table(
        "§6.1 HashedNet comparison (paper: TT8 12602 params / HashedNet 12720 @ 2.79%)",
        &["architecture", "params", "test error", "compression"],
        &table,
    );
    Ok(())
}

fn cmd_cifar(args: &Args) -> Result<()> {
    let rows = run_cifar(!args.flag("full"), true)?;
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| vec![r.label.clone(), r.tail_params.to_string(), format!("{:.3}", r.test_error)])
        .collect();
    print_table("§6.2 CIFAR tails", &["tail", "params", "test error"], &table);
    Ok(())
}

fn cmd_wide(args: &Args) -> Result<()> {
    let r = run_wide(!args.flag("full"), true)?;
    print_table(
        "§6.2.1 wide & shallow TensorNet",
        &["hidden units", "params", "dense equiv", "error before", "error after"],
        &[vec![
            r.hidden_units.to_string(),
            r.total_params.to_string(),
            r.dense_equivalent.to_string(),
            format!("{:.3}", r.initial_error),
            format!("{:.3}", r.test_error),
        ]],
    );
    Ok(())
}

fn cmd_table2(args: &Args) -> Result<()> {
    let rows = run_table2(args.flag("quick"), args.flag("accuracy"), true)?;
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.arch.clone(),
                format!("{:.0}", r.layer_compression),
                format!("{:.1}", r.vgg16_compression),
                format!("{:.1}", r.vgg19_compression),
                if r.proxy_error.is_nan() { "-".into() } else { format!("{:.3}", r.proxy_error) },
            ]
        })
        .collect();
    print_table(
        "Table 2 — vgg compression (exact) + proxy error ordering",
        &["architecture", "layer compr.", "vgg16 compr.", "vgg19 compr.", "proxy err"],
        &table,
    );
    Ok(())
}

fn cmd_table3(args: &Args) -> Result<()> {
    let rows = run_table3(args.flag("quick"), true)?;
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.kind.clone(),
                r.batch.to_string(),
                format!("{:.3}", r.mean_ms),
                format!("{:.2} MB", r.mem_bytes as f64 / 1048576.0),
            ]
        })
        .collect();
    print_table(
        "Table 3 — 25088x4096 inference (native hot paths)",
        &["layer", "batch", "time", "fwd memory"],
        &table,
    );
    Ok(())
}

fn cmd_bench(args: &Args) -> Result<()> {
    let quick = args.flag("quick");
    let out_dir = args.get_or("out-dir", ".");
    println!(
        "== perf baseline ({}; writing BENCH_*.json to {out_dir})",
        if quick { "quick profile" } else { "full profile" }
    );
    let paths = run_bench_suite(quick, std::path::Path::new(&out_dir), true)?;
    for p in &paths {
        println!("wrote {}", p.display());
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let rank = args.get_usize("rank", 8)?;
    let blocks = args.get_usize("blocks", 4)?;
    let epochs = args.get_usize("epochs", 5)?;
    let n_train = args.get_usize("train-samples", 4000)?;
    let n_test = args.get_usize("test-samples", 1000)?;
    let lr = args.get_f64("lr", 0.03)? as f32;
    let seed = args.get_usize("seed", 7)? as u64;
    let arch = args.get_or("model", "tt");

    let mut all = synth_mnist(n_train + n_test, seed)?;
    global_contrast_normalize(&mut all.x)?;
    let (train, test) = all.split(n_train)?;

    let mut net: Box<dyn Layer> = match args.get("init-from") {
        Some(ckpt) => {
            // the architecture comes from the checkpoint — silently
            // ignoring --model/--rank/--blocks would make a scripted sweep
            // produce identical runs that look distinct
            if args.get("model").is_some()
                || args.get("rank").is_some()
                || args.get("blocks").is_some()
            {
                return Err(tensornet::error::Error::Config(
                    "--init-from restores the checkpointed architecture; \
                     drop --model/--rank/--blocks (compress chooses the ranks)"
                        .into(),
                ));
            }
            // the fine-tune half of compress-then-fine-tune (§5): resume
            // from whatever `train --save` or `compress` wrote
            println!("== fine-tuning from checkpoint {ckpt}");
            Checkpoint::load(ckpt)?.build()?
        }
        None => {
            let mut rng = Rng::new(seed);
            match arch.as_str() {
                "tt" => {
                    println!(
                        "== MNIST TensorNet: TT(1024->1024 4^5, rank {rank}) -> ReLU -> FC(10)"
                    );
                    Box::new(mnist_tensornet(rank, &mut rng)?)
                }
                "fc" => {
                    println!("== MNIST FC baseline: FC(1024->1024) -> ReLU -> FC(10)");
                    Box::new(mnist_fc_baseline(&mut rng))
                }
                "conv" => {
                    // the dense parent of the conv->TT-conv compress path
                    println!(
                        "== MNIST convnet: Conv(1x32x32 -> 8x16x16) -> ReLU -> FC(2048->10)"
                    );
                    Box::new(mnist_convnet(&mut rng)?)
                }
                "bt" => {
                    println!(
                        "== MNIST BT-Net: BT(1024->1024, {blocks} blocks x rank {rank}) \
                         -> ReLU -> FC(10)"
                    );
                    Box::new(bt_classifier(1024, 1024, blocks, rank, 10, &mut rng)?.0)
                }
                other => {
                    return Err(tensornet::error::Error::Config(format!(
                        "--model must be 'tt', 'fc', 'conv' or 'bt', got '{other}'"
                    )))
                }
            }
        }
    };
    println!("{}  ({} params)", net.name(), net.num_params());

    let trainer = Trainer::new(TrainConfig {
        epochs,
        batch_size: args.get_usize("batch", 32)?,
        sgd: SgdConfig::with_lr(lr),
        lr_decay: 0.9,
        log_every: args.get_usize("log-every", 50)?,
        seed,
    });
    // for a fine-tune run, the pre-training eval IS the data point the
    // paper's compress-then-fine-tune curve needs (truncation-only error)
    let initial_eval = match args.get("init-from") {
        Some(_) => {
            let rep = trainer.evaluate(&mut *net, &test)?;
            println!(
                "initial:  test loss {:.4}, test error {:.3} (before fine-tuning)",
                rep.loss, rep.error
            );
            Some(rep)
        }
        None => None,
    };
    let hist = trainer.fit(&mut *net, &train, Some(&test))?;
    for (e, (loss, err)) in hist.epochs.iter().enumerate() {
        println!("epoch {:>2}: train loss {loss:.4}, test error {err:.3}", e + 1);
    }
    let final_eval = trainer.evaluate(&mut *net, &test)?;
    println!(
        "final:    test loss {:.4}, test error {:.3} ({} samples)",
        final_eval.loss, final_eval.error, final_eval.n
    );
    println!("wall time: {:.1}s", hist.wall_seconds);

    if let Some(dir) = args.get("save") {
        let dir = Path::new(dir);
        Checkpoint::save(dir, &*net)?;
        // convergence stays inspectable after the process exits
        let mut report = match hist.to_json() {
            Json::Obj(m) => m,
            _ => unreachable!("TrainHistory::to_json returns an object"),
        };
        if let Some(rep) = initial_eval {
            report.insert("initial_eval".to_string(), rep.to_json());
        }
        report.insert("final_eval".to_string(), final_eval.to_json());
        std::fs::write(dir.join("history.json"), Json::Obj(report).to_string())?;
        println!("saved checkpoint + history.json to {}", dir.display());
    }
    Ok(())
}

fn cmd_compress(args: &Args) -> Result<()> {
    let from = args.get("from").ok_or_else(|| {
        tensornet::error::Error::Config("compress needs --from <checkpoint dir>".into())
    })?;
    let to = args.get("to").ok_or_else(|| {
        tensornet::error::Error::Config("compress needs --to <output dir>".into())
    })?;
    let family = args.get_or("family", "tt");
    let ms = args.get_usize_list("ms", &[4, 4, 4, 4, 4])?;
    let ns = args.get_usize_list("ns", &[4, 4, 4, 4, 4])?;
    let rank = args.get_usize("rank", 8)?;
    let blocks = args.get_usize("blocks", 4)?;
    let eps = args.get_f64("eps", 0.0)?;
    let max_rank = if rank == 0 { None } else { Some(rank) };
    let m_total: usize = ms.iter().product();
    let n_total: usize = ns.iter().product();
    let rank_str = if rank == 0 { "none".to_string() } else { rank.to_string() };

    let spec = match family.as_str() {
        "tt" => {
            println!(
                "== compress: TT-SVD every dense {m_total}x{n_total} layer of {from} \
                 (modes {ms:?}x{ns:?}, rank cap {rank_str}, eps {eps})"
            );
            Compression::DenseToTt { ms: ms.clone(), ns: ns.clone(), max_rank, eps }
        }
        "bt" => {
            if rank == 0 {
                return Err(tensornet::error::Error::Config(
                    "--family bt needs a positive --rank (the per-block Tucker rank)".into(),
                ));
            }
            println!(
                "== compress: split every dense {m_total}x{n_total} layer of {from} \
                 into {blocks} block-terms of rank {rank} (eps {eps})"
            );
            Compression::DenseToBt { n_out: m_total, n_in: n_total, blocks, rank, eps }
        }
        "tt-conv" => {
            println!(
                "== compress: TT-SVD every dense conv kernel of {from} via the \
                 Garipov reshape (rank cap {rank_str}, eps {eps})"
            );
            Compression::ConvToTt { max_rank, eps }
        }
        other => {
            return Err(tensornet::error::Error::Config(format!(
                "--family must be 'tt', 'bt' or 'tt-conv', got '{other}'"
            )))
        }
    };
    let ck = Checkpoint::load(from)?;
    let dense_values = ck.info.num_values;
    let (state, report) = ck.state.compress(&spec)?;
    if report.is_empty() {
        return Err(tensornet::error::Error::Config(format!(
            "no layer in {from} matches --family {family} — check the flags \
             against the checkpointed architecture"
        )));
    }
    Checkpoint::save_state(to, &state)?;
    // per-layer provenance: which layer converted to what, how many stored
    // values it costs now, and the ranks the tolerance actually achieved
    println!("per-layer:");
    for r in &report {
        println!(
            "  {:<12} {} -> {:<9} {} -> {} values ({:.1}x)  ranks {:?}",
            r.path, r.from_kind, r.to_kind, r.from_values, r.to_values, r.ratio(), r.ranks
        );
    }
    let out_values = state.num_values();
    println!(
        "converted {} layer(s): {dense_values} -> {out_values} stored values \
         ({:.1}x smaller checkpoint)",
        report.len(),
        dense_values as f64 / out_values as f64
    );
    println!(
        "wrote {family} checkpoint to {to}  (fine-tune: tensornet train --init-from {to})"
    );
    Ok(())
}

/// The serve end-of-run summary — load-shedding (`rejected`) and pool
/// degradation (`failed workers`) included, so a run that silently shed
/// or limped is visible in the log, not just in the exit code; the
/// per-model block makes batch efficiency visible per model (the
/// aggregate can hide one model batching well while another runs at
/// batch 1).  The CI interleave smoke greps the per-model lines — keep
/// the format stable.
fn print_serve_summary(server: &Server, wall: f64) {
    let stats = server.stats();
    println!("completed:  {}", stats.completed.get());
    println!(
        "rejected:   {} (admission shed; {} against per-model quotas)",
        stats.rejected.get(),
        stats.quota_shed.get()
    );
    println!("errors:     {}", stats.errors.get());
    println!("failed workers: {}", stats.failed_workers.get());
    println!("throughput: {:.1} req/s (wall {:.2}s)", stats.completed.get() as f64 / wall, wall);
    // the Meter clocks from the first executed batch, so worker init and
    // lazy model builds don't deflate the executor-side rate the way the
    // wall-clock number above includes them
    println!("exec rate:  {:.1} rows/s (since first batch)", stats.throughput.per_second());
    println!("mean batch: {:.2}", stats.mean_batch_size());
    // admission provenance: where the capacity controller ended up and
    // whether the run ever went into overload (LIFO) mode
    let adm = server.admission().snapshot();
    println!(
        "admission:  capacity {} (observed min {} max {}) mode {} flips {}",
        adm.capacity,
        adm.capacity_min,
        adm.capacity_max,
        match adm.mode {
            QueueMode::Fifo => "fifo",
            QueueMode::Lifo => "lifo",
        },
        adm.mode_flips,
    );
    println!("e2e:   {}", stats.e2e.summary());
    println!("exec:  {}", stats.exec.summary());
    println!("queue: {}", stats.queue.summary());
    let per_model = stats.per_model();
    if !per_model.is_empty() {
        println!("per-model:");
        for (name, m) in per_model {
            println!(
                "  {name:<12} completed {} errors {} shed {} batches {} rows {} mean batch {:.2}  e2e {}",
                m.completed.get(),
                m.errors.get(),
                m.shed.get(),
                m.batches.get(),
                m.batched_rows.get(),
                m.mean_batch_size(),
                m.e2e.summary(),
            );
        }
    }
}

/// Parse `--quota MODEL=N[,MODEL=N...]` into admission reservations.
fn parse_quotas(spec: Option<&str>) -> Result<Vec<(String, usize)>> {
    let mut quotas = Vec::new();
    if let Some(spec) = spec {
        for part in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            let Some((name, slots)) = part.split_once('=') else {
                return Err(tensornet::error::Error::Config(format!(
                    "--quota expects MODEL=N[,MODEL=N...], got '{part}'"
                )));
            };
            let name = name.trim();
            let slots: usize = slots.trim().parse().map_err(|_| {
                tensornet::error::Error::Config(format!(
                    "--quota {part}: '{}' is not a slot count",
                    slots.trim()
                ))
            })?;
            if name.is_empty() {
                return Err(tensornet::error::Error::Config(format!(
                    "--quota {part}: empty model name"
                )));
            }
            quotas.push((name.to_string(), slots));
        }
    }
    Ok(quotas)
}

fn cmd_serve(args: &Args) -> Result<()> {
    let backend = args.get_or("backend", "native");
    let dir = args.get_or("artifacts", "artifacts");
    let models_dir = args.get("models");
    let n_requests = args.get_usize("requests", 200)?;
    let concurrency = args.get_usize("concurrency", 8)?.max(1);
    let max_batch = args.get_usize("max-batch", 32)?;
    let max_delay_ms = args.get_usize("max-delay-ms", 2)?;
    let executor_threads = args.get_usize("executor-threads", 1)?;
    let io_threads = args.get_usize("io-threads", 1)?.max(1);
    let kernel_threads = args.get_usize("kernel-threads", 0)?;
    let queue_capacity = args.get_usize("max-queue", 1024)?.max(1);
    let latency_target_ms = args.get_usize("latency-target-ms", 0)? as u64;
    let overload_after_ms = args.get_usize("overload-after-ms", 2_000)?.max(1) as u64;
    let quotas = parse_quotas(args.get("quota"))?;
    let listen = args.get("listen");

    let cfg = ServerConfig {
        policy: BatchPolicy {
            max_batch,
            max_delay: Duration::from_millis(max_delay_ms as u64),
        },
        executor_threads,
        kernel_threads,
        queue_capacity,
        admission: AdmissionConfig {
            latency_target_ms,
            overload_after: Duration::from_millis(overload_after_ms),
            quotas,
            ..Default::default()
        },
        ..Default::default()
    };
    let (server, dim, model, lineup) = match backend.as_str() {
        "native" => {
            // --models DIR swaps the seed-deterministic zoo for trained
            // checkpoints; without an explicit --model the first (sorted)
            // checkpoint is served
            let registry = match models_dir {
                Some(d) => ModelRegistry::from_dir(d)?,
                None => ModelRegistry::standard(),
            };
            let model = match args.get("model") {
                Some(m) => m.to_string(),
                None if models_dir.is_some() => {
                    registry.names().first().expect("from_dir is non-empty").to_string()
                }
                None => "tt_layer".to_string(),
            };
            let source = models_dir.map_or_else(
                || "native backend".to_string(),
                |d| format!("checkpoints in {d}"),
            );
            println!(
                "== serving '{model}' ({source}, {executor_threads} executor threads x {} kernel threads)",
                cfg.effective_kernel_threads()
            );
            // the full registry is advertised over the wire, not just the
            // locally-driven model
            let lineup: Vec<ModelInfo> = registry
                .names()
                .iter()
                .map(|n| {
                    let spec = registry.spec(n).expect("name is registered");
                    ModelInfo {
                        name: n.to_string(),
                        input_dim: spec.input_dim() as u32,
                        output_dim: spec.output_dim() as u32,
                    }
                })
                .collect();
            // unknown --model errors here, listing the registered names
            let dim = registry.input_dim(&model)?;
            (
                Server::start(cfg, move || Ok(NativeExecutor::new(registry.clone())))?,
                dim,
                model,
                lineup,
            )
        }
        "pjrt" => {
            if models_dir.is_some() {
                return Err(tensornet::error::Error::Config(
                    "--models serves native checkpoints; use --artifacts with --backend pjrt"
                        .into(),
                ));
            }
            let model = args.get_or("model", "tt_layer");
            println!(
                "== serving '{model}' from {dir} ({executor_threads} executor threads)"
            );
            let manifest = Manifest::load(&dir)?;
            // advertise EVERY artifact, not just the driven model — the
            // TCP front-end validates requests against this lineup, and
            // the executor can serve any artifact (same policy as the
            // native branch's full-registry lineup above).  The manifest
            // is external on-disk data: an artifact without a [batch,
            // dim]-shaped runtime input or output is skipped, not a
            // panic source.
            let mut lineup: Vec<ModelInfo> = manifest
                .artifacts
                .iter()
                .filter_map(|a| {
                    let input_dim = *a.runtime_inputs().first()?.shape.get(1)?;
                    let output_dim = *a.outputs.first()?.shape.get(1)?;
                    Some(ModelInfo {
                        name: a.name.clone(),
                        input_dim: input_dim as u32,
                        output_dim: output_dim as u32,
                    })
                })
                .collect();
            // resolve the driven model from the lineup (prefix match, as
            // before) — a malformed artifact spec surfaces here as a
            // clean Config error, never an index panic
            let resolved = lineup
                .iter()
                .find(|m| m.name.starts_with(&model))
                .cloned()
                .ok_or_else(|| {
                    let names: Vec<&str> =
                        lineup.iter().map(|m| m.name.as_str()).collect();
                    tensornet::error::Error::Config(format!(
                        "no servable artifacts match '{model}' (available: {})",
                        names.join(", ")
                    ))
                })?;
            let dim = resolved.input_dim as usize;
            let out_dim = resolved.output_dim as usize;
            if !lineup.iter().any(|m| m.name == model) {
                // `--model` may be a prefix of an artifact name; keep it
                // reachable over the wire under the name clients use
                lineup.push(ModelInfo {
                    name: model.clone(),
                    input_dim: dim as u32,
                    output_dim: out_dim as u32,
                });
            }
            let dir2 = dir.clone();
            (Server::start(cfg, move || PjrtExecutor::new(&dir2))?, dim, model, lineup)
        }
        other => {
            return Err(tensornet::error::Error::Config(format!(
                "--backend must be 'native' or 'pjrt', got '{other}'"
            )))
        }
    };

    if let Some(addr) = listen {
        // daemon mode: requests arrive over TCP; runs until a client's
        // wire Shutdown frame (tensornet client --shutdown)
        let server = Arc::new(server);
        let net = NetServer::start_with(server.clone(), addr, lineup, io_threads)?;
        let t0 = Instant::now();
        // the bound address line is the machine-readable handshake the CI
        // loopback smoke greps for — keep the format stable
        println!("listening on {}", net.local_addr());
        println!(
            "transport: {} reactor thread(s) + accept ({} total)",
            net.io_threads(),
            net.transport_threads()
        );
        net.wait_for_shutdown();
        println!("wire shutdown received — draining connections");
        net.shutdown();
        let wall = t0.elapsed().as_secs_f64().max(1e-9);
        let stats = server.stats();
        print_serve_summary(&server, wall);
        // remote request errors belong to the clients that sent them; the
        // daemon's own health gate is the executor pool
        if stats.failed_workers.get() > 0 {
            return Err(tensornet::error::Error::Coordinator(format!(
                "{} executor workers failed init",
                stats.failed_workers.get()
            )));
        }
        return Ok(());
    }

    println!("driving {n_requests} requests from {concurrency} in-process clients");
    let wall = drive_clients(&server, &model, dim, n_requests, concurrency);
    let stats = server.stats();
    print_serve_summary(&server, wall);
    // gate on completions and pool health, not just counted errors: a
    // reply channel dropped by a dying worker fails the caller without
    // touching stats.errors, and a worker whose init failed leaves the
    // pool silently degraded — both must fail the run (CI smokes on this)
    if stats.errors.get() > 0
        || stats.completed.get() != n_requests as u64
        || stats.failed_workers.get() > 0
    {
        return Err(tensornet::error::Error::Coordinator(format!(
            "{} of {n_requests} requests completed, {} errored, {} workers failed init",
            stats.completed.get(),
            stats.errors.get(),
            stats.failed_workers.get()
        )));
    }
    Ok(())
}

fn cmd_client(args: &Args) -> Result<()> {
    let addr = args.get("connect").ok_or_else(|| {
        tensornet::error::Error::Config("client needs --connect <addr> (as printed by serve --listen)".into())
    })?;
    let n_requests = args.get_usize("requests", 100)?;
    let connections = args.get_usize("connections", 1)?.max(1);
    let pipeline = args.get_usize("pipeline", 4)?.max(1);
    // bound on connect + each reply wait, so a hung or unreachable
    // server fails the CLI instead of blocking it forever; 0 disables
    let timeout_ms = args.get_usize("timeout-ms", 30_000)?;
    let timeout = (timeout_ms > 0).then(|| Duration::from_millis(timeout_ms as u64));

    // the probe connection discovers the lineup and, at the end, fetches
    // server-side stats / requests shutdown — the drive uses its own
    // connections so the probe never skews timings
    let mut probe = match timeout {
        Some(t) => Client::connect_timeout(addr, t)?,
        None => Client::connect(addr)?,
    };
    let lineup = probe.list_models()?;
    if lineup.is_empty() {
        return Err(tensornet::error::Error::Coordinator(format!(
            "{addr} advertises no models"
        )));
    }
    let described: Vec<String> = lineup
        .iter()
        .map(|m| format!("{} ({}->{})", m.name, m.input_dim, m.output_dim))
        .collect();
    println!("== {addr} serves: {}", described.join(", "));
    // --model takes a comma-separated list; multiple names drive
    // interleaved (round-robin 1:1) multi-model traffic — the workload
    // the server's per-model batch groups exist for
    let want: Vec<String> = match args.get("model") {
        Some(spec) => spec
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect(),
        None => vec![lineup[0].name.clone()],
    };
    if want.is_empty() {
        return Err(tensornet::error::Error::Config("--model lists no model names".into()));
    }
    let mut models: Vec<(String, usize)> = Vec::with_capacity(want.len());
    for w in &want {
        match lineup.iter().find(|m| m.name == *w) {
            Some(m) => models.push((m.name.clone(), m.input_dim as usize)),
            None => {
                let names: Vec<&str> = lineup.iter().map(|m| m.name.as_str()).collect();
                return Err(tensornet::error::Error::Config(format!(
                    "model '{w}' not served (available: {})",
                    names.join(", ")
                )));
            }
        }
    }

    println!(
        "== driving {n_requests} requests at '{}' over {connections} connection(s), \
         {pipeline} pipelined each{}",
        want.join("', '"),
        if models.len() > 1 { " (interleaved 1:1)" } else { "" },
    );
    let drive = drive_remote_clients(addr, &models, n_requests, connections, pipeline, timeout);
    let wall = drive.wall_seconds.max(1e-9);
    println!("completed:  {}", drive.completed);
    println!("busy:       {} (load shed by the server)", drive.busy);
    println!("failed:     {}", drive.failed);
    println!("throughput: {:.1} req/s (wall {:.2}s)", drive.completed as f64 / wall, wall);
    println!("e2e:   {}", drive.e2e.summary());
    if let Ok(st) = probe.stats() {
        println!(
            "server: completed {} rejected {} errors {} failed_workers {} quota_shed {}",
            st.completed, st.rejected, st.errors, st.failed_workers, st.quota_shed
        );
        for m in &st.per_model {
            println!(
                "server per-model: {:<12} completed {} errors {} shed {} batches {} rows {} mean batch {:.2}",
                m.name, m.completed, m.errors, m.shed, m.batches, m.batched_rows, m.mean_batch_size(),
            );
        }
    }
    if args.flag("shutdown") {
        probe.shutdown_server()?;
        println!("server shutdown acknowledged");
    }
    // busy is load shedding (the server behaving as designed under
    // pressure); transport/execution failures and zero progress are not
    if drive.failed > 0 || drive.completed == 0 {
        return Err(tensornet::error::Error::Coordinator(format!(
            "{} of {n_requests} requests completed, {} failed, {} shed",
            drive.completed, drive.failed, drive.busy
        )));
    }
    Ok(())
}

/// The router end-of-run summary.  Same contract as
/// [`print_serve_summary`]: the CI fleet smoke greps the `rejected:`
/// and per-model lines — keep the format stable.  `rejected` here is
/// upstream load shedding (`Busy` replies forwarded from shards);
/// the shard block is the placement/health provenance.
fn print_router_summary(stats: &RemoteStats, shards: &[ShardSnapshot], wall: f64) {
    println!("completed:  {}", stats.completed);
    println!(
        "rejected:   {} (upstream busy; {} quota sheds reported by shards)",
        stats.rejected, stats.quota_shed
    );
    println!("errors:     {}", stats.errors);
    println!("failed shards: {}", stats.failed_workers);
    println!("throughput: {:.1} req/s (wall {:.2}s)", stats.completed as f64 / wall, wall);
    if !stats.per_model.is_empty() {
        println!("per-model:");
        for m in &stats.per_model {
            println!(
                "  {:<12} completed {} errors {} shed {} batches {} rows {} mean batch {:.2}",
                m.name,
                m.completed,
                m.errors,
                m.shed,
                m.batches,
                m.batched_rows,
                m.mean_batch_size(),
            );
        }
    }
    println!("shards:");
    for s in shards {
        println!(
            "  {:<21} {} models [{}] forwarded {} completed {} errors {} busy {} failovers {}",
            s.addr,
            if s.healthy { "healthy" } else { "DOWN" },
            s.models.join(", "),
            s.forwarded,
            s.completed,
            s.errors,
            s.busy,
            s.failovers,
        );
    }
}

fn cmd_router(args: &Args) -> Result<()> {
    let spec = args.get("shards").ok_or_else(|| {
        tensornet::error::Error::Config(
            "router needs --shards A,B,... (addresses printed by serve --listen)".into(),
        )
    })?;
    let shards: Vec<String> =
        spec.split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()).collect();
    if shards.is_empty() {
        return Err(tensornet::error::Error::Config("--shards lists no addresses".into()));
    }
    let cfg = RouterConfig {
        shards,
        replicas: args.get_usize("replicas", 0)?,
        io_threads: args.get_usize("io-threads", 1)?.max(1),
        connect_timeout: Duration::from_millis(args.get_usize("timeout-ms", 5_000)? as u64),
    };
    let listen = args.get_or("listen", "127.0.0.1:0");
    let router = ShardRouter::start(cfg, &listen)?;
    let t0 = Instant::now();
    // same machine-readable handshake line as serve --listen (CI greps it)
    println!("listening on {}", router.local_addr());
    println!(
        "transport: {} reactor thread(s) + accept ({} total)",
        router.io_threads(),
        router.transport_threads()
    );
    for s in router.shard_snapshots() {
        println!("placement: {} serves [{}]", s.addr, s.models.join(", "));
    }
    router.wait_for_shutdown();
    println!("wire shutdown received — draining router");
    let wall = t0.elapsed().as_secs_f64().max(1e-9);
    let stats = router.remote_stats();
    let snaps = router.shard_snapshots();
    router.shutdown();
    print_router_summary(&stats, &snaps, wall);
    Ok(())
}

fn cmd_fleet(args: &Args) -> Result<()> {
    use std::io::BufRead;
    use std::process::{Command, Stdio};

    let n_shards = args.get_usize("shards", 2)?.max(1);
    let listen = args.get_or("listen", "127.0.0.1:0");
    let exe = std::env::current_exe()
        .map_err(|e| tensornet::error::Error::Config(format!("current_exe: {e}")))?;

    // spawn the shard daemons: each is a full `serve --listen 127.0.0.1:0`
    // child process (own registry, own batcher, own executor pool), with
    // the serve-relevant flags passed through verbatim
    let mut children = Vec::with_capacity(n_shards);
    let mut addr_rxs = Vec::with_capacity(n_shards);
    let mut echo_threads = Vec::with_capacity(n_shards);
    for k in 0..n_shards {
        let mut cmd = Command::new(&exe);
        cmd.arg("serve").arg("--listen").arg("127.0.0.1:0");
        for flag in [
            "backend",
            "models",
            "artifacts",
            "executor-threads",
            "max-batch",
            "max-delay-ms",
            "io-threads",
            "kernel-threads",
            "max-queue",
            "latency-target-ms",
            "quota",
            "overload-after-ms",
        ] {
            if let Some(v) = args.get(flag) {
                cmd.arg(format!("--{flag}")).arg(v);
            }
        }
        cmd.stdout(Stdio::piped());
        let mut child = cmd
            .spawn()
            .map_err(|e| tensornet::error::Error::Config(format!("spawn shard {k}: {e}")))?;
        let stdout = child.stdout.take().expect("stdout was piped");
        let (tx, rx) = std::sync::mpsc::channel::<String>();
        // echo every shard line under a `[shard k]` prefix (so the
        // router's own unprefixed `listening on` stays unambiguous for
        // scripts) and capture the shard's bound address from its
        // handshake line
        let echo = std::thread::spawn(move || {
            for line in std::io::BufReader::new(stdout).lines() {
                let Ok(line) = line else { break };
                println!("[shard {k}] {line}");
                if let Some(addr) = line.strip_prefix("listening on ") {
                    let _ = tx.send(addr.trim().to_string());
                }
            }
        });
        children.push(child);
        addr_rxs.push(rx);
        echo_threads.push(echo);
    }

    let shard_addrs: Vec<String> = {
        let mut addrs = Vec::with_capacity(n_shards);
        let mut boot_err = None;
        for (k, rx) in addr_rxs.iter().enumerate() {
            match rx.recv_timeout(Duration::from_secs(30)) {
                Ok(a) => addrs.push(a),
                Err(_) => {
                    boot_err =
                        Some(format!("shard {k} did not print its listen address within 30s"));
                    break;
                }
            }
        }
        if let Some(why) = boot_err {
            for c in children.iter_mut() {
                let _ = c.kill();
            }
            for c in children.iter_mut() {
                let _ = c.wait();
            }
            return Err(tensornet::error::Error::Coordinator(why));
        }
        addrs
    };
    println!("== fleet: {n_shards} shard(s) up at {}", shard_addrs.join(", "));

    let cfg = RouterConfig {
        shards: shard_addrs.clone(),
        replicas: args.get_usize("replicas", 0)?,
        io_threads: args.get_usize("router-io-threads", 1)?.max(1),
        connect_timeout: Duration::from_secs(5),
    };
    let router = match ShardRouter::start(cfg, &listen) {
        Ok(r) => r,
        Err(e) => {
            for c in children.iter_mut() {
                let _ = c.kill();
            }
            for c in children.iter_mut() {
                let _ = c.wait();
            }
            return Err(e);
        }
    };
    let t0 = Instant::now();
    println!("listening on {}", router.local_addr());
    for s in router.shard_snapshots() {
        println!("placement: {} serves [{}]", s.addr, s.models.join(", "));
    }

    router.wait_for_shutdown();
    println!("wire shutdown received — draining router, stopping shards");
    let wall = t0.elapsed().as_secs_f64().max(1e-9);
    let stats = router.remote_stats();
    let snaps = router.shard_snapshots();
    router.shutdown();

    // the one wire Shutdown a client sent the router fans out to the
    // whole fleet: ask each shard to stop (printing its own summary),
    // then reap the children
    let mut shard_failures = 0usize;
    for addr in &shard_addrs {
        let stop = Client::connect_timeout(addr, Duration::from_secs(5))
            .and_then(|mut c| c.shutdown_server());
        if let Err(e) = stop {
            eprintln!("fleet: shutdown of shard {addr} failed: {e}");
            shard_failures += 1;
        }
    }
    for (k, mut child) in children.into_iter().enumerate() {
        match child.wait() {
            Ok(status) if status.success() => {}
            Ok(status) => {
                eprintln!("fleet: shard {k} exited with {status}");
                shard_failures += 1;
            }
            Err(e) => {
                eprintln!("fleet: wait on shard {k}: {e}");
                shard_failures += 1;
            }
        }
    }
    for t in echo_threads {
        let _ = t.join();
    }
    print_router_summary(&stats, &snaps, wall);
    if shard_failures > 0 {
        return Err(tensornet::error::Error::Coordinator(format!(
            "{shard_failures} shard(s) failed to stop cleanly"
        )));
    }
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let dir = args.get_or("artifacts", "artifacts");
    let manifest = Manifest::load(&dir)?;
    println!("artifacts in {dir} (seed {}):", manifest.seed);
    for a in &manifest.artifacts {
        let runtime: Vec<String> = a
            .runtime_inputs()
            .iter()
            .map(|i| format!("{}{:?}", i.name, i.shape))
            .collect();
        println!(
            "  {:<24} inputs: {:<3} runtime: {:<28} outputs: {:?}",
            a.name,
            a.inputs.len(),
            runtime.join(", "),
            a.outputs.iter().map(|o| format!("{:?}", o.shape)).collect::<Vec<_>>()
        );
    }
    for (name, g) in &manifest.weight_groups {
        let total: usize = g.layout.iter().map(|(_, _, _, l)| l).sum();
        println!("  weights '{name}': {} tensors, {} params", g.layout.len(), total);
    }
    Ok(())
}
