//! Experiment configuration (S9 in DESIGN.md).
//!
//! A minimal `key = value` config-file format (serde/TOML are unavailable
//! offline) layered under CLI flags: CLI > file > defaults.  Sections are
//! flattened with dots: `train.lr = 0.05`.

use crate::error::{Error, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Flat key-value configuration with typed getters.
#[derive(Clone, Debug, Default)]
pub struct Config {
    values: BTreeMap<String, String>,
}

impl Config {
    pub fn new() -> Self {
        Config::default()
    }

    /// Parse `key = value` lines; `#` starts a comment; `[section]`
    /// headers prefix following keys with `section.`.
    pub fn parse(text: &str) -> Result<Config> {
        let mut values = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                section = name.trim().to_string();
                continue;
            }
            let (k, v) = line.split_once('=').ok_or_else(|| {
                Error::Config(format!("line {}: expected 'key = value', got '{raw}'", lineno + 1))
            })?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            values.insert(key, v.trim().to_string());
        }
        Ok(Config { values })
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Config> {
        let text = std::fs::read_to_string(path.as_ref())
            .map_err(|e| Error::Config(format!("{}: {e}", path.as_ref().display())))?;
        Config::parse(&text)
    }

    /// Overlay `other` on top of `self` (other wins).
    pub fn overlay(mut self, other: &Config) -> Config {
        for (k, v) in &other.values {
            self.values.insert(k.clone(), v.clone());
        }
        self
    }

    pub fn set(&mut self, key: &str, value: impl ToString) {
        self.values.insert(key.to_string(), value.to_string());
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => {
                v.parse().map_err(|_| Error::Config(format!("{key}: bad integer '{v}'")))
            }
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| Error::Config(format!("{key}: bad number '{v}'"))),
        }
    }

    pub fn get_bool(&self, key: &str, default: bool) -> Result<bool> {
        match self.get(key) {
            None => Ok(default),
            Some("true") | Some("1") | Some("yes") => Ok(true),
            Some("false") | Some("0") | Some("no") => Ok(false),
            Some(v) => Err(Error::Config(format!("{key}: bad bool '{v}'"))),
        }
    }

    pub fn get_usize_list(&self, key: &str, default: &[usize]) -> Result<Vec<usize>> {
        match self.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|x| {
                    x.trim().parse().map_err(|_| Error::Config(format!("{key}: bad int '{x}'")))
                })
                .collect(),
        }
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_sections_comments() {
        let cfg = Config::parse(
            "# experiment\nseed = 7\n[train]\nlr = 0.05 # step size\nbatch = 32\n[model]\nranks = 1,2,4\n",
        )
        .unwrap();
        assert_eq!(cfg.get_usize("seed", 0).unwrap(), 7);
        assert_eq!(cfg.get_f64("train.lr", 0.0).unwrap(), 0.05);
        assert_eq!(cfg.get_usize("train.batch", 0).unwrap(), 32);
        assert_eq!(cfg.get_usize_list("model.ranks", &[]).unwrap(), vec![1, 2, 4]);
    }

    #[test]
    fn overlay_wins() {
        let base = Config::parse("a = 1\nb = 2").unwrap();
        let top = Config::parse("b = 3").unwrap();
        let merged = base.overlay(&top);
        assert_eq!(merged.get_usize("a", 0).unwrap(), 1);
        assert_eq!(merged.get_usize("b", 0).unwrap(), 3);
    }

    #[test]
    fn errors_are_informative() {
        assert!(Config::parse("not a kv line").is_err());
        let cfg = Config::parse("x = abc").unwrap();
        assert!(cfg.get_usize("x", 0).is_err());
        assert!(cfg.get_bool("x", false).is_err());
        assert_eq!(cfg.get_bool("missing", true).unwrap(), true);
    }
}
