//! Explicit fully-connected layer — the paper's FC baseline.

use crate::error::{shape_err, Result};
use crate::nn::layer::Layer;
use crate::nn::optim::{sgd_update, SgdConfig};
use crate::nn::state::{import_mismatch, LayerState};
use crate::tensor::{matmul, matmul_at, matmul_bt, Tensor};
use crate::util::rng::Rng;

/// `y = x Wᵀ + b` with `W (out, in)`, `b (out,)`, batched over rows of x.
pub struct Dense {
    w: Tensor,
    b: Tensor,
    grad_w: Tensor,
    grad_b: Tensor,
    vel_w: Tensor,
    vel_b: Tensor,
    cached_x: Option<Tensor>,
}

impl Dense {
    /// He-initialized dense layer.
    pub fn new(n_in: usize, n_out: usize, rng: &mut Rng) -> Self {
        let std = (2.0 / n_in as f32).sqrt();
        Dense {
            w: Tensor::randn(&[n_out, n_in], std, rng),
            b: Tensor::zeros(&[n_out]),
            grad_w: Tensor::zeros(&[n_out, n_in]),
            grad_b: Tensor::zeros(&[n_out]),
            vel_w: Tensor::zeros(&[n_out, n_in]),
            vel_b: Tensor::zeros(&[n_out]),
            cached_x: None,
        }
    }

    /// Wrap explicit weights (used to compare against AOT artifacts and to
    /// build MR baselines from truncated factors).
    pub fn from_weights(w: Tensor, b: Tensor) -> Result<Self> {
        if w.ndim() != 2 || b.ndim() != 1 || b.shape()[0] != w.shape()[0] {
            return shape_err(format!("dense weights {:?} / bias {:?}", w.shape(), b.shape()));
        }
        let (o, i) = (w.shape()[0], w.shape()[1]);
        Ok(Dense {
            grad_w: Tensor::zeros(&[o, i]),
            grad_b: Tensor::zeros(&[o]),
            vel_w: Tensor::zeros(&[o, i]),
            vel_b: Tensor::zeros(&[o]),
            w,
            b,
            cached_x: None,
        })
    }

    pub fn n_in(&self) -> usize {
        self.w.shape()[1]
    }

    pub fn n_out(&self) -> usize {
        self.w.shape()[0]
    }

    pub fn weights(&self) -> (&Tensor, &Tensor) {
        (&self.w, &self.b)
    }
}

impl Layer for Dense {
    fn name(&self) -> String {
        format!("Dense({}x{})", self.n_out(), self.n_in())
    }

    fn forward(&mut self, x: &Tensor, train: bool) -> Result<Tensor> {
        if x.ndim() != 2 || x.shape()[1] != self.n_in() {
            return shape_err(format!("dense fwd: {:?}, want (B, {})", x.shape(), self.n_in()));
        }
        let mut y = matmul_bt(x, &self.w)?; // (B, out)
        let b = self.b.data();
        for row in y.data_mut().chunks_mut(b.len()) {
            for (o, &bb) in row.iter_mut().zip(b) {
                *o += bb;
            }
        }
        if train {
            self.cached_x = Some(x.clone());
        }
        Ok(y)
    }

    fn backward(&mut self, grad_out: &Tensor) -> Result<Tensor> {
        let x = self
            .cached_x
            .take()
            .ok_or_else(|| crate::error::Error::Numerical("dense backward without forward".into()))?;
        // dW += dyᵀ x ; db += column sums of dy ; dx = dy W
        self.grad_w.axpy(1.0, &matmul_at(grad_out, &x)?)?;
        let cols = grad_out.shape()[1];
        let gb = self.grad_b.data_mut();
        for row in grad_out.data().chunks(cols) {
            for (g, &v) in gb.iter_mut().zip(row) {
                *g += v;
            }
        }
        matmul(grad_out, &self.w)
    }

    fn num_params(&self) -> usize {
        self.w.numel() + self.b.numel()
    }

    fn sgd_step(&mut self, cfg: &SgdConfig) -> Result<()> {
        sgd_update(&mut self.w, &self.grad_w, &mut self.vel_w, cfg);
        sgd_update(&mut self.b, &self.grad_b, &mut self.vel_b, cfg);
        self.zero_grads();
        Ok(())
    }

    fn zero_grads(&mut self) {
        self.grad_w.data_mut().fill(0.0);
        self.grad_b.data_mut().fill(0.0);
    }

    fn export_state(&self) -> Result<LayerState> {
        Ok(LayerState::Dense { w: self.w.clone(), b: self.b.clone() })
    }

    fn import_state(&mut self, state: LayerState) -> Result<()> {
        match state {
            LayerState::Dense { w, b }
                if w.shape() == self.w.shape() && b.shape() == self.b.shape() =>
            {
                *self = Dense::from_weights(w, b)?;
                Ok(())
            }
            LayerState::Dense { w, b } => Err(crate::error::Error::Checkpoint(format!(
                "dense import: state {:?}/{:?} into layer {:?}/{:?}",
                w.shape(),
                b.shape(),
                self.w.shape(),
                self.b.shape()
            ))),
            other => Err(import_mismatch("Dense", &other)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn numerical_grad_check(layer: &mut Dense, x: &Tensor) {
        // finite differences on a scalar loss L = sum(y)
        let y = layer.forward(x, true).unwrap();
        let ones = Tensor::filled(y.shape(), 1.0);
        let dx = layer.backward(&ones).unwrap();
        let eps = 1e-3f32;
        // check a few input coordinates
        for &idx in &[0usize, 3, 7] {
            let mut xp = x.clone();
            xp.data_mut()[idx] += eps;
            let mut xm = x.clone();
            xm.data_mut()[idx] -= eps;
            let yp: f32 = layer.forward(&xp, false).unwrap().data().iter().sum();
            let ym: f32 = layer.forward(&xm, false).unwrap().data().iter().sum();
            let want = (yp - ym) / (2.0 * eps);
            let got = dx.data()[idx];
            assert!((got - want).abs() < 1e-2 * (1.0 + want.abs()), "{got} vs {want}");
        }
        // check a few weight coordinates via grad_w
        let mut l2 = Dense::from_weights(layer.w.clone(), layer.b.clone()).unwrap();
        let _ = l2.forward(x, true).unwrap();
        let _ = l2.backward(&ones).unwrap();
        for &idx in &[0usize, 5, 11] {
            let mut wp = layer.w.clone();
            wp.data_mut()[idx] += eps;
            let mut lp = Dense::from_weights(wp, layer.b.clone()).unwrap();
            let yp: f32 = lp.forward(x, false).unwrap().data().iter().sum();
            let mut wm = layer.w.clone();
            wm.data_mut()[idx] -= eps;
            let mut lm = Dense::from_weights(wm, layer.b.clone()).unwrap();
            let ym: f32 = lm.forward(x, false).unwrap().data().iter().sum();
            let want = (yp - ym) / (2.0 * eps);
            let got = l2.grad_w.data()[idx];
            assert!((got - want).abs() < 1e-2 * (1.0 + want.abs()), "w[{idx}]: {got} vs {want}");
        }
    }

    #[test]
    fn forward_shape_and_bias() {
        let mut rng = Rng::new(1);
        let mut l = Dense::new(4, 3, &mut rng);
        l.b = Tensor::from_vec(&[3], vec![1.0, 2.0, 3.0]).unwrap();
        let y = l.forward(&Tensor::zeros(&[2, 4]), false).unwrap();
        assert_eq!(y.shape(), &[2, 3]);
        assert_eq!(y.row(0), &[1.0, 2.0, 3.0]);
    }

    #[test]
    fn gradients_match_finite_differences() {
        let mut rng = Rng::new(2);
        let mut l = Dense::new(6, 4, &mut rng);
        let x = Tensor::randn(&[3, 6], 1.0, &mut rng);
        numerical_grad_check(&mut l, &x);
    }

    #[test]
    fn backward_without_forward_errors() {
        let mut rng = Rng::new(3);
        let mut l = Dense::new(2, 2, &mut rng);
        assert!(l.backward(&Tensor::zeros(&[1, 2])).is_err());
    }

    #[test]
    fn sgd_step_changes_params_and_clears_grads() {
        let mut rng = Rng::new(4);
        let mut l = Dense::new(3, 2, &mut rng);
        let x = Tensor::randn(&[4, 3], 1.0, &mut rng);
        let y = l.forward(&x, true).unwrap();
        let _ = l.backward(&Tensor::filled(y.shape(), 1.0)).unwrap();
        let before = l.w.clone();
        l.sgd_step(&SgdConfig::default()).unwrap();
        assert_ne!(before, l.w);
        assert!(l.grad_w.data().iter().all(|&g| g == 0.0));
    }

    #[test]
    fn num_params() {
        let mut rng = Rng::new(5);
        let l = Dense::new(10, 7, &mut rng);
        assert_eq!(l.num_params(), 70 + 7);
    }

    #[test]
    fn state_roundtrip_is_bitwise_and_resets_momentum() {
        let mut rng = Rng::new(6);
        let mut l = Dense::new(4, 3, &mut rng);
        // accumulate some momentum so the import provably resets it
        let x = Tensor::randn(&[2, 4], 1.0, &mut rng);
        let y = l.forward(&x, true).unwrap();
        let _ = l.backward(&Tensor::filled(y.shape(), 1.0)).unwrap();
        l.sgd_step(&SgdConfig::default()).unwrap();
        assert!(l.vel_w.max_abs() > 0.0);

        let state = l.export_state().unwrap();
        let mut fresh = Dense::new(4, 3, &mut Rng::new(99));
        fresh.import_state(state).unwrap();
        assert_eq!(fresh.w, l.w);
        assert_eq!(fresh.b, l.b);
        assert_eq!(fresh.vel_w.max_abs(), 0.0);
    }
}
