//! Elementwise nonlinearities.

use crate::error::{Error, Result};
use crate::nn::layer::Layer;
use crate::nn::state::{import_mismatch, LayerState};
use crate::tensor::Tensor;

/// Rectified linear unit.
#[derive(Default)]
pub struct Relu {
    mask: Option<Vec<bool>>,
}

impl Relu {
    pub fn new() -> Self {
        Relu::default()
    }
}

impl Layer for Relu {
    fn name(&self) -> String {
        "ReLU".into()
    }

    fn forward(&mut self, x: &Tensor, train: bool) -> Result<Tensor> {
        let mut y = x.clone();
        if train {
            let mut mask = vec![false; x.numel()];
            for (v, m) in y.data_mut().iter_mut().zip(mask.iter_mut()) {
                if *v > 0.0 {
                    *m = true;
                } else {
                    *v = 0.0;
                }
            }
            self.mask = Some(mask);
        } else {
            for v in y.data_mut() {
                if *v < 0.0 {
                    *v = 0.0;
                }
            }
        }
        Ok(y)
    }

    fn backward(&mut self, grad_out: &Tensor) -> Result<Tensor> {
        let mask = self
            .mask
            .take()
            .ok_or_else(|| Error::Numerical("relu backward without forward".into()))?;
        if mask.len() != grad_out.numel() {
            return Err(Error::Shape("relu grad shape mismatch".into()));
        }
        let mut g = grad_out.clone();
        for (v, m) in g.data_mut().iter_mut().zip(&mask) {
            if !*m {
                *v = 0.0;
            }
        }
        Ok(g)
    }

    fn export_state(&self) -> Result<LayerState> {
        Ok(LayerState::Relu)
    }

    fn import_state(&mut self, state: LayerState) -> Result<()> {
        match state {
            LayerState::Relu => {
                self.mask = None; // stateless: just drop any stale cache
                Ok(())
            }
            other => Err(import_mismatch("ReLU", &other)),
        }
    }
}

/// Logistic sigmoid (used by the wide-and-shallow §6.2.1 discussion).
#[derive(Default)]
pub struct Sigmoid {
    cached_y: Option<Tensor>,
}

impl Sigmoid {
    pub fn new() -> Self {
        Sigmoid::default()
    }
}

impl Layer for Sigmoid {
    fn name(&self) -> String {
        "Sigmoid".into()
    }

    fn forward(&mut self, x: &Tensor, train: bool) -> Result<Tensor> {
        let mut y = x.clone();
        for v in y.data_mut() {
            *v = 1.0 / (1.0 + (-*v).exp());
        }
        if train {
            self.cached_y = Some(y.clone());
        }
        Ok(y)
    }

    fn backward(&mut self, grad_out: &Tensor) -> Result<Tensor> {
        let y = self
            .cached_y
            .take()
            .ok_or_else(|| Error::Numerical("sigmoid backward without forward".into()))?;
        let mut g = grad_out.clone();
        for (gv, &yv) in g.data_mut().iter_mut().zip(y.data()) {
            *gv *= yv * (1.0 - yv);
        }
        Ok(g)
    }

    fn export_state(&self) -> Result<LayerState> {
        Ok(LayerState::Sigmoid)
    }

    fn import_state(&mut self, state: LayerState) -> Result<()> {
        match state {
            LayerState::Sigmoid => {
                self.cached_y = None;
                Ok(())
            }
            other => Err(import_mismatch("Sigmoid", &other)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn relu_clamps_and_masks() {
        let mut r = Relu::new();
        let x = Tensor::from_vec(&[1, 4], vec![-1.0, 0.5, -0.2, 2.0]).unwrap();
        let y = r.forward(&x, true).unwrap();
        assert_eq!(y.data(), &[0.0, 0.5, 0.0, 2.0]);
        let g = r.backward(&Tensor::filled(&[1, 4], 1.0)).unwrap();
        assert_eq!(g.data(), &[0.0, 1.0, 0.0, 1.0]);
    }

    #[test]
    fn relu_inference_does_not_cache() {
        let mut r = Relu::new();
        let _ = r.forward(&Tensor::zeros(&[1, 2]), false).unwrap();
        assert!(r.backward(&Tensor::zeros(&[1, 2])).is_err());
    }

    #[test]
    fn sigmoid_values_and_grad() {
        let mut s = Sigmoid::new();
        let x = Tensor::from_vec(&[1, 2], vec![0.0, 100.0]).unwrap();
        let y = s.forward(&x, true).unwrap();
        assert!((y.data()[0] - 0.5).abs() < 1e-6);
        assert!((y.data()[1] - 1.0).abs() < 1e-6);
        let g = s.backward(&Tensor::filled(&[1, 2], 1.0)).unwrap();
        assert!((g.data()[0] - 0.25).abs() < 1e-6); // σ'(0) = 1/4
        assert!(g.data()[1].abs() < 1e-6);
    }

    #[test]
    fn sigmoid_grad_matches_finite_diff() {
        let mut s = Sigmoid::new();
        let x = Tensor::from_vec(&[1, 3], vec![-0.7, 0.3, 1.9]).unwrap();
        let _ = s.forward(&x, true).unwrap();
        let g = s.backward(&Tensor::filled(&[1, 3], 1.0)).unwrap();
        let eps = 1e-3f32;
        for i in 0..3 {
            let mut xp = x.clone();
            xp.data_mut()[i] += eps;
            let mut xm = x.clone();
            xm.data_mut()[i] -= eps;
            let mut s2 = Sigmoid::new();
            let yp: f32 = s2.forward(&xp, false).unwrap().data().iter().sum();
            let ym: f32 = s2.forward(&xm, false).unwrap().data().iter().sum();
            let want = (yp - ym) / (2.0 * eps);
            assert!((g.data()[i] - want).abs() < 1e-3);
        }
    }
}
