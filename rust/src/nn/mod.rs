//! Native training engine (S4/S5 in DESIGN.md) — the MatConvNet+TensorNet
//! replacement that reproduces the paper's training experiments without
//! python anywhere near the loop.
//!
//! * [`Layer`] — forward/backward/update trait.
//! * [`Dense`] — explicit fully-connected layer (the FC baseline).
//! * [`TtLinear`] — the paper's §4 TT-layer with the §5 core-gradient
//!   algorithm: `∂L/∂W (M x N)` is never materialized; gradients are
//!   assembled per core by reversing the contraction sweep, at
//!   `O(d² r² m max{M,N})`-style cost and `O(r max{M,N})` extra memory
//!   per cached sweep state.
//! * [`low_rank_pair`] — the matrix-rank (MR) compression baseline of
//!   Fig. 1 / Table 2 (two stacked dense layers `1024 x r`, `r x 1024`).
//! * [`Relu`] / [`Sigmoid`], [`SoftmaxXent`], [`Sgd`] (momentum 0.9 +
//!   L2 5e-4 — §6.4), [`Sequential`], [`Trainer`].
//! * [`LayerState`] — the export/import snapshot every layer implements;
//!   `runtime::checkpoint` persists it, `coordinator::native` serves it.

mod activations;
mod btlayer;
mod conv;
mod dense;
mod frozen;
mod layer;
mod loss;
mod lowrank;
mod optim;
mod sequential;
mod state;
mod trainer;
mod ttlayer;
mod zoo;

pub use activations::{Relu, Sigmoid};
pub use btlayer::BtLinear;
pub use conv::{garipov_modes, Conv2d, ConvGeom, TtConv};
pub use dense::Dense;
pub use frozen::Frozen;
pub use layer::Layer;
pub use loss::{accuracy, SoftmaxXent};
pub use lowrank::low_rank_pair;
pub use optim::{sgd_update, SgdConfig};
pub use sequential::Sequential;
pub use state::{CompressedLayer, Compression, LayerState};
pub use trainer::{predict, EvalReport, TrainConfig, TrainHistory, Trainer};
pub use ttlayer::TtLinear;
pub use zoo::{
    bt_classifier, conv_geom_mnist, mnist_convnet, mnist_fc_baseline, mnist_tensornet,
    mnist_tt_convnet, mr_classifier, tt_classifier,
};
