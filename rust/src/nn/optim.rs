//! SGD with momentum and L2 weight decay — the paper's §6.4 recipe
//! (momentum 0.9, weight decay 5e-4, Gaussian init).

use crate::tensor::Tensor;

/// Optimizer hyper-parameters for one step.
#[derive(Clone, Copy, Debug)]
pub struct SgdConfig {
    pub lr: f32,
    pub momentum: f32,
    pub weight_decay: f32,
    /// per-tensor gradient-norm clip (0 disables).  The TT
    /// parametrization is a product of d cores, so gradients can spike by
    /// factors of r^{d-1} on bad minibatches; clipping keeps SGD+momentum
    /// in its stable region (the MatConvNet runs the paper describes used
    /// smaller effective steps via averaged full-dataset epochs).
    pub clip_norm: f32,
}

impl Default for SgdConfig {
    fn default() -> Self {
        // paper section 6.4 + clip for the product parametrization
        SgdConfig { lr: 0.03, momentum: 0.9, weight_decay: 5e-4, clip_norm: 5.0 }
    }
}

impl SgdConfig {
    pub fn with_lr(lr: f32) -> Self {
        SgdConfig { lr, ..Default::default() }
    }
}

#[cfg(test)]
mod clip_tests {
    use super::*;
    use crate::tensor::Tensor;

    #[test]
    fn clipping_bounds_update() {
        let mut p = Tensor::zeros(&[4]);
        let g = Tensor::filled(&[4], 100.0); // norm 200
        let mut v = Tensor::zeros(&[4]);
        let cfg = SgdConfig { lr: 1.0, momentum: 0.0, weight_decay: 0.0, clip_norm: 2.0 };
        sgd_update(&mut p, &g, &mut v, &cfg);
        // clipped grad has norm 2 -> each entry 1, update -1
        for &x in p.data() {
            assert!((x + 1.0).abs() < 1e-5, "{x}");
        }
    }

    #[test]
    fn small_grads_unclipped() {
        let mut p = Tensor::zeros(&[2]);
        let g = Tensor::filled(&[2], 0.1);
        let mut v = Tensor::zeros(&[2]);
        let cfg = SgdConfig { lr: 1.0, momentum: 0.0, weight_decay: 0.0, clip_norm: 5.0 };
        sgd_update(&mut p, &g, &mut v, &cfg);
        for &x in p.data() {
            assert!((x + 0.1).abs() < 1e-6);
        }
    }
}

/// One classic-momentum update:
/// `v ← μ·v − lr·(g + wd·p);  p ← p + v`.
///
/// `velocity` is lazily initialized to zeros on first use (layers allocate
/// it next to each parameter).
pub fn sgd_update(param: &mut Tensor, grad: &Tensor, velocity: &mut Tensor, cfg: &SgdConfig) {
    debug_assert_eq!(param.shape(), grad.shape());
    debug_assert_eq!(param.shape(), velocity.shape());
    // per-tensor gradient clipping
    let gscale = if cfg.clip_norm > 0.0 {
        let n = grad.norm();
        if n > cfg.clip_norm {
            cfg.clip_norm / n
        } else {
            1.0
        }
    } else {
        1.0
    };
    let p = param.data_mut();
    let g = grad.data();
    let v = velocity.data_mut();
    for i in 0..p.len() {
        v[i] = cfg.momentum * v[i] - cfg.lr * (gscale * g[i] + cfg.weight_decay * p[i]);
        p[i] += v[i];
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plain_gd_when_no_momentum() {
        let mut p = Tensor::filled(&[3], 1.0);
        let g = Tensor::filled(&[3], 2.0);
        let mut v = Tensor::zeros(&[3]);
        let cfg = SgdConfig { lr: 0.1, momentum: 0.0, weight_decay: 0.0, clip_norm: 0.0 };
        sgd_update(&mut p, &g, &mut v, &cfg);
        for &x in p.data() {
            assert!((x - 0.8).abs() < 1e-6);
        }
    }

    #[test]
    fn momentum_accumulates() {
        let mut p = Tensor::zeros(&[1]);
        let g = Tensor::filled(&[1], 1.0);
        let mut v = Tensor::zeros(&[1]);
        let cfg = SgdConfig { lr: 1.0, momentum: 0.5, weight_decay: 0.0, clip_norm: 0.0 };
        sgd_update(&mut p, &g, &mut v, &cfg); // v=-1, p=-1
        sgd_update(&mut p, &g, &mut v, &cfg); // v=-1.5, p=-2.5
        assert!((p.data()[0] + 2.5).abs() < 1e-6);
    }

    #[test]
    fn weight_decay_shrinks_params() {
        let mut p = Tensor::filled(&[1], 10.0);
        let g = Tensor::zeros(&[1]);
        let mut v = Tensor::zeros(&[1]);
        let cfg = SgdConfig { lr: 0.1, momentum: 0.0, weight_decay: 0.5, clip_norm: 0.0 };
        sgd_update(&mut p, &g, &mut v, &cfg);
        assert!((p.data()[0] - 9.5).abs() < 1e-6);
    }
}
