//! Convolutional layers: a dense `Conv2d` and its TT-compressed
//! counterpart `TtConv` (Garipov et al. 2016, "Ultimate tensorization").
//!
//! Both lower the convolution to a GEMM over im2col patch rows
//! (`tensor::im2col`), so the contraction rides the same `Gemm`/SIMD
//! kernels — and, for `TtConv`, the same `MatvecScratch` 1-alloc sweep —
//! as every fully-connected layer.  A conv kernel `(c_out, c_in, kh, kw)`
//! flattens row-major into the `(c_out, c_in·kh·kw)` matrix whose columns
//! match the patch layout; `TtConv` stores that matrix in TT format using
//! the Garipov reshape (output channels factored into `ms`, input
//! channels × spatial taps into `ns`).
//!
//! Layer I/O stays flat 2-D like every other layer: inputs are
//! `(B, c_in·h·w)` channel-major images, outputs `(B, c_out·ho·wo)` —
//! which is what the serving executor's row-oriented batch interface
//! speaks.

use std::fmt;

use crate::error::{shape_err, Error, Result};
use crate::nn::layer::Layer;
use crate::nn::optim::SgdConfig;
use crate::nn::state::{import_mismatch, LayerState};
use crate::nn::ttlayer::TtLinear;
use crate::tensor::{col2im, conv_out_dim, im2col, Tensor};
use crate::tt::{TtMatrix, TtShape};
use crate::util::rng::Rng;

/// Geometry of a 2-D convolution over channel-major `(C, H, W)` images.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ConvGeom {
    pub c_in: usize,
    pub h: usize,
    pub w: usize,
    pub c_out: usize,
    pub kh: usize,
    pub kw: usize,
    pub stride: usize,
    pub pad: usize,
}

impl ConvGeom {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        c_in: usize,
        h: usize,
        w: usize,
        c_out: usize,
        kh: usize,
        kw: usize,
        stride: usize,
        pad: usize,
    ) -> Result<Self> {
        let g = ConvGeom { c_in, h, w, c_out, kh, kw, stride, pad };
        g.validate()?;
        Ok(g)
    }

    pub fn validate(&self) -> Result<()> {
        if self.c_in == 0 || self.c_out == 0 {
            return shape_err(format!("conv geom: zero channels in {self}"));
        }
        conv_out_dim(self.h, self.kh, self.stride, self.pad)?;
        conv_out_dim(self.w, self.kw, self.stride, self.pad)?;
        Ok(())
    }

    pub fn out_h(&self) -> usize {
        (self.h + 2 * self.pad - self.kh) / self.stride + 1
    }

    pub fn out_w(&self) -> usize {
        (self.w + 2 * self.pad - self.kw) / self.stride + 1
    }

    /// Flat input width `c_in·h·w`.
    pub fn input_dim(&self) -> usize {
        self.c_in * self.h * self.w
    }

    /// Flat output width `c_out·ho·wo`.
    pub fn output_dim(&self) -> usize {
        self.c_out * self.out_h() * self.out_w()
    }

    /// im2col patch width `c_in·kh·kw` — the kernel matrix's column count.
    pub fn patch_dim(&self) -> usize {
        self.c_in * self.kh * self.kw
    }

    /// Dense kernel parameter count (kernel matrix + per-channel bias).
    pub fn dense_params(&self) -> usize {
        self.c_out * self.patch_dim() + self.c_out
    }
}

impl fmt::Display for ConvGeom {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}x{}x{} -> {}x{}x{}; k{}x{} s{} p{}",
            self.c_in,
            self.h,
            self.w,
            self.c_out,
            self.out_h(),
            self.out_w(),
            self.kh,
            self.kw,
            self.stride,
            self.pad
        )
    }
}

/// Greedy mode factorization: split `n` into factors, merging adjacent
/// prime factors while the product stays ≤ 4 (the paper's mode sizes).
fn factorize_modes(n: usize) -> Vec<usize> {
    if n <= 1 {
        return vec![1];
    }
    let mut primes = Vec::new();
    let mut rem = n;
    let mut p = 2;
    while p * p <= rem {
        while rem % p == 0 {
            primes.push(p);
            rem /= p;
        }
        p += 1;
    }
    if rem > 1 {
        primes.push(rem);
    }
    let mut modes: Vec<usize> = Vec::new();
    for f in primes {
        match modes.last_mut() {
            Some(last) if *last * f <= 4 => *last *= f,
            _ => modes.push(f),
        }
    }
    modes
}

/// The Garipov reshape for `geom`'s kernel matrix `(c_out, c_in·kh·kw)`:
/// output channels factor into `ms`, input channels into the leading `ns`
/// modes with the `kh·kw` spatial taps as the trailing mode.  The two
/// lists are left-padded with size-1 modes to equal length (TT requires
/// `ms.len() == ns.len()`).
pub fn garipov_modes(geom: &ConvGeom) -> (Vec<usize>, Vec<usize>) {
    let mut ms = factorize_modes(geom.c_out);
    let mut ns = factorize_modes(geom.c_in);
    ns.push(geom.kh * geom.kw);
    while ms.len() < ns.len() {
        ms.insert(0, 1);
    }
    while ns.len() < ms.len() {
        ns.insert(0, 1);
    }
    (ms, ns)
}

/// Dense 2-D convolution: im2col lowering + one GEMM against the
/// `(c_out, c_in·kh·kw)` kernel matrix, plus a per-channel bias.
pub struct Conv2d {
    geom: ConvGeom,
    w: Tensor, // (c_out, patch_dim)
    b: Tensor, // (c_out)
    grad_w: Tensor,
    grad_b: Tensor,
    vel_w: Tensor,
    vel_b: Tensor,
    /// batch size + patch matrix cached by the training forward
    cache: Option<(usize, Tensor)>,
}

impl Conv2d {
    /// He-initialized dense conv (fan-in = `c_in·kh·kw`).
    pub fn new(geom: ConvGeom, rng: &mut Rng) -> Result<Self> {
        geom.validate()?;
        let std = (2.0 / geom.patch_dim() as f32).sqrt();
        let w = Tensor::randn(&[geom.c_out, geom.patch_dim()], std, rng);
        let b = Tensor::zeros(&[geom.c_out]);
        Self::from_weights(geom, w, b)
    }

    /// Wrap an existing kernel matrix `(c_out, c_in·kh·kw)` and bias.
    pub fn from_weights(geom: ConvGeom, w: Tensor, b: Tensor) -> Result<Self> {
        geom.validate()?;
        if w.shape() != [geom.c_out, geom.patch_dim()] {
            return shape_err(format!(
                "conv weights {:?}, want ({}, {})",
                w.shape(),
                geom.c_out,
                geom.patch_dim()
            ));
        }
        if b.shape() != [geom.c_out] {
            return shape_err(format!("conv bias {:?}, want ({})", b.shape(), geom.c_out));
        }
        let grad_w = Tensor::zeros(w.shape());
        let grad_b = Tensor::zeros(b.shape());
        let vel_w = Tensor::zeros(w.shape());
        let vel_b = Tensor::zeros(b.shape());
        Ok(Conv2d { geom, w, b, grad_w, grad_b, vel_w, vel_b, cache: None })
    }

    pub fn geom(&self) -> &ConvGeom {
        &self.geom
    }

    /// The kernel matrix and bias (e.g. for TT-SVD compression).
    pub fn weights(&self) -> (&Tensor, &Tensor) {
        (&self.w, &self.b)
    }

    fn lower(&self, x: &Tensor) -> Result<Tensor> {
        let g = &self.geom;
        im2col(x, g.c_in, g.h, g.w, g.kh, g.kw, g.stride, g.pad)
    }
}

/// Transpose `(B·Ho·Wo, c_out)` GEMM output into the channel-major flat
/// layout `(B, c_out·Ho·Wo)` every layer downstream expects.
fn rows_to_channel_major(y: Tensor, b: usize, c_out: usize, hw: usize) -> Result<Tensor> {
    y.reshape(&[b, hw, c_out])?.permute(&[0, 2, 1])?.reshape(&[b, c_out * hw])
}

/// Inverse of [`rows_to_channel_major`] for the backward pass.
fn channel_major_to_rows(g: &Tensor, b: usize, c_out: usize, hw: usize) -> Result<Tensor> {
    g.reshaped(&[b, c_out, hw])?.permute(&[0, 2, 1])?.reshape(&[b * hw, c_out])
}

impl Layer for Conv2d {
    fn name(&self) -> String {
        format!("Conv2d({}; params {})", self.geom, self.num_params())
    }

    fn forward(&mut self, x: &Tensor, train: bool) -> Result<Tensor> {
        if x.ndim() != 2 || x.shape()[1] != self.geom.input_dim() {
            return shape_err(format!(
                "conv fwd: {:?}, want (B, {})",
                x.shape(),
                self.geom.input_dim()
            ));
        }
        let b = x.shape()[0];
        let cols = self.lower(x)?; // (B*Ho*Wo, patch)
        let mut y = crate::tensor::matmul_bt(&cols, &self.w)?; // (B*Ho*Wo, c_out)
        let bias = self.b.data();
        for row in y.data_mut().chunks_mut(bias.len()) {
            for (o, &bb) in row.iter_mut().zip(bias) {
                *o += bb;
            }
        }
        if train {
            self.cache = Some((b, cols));
        }
        rows_to_channel_major(y, b, self.geom.c_out, self.geom.out_h() * self.geom.out_w())
    }

    fn backward(&mut self, grad_out: &Tensor) -> Result<Tensor> {
        let (b, cols) = self
            .cache
            .take()
            .ok_or_else(|| Error::Numerical("conv backward without forward".into()))?;
        if grad_out.shape() != [b, self.geom.output_dim()] {
            return shape_err(format!("conv bwd: grad {:?}", grad_out.shape()));
        }
        let g = self.geom;
        let hw = g.out_h() * g.out_w();
        let d_rows = channel_major_to_rows(grad_out, b, g.c_out, hw)?; // (B*Ho*Wo, c_out)
        self.grad_w.axpy(1.0, &crate::tensor::matmul_at(&d_rows, &cols)?)?;
        let gb = self.grad_b.data_mut();
        for row in d_rows.data().chunks(g.c_out) {
            for (acc, &v) in gb.iter_mut().zip(row) {
                *acc += v;
            }
        }
        let d_cols = crate::tensor::matmul(&d_rows, &self.w)?; // (B*Ho*Wo, patch)
        col2im(&d_cols, b, g.c_in, g.h, g.w, g.kh, g.kw, g.stride, g.pad)
    }

    fn num_params(&self) -> usize {
        self.w.numel() + self.b.numel()
    }

    fn sgd_step(&mut self, cfg: &SgdConfig) -> Result<()> {
        crate::nn::optim::sgd_update(&mut self.w, &self.grad_w, &mut self.vel_w, cfg);
        crate::nn::optim::sgd_update(&mut self.b, &self.grad_b, &mut self.vel_b, cfg);
        self.zero_grads();
        Ok(())
    }

    fn zero_grads(&mut self) {
        self.grad_w.data_mut().fill(0.0);
        self.grad_b.data_mut().fill(0.0);
    }

    fn export_state(&self) -> Result<LayerState> {
        Ok(LayerState::Conv { geom: self.geom, w: self.w.clone(), b: self.b.clone() })
    }

    fn import_state(&mut self, state: LayerState) -> Result<()> {
        match state {
            LayerState::Conv { geom, w, b } if geom == self.geom => {
                *self = Conv2d::from_weights(geom, w, b)?;
                Ok(())
            }
            LayerState::Conv { geom, .. } => Err(Error::Checkpoint(format!(
                "conv import: geometry ({geom}) into ({})",
                self.geom
            ))),
            other => Err(import_mismatch("Conv2d", &other)),
        }
    }
}

/// A convolution whose kernel matrix lives in TT format (the Garipov
/// reshape).  The per-patch linear map is a full [`TtLinear`]
/// (`patch_dim → c_out`, per-channel bias), so training gradients and the
/// scratch-buffered inference sweep come from the TT machinery unchanged.
pub struct TtConv {
    geom: ConvGeom,
    inner: TtLinear,
}

impl TtConv {
    /// Randomly-initialized TT-conv with the default Garipov mode
    /// factorization at uniform `rank`.
    pub fn new(geom: ConvGeom, rank: usize, rng: &mut Rng) -> Result<Self> {
        let (ms, ns) = garipov_modes(&geom);
        Self::with_modes(geom, &ms, &ns, rank, rng)
    }

    /// Randomly-initialized TT-conv with explicit mode factorizations
    /// (`Π ms = c_out`, `Π ns = c_in·kh·kw`).
    pub fn with_modes(
        geom: ConvGeom,
        ms: &[usize],
        ns: &[usize],
        rank: usize,
        rng: &mut Rng,
    ) -> Result<Self> {
        let shape = TtShape::uniform(ms, ns, rank)?;
        let inner = TtLinear::new(&shape, rng)?;
        Self::from_tt(geom, inner)
    }

    /// Wrap an existing TT kernel (e.g. from TT-SVD or a checkpoint).
    pub fn from_tt(geom: ConvGeom, inner: TtLinear) -> Result<Self> {
        geom.validate()?;
        if inner.n_in() != geom.patch_dim() || inner.n_out() != geom.c_out {
            return shape_err(format!(
                "tt-conv: kernel {}x{} doesn't fit geometry ({geom}: {}x{})",
                inner.n_out(),
                inner.n_in(),
                geom.c_out,
                geom.patch_dim()
            ));
        }
        Ok(TtConv { geom, inner })
    }

    /// TT-SVD compression of a trained dense kernel matrix
    /// `w (c_out, c_in·kh·kw)` at the given rank cap / relative tolerance,
    /// using the Garipov mode factorization.
    pub fn compress(
        geom: ConvGeom,
        w: &Tensor,
        b: &Tensor,
        max_rank: Option<usize>,
        eps: f64,
    ) -> Result<Self> {
        let (ms, ns) = garipov_modes(&geom);
        let tt = TtMatrix::from_dense(w, &ms, &ns, max_rank, eps)?;
        Self::from_tt(geom, TtLinear::from_tt(tt, b.clone()))
    }

    pub fn geom(&self) -> &ConvGeom {
        &self.geom
    }

    /// The TT kernel (per-patch linear map).
    pub fn inner(&self) -> &TtLinear {
        &self.inner
    }
}

impl Layer for TtConv {
    fn name(&self) -> String {
        format!("TtConv({}; {})", self.geom, self.inner.tt().shape())
    }

    fn forward(&mut self, x: &Tensor, train: bool) -> Result<Tensor> {
        if x.ndim() != 2 || x.shape()[1] != self.geom.input_dim() {
            return shape_err(format!(
                "tt-conv fwd: {:?}, want (B, {})",
                x.shape(),
                self.geom.input_dim()
            ));
        }
        let g = &self.geom;
        let b = x.shape()[0];
        let cols = im2col(x, g.c_in, g.h, g.w, g.kh, g.kw, g.stride, g.pad)?;
        let y = self.inner.forward(&cols, train)?; // (B*Ho*Wo, c_out), bias added
        rows_to_channel_major(y, b, g.c_out, g.out_h() * g.out_w())
    }

    fn backward(&mut self, grad_out: &Tensor) -> Result<Tensor> {
        let g = self.geom;
        if grad_out.ndim() != 2 || grad_out.shape()[1] != g.output_dim() {
            return shape_err(format!("tt-conv bwd: grad {:?}", grad_out.shape()));
        }
        let b = grad_out.shape()[0];
        let hw = g.out_h() * g.out_w();
        let d_rows = channel_major_to_rows(grad_out, b, g.c_out, hw)?;
        let d_cols = self.inner.backward(&d_rows)?; // (B*Ho*Wo, patch)
        col2im(&d_cols, b, g.c_in, g.h, g.w, g.kh, g.kw, g.stride, g.pad)
    }

    fn num_params(&self) -> usize {
        self.inner.num_params()
    }

    fn sgd_step(&mut self, cfg: &SgdConfig) -> Result<()> {
        self.inner.sgd_step(cfg)
    }

    fn zero_grads(&mut self) {
        self.inner.zero_grads()
    }

    fn export_state(&self) -> Result<LayerState> {
        match self.inner.export_state()? {
            LayerState::TtLinear { shape, cores, bias } => {
                Ok(LayerState::TtConv { geom: self.geom, shape, cores, bias })
            }
            other => Err(import_mismatch("TtConv(inner)", &other)),
        }
    }

    fn import_state(&mut self, state: LayerState) -> Result<()> {
        match state {
            LayerState::TtConv { geom, shape, cores, bias } if geom == self.geom => {
                // delegate shape/rank validation to the TT import; on error
                // the inner layer is untouched
                self.inner.import_state(LayerState::TtLinear { shape, cores, bias })
            }
            LayerState::TtConv { geom, .. } => Err(Error::Checkpoint(format!(
                "tt-conv import: geometry ({geom}) into ({})",
                self.geom
            ))),
            other => Err(import_mismatch("TtConv", &other)),
        }
    }
}

/// Dense-conv counterpart builder used by the checkpoint compress walk:
/// reconstructs a [`Conv2d`] from a conv state (helper for tests/tools).
pub fn conv_from_state(state: LayerState) -> Result<Conv2d> {
    match state {
        LayerState::Conv { geom, w, b } => Conv2d::from_weights(geom, w, b),
        other => Err(import_mismatch("Conv2d", &other)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_geom() -> ConvGeom {
        // 2 channels 5x4, 3 output channels, 3x2 kernel, stride 2, pad 1
        ConvGeom::new(2, 5, 4, 3, 3, 2, 2, 1).unwrap()
    }

    /// Direct (nested-loop) convolution oracle in the same flat layout.
    fn naive_conv(g: &ConvGeom, w: &Tensor, b: &Tensor, x: &Tensor) -> Tensor {
        let bs = x.shape()[0];
        let (ho, wo) = (g.out_h(), g.out_w());
        let mut out = Tensor::zeros(&[bs, g.output_dim()]);
        for bi in 0..bs {
            for co in 0..g.c_out {
                for oy in 0..ho {
                    for ox in 0..wo {
                        let mut acc = b.data()[co];
                        for ci in 0..g.c_in {
                            for u in 0..g.kh {
                                for v in 0..g.kw {
                                    let iy = (oy * g.stride + u) as isize - g.pad as isize;
                                    let ix = (ox * g.stride + v) as isize - g.pad as isize;
                                    if iy < 0
                                        || ix < 0
                                        || iy >= g.h as isize
                                        || ix >= g.w as isize
                                    {
                                        continue;
                                    }
                                    let xi = ci * g.h * g.w + iy as usize * g.w + ix as usize;
                                    let wi = (ci * g.kh + u) * g.kw + v;
                                    acc += w.at(&[co, wi]) * x.at(&[bi, xi]);
                                }
                            }
                        }
                        out.set(&[bi, co * ho * wo + oy * wo + ox], acc);
                    }
                }
            }
        }
        out
    }

    #[test]
    fn dense_conv_matches_naive_oracle() {
        let g = small_geom();
        let mut rng = Rng::new(21);
        let mut layer = Conv2d::new(g, &mut rng).unwrap();
        // nonzero bias to exercise the broadcast
        let b = Tensor::randn(&[g.c_out], 0.5, &mut rng);
        let (w, _) = layer.weights();
        let w = w.clone();
        layer = Conv2d::from_weights(g, w.clone(), b.clone()).unwrap();
        let x = Tensor::randn(&[3, g.input_dim()], 1.0, &mut rng);
        let got = layer.forward(&x, false).unwrap();
        let want = naive_conv(&g, &w, &b, &x);
        assert_eq!(got.shape(), want.shape());
        for (a, e) in got.data().iter().zip(want.data()) {
            assert!((a - e).abs() < 1e-4 * (1.0 + e.abs()), "{a} vs {e}");
        }
    }

    #[test]
    fn conv_input_gradient_matches_finite_differences() {
        let g = ConvGeom::new(1, 4, 4, 2, 3, 3, 1, 1).unwrap();
        let mut rng = Rng::new(22);
        let mut layer = Conv2d::new(g, &mut rng).unwrap();
        let x = Tensor::randn(&[2, g.input_dim()], 1.0, &mut rng);
        let y = layer.forward(&x, true).unwrap();
        let dx = layer.backward(&Tensor::filled(y.shape(), 1.0)).unwrap();
        let eps = 1e-2f32;
        for &idx in &[0usize, 5, g.input_dim() - 1] {
            for bi in 0..2 {
                let mut xp = x.clone();
                xp.set(&[bi, idx], x.at(&[bi, idx]) + eps);
                let mut xm = x.clone();
                xm.set(&[bi, idx], x.at(&[bi, idx]) - eps);
                let yp: f32 = layer.forward(&xp, false).unwrap().data().iter().sum();
                let ym: f32 = layer.forward(&xm, false).unwrap().data().iter().sum();
                let want = (yp - ym) / (2.0 * eps);
                let got = dx.at(&[bi, idx]);
                assert!(
                    (got - want).abs() < 2e-2 * (1.0 + want.abs()),
                    "dx[{bi},{idx}]: {got} vs {want}"
                );
            }
        }
    }

    #[test]
    fn conv_weight_gradient_matches_finite_differences() {
        let g = ConvGeom::new(1, 3, 3, 2, 2, 2, 1, 0).unwrap();
        let mut rng = Rng::new(23);
        let mut layer = Conv2d::new(g, &mut rng).unwrap();
        let x = Tensor::randn(&[2, g.input_dim()], 1.0, &mut rng);
        let y = layer.forward(&x, true).unwrap();
        let _ = layer.backward(&Tensor::filled(y.shape(), 1.0)).unwrap();
        let (w0, b0) = (layer.w.clone(), layer.b.clone());
        let eps = 1e-2f32;
        for &idx in &[0usize, 3, w0.numel() - 1] {
            let mut wp = w0.clone();
            wp.data_mut()[idx] += eps;
            let mut lp = Conv2d::from_weights(g, wp, b0.clone()).unwrap();
            let yp: f32 = lp.forward(&x, false).unwrap().data().iter().sum();
            let mut wm = w0.clone();
            wm.data_mut()[idx] -= eps;
            let mut lm = Conv2d::from_weights(g, wm, b0.clone()).unwrap();
            let ym: f32 = lm.forward(&x, false).unwrap().data().iter().sum();
            let want = (yp - ym) / (2.0 * eps);
            let got = layer.grad_w.data()[idx];
            assert!(
                (got - want).abs() < 2e-2 * (1.0 + want.abs()),
                "dw[{idx}]: {got} vs {want}"
            );
        }
        // bias gradient: dL/db_c = count of output positions per channel
        let per_chan = (g.out_h() * g.out_w() * 2) as f32;
        for c in 0..g.c_out {
            assert!((layer.grad_b.data()[c] - per_chan).abs() < 1e-3);
        }
    }

    #[test]
    fn garipov_modes_factor_the_kernel_matrix() {
        let g = ConvGeom::new(8, 16, 16, 16, 3, 3, 1, 1).unwrap();
        let (ms, ns) = garipov_modes(&g);
        assert_eq!(ms.len(), ns.len());
        assert_eq!(ms.iter().product::<usize>(), g.c_out);
        assert_eq!(ns.iter().product::<usize>(), g.patch_dim());
        assert_eq!(*ns.last().unwrap(), 9, "spatial taps are the trailing n-mode");
    }

    #[test]
    fn full_rank_tt_conv_matches_dense_conv() {
        // TT-SVD without truncation reproduces the dense kernel, so the
        // TT-conv forward must match the dense conv to f32 tolerance
        let g = small_geom();
        let mut rng = Rng::new(24);
        let mut dense = Conv2d::new(g, &mut rng).unwrap();
        let (w, b) = dense.weights();
        let (w, b) = (w.clone(), b.clone());
        let mut ttc = TtConv::compress(g, &w, &b, None, 0.0).unwrap();
        let x = Tensor::randn(&[4, g.input_dim()], 1.0, &mut rng);
        let want = dense.forward(&x, false).unwrap();
        let got = ttc.forward(&x, false).unwrap();
        for (a, e) in got.data().iter().zip(want.data()) {
            assert!((a - e).abs() < 1e-3 * (1.0 + e.abs()), "{a} vs {e}");
        }
        // compression actually reduced stored values at truncated rank
        let small = TtConv::compress(g, &w, &b, Some(2), 0.0).unwrap();
        assert!(small.num_params() < g.dense_params());
    }

    #[test]
    fn tt_conv_train_and_infer_paths_agree() {
        let g = small_geom();
        let mut rng = Rng::new(25);
        let mut layer = TtConv::new(g, 2, &mut rng).unwrap();
        let x = Tensor::randn(&[3, g.input_dim()], 1.0, &mut rng);
        let yt = layer.forward(&x, true).unwrap();
        let yi = layer.forward(&x, false).unwrap();
        for (a, b) in yt.data().iter().zip(yi.data()) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn tt_conv_input_gradient_matches_dense_equivalent() {
        let g = small_geom();
        let mut rng = Rng::new(26);
        let mut ttc = TtConv::new(g, 3, &mut rng).unwrap();
        // densify the TT kernel into an equivalent dense conv
        let w = ttc.inner().tt().to_dense().unwrap();
        let b = ttc.inner().bias().clone();
        let mut dense = Conv2d::from_weights(g, w, b).unwrap();
        let x = Tensor::randn(&[3, g.input_dim()], 1.0, &mut rng);
        let grad = Tensor::randn(&[3, g.output_dim()], 1.0, &mut rng);
        let _ = ttc.forward(&x, true).unwrap();
        let _ = dense.forward(&x, true).unwrap();
        let got = ttc.backward(&grad).unwrap();
        let want = dense.backward(&grad).unwrap();
        for (a, e) in got.data().iter().zip(want.data()) {
            assert!((a - e).abs() < 1e-3 * (1.0 + e.abs()), "{a} vs {e}");
        }
    }

    #[test]
    fn conv_state_roundtrips_bitwise_and_rejects_mismatch() {
        let g = small_geom();
        let mut rng = Rng::new(27);
        let mut dense = Conv2d::new(g, &mut rng).unwrap();
        let mut rebuilt = dense.export_state().unwrap().build().unwrap();
        let x = Tensor::randn(&[2, g.input_dim()], 1.0, &mut rng);
        assert_eq!(
            dense.forward(&x, false).unwrap().data(),
            rebuilt.forward(&x, false).unwrap().data()
        );

        let mut ttc = TtConv::new(g, 2, &mut rng).unwrap();
        let mut tt_rebuilt = ttc.export_state().unwrap().build().unwrap();
        assert_eq!(
            ttc.forward(&x, false).unwrap().data(),
            tt_rebuilt.forward(&x, false).unwrap().data()
        );

        // geometry mismatch is a hard reject that leaves params unchanged
        let other_geom = ConvGeom::new(2, 5, 4, 3, 3, 2, 1, 1).unwrap();
        let other = Conv2d::new(other_geom, &mut rng).unwrap().export_state().unwrap();
        let before = dense.w.clone();
        assert!(dense.import_state(other).is_err());
        assert_eq!(before.data(), dense.w.data());
        // rank mismatch through the TT inner import
        let other_tt = TtConv::new(g, 1, &mut rng).unwrap().export_state().unwrap();
        assert!(ttc.import_state(other_tt).is_err());
        // cross-kind mismatch
        assert!(ttc.import_state(dense.export_state().unwrap()).is_err());
    }
}
