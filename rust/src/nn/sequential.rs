//! Layer composition.

use crate::error::{Error, Result};
use crate::nn::layer::Layer;
use crate::nn::optim::SgdConfig;
use crate::nn::state::{import_mismatch, LayerState};
use crate::tensor::Tensor;

/// A straight-line stack of layers.
pub struct Sequential {
    layers: Vec<Box<dyn Layer>>,
}

impl Sequential {
    pub fn new(layers: Vec<Box<dyn Layer>>) -> Self {
        Sequential { layers }
    }

    pub fn push(&mut self, layer: Box<dyn Layer>) {
        self.layers.push(layer);
    }

    pub fn len(&self) -> usize {
        self.layers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.layers.is_empty()
    }

    pub fn layers(&self) -> &[Box<dyn Layer>] {
        &self.layers
    }

    /// One-line-per-layer structure summary with parameter counts.
    pub fn summary(&self) -> String {
        let mut s = String::new();
        for l in &self.layers {
            s.push_str(&format!("{:<60} {:>12} params\n", l.name(), l.num_params()));
        }
        s.push_str(&format!("{:<60} {:>12} params\n", "TOTAL", self.num_params()));
        s
    }
}

impl Layer for Sequential {
    fn name(&self) -> String {
        format!("Sequential[{}]", self.layers.iter().map(|l| l.name()).collect::<Vec<_>>().join(" -> "))
    }

    fn forward(&mut self, x: &Tensor, train: bool) -> Result<Tensor> {
        let mut cur = x.clone();
        for l in &mut self.layers {
            cur = l.forward(&cur, train)?;
        }
        Ok(cur)
    }

    fn backward(&mut self, grad_out: &Tensor) -> Result<Tensor> {
        let mut g = grad_out.clone();
        for l in self.layers.iter_mut().rev() {
            g = l.backward(&g)?;
        }
        Ok(g)
    }

    fn num_params(&self) -> usize {
        self.layers.iter().map(|l| l.num_params()).sum()
    }

    fn sgd_step(&mut self, cfg: &SgdConfig) -> Result<()> {
        for l in &mut self.layers {
            l.sgd_step(cfg)?;
        }
        Ok(())
    }

    fn zero_grads(&mut self) {
        for l in &mut self.layers {
            l.zero_grads();
        }
    }

    fn export_state(&self) -> Result<LayerState> {
        Ok(LayerState::Stack(
            self.layers.iter().map(|l| l.export_state()).collect::<Result<Vec<_>>>()?,
        ))
    }

    fn import_state(&mut self, state: LayerState) -> Result<()> {
        match state {
            LayerState::Stack(states) if states.len() == self.layers.len() => {
                // snapshot first so a mid-stack mismatch can roll back —
                // a half-imported stack would silently mix old and new
                // weights.  Rollback restores parameters bitwise but not
                // optimizer slots (states don't carry them); the Layer
                // contract documents that caveat.
                let snapshot = match self.export_state()? {
                    LayerState::Stack(prev) => prev,
                    _ => unreachable!("Sequential exports a Stack"),
                };
                for (i, s) in states.into_iter().enumerate() {
                    if let Err(e) = self.layers[i].import_state(s) {
                        for (l, p) in
                            self.layers.iter_mut().zip(snapshot.iter().cloned()).take(i)
                        {
                            let _ = l.import_state(p);
                        }
                        return Err(e);
                    }
                }
                Ok(())
            }
            LayerState::Stack(states) => Err(Error::Checkpoint(format!(
                "sequential import: {} layer states into a {}-layer stack",
                states.len(),
                self.layers.len()
            ))),
            other => Err(import_mismatch("Sequential", &other)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{Dense, Relu};
    use crate::util::rng::Rng;

    fn mlp(seed: u64) -> Sequential {
        let mut rng = Rng::new(seed);
        Sequential::new(vec![
            Box::new(Dense::new(6, 8, &mut rng)),
            Box::new(Relu::new()),
            Box::new(Dense::new(8, 3, &mut rng)),
        ])
    }

    #[test]
    fn forward_chains_shapes() {
        let mut net = mlp(1);
        let y = net.forward(&Tensor::zeros(&[5, 6]), false).unwrap();
        assert_eq!(y.shape(), &[5, 3]);
    }

    #[test]
    fn backward_returns_input_grad() {
        let mut net = mlp(2);
        let x = Tensor::randn(&[2, 6], 1.0, &mut Rng::new(3));
        let y = net.forward(&x, true).unwrap();
        let dx = net.backward(&Tensor::filled(y.shape(), 1.0)).unwrap();
        assert_eq!(dx.shape(), x.shape());
    }

    #[test]
    fn end_to_end_finite_difference() {
        let mut net = mlp(4);
        let x = Tensor::randn(&[2, 6], 1.0, &mut Rng::new(5));
        let y = net.forward(&x, true).unwrap();
        let dx = net.backward(&Tensor::filled(y.shape(), 1.0)).unwrap();
        let eps = 1e-2f32;
        for &i in &[0usize, 5, 11] {
            let mut xp = x.clone();
            xp.data_mut()[i] += eps;
            let mut xm = x.clone();
            xm.data_mut()[i] -= eps;
            let yp: f32 = net.forward(&xp, false).unwrap().data().iter().sum();
            let ym: f32 = net.forward(&xm, false).unwrap().data().iter().sum();
            let want = (yp - ym) / (2.0 * eps);
            assert!((dx.data()[i] - want).abs() < 2e-2 * (1.0 + want.abs()));
        }
    }

    #[test]
    fn num_params_sums() {
        let net = mlp(6);
        assert_eq!(net.num_params(), (6 * 8 + 8) + (8 * 3 + 3));
    }

    #[test]
    fn summary_mentions_layers() {
        let s = mlp(7).summary();
        assert!(s.contains("Dense(8x6)"));
        assert!(s.contains("TOTAL"));
    }
}
