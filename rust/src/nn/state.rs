//! Layer state export/import — the substrate of the checkpoint subsystem.
//!
//! [`LayerState`] is a concrete, serializable snapshot of a layer's
//! learnable parameters plus the minimal structure needed to rebuild the
//! layer *without knowing its concrete type*: every [`Layer`] can export
//! itself (`Layer::export_state`), restore in place
//! (`Layer::import_state`), or be reconstructed from scratch
//! ([`LayerState::build`]).  `runtime::checkpoint` maps this tree onto a
//! versioned on-disk manifest + tensor blob; this module stays pure
//! in-memory so the nn layer never depends on the runtime layer.
//!
//! Optimizer slots (gradients, momentum velocities) are deliberately NOT
//! part of the state: a restored layer starts with fresh zeros, which is
//! also what the paper's compress-then-fine-tune workflow (§5) wants —
//! the TT-SVD initialization carries no momentum history.

use crate::error::{Error, Result};
use crate::nn::btlayer::validate_parts;
use crate::nn::layer::Layer;
use crate::nn::{
    BtLinear, Conv2d, ConvGeom, Dense, Frozen, Relu, Sequential, Sigmoid, TtConv, TtLinear,
};
use crate::tensor::Tensor;
use crate::tt::{TtMatrix, TtShape};

/// A snapshot of one layer's parameters and structure.
///
/// The tree mirrors the layer tree: composite layers ([`Sequential`],
/// [`Frozen`]) hold child states, parametric layers hold tensors, and
/// stateless activations are bare tags.
#[derive(Clone, Debug)]
pub enum LayerState {
    /// [`Dense`]: `w (out, in)`, `b (out,)`.
    Dense { w: Tensor, b: Tensor },
    /// [`TtLinear`]: the full [`TtShape`] (modes + per-boundary ranks, so
    /// non-uniform TT-SVD ranks survive), cores `(r0, m, n, r1)`, bias.
    TtLinear { shape: TtShape, cores: Vec<Tensor>, bias: Tensor },
    /// [`Conv2d`]: geometry + kernel matrix `w (c_out, c_in·kh·kw)` and
    /// per-channel bias `b (c_out,)`.
    Conv { geom: ConvGeom, w: Tensor, b: Tensor },
    /// [`TtConv`]: geometry + the TT-format kernel (Garipov reshape).
    TtConv { geom: ConvGeom, shape: TtShape, cores: Vec<Tensor>, bias: Tensor },
    /// [`BtLinear`]: per-block Tucker-2 factors `A_b (out, r_b)`,
    /// `G_b (r_b, r_b)`, `B_b (r_b, in)`, plus bias `(out,)`.
    BtLinear { a: Vec<Tensor>, g: Vec<Tensor>, bt: Vec<Tensor>, bias: Tensor },
    /// [`Sequential`]: child states in forward order.
    Stack(Vec<LayerState>),
    /// [`Frozen`]: the wrapped layer's state (restored frozen again).
    Frozen(Box<LayerState>),
    /// [`Relu`] — stateless.
    Relu,
    /// [`Sigmoid`] — stateless.
    Sigmoid,
}

impl LayerState {
    /// Stable tag used by the checkpoint manifest.
    pub fn kind(&self) -> &'static str {
        match self {
            LayerState::Dense { .. } => "dense",
            LayerState::TtLinear { .. } => "tt_linear",
            LayerState::Conv { .. } => "conv",
            LayerState::TtConv { .. } => "tt_conv",
            LayerState::BtLinear { .. } => "bt_linear",
            LayerState::Stack(_) => "sequential",
            LayerState::Frozen(_) => "frozen",
            LayerState::Relu => "relu",
            LayerState::Sigmoid => "sigmoid",
        }
    }

    /// Per-row input dimension, when the structure determines one
    /// (activations are shape-polymorphic and report `None`).
    pub fn input_dim(&self) -> Option<usize> {
        match self {
            LayerState::Dense { w, .. } => Some(w.shape()[1]),
            LayerState::TtLinear { shape, .. } => Some(shape.n_total()),
            LayerState::Conv { geom, .. } | LayerState::TtConv { geom, .. } => {
                Some(geom.input_dim())
            }
            LayerState::BtLinear { bt, .. } => bt.first().map(|t| t.shape()[1]),
            LayerState::Stack(layers) => layers.iter().find_map(|l| l.input_dim()),
            LayerState::Frozen(inner) => inner.input_dim(),
            LayerState::Relu | LayerState::Sigmoid => None,
        }
    }

    /// Per-row output dimension (last shape-determining layer of a stack).
    pub fn output_dim(&self) -> Option<usize> {
        match self {
            LayerState::Dense { w, .. } => Some(w.shape()[0]),
            LayerState::TtLinear { shape, .. } => Some(shape.m_total()),
            LayerState::Conv { geom, .. } | LayerState::TtConv { geom, .. } => {
                Some(geom.output_dim())
            }
            LayerState::BtLinear { a, .. } => a.first().map(|t| t.shape()[0]),
            LayerState::Stack(layers) => layers.iter().rev().find_map(|l| l.output_dim()),
            LayerState::Frozen(inner) => inner.output_dim(),
            LayerState::Relu | LayerState::Sigmoid => None,
        }
    }

    /// Total stored scalar count — the exact number of f32 values a
    /// checkpoint blob of this state holds (unlike `Layer::num_params`,
    /// frozen parameters count: they still have to be persisted).
    pub fn num_values(&self) -> usize {
        match self {
            LayerState::Dense { w, b } => w.numel() + b.numel(),
            LayerState::TtLinear { cores, bias, .. }
            | LayerState::TtConv { cores, bias, .. } => {
                cores.iter().map(|c| c.numel()).sum::<usize>() + bias.numel()
            }
            LayerState::Conv { w, b, .. } => w.numel() + b.numel(),
            LayerState::BtLinear { a, g, bt, bias } => {
                let factors: usize =
                    [a, g, bt].iter().flat_map(|v| v.iter()).map(|t| t.numel()).sum();
                factors + bias.numel()
            }
            LayerState::Stack(layers) => layers.iter().map(|l| l.num_values()).sum(),
            LayerState::Frozen(inner) => inner.num_values(),
            LayerState::Relu | LayerState::Sigmoid => 0,
        }
    }

    /// Validate internal consistency (core shapes against the recorded
    /// [`TtShape`], bias lengths against output dims).  `build` performs
    /// the same checks implicitly; this is the cheap pre-flight used by
    /// checkpoint loading for early, well-located errors.
    pub fn validate(&self) -> Result<()> {
        match self {
            LayerState::Dense { w, b } => {
                if w.ndim() != 2 || b.ndim() != 1 || b.shape()[0] != w.shape()[0] {
                    return Err(Error::Checkpoint(format!(
                        "dense state: w {:?} incompatible with b {:?}",
                        w.shape(),
                        b.shape()
                    )));
                }
                Ok(())
            }
            LayerState::TtLinear { shape, cores, bias } => {
                validate_tt_parts(shape, cores, bias)
            }
            LayerState::Conv { geom, w, b } => {
                geom.validate()?;
                if w.shape() != [geom.c_out, geom.patch_dim()] || b.shape() != [geom.c_out] {
                    return Err(Error::Checkpoint(format!(
                        "conv state: w {:?} / b {:?} for geometry ({geom})",
                        w.shape(),
                        b.shape()
                    )));
                }
                Ok(())
            }
            LayerState::TtConv { geom, shape, cores, bias } => {
                geom.validate()?;
                if shape.m_total() != geom.c_out || shape.n_total() != geom.patch_dim() {
                    return Err(Error::Checkpoint(format!(
                        "tt-conv state: kernel {}x{} for geometry ({geom}: {}x{})",
                        shape.m_total(),
                        shape.n_total(),
                        geom.c_out,
                        geom.patch_dim()
                    )));
                }
                validate_tt_parts(shape, cores, bias)
            }
            LayerState::BtLinear { a, g, bt, bias } => {
                validate_parts(a, g, bt, bias)
                    .map(|_| ())
                    .map_err(|e| Error::Checkpoint(format!("bt state: {e}")))
            }
            LayerState::Stack(layers) => layers.iter().try_for_each(|l| l.validate()),
            LayerState::Frozen(inner) => inner.validate(),
            LayerState::Relu | LayerState::Sigmoid => Ok(()),
        }
    }

    /// Reconstruct a fresh layer from this state.  The inverse of
    /// `Layer::export_state`: `state.build()?.export_state()?` is
    /// bitwise-identical to `state`.
    pub fn build(self) -> Result<Box<dyn Layer>> {
        Ok(match self {
            LayerState::Dense { w, b } => Box::new(Dense::from_weights(w, b)?),
            LayerState::TtLinear { shape, cores, bias } => {
                let tt = TtMatrix::from_cores(shape, cores)?;
                if bias.shape() != [tt.m_total()] {
                    return Err(Error::Checkpoint(format!(
                        "tt bias {:?} for output dim {}",
                        bias.shape(),
                        tt.m_total()
                    )));
                }
                Box::new(TtLinear::from_tt(tt, bias))
            }
            LayerState::Conv { geom, w, b } => Box::new(Conv2d::from_weights(geom, w, b)?),
            LayerState::TtConv { geom, shape, cores, bias } => {
                validate_tt_parts(&shape, &cores, &bias)?;
                let tt = TtMatrix::from_cores(shape, cores)?;
                Box::new(TtConv::from_tt(geom, TtLinear::from_tt(tt, bias))?)
            }
            LayerState::BtLinear { a, g, bt, bias } => {
                Box::new(BtLinear::from_parts(a, g, bt, bias)?)
            }
            LayerState::Stack(layers) => {
                let built = layers
                    .into_iter()
                    .map(|l| l.build())
                    .collect::<Result<Vec<_>>>()?;
                Box::new(Sequential::new(built))
            }
            LayerState::Frozen(inner) => Box::new(Frozen(inner.build()?)),
            LayerState::Relu => Box::new(Relu::new()),
            LayerState::Sigmoid => Box::new(Sigmoid::new()),
        })
    }

    /// The compress half of the paper's train → compress → fine-tune loop,
    /// TT flavor: walk the tree and TT-SVD every [`Dense`] whose weight
    /// matrix is `(Πms x Πns)` into a [`TtLinear`].  Kept as a thin
    /// wrapper over the family-generic [`LayerState::compress`]; returns
    /// the transformed state and how many layers were converted.
    pub fn compress_dense(
        self,
        ms: &[usize],
        ns: &[usize],
        max_rank: Option<usize>,
        eps: f64,
    ) -> Result<(LayerState, usize)> {
        let spec = Compression::DenseToTt {
            ms: ms.to_vec(),
            ns: ns.to_vec(),
            max_rank,
            eps,
        };
        let (state, report) = self.compress(&spec)?;
        Ok((state, report.len()))
    }

    /// Family-generic compression walk: convert every leaf the `spec`
    /// targets (FC→TT, FC→BT, or dense-conv→TT-conv), pass everything
    /// else through untouched, and report one [`CompressedLayer`] per
    /// conversion (dotted paths match the checkpoint tensor namespace,
    /// rooted at `model`).
    pub fn compress(self, spec: &Compression) -> Result<(LayerState, Vec<CompressedLayer>)> {
        let mut report = Vec::new();
        let state = self.compress_walk(spec, "model", &mut report)?;
        Ok((state, report))
    }

    fn compress_walk(
        self,
        spec: &Compression,
        path: &str,
        report: &mut Vec<CompressedLayer>,
    ) -> Result<LayerState> {
        Ok(match self {
            LayerState::Dense { w, b } => compress_dense_leaf(w, b, spec, path, report)?,
            LayerState::Conv { geom, w, b } => {
                compress_conv_leaf(geom, w, b, spec, path, report)?
            }
            LayerState::Stack(layers) => {
                let mut out = Vec::with_capacity(layers.len());
                for (i, l) in layers.into_iter().enumerate() {
                    out.push(l.compress_walk(spec, &format!("{path}.{i}"), report)?);
                }
                LayerState::Stack(out)
            }
            LayerState::Frozen(inner) => LayerState::Frozen(Box::new(
                inner.compress_walk(spec, &format!("{path}.inner"), report)?,
            )),
            other => other,
        })
    }
}

/// One conversion target for the generalized compress walk
/// ([`LayerState::compress`]).
#[derive(Clone, Debug)]
pub enum Compression {
    /// [`Dense`] `(Πms x Πns)` → [`TtLinear`] via TT-SVD at the given
    /// rank cap / relative Frobenius tolerance.
    DenseToTt { ms: Vec<usize>, ns: Vec<usize>, max_rank: Option<usize>, eps: f64 },
    /// [`Dense`] `(n_out x n_in)` → [`BtLinear`] via truncated SVD split
    /// into `blocks` Tucker-2 blocks of rank ≤ `rank`.
    DenseToBt { n_out: usize, n_in: usize, blocks: usize, rank: usize, eps: f64 },
    /// Every dense [`Conv2d`] kernel → [`TtConv`] via TT-SVD over the
    /// Garipov reshape (modes derived from each layer's geometry).
    ConvToTt { max_rank: Option<usize>, eps: f64 },
}

/// Per-layer record of one compression conversion — the compression
/// factor is the paper's headline number, so the CLI prints these.
#[derive(Clone, Debug)]
pub struct CompressedLayer {
    /// Dotted path in the checkpoint tensor namespace (e.g. `model.1`).
    pub path: String,
    pub from_kind: &'static str,
    pub to_kind: &'static str,
    /// Stored f32 values before / after conversion.
    pub from_values: usize,
    pub to_values: usize,
    /// Achieved ranks: TT boundary ranks for TT targets, per-block
    /// Tucker ranks for BT.
    pub ranks: Vec<usize>,
}

impl CompressedLayer {
    pub fn ratio(&self) -> f64 {
        self.from_values as f64 / (self.to_values as f64).max(1.0)
    }
}

fn compress_dense_leaf(
    w: Tensor,
    b: Tensor,
    spec: &Compression,
    path: &str,
    report: &mut Vec<CompressedLayer>,
) -> Result<LayerState> {
    let from_values = w.numel() + b.numel();
    match spec {
        Compression::DenseToTt { ms, ns, max_rank, eps } => {
            let m_total: usize = ms.iter().product();
            let n_total: usize = ns.iter().product();
            if w.shape() != [m_total, n_total] {
                return Ok(LayerState::Dense { w, b });
            }
            let tt = TtMatrix::from_dense(&w, ms, ns, *max_rank, *eps)?;
            let state = LayerState::TtLinear {
                shape: tt.shape().clone(),
                cores: tt.cores().to_vec(),
                bias: b,
            };
            report.push(CompressedLayer {
                path: path.to_string(),
                from_kind: "dense",
                to_kind: "tt_linear",
                from_values,
                to_values: state.num_values(),
                ranks: tt.shape().ranks().to_vec(),
            });
            Ok(state)
        }
        Compression::DenseToBt { n_out, n_in, blocks, rank, eps } => {
            if w.shape() != [*n_out, *n_in] {
                return Ok(LayerState::Dense { w, b });
            }
            let bt = BtLinear::from_dense(&w, &b, *blocks, *rank, *eps)?;
            let ranks = bt.ranks();
            let state = bt.export_state()?;
            report.push(CompressedLayer {
                path: path.to_string(),
                from_kind: "dense",
                to_kind: "bt_linear",
                from_values,
                to_values: state.num_values(),
                ranks,
            });
            Ok(state)
        }
        Compression::ConvToTt { .. } => Ok(LayerState::Dense { w, b }),
    }
}

fn compress_conv_leaf(
    geom: ConvGeom,
    w: Tensor,
    b: Tensor,
    spec: &Compression,
    path: &str,
    report: &mut Vec<CompressedLayer>,
) -> Result<LayerState> {
    match spec {
        Compression::ConvToTt { max_rank, eps } => {
            let from_values = w.numel() + b.numel();
            let ttc = TtConv::compress(geom, &w, &b, *max_rank, *eps)?;
            let ranks = ttc.inner().tt().shape().ranks().to_vec();
            let state = ttc.export_state()?;
            report.push(CompressedLayer {
                path: path.to_string(),
                from_kind: "conv",
                to_kind: "tt_conv",
                from_values,
                to_values: state.num_values(),
                ranks,
            });
            Ok(state)
        }
        _ => Ok(LayerState::Conv { geom, w, b }),
    }
}

/// Shared TT shape/core/bias consistency checks for the `tt_linear` and
/// `tt_conv` state kinds.
fn validate_tt_parts(shape: &TtShape, cores: &[Tensor], bias: &Tensor) -> Result<()> {
    if cores.len() != shape.d() {
        return Err(Error::Checkpoint(format!(
            "tt state: {} cores for d={}",
            cores.len(),
            shape.d()
        )));
    }
    for (k, core) in cores.iter().enumerate() {
        if core.shape() != shape.core_shape(k) {
            return Err(Error::Checkpoint(format!(
                "tt state: core {k} is {:?}, shape says {:?}",
                core.shape(),
                shape.core_shape(k)
            )));
        }
    }
    if bias.shape() != [shape.m_total()] {
        return Err(Error::Checkpoint(format!(
            "tt state: bias {:?} for output dim {}",
            bias.shape(),
            shape.m_total()
        )));
    }
    Ok(())
}

/// Shorthand for the mismatch error every `import_state` impl raises.
pub(crate) fn import_mismatch(layer: &str, state: &LayerState) -> Error {
    Error::Checkpoint(format!(
        "cannot import '{}' state into a {layer} layer",
        state.kind()
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn mixed_net(seed: u64) -> Sequential {
        let mut rng = Rng::new(seed);
        let shape = TtShape::uniform(&[2, 3], &[3, 2], 2).unwrap();
        Sequential::new(vec![
            Box::new(Frozen(Dense::new(6, 6, &mut rng))),
            Box::new(TtLinear::new(&shape, &mut rng).unwrap()),
            Box::new(Relu::new()),
            Box::new(Dense::new(6, 4, &mut rng)),
            Box::new(Sigmoid::new()),
        ])
    }

    #[test]
    fn export_build_roundtrip_is_bitwise() {
        let mut net = mixed_net(1);
        let state = net.export_state().unwrap();
        assert_eq!(state.kind(), "sequential");
        assert_eq!(state.input_dim(), Some(6));
        assert_eq!(state.output_dim(), Some(4));
        let mut rebuilt = state.build().unwrap();
        let x = Tensor::randn(&[3, 6], 1.0, &mut Rng::new(2));
        let want = net.forward(&x, false).unwrap();
        let got = rebuilt.forward(&x, false).unwrap();
        assert_eq!(want.data(), got.data(), "rebuilt forward must be bitwise identical");
        // trainability preserved: frozen stays frozen
        assert_eq!(rebuilt.num_params(), net.num_params());
    }

    #[test]
    fn import_restores_in_place() {
        let mut a = mixed_net(3);
        let mut b = mixed_net(4); // same architecture, different weights
        let x = Tensor::randn(&[2, 6], 1.0, &mut Rng::new(5));
        let ya = a.forward(&x, false).unwrap();
        b.import_state(a.export_state().unwrap()).unwrap();
        let yb = b.forward(&x, false).unwrap();
        assert_eq!(ya.data(), yb.data());
    }

    #[test]
    fn import_rejects_wrong_kind_and_geometry() {
        let mut rng = Rng::new(6);
        let mut d = Dense::new(4, 3, &mut rng);
        assert!(d.import_state(LayerState::Relu).is_err());
        let other = Dense::new(5, 3, &mut rng).export_state().unwrap();
        assert!(d.import_state(other).is_err());
        let mut stack = Sequential::new(vec![Box::new(Relu::new())]);
        let two = LayerState::Stack(vec![LayerState::Relu, LayerState::Relu]);
        assert!(stack.import_state(two).is_err());
    }

    #[test]
    fn sequential_import_failure_leaves_stack_unchanged() {
        let mut rng = Rng::new(9);
        let mut net = Sequential::new(vec![
            Box::new(Dense::new(4, 4, &mut rng)),
            Box::new(Dense::new(4, 2, &mut rng)),
        ]);
        let x = Tensor::randn(&[2, 4], 1.0, &mut rng);
        let before = net.forward(&x, false).unwrap();
        // layer 0's state matches, layer 1's geometry doesn't: the import
        // must fail AND roll layer 0 back (Layer contract: unchanged on error)
        let bad = LayerState::Stack(vec![
            Dense::new(4, 4, &mut rng).export_state().unwrap(),
            Dense::new(5, 3, &mut rng).export_state().unwrap(),
        ]);
        assert!(net.import_state(bad).is_err());
        let after = net.forward(&x, false).unwrap();
        assert_eq!(before.data(), after.data());
    }

    #[test]
    fn validate_catches_inconsistent_tt_state() {
        let shape = TtShape::uniform(&[2, 2], &[2, 2], 2).unwrap();
        let bad = LayerState::TtLinear {
            shape: shape.clone(),
            cores: vec![Tensor::zeros(&[1, 2, 2, 2])], // only one of two cores
            bias: Tensor::zeros(&[4]),
        };
        assert!(bad.validate().is_err());
        let bad_bias = LayerState::TtLinear {
            shape: shape.clone(),
            cores: vec![Tensor::zeros(&[1, 2, 2, 2]), Tensor::zeros(&[2, 2, 2, 1])],
            bias: Tensor::zeros(&[3]),
        };
        assert!(bad_bias.validate().is_err());
        let good = LayerState::TtLinear {
            shape,
            cores: vec![Tensor::zeros(&[1, 2, 2, 2]), Tensor::zeros(&[2, 2, 2, 1])],
            bias: Tensor::zeros(&[4]),
        };
        assert!(good.validate().is_ok());
    }

    #[test]
    fn compress_dense_converts_matching_layers_only() {
        let mut rng = Rng::new(7);
        let net = Sequential::new(vec![
            Box::new(Dense::new(16, 16, &mut rng)),
            Box::new(Relu::new()),
            Box::new(Dense::new(16, 4, &mut rng)),
        ]);
        let state = net.export_state().unwrap();
        let dense_values = state.num_values();
        let (tt_state, converted) =
            state.compress_dense(&[4, 4], &[4, 4], Some(2), 0.0).unwrap();
        assert_eq!(converted, 1, "only the 16x16 layer matches the modes");
        assert!(tt_state.num_values() < dense_values);
        match &tt_state {
            LayerState::Stack(layers) => {
                assert_eq!(layers[0].kind(), "tt_linear");
                assert_eq!(layers[2].kind(), "dense"); // head untouched
            }
            other => panic!("expected stack, got {}", other.kind()),
        }
        // the compressed net still runs and approximates the original
        let mut rebuilt = tt_state.build().unwrap();
        let y = rebuilt.forward(&Tensor::zeros(&[2, 16]), false).unwrap();
        assert_eq!(y.shape(), &[2, 4]);
    }

    #[test]
    fn compress_reports_per_layer_ranks_and_ratio() {
        let mut rng = Rng::new(40);
        let net = Sequential::new(vec![
            Box::new(Dense::new(16, 16, &mut rng)),
            Box::new(Relu::new()),
            Box::new(Dense::new(16, 4, &mut rng)),
        ]);
        let spec = Compression::DenseToTt {
            ms: vec![4, 4],
            ns: vec![4, 4],
            max_rank: Some(2),
            eps: 0.0,
        };
        let (_, report) = net.export_state().unwrap().compress(&spec).unwrap();
        assert_eq!(report.len(), 1);
        let r = &report[0];
        assert_eq!(r.path, "model.0");
        assert_eq!((r.from_kind, r.to_kind), ("dense", "tt_linear"));
        assert_eq!(r.from_values, 16 * 16 + 16);
        assert!(r.to_values < r.from_values);
        assert!(r.ratio() > 1.0);
        assert_eq!(r.ranks.first(), Some(&1));
        assert!(r.ranks.iter().all(|&x| x <= 2));
    }

    #[test]
    fn compress_dense_to_bt_converts_matching_layers_only() {
        let mut rng = Rng::new(41);
        let mut net = Sequential::new(vec![
            Box::new(Dense::new(16, 16, &mut rng)),
            Box::new(Relu::new()),
            Box::new(Dense::new(16, 4, &mut rng)),
        ]);
        let x = Tensor::randn(&[3, 16], 1.0, &mut rng);
        let want = net.forward(&x, false).unwrap();
        // blocks·rank = 16 covers the full spectrum: exact conversion
        let spec = Compression::DenseToBt { n_out: 16, n_in: 16, blocks: 4, rank: 4, eps: 0.0 };
        let (state, report) = net.export_state().unwrap().compress(&spec).unwrap();
        assert_eq!(report.len(), 1, "only the 16x16 layer matches");
        assert_eq!(report[0].to_kind, "bt_linear");
        assert_eq!(report[0].ranks, vec![4, 4, 4, 4]);
        match &state {
            LayerState::Stack(layers) => {
                assert_eq!(layers[0].kind(), "bt_linear");
                assert_eq!(layers[2].kind(), "dense");
            }
            other => panic!("expected stack, got {}", other.kind()),
        }
        let got = state.build().unwrap().forward(&x, false).unwrap();
        for (a, b) in got.data().iter().zip(want.data()) {
            assert!((a - b).abs() < 1e-3 * (1.0 + b.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn compress_conv_to_tt_converts_conv_layers() {
        let mut rng = Rng::new(42);
        let geom = ConvGeom::new(2, 6, 6, 4, 3, 3, 1, 1).unwrap();
        let mut net = Sequential::new(vec![
            Box::new(Conv2d::new(geom, &mut rng).unwrap()),
            Box::new(Relu::new()),
            Box::new(Dense::new(geom.output_dim(), 4, &mut rng)),
        ]);
        let x = Tensor::randn(&[2, geom.input_dim()], 1.0, &mut rng);
        let want = net.forward(&x, false).unwrap();
        let spec = Compression::ConvToTt { max_rank: None, eps: 0.0 };
        let (state, report) = net.export_state().unwrap().compress(&spec).unwrap();
        assert_eq!(report.len(), 1, "the dense head is untouched by conv->tt");
        assert_eq!((report[0].from_kind, report[0].to_kind), ("conv", "tt_conv"));
        match &state {
            LayerState::Stack(layers) => assert_eq!(layers[0].kind(), "tt_conv"),
            other => panic!("expected stack, got {}", other.kind()),
        }
        // exact rank: compressed forward reproduces the dense conv net
        let got = state.build().unwrap().forward(&x, false).unwrap();
        for (a, b) in got.data().iter().zip(want.data()) {
            assert!((a - b).abs() < 1e-3 * (1.0 + b.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn compress_exact_rank_reproduces_forward() {
        // uncapped, eps 0: TT-SVD is exact, so forward outputs agree to
        // numerical precision with the dense parent
        let mut rng = Rng::new(8);
        let mut net = Sequential::new(vec![Box::new(Dense::new(16, 16, &mut rng))]);
        let x = Tensor::randn(&[3, 16], 1.0, &mut rng);
        let want = net.forward(&x, false).unwrap();
        let (state, c) = net
            .export_state()
            .unwrap()
            .compress_dense(&[4, 4], &[4, 4], None, 0.0)
            .unwrap();
        assert_eq!(c, 1);
        let got = state.build().unwrap().forward(&x, false).unwrap();
        for (a, b) in got.data().iter().zip(want.data()) {
            assert!((a - b).abs() < 1e-4 * (1.0 + b.abs()), "{a} vs {b}");
        }
    }
}
