//! Layer state export/import — the substrate of the checkpoint subsystem.
//!
//! [`LayerState`] is a concrete, serializable snapshot of a layer's
//! learnable parameters plus the minimal structure needed to rebuild the
//! layer *without knowing its concrete type*: every [`Layer`] can export
//! itself (`Layer::export_state`), restore in place
//! (`Layer::import_state`), or be reconstructed from scratch
//! ([`LayerState::build`]).  `runtime::checkpoint` maps this tree onto a
//! versioned on-disk manifest + tensor blob; this module stays pure
//! in-memory so the nn layer never depends on the runtime layer.
//!
//! Optimizer slots (gradients, momentum velocities) are deliberately NOT
//! part of the state: a restored layer starts with fresh zeros, which is
//! also what the paper's compress-then-fine-tune workflow (§5) wants —
//! the TT-SVD initialization carries no momentum history.

use crate::error::{Error, Result};
use crate::nn::layer::Layer;
use crate::nn::{Dense, Frozen, Relu, Sequential, Sigmoid, TtLinear};
use crate::tensor::Tensor;
use crate::tt::{TtMatrix, TtShape};

/// A snapshot of one layer's parameters and structure.
///
/// The tree mirrors the layer tree: composite layers ([`Sequential`],
/// [`Frozen`]) hold child states, parametric layers hold tensors, and
/// stateless activations are bare tags.
#[derive(Clone, Debug)]
pub enum LayerState {
    /// [`Dense`]: `w (out, in)`, `b (out,)`.
    Dense { w: Tensor, b: Tensor },
    /// [`TtLinear`]: the full [`TtShape`] (modes + per-boundary ranks, so
    /// non-uniform TT-SVD ranks survive), cores `(r0, m, n, r1)`, bias.
    TtLinear { shape: TtShape, cores: Vec<Tensor>, bias: Tensor },
    /// [`Sequential`]: child states in forward order.
    Stack(Vec<LayerState>),
    /// [`Frozen`]: the wrapped layer's state (restored frozen again).
    Frozen(Box<LayerState>),
    /// [`Relu`] — stateless.
    Relu,
    /// [`Sigmoid`] — stateless.
    Sigmoid,
}

impl LayerState {
    /// Stable tag used by the checkpoint manifest.
    pub fn kind(&self) -> &'static str {
        match self {
            LayerState::Dense { .. } => "dense",
            LayerState::TtLinear { .. } => "tt_linear",
            LayerState::Stack(_) => "sequential",
            LayerState::Frozen(_) => "frozen",
            LayerState::Relu => "relu",
            LayerState::Sigmoid => "sigmoid",
        }
    }

    /// Per-row input dimension, when the structure determines one
    /// (activations are shape-polymorphic and report `None`).
    pub fn input_dim(&self) -> Option<usize> {
        match self {
            LayerState::Dense { w, .. } => Some(w.shape()[1]),
            LayerState::TtLinear { shape, .. } => Some(shape.n_total()),
            LayerState::Stack(layers) => layers.iter().find_map(|l| l.input_dim()),
            LayerState::Frozen(inner) => inner.input_dim(),
            LayerState::Relu | LayerState::Sigmoid => None,
        }
    }

    /// Per-row output dimension (last shape-determining layer of a stack).
    pub fn output_dim(&self) -> Option<usize> {
        match self {
            LayerState::Dense { w, .. } => Some(w.shape()[0]),
            LayerState::TtLinear { shape, .. } => Some(shape.m_total()),
            LayerState::Stack(layers) => layers.iter().rev().find_map(|l| l.output_dim()),
            LayerState::Frozen(inner) => inner.output_dim(),
            LayerState::Relu | LayerState::Sigmoid => None,
        }
    }

    /// Total stored scalar count — the exact number of f32 values a
    /// checkpoint blob of this state holds (unlike `Layer::num_params`,
    /// frozen parameters count: they still have to be persisted).
    pub fn num_values(&self) -> usize {
        match self {
            LayerState::Dense { w, b } => w.numel() + b.numel(),
            LayerState::TtLinear { cores, bias, .. } => {
                cores.iter().map(|c| c.numel()).sum::<usize>() + bias.numel()
            }
            LayerState::Stack(layers) => layers.iter().map(|l| l.num_values()).sum(),
            LayerState::Frozen(inner) => inner.num_values(),
            LayerState::Relu | LayerState::Sigmoid => 0,
        }
    }

    /// Validate internal consistency (core shapes against the recorded
    /// [`TtShape`], bias lengths against output dims).  `build` performs
    /// the same checks implicitly; this is the cheap pre-flight used by
    /// checkpoint loading for early, well-located errors.
    pub fn validate(&self) -> Result<()> {
        match self {
            LayerState::Dense { w, b } => {
                if w.ndim() != 2 || b.ndim() != 1 || b.shape()[0] != w.shape()[0] {
                    return Err(Error::Checkpoint(format!(
                        "dense state: w {:?} incompatible with b {:?}",
                        w.shape(),
                        b.shape()
                    )));
                }
                Ok(())
            }
            LayerState::TtLinear { shape, cores, bias } => {
                if cores.len() != shape.d() {
                    return Err(Error::Checkpoint(format!(
                        "tt state: {} cores for d={}",
                        cores.len(),
                        shape.d()
                    )));
                }
                for (k, core) in cores.iter().enumerate() {
                    if core.shape() != shape.core_shape(k) {
                        return Err(Error::Checkpoint(format!(
                            "tt state: core {k} is {:?}, shape says {:?}",
                            core.shape(),
                            shape.core_shape(k)
                        )));
                    }
                }
                if bias.shape() != [shape.m_total()] {
                    return Err(Error::Checkpoint(format!(
                        "tt state: bias {:?} for output dim {}",
                        bias.shape(),
                        shape.m_total()
                    )));
                }
                Ok(())
            }
            LayerState::Stack(layers) => layers.iter().try_for_each(|l| l.validate()),
            LayerState::Frozen(inner) => inner.validate(),
            LayerState::Relu | LayerState::Sigmoid => Ok(()),
        }
    }

    /// Reconstruct a fresh layer from this state.  The inverse of
    /// `Layer::export_state`: `state.build()?.export_state()?` is
    /// bitwise-identical to `state`.
    pub fn build(self) -> Result<Box<dyn Layer>> {
        Ok(match self {
            LayerState::Dense { w, b } => Box::new(Dense::from_weights(w, b)?),
            LayerState::TtLinear { shape, cores, bias } => {
                let tt = TtMatrix::from_cores(shape, cores)?;
                if bias.shape() != [tt.m_total()] {
                    return Err(Error::Checkpoint(format!(
                        "tt bias {:?} for output dim {}",
                        bias.shape(),
                        tt.m_total()
                    )));
                }
                Box::new(TtLinear::from_tt(tt, bias))
            }
            LayerState::Stack(layers) => {
                let built = layers
                    .into_iter()
                    .map(|l| l.build())
                    .collect::<Result<Vec<_>>>()?;
                Box::new(Sequential::new(built))
            }
            LayerState::Frozen(inner) => Box::new(Frozen(inner.build()?)),
            LayerState::Relu => Box::new(Relu::new()),
            LayerState::Sigmoid => Box::new(Sigmoid::new()),
        })
    }

    /// The compress half of the paper's train → compress → fine-tune loop:
    /// walk the tree and TT-SVD every [`Dense`] whose weight matrix is
    /// `(Πms x Πns)` into a [`TtLinear`] at the given rank cap / relative
    /// Frobenius tolerance (`tt::ttsvd`).  Non-matching layers (e.g. the
    /// final classifier head) pass through untouched.  Returns the
    /// transformed state and how many layers were converted.
    pub fn compress_dense(
        self,
        ms: &[usize],
        ns: &[usize],
        max_rank: Option<usize>,
        eps: f64,
    ) -> Result<(LayerState, usize)> {
        let m_total: usize = ms.iter().product();
        let n_total: usize = ns.iter().product();
        Ok(match self {
            LayerState::Dense { w, b } if w.shape() == [m_total, n_total] => {
                let tt = TtMatrix::from_dense(&w, ms, ns, max_rank, eps)?;
                (
                    LayerState::TtLinear {
                        shape: tt.shape().clone(),
                        cores: tt.cores().to_vec(),
                        bias: b,
                    },
                    1,
                )
            }
            LayerState::Stack(layers) => {
                let mut converted = 0;
                let mut out = Vec::with_capacity(layers.len());
                for l in layers {
                    let (s, c) = l.compress_dense(ms, ns, max_rank, eps)?;
                    converted += c;
                    out.push(s);
                }
                (LayerState::Stack(out), converted)
            }
            LayerState::Frozen(inner) => {
                let (s, c) = inner.compress_dense(ms, ns, max_rank, eps)?;
                (LayerState::Frozen(Box::new(s)), c)
            }
            other => (other, 0),
        })
    }
}

/// Shorthand for the mismatch error every `import_state` impl raises.
pub(crate) fn import_mismatch(layer: &str, state: &LayerState) -> Error {
    Error::Checkpoint(format!(
        "cannot import '{}' state into a {layer} layer",
        state.kind()
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn mixed_net(seed: u64) -> Sequential {
        let mut rng = Rng::new(seed);
        let shape = TtShape::uniform(&[2, 3], &[3, 2], 2).unwrap();
        Sequential::new(vec![
            Box::new(Frozen(Dense::new(6, 6, &mut rng))),
            Box::new(TtLinear::new(&shape, &mut rng).unwrap()),
            Box::new(Relu::new()),
            Box::new(Dense::new(6, 4, &mut rng)),
            Box::new(Sigmoid::new()),
        ])
    }

    #[test]
    fn export_build_roundtrip_is_bitwise() {
        let mut net = mixed_net(1);
        let state = net.export_state().unwrap();
        assert_eq!(state.kind(), "sequential");
        assert_eq!(state.input_dim(), Some(6));
        assert_eq!(state.output_dim(), Some(4));
        let mut rebuilt = state.build().unwrap();
        let x = Tensor::randn(&[3, 6], 1.0, &mut Rng::new(2));
        let want = net.forward(&x, false).unwrap();
        let got = rebuilt.forward(&x, false).unwrap();
        assert_eq!(want.data(), got.data(), "rebuilt forward must be bitwise identical");
        // trainability preserved: frozen stays frozen
        assert_eq!(rebuilt.num_params(), net.num_params());
    }

    #[test]
    fn import_restores_in_place() {
        let mut a = mixed_net(3);
        let mut b = mixed_net(4); // same architecture, different weights
        let x = Tensor::randn(&[2, 6], 1.0, &mut Rng::new(5));
        let ya = a.forward(&x, false).unwrap();
        b.import_state(a.export_state().unwrap()).unwrap();
        let yb = b.forward(&x, false).unwrap();
        assert_eq!(ya.data(), yb.data());
    }

    #[test]
    fn import_rejects_wrong_kind_and_geometry() {
        let mut rng = Rng::new(6);
        let mut d = Dense::new(4, 3, &mut rng);
        assert!(d.import_state(LayerState::Relu).is_err());
        let other = Dense::new(5, 3, &mut rng).export_state().unwrap();
        assert!(d.import_state(other).is_err());
        let mut stack = Sequential::new(vec![Box::new(Relu::new())]);
        let two = LayerState::Stack(vec![LayerState::Relu, LayerState::Relu]);
        assert!(stack.import_state(two).is_err());
    }

    #[test]
    fn sequential_import_failure_leaves_stack_unchanged() {
        let mut rng = Rng::new(9);
        let mut net = Sequential::new(vec![
            Box::new(Dense::new(4, 4, &mut rng)),
            Box::new(Dense::new(4, 2, &mut rng)),
        ]);
        let x = Tensor::randn(&[2, 4], 1.0, &mut rng);
        let before = net.forward(&x, false).unwrap();
        // layer 0's state matches, layer 1's geometry doesn't: the import
        // must fail AND roll layer 0 back (Layer contract: unchanged on error)
        let bad = LayerState::Stack(vec![
            Dense::new(4, 4, &mut rng).export_state().unwrap(),
            Dense::new(5, 3, &mut rng).export_state().unwrap(),
        ]);
        assert!(net.import_state(bad).is_err());
        let after = net.forward(&x, false).unwrap();
        assert_eq!(before.data(), after.data());
    }

    #[test]
    fn validate_catches_inconsistent_tt_state() {
        let shape = TtShape::uniform(&[2, 2], &[2, 2], 2).unwrap();
        let bad = LayerState::TtLinear {
            shape: shape.clone(),
            cores: vec![Tensor::zeros(&[1, 2, 2, 2])], // only one of two cores
            bias: Tensor::zeros(&[4]),
        };
        assert!(bad.validate().is_err());
        let bad_bias = LayerState::TtLinear {
            shape: shape.clone(),
            cores: vec![Tensor::zeros(&[1, 2, 2, 2]), Tensor::zeros(&[2, 2, 2, 1])],
            bias: Tensor::zeros(&[3]),
        };
        assert!(bad_bias.validate().is_err());
        let good = LayerState::TtLinear {
            shape,
            cores: vec![Tensor::zeros(&[1, 2, 2, 2]), Tensor::zeros(&[2, 2, 2, 1])],
            bias: Tensor::zeros(&[4]),
        };
        assert!(good.validate().is_ok());
    }

    #[test]
    fn compress_dense_converts_matching_layers_only() {
        let mut rng = Rng::new(7);
        let net = Sequential::new(vec![
            Box::new(Dense::new(16, 16, &mut rng)),
            Box::new(Relu::new()),
            Box::new(Dense::new(16, 4, &mut rng)),
        ]);
        let state = net.export_state().unwrap();
        let dense_values = state.num_values();
        let (tt_state, converted) =
            state.compress_dense(&[4, 4], &[4, 4], Some(2), 0.0).unwrap();
        assert_eq!(converted, 1, "only the 16x16 layer matches the modes");
        assert!(tt_state.num_values() < dense_values);
        match &tt_state {
            LayerState::Stack(layers) => {
                assert_eq!(layers[0].kind(), "tt_linear");
                assert_eq!(layers[2].kind(), "dense"); // head untouched
            }
            other => panic!("expected stack, got {}", other.kind()),
        }
        // the compressed net still runs and approximates the original
        let mut rebuilt = tt_state.build().unwrap();
        let y = rebuilt.forward(&Tensor::zeros(&[2, 16]), false).unwrap();
        assert_eq!(y.shape(), &[2, 4]);
    }

    #[test]
    fn compress_exact_rank_reproduces_forward() {
        // uncapped, eps 0: TT-SVD is exact, so forward outputs agree to
        // numerical precision with the dense parent
        let mut rng = Rng::new(8);
        let mut net = Sequential::new(vec![Box::new(Dense::new(16, 16, &mut rng))]);
        let x = Tensor::randn(&[3, 16], 1.0, &mut rng);
        let want = net.forward(&x, false).unwrap();
        let (state, c) = net
            .export_state()
            .unwrap()
            .compress_dense(&[4, 4], &[4, 4], None, 0.0)
            .unwrap();
        assert_eq!(c, 1);
        let got = state.build().unwrap().forward(&x, false).unwrap();
        for (a, b) in got.data().iter().zip(want.data()) {
            assert!((a - b).abs() < 1e-4 * (1.0 + b.abs()), "{a} vs {b}");
        }
    }
}
