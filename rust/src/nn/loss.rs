//! Softmax cross-entropy (fused loss + gradient) and accuracy metrics.

use crate::error::{shape_err, Result};
use crate::tensor::Tensor;

/// Fused softmax + cross-entropy over integer class labels.
pub struct SoftmaxXent;

impl SoftmaxXent {
    /// Returns `(mean_loss, dL/dlogits)` for logits `(B, C)` and labels
    /// `(B,)`.  Numerically stable (max-subtracted log-sum-exp); the
    /// gradient is the classic `softmax(p) - onehot(y)` scaled by `1/B`.
    pub fn loss_and_grad(logits: &Tensor, labels: &[usize]) -> Result<(f32, Tensor)> {
        if logits.ndim() != 2 || logits.shape()[0] != labels.len() {
            return shape_err(format!(
                "xent: logits {:?} vs {} labels",
                logits.shape(),
                labels.len()
            ));
        }
        let (b, c) = (logits.shape()[0], logits.shape()[1]);
        if let Some(&bad) = labels.iter().find(|&&y| y >= c) {
            return shape_err(format!("label {bad} out of range for {c} classes"));
        }
        let mut grad = logits.clone();
        let mut total = 0.0f64;
        let inv_b = 1.0 / b as f32;
        for (i, row) in grad.data_mut().chunks_mut(c).enumerate() {
            let max = row.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x));
            let mut sum = 0.0f32;
            for v in row.iter_mut() {
                *v = (*v - max).exp();
                sum += *v;
            }
            let y = labels[i];
            total += -((row[y] / sum).max(f32::MIN_POSITIVE).ln() as f64);
            for v in row.iter_mut() {
                *v /= sum; // softmax
            }
            row[y] -= 1.0;
            for v in row.iter_mut() {
                *v *= inv_b;
            }
        }
        Ok(((total / b as f64) as f32, grad))
    }

    /// Mean loss only (evaluation).
    pub fn loss(logits: &Tensor, labels: &[usize]) -> Result<f32> {
        Ok(Self::loss_and_grad(logits, labels)?.0)
    }
}

/// Fraction of rows whose argmax equals the label.
pub fn accuracy(logits: &Tensor, labels: &[usize]) -> Result<f32> {
    if logits.ndim() != 2 || logits.shape()[0] != labels.len() {
        return shape_err(format!("accuracy: {:?} vs {}", logits.shape(), labels.len()));
    }
    let c = logits.shape()[1];
    let mut hits = 0usize;
    for (row, &y) in logits.data().chunks(c).zip(labels) {
        let mut best = 0usize;
        for (j, &v) in row.iter().enumerate() {
            if v > row[best] {
                best = j;
            }
        }
        if best == y {
            hits += 1;
        }
    }
    Ok(hits as f32 / labels.len() as f32)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loss_matches_manual() {
        let logits = Tensor::from_vec(&[2, 3], vec![2.0, 0.5, -1.0, 0.0, 0.0, 0.0]).unwrap();
        let labels = [0usize, 2];
        let (loss, _) = SoftmaxXent::loss_and_grad(&logits, &labels).unwrap();
        let p0 = (2.0f64).exp() / ((2.0f64).exp() + (0.5f64).exp() + (-1.0f64).exp());
        let want = (-(p0.ln()) - (1.0f64 / 3.0).ln()) / 2.0;
        assert!((loss as f64 - want).abs() < 1e-5, "{loss} vs {want}");
    }

    #[test]
    fn grad_rows_sum_to_zero() {
        let logits = Tensor::from_vec(&[2, 4], vec![1., 2., 3., 4., -1., 0., 1., 2.]).unwrap();
        let (_, g) = SoftmaxXent::loss_and_grad(&logits, &[1, 3]).unwrap();
        for row in g.data().chunks(4) {
            let s: f32 = row.iter().sum();
            assert!(s.abs() < 1e-6);
        }
    }

    #[test]
    fn grad_matches_finite_difference() {
        let logits = Tensor::from_vec(&[1, 3], vec![0.3, -0.8, 1.2]).unwrap();
        let labels = [2usize];
        let (_, g) = SoftmaxXent::loss_and_grad(&logits, &labels).unwrap();
        let eps = 1e-3f32;
        for i in 0..3 {
            let mut lp = logits.clone();
            lp.data_mut()[i] += eps;
            let mut lm = logits.clone();
            lm.data_mut()[i] -= eps;
            let want = (SoftmaxXent::loss(&lp, &labels).unwrap()
                - SoftmaxXent::loss(&lm, &labels).unwrap())
                / (2.0 * eps);
            assert!((g.data()[i] - want).abs() < 1e-3, "{} vs {}", g.data()[i], want);
        }
    }

    #[test]
    fn accuracy_counts() {
        let logits =
            Tensor::from_vec(&[3, 2], vec![0.9, 0.1, 0.2, 0.8, 0.6, 0.4]).unwrap();
        assert!((accuracy(&logits, &[0, 1, 1]).unwrap() - 2.0 / 3.0).abs() < 1e-6);
    }

    #[test]
    fn label_out_of_range() {
        let logits = Tensor::zeros(&[1, 3]);
        assert!(SoftmaxXent::loss_and_grad(&logits, &[3]).is_err());
    }

    #[test]
    fn extreme_logits_stable() {
        let logits = Tensor::from_vec(&[1, 2], vec![1000.0, -1000.0]).unwrap();
        let (loss, g) = SoftmaxXent::loss_and_grad(&logits, &[0]).unwrap();
        assert!(loss.is_finite());
        assert!(g.data().iter().all(|x| x.is_finite()));
    }
}
