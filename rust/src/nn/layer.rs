//! The layer trait: forward with activation caching, backward, SGD update.

use crate::error::Result;
use crate::nn::optim::SgdConfig;
use crate::tensor::Tensor;

/// A differentiable network layer.
///
/// Contract: `forward(x, train=true)` caches whatever `backward` needs;
/// `backward(grad_out)` consumes that cache and returns `grad_in`, leaving
/// parameter gradients stored in the layer until `sgd_step` / `zero_grads`.
pub trait Layer: Send {
    /// Human-readable layer description (used in summaries).
    fn name(&self) -> String;

    /// Compute the layer output.  With `train = false` no state is cached.
    fn forward(&mut self, x: &Tensor, train: bool) -> Result<Tensor>;

    /// Back-propagate: given `dL/d(output)` return `dL/d(input)` and
    /// accumulate parameter gradients.
    fn backward(&mut self, grad_out: &Tensor) -> Result<Tensor>;

    /// Number of learnable parameters.
    fn num_params(&self) -> usize {
        0
    }

    /// Apply one SGD-with-momentum step to the layer's parameters using
    /// the gradients accumulated by `backward`, then clear them.
    fn sgd_step(&mut self, _cfg: &SgdConfig) -> Result<()> {
        Ok(())
    }

    /// Drop any accumulated gradients.
    fn zero_grads(&mut self) {}
}
