//! The layer trait: forward with activation caching, backward, SGD update,
//! and state export/import (the checkpoint visitor).

use crate::error::Result;
use crate::nn::optim::SgdConfig;
use crate::nn::state::LayerState;
use crate::tensor::Tensor;

/// A differentiable network layer.
///
/// Contract: `forward(x, train=true)` caches whatever `backward` needs;
/// `backward(grad_out)` consumes that cache and returns `grad_in`, leaving
/// parameter gradients stored in the layer until `sgd_step` / `zero_grads`.
///
/// Every layer additionally participates in the checkpoint protocol:
/// `export_state` snapshots its parameters into a [`LayerState`] tree and
/// `import_state` restores them in place.  Both are mandatory — a layer
/// that cannot be persisted cannot ship through the train → compress →
/// serve lifecycle (see `runtime::checkpoint`).
pub trait Layer: Send {
    /// Human-readable layer description (used in summaries).
    fn name(&self) -> String;

    /// Compute the layer output.  With `train = false` no state is cached.
    fn forward(&mut self, x: &Tensor, train: bool) -> Result<Tensor>;

    /// Back-propagate: given `dL/d(output)` return `dL/d(input)` and
    /// accumulate parameter gradients.
    fn backward(&mut self, grad_out: &Tensor) -> Result<Tensor>;

    /// Number of learnable parameters.
    fn num_params(&self) -> usize {
        0
    }

    /// Apply one SGD-with-momentum step to the layer's parameters using
    /// the gradients accumulated by `backward`, then clear them.
    fn sgd_step(&mut self, _cfg: &SgdConfig) -> Result<()> {
        Ok(())
    }

    /// Drop any accumulated gradients.
    fn zero_grads(&mut self) {}

    /// Snapshot the layer's parameters and structure.
    ///
    /// Invariant: `export_state()?.build()?` yields a layer whose eval-mode
    /// forward is bitwise-identical to this one's.
    fn export_state(&self) -> Result<LayerState>;

    /// Restore parameters from a state previously produced by
    /// `export_state` on a layer of the same architecture.  Gradients and
    /// optimizer velocities reset to zero.  Errors on a kind or geometry
    /// mismatch, leaving *parameters* unchanged; a composite layer whose
    /// rollback re-imports an earlier snapshot may still have reset the
    /// optimizer slots of its children ([`LayerState`] does not carry
    /// them), so treat a failed import as also zeroing momentum.
    fn import_state(&mut self, state: LayerState) -> Result<()>;
}

/// Boxed layers are layers: lets heterogeneous stacks rebuilt from
/// checkpoints ([`LayerState::build`]) slot in anywhere a concrete layer
/// would — e.g. inside [`crate::nn::Frozen`].
impl Layer for Box<dyn Layer> {
    fn name(&self) -> String {
        (**self).name()
    }

    fn forward(&mut self, x: &Tensor, train: bool) -> Result<Tensor> {
        (**self).forward(x, train)
    }

    fn backward(&mut self, grad_out: &Tensor) -> Result<Tensor> {
        (**self).backward(grad_out)
    }

    fn num_params(&self) -> usize {
        (**self).num_params()
    }

    fn sgd_step(&mut self, cfg: &SgdConfig) -> Result<()> {
        (**self).sgd_step(cfg)
    }

    fn zero_grads(&mut self) {
        (**self).zero_grads()
    }

    fn export_state(&self) -> Result<LayerState> {
        (**self).export_state()
    }

    fn import_state(&mut self, state: LayerState) -> Result<()> {
        (**self).import_state(state)
    }
}
