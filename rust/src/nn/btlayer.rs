//! Block-term linear layer (BT-Nets, Li et al. 2018): the weight matrix
//! is a sum of Tucker-2 blocks `W = Σ_b A_b·G_b·B_b` with
//! `A_b (M x r_b)`, `G_b (r_b x r_b)`, `B_b (r_b x N)` — a different
//! low-parameter family than TT, trading the TT ranks' chain structure
//! for a wider, flatter sum of low-rank terms.
//!
//! Storage is `Σ_b (M·r_b + r_b² + r_b·N) + M` values against the dense
//! `M·N + M`; the matvec costs `Σ_b 2·r_b·(M + N + r_b)` FLOPs per row,
//! all of it riding the shared `Gemm`/SIMD kernels (three skinny GEMMs
//! per block).  The SVD-based `from_dense` compress path splits the
//! top-`Σ r_b` singular triplets of the trained dense matrix contiguously
//! across blocks, so at full rank it is exact — the same
//! "compress-then-fine-tune" lifecycle the paper runs for TT.

use crate::error::{shape_err, Error, Result};
use crate::linalg::truncated_svd;
use crate::nn::layer::Layer;
use crate::nn::optim::{sgd_update, SgdConfig};
use crate::nn::state::{import_mismatch, LayerState};
use crate::tensor::{matmul, matmul_at, matmul_bt, Tensor};
use crate::util::rng::Rng;

struct BtCache {
    x: Tensor,
    /// per-block `(t1 = x·B_bᵀ, t2 = t1·G_bᵀ)`
    mids: Vec<(Tensor, Tensor)>,
}

/// A fully-connected layer whose weight matrix is a sum of Tucker-2
/// blocks (block-term decomposition).
pub struct BtLinear {
    n_out: usize,
    n_in: usize,
    a: Vec<Tensor>,  // (n_out, r_b)
    g: Vec<Tensor>,  // (r_b, r_b)
    bt: Vec<Tensor>, // (r_b, n_in)
    bias: Tensor,    // (n_out)
    grad_a: Vec<Tensor>,
    grad_g: Vec<Tensor>,
    grad_bt: Vec<Tensor>,
    grad_bias: Tensor,
    vel_a: Vec<Tensor>,
    vel_g: Vec<Tensor>,
    vel_bt: Vec<Tensor>,
    vel_bias: Tensor,
    cache: Option<BtCache>,
}

/// Shape-check the factor lists; returns `(n_out, n_in)`.
pub(crate) fn validate_parts(
    a: &[Tensor],
    g: &[Tensor],
    bt: &[Tensor],
    bias: &Tensor,
) -> Result<(usize, usize)> {
    if a.is_empty() || a.len() != g.len() || a.len() != bt.len() {
        return shape_err(format!(
            "bt: block counts differ (a {}, g {}, b {})",
            a.len(),
            g.len(),
            bt.len()
        ));
    }
    let first = &a[0];
    if first.ndim() != 2 {
        return shape_err(format!("bt: A_0 not a matrix: {:?}", first.shape()));
    }
    let n_out = first.shape()[0];
    if bt[0].ndim() != 2 {
        return shape_err(format!("bt: B_0 not a matrix: {:?}", bt[0].shape()));
    }
    let n_in = bt[0].shape()[1];
    for k in 0..a.len() {
        let r = a[k].shape()[1];
        if r == 0
            || a[k].shape() != [n_out, r]
            || g[k].shape() != [r, r]
            || bt[k].shape() != [r, n_in]
        {
            return shape_err(format!(
                "bt block {k}: A {:?}, G {:?}, B {:?} inconsistent for {n_out}x{n_in}",
                a[k].shape(),
                g[k].shape(),
                bt[k].shape()
            ));
        }
    }
    if bias.shape() != [n_out] {
        return shape_err(format!("bt bias {:?}, want ({n_out})", bias.shape()));
    }
    Ok((n_out, n_in))
}

impl BtLinear {
    /// Gaussian-initialized BT layer with `blocks` equal-rank blocks.
    /// The per-factor std is chosen so the composed `W` has He-style
    /// fan-in variance `2/n_in` across the block sum.
    pub fn new(n_out: usize, n_in: usize, blocks: usize, rank: usize, rng: &mut Rng) -> Result<Self> {
        if blocks == 0 || rank == 0 || n_out == 0 || n_in == 0 {
            return shape_err(format!(
                "bt new: degenerate config {n_out}x{n_in}, blocks {blocks}, rank {rank}"
            ));
        }
        let var = 2.0 / (n_in as f64 * blocks as f64 * (rank * rank) as f64);
        let std = (var as f32).powf(1.0 / 6.0);
        let a = (0..blocks).map(|_| Tensor::randn(&[n_out, rank], std, rng)).collect();
        let g = (0..blocks).map(|_| Tensor::randn(&[rank, rank], std, rng)).collect();
        let bt = (0..blocks).map(|_| Tensor::randn(&[rank, n_in], std, rng)).collect();
        Self::from_parts(a, g, bt, Tensor::zeros(&[n_out]))
    }

    /// Wrap existing factors (e.g. from a checkpoint or `from_dense`).
    pub fn from_parts(
        a: Vec<Tensor>,
        g: Vec<Tensor>,
        bt: Vec<Tensor>,
        bias: Tensor,
    ) -> Result<Self> {
        let (n_out, n_in) = validate_parts(&a, &g, &bt, &bias)?;
        let zeros = |ts: &[Tensor]| -> Vec<Tensor> {
            ts.iter().map(|t| Tensor::zeros(t.shape())).collect()
        };
        let (grad_a, grad_g, grad_bt) = (zeros(&a), zeros(&g), zeros(&bt));
        let (vel_a, vel_g, vel_bt) = (zeros(&a), zeros(&g), zeros(&bt));
        let grad_bias = Tensor::zeros(bias.shape());
        let vel_bias = Tensor::zeros(bias.shape());
        Ok(BtLinear {
            n_out,
            n_in,
            a,
            g,
            bt,
            bias,
            grad_a,
            grad_g,
            grad_bt,
            grad_bias,
            vel_a,
            vel_g,
            vel_bt,
            vel_bias,
            cache: None,
        })
    }

    /// SVD-based compression of a trained dense matrix `w (M x N)` into
    /// `blocks` Tucker-2 blocks of rank ≤ `rank` each: the top
    /// `blocks·rank` singular triplets (after the relative-Frobenius
    /// `eps` truncation) are split contiguously across blocks, with
    /// `G_b = diag(σ)` carrying the spectrum.  Exact when
    /// `blocks·rank ≥ rank(w)` and `eps = 0`.
    pub fn from_dense(
        w: &Tensor,
        bias: &Tensor,
        blocks: usize,
        rank: usize,
        eps: f64,
    ) -> Result<Self> {
        if w.ndim() != 2 {
            return shape_err(format!("bt from_dense: want 2-D, got {:?}", w.shape()));
        }
        if blocks == 0 || rank == 0 {
            return shape_err(format!("bt from_dense: blocks {blocks}, rank {rank}"));
        }
        let delta = eps * w.norm() as f64;
        let tsvd = truncated_svd(w, Some(blocks * rank), delta)?;
        let k = tsvd.s.len();
        let blocks_eff = blocks.min(k); // never materialize empty blocks
        let mut a = Vec::with_capacity(blocks_eff);
        let mut g = Vec::with_capacity(blocks_eff);
        let mut bt = Vec::with_capacity(blocks_eff);
        let ut = tsvd.u.t2()?; // (k, M): row slices are U column slices
        for bi in 0..blocks_eff {
            let c0 = bi * k / blocks_eff;
            let c1 = (bi + 1) * k / blocks_eff;
            let r = c1 - c0;
            a.push(ut.rows(c0, c1)?.t2()?); // (M, r)
            let mut core = Tensor::zeros(&[r, r]);
            for (i, &sv) in tsvd.s[c0..c1].iter().enumerate() {
                core.set(&[i, i], sv);
            }
            g.push(core);
            bt.push(tsvd.vt.rows(c0, c1)?); // (r, N)
        }
        Self::from_parts(a, g, bt, bias.clone())
    }

    pub fn n_in(&self) -> usize {
        self.n_in
    }

    pub fn n_out(&self) -> usize {
        self.n_out
    }

    pub fn blocks(&self) -> usize {
        self.a.len()
    }

    /// Per-block Tucker ranks.
    pub fn ranks(&self) -> Vec<usize> {
        self.a.iter().map(|t| t.shape()[1]).collect()
    }

    pub fn bias(&self) -> &Tensor {
        &self.bias
    }

    /// Materialize `W = Σ_b A_b·G_b·B_b` (tests / parity checks only).
    pub fn to_dense(&self) -> Result<Tensor> {
        let mut w = Tensor::zeros(&[self.n_out, self.n_in]);
        for k in 0..self.blocks() {
            let ag = matmul(&self.a[k], &self.g[k])?;
            w.axpy(1.0, &matmul(&ag, &self.bt[k])?)?;
        }
        Ok(w)
    }

    /// Dense parameter count this layer replaces.
    pub fn dense_params(&self) -> usize {
        self.n_out * self.n_in + self.n_out
    }
}

impl Layer for BtLinear {
    fn name(&self) -> String {
        format!(
            "BtLinear({}x{}; blocks {}; ranks {:?}; params {})",
            self.n_out,
            self.n_in,
            self.blocks(),
            self.ranks(),
            self.num_params()
        )
    }

    fn forward(&mut self, x: &Tensor, train: bool) -> Result<Tensor> {
        if x.ndim() != 2 || x.shape()[1] != self.n_in {
            return shape_err(format!("bt fwd: {:?}, want (B, {})", x.shape(), self.n_in));
        }
        let b = x.shape()[0];
        let mut y = Tensor::zeros(&[b, self.n_out]);
        let mut mids = Vec::with_capacity(if train { self.blocks() } else { 0 });
        for k in 0..self.blocks() {
            // y += x·B_bᵀ·G_bᵀ·A_bᵀ — three skinny GEMMs
            let t1 = matmul_bt(x, &self.bt[k])?; // (B, r)
            let t2 = matmul_bt(&t1, &self.g[k])?; // (B, r)
            y.axpy(1.0, &matmul_bt(&t2, &self.a[k])?)?;
            if train {
                mids.push((t1, t2));
            }
        }
        let bias = self.bias.data();
        for row in y.data_mut().chunks_mut(bias.len()) {
            for (o, &bb) in row.iter_mut().zip(bias) {
                *o += bb;
            }
        }
        if train {
            self.cache = Some(BtCache { x: x.clone(), mids });
        }
        Ok(y)
    }

    fn backward(&mut self, grad_out: &Tensor) -> Result<Tensor> {
        let cache = self
            .cache
            .take()
            .ok_or_else(|| Error::Numerical("bt backward without forward".into()))?;
        let b = cache.x.shape()[0];
        if grad_out.shape() != [b, self.n_out] {
            return shape_err(format!("bt bwd: grad {:?}", grad_out.shape()));
        }
        let gb = self.grad_bias.data_mut();
        for row in grad_out.data().chunks(self.n_out) {
            for (acc, &v) in gb.iter_mut().zip(row) {
                *acc += v;
            }
        }
        let mut dx = Tensor::zeros(&[b, self.n_in]);
        for k in 0..self.blocks() {
            let (t1, t2) = &cache.mids[k];
            // y_b = t2·A_bᵀ  ⇒  dA_b = dYᵀ·t2, dt2 = dY·A_b
            self.grad_a[k].axpy(1.0, &matmul_at(grad_out, t2)?)?;
            let dt2 = matmul(grad_out, &self.a[k])?; // (B, r)
            // t2 = t1·G_bᵀ  ⇒  dG_b = dt2ᵀ·t1, dt1 = dt2·G_b
            self.grad_g[k].axpy(1.0, &matmul_at(&dt2, t1)?)?;
            let dt1 = matmul(&dt2, &self.g[k])?; // (B, r)
            // t1 = x·B_bᵀ  ⇒  dB_b = dt1ᵀ·x, dx += dt1·B_b
            self.grad_bt[k].axpy(1.0, &matmul_at(&dt1, &cache.x)?)?;
            dx.axpy(1.0, &matmul(&dt1, &self.bt[k])?)?;
        }
        Ok(dx)
    }

    fn num_params(&self) -> usize {
        let factors: usize = (0..self.blocks())
            .map(|k| self.a[k].numel() + self.g[k].numel() + self.bt[k].numel())
            .sum();
        factors + self.bias.numel()
    }

    fn sgd_step(&mut self, cfg: &SgdConfig) -> Result<()> {
        for k in 0..self.blocks() {
            sgd_update(&mut self.a[k], &self.grad_a[k], &mut self.vel_a[k], cfg);
            sgd_update(&mut self.g[k], &self.grad_g[k], &mut self.vel_g[k], cfg);
            sgd_update(&mut self.bt[k], &self.grad_bt[k], &mut self.vel_bt[k], cfg);
        }
        sgd_update(&mut self.bias, &self.grad_bias, &mut self.vel_bias, cfg);
        self.zero_grads();
        Ok(())
    }

    fn zero_grads(&mut self) {
        for gset in [&mut self.grad_a, &mut self.grad_g, &mut self.grad_bt] {
            for t in gset.iter_mut() {
                t.data_mut().fill(0.0);
            }
        }
        self.grad_bias.data_mut().fill(0.0);
    }

    fn export_state(&self) -> Result<LayerState> {
        Ok(LayerState::BtLinear {
            a: self.a.clone(),
            g: self.g.clone(),
            bt: self.bt.clone(),
            bias: self.bias.clone(),
        })
    }

    fn import_state(&mut self, state: LayerState) -> Result<()> {
        match state {
            LayerState::BtLinear { a, g, bt, bias } => {
                let same = a.len() == self.a.len()
                    && (0..a.len()).all(|k| {
                        a[k].shape() == self.a[k].shape()
                            && g[k].shape() == self.g[k].shape()
                            && bt[k].shape() == self.bt[k].shape()
                    })
                    && bias.shape() == self.bias.shape();
                if !same {
                    return Err(Error::Checkpoint(format!(
                        "bt import: blocks/ranks mismatch (state blocks {}, layer {})",
                        a.len(),
                        self.a.len()
                    )));
                }
                *self = BtLinear::from_parts(a, g, bt, bias)?;
                Ok(())
            }
            other => Err(import_mismatch("BtLinear", &other)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forward_matches_dense_reconstruction() {
        let mut rng = Rng::new(31);
        let mut l = BtLinear::new(6, 8, 2, 3, &mut rng).unwrap();
        let x = Tensor::randn(&[4, 8], 1.0, &mut rng);
        let y = l.forward(&x, false).unwrap();
        let w = l.to_dense().unwrap();
        let want = matmul_bt(&x, &w).unwrap();
        for (i, (a, b)) in y.data().iter().zip(want.data()).enumerate() {
            let bias = l.bias().data()[i % 6];
            assert!((a - (b + bias)).abs() < 1e-4 * (1.0 + b.abs()), "{a} vs {}", b + bias);
        }
    }

    #[test]
    fn train_and_infer_paths_agree() {
        let mut rng = Rng::new(32);
        let mut l = BtLinear::new(5, 7, 3, 2, &mut rng).unwrap();
        let x = Tensor::randn(&[3, 7], 1.0, &mut rng);
        let yt = l.forward(&x, true).unwrap();
        let yi = l.forward(&x, false).unwrap();
        assert_eq!(yt.data(), yi.data());
    }

    #[test]
    fn from_dense_is_exact_at_full_rank() {
        let mut rng = Rng::new(33);
        let w = Tensor::randn(&[10, 12], 1.0, &mut rng);
        let bias = Tensor::randn(&[10], 0.1, &mut rng);
        // blocks·rank = 12 ≥ rank(w) = 10 ⇒ exact up to f32 SVD error
        let l = BtLinear::from_dense(&w, &bias, 3, 4, 0.0).unwrap();
        let rec = l.to_dense().unwrap();
        let mut diff = rec;
        diff.axpy(-1.0, &w).unwrap();
        let rel = diff.norm() / w.norm();
        assert!(rel < 1e-4, "rel {rel}");
    }

    #[test]
    fn from_dense_truncates_to_blocks_times_rank() {
        let mut rng = Rng::new(34);
        let w = Tensor::randn(&[16, 16], 1.0, &mut rng);
        let l = BtLinear::from_dense(&w, &Tensor::zeros(&[16]), 2, 3, 0.0).unwrap();
        assert_eq!(l.blocks(), 2);
        assert_eq!(l.ranks(), vec![3, 3]);
        assert!(l.num_params() < l.dense_params());
    }

    #[test]
    fn input_gradient_matches_dense_layer() {
        let mut rng = Rng::new(35);
        let mut l = BtLinear::new(6, 9, 2, 2, &mut rng).unwrap();
        let x = Tensor::randn(&[3, 9], 1.0, &mut rng);
        let gout = Tensor::randn(&[3, 6], 1.0, &mut rng);
        let _ = l.forward(&x, true).unwrap();
        let dx = l.backward(&gout).unwrap();
        let w = l.to_dense().unwrap();
        let want = matmul(&gout, &w).unwrap();
        for (a, b) in dx.data().iter().zip(want.data()) {
            assert!((a - b).abs() < 1e-4 * (1.0 + b.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn factor_gradients_match_finite_differences() {
        let mut rng = Rng::new(36);
        let mut l = BtLinear::new(4, 5, 2, 2, &mut rng).unwrap();
        let x = Tensor::randn(&[2, 5], 1.0, &mut rng);
        let y = l.forward(&x, true).unwrap();
        let _ = l.backward(&Tensor::filled(y.shape(), 1.0)).unwrap();
        let eps = 1e-2f32;
        let sum_forward = |l: &mut BtLinear, x: &Tensor| -> f32 {
            l.forward(x, false).unwrap().data().iter().sum()
        };
        for k in 0..2 {
            for (which, grad) in
                [(0usize, l.grad_a[k].clone()), (1, l.grad_g[k].clone()), (2, l.grad_bt[k].clone())]
            {
                let param = match which {
                    0 => l.a[k].clone(),
                    1 => l.g[k].clone(),
                    _ => l.bt[k].clone(),
                };
                for &idx in &[0usize, param.numel() - 1] {
                    let mut bump = |delta: f32, l: &mut BtLinear| -> f32 {
                        let mut p = param.clone();
                        p.data_mut()[idx] += delta;
                        match which {
                            0 => l.a[k] = p,
                            1 => l.g[k] = p,
                            _ => l.bt[k] = p,
                        }
                        let s = sum_forward(l, &x);
                        match which {
                            0 => l.a[k] = param.clone(),
                            1 => l.g[k] = param.clone(),
                            _ => l.bt[k] = param.clone(),
                        }
                        s
                    };
                    let want = (bump(eps, &mut l) - bump(-eps, &mut l)) / (2.0 * eps);
                    let got = grad.data()[idx];
                    assert!(
                        (got - want).abs() < 2e-2 * (1.0 + want.abs()),
                        "block {k} factor {which}[{idx}]: {got} vs {want}"
                    );
                }
            }
        }
    }

    #[test]
    fn sgd_step_moves_factors_and_clears_grads() {
        let mut rng = Rng::new(37);
        let mut l = BtLinear::new(4, 4, 2, 2, &mut rng).unwrap();
        let x = Tensor::randn(&[2, 4], 1.0, &mut rng);
        let y = l.forward(&x, true).unwrap();
        let _ = l.backward(&Tensor::filled(y.shape(), 1.0)).unwrap();
        let before = l.a[0].clone();
        l.sgd_step(&SgdConfig::default()).unwrap();
        assert_ne!(before, l.a[0]);
        assert!(l.grad_a.iter().all(|g| g.data().iter().all(|&v| v == 0.0)));
    }

    #[test]
    fn state_roundtrip_is_bitwise_and_mismatches_reject() {
        let mut rng = Rng::new(38);
        let mut l = BtLinear::new(6, 8, 2, 3, &mut rng).unwrap();
        let mut rebuilt = l.export_state().unwrap().build().unwrap();
        let x = Tensor::randn(&[3, 8], 1.0, &mut rng);
        assert_eq!(
            l.forward(&x, false).unwrap().data(),
            rebuilt.forward(&x, false).unwrap().data()
        );
        // rank mismatch
        let other = BtLinear::new(6, 8, 2, 2, &mut rng).unwrap().export_state().unwrap();
        let before = l.a[0].clone();
        assert!(l.import_state(other).is_err());
        assert_eq!(before.data(), l.a[0].data());
        // block-count mismatch
        let other = BtLinear::new(6, 8, 3, 3, &mut rng).unwrap().export_state().unwrap();
        assert!(l.import_state(other).is_err());
        // cross-kind mismatch
        assert!(l
            .import_state(LayerState::Relu)
            .is_err());
    }

    #[test]
    fn backward_without_forward_errors() {
        let mut rng = Rng::new(39);
        let mut l = BtLinear::new(3, 3, 1, 1, &mut rng).unwrap();
        assert!(l.backward(&Tensor::zeros(&[1, 3])).is_err());
    }
}
