//! The model zoo: canonical builders for the paper's networks, shared by
//! the experiment drivers, the CLI trainer and the serving registry
//! (`coordinator/native.rs`).  Living in `nn/` keeps the layering one-way
//! — the coordinator must not depend on the experiment drivers that
//! themselves drive the coordinator.

use crate::error::Result;
use crate::nn::{low_rank_pair, Dense, Layer, Relu, Sequential, TtLinear};
use crate::tt::TtShape;
use crate::util::rng::Rng;

/// `TT(n_in -> n_hidden) -> ReLU -> FC(n_hidden -> classes)` — the paper's
/// §6.1 single-TT-layer network.
pub fn tt_classifier(
    ms: &[usize],
    ns: &[usize],
    rank: usize,
    n_classes: usize,
    rng: &mut Rng,
) -> Result<(Sequential, usize)> {
    let shape = TtShape::uniform(ms, ns, rank)?;
    let hidden = shape.m_total();
    let tt = TtLinear::new(&shape, rng)?;
    let layer1_params = tt.num_params();
    let net = Sequential::new(vec![
        Box::new(tt),
        Box::new(Relu::new()),
        Box::new(Dense::new(hidden, n_classes, rng)),
    ]);
    Ok((net, layer1_params))
}

/// `MR_r(n_in -> n_hidden) -> ReLU -> FC(n_hidden -> classes)` — the
/// matrix-rank baseline of Fig. 1.
pub fn mr_classifier(
    n_in: usize,
    n_hidden: usize,
    rank: usize,
    n_classes: usize,
    rng: &mut Rng,
) -> Result<(Sequential, usize)> {
    let pair = low_rank_pair(n_in, n_hidden, rank, rng)?;
    let layer1_params = crate::nn::Layer::num_params(&pair);
    let net = Sequential::new(vec![
        Box::new(pair),
        Box::new(Relu::new()),
        Box::new(Dense::new(n_hidden, n_classes, rng)),
    ]);
    Ok((net, layer1_params))
}

/// The uncompressed `FC(1024) -> ReLU -> FC(10)` reference (§6.1 baseline,
/// 1.9% on real MNIST).
pub fn mnist_fc_baseline(rng: &mut Rng) -> Sequential {
    Sequential::new(vec![
        Box::new(Dense::new(1024, 1024, rng)),
        Box::new(Relu::new()),
        Box::new(Dense::new(1024, 10, rng)),
    ])
}

/// The MNIST TensorNet of the AOT artifacts: TT(4^5/4^5, r) -> ReLU ->
/// FC(1024 -> 10).
pub fn mnist_tensornet(rank: usize, rng: &mut Rng) -> Result<Sequential> {
    Ok(tt_classifier(&[4; 5], &[4; 5], rank, 10, rng)?.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::Layer;

    #[test]
    fn tt_classifier_param_accounting() {
        let mut rng = Rng::new(1);
        let (net, l1) = tt_classifier(&[4; 5], &[4; 5], 8, 10, &mut rng).unwrap();
        assert_eq!(l1, 3328 + 1024); // cores + bias
        assert_eq!(net.num_params(), l1 + 1024 * 10 + 10);
    }

    #[test]
    fn mr_classifier_param_accounting() {
        let mut rng = Rng::new(2);
        let (net, l1) = mr_classifier(1024, 1024, 4, 10, &mut rng).unwrap();
        assert_eq!(l1, 4 * 1024 + 4 + 1024 * 4 + 1024);
        assert!(net.num_params() > l1);
    }

    #[test]
    fn fc_baseline_shape() {
        let mut rng = Rng::new(3);
        let mut net = mnist_fc_baseline(&mut rng);
        let y = net
            .forward(&crate::tensor::Tensor::zeros(&[2, 1024]), false)
            .unwrap();
        assert_eq!(y.shape(), &[2, 10]);
    }
}
