//! The model zoo: canonical builders for the paper's networks, shared by
//! the experiment drivers, the CLI trainer and the serving registry
//! (`coordinator/native.rs`).  Living in `nn/` keeps the layering one-way
//! — the coordinator must not depend on the experiment drivers that
//! themselves drive the coordinator.

use crate::error::Result;
use crate::nn::{
    low_rank_pair, BtLinear, Conv2d, ConvGeom, Dense, Layer, Relu, Sequential, TtConv, TtLinear,
};
use crate::tt::TtShape;
use crate::util::rng::Rng;

/// `TT(n_in -> n_hidden) -> ReLU -> FC(n_hidden -> classes)` — the paper's
/// §6.1 single-TT-layer network.
pub fn tt_classifier(
    ms: &[usize],
    ns: &[usize],
    rank: usize,
    n_classes: usize,
    rng: &mut Rng,
) -> Result<(Sequential, usize)> {
    let shape = TtShape::uniform(ms, ns, rank)?;
    let hidden = shape.m_total();
    let tt = TtLinear::new(&shape, rng)?;
    let layer1_params = tt.num_params();
    let net = Sequential::new(vec![
        Box::new(tt),
        Box::new(Relu::new()),
        Box::new(Dense::new(hidden, n_classes, rng)),
    ]);
    Ok((net, layer1_params))
}

/// `MR_r(n_in -> n_hidden) -> ReLU -> FC(n_hidden -> classes)` — the
/// matrix-rank baseline of Fig. 1.
pub fn mr_classifier(
    n_in: usize,
    n_hidden: usize,
    rank: usize,
    n_classes: usize,
    rng: &mut Rng,
) -> Result<(Sequential, usize)> {
    let pair = low_rank_pair(n_in, n_hidden, rank, rng)?;
    let layer1_params = crate::nn::Layer::num_params(&pair);
    let net = Sequential::new(vec![
        Box::new(pair),
        Box::new(Relu::new()),
        Box::new(Dense::new(n_hidden, n_classes, rng)),
    ]);
    Ok((net, layer1_params))
}

/// The uncompressed `FC(1024) -> ReLU -> FC(10)` reference (§6.1 baseline,
/// 1.9% on real MNIST).
pub fn mnist_fc_baseline(rng: &mut Rng) -> Sequential {
    Sequential::new(vec![
        Box::new(Dense::new(1024, 1024, rng)),
        Box::new(Relu::new()),
        Box::new(Dense::new(1024, 10, rng)),
    ])
}

/// The MNIST TensorNet of the AOT artifacts: TT(4^5/4^5, r) -> ReLU ->
/// FC(1024 -> 10).
pub fn mnist_tensornet(rank: usize, rng: &mut Rng) -> Result<Sequential> {
    Ok(tt_classifier(&[4; 5], &[4; 5], rank, 10, rng)?.0)
}

/// The conv-MNIST geometry shared by the dense and TT conv nets: the
/// 1024-wide MNIST input viewed as one 32x32 channel, convolved with 8
/// 3x3 filters at stride 2 / pad 1 → `8x16x16 = 2048` features.
pub fn conv_geom_mnist() -> ConvGeom {
    ConvGeom { c_in: 1, h: 32, w: 32, c_out: 8, kh: 3, kw: 3, stride: 2, pad: 1 }
}

/// Dense conv-MNIST net: `Conv(1x32x32 -> 8x16x16) -> ReLU -> FC(2048 -> 10)`
/// — the trainable parent of the TT-conv compression path (Garipov et
/// al. 2016 run the same conv-then-compress loop at CIFAR scale).
pub fn mnist_convnet(rng: &mut Rng) -> Result<Sequential> {
    let geom = conv_geom_mnist();
    let head_in = geom.output_dim();
    Ok(Sequential::new(vec![
        Box::new(Conv2d::new(geom, rng)?),
        Box::new(Relu::new()),
        Box::new(Dense::new(head_in, 10, rng)),
    ]))
}

/// TT-conv-MNIST net: the same geometry with the conv kernel stored in
/// TT format (Garipov reshape) at uniform `rank`.
pub fn mnist_tt_convnet(rank: usize, rng: &mut Rng) -> Result<Sequential> {
    let geom = conv_geom_mnist();
    let head_in = geom.output_dim();
    Ok(Sequential::new(vec![
        Box::new(TtConv::new(geom, rank, rng)?),
        Box::new(Relu::new()),
        Box::new(Dense::new(head_in, 10, rng)),
    ]))
}

/// `BT(n_in -> n_hidden; blocks x rank) -> ReLU -> FC(n_hidden -> classes)`
/// — the block-term counterpart of [`tt_classifier`] (BT-Nets, Li et
/// al. 2018).
pub fn bt_classifier(
    n_in: usize,
    n_hidden: usize,
    blocks: usize,
    rank: usize,
    n_classes: usize,
    rng: &mut Rng,
) -> Result<(Sequential, usize)> {
    let bt = BtLinear::new(n_hidden, n_in, blocks, rank, rng)?;
    let layer1_params = bt.num_params();
    let net = Sequential::new(vec![
        Box::new(bt),
        Box::new(Relu::new()),
        Box::new(Dense::new(n_hidden, n_classes, rng)),
    ]);
    Ok((net, layer1_params))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::Layer;

    #[test]
    fn tt_classifier_param_accounting() {
        let mut rng = Rng::new(1);
        let (net, l1) = tt_classifier(&[4; 5], &[4; 5], 8, 10, &mut rng).unwrap();
        assert_eq!(l1, 3328 + 1024); // cores + bias
        assert_eq!(net.num_params(), l1 + 1024 * 10 + 10);
    }

    #[test]
    fn mr_classifier_param_accounting() {
        let mut rng = Rng::new(2);
        let (net, l1) = mr_classifier(1024, 1024, 4, 10, &mut rng).unwrap();
        assert_eq!(l1, 4 * 1024 + 4 + 1024 * 4 + 1024);
        assert!(net.num_params() > l1);
    }

    #[test]
    fn fc_baseline_shape() {
        let mut rng = Rng::new(3);
        let mut net = mnist_fc_baseline(&mut rng);
        let y = net
            .forward(&crate::tensor::Tensor::zeros(&[2, 1024]), false)
            .unwrap();
        assert_eq!(y.shape(), &[2, 10]);
    }

    #[test]
    fn conv_nets_map_1024_to_10() {
        let mut rng = Rng::new(4);
        let geom = conv_geom_mnist();
        assert_eq!(geom.input_dim(), 1024);
        assert_eq!(geom.output_dim(), 2048);
        let mut dense = mnist_convnet(&mut rng).unwrap();
        let mut tt = mnist_tt_convnet(2, &mut rng).unwrap();
        let x = crate::tensor::Tensor::randn(&[2, 1024], 1.0, &mut rng);
        assert_eq!(dense.forward(&x, false).unwrap().shape(), &[2, 10]);
        assert_eq!(tt.forward(&x, false).unwrap().shape(), &[2, 10]);
        // at rank 2 the TT kernel stores fewer values than the dense kernel
        assert!(tt.num_params() < dense.num_params());
    }

    #[test]
    fn bt_classifier_param_accounting() {
        let mut rng = Rng::new(5);
        let (net, l1) = bt_classifier(1024, 1024, 4, 8, 10, &mut rng).unwrap();
        // 4 blocks x (1024*8 + 8*8 + 8*1024) + 1024 bias
        assert_eq!(l1, 4 * (1024 * 8 + 64 + 8 * 1024) + 1024);
        assert_eq!(net.num_params(), l1 + 1024 * 10 + 10);
    }
}
