//! Training loop: epochs of shuffled minibatches, SGD with momentum,
//! loss-curve recording and held-out evaluation.

use crate::data::{BatchIter, Dataset};
use crate::error::Result;
use crate::nn::layer::Layer;
use crate::nn::loss::{accuracy, SoftmaxXent};
use crate::nn::optim::SgdConfig;
use crate::tensor::Tensor;
use crate::util::rng::Rng;
use std::time::Instant;

/// Training hyper-parameters.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub epochs: usize,
    pub batch_size: usize,
    pub sgd: SgdConfig,
    /// multiply lr by this factor at each epoch boundary (1.0 = constant)
    pub lr_decay: f32,
    /// log every n steps (0 = silent)
    pub log_every: usize,
    pub seed: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            epochs: 10,
            batch_size: 32,
            sgd: SgdConfig::default(),
            lr_decay: 0.9,
            log_every: 0,
            seed: 7,
        }
    }
}

/// Loss curve + timing of one training run.
#[derive(Clone, Debug, Default)]
pub struct TrainHistory {
    /// `(global_step, minibatch loss)`
    pub losses: Vec<(usize, f32)>,
    /// per-epoch `(train_loss_mean, test_error)` when eval data is given
    pub epochs: Vec<(f32, f32)>,
    pub wall_seconds: f64,
}

impl TrainHistory {
    pub fn final_loss(&self) -> f32 {
        self.losses.last().map(|&(_, l)| l).unwrap_or(f32::NAN)
    }

    /// Serialize the history for `train --save`'s `history.json`: the
    /// loss curve, per-epoch summary and wall time, so convergence is
    /// inspectable after the run instead of vanishing with the process.
    /// Non-finite values (diverged loss, no-eval NaN) map to JSON null.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let num = |x: f64| if x.is_finite() { Json::Num(x) } else { Json::Null };
        let mut obj = std::collections::BTreeMap::new();
        obj.insert(
            "losses".to_string(),
            Json::Arr(
                self.losses
                    .iter()
                    .map(|&(step, loss)| Json::Arr(vec![Json::Num(step as f64), num(loss as f64)]))
                    .collect(),
            ),
        );
        obj.insert(
            "epochs".to_string(),
            Json::Arr(
                self.epochs
                    .iter()
                    .map(|&(loss, err)| Json::Arr(vec![num(loss as f64), num(err as f64)]))
                    .collect(),
            ),
        );
        obj.insert("wall_seconds".to_string(), num(self.wall_seconds));
        Json::Obj(obj)
    }

    /// Mean loss over the first / last `k` recorded steps — used by
    /// convergence assertions.
    pub fn mean_head_tail(&self, k: usize) -> (f32, f32) {
        let k = k.min(self.losses.len()).max(1);
        let head: f32 =
            self.losses[..k].iter().map(|&(_, l)| l).sum::<f32>() / k as f32;
        let tail: f32 = self.losses[self.losses.len() - k..]
            .iter()
            .map(|&(_, l)| l)
            .sum::<f32>()
            / k as f32;
        (head, tail)
    }
}

/// Evaluation summary.
#[derive(Clone, Copy, Debug)]
pub struct EvalReport {
    pub loss: f32,
    pub error: f32, // 1 - accuracy, the paper's metric
    pub n: usize,
}

impl EvalReport {
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let num = |x: f64| if x.is_finite() { Json::Num(x) } else { Json::Null };
        let mut obj = std::collections::BTreeMap::new();
        obj.insert("loss".to_string(), num(self.loss as f64));
        obj.insert("error".to_string(), num(self.error as f64));
        obj.insert("n".to_string(), Json::Num(self.n as f64));
        Json::Obj(obj)
    }
}

/// Drives a [`Layer`] (usually a [`crate::nn::Sequential`]) through
/// softmax-CE training on a [`Dataset`].
pub struct Trainer {
    pub cfg: TrainConfig,
}

impl Trainer {
    pub fn new(cfg: TrainConfig) -> Self {
        Trainer { cfg }
    }

    /// Train; if `test` is given, evaluate at each epoch end.
    pub fn fit(
        &self,
        model: &mut dyn Layer,
        train: &Dataset,
        test: Option<&Dataset>,
    ) -> Result<TrainHistory> {
        let mut rng = Rng::new(self.cfg.seed);
        let mut history = TrainHistory::default();
        let mut sgd = self.cfg.sgd;
        let t0 = Instant::now();
        let mut step = 0usize;
        for _epoch in 0..self.cfg.epochs {
            let mut epoch_loss = 0.0f64;
            let mut batches = 0usize;
            for (x, labels) in BatchIter::new(train, self.cfg.batch_size, &mut rng, false) {
                let logits = model.forward(&x, true)?;
                let (loss, grad) = SoftmaxXent::loss_and_grad(&logits, &labels)?;
                model.backward(&grad)?;
                model.sgd_step(&sgd)?;
                history.losses.push((step, loss));
                epoch_loss += loss as f64;
                batches += 1;
                step += 1;
                if self.cfg.log_every > 0 && step % self.cfg.log_every == 0 {
                    println!("step {step:>6}  loss {loss:.4}");
                }
            }
            let test_err = match test {
                Some(t) => self.evaluate(model, t)?.error,
                None => f32::NAN,
            };
            history
                .epochs
                .push(((epoch_loss / batches.max(1) as f64) as f32, test_err));
            sgd.lr *= self.cfg.lr_decay;
        }
        history.wall_seconds = t0.elapsed().as_secs_f64();
        Ok(history)
    }

    /// Loss + error on a dataset (inference mode, batched).
    pub fn evaluate(&self, model: &mut dyn Layer, data: &Dataset) -> Result<EvalReport> {
        let mut total_loss = 0.0f64;
        let mut total_acc = 0.0f64;
        let mut n = 0usize;
        for (x, labels) in BatchIter::sequential(data, self.cfg.batch_size.max(64)) {
            let logits = model.forward(&x, false)?;
            let loss = SoftmaxXent::loss(&logits, &labels)?;
            let acc = accuracy(&logits, &labels)?;
            let b = labels.len();
            total_loss += loss as f64 * b as f64;
            total_acc += acc as f64 * b as f64;
            n += b;
        }
        Ok(EvalReport {
            loss: (total_loss / n.max(1) as f64) as f32,
            error: 1.0 - (total_acc / n.max(1) as f64) as f32,
            n,
        })
    }
}

/// Convenience: logits of a model over a full dataset (batched, eval mode).
pub fn predict(model: &mut dyn Layer, data: &Dataset, batch: usize) -> Result<Tensor> {
    let mut parts: Vec<Tensor> = Vec::new();
    for (x, _) in BatchIter::sequential(data, batch) {
        parts.push(model.forward(&x, false)?);
    }
    let refs: Vec<&Tensor> = parts.iter().collect();
    Tensor::vstack(&refs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{Dense, Relu, Sequential};
    use crate::tensor::Tensor;

    /// Tiny 2-class linearly-separable task.
    fn toy_data(n: usize, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed);
        let mut data = Vec::with_capacity(n * 4);
        let mut labels = Vec::with_capacity(n);
        for i in 0..n {
            let class = i % 2;
            let sign = if class == 0 { 1.0f32 } else { -1.0 };
            for j in 0..4 {
                let base = if j < 2 { sign } else { -sign };
                data.push(base + rng.normal_f32(0.3));
            }
            labels.push(class);
        }
        Dataset::new(Tensor::from_vec(&[n, 4], data).unwrap(), labels, 2).unwrap()
    }

    fn toy_model(seed: u64) -> Sequential {
        let mut rng = Rng::new(seed);
        Sequential::new(vec![
            Box::new(Dense::new(4, 16, &mut rng)),
            Box::new(Relu::new()),
            Box::new(Dense::new(16, 2, &mut rng)),
        ])
    }

    #[test]
    fn training_reduces_loss_and_error() {
        let train = toy_data(256, 1);
        let test = toy_data(128, 2);
        let mut model = toy_model(3);
        let trainer = Trainer::new(TrainConfig {
            epochs: 5,
            batch_size: 16,
            sgd: SgdConfig::with_lr(0.05),
            ..Default::default()
        });
        let before = trainer.evaluate(&mut model, &test).unwrap();
        let hist = trainer.fit(&mut model, &train, Some(&test)).unwrap();
        let after = trainer.evaluate(&mut model, &test).unwrap();
        let (head, tail) = hist.mean_head_tail(10);
        assert!(tail < head, "loss did not decrease: {head} -> {tail}");
        assert!(after.error < before.error);
        assert!(after.error < 0.1, "test error {}", after.error);
        assert_eq!(hist.epochs.len(), 5);
    }

    #[test]
    fn evaluate_counts_everything() {
        let data = toy_data(100, 4);
        let mut model = toy_model(5);
        let rep = Trainer::new(TrainConfig::default()).evaluate(&mut model, &data).unwrap();
        assert_eq!(rep.n, 100);
        assert!(rep.error >= 0.0 && rep.error <= 1.0);
    }

    #[test]
    fn history_json_roundtrips_and_nan_becomes_null() {
        let hist = TrainHistory {
            losses: vec![(0, 1.5), (1, f32::NAN)],
            epochs: vec![(0.7, f32::NAN)],
            wall_seconds: 2.0,
        };
        let text = hist.to_json().to_string();
        let back = crate::util::json::Json::parse(&text).unwrap();
        assert_eq!(back.req("wall_seconds").unwrap().as_f64(), Some(2.0));
        assert_eq!(back.req("losses").unwrap().as_arr().unwrap().len(), 2);
        assert!(text.contains("null"), "NaN must serialize as null: {text}");
        let rep = EvalReport { loss: 0.3, error: 0.1, n: 100 };
        let rj = rep.to_json();
        assert_eq!(rj.req("n").unwrap().as_usize(), Some(100));
    }

    #[test]
    fn predict_shapes() {
        let data = toy_data(10, 6);
        let mut model = toy_model(7);
        let logits = predict(&mut model, &data, 4).unwrap();
        assert_eq!(logits.shape(), &[10, 2]);
    }
}
