//! Frozen layer adapter — forward/backward flow through, parameters never
//! update.  Used for the paper's §6.2 setup ("we fix the convolutional
//! part of the network and substitute the fully-connected part"), where a
//! fixed feature extractor feeds the trainable TT/FC tail.

use crate::error::Result;
use crate::nn::layer::Layer;
use crate::nn::optim::SgdConfig;
use crate::nn::state::{import_mismatch, LayerState};
use crate::tensor::Tensor;

/// Wraps any layer, disabling its parameter updates.
pub struct Frozen<L: Layer>(pub L);

impl<L: Layer> Layer for Frozen<L> {
    fn name(&self) -> String {
        format!("Frozen[{}]", self.0.name())
    }

    fn forward(&mut self, x: &Tensor, train: bool) -> Result<Tensor> {
        self.0.forward(x, train)
    }

    fn backward(&mut self, grad_out: &Tensor) -> Result<Tensor> {
        let g = self.0.backward(grad_out)?;
        self.0.zero_grads(); // discard parameter gradients
        Ok(g)
    }

    fn num_params(&self) -> usize {
        0 // not trainable, not counted against the compression budget
    }

    fn sgd_step(&mut self, _cfg: &SgdConfig) -> Result<()> {
        Ok(())
    }

    fn zero_grads(&mut self) {
        self.0.zero_grads();
    }

    fn export_state(&self) -> Result<LayerState> {
        // frozen weights still persist — a checkpointed §6.2 network must
        // restore its fixed feature extractor, not reinitialize it
        Ok(LayerState::Frozen(Box::new(self.0.export_state()?)))
    }

    fn import_state(&mut self, state: LayerState) -> Result<()> {
        match state {
            LayerState::Frozen(inner) => self.0.import_state(*inner),
            other => Err(import_mismatch("Frozen", &other)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::Dense;
    use crate::util::rng::Rng;

    #[test]
    fn frozen_never_moves() {
        let mut rng = Rng::new(1);
        let inner = Dense::new(4, 3, &mut rng);
        let snapshot = inner.weights().0.clone();
        let mut f = Frozen(inner);
        let x = Tensor::randn(&[2, 4], 1.0, &mut rng);
        let y = f.forward(&x, true).unwrap();
        let _ = f.backward(&Tensor::filled(y.shape(), 1.0)).unwrap();
        f.sgd_step(&SgdConfig::default()).unwrap();
        assert_eq!(f.0.weights().0, &snapshot);
        assert_eq!(f.num_params(), 0);
    }

    #[test]
    fn gradient_still_flows_through() {
        let mut rng = Rng::new(2);
        let mut f = Frozen(Dense::new(4, 3, &mut rng));
        let x = Tensor::randn(&[2, 4], 1.0, &mut rng);
        let y = f.forward(&x, true).unwrap();
        let dx = f.backward(&Tensor::filled(y.shape(), 1.0)).unwrap();
        assert_eq!(dx.shape(), x.shape());
        assert!(dx.max_abs() > 0.0);
    }
}
