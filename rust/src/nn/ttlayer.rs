//! The TT-layer (paper §4) with the §5 learning algorithm.
//!
//! Forward is the core-by-core contraction sweep (one GEMM per core).
//! Backward reverses the sweep: it caches the per-core GEMM inputs — the
//! left partial products `P⁻` contracted with the input, exactly the
//! quantities of eq. (7)/(10) — and assembles each core's gradient as a
//! single `aᵀ · dOut` GEMM while propagating the data gradient through the
//! transposed core matrices (the right partials `P⁺`).  The dense
//! `∂L/∂W (M x N)` of eq. (6) is never materialized; per-batch cost is
//! `O(d r² m max{M, N})` for each sweep direction, matching Table 1 up to
//! the `r²` factor the paper spends on its explicit-DP formulation.

use crate::error::{shape_err, Error, Result};
use crate::nn::layer::Layer;
use crate::nn::optim::{sgd_update, SgdConfig};
use crate::nn::state::{import_mismatch, LayerState};
use crate::tensor::{matmul, matmul_at, matmul_bt, Tensor};
use crate::tt::{MatvecScratch, TtMatrix, TtShape};
use crate::util::rng::Rng;

/// One contraction step's geometry, recorded by forward for backward.
#[derive(Clone, Copy, Debug)]
struct StepDims {
    m_done: usize, // Π m_i for i < k
    rest: usize,   // Π n_i for i > k
    r0: usize,
    m: usize,
    n: usize,
    r1: usize,
}

struct FwdCache {
    batch: usize,
    /// per-core GEMM inputs `(rows_k, r0·n)`
    a_inputs: Vec<Tensor>,
    dims: Vec<StepDims>,
}

/// A fully-connected layer whose weight matrix lives in TT format.
pub struct TtLinear {
    tt: TtMatrix,
    bias: Tensor,
    grad_cores: Vec<Tensor>,
    grad_bias: Tensor,
    vel_cores: Vec<Tensor>,
    vel_bias: Tensor,
    cache: Option<FwdCache>,
    /// eval-path sweep buffers, retained across forwards so a served
    /// checkpoint model allocates like the zoo's bare-TT hot path
    scratch: MatvecScratch,
}

impl TtLinear {
    /// Gaussian-initialized TT-layer (paper §6.4).
    pub fn new(shape: &TtShape, rng: &mut Rng) -> Result<Self> {
        let tt = TtMatrix::random(shape, rng)?;
        Ok(Self::from_tt(tt, Tensor::zeros(&[shape.m_total()])))
    }

    /// Wrap an existing TT-matrix (e.g. one produced by TT-SVD of trained
    /// dense weights, or loaded from an artifact checkpoint).
    pub fn from_tt(tt: TtMatrix, bias: Tensor) -> Self {
        let grad_cores = tt.cores().iter().map(|c| Tensor::zeros(c.shape())).collect();
        let vel_cores = tt.cores().iter().map(|c| Tensor::zeros(c.shape())).collect();
        let grad_bias = Tensor::zeros(bias.shape());
        let vel_bias = Tensor::zeros(bias.shape());
        TtLinear {
            tt,
            bias,
            grad_cores,
            grad_bias,
            vel_cores,
            vel_bias,
            cache: None,
            scratch: MatvecScratch::default(),
        }
    }

    pub fn tt(&self) -> &TtMatrix {
        &self.tt
    }

    pub fn bias(&self) -> &Tensor {
        &self.bias
    }

    pub fn n_in(&self) -> usize {
        self.tt.n_total()
    }

    pub fn n_out(&self) -> usize {
        self.tt.m_total()
    }

    /// Training-path forward: the same sweep as `TtMatrix::matvec` but
    /// caching each GEMM input for the backward pass.
    fn forward_cached(&mut self, x: &Tensor) -> Result<Tensor> {
        let b = x.shape()[0];
        let d = self.tt.d();
        let mut dims = Vec::with_capacity(d);
        let mut a_inputs = Vec::with_capacity(d);

        let mut z = x.reshaped(&[b, 1, self.n_in(), 1])?;
        let mut m_done = 1usize;
        for k in 0..d {
            let [r0, m, n, r1] = self.tt.shape().core_shape(k);
            let nr = z.shape()[2];
            let rest = nr / n;
            dims.push(StepDims { m_done, rest, r0, m, n, r1 });
            let z5 = z.reshaped(&[b, m_done, n, rest, r0])?.permute(&[0, 1, 3, 4, 2])?;
            let a = z5.reshape(&[b * m_done * rest, r0 * n])?;
            let out = matmul(&a, &self.tt.core_mats()[k])?; // (rows, m*r1)
            a_inputs.push(a);
            z = out
                .reshape(&[b, m_done, rest, m, r1])?
                .permute(&[0, 1, 3, 2, 4])?
                .reshape(&[b, m_done * m, rest, r1])?;
            m_done *= m;
        }
        let mut y = z.reshape(&[b, self.n_out()])?;
        let bias = self.bias.data();
        for row in y.data_mut().chunks_mut(bias.len()) {
            for (o, &bb) in row.iter_mut().zip(bias) {
                *o += bb;
            }
        }
        self.cache = Some(FwdCache { batch: b, a_inputs, dims });
        Ok(y)
    }
}

impl Layer for TtLinear {
    fn name(&self) -> String {
        format!("TtLinear({})", self.tt.shape())
    }

    fn forward(&mut self, x: &Tensor, train: bool) -> Result<Tensor> {
        if x.ndim() != 2 || x.shape()[1] != self.n_in() {
            return shape_err(format!("tt fwd: {:?}, want (B, {})", x.shape(), self.n_in()));
        }
        if train {
            self.forward_cached(x)
        } else {
            // inference path: fused pack/unpack sweep, no gradient caching;
            // the retained scratch keeps served checkpoints at one
            // allocation per forward (the output) in steady state
            let mut y = self.tt.matvec_with(x, &mut self.scratch)?;
            let bias = self.bias.data();
            for row in y.data_mut().chunks_mut(bias.len()) {
                for (o, &bb) in row.iter_mut().zip(bias) {
                    *o += bb;
                }
            }
            Ok(y)
        }
    }

    fn backward(&mut self, grad_out: &Tensor) -> Result<Tensor> {
        let cache = self
            .cache
            .take()
            .ok_or_else(|| Error::Numerical("tt backward without forward".into()))?;
        let b = cache.batch;
        if grad_out.shape() != [b, self.n_out()] {
            return shape_err(format!("tt bwd: grad {:?}", grad_out.shape()));
        }

        // bias gradient: column sums
        let cols = self.n_out();
        let gb = self.grad_bias.data_mut();
        for row in grad_out.data().chunks(cols) {
            for (g, &v) in gb.iter_mut().zip(row) {
                *g += v;
            }
        }

        let d = self.tt.d();
        // dz starts as the gradient of the final (B, M_total, 1, 1) state
        let mut dz = grad_out.reshaped(&[b, self.n_out(), 1, 1])?;
        for k in (0..d).rev() {
            let StepDims { m_done, rest, r0, m, n, r1 } = cache.dims[k];
            // dz: (B, m_done*m, rest, r1) -> dOut (rows, m*r1)
            let d_out = dz
                .reshaped(&[b, m_done, m, rest, r1])?
                .permute(&[0, 1, 3, 2, 4])?
                .reshape(&[b * m_done * rest, m * r1])?;
            // core gradient: aᵀ · dOut, then un-flatten to (r0, m, n, r1)
            let grad_cmat = matmul_at(&cache.a_inputs[k], &d_out)?; // (r0*n, m*r1)
            let grad_core = grad_cmat
                .reshape(&[r0, n, m, r1])?
                .permute(&[0, 2, 1, 3])?;
            self.grad_cores[k].axpy(1.0, &grad_core)?;
            // data gradient: dA = dOut · cmatᵀ
            let d_a = matmul_bt(&d_out, &self.tt.core_mats()[k])?; // (rows, r0*n)
            // invert the pack permute [0,1,3,4,2] -> [0,1,4,2,3]
            dz = d_a
                .reshape(&[b, m_done, rest, r0, n])?
                .permute(&[0, 1, 4, 2, 3])?
                .reshape(&[b, m_done, n * rest, r0])?;
        }
        dz.reshape(&[b, self.n_in()])
    }

    fn num_params(&self) -> usize {
        self.tt.num_params() + self.bias.numel()
    }

    fn sgd_step(&mut self, cfg: &SgdConfig) -> Result<()> {
        for k in 0..self.tt.d() {
            let mut core = self.tt.cores()[k].clone();
            sgd_update(&mut core, &self.grad_cores[k], &mut self.vel_cores[k], cfg);
            self.tt.set_core(k, core)?;
        }
        sgd_update(&mut self.bias, &self.grad_bias, &mut self.vel_bias, cfg);
        self.zero_grads();
        Ok(())
    }

    fn zero_grads(&mut self) {
        for g in &mut self.grad_cores {
            g.data_mut().fill(0.0);
        }
        self.grad_bias.data_mut().fill(0.0);
    }

    fn export_state(&self) -> Result<LayerState> {
        Ok(LayerState::TtLinear {
            shape: self.tt.shape().clone(),
            cores: self.tt.cores().to_vec(),
            bias: self.bias.clone(),
        })
    }

    fn import_state(&mut self, state: LayerState) -> Result<()> {
        match state {
            LayerState::TtLinear { shape, cores, bias } if &shape == self.tt.shape() => {
                let tt = TtMatrix::from_cores(shape, cores)?;
                if bias.shape() != self.bias.shape() {
                    return Err(Error::Checkpoint(format!(
                        "tt import: bias {:?} into {:?}",
                        bias.shape(),
                        self.bias.shape()
                    )));
                }
                *self = TtLinear::from_tt(tt, bias);
                Ok(())
            }
            LayerState::TtLinear { shape, .. } => Err(Error::Checkpoint(format!(
                "tt import: state {shape} into layer {}",
                self.tt.shape()
            ))),
            other => Err(import_mismatch("TtLinear", &other)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn make_layer(ms: &[usize], ns: &[usize], r: usize, seed: u64) -> TtLinear {
        let shape = TtShape::uniform(ms, ns, r).unwrap();
        TtLinear::new(&shape, &mut Rng::new(seed)).unwrap()
    }

    #[test]
    fn train_and_infer_paths_agree() {
        let mut l = make_layer(&[2, 3, 2], &[3, 2, 3], 3, 1);
        let x = Tensor::randn(&[4, 18], 1.0, &mut Rng::new(2));
        let yt = l.forward(&x, true).unwrap();
        let yi = l.forward(&x, false).unwrap();
        for (a, b) in yt.data().iter().zip(yi.data()) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn forward_matches_dense_reconstruction() {
        let mut l = make_layer(&[4, 4], &[4, 4], 3, 3);
        let x = Tensor::randn(&[5, 16], 1.0, &mut Rng::new(4));
        let y = l.forward(&x, false).unwrap();
        let w = l.tt().to_dense().unwrap();
        let want = matmul_bt(&x, &w).unwrap();
        for (i, (a, b)) in y.data().iter().zip(want.data()).enumerate() {
            let bias = l.bias().data()[i % 16];
            assert!((a - (b + bias)).abs() < 1e-4, "{a} vs {}", b + bias);
        }
    }

    #[test]
    fn input_gradient_matches_dense_layer() {
        // dL/dx through TT must equal dL/dx through the densified W
        let mut l = make_layer(&[2, 2, 2], &[2, 2, 2], 2, 5);
        let x = Tensor::randn(&[3, 8], 1.0, &mut Rng::new(6));
        let g = Tensor::randn(&[3, 8], 1.0, &mut Rng::new(7));
        let _ = l.forward(&x, true).unwrap();
        let dx = l.backward(&g).unwrap();
        // dense: dx = g W
        let w = l.tt().to_dense().unwrap();
        let want = matmul(&g, &w).unwrap();
        for (a, b) in dx.data().iter().zip(want.data()) {
            assert!((a - b).abs() < 1e-4 * (1.0 + b.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn core_gradients_match_finite_differences() {
        let mut l = make_layer(&[2, 2], &[2, 2], 2, 8);
        let x = Tensor::randn(&[2, 4], 1.0, &mut Rng::new(9));
        // L = sum(y)
        let y = l.forward(&x, true).unwrap();
        let ones = Tensor::filled(y.shape(), 1.0);
        let _ = l.backward(&ones).unwrap();
        let eps = 1e-3f32;
        for k in 0..2 {
            let core = l.tt().cores()[k].clone();
            for &idx in &[0usize, 3, core.numel() - 1] {
                let mut lp = TtLinear::from_tt(l.tt.clone(), l.bias.clone());
                let mut cp = core.clone();
                cp.data_mut()[idx] += eps;
                lp.tt.set_core(k, cp).unwrap();
                let yp: f32 = lp.forward(&x, false).unwrap().data().iter().sum();
                let mut lm = TtLinear::from_tt(l.tt.clone(), l.bias.clone());
                let mut cm = core.clone();
                cm.data_mut()[idx] -= eps;
                lm.tt.set_core(k, cm).unwrap();
                let ym: f32 = lm.forward(&x, false).unwrap().data().iter().sum();
                let want = (yp - ym) / (2.0 * eps);
                let got = l.grad_cores[k].data()[idx];
                assert!(
                    (got - want).abs() < 2e-2 * (1.0 + want.abs()),
                    "core {k}[{idx}]: {got} vs {want}"
                );
            }
        }
    }

    #[test]
    fn bias_gradient_is_column_sums() {
        let mut l = make_layer(&[2, 2], &[2, 2], 1, 10);
        let x = Tensor::randn(&[3, 4], 1.0, &mut Rng::new(11));
        let _ = l.forward(&x, true).unwrap();
        let mut g = Tensor::zeros(&[3, 4]);
        g.data_mut()[0] = 1.0; // row 0, col 0
        g.data_mut()[4] = 2.0; // row 1, col 0
        let _ = l.backward(&g).unwrap();
        assert!((l.grad_bias.data()[0] - 3.0).abs() < 1e-6);
        assert!(l.grad_bias.data()[1].abs() < 1e-6);
    }

    #[test]
    fn sgd_step_moves_cores() {
        let mut l = make_layer(&[2, 2], &[2, 2], 2, 12);
        let x = Tensor::randn(&[2, 4], 1.0, &mut Rng::new(13));
        let y = l.forward(&x, true).unwrap();
        let _ = l.backward(&Tensor::filled(y.shape(), 1.0)).unwrap();
        let before = l.tt.cores()[0].clone();
        l.sgd_step(&SgdConfig::default()).unwrap();
        assert_ne!(before, l.tt.cores()[0]);
        assert!(l.grad_cores.iter().all(|g| g.data().iter().all(|&x| x == 0.0)));
    }

    #[test]
    fn gradient_never_materializes_dense_w() {
        // structural check: the layer's memory footprint stays at core
        // scale even for a large logical W (1024 x 1024)
        let l = make_layer(&[4; 5], &[4; 5], 8, 14);
        assert_eq!(l.num_params(), 3328 + 1024);
        let core_bytes: usize =
            l.grad_cores.iter().map(|g| g.numel() * 4).sum::<usize>();
        assert!(core_bytes < 64 * 1024, "grad storage {core_bytes}B should be core-sized");
    }

    #[test]
    fn backward_without_forward_errors() {
        let mut l = make_layer(&[2, 2], &[2, 2], 1, 15);
        assert!(l.backward(&Tensor::zeros(&[1, 4])).is_err());
    }

    #[test]
    fn state_roundtrip_preserves_nonuniform_ranks_bitwise() {
        // TT-SVD yields per-boundary ranks; the state must carry them
        let w = Tensor::randn(&[24, 24], 1.0, &mut Rng::new(16));
        let tt = TtMatrix::from_dense(&w, &[2, 3, 4], &[4, 3, 2], None, 1e-3).unwrap();
        let ranks = tt.shape().ranks().to_vec();
        let mut l = TtLinear::from_tt(tt, Tensor::randn(&[24], 0.1, &mut Rng::new(17)));
        let mut rebuilt = l.export_state().unwrap().build().unwrap();
        match rebuilt.export_state().unwrap() {
            LayerState::TtLinear { shape, .. } => assert_eq!(shape.ranks(), &ranks[..]),
            other => panic!("expected tt state, got {}", other.kind()),
        }
        let x = Tensor::randn(&[3, 24], 1.0, &mut Rng::new(18));
        let want = l.forward(&x, false).unwrap();
        let got = rebuilt.forward(&x, false).unwrap();
        assert_eq!(want.data(), got.data());
    }

    #[test]
    fn import_rejects_rank_mismatch() {
        let mut l = make_layer(&[2, 2], &[2, 2], 2, 19);
        let other = make_layer(&[2, 2], &[2, 2], 1, 20).export_state().unwrap();
        assert!(l.import_state(other).is_err());
    }
}
