//! The matrix-rank (MR) compression baseline of Fig. 1 and Table 2.
//!
//! The paper implements "FC layer with weight-matrix rank bounded by r" as
//! two consecutive fully-connected layers with weight matrices `(r x N)`
//! and `(M x r)` and no nonlinearity between them — exactly what
//! [`low_rank_pair`] builds.  Parameter count: `r·(M + N) + M` (one bias on
//! the output, matching the single logical layer).

use crate::error::Result;
use crate::nn::dense::Dense;
use crate::nn::sequential::Sequential;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Build the rank-`r` factored layer `x ↦ U (V x) + b` as a [`Sequential`]
/// of two [`Dense`] layers (first one bias-free in effect: its bias starts
/// at zero and is counted, mirroring the two-FC-layer implementation the
/// paper describes).
pub fn low_rank_pair(n_in: usize, n_out: usize, r: usize, rng: &mut Rng) -> Result<Sequential> {
    let v = Dense::new(n_in, r, rng); // (r, N)
    let u = Dense::new(r, n_out, rng); // (M, r)
    Ok(Sequential::new(vec![Box::new(v), Box::new(u)]))
}

/// Truncated-SVD initialization of the factors from an explicit matrix —
/// lets the MR baseline start from the best rank-`r` approximation of a
/// trained dense layer (how Table 2's MR rows are seeded).
pub fn low_rank_from_dense(w: &Tensor, b: &Tensor, r: usize) -> Result<Sequential> {
    let tsvd = crate::linalg::truncated_svd(w, Some(r), 0.0)?;
    // W (M, N) ~= U_k diag(s) Vt_k; split sqrt(s) into both factors
    let k = tsvd.s.len();
    let mut u = tsvd.u; // (M, k)
    let mut vt = tsvd.vt; // (k, N)
    for j in 0..k {
        let sq = tsvd.s[j].max(0.0).sqrt();
        for i in 0..u.shape()[0] {
            let val = u.at(&[i, j]) * sq;
            u.set(&[i, j], val);
        }
        let cols = vt.shape()[1];
        for x in &mut vt.data_mut()[j * cols..(j + 1) * cols] {
            *x *= sq;
        }
    }
    let first = Dense::from_weights(vt, Tensor::zeros(&[k]))?; // y1 = V x
    let second = Dense::from_weights(u, b.clone())?; // y = U y1 + b
    Ok(Sequential::new(vec![Box::new(first), Box::new(second)]))
}

/// Parameter count of the MR baseline at rank `r` (for compression tables).
pub fn low_rank_params(n_in: usize, n_out: usize, r: usize) -> usize {
    r * n_in + r + n_out * r + n_out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::Layer;
    use crate::tensor::matmul_bt;

    #[test]
    fn pair_shapes_and_params() {
        let mut rng = Rng::new(1);
        let net = low_rank_pair(1024, 1024, 8, &mut rng).unwrap();
        assert_eq!(net.num_params(), low_rank_params(1024, 1024, 8));
        assert!(net.num_params() < 1024 * 1024 / 50); // big compression
    }

    #[test]
    fn svd_init_approximates_dense() {
        let mut rng = Rng::new(2);
        // a genuinely low-rank matrix is reproduced exactly
        let u = Tensor::randn(&[12, 3], 1.0, &mut rng);
        let v = Tensor::randn(&[3, 10], 1.0, &mut rng);
        let w = crate::tensor::matmul(&u, &v).unwrap();
        let b = Tensor::randn(&[12], 1.0, &mut rng);
        let mut net = low_rank_from_dense(&w, &b, 3).unwrap();
        let x = Tensor::randn(&[4, 10], 1.0, &mut rng);
        let got = net.forward(&x, false).unwrap();
        let mut want = matmul_bt(&x, &w).unwrap();
        for row in want.data_mut().chunks_mut(12) {
            for (o, &bb) in row.iter_mut().zip(b.data()) {
                *o += bb;
            }
        }
        for (a, c) in got.data().iter().zip(want.data()) {
            assert!((a - c).abs() < 1e-3 * (1.0 + c.abs()), "{a} vs {c}");
        }
    }

    #[test]
    fn factored_pair_state_roundtrip_is_bitwise() {
        // the MR baseline is a Sequential of two Dense layers; its state
        // must survive export -> rebuild with the factorization intact
        let mut rng = Rng::new(4);
        let mut net = low_rank_pair(10, 12, 3, &mut rng).unwrap();
        let state = net.export_state().unwrap();
        assert_eq!(state.input_dim(), Some(10));
        assert_eq!(state.output_dim(), Some(12));
        let mut rebuilt = state.build().unwrap();
        assert_eq!(rebuilt.num_params(), low_rank_params(10, 12, 3));
        let x = Tensor::randn(&[5, 10], 1.0, &mut rng);
        let want = net.forward(&x, false).unwrap();
        let got = rebuilt.forward(&x, false).unwrap();
        assert_eq!(want.data(), got.data());
    }

    #[test]
    fn truncation_degrades_gracefully() {
        let mut rng = Rng::new(3);
        let w = Tensor::randn(&[16, 16], 1.0, &mut rng);
        let b = Tensor::zeros(&[16]);
        let x = Tensor::randn(&[8, 16], 1.0, &mut rng);
        let full = matmul_bt(&x, &w).unwrap();
        let mut err_prev = f32::INFINITY;
        for r in [2usize, 8, 16] {
            let mut net = low_rank_from_dense(&w, &b, r).unwrap();
            let y = net.forward(&x, false).unwrap();
            let mut diff = y.clone();
            diff.axpy(-1.0, &full).unwrap();
            let err = diff.norm() / full.norm();
            assert!(err <= err_prev + 1e-5, "rank {r}: err {err} vs prev {err_prev}");
            err_prev = err;
        }
        assert!(err_prev < 1e-3, "full rank must be near-exact, got {err_prev}");
    }
}
