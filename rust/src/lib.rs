//! # TensorNet
//!
//! A production-grade reproduction of *Tensorizing Neural Networks*
//! (Novikov, Podoprikhin, Osokin, Vetrov — NIPS 2015): fully-connected
//! layers whose weight matrices live in the Tensor-Train (TT) format,
//! compressed by factors up to 200 000× while preserving accuracy.
//!
//! The crate is the runtime third of a three-layer stack:
//!
//! * **L1** — Pallas kernels (`python/compile/kernels/`): the per-core
//!   contraction GEMM, authored for the TPU MXU, validated in interpret
//!   mode against a pure-jnp oracle.
//! * **L2** — JAX graphs (`python/compile/model.py`): TT-layer forward,
//!   full TensorNet, SGD-with-momentum train step; AOT-lowered to HLO text.
//! * **L3** — this crate: a self-contained rust binary that loads the AOT
//!   artifacts through PJRT ([`runtime`]), serves them behind a dynamic
//!   batcher ([`coordinator`]), and additionally implements the *entire*
//!   TT + training substrate natively ([`tensor`], [`linalg`], [`tt`],
//!   [`nn`], [`data`]) so every experiment in the paper can be regenerated
//!   without python anywhere near the hot path.
//!
//! See `DESIGN.md` for the system inventory and the per-experiment index,
//! and `EXPERIMENTS.md` for measured-vs-paper results.

pub mod config;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod experiments;
pub mod linalg;
pub mod metrics;
pub mod nn;
pub mod runtime;
pub mod tensor;
pub mod tt;
pub mod util;

pub use error::{Error, Result};
