//! Rank / tolerance truncation of an SVD — the primitive under TT-SVD and
//! TT-rounding (Oseledets 2011, Alg. 1 & 2).

use crate::error::Result;
use crate::linalg::svd::{svd_mat, Svd};
use crate::linalg::Mat;
use crate::tensor::Tensor;

/// Truncated factorization `A ~= U * diag(s) * Vt` with `U: m x k`,
/// `Vt: k x n`.
#[derive(Clone, Debug)]
pub struct TruncatedSvd {
    pub u: Tensor,
    pub s: Vec<f32>,
    pub vt: Tensor,
    /// `sqrt(sum of discarded sigma^2)` — the exact Frobenius error of the
    /// truncation, reported so TT-SVD can distribute its error budget.
    pub discarded: f64,
}

/// Smallest rank `k` such that the discarded tail satisfies
/// `sqrt(sum_{i>=k} s[i]^2) <= delta`.  `delta <= 0` keeps everything
/// (up to numerically-zero values).
pub fn rank_for_tolerance(s: &[f64], delta: f64) -> usize {
    if s.is_empty() {
        return 0;
    }
    let mut tail = 0.0f64;
    let mut k = s.len();
    // walk from the smallest singular value upward
    for i in (0..s.len()).rev() {
        let cand = tail + s[i] * s[i];
        if cand.sqrt() <= delta {
            tail = cand;
            k = i;
        } else {
            break;
        }
    }
    k.max(1) // never truncate to rank 0: keep a degenerate rank-1 factor
}

/// SVD truncated by optional rank cap and Frobenius tolerance.
///
/// The effective rank is `min(rank_cap, rank_for_tolerance(s, delta))` —
/// exactly the policy the TT-SVD sweep applies at every unfolding.
pub fn truncated_svd(a: &Tensor, rank_cap: Option<usize>, delta: f64) -> Result<TruncatedSvd> {
    let svd: Svd = svd_mat(&Mat::from_tensor(a))?;
    let k_tol = rank_for_tolerance(&svd.s, delta);
    let k = rank_cap.map_or(k_tol, |c| c.min(k_tol)).max(1).min(svd.s.len());
    let discarded: f64 = svd.s[k..].iter().map(|x| x * x).sum::<f64>().sqrt();
    Ok(TruncatedSvd {
        u: svd.u.take_cols(k).to_tensor(),
        s: svd.s[..k].iter().map(|&x| x as f32).collect(),
        vt: svd.vt.take_rows(k).to_tensor(),
        discarded,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::matmul;
    use crate::util::rng::Rng;

    #[test]
    fn rank_for_tolerance_basics() {
        let s = vec![4.0, 2.0, 1.0, 0.5];
        assert_eq!(rank_for_tolerance(&s, 0.0), 4);
        assert_eq!(rank_for_tolerance(&s, 0.6), 3); // drop 0.5 (tail 0.5 <= 0.6)
        assert_eq!(rank_for_tolerance(&s, 1.2), 2); // tail sqrt(1+0.25)=1.118
        assert_eq!(rank_for_tolerance(&s, 100.0), 1); // never 0
        assert_eq!(rank_for_tolerance(&[], 1.0), 0);
    }

    #[test]
    fn truncation_error_matches_discarded() {
        let mut rng = Rng::new(0);
        let a = Tensor::randn(&[12, 10], 1.0, &mut rng);
        let t = truncated_svd(&a, Some(4), 0.0).unwrap();
        // reconstruct U diag(s) Vt
        let mut us = t.u.clone();
        for i in 0..12 {
            for j in 0..t.s.len() {
                let v = us.at(&[i, j]) * t.s[j];
                us.set(&[i, j], v);
            }
        }
        let rec = matmul(&us, &t.vt).unwrap();
        let mut diff = rec.clone();
        diff.axpy(-1.0, &a).unwrap();
        assert!((diff.norm() as f64 - t.discarded).abs() < 1e-4 * (1.0 + t.discarded));
    }

    #[test]
    fn exact_when_rank_suffices() {
        let mut rng = Rng::new(1);
        // rank-3 matrix
        let u = Tensor::randn(&[9, 3], 1.0, &mut rng);
        let v = Tensor::randn(&[3, 7], 1.0, &mut rng);
        let a = matmul(&u, &v).unwrap();
        let t = truncated_svd(&a, Some(3), 0.0).unwrap();
        assert_eq!(t.s.len(), 3);
        // a is rank 3 up to f32 rounding of the product
        assert!(t.discarded < 1e-4, "discarded {}", t.discarded);
    }

    #[test]
    fn rank_cap_respected() {
        let mut rng = Rng::new(2);
        let a = Tensor::randn(&[8, 8], 1.0, &mut rng);
        let t = truncated_svd(&a, Some(2), 0.0).unwrap();
        assert_eq!(t.u.shape(), &[8, 2]);
        assert_eq!(t.vt.shape(), &[2, 8]);
    }
}
