//! Householder QR factorization (thin form).

use crate::error::{shape_err, Result};
use crate::linalg::Mat;
use crate::tensor::Tensor;

/// Thin QR of an `m x n` matrix: returns `(Q: m x k, R: k x n)` with
/// `k = min(m, n)`, `Q` having orthonormal columns and `R` upper
/// trapezoidal (triangular when `m >= n`).  Wide inputs (`m < n`) are
/// supported — the TT rounding sweep produces them when a chain rank
/// exceeds the adjacent mode product.
///
/// Classic Householder reflections applied in place; `Q` is recovered by
/// applying the reflectors to the first `k` columns of the identity.
pub fn qr_mat(a: &Mat) -> Result<(Mat, Mat)> {
    let (m, n) = (a.rows, a.cols);
    if m == 0 || n == 0 {
        return shape_err(format!("qr of empty {}x{}", m, n));
    }
    let kmax = m.min(n);
    let mut r = a.clone();
    // Householder vectors, stored per column (length m, zero above pivot).
    let mut vs: Vec<Vec<f64>> = Vec::with_capacity(kmax);

    for k in 0..kmax {
        // build the reflector for column k
        let mut v = vec![0.0f64; m];
        let mut norm_x = 0.0f64;
        for i in k..m {
            let x = r.at(i, k);
            v[i] = x;
            norm_x += x * x;
        }
        norm_x = norm_x.sqrt();
        if norm_x <= f64::MIN_POSITIVE {
            vs.push(vec![0.0; m]); // nothing to eliminate
            continue;
        }
        let alpha = if v[k] >= 0.0 { -norm_x } else { norm_x };
        v[k] -= alpha;
        let vnorm2: f64 = v[k..].iter().map(|x| x * x).sum();
        if vnorm2 <= f64::MIN_POSITIVE {
            vs.push(vec![0.0; m]);
            continue;
        }
        // apply H = I - 2 v v^T / (v^T v) to R columns k..n
        for j in k..n {
            let dot: f64 = (k..m).map(|i| v[i] * r.at(i, j)).sum();
            let c = 2.0 * dot / vnorm2;
            for i in k..m {
                let cur = r.at(i, j);
                r.set(i, j, cur - c * v[i]);
            }
        }
        vs.push(v);
    }

    // Q = H_0 H_1 ... H_{kmax-1} * I_{m x kmax}: apply reflectors in reverse.
    let mut q = Mat::zeros(m, kmax);
    for j in 0..kmax {
        q.set(j, j, 1.0);
    }
    for k in (0..kmax).rev() {
        let v = &vs[k];
        let vnorm2: f64 = v[k..].iter().map(|x| x * x).sum();
        if vnorm2 <= f64::MIN_POSITIVE {
            continue;
        }
        for j in 0..kmax {
            let dot: f64 = (k..m).map(|i| v[i] * q.at(i, j)).sum();
            let c = 2.0 * dot / vnorm2;
            for i in k..m {
                let cur = q.at(i, j);
                q.set(i, j, cur - c * v[i]);
            }
        }
    }

    // upper-trapezoidal R: k x n, rows below the diagonal zeroed
    let mut r_out = Mat::zeros(kmax, n);
    for i in 0..kmax {
        for j in i..n {
            r_out.set(i, j, r.at(i, j));
        }
    }
    Ok((q, r_out))
}

/// Thin QR over `Tensor` (f32 boundary).
pub fn qr(a: &Tensor) -> Result<(Tensor, Tensor)> {
    if a.ndim() != 2 {
        return shape_err(format!("qr on shape {:?}", a.shape()));
    }
    let (q, r) = qr_mat(&Mat::from_tensor(a))?;
    Ok((q.to_tensor(), r.to_tensor()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_mat(m: usize, n: usize, seed: u64) -> Mat {
        Mat::from_tensor(&Tensor::randn(&[m, n], 1.0, &mut Rng::new(seed)))
    }

    fn assert_close(a: &Mat, b: &Mat, tol: f64) {
        assert_eq!((a.rows, a.cols), (b.rows, b.cols));
        for (x, y) in a.data.iter().zip(&b.data) {
            assert!((x - y).abs() < tol, "{x} vs {y}");
        }
    }

    #[test]
    fn qr_reconstructs() {
        for &(m, n, seed) in &[(5, 5, 1), (10, 4, 2), (30, 30, 3), (100, 7, 4)] {
            let a = rand_mat(m, n, seed);
            let (q, r) = qr_mat(&a).unwrap();
            assert_close(&q.matmul(&r), &a, 1e-10);
        }
    }

    #[test]
    fn q_is_orthonormal() {
        let a = rand_mat(20, 6, 5);
        let (q, _) = qr_mat(&a).unwrap();
        let qtq = q.transpose().matmul(&q);
        assert_close(&qtq, &Mat::eye(6), 1e-12);
    }

    #[test]
    fn r_is_upper_triangular() {
        let a = rand_mat(9, 9, 6);
        let (_, r) = qr_mat(&a).unwrap();
        for i in 0..9 {
            for j in 0..i {
                assert!(r.at(i, j).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn qr_handles_rank_deficiency() {
        // two identical columns
        let mut a = rand_mat(8, 3, 7);
        for i in 0..8 {
            let v = a.at(i, 0);
            a.set(i, 1, v);
        }
        let (q, r) = qr_mat(&a).unwrap();
        assert_close(&q.matmul(&r), &a, 1e-10);
    }

    #[test]
    fn qr_wide_trapezoidal() {
        let a = rand_mat(3, 5, 8);
        let (q, r) = qr_mat(&a).unwrap();
        assert_eq!((q.rows, q.cols), (3, 3));
        assert_eq!((r.rows, r.cols), (3, 5));
        assert_close(&q.matmul(&r), &a, 1e-10);
        let qtq = q.transpose().matmul(&q);
        assert_close(&qtq, &Mat::eye(3), 1e-12);
    }

    #[test]
    fn qr_rejects_empty() {
        assert!(qr_mat(&Mat::zeros(0, 3)).is_err());
    }

    #[test]
    fn qr_tensor_boundary() {
        let t = Tensor::randn(&[12, 5], 1.0, &mut Rng::new(9));
        let (q, r) = qr(&t).unwrap();
        assert_eq!(q.shape(), &[12, 5]);
        assert_eq!(r.shape(), &[5, 5]);
    }
}
