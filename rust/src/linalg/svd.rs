//! One-sided Jacobi SVD, QR-preconditioned for tall problems.
//!
//! One-sided Jacobi is slow but *robust* — exactly the property the
//! TT-SVD sweep needs (it factors hundreds of unfoldings of wildly varying
//! aspect ratio and conditioning).  For an `m x n` input with `m >= n` the
//! method orthogonalizes the columns by plane rotations; the singular
//! values are the resulting column norms.  Wide inputs are handled by
//! factoring the transpose, very tall ones by a QR step first.

use crate::error::{shape_err, Result};
use crate::linalg::qr::qr_mat;
use crate::linalg::Mat;
use crate::tensor::Tensor;

/// SVD result: `A = U * diag(s) * Vt`, with `U: m x p`, `Vt: p x n`,
/// `p = min(m, n)`, and `s` sorted descending.
#[derive(Clone, Debug)]
pub struct Svd {
    pub u: Mat,
    pub s: Vec<f64>,
    pub vt: Mat,
}

const MAX_SWEEPS: usize = 60;
const JACOBI_TOL: f64 = 1e-14;

/// One-sided Jacobi on a matrix with `m >= n`.  Returns (U, s, V).
fn jacobi_tall(a: &Mat) -> (Mat, Vec<f64>, Mat) {
    let (m, n) = (a.rows, a.cols);
    debug_assert!(m >= n);
    // work on columns: store A column-major for cache-friendly rotations
    let mut cols: Vec<Vec<f64>> = (0..n).map(|j| (0..m).map(|i| a.at(i, j)).collect()).collect();
    let mut v = Mat::eye(n);

    for _sweep in 0..MAX_SWEEPS {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                let (mut app, mut aqq, mut apq) = (0.0f64, 0.0f64, 0.0f64);
                for i in 0..m {
                    app += cols[p][i] * cols[p][i];
                    aqq += cols[q][i] * cols[q][i];
                    apq += cols[p][i] * cols[q][i];
                }
                if apq.abs() <= JACOBI_TOL * (app * aqq).sqrt().max(f64::MIN_POSITIVE) {
                    continue;
                }
                off = off.max(apq.abs() / (app * aqq).sqrt().max(f64::MIN_POSITIVE));
                // Jacobi rotation zeroing the (p,q) inner product
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..m {
                    let xp = cols[p][i];
                    let xq = cols[q][i];
                    cols[p][i] = c * xp - s * xq;
                    cols[q][i] = s * xp + c * xq;
                }
                for i in 0..n {
                    let vp = v.at(i, p);
                    let vq = v.at(i, q);
                    v.set(i, p, c * vp - s * vq);
                    v.set(i, q, s * vp + c * vq);
                }
            }
        }
        if off <= JACOBI_TOL {
            break;
        }
    }

    // singular values = column norms; U = normalized columns
    let mut order: Vec<usize> = (0..n).collect();
    let norms: Vec<f64> = cols.iter().map(|c| c.iter().map(|x| x * x).sum::<f64>().sqrt()).collect();
    order.sort_by(|&i, &j| norms[j].partial_cmp(&norms[i]).unwrap());

    let mut u = Mat::zeros(m, n);
    let mut s = vec![0.0f64; n];
    let mut v_sorted = Mat::zeros(n, n);
    for (rank, &j) in order.iter().enumerate() {
        s[rank] = norms[j];
        if norms[j] > f64::MIN_POSITIVE {
            for i in 0..m {
                u.set(i, rank, cols[j][i] / norms[j]);
            }
        }
        for i in 0..n {
            v_sorted.set(i, rank, v.at(i, j));
        }
    }
    (u, s, v_sorted)
}

/// Full (thin) SVD of an arbitrary `Mat`.
pub fn svd_mat(a: &Mat) -> Result<Svd> {
    let (m, n) = (a.rows, a.cols);
    if m == 0 || n == 0 {
        return shape_err(format!("svd of empty {}x{}", m, n));
    }
    if m < n {
        // A = U S Vt  <=>  At = V S Ut
        let t = svd_mat(&a.transpose())?;
        return Ok(Svd { u: t.vt.transpose(), s: t.s, vt: t.u.transpose() });
    }
    if m > 2 * n {
        // QR precondition: A = Q R, svd(R) = Ur S Vt, U = Q Ur
        let (q, r) = qr_mat(a)?;
        let (ur, s, v) = jacobi_tall(&r);
        let u = q.matmul(&ur);
        return Ok(Svd { u, s, vt: v.transpose() });
    }
    let (u, s, v) = jacobi_tall(a);
    Ok(Svd { u, s, vt: v.transpose() })
}

/// Thin SVD over `Tensor` (f32 boundary): returns `(U, s, Vt)`.
pub fn svd(a: &Tensor) -> Result<(Tensor, Vec<f32>, Tensor)> {
    if a.ndim() != 2 {
        return shape_err(format!("svd on shape {:?}", a.shape()));
    }
    let r = svd_mat(&Mat::from_tensor(a))?;
    Ok((r.u.to_tensor(), r.s.iter().map(|&x| x as f32).collect(), r.vt.to_tensor()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_mat(m: usize, n: usize, seed: u64) -> Mat {
        Mat::from_tensor(&Tensor::randn(&[m, n], 1.0, &mut Rng::new(seed)))
    }

    fn reconstruct(svd: &Svd) -> Mat {
        let p = svd.s.len();
        let mut us = svd.u.clone();
        for i in 0..us.rows {
            for j in 0..p {
                let v = us.at(i, j) * svd.s[j];
                us.set(i, j, v);
            }
        }
        us.matmul(&svd.vt)
    }

    fn assert_close(a: &Mat, b: &Mat, tol: f64) {
        for (x, y) in a.data.iter().zip(&b.data) {
            assert!((x - y).abs() < tol, "{x} vs {y}");
        }
    }

    #[test]
    fn svd_reconstructs_various_shapes() {
        for &(m, n, seed) in &[(1, 1, 0), (6, 6, 1), (12, 5, 2), (5, 12, 3), (64, 8, 4), (3, 40, 5)] {
            let a = rand_mat(m, n, seed);
            let s = svd_mat(&a).unwrap();
            assert_close(&reconstruct(&s), &a, 1e-9);
            assert_eq!(s.s.len(), m.min(n));
        }
    }

    #[test]
    fn singular_values_sorted_nonnegative() {
        let a = rand_mat(20, 9, 6);
        let s = svd_mat(&a).unwrap();
        for w in s.s.windows(2) {
            assert!(w[0] >= w[1] - 1e-12);
        }
        assert!(s.s.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn u_and_v_orthonormal() {
        let a = rand_mat(15, 7, 7);
        let s = svd_mat(&a).unwrap();
        let utu = s.u.transpose().matmul(&s.u);
        let vvt = s.vt.matmul(&s.vt.transpose());
        assert_close(&utu, &Mat::eye(7), 1e-10);
        assert_close(&vvt, &Mat::eye(7), 1e-10);
    }

    #[test]
    fn known_singular_values() {
        // diag(3, 2, 1) embedded in 4x3
        let mut a = Mat::zeros(4, 3);
        a.set(0, 0, 3.0);
        a.set(1, 1, 2.0);
        a.set(2, 2, 1.0);
        let s = svd_mat(&a).unwrap();
        assert!((s.s[0] - 3.0).abs() < 1e-12);
        assert!((s.s[1] - 2.0).abs() < 1e-12);
        assert!((s.s[2] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn low_rank_input_gives_zero_tail() {
        // rank-2 matrix: outer products
        let u = rand_mat(10, 2, 8);
        let v = rand_mat(2, 6, 9);
        let a = u.matmul(&v);
        let s = svd_mat(&a).unwrap();
        for &x in &s.s[2..] {
            assert!(x < 1e-9, "expected zero tail, got {x}");
        }
        assert_close(&reconstruct(&s), &a, 1e-9);
    }

    #[test]
    fn frobenius_norm_preserved() {
        let a = rand_mat(9, 9, 10);
        let s = svd_mat(&a).unwrap();
        let norm_s: f64 = s.s.iter().map(|x| x * x).sum::<f64>().sqrt();
        assert!((norm_s - a.norm()).abs() < 1e-10);
    }

    #[test]
    fn svd_tensor_boundary() {
        let t = Tensor::randn(&[8, 5], 1.0, &mut Rng::new(11));
        let (u, s, vt) = svd(&t).unwrap();
        assert_eq!(u.shape(), &[8, 5]);
        assert_eq!(s.len(), 5);
        assert_eq!(vt.shape(), &[5, 5]);
    }
}
