//! Internal f64 row-major matrix used by the factorization routines.

use crate::tensor::Tensor;

/// Row-major `f64` matrix (internal to `linalg`, but exposed for tests and
/// for callers that need double precision end to end).
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    pub fn from_tensor(t: &Tensor) -> Self {
        assert_eq!(t.ndim(), 2, "Mat::from_tensor needs 2-D");
        Mat {
            rows: t.shape()[0],
            cols: t.shape()[1],
            data: t.data().iter().map(|&x| x as f64).collect(),
        }
    }

    pub fn to_tensor(&self) -> Tensor {
        Tensor::from_vec(&[self.rows, self.cols], self.data.iter().map(|&x| x as f32).collect())
            .expect("consistent")
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f64 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.data[i * self.cols + j] = v;
    }

    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        out
    }

    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul {}x{} * {}x{}", self.rows, self.cols, other.rows, other.cols);
        let mut out = Mat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            let arow = self.row(i);
            let orow = &mut out.data[i * other.cols..(i + 1) * other.cols];
            for (k, &aik) in arow.iter().enumerate() {
                if aik != 0.0 {
                    let brow = &other.data[k * other.cols..(k + 1) * other.cols];
                    for (o, &b) in orow.iter_mut().zip(brow) {
                        *o += aik * b;
                    }
                }
            }
        }
        out
    }

    /// Frobenius norm.
    pub fn norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Column `j` 2-norm.
    pub fn col_norm(&self, j: usize) -> f64 {
        (0..self.rows).map(|i| self.at(i, j).powi(2)).sum::<f64>().sqrt()
    }

    /// Keep only the first `k` columns.
    pub fn take_cols(&self, k: usize) -> Mat {
        assert!(k <= self.cols);
        let mut out = Mat::zeros(self.rows, k);
        for i in 0..self.rows {
            out.data[i * k..(i + 1) * k].copy_from_slice(&self.row(i)[..k]);
        }
        out
    }

    /// Keep only the first `k` rows.
    pub fn take_rows(&self, k: usize) -> Mat {
        assert!(k <= self.rows);
        Mat { rows: k, cols: self.cols, data: self.data[..k * self.cols].to_vec() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = Mat { rows: 2, cols: 3, data: vec![1., 2., 3., 4., 5., 6.] };
        let got = a.matmul(&Mat::eye(3));
        assert_eq!(got, a);
    }

    #[test]
    fn transpose_roundtrip() {
        let a = Mat { rows: 2, cols: 3, data: vec![1., 2., 3., 4., 5., 6.] };
        assert_eq!(a.transpose().transpose(), a);
        assert_eq!(a.transpose().at(2, 1), 6.0);
    }

    #[test]
    fn tensor_roundtrip() {
        let t = Tensor::from_vec(&[2, 2], vec![1., 2., 3., 4.]).unwrap();
        assert_eq!(Mat::from_tensor(&t).to_tensor(), t);
    }

    #[test]
    fn take_cols_rows() {
        let a = Mat { rows: 2, cols: 3, data: vec![1., 2., 3., 4., 5., 6.] };
        let c = a.take_cols(2);
        assert_eq!(c.data, vec![1., 2., 4., 5.]);
        let r = a.take_rows(1);
        assert_eq!(r.data, vec![1., 2., 3.]);
    }
}
