//! Dense linear algebra (S2 in DESIGN.md) — no external BLAS/LAPACK.
//!
//! Provides exactly what the TT machinery needs: Householder QR,
//! one-sided-Jacobi SVD (QR-preconditioned for tall matrices), and
//! tolerance/rank truncation.  Computation is done in `f64` internally and
//! converted at the `Tensor` (f32) boundary — TT-SVD chains many
//! factorizations and f32 accumulation visibly degrades the reconstruction
//! tolerance.

mod mat;
mod qr;
mod svd;
mod truncate;

pub use mat::Mat;
pub use qr::{qr, qr_mat};
pub use svd::{svd, svd_mat, Svd};
pub use truncate::{rank_for_tolerance, truncated_svd, TruncatedSvd};
