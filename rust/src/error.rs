//! Crate-wide error type.

use thiserror::Error;

/// All failure modes surfaced by the library.
#[derive(Error, Debug)]
pub enum Error {
    /// Shape arithmetic went wrong (mismatched dims, bad reshape, ...).
    #[error("shape error: {0}")]
    Shape(String),

    /// Numerical routine failed to converge or hit an invalid input.
    #[error("numerical error: {0}")]
    Numerical(String),

    /// Artifact loading / manifest parsing problems.
    #[error("artifact error: {0}")]
    Artifact(String),

    /// PJRT / XLA runtime failure.
    #[error("xla error: {0}")]
    Xla(String),

    /// Coordinator-level failure (queue closed, worker died, ...).
    #[error("coordinator error: {0}")]
    Coordinator(String),

    /// Configuration file / CLI problems.
    #[error("config error: {0}")]
    Config(String),

    #[error(transparent)]
    Io(#[from] std::io::Error),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, Error>;

/// Shorthand for shape errors.
pub fn shape_err<T>(msg: impl Into<String>) -> Result<T> {
    Err(Error::Shape(msg.into()))
}
