//! Crate-wide error type.
//!
//! Hand-rolled `Display`/`Error` impls — `thiserror` is one of the crates
//! unavailable in the offline std-only build (DESIGN.md §Substitutions).

use std::fmt;

/// All failure modes surfaced by the library.
#[derive(Debug)]
pub enum Error {
    /// Shape arithmetic went wrong (mismatched dims, bad reshape, ...).
    Shape(String),

    /// Numerical routine failed to converge or hit an invalid input.
    Numerical(String),

    /// Artifact loading / manifest parsing problems.
    Artifact(String),

    /// PJRT / XLA runtime failure (in this build: the backend is a stub
    /// that reports itself unavailable — see `runtime::executable`).
    Xla(String),

    /// Coordinator-level failure (queue closed, worker died, ...).
    Coordinator(String),

    /// Checkpoint save/load problems (version mismatch, corrupt blob,
    /// state/architecture mismatch — see `runtime::checkpoint`).
    Checkpoint(String),

    /// Wire-protocol violation (bad magic/version, oversized or
    /// truncated frame, checksum mismatch — see `coordinator::wire`).
    Wire(String),

    /// Network transport failure (connect/read/write on the TCP
    /// front-end or client — see `coordinator::net` / `coordinator::client`).
    Net(String),

    /// Server-side load shed: admission capacity (or this model's
    /// quota) was exhausted and the request was answered with a
    /// retryable `Busy` wire reply — not a failure of the request
    /// itself (see `coordinator::wire::ErrCode`).  `retry_after_ms` is
    /// the server's backoff hint (≈ one observed service time); `0`
    /// means the server sent none (pre-v3 peer).
    Busy { message: String, retry_after_ms: u32 },

    /// Configuration file / CLI problems.
    Config(String),

    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Shape(m) => write!(f, "shape error: {m}"),
            Error::Numerical(m) => write!(f, "numerical error: {m}"),
            Error::Artifact(m) => write!(f, "artifact error: {m}"),
            Error::Xla(m) => write!(f, "xla error: {m}"),
            Error::Coordinator(m) => write!(f, "coordinator error: {m}"),
            Error::Checkpoint(m) => write!(f, "checkpoint error: {m}"),
            Error::Wire(m) => write!(f, "wire error: {m}"),
            Error::Net(m) => write!(f, "net error: {m}"),
            Error::Busy { message, .. } => write!(f, "server busy: {message}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Io(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

pub type Result<T> = std::result::Result<T, Error>;

/// Shorthand for shape errors.
pub fn shape_err<T>(msg: impl Into<String>) -> Result<T> {
    Err(Error::Shape(msg.into()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_category_and_message() {
        assert_eq!(format!("{}", Error::Shape("2x3 vs 4x5".into())), "shape error: 2x3 vs 4x5");
        assert_eq!(format!("{}", Error::Config("bad flag".into())), "config error: bad flag");
        assert_eq!(format!("{}", Error::Wire("bad magic".into())), "wire error: bad magic");
        assert_eq!(format!("{}", Error::Net("refused".into())), "net error: refused");
        assert_eq!(
            format!(
                "{}",
                Error::Busy { message: "admission queue full".into(), retry_after_ms: 5 }
            ),
            "server busy: admission queue full"
        );
        let io: Error = std::io::Error::new(std::io::ErrorKind::NotFound, "gone").into();
        assert!(format!("{io}").contains("gone"));
    }

    #[test]
    fn shape_err_helper() {
        let r: Result<()> = shape_err("boom");
        assert!(matches!(r, Err(Error::Shape(m)) if m == "boom"));
    }

    #[test]
    fn io_source_is_preserved() {
        use std::error::Error as _;
        let e: Error = std::io::Error::new(std::io::ErrorKind::Other, "disk").into();
        assert!(e.source().is_some());
        assert!(Error::Xla("x".into()).source().is_none());
    }
}
