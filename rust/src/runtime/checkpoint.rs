//! Native checkpoints: persist any [`Layer`] to disk and restore it —
//! the subsystem that turns train / compress / serve into one lifecycle.
//!
//! A checkpoint directory holds three files:
//!
//! ```text
//! <dir>/checkpoint.json      versioned header + the LayerState tree
//!                            (layer kinds, TT modes/ranks, tensor names)
//! <dir>/manifest.json        artifact-convention manifest (same schema as
//!                            python/compile/aot.py emits) describing the
//!                            weight blob layout — readable by `Manifest`
//! <dir>/model.weights.bin    little-endian f32 blob, offsets per layout
//! ```
//!
//! The blob and its layout deliberately reuse the existing [`Manifest`]
//! weight-group conventions (`(name, shape, offset, len)`, LE f32, one
//! file per group): loading goes through `Manifest::load_weights`, so the
//! artifact reader and the checkpoint writer are provably inverse — and
//! `tensornet inspect --artifacts <ckpt>` works on checkpoints for free.
//!
//! `checkpoint.json` is the part the AOT manifests don't have: a `format`
//! tag + `version` (loads reject anything else), the model structure as a
//! [`LayerState`] tree with tensors referenced by name, and the I/O dims
//! so a serving registry can admit requests without materializing the
//! model ([`Checkpoint::peek`]).

use crate::error::{Error, Result};
use crate::nn::{ConvGeom, Layer, LayerState};
use crate::runtime::artifact::Manifest;
use crate::tensor::Tensor;
use crate::tt::TtShape;
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::io::Write as _;
use std::path::{Path, PathBuf};

/// The header file inside a checkpoint directory.
pub const CHECKPOINT_FILE: &str = "checkpoint.json";
/// Format tag — rejects non-checkpoint json that happens to parse.
pub const FORMAT: &str = "tensornet.checkpoint";
/// On-disk format version this build reads and writes.
pub const VERSION: u64 = 1;
/// Weight-group name / blob file used by checkpoints.
const GROUP: &str = "model";
const BLOB_FILE: &str = "model.weights.bin";

/// Cheap header facts — everything a registry needs before build time.
#[derive(Clone, Copy, Debug)]
pub struct CheckpointInfo {
    pub input_dim: usize,
    pub output_dim: usize,
    /// stored f32 count (blob bytes / 4) — the compression denominator
    pub num_values: usize,
}

/// A loaded checkpoint: the state tree plus its header facts.
#[derive(Debug)]
pub struct Checkpoint {
    pub dir: PathBuf,
    pub state: LayerState,
    pub info: CheckpointInfo,
}

impl Checkpoint {
    /// Persist a layer: `save(dir, &net)` = `save_state(dir, &export)`.
    pub fn save(dir: impl AsRef<Path>, layer: &dyn Layer) -> Result<()> {
        Checkpoint::save_state(dir, &layer.export_state()?)
    }

    /// Write `checkpoint.json` + `manifest.json` + the weight blob.
    /// The directory is created if needed; existing files are replaced.
    pub fn save_state(dir: impl AsRef<Path>, state: &LayerState) -> Result<()> {
        let dir = dir.as_ref();
        state.validate()?;
        let (input_dim, output_dim) = io_dims(state)?;

        let mut blob = BlobBuilder::default();
        let model = state_to_json(state, GROUP, &mut blob);

        std::fs::create_dir_all(dir)
            .map_err(|e| Error::Checkpoint(format!("creating {}: {e}", dir.display())))?;
        blob.write_files(dir, 0, GROUP, BLOB_FILE)?;

        let mut header = BTreeMap::new();
        header.insert("format".to_string(), Json::Str(FORMAT.into()));
        header.insert("version".to_string(), Json::Num(VERSION as f64));
        header.insert("input_dim".to_string(), Json::Num(input_dim as f64));
        header.insert("output_dim".to_string(), Json::Num(output_dim as f64));
        header.insert("num_values".to_string(), Json::Num(blob.data.len() as f64));
        header.insert("weight_group".to_string(), Json::Str(GROUP.into()));
        header.insert("model".to_string(), model);
        write_text(&dir.join(CHECKPOINT_FILE), &Json::Obj(header).to_string())
    }

    /// Read the header only — no blob I/O, no model construction.
    pub fn peek(dir: impl AsRef<Path>) -> Result<CheckpointInfo> {
        let header = read_header(dir.as_ref())?;
        Ok(CheckpointInfo {
            input_dim: req_usize(&header, "input_dim")?,
            output_dim: req_usize(&header, "output_dim")?,
            num_values: req_usize(&header, "num_values")?,
        })
    }

    /// Load a checkpoint: validate the header, read the blob through the
    /// artifact [`Manifest`] machinery, and reassemble the state tree.
    pub fn load(dir: impl AsRef<Path>) -> Result<Checkpoint> {
        let dir = dir.as_ref();
        let header = read_header(dir)?;
        let info = CheckpointInfo {
            input_dim: req_usize(&header, "input_dim")?,
            output_dim: req_usize(&header, "output_dim")?,
            num_values: req_usize(&header, "num_values")?,
        };
        let group = header
            .req("weight_group")?
            .as_str()
            .ok_or_else(|| Error::Checkpoint("bad 'weight_group'".into()))?;
        let manifest = Manifest::load(dir)?;
        let mut tensors = manifest.load_weights(group)?;
        let state = state_from_json(header.req("model")?, &mut tensors)?;
        state.validate()?;
        let (input_dim, output_dim) = io_dims(&state)?;
        if input_dim != info.input_dim || output_dim != info.output_dim {
            return Err(Error::Checkpoint(format!(
                "header says {}x{} but the model tree is {}x{}",
                info.input_dim, info.output_dim, input_dim, output_dim
            )));
        }
        // num_values feeds compression-ratio reporting — a tampered header
        // must not silently skew it
        if state.num_values() != info.num_values {
            return Err(Error::Checkpoint(format!(
                "header says {} stored values but the model tree holds {}",
                info.num_values,
                state.num_values()
            )));
        }
        Ok(Checkpoint { dir: dir.to_path_buf(), state, info })
    }

    /// Rebuild the model (`LayerState::build`).
    pub fn build(self) -> Result<Box<dyn Layer>> {
        self.state.build()
    }

    /// Whether `dir` looks like a checkpoint (has the header file).
    pub fn exists(dir: impl AsRef<Path>) -> bool {
        dir.as_ref().join(CHECKPOINT_FILE).is_file()
    }
}

/// Write named tensors as an artifact-convention weight group: a
/// `manifest.json` (no artifacts, one weight group) plus a little-endian
/// f32 blob, exactly the files `Manifest::load` + `load_weights` read.
/// This is the reusable half of the checkpoint writer — callers that only
/// need Manifest-compatible tensors (tests, artifact tooling) use it
/// directly.
pub fn write_weight_group(
    dir: impl AsRef<Path>,
    seed: u64,
    group: &str,
    file: &str,
    tensors: &[(String, Tensor)],
) -> Result<()> {
    let mut blob = BlobBuilder::default();
    for (name, t) in tensors {
        blob.push(name, t);
    }
    std::fs::create_dir_all(dir.as_ref())
        .map_err(|e| Error::Checkpoint(format!("creating {}: {e}", dir.as_ref().display())))?;
    blob.write_files(dir.as_ref(), seed, group, file)
}

// ---------------------------------------------------------------------------
// blob + manifest writing
// ---------------------------------------------------------------------------

/// Accumulates tensors into one flat buffer with a Manifest-style layout.
#[derive(Default)]
struct BlobBuilder {
    /// `(name, shape, offset_elems, len_elems)` — the `WeightGroup` layout
    layout: Vec<(String, Vec<usize>, usize, usize)>,
    data: Vec<f32>,
}

impl BlobBuilder {
    /// Append a tensor under `name` at the next free offset.
    fn push(&mut self, name: &str, t: &Tensor) {
        let offset = self.data.len();
        self.data.extend_from_slice(t.data());
        self.layout.push((name.to_string(), t.shape().to_vec(), offset, t.numel()));
    }

    /// Emit `<dir>/<file>` (LE f32) and `<dir>/manifest.json`.
    fn write_files(&self, dir: &Path, seed: u64, group: &str, file: &str) -> Result<()> {
        let blob_path = dir.join(file);
        let mut bytes = Vec::with_capacity(self.data.len() * 4);
        for v in &self.data {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        let mut f = std::fs::File::create(&blob_path)
            .map_err(|e| Error::Checkpoint(format!("creating {}: {e}", blob_path.display())))?;
        f.write_all(&bytes)
            .map_err(|e| Error::Checkpoint(format!("writing {}: {e}", blob_path.display())))?;

        let layout: Vec<Json> = self
            .layout
            .iter()
            .map(|(name, shape, offset, len)| {
                let mut e = BTreeMap::new();
                e.insert("name".to_string(), Json::Str(name.clone()));
                e.insert(
                    "shape".to_string(),
                    Json::Arr(shape.iter().map(|&s| Json::Num(s as f64)).collect()),
                );
                e.insert("offset".to_string(), Json::Num(*offset as f64));
                e.insert("len".to_string(), Json::Num(*len as f64));
                Json::Obj(e)
            })
            .collect();
        let mut g = BTreeMap::new();
        g.insert("file".to_string(), Json::Str(file.into()));
        g.insert("layout".to_string(), Json::Arr(layout));
        let mut groups = BTreeMap::new();
        groups.insert(group.to_string(), Json::Obj(g));
        let mut manifest = BTreeMap::new();
        manifest.insert("seed".to_string(), Json::Num(seed as f64));
        manifest.insert("artifacts".to_string(), Json::Arr(vec![]));
        manifest.insert("weight_groups".to_string(), Json::Obj(groups));
        write_text(&dir.join("manifest.json"), &Json::Obj(manifest).to_string())
    }
}

fn write_text(path: &Path, text: &str) -> Result<()> {
    std::fs::write(path, text)
        .map_err(|e| Error::Checkpoint(format!("writing {}: {e}", path.display())))
}

// ---------------------------------------------------------------------------
// state tree <-> json
// ---------------------------------------------------------------------------

/// Serialize the state tree, pushing tensors into `blob` and referencing
/// them by name.  `prefix` is the dotted path of this node ("model",
/// "model.0", "model.1.inner", ...).
fn state_to_json(state: &LayerState, prefix: &str, blob: &mut BlobBuilder) -> Json {
    let mut node = BTreeMap::new();
    node.insert("kind".to_string(), Json::Str(state.kind().into()));
    match state {
        LayerState::Dense { w, b } => {
            let (wn, bn) = (format!("{prefix}.w"), format!("{prefix}.b"));
            blob.push(&wn, w);
            blob.push(&bn, b);
            node.insert("w".to_string(), Json::Str(wn));
            node.insert("b".to_string(), Json::Str(bn));
        }
        LayerState::TtLinear { shape, cores, bias } => {
            push_tt_kernel(&mut node, shape, cores, bias, prefix, blob);
        }
        LayerState::Conv { geom, w, b } => {
            geom_to_json(&mut node, geom);
            let (wn, bn) = (format!("{prefix}.w"), format!("{prefix}.b"));
            blob.push(&wn, w);
            blob.push(&bn, b);
            node.insert("w".to_string(), Json::Str(wn));
            node.insert("b".to_string(), Json::Str(bn));
        }
        LayerState::TtConv { geom, shape, cores, bias } => {
            geom_to_json(&mut node, geom);
            push_tt_kernel(&mut node, shape, cores, bias, prefix, blob);
        }
        LayerState::BtLinear { a, g, bt, bias } => {
            for (key, factors) in [("a", a), ("g", g), ("bt", bt)] {
                let mut names = Vec::with_capacity(factors.len());
                for (k, t) in factors.iter().enumerate() {
                    let tn = format!("{prefix}.block{k}.{key}");
                    blob.push(&tn, t);
                    names.push(Json::Str(tn));
                }
                node.insert(key.to_string(), Json::Arr(names));
            }
            let bn = format!("{prefix}.bias");
            blob.push(&bn, bias);
            node.insert("bias".to_string(), Json::Str(bn));
        }
        LayerState::Stack(layers) => {
            let children: Vec<Json> = layers
                .iter()
                .enumerate()
                .map(|(i, l)| state_to_json(l, &format!("{prefix}.{i}"), blob))
                .collect();
            node.insert("layers".to_string(), Json::Arr(children));
        }
        LayerState::Frozen(inner) => {
            node.insert(
                "inner".to_string(),
                state_to_json(inner, &format!("{prefix}.inner"), blob),
            );
        }
        LayerState::Relu | LayerState::Sigmoid => {}
    }
    Json::Obj(node)
}

/// Inverse of [`state_to_json`]: tensors move out of the loaded map.
fn state_from_json(j: &Json, tensors: &mut BTreeMap<String, Tensor>) -> Result<LayerState> {
    let kind = j
        .req("kind")?
        .as_str()
        .ok_or_else(|| Error::Checkpoint("layer 'kind' not a string".into()))?
        .to_string();
    match kind.as_str() {
        "dense" => Ok(LayerState::Dense {
            w: take_tensor(j.req("w")?, tensors)?,
            b: take_tensor(j.req("b")?, tensors)?,
        }),
        "tt_linear" => {
            let (shape, cores, bias) = tt_kernel_from_json(j, tensors)?;
            Ok(LayerState::TtLinear { shape, cores, bias })
        }
        "conv" => Ok(LayerState::Conv {
            geom: geom_from_json(j)?,
            w: take_tensor(j.req("w")?, tensors)?,
            b: take_tensor(j.req("b")?, tensors)?,
        }),
        "tt_conv" => {
            let geom = geom_from_json(j)?;
            let (shape, cores, bias) = tt_kernel_from_json(j, tensors)?;
            Ok(LayerState::TtConv { geom, shape, cores, bias })
        }
        "bt_linear" => {
            let a = tensor_list(j, "a", tensors)?;
            let g = tensor_list(j, "g", tensors)?;
            let bt = tensor_list(j, "bt", tensors)?;
            Ok(LayerState::BtLinear { a, g, bt, bias: take_tensor(j.req("bias")?, tensors)? })
        }
        "sequential" => Ok(LayerState::Stack(
            j.req("layers")?
                .as_arr()
                .ok_or_else(|| Error::Checkpoint("'layers' not an array".into()))?
                .iter()
                .map(|c| state_from_json(c, tensors))
                .collect::<Result<Vec<_>>>()?,
        )),
        "frozen" => Ok(LayerState::Frozen(Box::new(state_from_json(
            j.req("inner")?,
            tensors,
        )?))),
        "relu" => Ok(LayerState::Relu),
        "sigmoid" => Ok(LayerState::Sigmoid),
        other => Err(Error::Checkpoint(format!("unknown layer kind '{other}'"))),
    }
}

/// Serialize a TT kernel (shape arrays + named cores + bias) into `node` —
/// shared by the `tt_linear` and `tt_conv` kinds.
fn push_tt_kernel(
    node: &mut BTreeMap<String, Json>,
    shape: &TtShape,
    cores: &[Tensor],
    bias: &Tensor,
    prefix: &str,
    blob: &mut BlobBuilder,
) {
    node.insert("ms".to_string(), usize_arr(shape.ms()));
    node.insert("ns".to_string(), usize_arr(shape.ns()));
    node.insert("ranks".to_string(), usize_arr(shape.ranks()));
    let mut names = Vec::with_capacity(cores.len());
    for (k, core) in cores.iter().enumerate() {
        let cn = format!("{prefix}.core{k}");
        blob.push(&cn, core);
        names.push(Json::Str(cn));
    }
    node.insert("cores".to_string(), Json::Arr(names));
    let bn = format!("{prefix}.bias");
    blob.push(&bn, bias);
    node.insert("bias".to_string(), Json::Str(bn));
}

/// Inverse of [`push_tt_kernel`].
fn tt_kernel_from_json(
    j: &Json,
    tensors: &mut BTreeMap<String, Tensor>,
) -> Result<(TtShape, Vec<Tensor>, Tensor)> {
    let ms = usize_list(j.req("ms")?)?;
    let ns = usize_list(j.req("ns")?)?;
    let ranks = usize_list(j.req("ranks")?)?;
    let shape = TtShape::new(&ms, &ns, &ranks)?;
    let cores = tensor_list(j, "cores", tensors)?;
    Ok((shape, cores, take_tensor(j.req("bias")?, tensors)?))
}

/// Resolve an array of tensor-name references under `key`.
fn tensor_list(
    j: &Json,
    key: &str,
    tensors: &mut BTreeMap<String, Tensor>,
) -> Result<Vec<Tensor>> {
    j.req(key)?
        .as_arr()
        .ok_or_else(|| Error::Checkpoint(format!("'{key}' not an array")))?
        .iter()
        .map(|n| take_tensor(n, tensors))
        .collect()
}

/// Conv geometry scalars, flattened into the layer node.
fn geom_to_json(node: &mut BTreeMap<String, Json>, geom: &ConvGeom) {
    for (key, v) in [
        ("c_in", geom.c_in),
        ("h", geom.h),
        ("w_in", geom.w),
        ("c_out", geom.c_out),
        ("kh", geom.kh),
        ("kw", geom.kw),
        ("stride", geom.stride),
        ("pad", geom.pad),
    ] {
        node.insert(key.to_string(), Json::Num(v as f64));
    }
}

fn geom_from_json(j: &Json) -> Result<ConvGeom> {
    ConvGeom::new(
        req_usize(j, "c_in")?,
        req_usize(j, "h")?,
        req_usize(j, "w_in")?,
        req_usize(j, "c_out")?,
        req_usize(j, "kh")?,
        req_usize(j, "kw")?,
        req_usize(j, "stride")?,
        req_usize(j, "pad")?,
    )
}

fn take_tensor(name: &Json, tensors: &mut BTreeMap<String, Tensor>) -> Result<Tensor> {
    let name = name
        .as_str()
        .ok_or_else(|| Error::Checkpoint("tensor reference not a string".into()))?;
    tensors
        .remove(name)
        .ok_or_else(|| Error::Checkpoint(format!("tensor '{name}' missing from the weight blob")))
}

fn usize_arr(xs: &[usize]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
}

fn usize_list(j: &Json) -> Result<Vec<usize>> {
    j.as_arr()
        .ok_or_else(|| Error::Checkpoint("expected an integer array".into()))?
        .iter()
        .map(|x| x.as_usize().ok_or_else(|| Error::Checkpoint("bad integer entry".into())))
        .collect()
}

fn req_usize(j: &Json, key: &str) -> Result<usize> {
    j.req(key)?
        .as_usize()
        .ok_or_else(|| Error::Checkpoint(format!("bad '{key}' in checkpoint header")))
}

/// Parse + validate `<dir>/checkpoint.json` (format tag, version).
fn read_header(dir: &Path) -> Result<Json> {
    let path = dir.join(CHECKPOINT_FILE);
    let text = std::fs::read_to_string(&path)
        .map_err(|e| Error::Checkpoint(format!("reading {}: {e}", path.display())))?;
    let header = Json::parse(&text)?;
    match header.get("format").and_then(|f| f.as_str()) {
        Some(f) if f == FORMAT => {}
        Some(f) => {
            return Err(Error::Checkpoint(format!(
                "{} has format '{f}', expected '{FORMAT}'",
                path.display()
            )))
        }
        None => {
            return Err(Error::Checkpoint(format!(
                "{} is not a tensornet checkpoint (no 'format' tag)",
                path.display()
            )))
        }
    }
    let version = req_usize(&header, "version")? as u64;
    if version != VERSION {
        return Err(Error::Checkpoint(format!(
            "checkpoint version {version} not supported (this build reads {VERSION})"
        )));
    }
    Ok(header)
}

/// First/last shape-determining dims of the tree; a model whose boundary
/// layers are all shape-polymorphic (pure activations) can't be served and
/// is rejected at save time.
fn io_dims(state: &LayerState) -> Result<(usize, usize)> {
    match (state.input_dim(), state.output_dim()) {
        (Some(i), Some(o)) => Ok((i, o)),
        _ => Err(Error::Checkpoint(
            "model has no parametric layer to determine I/O dims".into(),
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{Dense, Frozen, Relu, Sequential, Sigmoid, TtLinear};
    use crate::util::rng::Rng;

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("tensornet_ckpt_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn mixed_net(seed: u64) -> Sequential {
        let mut rng = Rng::new(seed);
        let shape = TtShape::uniform(&[2, 3], &[3, 2], 2).unwrap();
        Sequential::new(vec![
            Box::new(Frozen(Dense::new(6, 6, &mut rng))),
            Box::new(TtLinear::new(&shape, &mut rng).unwrap()),
            Box::new(Relu::new()),
            Box::new(Dense::new(6, 4, &mut rng)),
            Box::new(Sigmoid::new()),
        ])
    }

    #[test]
    fn save_load_roundtrip_is_bitwise() {
        let dir = tmpdir("roundtrip");
        let mut net = mixed_net(1);
        Checkpoint::save(&dir, &net).unwrap();

        let ck = Checkpoint::load(&dir).unwrap();
        assert_eq!(ck.info.input_dim, 6);
        assert_eq!(ck.info.output_dim, 4);
        assert_eq!(ck.info.num_values, net.export_state().unwrap().num_values());

        let mut rebuilt = ck.build().unwrap();
        let x = Tensor::randn(&[3, 6], 1.0, &mut Rng::new(2));
        let want = net.forward(&x, false).unwrap();
        let got = rebuilt.forward(&x, false).unwrap();
        assert_eq!(want.data(), got.data());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn conv_and_bt_kinds_roundtrip_bitwise() {
        use crate::nn::{BtLinear, Conv2d, ConvGeom, TtConv};
        let dir = tmpdir("families");
        let mut rng = Rng::new(11);
        let geom = ConvGeom::new(2, 6, 6, 4, 3, 3, 2, 1).unwrap();
        let mut net = Sequential::new(vec![
            Box::new(Conv2d::new(geom, &mut rng).unwrap()),
            Box::new(Relu::new()),
            Box::new(TtConv::new(
                ConvGeom::new(4, 3, 3, 4, 3, 3, 1, 1).unwrap(),
                2,
                &mut rng,
            )
            .unwrap()),
            Box::new(BtLinear::new(8, 36, 2, 3, &mut rng).unwrap()),
        ]);
        Checkpoint::save(&dir, &net).unwrap();
        let ck = Checkpoint::load(&dir).unwrap();
        assert_eq!(ck.info.input_dim, geom.input_dim());
        assert_eq!(ck.info.output_dim, 8);
        // every family's tensors land under their tree paths
        let weights = Manifest::load(&dir).unwrap().load_weights(GROUP).unwrap();
        assert!(weights.contains_key("model.0.w"));
        assert!(weights.contains_key("model.2.core0"));
        assert!(weights.contains_key("model.3.block1.g"));
        let mut rebuilt = ck.build().unwrap();
        let x = Tensor::randn(&[2, geom.input_dim()], 1.0, &mut Rng::new(12));
        let want = net.forward(&x, false).unwrap();
        let got = rebuilt.forward(&x, false).unwrap();
        assert_eq!(want.data(), got.data());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn peek_reads_header_without_blob() {
        let dir = tmpdir("peek");
        Checkpoint::save(&dir, &mixed_net(2)).unwrap();
        // delete the blob: peek must still work, load must fail
        std::fs::remove_file(dir.join(BLOB_FILE)).unwrap();
        let info = Checkpoint::peek(&dir).unwrap();
        assert_eq!((info.input_dim, info.output_dim), (6, 4));
        assert!(Checkpoint::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn blob_follows_manifest_conventions() {
        // the existing artifact reader must round-trip checkpoint tensors
        let dir = tmpdir("manifest_conv");
        let net = mixed_net(3);
        Checkpoint::save(&dir, &net).unwrap();
        let manifest = Manifest::load(&dir).unwrap();
        let weights = manifest.load_weights(GROUP).unwrap();
        // the frozen dense layer's weight is stored under its tree path
        let w = &weights["model.0.inner.w"];
        match net.layers()[0].export_state().unwrap() {
            LayerState::Frozen(inner) => match *inner {
                LayerState::Dense { w: want, .. } => assert_eq!(w.data(), want.data()),
                other => panic!("expected dense, got {}", other.kind()),
            },
            other => panic!("expected frozen, got {}", other.kind()),
        }
        // TT cores land too
        assert!(weights.contains_key("model.1.core0"));
        assert!(weights.contains_key("model.1.bias"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn write_weight_group_roundtrips_through_manifest() {
        let dir = tmpdir("wg");
        let mut rng = Rng::new(4);
        let a = Tensor::randn(&[3, 5], 1.0, &mut rng);
        let b = Tensor::randn(&[7], 1.0, &mut rng);
        write_weight_group(
            &dir,
            42,
            "params",
            "params.weights.bin",
            &[("a".into(), a.clone()), ("b".into(), b.clone())],
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.seed, 42);
        let w = m.load_weights("params").unwrap();
        assert_eq!(w["a"].data(), a.data());
        assert_eq!(w["a"].shape(), a.shape());
        assert_eq!(w["b"].data(), b.data());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn version_mismatch_is_rejected() {
        let dir = tmpdir("version");
        Checkpoint::save(&dir, &mixed_net(5)).unwrap();
        let path = dir.join(CHECKPOINT_FILE);
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::write(&path, text.replace("\"version\":1", "\"version\":999")).unwrap();
        let err = Checkpoint::load(&dir).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("version 999"), "{msg}");
        assert!(Checkpoint::peek(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn tampered_num_values_is_rejected() {
        let dir = tmpdir("numvalues");
        Checkpoint::save(&dir, &mixed_net(9)).unwrap();
        let path = dir.join(CHECKPOINT_FILE);
        let text = std::fs::read_to_string(&path).unwrap();
        let values = mixed_net(9).export_state().unwrap().num_values();
        std::fs::write(
            &path,
            text.replace(&format!("\"num_values\":{values}"), "\"num_values\":1"),
        )
        .unwrap();
        let err = Checkpoint::load(&dir).unwrap_err();
        assert!(format!("{err}").contains("stored values"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn foreign_json_is_rejected() {
        let dir = tmpdir("format");
        std::fs::write(dir.join(CHECKPOINT_FILE), r#"{"version": 1, "model": {}}"#).unwrap();
        let err = Checkpoint::load(&dir).unwrap_err();
        assert!(format!("{err}").contains("not a tensornet checkpoint"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn truncated_blob_is_rejected() {
        let dir = tmpdir("truncated");
        Checkpoint::save(&dir, &mixed_net(6)).unwrap();
        let blob = dir.join(BLOB_FILE);
        let bytes = std::fs::read(&blob).unwrap();
        std::fs::write(&blob, &bytes[..bytes.len() / 2]).unwrap();
        assert!(Checkpoint::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_tensor_reference_is_rejected() {
        let dir = tmpdir("missing_ref");
        Checkpoint::save(&dir, &mixed_net(7)).unwrap();
        let path = dir.join(CHECKPOINT_FILE);
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::write(&path, text.replace("model.1.bias", "model.1.ghost")).unwrap();
        let err = Checkpoint::load(&dir).unwrap_err();
        assert!(format!("{err}").contains("missing from the weight blob"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn pure_activation_model_is_rejected_at_save() {
        let dir = tmpdir("activations_only");
        let net = Sequential::new(vec![Box::new(Relu::new())]);
        assert!(Checkpoint::save(&dir, &net).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
