//! PJRT runtime (S7 in DESIGN.md): load the AOT HLO-text artifacts emitted
//! by `python/compile/aot.py`, validate them against their weight blobs,
//! and — in a full build — compile them on the PJRT CPU client and execute
//! from the serving hot path.  Alongside the AOT reader lives the *native*
//! checkpoint subsystem ([`Checkpoint`], S11 in DESIGN.md): versioned
//! save/load of any trained model through the same weight-blob
//! conventions, closing the train → compress → serve lifecycle.
//!
//! OFFLINE GATING: the `xla` PJRT bindings cannot be vendored into this
//! std-only build, so the device half is stubbed (see `executable.rs`) —
//! [`cpu_client`] returns `Error::Xla` and execution paths fail fast with
//! a clear message.  The host half (manifest parsing, weight loading,
//! shape checks) is fully functional and tested.
//!
//! Thread model (unchanged by the stub): PJRT handles hold raw pointers
//! and are not `Send`, so a [`CompiledModel`] is *thread-confined* — the
//! coordinator runs all PJRT execution on a dedicated executor thread that
//! owns the registry (see `coordinator::worker`).

mod artifact;
mod checkpoint;
mod executable;

pub use artifact::{ArtifactSpec, InputSource, InputSpec, IoSpec, Manifest, WeightGroup};
pub use checkpoint::{
    write_weight_group, Checkpoint, CheckpointInfo, CHECKPOINT_FILE, FORMAT, VERSION,
};
pub use executable::{CompiledModel, PjrtClient, RuntimeInput, PJRT_UNAVAILABLE};

use crate::error::Result;

/// Create a PJRT CPU client.  One per executor thread; creation is heavy
/// (thread pools), so callers cache it for the thread's lifetime.  In this
/// offline build the call always fails — see [`PJRT_UNAVAILABLE`].
pub fn cpu_client() -> Result<PjrtClient> {
    PjrtClient::cpu()
}
