//! PJRT runtime (S7 in DESIGN.md): load the AOT HLO-text artifacts emitted
//! by `python/compile/aot.py`, compile them on the PJRT CPU client, keep
//! parameters resident as device buffers, and execute from the serving hot
//! path.  Python never runs here — the artifacts directory is the entire
//! interface between the build path and the request path.
//!
//! Thread model: the `xla` crate's handles hold raw pointers and are not
//! `Send`, so a [`CompiledModel`] is *thread-confined* — the coordinator
//! runs all PJRT execution on a dedicated executor thread that owns the
//! registry (see `coordinator::worker`).

mod artifact;
mod executable;

pub use artifact::{ArtifactSpec, InputSource, InputSpec, IoSpec, Manifest, WeightGroup};
pub use executable::{CompiledModel, RuntimeInput};

use crate::error::{Error, Result};

/// Create a PJRT CPU client.  One per executor thread; creation is heavy
/// (thread pools), so callers cache it for the thread's lifetime.
pub fn cpu_client() -> Result<xla::PjRtClient> {
    xla::PjRtClient::cpu().map_err(Error::from)
}
