//! A compiled artifact — host side of the PJRT boundary.
//!
//! OFFLINE GATING.  The real device path needs the `xla` crate (PJRT FFI
//! bindings), which cannot be vendored into this offline std-only build.
//! This module keeps the entire *host* side working — manifest parsing,
//! weight-blob loading, per-input shape validation, runtime-slot
//! accounting — and stubs the *device* side: [`PjrtClient::cpu`] returns
//! [`Error::Xla`] with an explanatory message, so anything that would
//! actually execute an artifact fails fast and loudly instead of at link
//! time.  The serving stack degrades gracefully: `PjrtExecutor`-backed
//! servers report "executor init failed" per request, while the echo and
//! native executors (and everything else in the crate) are unaffected.
//! See DESIGN.md §Substitutions for the re-enabling plan.

use crate::error::{Error, Result};
use crate::runtime::artifact::{ArtifactSpec, InputSource, Manifest};
use crate::tensor::Tensor;
use std::collections::BTreeMap;

/// The message every stubbed device operation fails with.
pub const PJRT_UNAVAILABLE: &str = "PJRT/XLA backend is not linked in this std-only offline \
     build; artifact execution needs an XLA toolchain (DESIGN.md §Substitutions)";

/// Placeholder for the PJRT client handle.  Construction always fails in
/// this build; the type exists so the executor/server plumbing keeps its
/// real shape (thread-confined client created on the executor thread).
#[derive(Debug)]
pub struct PjrtClient {
    _private: (),
}

impl PjrtClient {
    /// Create a PJRT CPU client — always `Err(Error::Xla)` in this build.
    pub fn cpu() -> Result<PjrtClient> {
        Err(Error::Xla(PJRT_UNAVAILABLE.into()))
    }
}

/// A per-request input value (matched positionally against the artifact's
/// `source == Runtime` slots).
#[derive(Clone, Debug)]
pub enum RuntimeInput {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl RuntimeInput {
    pub fn len(&self) -> usize {
        match self {
            RuntimeInput::F32(v) => v.len(),
            RuntimeInput::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// An AOT artifact with its host-side state loaded and validated: the
/// spec, plus the weight group decoded into named tensors.  In a full
/// build these tensors become device-resident buffers; here they stay on
/// the host and [`CompiledModel::run`] reports the backend unavailable.
pub struct CompiledModel {
    spec: ArtifactSpec,
    weights: BTreeMap<String, Tensor>,
}

impl CompiledModel {
    /// Load `name` from `manifest`'s directory and validate every
    /// weight-sourced input against the blob (shape and presence) — the
    /// same checks the device path performs before transfer.
    pub fn load(_client: &PjrtClient, manifest: &Manifest, name: &str) -> Result<CompiledModel> {
        let spec = manifest.artifact(name)?.clone();
        // the device path parsed the HLO text here; keep at least the
        // presence check so a partially-synced artifact dir still fails
        // at load time with a pointed message
        let hlo_path = manifest.dir.join(&spec.hlo);
        if !hlo_path.is_file() {
            return Err(Error::Artifact(format!(
                "artifact {name}: HLO file {} is missing",
                hlo_path.display()
            )));
        }
        let weights = match &spec.weight_group {
            Some(g) => manifest.load_weights(g)?,
            None => BTreeMap::new(),
        };
        for input in &spec.inputs {
            if input.source == InputSource::Weights {
                let t = weights.get(&input.name).ok_or_else(|| {
                    Error::Artifact(format!(
                        "artifact {name}: weight '{}' missing from group",
                        input.name
                    ))
                })?;
                if t.shape() != &input.shape[..] {
                    return Err(Error::Artifact(format!(
                        "weight '{}': blob shape {:?} vs spec {:?}",
                        input.name,
                        t.shape(),
                        input.shape
                    )));
                }
            }
        }
        Ok(CompiledModel { spec, weights })
    }

    pub fn name(&self) -> &str {
        &self.spec.name
    }

    pub fn spec(&self) -> &ArtifactSpec {
        &self.spec
    }

    /// Host-resident weight tensors (the native cross-check tests compare
    /// these against the pure-rust implementations).
    pub fn weights(&self) -> &BTreeMap<String, Tensor> {
        &self.weights
    }

    /// Batch size of the first runtime input (serving uses this to route
    /// requests to the right batch variant).
    pub fn batch_size(&self) -> Option<usize> {
        self.spec.runtime_inputs().first().map(|i| i.shape[0])
    }

    /// Execute with per-request inputs.  Validates the runtime slots
    /// (count and element counts) exactly like the device path, then
    /// reports the backend unavailable.
    pub fn run(&self, runtime_inputs: &[RuntimeInput]) -> Result<Vec<Tensor>> {
        let runtime_slots = self.spec.runtime_inputs();
        if runtime_inputs.len() != runtime_slots.len() {
            return Err(Error::Xla(format!(
                "{}: {} runtime inputs given, want {}",
                self.spec.name,
                runtime_inputs.len(),
                runtime_slots.len()
            )));
        }
        for (given, slot) in runtime_inputs.iter().zip(&runtime_slots) {
            if given.len() != slot.numel() {
                return Err(Error::Xla(format!(
                    "input '{}': {} elems, want {}",
                    slot.name,
                    given.len(),
                    slot.numel()
                )));
            }
        }
        Err(Error::Xla(format!("{}: {PJRT_UNAVAILABLE}", self.spec.name)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write as _;
    use std::path::PathBuf;

    fn fixture_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("tensornet_exe_test_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let manifest = r#"{
          "seed": 3,
          "artifacts": [{
            "name": "toy_b2",
            "hlo": "toy_b2.hlo.txt",
            "inputs": [
              {"name": "w", "shape": [3, 4], "dtype": "float32", "source": "weights"},
              {"name": "x", "shape": [2, 4], "dtype": "float32", "source": "runtime"}
            ],
            "outputs": [{"shape": [2, 3], "dtype": "float32"}],
            "weight_group": "toy"
          }],
          "weight_groups": {
            "toy": {"file": "toy.weights.bin",
                    "layout": [{"name": "w", "shape": [3, 4], "offset": 0, "len": 12}]}
          }
        }"#;
        std::fs::write(dir.join("manifest.json"), manifest).unwrap();
        std::fs::write(dir.join("toy_b2.hlo.txt"), "HloModule toy_b2\n").unwrap();
        let mut f = std::fs::File::create(dir.join("toy.weights.bin")).unwrap();
        for i in 0..12 {
            f.write_all(&(i as f32).to_le_bytes()).unwrap();
        }
        dir
    }

    #[test]
    fn cpu_client_reports_unavailable() {
        let err = PjrtClient::cpu().unwrap_err();
        assert!(format!("{err}").contains("PJRT"), "{err}");
    }

    #[test]
    fn load_validates_host_side_and_run_reports_unavailable() {
        let dir = fixture_dir("load");
        let manifest = Manifest::load(&dir).unwrap();
        // client construction is stubbed, so fabricate the handle the way
        // only tests may: through the validated-load entry point
        let client = PjrtClient { _private: () };
        let model = CompiledModel::load(&client, &manifest, "toy_b2").unwrap();
        assert_eq!(model.name(), "toy_b2");
        assert_eq!(model.batch_size(), Some(2));
        assert_eq!(model.weights()["w"].shape(), &[3, 4]);
        // wrong slot count / element count are caught before the stub error
        assert!(model.run(&[]).is_err());
        let bad = model.run(&[RuntimeInput::F32(vec![0.0; 3])]).unwrap_err();
        assert!(format!("{bad}").contains("elems"), "{bad}");
        let stub = model.run(&[RuntimeInput::F32(vec![0.0; 8])]).unwrap_err();
        assert!(format!("{stub}").contains("PJRT"), "{stub}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_rejects_truncated_blob() {
        let dir = fixture_dir("truncated");
        // truncate the blob so the layout no longer fits (fails inside
        // Manifest::load_weights, before load()'s own shape check)
        std::fs::write(dir.join("toy.weights.bin"), [0u8; 8]).unwrap();
        let manifest = Manifest::load(&dir).unwrap();
        let client = PjrtClient { _private: () };
        assert!(CompiledModel::load(&client, &manifest, "toy_b2").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_rejects_weight_shape_mismatch() {
        let dir = fixture_dir("mismatch");
        // same 12-float blob, but the layout decodes it as (4, 3) while
        // the input spec wants (3, 4): load_weights succeeds and load()'s
        // shape-vs-spec branch must fire
        let manifest_text = std::fs::read_to_string(dir.join("manifest.json")).unwrap();
        let twisted = manifest_text.replace(
            r#""layout": [{"name": "w", "shape": [3, 4], "offset": 0, "len": 12}]"#,
            r#""layout": [{"name": "w", "shape": [4, 3], "offset": 0, "len": 12}]"#,
        );
        assert_ne!(manifest_text, twisted, "fixture layout line moved; update the test");
        std::fs::write(dir.join("manifest.json"), twisted).unwrap();
        let manifest = Manifest::load(&dir).unwrap();
        let client = PjrtClient { _private: () };
        let err = CompiledModel::load(&client, &manifest, "toy_b2").unwrap_err();
        assert!(format!("{err}").contains("blob shape"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_rejects_missing_hlo_file() {
        let dir = fixture_dir("nohlo");
        std::fs::remove_file(dir.join("toy_b2.hlo.txt")).unwrap();
        let manifest = Manifest::load(&dir).unwrap();
        let client = PjrtClient { _private: () };
        let err = CompiledModel::load(&client, &manifest, "toy_b2").unwrap_err();
        assert!(format!("{err}").contains("HLO"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
