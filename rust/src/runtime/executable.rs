//! A compiled artifact with device-resident parameters.

use crate::error::{Error, Result};
use crate::runtime::artifact::{ArtifactSpec, InputSource, Manifest};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// A per-request input value (matched positionally against the artifact's
/// `source == Runtime` slots).
#[derive(Clone, Debug)]
pub enum RuntimeInput {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// An AOT artifact compiled onto a PJRT client, with `weights` / `state` /
/// `synthesize` arguments already transferred to device buffers.
///
/// Not `Send` (PJRT handles are raw pointers) — owned by one executor
/// thread; see `coordinator::worker`.
pub struct CompiledModel {
    spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
    /// device buffers for every non-runtime slot, `None` for runtime slots
    resident: Vec<Option<xla::PjRtBuffer>>,
    client: xla::PjRtClient,
}

impl CompiledModel {
    /// Load + compile `spec` from `manifest`'s directory, transferring its
    /// weight group (if any) to the device.  `Synthesize` inputs get seeded
    /// He-scaled Gaussians; `State` inputs get zeros.
    pub fn load(client: &xla::PjRtClient, manifest: &Manifest, name: &str) -> Result<CompiledModel> {
        let spec = manifest.artifact(name)?.clone();
        let hlo_path = manifest.dir.join(&spec.hlo);
        let proto = xla::HloModuleProto::from_text_file(&hlo_path)
            .map_err(|e| Error::Artifact(format!("parsing {}: {e}", hlo_path.display())))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp)?;

        let weights = match &spec.weight_group {
            Some(g) => manifest.load_weights(g)?,
            None => Default::default(),
        };
        let mut rng = Rng::new(manifest.seed ^ 0x7265_7369_64);
        let mut resident = Vec::with_capacity(spec.inputs.len());
        for input in &spec.inputs {
            let buf = match input.source {
                InputSource::Runtime => None,
                InputSource::Weights => {
                    let t = weights.get(&input.name).ok_or_else(|| {
                        Error::Artifact(format!(
                            "artifact {name}: weight '{}' missing from group",
                            input.name
                        ))
                    })?;
                    if t.shape() != &input.shape[..] {
                        return Err(Error::Artifact(format!(
                            "weight '{}': blob shape {:?} vs spec {:?}",
                            input.name,
                            t.shape(),
                            input.shape
                        )));
                    }
                    Some(client.buffer_from_host_buffer(t.data(), &input.shape, None)?)
                }
                InputSource::State => {
                    let zeros = vec![0.0f32; input.numel()];
                    Some(client.buffer_from_host_buffer(&zeros, &input.shape, None)?)
                }
                InputSource::Synthesize => {
                    // He-scaled Gaussian: same init family as the python side
                    let fan_in = *input.shape.last().unwrap_or(&1) as f32;
                    let std = (2.0 / fan_in.max(1.0)).sqrt();
                    let data: Vec<f32> =
                        (0..input.numel()).map(|_| rng.normal_f32(std)).collect();
                    Some(client.buffer_from_host_buffer(&data, &input.shape, None)?)
                }
            };
            resident.push(buf);
        }
        Ok(CompiledModel { spec, exe, resident, client: client.clone() })
    }

    pub fn name(&self) -> &str {
        &self.spec.name
    }

    pub fn spec(&self) -> &ArtifactSpec {
        &self.spec
    }

    /// Batch size of the first runtime input (serving uses this to route
    /// requests to the right batch variant).
    pub fn batch_size(&self) -> Option<usize> {
        self.spec.runtime_inputs().first().map(|i| i.shape[0])
    }

    /// Execute with per-request inputs (positional over the runtime slots).
    /// Returns the flattened output tuple as f32 tensors.
    pub fn run(&self, runtime_inputs: &[RuntimeInput]) -> Result<Vec<Tensor>> {
        let runtime_slots: Vec<usize> = self
            .spec
            .inputs
            .iter()
            .enumerate()
            .filter(|(_, i)| i.source == InputSource::Runtime)
            .map(|(idx, _)| idx)
            .collect();
        if runtime_inputs.len() != runtime_slots.len() {
            return Err(Error::Xla(format!(
                "{}: {} runtime inputs given, want {}",
                self.spec.name,
                runtime_inputs.len(),
                runtime_slots.len()
            )));
        }
        // transfer the per-request inputs, then borrow resident buffers in
        // positional order (execute_b takes Borrow<PjRtBuffer>)
        let mut fresh: Vec<xla::PjRtBuffer> = Vec::with_capacity(runtime_inputs.len());
        let mut rt_iter = runtime_inputs.iter();
        for (idx, input) in self.spec.inputs.iter().enumerate() {
            if self.resident[idx].is_none() {
                let rt = rt_iter.next().unwrap();
                let (len, buf) = match rt {
                    RuntimeInput::F32(v) => {
                        (v.len(), self.client.buffer_from_host_buffer(v, &input.shape, None))
                    }
                    RuntimeInput::I32(v) => {
                        (v.len(), self.client.buffer_from_host_buffer(v, &input.shape, None))
                    }
                };
                if len != input.numel() {
                    return Err(Error::Xla(format!(
                        "input '{}': {len} elems, want {}",
                        input.name,
                        input.numel()
                    )));
                }
                fresh.push(buf?);
            }
        }
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(self.spec.inputs.len());
        let mut fi = 0usize;
        for idx in 0..self.spec.inputs.len() {
            match &self.resident[idx] {
                Some(buf) => args.push(buf),
                None => {
                    args.push(&fresh[fi]);
                    fi += 1;
                }
            }
        }
        let result = self.exe.execute_b(&args)?;
        let tuple = result[0][0].to_literal_sync()?;
        let literals = tuple.to_tuple()?;
        let mut out = Vec::with_capacity(literals.len());
        for (i, lit) in literals.into_iter().enumerate() {
            let vals: Vec<f32> = lit
                .to_vec::<f32>()
                .map_err(|e| Error::Xla(format!("output {i} to f32: {e}")))?;
            let shape = self
                .spec
                .outputs
                .get(i)
                .map(|o| o.shape.clone())
                .unwrap_or_else(|| vec![vals.len()]);
            out.push(Tensor::from_vec(&shape, vals)?);
        }
        Ok(out)
    }
}
