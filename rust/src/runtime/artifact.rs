//! Artifact manifest parsing + weight blob loading.
//!
//! The manifest is the contract with `python/compile/aot.py`: artifact
//! names, HLO file paths, positional input specs (with their source), and
//! the layout of each `*.weights.bin` blob (LE f32, sorted by name).

use crate::error::{Error, Result};
use crate::tensor::Tensor;
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Where an entry-computation argument comes from at runtime.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InputSource {
    /// loaded from the artifact's weight group, kept device-resident
    Weights,
    /// provided per request (the payload)
    Runtime,
    /// mutable training state (velocities) — initialized to zeros
    State,
    /// synthesized by the runtime (seeded Gaussian) — used for baseline
    /// weights too large to ship (vgg fc6 dense, 411 MB)
    Synthesize,
}

impl InputSource {
    fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "weights" => InputSource::Weights,
            "runtime" => InputSource::Runtime,
            "state" => InputSource::State,
            "synthesize" => InputSource::Synthesize,
            other => return Err(Error::Artifact(format!("unknown input source '{other}'"))),
        })
    }
}

/// One positional input of an artifact's entry computation.
#[derive(Clone, Debug)]
pub struct InputSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
    pub source: InputSource,
}

impl InputSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One output of an artifact.
#[derive(Clone, Debug)]
pub struct IoSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

/// One AOT-compiled computation.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub hlo: String,
    pub inputs: Vec<InputSpec>,
    pub outputs: Vec<IoSpec>,
    pub weight_group: Option<String>,
}

impl ArtifactSpec {
    pub fn runtime_inputs(&self) -> Vec<&InputSpec> {
        self.inputs.iter().filter(|i| i.source == InputSource::Runtime).collect()
    }
}

/// Layout of a weights blob.
#[derive(Clone, Debug)]
pub struct WeightGroup {
    pub file: String,
    /// `(name, shape, offset_elems, len_elems)`
    pub layout: Vec<(String, Vec<usize>, usize, usize)>,
}

/// The parsed `manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub seed: u64,
    pub artifacts: Vec<ArtifactSpec>,
    pub weight_groups: BTreeMap<String, WeightGroup>,
}

fn parse_shape(j: &Json) -> Result<Vec<usize>> {
    j.as_arr()
        .ok_or_else(|| Error::Artifact("shape not an array".into()))?
        .iter()
        .map(|x| x.as_usize().ok_or_else(|| Error::Artifact("bad shape entry".into())))
        .collect()
}

impl Manifest {
    /// Parse `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| Error::Artifact(format!("reading {}: {e}", path.display())))?;
        let root = Json::parse(&text)?;
        let seed = root.req("seed")?.as_usize().unwrap_or(0) as u64;

        let mut artifacts = Vec::new();
        for a in root.req("artifacts")?.as_arr().unwrap_or(&[]) {
            let mut inputs = Vec::new();
            for i in a.req("inputs")?.as_arr().unwrap_or(&[]) {
                inputs.push(InputSpec {
                    name: i.req("name")?.as_str().unwrap_or("").to_string(),
                    shape: parse_shape(i.req("shape")?)?,
                    dtype: i.req("dtype")?.as_str().unwrap_or("float32").to_string(),
                    source: InputSource::parse(i.req("source")?.as_str().unwrap_or(""))?,
                });
            }
            let mut outputs = Vec::new();
            for o in a.req("outputs")?.as_arr().unwrap_or(&[]) {
                outputs.push(IoSpec {
                    shape: parse_shape(o.req("shape")?)?,
                    dtype: o.req("dtype")?.as_str().unwrap_or("float32").to_string(),
                });
            }
            artifacts.push(ArtifactSpec {
                name: a.req("name")?.as_str().unwrap_or("").to_string(),
                hlo: a.req("hlo")?.as_str().unwrap_or("").to_string(),
                inputs,
                outputs,
                weight_group: a
                    .get("weight_group")
                    .and_then(|g| g.as_str())
                    .map(|s| s.to_string()),
            });
        }

        let mut weight_groups = BTreeMap::new();
        if let Some(groups) = root.get("weight_groups").and_then(|g| g.as_obj()) {
            for (name, g) in groups {
                let mut layout = Vec::new();
                for e in g.req("layout")?.as_arr().unwrap_or(&[]) {
                    layout.push((
                        e.req("name")?.as_str().unwrap_or("").to_string(),
                        parse_shape(e.req("shape")?)?,
                        e.req("offset")?
                            .as_usize()
                            .ok_or_else(|| Error::Artifact("bad offset".into()))?,
                        e.req("len")?
                            .as_usize()
                            .ok_or_else(|| Error::Artifact("bad len".into()))?,
                    ));
                }
                weight_groups.insert(
                    name.clone(),
                    WeightGroup {
                        file: g.req("file")?.as_str().unwrap_or("").to_string(),
                        layout,
                    },
                );
            }
        }

        Ok(Manifest { dir, seed, artifacts, weight_groups })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .ok_or_else(|| Error::Artifact(format!("no artifact '{name}' in manifest")))
    }

    /// Load a weight group's blob into named tensors.
    pub fn load_weights(&self, group: &str) -> Result<BTreeMap<String, Tensor>> {
        let g = self
            .weight_groups
            .get(group)
            .ok_or_else(|| Error::Artifact(format!("no weight group '{group}'")))?;
        let path = self.dir.join(&g.file);
        let bytes = std::fs::read(&path)
            .map_err(|e| Error::Artifact(format!("reading {}: {e}", path.display())))?;
        let floats: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();
        let mut out = BTreeMap::new();
        for (name, shape, offset, len) in &g.layout {
            if offset + len > floats.len() {
                return Err(Error::Artifact(format!(
                    "weight '{name}' range {offset}+{len} exceeds blob {}",
                    floats.len()
                )));
            }
            let t = Tensor::from_vec(shape, floats[*offset..*offset + *len].to_vec())?;
            out.insert(name.clone(), t);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "tensornet_test_{tag}_{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn write_fixture(dir: &Path) {
        let manifest = r#"{
          "seed": 7,
          "artifacts": [{
            "name": "toy_b2",
            "hlo": "toy_b2.hlo.txt",
            "inputs": [
              {"name": "w", "shape": [3, 4], "dtype": "float32", "source": "weights"},
              {"name": "x", "shape": [2, 4], "dtype": "float32", "source": "runtime"}
            ],
            "outputs": [{"shape": [2, 3], "dtype": "float32"}],
            "weight_group": "toy"
          }],
          "weight_groups": {
            "toy": {"file": "toy.weights.bin",
                    "layout": [{"name": "w", "shape": [3, 4], "offset": 0, "len": 12}]}
          }
        }"#;
        std::fs::write(dir.join("manifest.json"), manifest).unwrap();
        let mut f = std::fs::File::create(dir.join("toy.weights.bin")).unwrap();
        for i in 0..12 {
            f.write_all(&(i as f32).to_le_bytes()).unwrap();
        }
    }

    #[test]
    fn parses_manifest_and_weights() {
        let dir = tmpdir("manifest");
        write_fixture(&dir);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.seed, 7);
        let a = m.artifact("toy_b2").unwrap();
        assert_eq!(a.inputs.len(), 2);
        assert_eq!(a.inputs[0].source, InputSource::Weights);
        assert_eq!(a.runtime_inputs().len(), 1);
        assert_eq!(a.outputs[0].shape, vec![2, 3]);
        let w = m.load_weights("toy").unwrap();
        let t = &w["w"];
        assert_eq!(t.shape(), &[3, 4]);
        assert_eq!(t.data()[5], 5.0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_artifact_errors() {
        let dir = tmpdir("missing");
        write_fixture(&dir);
        let m = Manifest::load(&dir).unwrap();
        assert!(m.artifact("nope").is_err());
        assert!(m.load_weights("nope").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupt_layout_errors() {
        let dir = tmpdir("corrupt");
        write_fixture(&dir);
        // truncate the blob
        std::fs::write(dir.join("toy.weights.bin"), [0u8; 8]).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert!(m.load_weights("toy").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bad_source_rejected() {
        let dir = tmpdir("badsource");
        let manifest = r#"{"seed": 1, "artifacts": [{
            "name": "x", "hlo": "x.hlo.txt",
            "inputs": [{"name": "a", "shape": [1], "dtype": "float32", "source": "martian"}],
            "outputs": []}], "weight_groups": {}}"#;
        std::fs::write(dir.join("manifest.json"), manifest).unwrap();
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
