//! CIFAR-10 stand-in: textured color classes (DESIGN.md §Substitutions).
//!
//! Each of the 10 classes combines (a) a class-specific pair of oriented
//! sinusoidal gratings, (b) a class color tint, and (c) a smooth random
//! blob field, plus per-sample phase/orientation jitter and pixel noise.
//! Matches CIFAR-10's interface: 3 x 32 x 32 inputs (3072-dim rows,
//! channel-major like the flattened tensors the paper reshapes), 10
//! classes, preprocessed by GCN + ZCA like the paper (§6.2 follows
//! Goodfellow et al.).

use crate::data::Dataset;
use crate::error::Result;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

pub const CIFAR_SIDE: usize = 32;
pub const CIFAR_DIM: usize = 3 * CIFAR_SIDE * CIFAR_SIDE;
pub const CIFAR_CLASSES: usize = 10;

/// Class recipe: grating frequencies/orientations + RGB tint.
struct Recipe {
    freq1: f32,
    angle1: f32,
    freq2: f32,
    angle2: f32,
    tint: [f32; 3],
}

fn recipe(class: usize) -> Recipe {
    // spread parameters deterministically over classes
    let golden = 0.618_034f32;
    let a = (class as f32) * golden % 1.0;
    Recipe {
        freq1: 2.0 + 1.7 * (class % 5) as f32,
        angle1: std::f32::consts::PI * a,
        freq2: 3.0 + 1.3 * ((class + 3) % 5) as f32,
        angle2: std::f32::consts::PI * ((a + 0.37) % 1.0),
        tint: [
            0.35 + 0.6 * ((class % 3) as f32 / 2.0),
            0.35 + 0.6 * (((class / 3) % 3) as f32 / 2.0),
            0.35 + 0.6 * (((class / 9) % 3) as f32 / 2.0),
        ],
    }
}

fn render(class: usize, rng: &mut Rng, out: &mut [f32]) {
    debug_assert_eq!(out.len(), CIFAR_DIM);
    let r = recipe(class);
    let jitter = rng.range_f64(-0.2, 0.2) as f32;
    let phase1 = rng.range_f64(0.0, std::f64::consts::TAU) as f32;
    let phase2 = rng.range_f64(0.0, std::f64::consts::TAU) as f32;
    let (a1, a2) = (r.angle1 + jitter, r.angle2 - jitter);
    let (c1, s1) = (a1.cos(), a1.sin());
    let (c2, s2) = (a2.cos(), a2.sin());
    // smooth blob: 3 random Gaussians
    let blobs: Vec<(f32, f32, f32)> = (0..3)
        .map(|_| {
            (
                rng.range_f64(0.2, 0.8) as f32,
                rng.range_f64(0.2, 0.8) as f32,
                rng.range_f64(0.08, 0.25) as f32,
            )
        })
        .collect();
    let tau = std::f32::consts::TAU;
    for iy in 0..CIFAR_SIDE {
        for ix in 0..CIFAR_SIDE {
            let x = (ix as f32 + 0.5) / CIFAR_SIDE as f32;
            let y = (iy as f32 + 0.5) / CIFAR_SIDE as f32;
            let u1 = c1 * x + s1 * y;
            let u2 = c2 * x + s2 * y;
            let g = 0.5 * (tau * r.freq1 * u1 + phase1).sin() + 0.35 * (tau * r.freq2 * u2 + phase2).sin();
            let mut blob = 0.0f32;
            for &(bx, by, bs) in &blobs {
                let d2 = (x - bx).powi(2) + (y - by).powi(2);
                blob += (-d2 / (2.0 * bs * bs)).exp();
            }
            let base = 0.45 + 0.3 * g + 0.15 * blob;
            for ch in 0..3 {
                let v = base * r.tint[ch] + rng.normal_f32(0.05);
                out[ch * CIFAR_SIDE * CIFAR_SIDE + iy * CIFAR_SIDE + ix] = v.clamp(0.0, 1.0);
            }
        }
    }
}

/// Generate `n` CIFAR-like samples.
pub fn synth_cifar(n: usize, seed: u64) -> Result<Dataset> {
    let mut rng = Rng::new(seed ^ 0x6369_6661_725f_3130);
    let mut data = vec![0.0f32; n * CIFAR_DIM];
    let mut labels = Vec::with_capacity(n);
    for (i, chunk) in data.chunks_mut(CIFAR_DIM).enumerate() {
        let class = if i < CIFAR_CLASSES { i } else { rng.below(CIFAR_CLASSES) };
        render(class, &mut rng, chunk);
        labels.push(class);
    }
    Dataset::new(Tensor::from_vec(&[n, CIFAR_DIM], data)?, labels, CIFAR_CLASSES)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_determinism() {
        let a = synth_cifar(15, 1).unwrap();
        assert_eq!(a.x.shape(), &[15, 3072]);
        assert_eq!(a.x, synth_cifar(15, 1).unwrap().x);
    }

    #[test]
    fn classes_distinguishable() {
        let d = synth_cifar(120, 2).unwrap();
        let mean = |class: usize| -> Vec<f32> {
            let rows: Vec<usize> = (0..d.len()).filter(|&i| d.labels[i] == class).collect();
            let mut m = vec![0.0f32; CIFAR_DIM];
            for &i in &rows {
                for (mm, &v) in m.iter_mut().zip(d.x.row(i)) {
                    *mm += v / rows.len() as f32;
                }
            }
            m
        };
        let m2 = mean(2);
        let m7 = mean(7);
        let dist: f32 = m2.iter().zip(&m7).map(|(a, b)| (a - b) * (a - b)).sum::<f32>().sqrt();
        assert!(dist > 0.5, "class means too close: {dist}");
    }

    #[test]
    fn values_in_range() {
        let d = synth_cifar(10, 3).unwrap();
        assert!(d.x.data().iter().all(|&v| (0.0..=1.0).contains(&v)));
    }
}
