//! In-memory labelled dataset.

use crate::error::{shape_err, Result};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// A labelled dataset: `x (n, dim)` features, integer class labels.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub x: Tensor,
    pub labels: Vec<usize>,
    pub n_classes: usize,
}

impl Dataset {
    pub fn new(x: Tensor, labels: Vec<usize>, n_classes: usize) -> Result<Self> {
        if x.ndim() != 2 || x.shape()[0] != labels.len() {
            return shape_err(format!("dataset: x {:?} vs {} labels", x.shape(), labels.len()));
        }
        if let Some(&bad) = labels.iter().find(|&&y| y >= n_classes) {
            return shape_err(format!("label {bad} >= {n_classes}"));
        }
        Ok(Dataset { x, labels, n_classes })
    }

    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    pub fn dim(&self) -> usize {
        self.x.shape()[1]
    }

    /// Split off the first `n_train` samples as train, rest as test.
    pub fn split(&self, n_train: usize) -> Result<(Dataset, Dataset)> {
        if n_train > self.len() {
            return shape_err(format!("split {n_train} > {}", self.len()));
        }
        let train_x = self.x.rows(0, n_train)?;
        let test_x = self.x.rows(n_train, self.len())?;
        Ok((
            Dataset::new(train_x, self.labels[..n_train].to_vec(), self.n_classes)?,
            Dataset::new(test_x, self.labels[n_train..].to_vec(), self.n_classes)?,
        ))
    }

    /// Gather a subset by indices.
    pub fn subset(&self, idx: &[usize]) -> Result<Dataset> {
        let dim = self.dim();
        let mut data = Vec::with_capacity(idx.len() * dim);
        let mut labels = Vec::with_capacity(idx.len());
        for &i in idx {
            if i >= self.len() {
                return shape_err(format!("subset index {i} out of range"));
            }
            data.extend_from_slice(self.x.row(i));
            labels.push(self.labels[i]);
        }
        Ok(Dataset { x: Tensor::from_vec(&[idx.len(), dim], data)?, labels, n_classes: self.n_classes })
    }

    /// In-place shuffle of rows (seeded).
    pub fn shuffle(&mut self, rng: &mut Rng) {
        let mut order: Vec<usize> = (0..self.len()).collect();
        rng.shuffle(&mut order);
        let shuffled = self.subset(&order).expect("valid permutation");
        *self = shuffled;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        let x = Tensor::from_vec(&[4, 2], vec![0., 0., 1., 1., 2., 2., 3., 3.]).unwrap();
        Dataset::new(x, vec![0, 1, 0, 1], 2).unwrap()
    }

    #[test]
    fn construction_validates() {
        let x = Tensor::zeros(&[3, 2]);
        assert!(Dataset::new(x.clone(), vec![0, 1], 2).is_err()); // wrong len
        assert!(Dataset::new(x.clone(), vec![0, 1, 5], 2).is_err()); // label range
        assert!(Dataset::new(x, vec![0, 1, 1], 2).is_ok());
    }

    #[test]
    fn split_partitions() {
        let (tr, te) = tiny().split(3).unwrap();
        assert_eq!(tr.len(), 3);
        assert_eq!(te.len(), 1);
        assert_eq!(te.x.row(0), &[3., 3.]);
        assert_eq!(te.labels, vec![1]);
    }

    #[test]
    fn subset_gathers() {
        let s = tiny().subset(&[2, 0]).unwrap();
        assert_eq!(s.x.row(0), &[2., 2.]);
        assert_eq!(s.labels, vec![0, 0]);
        assert!(tiny().subset(&[9]).is_err());
    }

    #[test]
    fn shuffle_preserves_pairs() {
        let mut d = tiny();
        d.shuffle(&mut Rng::new(1));
        for i in 0..d.len() {
            // pair invariant: feature value equals its original row id,
            // whose label parity we know
            let v = d.x.row(i)[0] as usize;
            assert_eq!(d.labels[i], v % 2);
        }
    }
}
