//! High-dimensional feature stand-in for the ImageNet experiments
//! (DESIGN.md §Substitutions).
//!
//! The paper's Table 2/3 experiments train the FC tail of vgg-16/19 on
//! fc6 inputs: 25088-dimensional ReLU activations of the last conv layer.
//! We model them as a sparse non-negative Gaussian mixture: each class
//! owns a sparse mean direction; samples are `relu(mean + noise)` —
//! matching the sparsity and non-negativity of real conv features while
//! keeping class structure a linear tail can learn.

use crate::data::Dataset;
use crate::error::Result;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Generation parameters.
#[derive(Clone, Copy, Debug)]
pub struct FeatureSpec {
    pub dim: usize,
    pub n_classes: usize,
    /// fraction of dimensions active in each class mean
    pub density: f64,
    /// class-mean magnitude relative to noise (SNR knob)
    pub signal: f32,
}

impl Default for FeatureSpec {
    fn default() -> Self {
        // vgg fc6 geometry
        FeatureSpec { dim: 25088, n_classes: 10, density: 0.05, signal: 1.5 }
    }
}

/// Generate `n` samples under `spec` (deterministic in `seed`).
pub fn synth_features(n: usize, spec: FeatureSpec, seed: u64) -> Result<Dataset> {
    let mut rng = Rng::new(seed ^ 0x6663_365f_6665_6174);
    // class means: sparse non-negative
    let mut means = vec![0.0f32; spec.n_classes * spec.dim];
    for c in 0..spec.n_classes {
        let mut class_rng = rng.fork(c as u64 + 1);
        for j in 0..spec.dim {
            if class_rng.uniform() < spec.density {
                means[c * spec.dim + j] = spec.signal * (0.5 + class_rng.uniform_f32());
            }
        }
    }
    let mut data = vec![0.0f32; n * spec.dim];
    let mut labels = Vec::with_capacity(n);
    for (i, chunk) in data.chunks_mut(spec.dim).enumerate() {
        let class = if i < spec.n_classes { i } else { rng.below(spec.n_classes) };
        let mean = &means[class * spec.dim..(class + 1) * spec.dim];
        for (v, &m) in chunk.iter_mut().zip(mean) {
            *v = (m + rng.normal_f32(1.0)).max(0.0); // relu
        }
        labels.push(class);
    }
    Dataset::new(Tensor::from_vec(&[n, spec.dim], data)?, labels, spec.n_classes)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_spec() -> FeatureSpec {
        FeatureSpec { dim: 256, n_classes: 5, density: 0.1, signal: 2.0 }
    }

    #[test]
    fn shapes_and_determinism() {
        let a = synth_features(20, small_spec(), 1).unwrap();
        let b = synth_features(20, small_spec(), 1).unwrap();
        assert_eq!(a.x.shape(), &[20, 256]);
        assert_eq!(a.x, b.x);
    }

    #[test]
    fn non_negative_and_sparse_ish() {
        let d = synth_features(50, small_spec(), 2).unwrap();
        assert!(d.x.data().iter().all(|&v| v >= 0.0));
        let zero_frac =
            d.x.data().iter().filter(|&&v| v == 0.0).count() as f64 / d.x.numel() as f64;
        // relu of ~N(0,1) zeroes ≈ half
        assert!(zero_frac > 0.25 && zero_frac < 0.75, "zero fraction {zero_frac}");
    }

    #[test]
    fn class_signal_exists() {
        let d = synth_features(100, small_spec(), 3).unwrap();
        // nearest-class-mean classification should beat chance easily
        let mut means = vec![vec![0.0f32; 256]; 5];
        let mut counts = [0usize; 5];
        for i in 0..d.len() {
            counts[d.labels[i]] += 1;
            for (m, &v) in means[d.labels[i]].iter_mut().zip(d.x.row(i)) {
                *m += v;
            }
        }
        for (m, &c) in means.iter_mut().zip(&counts) {
            for v in m.iter_mut() {
                *v /= c.max(1) as f32;
            }
        }
        let mut hits = 0usize;
        for i in 0..d.len() {
            let row = d.x.row(i);
            let best = (0..5)
                .min_by(|&a, &b| {
                    let da: f32 = row.iter().zip(&means[a]).map(|(x, m)| (x - m).powi(2)).sum();
                    let db: f32 = row.iter().zip(&means[b]).map(|(x, m)| (x - m).powi(2)).sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            if best == d.labels[i] {
                hits += 1;
            }
        }
        let acc = hits as f32 / d.len() as f32;
        assert!(acc > 0.6, "nearest-mean accuracy only {acc}");
    }
}
