//! Synthetic dataset substrates (S6 in DESIGN.md).
//!
//! The paper trains on MNIST, CIFAR-10 and ImageNet.  None of those are
//! available in this offline environment, so each is replaced by a
//! procedurally-generated stand-in with the same input dimensionality,
//! class count and preprocessing path (documented per-generator and in
//! DESIGN.md §Substitutions).  The generators are deterministic in a
//! `u64` seed, making every experiment reproducible bit-for-bit.

mod batcher;
mod dataset;
mod preprocess;
mod synth_cifar;
mod synth_features;
mod synth_mnist;

pub use batcher::BatchIter;
pub use dataset::Dataset;
pub use preprocess::{global_contrast_normalize, ZcaWhitener};
pub use synth_cifar::{synth_cifar, CIFAR_CLASSES, CIFAR_DIM};
pub use synth_features::{synth_features, FeatureSpec};
pub use synth_mnist::{synth_mnist, MNIST_CLASSES, MNIST_DIM, MNIST_SIDE};
