//! Preprocessing: global contrast normalization + ZCA whitening
//! (the paper's §6.2 CIFAR pipeline, following Goodfellow et al.).

use crate::error::{shape_err, Result};
use crate::tensor::{matmul, matmul_at, Tensor};
use crate::linalg::qr;
use crate::util::rng::Rng;

/// Per-sample GCN: subtract the row mean and scale to unit std
/// (epsilon-guarded).
pub fn global_contrast_normalize(x: &mut Tensor) -> Result<()> {
    if x.ndim() != 2 {
        return shape_err(format!("gcn on {:?}", x.shape()));
    }
    let dim = x.shape()[1];
    for row in x.data_mut().chunks_mut(dim) {
        let mean: f32 = row.iter().sum::<f32>() / dim as f32;
        let mut var = 0.0f32;
        for v in row.iter_mut() {
            *v -= mean;
            var += *v * *v;
        }
        let std = (var / dim as f32).sqrt().max(1e-6);
        for v in row.iter_mut() {
            *v /= std;
        }
    }
    Ok(())
}

/// Truncated ZCA whitening.
///
/// Full ZCA needs the complete eigendecomposition of the `d x d`
/// covariance — infeasible to do exactly at CIFAR scale (3072²) with the
/// in-tree Jacobi SVD on every experiment run.  We use the standard
/// truncated variant: the top-`k` eigenpairs are found by block subspace
/// iteration (QR-orthonormalized power method — uses only GEMMs against
/// the data, never forming the covariance), dimensions in the top subspace
/// are rescaled by `1/sqrt(λ_i + eps)`, and the orthogonal complement is
/// rescaled by the average residual eigenvalue.  For `k = d` this equals
/// full ZCA up to iteration tolerance.
pub struct ZcaWhitener {
    mean: Vec<f32>,
    /// (d, k) top eigenvectors
    u: Tensor,
    /// per-component scale 1/sqrt(λ+eps), length k
    scale: Vec<f32>,
    /// scale applied to the residual subspace
    resid_scale: f32,
}

impl ZcaWhitener {
    /// Fit on `x (n, d)` with `k` components and `iters` subspace
    /// iterations.
    pub fn fit(x: &Tensor, k: usize, eps: f32, iters: usize, rng: &mut Rng) -> Result<Self> {
        if x.ndim() != 2 {
            return shape_err(format!("zca fit on {:?}", x.shape()));
        }
        let (n, d) = (x.shape()[0], x.shape()[1]);
        let k = k.min(d).min(n).max(1);
        // mean
        let mut mean = vec![0.0f32; d];
        for row in x.data().chunks(d) {
            for (m, &v) in mean.iter_mut().zip(row) {
                *m += v / n as f32;
            }
        }
        // centered data (materialized once)
        let mut xc = x.clone();
        for row in xc.data_mut().chunks_mut(d) {
            for (v, &m) in row.iter_mut().zip(&mean) {
                *v -= m;
            }
        }
        // subspace iteration: V <- orth(C V), C = xcᵀ xc / n
        let mut v = Tensor::randn(&[d, k], 1.0, rng);
        for _ in 0..iters.max(1) {
            let xv = matmul(&xc, &v)?; // (n, k)
            let cv = matmul_at(&xc, &xv)?; // (d, k)
            let (q, _) = qr(&cv)?;
            v = q;
        }
        // Rayleigh quotients: λ_i = ||xc v_i||² / n
        let xv = matmul(&xc, &v)?;
        let mut lambda = vec![0.0f32; k];
        for row in xv.data().chunks(k) {
            for (l, &val) in lambda.iter_mut().zip(row) {
                *l += val * val / n as f32;
            }
        }
        // residual average eigenvalue: (trace(C) - Σλ) / (d - k)
        let total_var: f32 =
            xc.data().iter().map(|&v| v * v).sum::<f32>() / n as f32;
        let resid = ((total_var - lambda.iter().sum::<f32>()) / (d - k).max(1) as f32).max(0.0);
        let scale: Vec<f32> = lambda.iter().map(|&l| 1.0 / (l + eps).sqrt()).collect();
        let resid_scale = 1.0 / (resid + eps).sqrt();
        Ok(ZcaWhitener { mean, u: v, scale, resid_scale })
    }

    pub fn k(&self) -> usize {
        self.scale.len()
    }

    /// Whiten in place: `x ← (x−μ)·resid + U (diag(scale)−resid·I) Uᵀ (x−μ)`.
    pub fn apply(&self, x: &mut Tensor) -> Result<()> {
        if x.ndim() != 2 || x.shape()[1] != self.mean.len() {
            return shape_err(format!("zca apply on {:?}", x.shape()));
        }
        let d = self.mean.len();
        for row in x.data_mut().chunks_mut(d) {
            for (v, &m) in row.iter_mut().zip(&self.mean) {
                *v -= m;
            }
        }
        // projections p = x U  (B, k)
        let p = matmul(x, &self.u)?;
        // adjusted = p * (scale - resid)
        let mut adj = p;
        let k = self.k();
        for row in adj.data_mut().chunks_mut(k) {
            for (v, &s) in row.iter_mut().zip(&self.scale) {
                *v *= s - self.resid_scale;
            }
        }
        // x = resid * x + adj Uᵀ
        let back = crate::tensor::matmul_bt(&adj, &self.u)?; // (B, d)
        for (v, &a) in x.data_mut().iter_mut().zip(back.data()) {
            *v = self.resid_scale * *v + a;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gcn_zero_mean_unit_std() {
        let mut rng = Rng::new(1);
        let mut x = Tensor::randn(&[5, 64], 3.0, &mut rng);
        x.data_mut()[0] += 10.0;
        global_contrast_normalize(&mut x).unwrap();
        for row in x.data().chunks(64) {
            let mean: f32 = row.iter().sum::<f32>() / 64.0;
            let var: f32 = row.iter().map(|v| v * v).sum::<f32>() / 64.0;
            assert!(mean.abs() < 1e-4);
            assert!((var - 1.0).abs() < 1e-3);
        }
    }

    #[test]
    fn zca_decorrelates_top_subspace() {
        let mut rng = Rng::new(2);
        // anisotropic data: stretch two directions hard
        let n = 400usize;
        let d = 16usize;
        let mut x = Tensor::randn(&[n, d], 1.0, &mut rng);
        for row in x.data_mut().chunks_mut(d) {
            row[0] *= 8.0;
            row[1] *= 4.0;
        }
        let zca = ZcaWhitener::fit(&x, d, 1e-3, 12, &mut rng).unwrap();
        let mut xw = x.clone();
        zca.apply(&mut xw).unwrap();
        // covariance of whitened data should be near identity
        let mut cov = vec![0.0f32; d * d];
        for row in xw.data().chunks(d) {
            for i in 0..d {
                for j in 0..d {
                    cov[i * d + j] += row[i] * row[j] / n as f32;
                }
            }
        }
        for i in 0..d {
            assert!((cov[i * d + i] - 1.0).abs() < 0.35, "diag {}: {}", i, cov[i * d + i]);
            for j in 0..i {
                assert!(cov[i * d + j].abs() < 0.2, "off ({i},{j}): {}", cov[i * d + j]);
            }
        }
    }

    #[test]
    fn truncated_zca_shrinks_dominant_direction() {
        let mut rng = Rng::new(3);
        let n = 300usize;
        let d = 32usize;
        let mut x = Tensor::randn(&[n, d], 1.0, &mut rng);
        for row in x.data_mut().chunks_mut(d) {
            row[3] *= 10.0;
        }
        let zca = ZcaWhitener::fit(&x, 4, 1e-3, 10, &mut rng).unwrap();
        let mut xw = x.clone();
        zca.apply(&mut xw).unwrap();
        let var_before: f32 = x.data().chunks(d).map(|r| r[3] * r[3]).sum::<f32>() / n as f32;
        let var_after: f32 = xw.data().chunks(d).map(|r| r[3] * r[3]).sum::<f32>() / n as f32;
        assert!(var_after < var_before / 10.0, "{var_after} vs {var_before}");
    }

    #[test]
    fn apply_validates_dims() {
        let mut rng = Rng::new(4);
        let x = Tensor::randn(&[20, 8], 1.0, &mut rng);
        let zca = ZcaWhitener::fit(&x, 4, 1e-3, 5, &mut rng).unwrap();
        let mut bad = Tensor::zeros(&[3, 9]);
        assert!(zca.apply(&mut bad).is_err());
    }
}
