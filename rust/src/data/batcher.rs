//! Minibatch iteration with per-epoch shuffling.

use crate::data::Dataset;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Iterator of `(x_batch, label_batch)` over a dataset, reshuffled each
/// time it is constructed.
pub struct BatchIter<'a> {
    data: &'a Dataset,
    order: Vec<usize>,
    batch: usize,
    pos: usize,
    drop_last: bool,
}

impl<'a> BatchIter<'a> {
    pub fn new(data: &'a Dataset, batch: usize, rng: &mut Rng, drop_last: bool) -> Self {
        let mut order: Vec<usize> = (0..data.len()).collect();
        rng.shuffle(&mut order);
        BatchIter { data, order, batch: batch.max(1), pos: 0, drop_last }
    }

    /// Deterministic order (evaluation).
    pub fn sequential(data: &'a Dataset, batch: usize) -> Self {
        BatchIter {
            data,
            order: (0..data.len()).collect(),
            batch: batch.max(1),
            pos: 0,
            drop_last: false,
        }
    }
}

impl<'a> Iterator for BatchIter<'a> {
    type Item = (Tensor, Vec<usize>);

    fn next(&mut self) -> Option<Self::Item> {
        if self.pos >= self.order.len() {
            return None;
        }
        let end = (self.pos + self.batch).min(self.order.len());
        if self.drop_last && end - self.pos < self.batch {
            return None;
        }
        let idx = &self.order[self.pos..end];
        self.pos = end;
        let sub = self.data.subset(idx).ok()?;
        Some((sub.x, sub.labels))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Dataset;

    fn data(n: usize) -> Dataset {
        let x = Tensor::from_vec(&[n, 1], (0..n).map(|i| i as f32).collect()).unwrap();
        let labels = (0..n).map(|i| i % 3).collect();
        Dataset::new(x, labels, 3).unwrap()
    }

    #[test]
    fn covers_all_once() {
        let d = data(10);
        let mut rng = Rng::new(1);
        let mut seen = vec![false; 10];
        for (x, _) in BatchIter::new(&d, 3, &mut rng, false) {
            for &v in x.data() {
                let i = v as usize;
                assert!(!seen[i], "duplicate {i}");
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn drop_last_trims() {
        let d = data(10);
        let mut rng = Rng::new(2);
        let batches: Vec<_> = BatchIter::new(&d, 4, &mut rng, true).collect();
        assert_eq!(batches.len(), 2); // 4+4, drops the 2 leftover
        assert!(batches.iter().all(|(x, _)| x.shape()[0] == 4));
    }

    #[test]
    fn sequential_in_order() {
        let d = data(5);
        let all: Vec<f32> = BatchIter::sequential(&d, 2)
            .flat_map(|(x, _)| x.data().to_vec())
            .collect();
        assert_eq!(all, vec![0., 1., 2., 3., 4.]);
    }

    #[test]
    fn labels_align() {
        let d = data(9);
        let mut rng = Rng::new(3);
        for (x, labels) in BatchIter::new(&d, 4, &mut rng, false) {
            for (row, &y) in x.data().chunks(1).zip(&labels) {
                assert_eq!((row[0] as usize) % 3, y);
            }
        }
    }
}
