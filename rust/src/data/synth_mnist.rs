//! MNIST stand-in: procedurally rendered digit glyphs (DESIGN.md
//! §Substitutions).
//!
//! Each class 0–9 is defined by a stroke skeleton (polyline set) in the
//! unit square; a sample applies a random affine jitter (rotation, scale,
//! shear, translation), rasterizes with a Gaussian brush onto a 32 x 32
//! grid (the paper resizes MNIST 28 -> 32 for reshaping options), and adds
//! pixel noise.  The result keeps what the paper's experiment actually
//! needs: a 1024-dimensional 10-class problem with smooth class manifolds
//! that a 2-layer MLP separates to a few-percent error.

use crate::data::Dataset;
use crate::error::Result;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

pub const MNIST_SIDE: usize = 32;
pub const MNIST_DIM: usize = MNIST_SIDE * MNIST_SIDE;
pub const MNIST_CLASSES: usize = 10;

type Pt = (f32, f32);

/// Stroke skeletons per digit, in [0,1]² (y grows downward).
fn glyph(digit: usize) -> Vec<Vec<Pt>> {
    match digit {
        0 => vec![vec![
            (0.5, 0.15),
            (0.75, 0.3),
            (0.75, 0.7),
            (0.5, 0.85),
            (0.25, 0.7),
            (0.25, 0.3),
            (0.5, 0.15),
        ]],
        1 => vec![vec![(0.35, 0.3), (0.55, 0.15), (0.55, 0.85)]],
        2 => vec![vec![
            (0.25, 0.3),
            (0.5, 0.15),
            (0.75, 0.3),
            (0.7, 0.5),
            (0.25, 0.85),
            (0.75, 0.85),
        ]],
        3 => vec![vec![
            (0.25, 0.2),
            (0.7, 0.2),
            (0.45, 0.5),
            (0.75, 0.7),
            (0.5, 0.88),
            (0.25, 0.78),
        ]],
        4 => vec![vec![(0.65, 0.85), (0.65, 0.15), (0.25, 0.6), (0.8, 0.6)]],
        5 => vec![vec![
            (0.75, 0.15),
            (0.3, 0.15),
            (0.28, 0.45),
            (0.65, 0.45),
            (0.75, 0.68),
            (0.55, 0.85),
            (0.25, 0.8),
        ]],
        6 => vec![vec![
            (0.7, 0.15),
            (0.4, 0.35),
            (0.28, 0.65),
            (0.5, 0.85),
            (0.72, 0.68),
            (0.5, 0.52),
            (0.3, 0.62),
        ]],
        7 => vec![vec![(0.25, 0.15), (0.78, 0.15), (0.45, 0.85)]],
        8 => vec![
            vec![(0.5, 0.15), (0.7, 0.3), (0.5, 0.48), (0.3, 0.3), (0.5, 0.15)],
            vec![(0.5, 0.48), (0.75, 0.68), (0.5, 0.88), (0.25, 0.68), (0.5, 0.48)],
        ],
        9 => vec![vec![
            (0.7, 0.4),
            (0.5, 0.5),
            (0.3, 0.35),
            (0.5, 0.15),
            (0.7, 0.3),
            (0.68, 0.6),
            (0.5, 0.85),
        ]],
        _ => panic!("digit out of range"),
    }
}

/// Distance from point to segment.
fn seg_dist(p: Pt, a: Pt, b: Pt) -> f32 {
    let (px, py) = p;
    let (ax, ay) = a;
    let (bx, by) = b;
    let (dx, dy) = (bx - ax, by - ay);
    let len2 = dx * dx + dy * dy;
    let t = if len2 <= 1e-12 {
        0.0
    } else {
        (((px - ax) * dx + (py - ay) * dy) / len2).clamp(0.0, 1.0)
    };
    let (cx, cy) = (ax + t * dx, ay + t * dy);
    ((px - cx).powi(2) + (py - cy).powi(2)).sqrt()
}

/// Render one digit with the given jitter into a 32x32 buffer.
fn render(digit: usize, rng: &mut Rng, out: &mut [f32]) {
    debug_assert_eq!(out.len(), MNIST_DIM);
    // random affine around the glyph center (0.5, 0.5)
    let theta = rng.range_f64(-0.30, 0.30) as f32;
    let scale = rng.range_f64(0.82, 1.15) as f32;
    let shear = rng.range_f64(-0.15, 0.15) as f32;
    let (tx, ty) = (rng.range_f64(-0.08, 0.08) as f32, rng.range_f64(-0.08, 0.08) as f32);
    let (c, s) = (theta.cos() * scale, theta.sin() * scale);
    let xform = |(x, y): Pt| -> Pt {
        let (dx, dy) = (x - 0.5, y - 0.5);
        let xs = dx + shear * dy;
        (0.5 + c * xs - s * dy + tx, 0.5 + s * xs + c * dy + ty)
    };
    let strokes: Vec<Vec<Pt>> =
        glyph(digit).into_iter().map(|poly| poly.into_iter().map(xform).collect()).collect();

    let sigma = 0.035f32 * rng.range_f64(0.85, 1.25) as f32;
    let inv2s2 = 1.0 / (2.0 * sigma * sigma);
    for iy in 0..MNIST_SIDE {
        for ix in 0..MNIST_SIDE {
            let p = ((ix as f32 + 0.5) / MNIST_SIDE as f32, (iy as f32 + 0.5) / MNIST_SIDE as f32);
            let mut dmin = f32::INFINITY;
            for poly in &strokes {
                for w in poly.windows(2) {
                    dmin = dmin.min(seg_dist(p, w[0], w[1]));
                }
            }
            let v = (-dmin * dmin * inv2s2).exp();
            let noise = rng.normal_f32(0.04);
            out[iy * MNIST_SIDE + ix] = (v + noise).clamp(0.0, 1.0);
        }
    }
}

/// Generate `n` samples (labels uniform over classes, deterministic seed).
pub fn synth_mnist(n: usize, seed: u64) -> Result<Dataset> {
    let mut rng = Rng::new(seed ^ 0x6d6e_6973_745f_3332);
    let mut data = vec![0.0f32; n * MNIST_DIM];
    let mut labels = Vec::with_capacity(n);
    for (i, chunk) in data.chunks_mut(MNIST_DIM).enumerate() {
        let digit = if i < MNIST_CLASSES { i } else { rng.below(MNIST_CLASSES) };
        render(digit, &mut rng, chunk);
        labels.push(digit);
    }
    Dataset::new(Tensor::from_vec(&[n, MNIST_DIM], data)?, labels, MNIST_CLASSES)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_determinism() {
        let a = synth_mnist(30, 1).unwrap();
        let b = synth_mnist(30, 1).unwrap();
        assert_eq!(a.x.shape(), &[30, 1024]);
        assert_eq!(a.x, b.x);
        assert_eq!(a.labels, b.labels);
        let c = synth_mnist(30, 2).unwrap();
        assert_ne!(a.x, c.x);
    }

    #[test]
    fn pixel_range() {
        let d = synth_mnist(20, 3).unwrap();
        assert!(d.x.data().iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn all_classes_present_and_distinct() {
        let d = synth_mnist(200, 4).unwrap();
        for c in 0..10 {
            assert!(d.labels.contains(&c), "class {c} missing");
        }
        // class means must differ (images carry class signal)
        let mean = |class: usize| -> Vec<f32> {
            let rows: Vec<usize> =
                (0..d.len()).filter(|&i| d.labels[i] == class).collect();
            let mut m = vec![0.0f32; MNIST_DIM];
            for &i in &rows {
                for (mm, &v) in m.iter_mut().zip(d.x.row(i)) {
                    *mm += v / rows.len() as f32;
                }
            }
            m
        };
        let m0 = mean(0);
        let m1 = mean(1);
        let dist: f32 = m0.iter().zip(&m1).map(|(a, b)| (a - b) * (a - b)).sum::<f32>().sqrt();
        assert!(dist > 1.0, "class means too close: {dist}");
    }

    #[test]
    fn intra_class_variation_exists() {
        let d = synth_mnist(50, 5).unwrap();
        let rows: Vec<usize> = (0..d.len()).filter(|&i| d.labels[i] == 7).collect();
        assert!(rows.len() >= 2);
        let a = d.x.row(rows[0]);
        let b = d.x.row(rows[1]);
        let dist: f32 = a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f32>().sqrt();
        assert!(dist > 0.1, "augmentation produced identical samples");
    }

    #[test]
    fn seg_dist_basics() {
        assert!((seg_dist((0.0, 1.0), (0.0, 0.0), (1.0, 0.0)) - 1.0).abs() < 1e-6);
        assert!(seg_dist((0.5, 0.0), (0.0, 0.0), (1.0, 0.0)) < 1e-6);
        // degenerate segment = point distance
        assert!((seg_dist((3.0, 4.0), (0.0, 0.0), (0.0, 0.0)) - 5.0).abs() < 1e-6);
    }
}
