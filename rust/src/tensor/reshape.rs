//! Index arithmetic helpers shared by the tensor / TT modules.

/// Row-major strides for a shape (last axis has stride 1).
pub fn strides_of(shape: &[usize]) -> Vec<usize> {
    let mut strides = vec![1usize; shape.len()];
    for i in (0..shape.len().saturating_sub(1)).rev() {
        strides[i] = strides[i + 1] * shape[i + 1];
    }
    strides
}

/// Linear (row-major) offset of a multi-index.
pub fn linear_index(idx: &[usize], shape: &[usize]) -> usize {
    debug_assert_eq!(idx.len(), shape.len());
    let mut lin = 0usize;
    for (i, s) in idx.iter().zip(shape) {
        debug_assert!(i < s);
        lin = lin * s + i;
    }
    lin
}

/// Multi-index of a linear (row-major) offset.
pub fn multi_index(mut lin: usize, shape: &[usize]) -> Vec<usize> {
    let mut idx = vec![0usize; shape.len()];
    for ax in (0..shape.len()).rev() {
        idx[ax] = lin % shape[ax];
        lin /= shape[ax];
    }
    debug_assert_eq!(lin, 0);
    idx
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strides_row_major() {
        assert_eq!(strides_of(&[2, 3, 4]), vec![12, 4, 1]);
        assert_eq!(strides_of(&[5]), vec![1]);
        assert!(strides_of(&[]).is_empty());
    }

    #[test]
    fn linear_multi_roundtrip() {
        let shape = [3, 4, 5];
        for lin in 0..60 {
            let idx = multi_index(lin, &shape);
            assert_eq!(linear_index(&idx, &shape), lin);
        }
    }

    #[test]
    fn linear_index_matches_strides() {
        let shape = [2, 3, 4];
        let strides = strides_of(&shape);
        let idx = [1, 2, 3];
        let by_strides: usize = idx.iter().zip(&strides).map(|(i, s)| i * s).sum();
        assert_eq!(linear_index(&idx, &shape), by_strides);
    }
}
