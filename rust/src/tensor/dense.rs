//! The dense row-major tensor.

use crate::error::{shape_err, Result};
use crate::tensor::reshape::strides_of;
use crate::util::rng::Rng;
use std::fmt;

/// Dense row-major contiguous `f32` tensor of arbitrary dimensionality.
///
/// The last axis varies fastest.  All reshapes are zero-copy (contiguity is
/// an invariant); permutations materialize a new tensor.
#[derive(Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}", self.shape)?;
        if self.data.len() <= 16 {
            write!(f, " {:?}", self.data)?;
        }
        Ok(())
    }
}

impl Tensor {
    /// All-zeros tensor of the given shape.
    pub fn zeros(shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    /// Constant-filled tensor.
    pub fn filled(shape: &[usize], v: f32) -> Self {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![v; n] }
    }

    /// Wrap an existing buffer; errors if the element count mismatches.
    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if data.len() != n {
            return shape_err(format!(
                "from_vec: {} elements for shape {:?} (need {})",
                data.len(),
                shape,
                n
            ));
        }
        Ok(Tensor { shape: shape.to_vec(), data })
    }

    /// 2-D identity matrix.
    pub fn eye(n: usize) -> Self {
        let mut t = Tensor::zeros(&[n, n]);
        for i in 0..n {
            t.data[i * n + i] = 1.0;
        }
        t
    }

    /// I.i.d. Gaussian entries with the given std (paper section 6.4 init).
    pub fn randn(shape: &[usize], std: f32, rng: &mut Rng) -> Self {
        let n: usize = shape.iter().product();
        let data = (0..n).map(|_| rng.normal_f32(std)).collect();
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Zero-copy reshape (element count must match).
    pub fn reshape(mut self, shape: &[usize]) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != self.data.len() {
            return shape_err(format!("reshape {:?} -> {:?}", self.shape, shape));
        }
        self.shape = shape.to_vec();
        Ok(self)
    }

    /// Reshape returning a new tensor (clones the buffer).
    pub fn reshaped(&self, shape: &[usize]) -> Result<Self> {
        self.clone().reshape(shape)
    }

    /// Element access by multi-index (debug/tests; hot paths use `data()`).
    pub fn at(&self, idx: &[usize]) -> f32 {
        debug_assert_eq!(idx.len(), self.shape.len());
        let strides = strides_of(&self.shape);
        let lin: usize = idx.iter().zip(&strides).map(|(i, s)| i * s).sum();
        self.data[lin]
    }

    pub fn set(&mut self, idx: &[usize], v: f32) {
        let strides = strides_of(&self.shape);
        let lin: usize = idx.iter().zip(&strides).map(|(i, s)| i * s).sum();
        self.data[lin] = v;
    }

    /// Materializing axis permutation: `out[i_perm] = self[i]`.
    pub fn permute(&self, axes: &[usize]) -> Result<Self> {
        let d = self.shape.len();
        if axes.len() != d {
            return shape_err(format!("permute axes {:?} for ndim {}", axes, d));
        }
        let mut seen = vec![false; d];
        for &a in axes {
            if a >= d || seen[a] {
                return shape_err(format!("bad permutation {:?}", axes));
            }
            seen[a] = true;
        }
        let new_shape: Vec<usize> = axes.iter().map(|&a| self.shape[a]).collect();
        let in_strides = strides_of(&self.shape);
        let out_strides = strides_of(&new_shape);
        // stride of output axis j in the INPUT buffer
        let gather: Vec<usize> = axes.iter().map(|&a| in_strides[a]).collect();
        let mut out = vec![0.0f32; self.data.len()];
        // iterate output linearly, computing source index incrementally
        let mut idx = vec![0usize; d];
        let mut src = 0usize;
        for slot in out.iter_mut() {
            *slot = self.data[src];
            // increment multi-index (row-major, last axis fastest)
            for ax in (0..d).rev() {
                idx[ax] += 1;
                src += gather[ax];
                if idx[ax] < new_shape[ax] {
                    break;
                }
                src -= gather[ax] * new_shape[ax];
                idx[ax] = 0;
            }
        }
        let _ = out_strides;
        Tensor::from_vec(&new_shape, out)
    }

    /// 2-D transpose (materializing), a common special case.
    pub fn t2(&self) -> Result<Self> {
        if self.ndim() != 2 {
            return shape_err(format!("t2 on shape {:?}", self.shape));
        }
        self.permute(&[1, 0])
    }

    /// Elementwise in-place `self += alpha * other` — the SGD/momentum
    /// update loop, routed through the dispatched axpy kernel.
    pub fn axpy(&mut self, alpha: f32, other: &Tensor) -> Result<()> {
        if self.shape != other.shape {
            return shape_err(format!("axpy {:?} vs {:?}", self.shape, other.shape));
        }
        (crate::tensor::simd::kernels().axpy)(alpha, &other.data, &mut self.data);
        Ok(())
    }

    /// In-place scaling.
    pub fn scale(&mut self, alpha: f32) {
        for a in self.data.iter_mut() {
            *a *= alpha;
        }
    }

    /// Elementwise sum returning a new tensor.
    pub fn add(&self, other: &Tensor) -> Result<Self> {
        let mut out = self.clone();
        out.axpy(1.0, other)?;
        Ok(out)
    }

    /// Elementwise (Hadamard) product.
    pub fn hadamard(&self, other: &Tensor) -> Result<Self> {
        if self.shape != other.shape {
            return shape_err(format!("hadamard {:?} vs {:?}", self.shape, other.shape));
        }
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a * b).collect();
        Tensor::from_vec(&self.shape, data)
    }

    /// Frobenius norm.
    pub fn norm(&self) -> f32 {
        self.data.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>().sqrt() as f32
    }

    /// Dot product of the flattened buffers.
    pub fn dot(&self, other: &Tensor) -> Result<f32> {
        if self.shape != other.shape {
            return shape_err(format!("dot {:?} vs {:?}", self.shape, other.shape));
        }
        Ok(self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (*a as f64) * (*b as f64))
            .sum::<f64>() as f32)
    }

    /// Max |x|.
    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, x| m.max(x.abs()))
    }

    /// Row `i` of a 2-D tensor as a slice.
    pub fn row(&self, i: usize) -> &[f32] {
        debug_assert_eq!(self.ndim(), 2);
        let cols = self.shape[1];
        &self.data[i * cols..(i + 1) * cols]
    }

    /// Copy rows `[start, end)` of a 2-D tensor.
    pub fn rows(&self, start: usize, end: usize) -> Result<Self> {
        if self.ndim() != 2 || end > self.shape[0] || start > end {
            return shape_err(format!("rows {}..{} of {:?}", start, end, self.shape));
        }
        let cols = self.shape[1];
        Tensor::from_vec(&[end - start, cols], self.data[start * cols..end * cols].to_vec())
    }

    /// Vertically stack 2-D tensors with equal column counts.
    pub fn vstack(parts: &[&Tensor]) -> Result<Self> {
        if parts.is_empty() {
            return shape_err("vstack of nothing");
        }
        let cols = parts[0].shape[1];
        let mut data = Vec::new();
        let mut rows = 0;
        for p in parts {
            if p.ndim() != 2 || p.shape[1] != cols {
                return shape_err(format!("vstack mismatch {:?}", p.shape));
            }
            rows += p.shape[0];
            data.extend_from_slice(&p.data);
        }
        Tensor::from_vec(&[rows, cols], data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_shape() {
        let t = Tensor::zeros(&[2, 3, 4]);
        assert_eq!(t.numel(), 24);
        assert_eq!(t.shape(), &[2, 3, 4]);
        assert!(t.data().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn from_vec_checks_count() {
        assert!(Tensor::from_vec(&[2, 2], vec![1.0; 3]).is_err());
        assert!(Tensor::from_vec(&[2, 2], vec![1.0; 4]).is_ok());
    }

    #[test]
    fn reshape_is_zero_copy_semantics() {
        let t = Tensor::from_vec(&[2, 6], (0..12).map(|x| x as f32).collect()).unwrap();
        let r = t.clone().reshape(&[3, 4]).unwrap();
        assert_eq!(r.data(), t.data());
        assert!(t.clone().reshape(&[5, 5]).is_err());
    }

    #[test]
    fn at_row_major() {
        let t = Tensor::from_vec(&[2, 3], vec![0., 1., 2., 3., 4., 5.]).unwrap();
        assert_eq!(t.at(&[0, 2]), 2.0);
        assert_eq!(t.at(&[1, 0]), 3.0);
    }

    #[test]
    fn permute_2d_is_transpose() {
        let t = Tensor::from_vec(&[2, 3], vec![0., 1., 2., 3., 4., 5.]).unwrap();
        let p = t.permute(&[1, 0]).unwrap();
        assert_eq!(p.shape(), &[3, 2]);
        for i in 0..2 {
            for j in 0..3 {
                assert_eq!(t.at(&[i, j]), p.at(&[j, i]));
            }
        }
    }

    #[test]
    fn permute_3d_roundtrip() {
        let mut rng = Rng::new(0);
        let t = Tensor::randn(&[3, 4, 5], 1.0, &mut rng);
        let p = t.permute(&[2, 0, 1]).unwrap();
        assert_eq!(p.shape(), &[5, 3, 4]);
        // inverse permutation of [2,0,1] is [1,2,0]
        let back = p.permute(&[1, 2, 0]).unwrap();
        assert_eq!(back, t);
        for i in 0..3 {
            for j in 0..4 {
                for k in 0..5 {
                    assert_eq!(t.at(&[i, j, k]), p.at(&[k, i, j]));
                }
            }
        }
    }

    #[test]
    fn permute_rejects_bad_axes() {
        let t = Tensor::zeros(&[2, 2]);
        assert!(t.permute(&[0, 0]).is_err());
        assert!(t.permute(&[0]).is_err());
        assert!(t.permute(&[0, 5]).is_err());
    }

    #[test]
    fn axpy_scale_norm() {
        let mut a = Tensor::filled(&[4], 1.0);
        let b = Tensor::filled(&[4], 2.0);
        a.axpy(0.5, &b).unwrap();
        assert!(a.data().iter().all(|&x| (x - 2.0).abs() < 1e-6));
        a.scale(0.5);
        assert!((a.norm() - 2.0).abs() < 1e-6);
    }

    #[test]
    fn eye_identity() {
        let e = Tensor::eye(3);
        assert_eq!(e.at(&[1, 1]), 1.0);
        assert_eq!(e.at(&[0, 1]), 0.0);
    }

    #[test]
    fn vstack_and_rows() {
        let a = Tensor::from_vec(&[1, 2], vec![1., 2.]).unwrap();
        let b = Tensor::from_vec(&[2, 2], vec![3., 4., 5., 6.]).unwrap();
        let s = Tensor::vstack(&[&a, &b]).unwrap();
        assert_eq!(s.shape(), &[3, 2]);
        assert_eq!(s.row(2), &[5., 6.]);
        let r = s.rows(1, 3).unwrap();
        assert_eq!(r.data(), &[3., 4., 5., 6.]);
    }

    #[test]
    fn randn_seeded_deterministic() {
        let mut r1 = Rng::new(7);
        let mut r2 = Rng::new(7);
        let a = Tensor::randn(&[8], 1.0, &mut r1);
        let b = Tensor::randn(&[8], 1.0, &mut r2);
        assert_eq!(a, b);
    }
}
