//! Explicit SIMD kernel layer — the three inner primitives every GEMM in
//! this crate reduces to, with runtime-dispatched AVX2+FMA implementations
//! and the previous auto-vectorized scalar code as the portable fallback.
//!
//! Dispatch happens ONCE per process: [`kernels`] consults
//! `is_x86_feature_detected!` (and the `TENSORNET_SIMD` env override) on
//! first use and caches a `&'static Kernels` vtable.  The hot loops then
//! call through plain `fn` pointers — no per-call feature checks, no
//! generics explosion, and the scalar path stays byte-for-byte the code
//! that shipped before this layer existed (so `TENSORNET_SIMD=off` is a
//! true A/B switch, not a third variant).
//!
//! Soundness of the `unsafe` here: the `#[target_feature(enable =
//! "avx2,fma")]` functions are only ever reachable through the [`AVX2`]
//! vtable, and that vtable is only ever returned by [`select_kernels`]
//! after `is_x86_feature_detected!("avx2")` && `("fma")` both passed on
//! this CPU.  The safe wrappers additionally `debug_assert` the length
//! contracts; all loads/stores are unaligned (`loadu`/`storeu`), so no
//! alignment is assumed.
//!
//! Accuracy note: the AVX2 `dot`/`dot4` sum in a different association
//! order than the scalar `[f32; 8]` lane accumulators (8-lane vector
//! accumulators + a horizontal reduction), so results differ from the
//! scalar path in the low bits — tests compare within 1e-4 relative
//! tolerance.  Each path on its own is deterministic run-to-run: the
//! reduction order is fixed by the code, not by thread scheduling.

use std::sync::OnceLock;

/// Function-pointer vtable over the inner kernels.  One static instance
/// exists per implementation; the hot paths hold `&'static Kernels`.
#[derive(Debug)]
pub struct Kernels {
    /// implementation name, recorded in bench provenance
    /// (`"avx2+fma"` or `"scalar"`)
    pub name: &'static str,
    /// `Σ a[i]·b[i]` — requires `a.len() == b.len()`.
    pub dot: fn(&[f32], &[f32]) -> f32,
    /// `y[i] += alpha · x[i]` — requires `x.len() == y.len()`.
    pub axpy: fn(f32, &[f32], &mut [f32]),
    /// Four simultaneous dots sharing one `x` load:
    /// `[x·y0, x·y1, x·y2, x·y3]` — all five slices the same length.
    /// This is the multi-row micro-kernel: in `matmul_bt` it computes 4
    /// output columns per A-row sweep (generic path) or 4 output rows
    /// per B-row sweep (k-blocked path), quartering the x-side traffic.
    pub dot4: fn(&[f32], &[f32], &[f32], &[f32], &[f32]) -> [f32; 4],
}

// ---------------------------------------------------------------- scalar

/// Lane-accumulator dot product: the `[f32; 8]` accumulator array is the
/// shape LLVM reliably auto-vectorizes into SIMD FMAs, and it also breaks
/// the serial FP dependency chain (perf pass iterations #1/#4).  This is
/// the pre-SIMD-layer `dot_unrolled`, unchanged, now serving as the
/// portable fallback.
#[inline]
pub fn dot_scalar(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; 8];
    let a8 = a.chunks_exact(8);
    let b8 = b.chunks_exact(8);
    let tail_a = a8.remainder();
    let tail_b = b8.remainder();
    for (ca, cb) in a8.zip(b8) {
        for l in 0..8 {
            acc[l] += ca[l] * cb[l];
        }
    }
    let mut tail = 0.0f32;
    for (x, y) in tail_a.iter().zip(tail_b) {
        tail += x * y;
    }
    acc.iter().sum::<f32>() + tail
}

#[inline]
fn axpy_scalar(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (o, &v) in y.iter_mut().zip(x) {
        *o += alpha * v;
    }
}

/// Scalar dot4 delegates to four plain dots, so with `TENSORNET_SIMD=off`
/// every result is arithmetically identical to the pre-SIMD-layer code
/// path (same per-column `dot_unrolled` sums, just grouped by four).
#[inline]
fn dot4_scalar(x: &[f32], y0: &[f32], y1: &[f32], y2: &[f32], y3: &[f32]) -> [f32; 4] {
    [dot_scalar(x, y0), dot_scalar(x, y1), dot_scalar(x, y2), dot_scalar(x, y3)]
}

static SCALAR: Kernels =
    Kernels { name: "scalar", dot: dot_scalar, axpy: axpy_scalar, dot4: dot4_scalar };

// ------------------------------------------------------------- avx2+fma

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use std::arch::x86_64::*;

    /// Horizontal sum of an 8-lane accumulator (fixed reduction order —
    /// deterministic run-to-run).
    #[inline]
    unsafe fn hsum256(v: __m256) -> f32 {
        let hi = _mm256_extractf128_ps(v, 1);
        let s = _mm_add_ps(_mm256_castps256_ps128(v), hi);
        let s = _mm_add_ps(s, _mm_movehl_ps(s, s));
        let s = _mm_add_ss(s, _mm_shuffle_ps(s, s, 1));
        _mm_cvtss_f32(s)
    }

    /// 4 independent 8-lane FMA accumulators (32 floats per iteration)
    /// keep the FMA pipeline full; an 8-wide cleanup loop, then a scalar
    /// tail for the last `len % 8` elements.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len();
        let (ap, bp) = (a.as_ptr(), b.as_ptr());
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let mut acc2 = _mm256_setzero_ps();
        let mut acc3 = _mm256_setzero_ps();
        let mut i = 0;
        while i + 32 <= n {
            acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(ap.add(i)), _mm256_loadu_ps(bp.add(i)), acc0);
            acc1 = _mm256_fmadd_ps(
                _mm256_loadu_ps(ap.add(i + 8)),
                _mm256_loadu_ps(bp.add(i + 8)),
                acc1,
            );
            acc2 = _mm256_fmadd_ps(
                _mm256_loadu_ps(ap.add(i + 16)),
                _mm256_loadu_ps(bp.add(i + 16)),
                acc2,
            );
            acc3 = _mm256_fmadd_ps(
                _mm256_loadu_ps(ap.add(i + 24)),
                _mm256_loadu_ps(bp.add(i + 24)),
                acc3,
            );
            i += 32;
        }
        while i + 8 <= n {
            acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(ap.add(i)), _mm256_loadu_ps(bp.add(i)), acc0);
            i += 8;
        }
        let mut sum =
            hsum256(_mm256_add_ps(_mm256_add_ps(acc0, acc1), _mm256_add_ps(acc2, acc3)));
        while i < n {
            sum += *ap.add(i) * *bp.add(i);
            i += 1;
        }
        sum
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
        let n = x.len();
        let va = _mm256_set1_ps(alpha);
        let xp = x.as_ptr();
        let yp = y.as_mut_ptr();
        let mut i = 0;
        while i + 16 <= n {
            let y0 = _mm256_fmadd_ps(va, _mm256_loadu_ps(xp.add(i)), _mm256_loadu_ps(yp.add(i)));
            let y1 = _mm256_fmadd_ps(
                va,
                _mm256_loadu_ps(xp.add(i + 8)),
                _mm256_loadu_ps(yp.add(i + 8)),
            );
            _mm256_storeu_ps(yp.add(i), y0);
            _mm256_storeu_ps(yp.add(i + 8), y1);
            i += 16;
        }
        while i + 8 <= n {
            let y0 = _mm256_fmadd_ps(va, _mm256_loadu_ps(xp.add(i)), _mm256_loadu_ps(yp.add(i)));
            _mm256_storeu_ps(yp.add(i), y0);
            i += 8;
        }
        while i < n {
            *yp.add(i) += alpha * *xp.add(i);
            i += 1;
        }
    }

    /// One `x` load feeds four row accumulators: 4 dots for the memory
    /// traffic of ~1.25.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn dot4(x: &[f32], y0: &[f32], y1: &[f32], y2: &[f32], y3: &[f32]) -> [f32; 4] {
        let n = x.len();
        let xp = x.as_ptr();
        let (p0, p1, p2, p3) = (y0.as_ptr(), y1.as_ptr(), y2.as_ptr(), y3.as_ptr());
        let mut a0 = _mm256_setzero_ps();
        let mut a1 = _mm256_setzero_ps();
        let mut a2 = _mm256_setzero_ps();
        let mut a3 = _mm256_setzero_ps();
        let mut i = 0;
        while i + 8 <= n {
            let vx = _mm256_loadu_ps(xp.add(i));
            a0 = _mm256_fmadd_ps(vx, _mm256_loadu_ps(p0.add(i)), a0);
            a1 = _mm256_fmadd_ps(vx, _mm256_loadu_ps(p1.add(i)), a1);
            a2 = _mm256_fmadd_ps(vx, _mm256_loadu_ps(p2.add(i)), a2);
            a3 = _mm256_fmadd_ps(vx, _mm256_loadu_ps(p3.add(i)), a3);
            i += 8;
        }
        let mut out = [hsum256(a0), hsum256(a1), hsum256(a2), hsum256(a3)];
        while i < n {
            let xv = *xp.add(i);
            out[0] += xv * *p0.add(i);
            out[1] += xv * *p1.add(i);
            out[2] += xv * *p2.add(i);
            out[3] += xv * *p3.add(i);
            i += 1;
        }
        out
    }
}

// Safe wrappers: only reachable through the AVX2 vtable, which only
// exists in the dispatch table after runtime detection succeeded.
#[cfg(target_arch = "x86_64")]
fn dot_avx2(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    unsafe { avx2::dot(a, b) }
}

#[cfg(target_arch = "x86_64")]
fn axpy_avx2(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    unsafe { avx2::axpy(alpha, x, y) }
}

#[cfg(target_arch = "x86_64")]
fn dot4_avx2(x: &[f32], y0: &[f32], y1: &[f32], y2: &[f32], y3: &[f32]) -> [f32; 4] {
    debug_assert!(
        x.len() == y0.len() && x.len() == y1.len() && x.len() == y2.len() && x.len() == y3.len()
    );
    unsafe { avx2::dot4(x, y0, y1, y2, y3) }
}

#[cfg(target_arch = "x86_64")]
static AVX2: Kernels =
    Kernels { name: "avx2+fma", dot: dot_avx2, axpy: axpy_avx2, dot4: dot4_avx2 };

// -------------------------------------------------------------- dispatch

/// The scalar vtable — the portable fallback, always available.
pub fn scalar_kernels() -> &'static Kernels {
    &SCALAR
}

/// The best vtable this CPU supports, or `None` when nothing beyond the
/// scalar fallback is available (non-x86, or x86 without AVX2/FMA).
/// Parity tests use this to exercise the SIMD path explicitly even when
/// the process-wide selection was overridden to scalar.
pub fn detected_kernels() -> Option<&'static Kernels> {
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
            return Some(&AVX2);
        }
    }
    None
}

/// Pure selection logic, unit-testable without touching the process env:
/// `env` is the value of `TENSORNET_SIMD` (if set).  `off` / `scalar` /
/// `0` force the fallback; anything else (including unset) takes the
/// best detected implementation.
pub fn select_kernels(env: Option<&str>) -> &'static Kernels {
    match env.map(str::trim) {
        Some(v) if v.eq_ignore_ascii_case("off")
            || v.eq_ignore_ascii_case("scalar")
            || v == "0" =>
        {
            &SCALAR
        }
        _ => detected_kernels().unwrap_or(&SCALAR),
    }
}

/// The process-wide kernel vtable: detected once (honoring
/// `TENSORNET_SIMD`), then cached for the life of the process.  Hot
/// paths call this per GEMM, not per element — it's one atomic load
/// after initialization.
pub fn kernels() -> &'static Kernels {
    static SELECTED: OnceLock<&'static Kernels> = OnceLock::new();
    SELECTED.get_or_init(|| select_kernels(std::env::var("TENSORNET_SIMD").ok().as_deref()))
}

/// Name of the selected implementation (`"avx2+fma"` | `"scalar"`) —
/// recorded in `BENCH_*.json` entries so the perf trajectory is
/// comparable across machines.
pub fn simd_name() -> &'static str {
    kernels().name
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal_f32(1.0)).collect()
    }

    fn assert_close(a: f32, b: f32, what: &str) {
        assert!(
            (a - b).abs() <= 1e-4 * (1.0 + a.abs().max(b.abs())),
            "{what}: {a} vs {b}"
        );
    }

    // lengths hitting every loop shape: empty, pure tail, one 8-lane
    // block, 16/32 boundaries, and odd tails on top of full blocks
    const LENS: &[usize] = &[0, 1, 2, 5, 7, 8, 9, 15, 16, 17, 31, 32, 33, 100, 257];

    #[test]
    fn scalar_dot_matches_naive() {
        let mut rng = Rng::new(11);
        for &n in LENS {
            let a = randv(&mut rng, n);
            let b = randv(&mut rng, n);
            let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert_close(dot_scalar(&a, &b), naive, "dot_scalar");
        }
    }

    #[test]
    fn scalar_axpy_matches_naive() {
        let mut rng = Rng::new(12);
        for &n in LENS {
            let x = randv(&mut rng, n);
            let mut y = randv(&mut rng, n);
            let want: Vec<f32> = y.iter().zip(&x).map(|(yv, xv)| yv + 2.5 * xv).collect();
            axpy_scalar(2.5, &x, &mut y);
            for (g, w) in y.iter().zip(&want) {
                assert_close(*g, *w, "axpy_scalar");
            }
        }
    }

    #[test]
    fn detected_kernels_match_scalar_within_tolerance() {
        // on a CPU without AVX2 this trivially skips — the CI x86 runners
        // all have it, and the proptests exercise the same parity harder
        let Some(simd) = detected_kernels() else { return };
        let mut rng = Rng::new(13);
        for &n in LENS {
            let x = randv(&mut rng, n);
            let ys: Vec<Vec<f32>> = (0..4).map(|_| randv(&mut rng, n)).collect();
            assert_close((simd.dot)(&x, &ys[0]), dot_scalar(&x, &ys[0]), "dot");
            let d4 = (simd.dot4)(&x, &ys[0], &ys[1], &ys[2], &ys[3]);
            let d4s = dot4_scalar(&x, &ys[0], &ys[1], &ys[2], &ys[3]);
            for (g, w) in d4.iter().zip(&d4s) {
                assert_close(*g, *w, "dot4");
            }
            let mut y_simd = ys[0].clone();
            let mut y_scal = ys[0].clone();
            (simd.axpy)(-1.75, &x, &mut y_simd);
            axpy_scalar(-1.75, &x, &mut y_scal);
            for (g, w) in y_simd.iter().zip(&y_scal) {
                assert_close(*g, *w, "axpy");
            }
        }
    }

    #[test]
    fn each_path_is_deterministic() {
        let mut rng = Rng::new(14);
        let a = randv(&mut rng, 1000);
        let b = randv(&mut rng, 1000);
        for k in [Some(scalar_kernels()), detected_kernels()].into_iter().flatten() {
            let first = (k.dot)(&a, &b);
            for _ in 0..3 {
                assert_eq!((k.dot)(&a, &b).to_bits(), first.to_bits(), "{}", k.name);
            }
        }
    }

    #[test]
    fn select_off_forces_scalar() {
        // the satellite contract: TENSORNET_SIMD=off selects the scalar
        // path regardless of what the CPU supports
        for v in ["off", "OFF", " off ", "scalar", "0"] {
            assert_eq!(select_kernels(Some(v)).name, "scalar", "{v:?}");
        }
        // unset / unrecognized values take the detected best
        let best = detected_kernels().unwrap_or(scalar_kernels()).name;
        assert_eq!(select_kernels(None).name, best);
        assert_eq!(select_kernels(Some("on")).name, best);
    }

    #[test]
    fn process_selection_honors_env() {
        // `kernels()` caches on first use, so this asserts against the
        // env as it was at selection time.  Under the CI
        // `TENSORNET_SIMD=off` run this pins the scalar path end-to-end;
        // in the default run it pins detection.
        let want = select_kernels(std::env::var("TENSORNET_SIMD").ok().as_deref());
        assert_eq!(kernels().name, want.name);
        assert_eq!(simd_name(), want.name);
    }
}
