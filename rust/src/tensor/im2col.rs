//! im2col / col2im lowering for convolutions (Garipov et al. 2016, §3:
//! a conv is a GEMM over patch rows once the input is unrolled).
//!
//! Layout conventions (row-major everywhere, matching the rest of the
//! tensor module):
//!   * an image batch is `(B, C*H*W)` with channel-major samples, i.e.
//!     sample index `c*(H*W) + y*W + x`;
//!   * the unrolled patch matrix is `(B*Ho*Wo, C*kh*kw)` with row index
//!     `(b*Ho + oy)*Wo + ox` and column index `(c*kh + u)*kw + v`.
//!
//! With that column order, a conv kernel `(c_out, c_in, kh, kw)` flattens
//! row-major into a `(c_out, c_in*kh*kw)` matrix whose columns line up
//! with the patch columns — the conv is then `cols · Wᵀ`, riding the same
//! `Gemm`/SIMD kernels as every dense layer.

use crate::error::{shape_err, Result};
use crate::tensor::Tensor;

/// Output spatial extent of a 1-D convolution: `(n + 2*pad - k)/stride + 1`.
pub fn conv_out_dim(n: usize, k: usize, stride: usize, pad: usize) -> Result<usize> {
    if k == 0 || stride == 0 {
        return shape_err(format!("conv_out_dim: zero kernel ({k}) or stride ({stride})"));
    }
    if n + 2 * pad < k {
        return shape_err(format!(
            "conv_out_dim: kernel {k} larger than padded input {n}+2*{pad}"
        ));
    }
    Ok((n + 2 * pad - k) / stride + 1)
}

/// Unroll `x (B, C*H*W)` into the patch matrix `(B*Ho*Wo, C*kh*kw)`.
/// Out-of-bounds taps (from zero padding) contribute zeros.
#[allow(clippy::too_many_arguments)]
pub fn im2col(
    x: &Tensor,
    c: usize,
    h: usize,
    w: usize,
    kh: usize,
    kw: usize,
    stride: usize,
    pad: usize,
) -> Result<Tensor> {
    if x.ndim() != 2 || x.shape()[1] != c * h * w {
        return shape_err(format!(
            "im2col: want (B, {}), got {:?}",
            c * h * w,
            x.shape()
        ));
    }
    let b = x.shape()[0];
    let ho = conv_out_dim(h, kh, stride, pad)?;
    let wo = conv_out_dim(w, kw, stride, pad)?;
    let patch = c * kh * kw;
    let mut out = vec![0.0f32; b * ho * wo * patch];
    let xs = x.data();
    for bi in 0..b {
        let sample = &xs[bi * c * h * w..(bi + 1) * c * h * w];
        for oy in 0..ho {
            for ox in 0..wo {
                let row = ((bi * ho + oy) * wo + ox) * patch;
                for ci in 0..c {
                    let chan = &sample[ci * h * w..(ci + 1) * h * w];
                    for u in 0..kh {
                        let iy = (oy * stride + u) as isize - pad as isize;
                        if iy < 0 || iy >= h as isize {
                            continue; // padding row: stays zero
                        }
                        let src = iy as usize * w;
                        let dst = row + (ci * kh + u) * kw;
                        for v in 0..kw {
                            let ix = (ox * stride + v) as isize - pad as isize;
                            if ix >= 0 && ix < w as isize {
                                out[dst + v] = chan[src + ix as usize];
                            }
                        }
                    }
                }
            }
        }
    }
    Tensor::from_vec(&[b * ho * wo, patch], out)
}

/// Adjoint of [`im2col`]: scatter-add the patch-matrix gradient
/// `cols (B*Ho*Wo, C*kh*kw)` back onto the image layout `(B, C*H*W)`.
/// Taps that fell in the zero padding are discarded.
#[allow(clippy::too_many_arguments)]
pub fn col2im(
    cols: &Tensor,
    b: usize,
    c: usize,
    h: usize,
    w: usize,
    kh: usize,
    kw: usize,
    stride: usize,
    pad: usize,
) -> Result<Tensor> {
    let ho = conv_out_dim(h, kh, stride, pad)?;
    let wo = conv_out_dim(w, kw, stride, pad)?;
    let patch = c * kh * kw;
    if cols.ndim() != 2 || cols.shape() != [b * ho * wo, patch] {
        return shape_err(format!(
            "col2im: want ({}, {}), got {:?}",
            b * ho * wo,
            patch,
            cols.shape()
        ));
    }
    let mut out = vec![0.0f32; b * c * h * w];
    let cs = cols.data();
    for bi in 0..b {
        let sample = &mut out[bi * c * h * w..(bi + 1) * c * h * w];
        for oy in 0..ho {
            for ox in 0..wo {
                let row = ((bi * ho + oy) * wo + ox) * patch;
                for ci in 0..c {
                    let chan = &mut sample[ci * h * w..(ci + 1) * h * w];
                    for u in 0..kh {
                        let iy = (oy * stride + u) as isize - pad as isize;
                        if iy < 0 || iy >= h as isize {
                            continue;
                        }
                        let dst = iy as usize * w;
                        let src = row + (ci * kh + u) * kw;
                        for v in 0..kw {
                            let ix = (ox * stride + v) as isize - pad as isize;
                            if ix >= 0 && ix < w as isize {
                                chan[dst + ix as usize] += cs[src + v];
                            }
                        }
                    }
                }
            }
        }
    }
    Tensor::from_vec(&[b, c * h * w], out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn out_dim_formula() {
        assert_eq!(conv_out_dim(32, 3, 2, 1).unwrap(), 16);
        assert_eq!(conv_out_dim(5, 3, 1, 0).unwrap(), 3);
        assert_eq!(conv_out_dim(4, 1, 1, 0).unwrap(), 4);
        assert!(conv_out_dim(2, 5, 1, 0).is_err());
        assert!(conv_out_dim(4, 3, 0, 0).is_err());
    }

    #[test]
    fn identity_kernel_reproduces_input() {
        // 1x1 kernel, stride 1, no padding: im2col is the identity on
        // each sample up to a (spatial, channel) transpose of the layout
        let mut rng = Rng::new(3);
        let (c, h, w) = (2, 3, 4);
        let x = Tensor::randn(&[2, c * h * w], 1.0, &mut rng);
        let cols = im2col(&x, c, h, w, 1, 1, 1, 0).unwrap();
        assert_eq!(cols.shape(), [2 * h * w, c]);
        for bi in 0..2 {
            for y in 0..h {
                for xx in 0..w {
                    for ci in 0..c {
                        assert_eq!(
                            cols.at(&[(bi * h + y) * w + xx, ci]),
                            x.at(&[bi, ci * h * w + y * w + xx])
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn padding_taps_are_zero() {
        let x = Tensor::filled(&[1, 4], 1.0); // 1 channel, 2x2, all ones
        let cols = im2col(&x, 1, 2, 2, 3, 3, 1, 1).unwrap();
        assert_eq!(cols.shape(), [4, 9]);
        // top-left output: only taps (1,1),(1,2),(2,1),(2,2) land in-bounds
        let r = cols.row(0);
        let want = [0.0, 0.0, 0.0, 0.0, 1.0, 1.0, 0.0, 1.0, 1.0];
        assert_eq!(r, want);
    }

    #[test]
    fn col2im_is_the_adjoint_of_im2col() {
        // <im2col(x), y> == <x, col2im(y)> for random x, y — the defining
        // property the conv backward pass relies on
        let mut rng = Rng::new(7);
        let (b, c, h, w, kh, kw, stride, pad) = (2, 3, 5, 4, 3, 2, 2, 1);
        let x = Tensor::randn(&[b, c * h * w], 1.0, &mut rng);
        let cols = im2col(&x, c, h, w, kh, kw, stride, pad).unwrap();
        let y = Tensor::randn(cols.shape(), 1.0, &mut rng);
        let back = col2im(&y, b, c, h, w, kh, kw, stride, pad).unwrap();
        let lhs = cols.dot(&y).unwrap() as f64;
        let rhs = x.dot(&back).unwrap() as f64;
        assert!(
            (lhs - rhs).abs() < 1e-3 * (1.0 + lhs.abs()),
            "adjoint identity violated: {lhs} vs {rhs}"
        );
    }

    #[test]
    fn rejects_bad_shapes() {
        let x = Tensor::zeros(&[2, 10]);
        assert!(im2col(&x, 1, 3, 3, 2, 2, 1, 0).is_err()); // 10 != 9
        let cols = Tensor::zeros(&[3, 4]);
        assert!(col2im(&cols, 1, 1, 3, 3, 2, 2, 1, 0).is_err());
    }
}
