//! Blocked, parallel GEMM — the native hot path.
//!
//! Three variants avoid materializing transposes in the backward pass:
//! `matmul` (A·B), `matmul_at` (Aᵀ·B), `matmul_bt` (A·Bᵀ).  Every inner
//! loop is one of the three [`crate::tensor::simd`] primitives — `axpy`
//! along contiguous rows of B / the output (the classic i-k-j kernel),
//! `dot`/`dot4` along contraction rows for the Bᵀ shapes — dispatched
//! once per process to AVX2+FMA or the scalar fallback.  Parallelism is
//! over output row chunks, bounded by the caller's
//! [`crate::util::threads::thread_budget`]; small problems stay
//! single-threaded (threshold tuned in the perf pass, see EXPERIMENTS.md
//! §Perf).

use crate::error::{shape_err, Result};
use crate::tensor::simd::kernels;
use crate::tensor::Tensor;
use crate::util::threads::{parallel_chunks_mut, thread_budget};

/// GEMM engine with tuning knobs (shared defaults via free functions).
#[derive(Clone, Copy, Debug)]
pub struct Gemm {
    /// Minimum FLOP count (2·m·k·n) before rayon kicks in.
    pub par_flops: usize,
    /// Row-chunk granularity for parallel dispatch.
    pub chunk_rows: usize,
}

impl Default for Gemm {
    fn default() -> Self {
        Gemm { par_flops: 1 << 20, chunk_rows: 16 }
    }
}

impl Gemm {
    fn check2(a: &Tensor, b: &Tensor) -> Result<()> {
        if a.ndim() != 2 || b.ndim() != 2 {
            return shape_err(format!("gemm needs 2-D, got {:?} x {:?}", a.shape(), b.shape()));
        }
        Ok(())
    }

    /// `C = A · B` for A:(m,k), B:(k,n).
    pub fn matmul(&self, a: &Tensor, b: &Tensor) -> Result<Tensor> {
        let mut out = Vec::new();
        self.matmul_into(a, b, &mut out)?;
        Tensor::from_vec(&[a.shape()[0], b.shape()[1]], out)
    }

    /// [`Gemm::matmul`] into a caller-owned buffer (cleared, resized,
    /// capacity retained across calls) — the serving hot path uses this to
    /// stay allocation-free in steady state ([`crate::tt::MatvecScratch`]).
    pub fn matmul_into(&self, a: &Tensor, b: &Tensor, out: &mut Vec<f32>) -> Result<()> {
        Self::check2(a, b)?;
        let (m, k) = (a.shape()[0], a.shape()[1]);
        let (k2, n) = (b.shape()[0], b.shape()[1]);
        if k != k2 {
            return shape_err(format!("matmul {:?} x {:?}", a.shape(), b.shape()));
        }
        out.clear();
        out.resize(m * n, 0.0);
        // degenerate dims: the product is all-zeros (or empty); the kernel
        // below would call `chunks_mut(0)` and panic when n == 0
        if m == 0 || n == 0 || k == 0 {
            return Ok(());
        }
        let ad = a.data();
        let bd = b.data();
        let kern = kernels();
        let kernel = |i0: usize, rows: &mut [f32]| {
            for (di, orow) in rows.chunks_mut(n).enumerate() {
                let i = i0 + di;
                let arow = &ad[i * k..(i + 1) * k];
                for (kk, &aik) in arow.iter().enumerate() {
                    if aik != 0.0 {
                        (kern.axpy)(aik, &bd[kk * n..(kk + 1) * n], orow);
                    }
                }
            }
        };
        let big = 2 * m * k * n >= self.par_flops;
        if big && m >= 2 * thread_budget() {
            // row-parallel with adaptive granularity
            let cr = (m / (thread_budget() * 4)).clamp(1, self.chunk_rows.max(1));
            parallel_chunks_mut(&mut out[..], cr * n, |start, rows| {
                kernel(start / n, rows);
            });
        } else if big && m == 1 && n >= 64 {
            // batch-1 case (Table 3): parallelize over COLUMN blocks of the
            // single output row — perf pass iteration #2
            let cb = (n / thread_budget()).max(32);
            let arow = &ad[..k];
            parallel_chunks_mut(&mut out[..], cb, |col0, cols| {
                for (kk, &aik) in arow.iter().enumerate() {
                    if aik != 0.0 {
                        (kern.axpy)(aik, &bd[kk * n + col0..kk * n + col0 + cols.len()], cols);
                    }
                }
            });
        } else if big && m > 1 {
            // few rows: one chunk per row
            parallel_chunks_mut(&mut out[..], n, |start, rows| {
                kernel(start / n, rows);
            });
        } else {
            kernel(0, &mut out[..]);
        }
        Ok(())
    }

    /// `C = Aᵀ · B` for A:(k,m), B:(k,n) — gradient-of-weights shape.
    pub fn matmul_at(&self, a: &Tensor, b: &Tensor) -> Result<Tensor> {
        Self::check2(a, b)?;
        let (k, m) = (a.shape()[0], a.shape()[1]);
        let (k2, n) = (b.shape()[0], b.shape()[1]);
        if k != k2 {
            return shape_err(format!("matmul_at {:?} x {:?}", a.shape(), b.shape()));
        }
        let mut out = vec![0.0f32; m * n];
        // degenerate dims: all-zeros result; the kernel would panic on
        // `chunks_mut(0)` when n == 0
        if m == 0 || n == 0 || k == 0 {
            return Tensor::from_vec(&[m, n], out);
        }
        let ad = a.data();
        let bd = b.data();
        let kern = kernels();
        let kernel = |i0: usize, rows: &mut [f32]| {
            // out[i, :] = sum_k a[k, i] * b[k, :]
            for kk in 0..k {
                let brow = &bd[kk * n..(kk + 1) * n];
                let arow = &ad[kk * m..(kk + 1) * m];
                for (di, orow) in rows.chunks_mut(n).enumerate() {
                    let aki = arow[i0 + di];
                    if aki != 0.0 {
                        (kern.axpy)(aki, brow, orow);
                    }
                }
            }
        };
        if 2 * m * k * n >= self.par_flops && m > 1 {
            let cr = self.chunk_rows.max(1);
            parallel_chunks_mut(&mut out, cr * n, |start, rows| {
                kernel(start / n, rows);
            });
        } else {
            kernel(0, &mut out);
        }
        Tensor::from_vec(&[m, n], out)
    }

    /// `C = A · Bᵀ` for A:(m,k), B:(n,k) — dense-layer forward shape
    /// (weights stored (out,in), inputs (batch,in)).
    pub fn matmul_bt(&self, a: &Tensor, b: &Tensor) -> Result<Tensor> {
        Self::check2(a, b)?;
        let (m, k) = (a.shape()[0], a.shape()[1]);
        let (n, k2) = (b.shape()[0], b.shape()[1]);
        if k != k2 {
            return shape_err(format!("matmul_bt {:?} x {:?}", a.shape(), b.shape()));
        }
        let mut out = vec![0.0f32; m * n];
        // degenerate dims: all-zeros result; the kernel would panic on
        // `chunks_mut(0)` when n == 0
        if m == 0 || n == 0 || k == 0 {
            return Tensor::from_vec(&[m, n], out);
        }
        let ad = a.data();
        let bd = b.data();
        let kern = kernels();
        // k-blocked path for multi-row batches (perf pass iteration #3):
        // the naive per-row loop streams ALL of B once per output row
        // (41 GB of traffic for the Table-3 batch-100 case).  Blocking the
        // contraction axis keeps the A-panel cache-resident and streams B
        // exactly once per panel: kb -> j -> i with the dot4 micro-kernel
        // amortizing each B-row load over 4 output rows.  Parallelism is
        // over output-ROW panels (perf pass iteration #10 — this path
        // used to return before any parallel dispatch, so the Table-3
        // batch regime it was built for ran single-threaded); each panel
        // recomputes its own kc so its A-panel stays ~512 KiB.
        if m >= 8 && k >= 4096 {
            let rows_per = if 2 * m * k * n >= self.par_flops {
                m.div_ceil(thread_budget()).max(1)
            } else {
                m // one panel — parallel_chunks_mut runs it inline
            };
            parallel_chunks_mut(&mut out, rows_per * n, |start, rows| {
                let i0 = start / n;
                let mp = rows.len() / n; // whole rows: granularity is a multiple of n
                let kc = (512 * 1024 / (4 * mp)).clamp(512, k);
                for k0 in (0..k).step_by(kc) {
                    let kb = kc.min(k - k0);
                    for j in 0..n {
                        let brow = &bd[j * k + k0..j * k + k0 + kb];
                        let mut i = 0;
                        while i + 4 <= mp {
                            let base = (i0 + i) * k + k0;
                            let d = (kern.dot4)(
                                brow,
                                &ad[base..base + kb],
                                &ad[base + k..base + k + kb],
                                &ad[base + 2 * k..base + 2 * k + kb],
                                &ad[base + 3 * k..base + 3 * k + kb],
                            );
                            rows[i * n + j] += d[0];
                            rows[(i + 1) * n + j] += d[1];
                            rows[(i + 2) * n + j] += d[2];
                            rows[(i + 3) * n + j] += d[3];
                            i += 4;
                        }
                        while i < mp {
                            let arow = &ad[(i0 + i) * k + k0..(i0 + i) * k + k0 + kb];
                            rows[i * n + j] += (kern.dot)(arow, brow);
                            i += 1;
                        }
                    }
                }
            });
            return Tensor::from_vec(&[m, n], out);
        }
        let kernel = |i0: usize, rows: &mut [f32]| {
            for (di, orow) in rows.chunks_mut(n).enumerate() {
                let arow = &ad[(i0 + di) * k..(i0 + di + 1) * k];
                let mut j = 0;
                while j + 4 <= n {
                    let d = (kern.dot4)(
                        arow,
                        &bd[j * k..(j + 1) * k],
                        &bd[(j + 1) * k..(j + 2) * k],
                        &bd[(j + 2) * k..(j + 3) * k],
                        &bd[(j + 3) * k..(j + 4) * k],
                    );
                    orow[j..j + 4].copy_from_slice(&d);
                    j += 4;
                }
                while j < n {
                    orow[j] = (kern.dot)(arow, &bd[j * k..(j + 1) * k]);
                    j += 1;
                }
            }
        };
        let big = 2 * m * k * n >= self.par_flops;
        if big && m >= 2 * thread_budget() {
            let cr = (m / (thread_budget() * 4)).clamp(1, self.chunk_rows.max(1));
            parallel_chunks_mut(&mut out, cr * n, |start, rows| {
                kernel(start / n, rows);
            });
        } else if big && m == 1 && n >= 2 {
            // batch-1 inference: each output column is an independent dot
            // against a row of B — parallelize over column blocks
            let cb = (n / thread_budget()).max(16);
            let arow = &ad[..k];
            parallel_chunks_mut(&mut out, cb, |col0, cols| {
                let nc = cols.len();
                let mut dj = 0;
                while dj + 4 <= nc {
                    let j = col0 + dj;
                    let d = (kern.dot4)(
                        arow,
                        &bd[j * k..(j + 1) * k],
                        &bd[(j + 1) * k..(j + 2) * k],
                        &bd[(j + 2) * k..(j + 3) * k],
                        &bd[(j + 3) * k..(j + 4) * k],
                    );
                    cols[dj..dj + 4].copy_from_slice(&d);
                    dj += 4;
                }
                while dj < nc {
                    let j = col0 + dj;
                    cols[dj] = (kern.dot)(arow, &bd[j * k..(j + 1) * k]);
                    dj += 1;
                }
            });
        } else if big && m > 1 {
            parallel_chunks_mut(&mut out, n, |start, rows| {
                kernel(start / n, rows);
            });
        } else {
            kernel(0, &mut out);
        }
        Tensor::from_vec(&[m, n], out)
    }
}

/// `A · B` with default tuning.
pub fn matmul(a: &Tensor, b: &Tensor) -> Result<Tensor> {
    Gemm::default().matmul(a, b)
}

/// `Aᵀ · B` with default tuning.
pub fn matmul_at(a: &Tensor, b: &Tensor) -> Result<Tensor> {
    Gemm::default().matmul_at(a, b)
}

/// `A · Bᵀ` with default tuning.
pub fn matmul_bt(a: &Tensor, b: &Tensor) -> Result<Tensor> {
    Gemm::default().matmul_bt(a, b)
}

/// Matrix-vector product `A · x` for A:(m,n), x:(n,).
pub fn matvec(a: &Tensor, x: &Tensor) -> Result<Tensor> {
    if a.ndim() != 2 || x.ndim() != 1 || a.shape()[1] != x.shape()[0] {
        return shape_err(format!("matvec {:?} x {:?}", a.shape(), x.shape()));
    }
    let (m, n) = (a.shape()[0], a.shape()[1]);
    let ad = a.data();
    let xd = x.data();
    let kern = kernels();
    let out: Vec<f32> = (0..m).map(|i| (kern.dot)(&ad[i * n..(i + 1) * n], xd)).collect();
    Tensor::from_vec(&[m], out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn naive(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k) = (a.shape()[0], a.shape()[1]);
        let n = b.shape()[1];
        let mut out = Tensor::zeros(&[m, n]);
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0;
                for kk in 0..k {
                    acc += a.at(&[i, kk]) * b.at(&[kk, j]);
                }
                out.set(&[i, j], acc);
            }
        }
        out
    }

    fn close(a: &Tensor, b: &Tensor, tol: f32) {
        assert_eq!(a.shape(), b.shape());
        for (x, y) in a.data().iter().zip(b.data()) {
            assert!((x - y).abs() <= tol * (1.0 + x.abs().max(y.abs())), "{x} vs {y}");
        }
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Rng::new(1);
        for &(m, k, n) in &[(1, 1, 1), (3, 4, 5), (17, 9, 23), (64, 64, 64)] {
            let a = Tensor::randn(&[m, k], 1.0, &mut rng);
            let b = Tensor::randn(&[k, n], 1.0, &mut rng);
            close(&matmul(&a, &b).unwrap(), &naive(&a, &b), 1e-5);
        }
    }

    #[test]
    fn matmul_at_matches_transpose() {
        let mut rng = Rng::new(2);
        let a = Tensor::randn(&[11, 7], 1.0, &mut rng);
        let b = Tensor::randn(&[11, 13], 1.0, &mut rng);
        let want = matmul(&a.t2().unwrap(), &b).unwrap();
        close(&matmul_at(&a, &b).unwrap(), &want, 1e-5);
    }

    #[test]
    fn matmul_bt_matches_transpose() {
        let mut rng = Rng::new(3);
        let a = Tensor::randn(&[9, 6], 1.0, &mut rng);
        let b = Tensor::randn(&[14, 6], 1.0, &mut rng);
        let want = matmul(&a, &b.t2().unwrap()).unwrap();
        close(&matmul_bt(&a, &b).unwrap(), &want, 1e-5);
    }

    #[test]
    fn parallel_path_matches_serial() {
        let mut rng = Rng::new(4);
        let a = Tensor::randn(&[300, 120], 1.0, &mut rng);
        let b = Tensor::randn(&[120, 250], 1.0, &mut rng);
        let par = Gemm { par_flops: 0, chunk_rows: 7 }; // force parallel, odd chunks
        let ser = Gemm { par_flops: usize::MAX, chunk_rows: 16 };
        close(&par.matmul(&a, &b).unwrap(), &ser.matmul(&a, &b).unwrap(), 1e-5);
        // a^T b needs equal FIRST dims: (300,120)^T x (300,250)
        let b2 = Tensor::randn(&[300, 250], 1.0, &mut rng);
        close(&par.matmul_at(&a, &b2).unwrap(), &ser.matmul_at(&a, &b2).unwrap(), 1e-5);
        let c = Tensor::randn(&[250, 120], 1.0, &mut rng);
        close(&par.matmul_bt(&a, &c).unwrap(), &ser.matmul_bt(&a, &c).unwrap(), 1e-5);
    }

    #[test]
    fn kblocked_bt_parallel_matches_reference() {
        // the m >= 8 && k >= 4096 branch — the Table-3 batch regime —
        // must agree with the generic path whether it runs as one panel
        // (par_flops = MAX) or many parallel panels (par_flops = 0).
        // Panel partitioning changes each panel's kc, hence the
        // summation order, so compare within tolerance, not bitwise.
        let mut rng = Rng::new(42);
        let (m, k, n) = (13, 4200, 9); // odd m: dot4 quads + a tail row
        let a = Tensor::randn(&[m, k], 1.0, &mut rng);
        let b = Tensor::randn(&[n, k], 1.0, &mut rng);
        let want = matmul(&a, &b.t2().unwrap()).unwrap();
        let par = Gemm { par_flops: 0, chunk_rows: 16 };
        let ser = Gemm { par_flops: usize::MAX, chunk_rows: 16 };
        close(&par.matmul_bt(&a, &b).unwrap(), &want, 1e-3);
        close(&ser.matmul_bt(&a, &b).unwrap(), &want, 1e-3);
        // fixed tuning + fixed kernel selection ⇒ deterministic run-to-run
        assert_eq!(par.matmul_bt(&a, &b).unwrap(), par.matmul_bt(&a, &b).unwrap());
    }

    #[test]
    fn identity_is_noop() {
        let mut rng = Rng::new(5);
        let a = Tensor::randn(&[8, 8], 1.0, &mut rng);
        close(&matmul(&a, &Tensor::eye(8)).unwrap(), &a, 1e-6);
        close(&matmul(&Tensor::eye(8), &a).unwrap(), &a, 1e-6);
    }

    #[test]
    fn matvec_matches_matmul() {
        let mut rng = Rng::new(6);
        let a = Tensor::randn(&[10, 20], 1.0, &mut rng);
        let x = Tensor::randn(&[20], 1.0, &mut rng);
        let xm = x.reshaped(&[20, 1]).unwrap();
        let want = matmul(&a, &xm).unwrap();
        let got = matvec(&a, &x).unwrap();
        close(&got.reshaped(&[10, 1]).unwrap(), &want, 1e-5);
    }

    #[test]
    fn shape_errors() {
        let a = Tensor::zeros(&[2, 3]);
        let b = Tensor::zeros(&[4, 5]);
        assert!(matmul(&a, &b).is_err());
        assert!(matmul_at(&a, &b).is_err());
        assert!(matmul_bt(&a, &b).is_err());
        assert!(matvec(&a, &Tensor::zeros(&[7])).is_err());
    }

    #[test]
    fn degenerate_dims_do_not_panic() {
        // n == 0 used to hit `chunks_mut(0)` inside the kernels
        for &(m, k, n) in &[(0usize, 3usize, 4usize), (3, 0, 4), (3, 4, 0), (0, 0, 0), (1, 5, 0)] {
            let a = Tensor::zeros(&[m, k]);
            let b = Tensor::zeros(&[k, n]);
            let c = matmul(&a, &b).unwrap();
            assert_eq!(c.shape(), &[m, n]);
            assert!(c.data().iter().all(|&x| x == 0.0));

            let at = Tensor::zeros(&[k, m]);
            let cat = matmul_at(&at, &b).unwrap();
            assert_eq!(cat.shape(), &[m, n]);

            let bt = Tensor::zeros(&[n, k]);
            let cbt = matmul_bt(&a, &bt).unwrap();
            assert_eq!(cbt.shape(), &[m, n]);
        }
        // forced-parallel tuning must survive the same degenerate shapes
        let par = Gemm { par_flops: 0, chunk_rows: 3 };
        let c = par.matmul(&Tensor::zeros(&[4, 0]), &Tensor::zeros(&[0, 4])).unwrap();
        assert_eq!(c.shape(), &[4, 4]);
        let v = matvec(&Tensor::zeros(&[0, 5]), &Tensor::zeros(&[5])).unwrap();
        assert_eq!(v.shape(), &[0]);
    }

    #[test]
    fn matmul_into_reuses_buffer_and_matches() {
        let mut rng = Rng::new(7);
        let g = Gemm::default();
        let a = Tensor::randn(&[6, 5], 1.0, &mut rng);
        let b = Tensor::randn(&[5, 9], 1.0, &mut rng);
        let want = g.matmul(&a, &b).unwrap();
        let mut buf = Vec::new();
        g.matmul_into(&a, &b, &mut buf).unwrap();
        assert_eq!(buf.as_slice(), want.data());
        let cap = buf.capacity();
        let ptr = buf.as_ptr();
        // a second same-shape call must not reallocate
        g.matmul_into(&a, &b, &mut buf).unwrap();
        assert_eq!(buf.capacity(), cap);
        assert_eq!(buf.as_ptr(), ptr);
        assert_eq!(buf.as_slice(), want.data());
        // stale contents from a larger previous result must not leak in
        let small_a = Tensor::randn(&[2, 5], 1.0, &mut rng);
        g.matmul_into(&small_a, &b, &mut buf).unwrap();
        assert_eq!(buf.len(), 2 * 9);
        let want_small = g.matmul(&small_a, &b).unwrap();
        assert_eq!(buf.as_slice(), want_small.data());
    }
}
