//! Dense strided tensor substrate (S1 in DESIGN.md).
//!
//! Row-major contiguous `f32` tensors plus the blocked, rayon-parallel GEMM
//! that backs both the fully-connected baseline and every TT core
//! contraction on the native path.  Deliberately minimal: contiguous
//! storage only — permutes materialize — which keeps the hot loops simple
//! enough to reason about and optimize.

mod dense;
mod im2col;
mod matmul;
mod reshape;
pub mod simd;

pub use dense::Tensor;
pub use im2col::{col2im, conv_out_dim, im2col};
pub use matmul::{matmul, matmul_at, matmul_bt, matvec, Gemm};
pub use reshape::{linear_index, multi_index, strides_of};
pub use simd::{kernels, simd_name, Kernels};
