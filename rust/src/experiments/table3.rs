//! E7 — Table 3: inference wall-clock of the 25088 -> 4096 layer, dense
//! vs TT (all ranks 4), at batch 1 and batch 100, plus memory accounting.
//!
//! Paper numbers (GTX 980 / quad-core i5): CPU FC 16.1ms/97.2ms,
//! CPU TT 1.2ms/94.7ms (batch 1 / batch 100); memory 392 MB vs 0.766 MB
//! for one image.  The reproducible *shape*: TT ≫ FC at batch 1, gap
//! narrows at batch 100, memory ratio ~512x.

use crate::error::Result;
use crate::experiments::table2::fc6_tt_shape;
use crate::tensor::{matmul_bt, Tensor};
use crate::tt::{MatvecScratch, TtMatrix};
use crate::util::bench::{black_box, Bencher};
use crate::util::rng::Rng;

/// One Table-3 row.
#[derive(Clone, Debug)]
pub struct Table3Row {
    pub kind: String, // "FC" / "TT4"
    pub batch: usize,
    pub mean_ms: f64,
    /// bytes touched per single-image forward (weights + activations)
    pub mem_bytes: usize,
}

/// Memory of one forward pass for a single image (paper's 392MB / 0.766MB
/// comparison): weight storage + the largest intermediate.
pub fn fc_forward_bytes() -> usize {
    // dense W (f32) + input + output
    4 * (25088 * 4096 + 25088 + 4096)
}

pub fn tt_forward_bytes(rank: usize) -> usize {
    let shape = fc6_tt_shape(rank).expect("valid shape");
    // cores + the maximal sweep intermediate: state is (r * N)-ish
    let max_state: usize = 25088 * rank.max(1);
    4 * (shape.num_params() + 25088 + 4096 + max_state)
}

/// Measure the native hot paths.  `quick` shortens measurement windows.
pub fn run_table3(quick: bool, verbose: bool) -> Result<Vec<Table3Row>> {
    let mut rng = Rng::new(0x5461_3362);
    let shape = fc6_tt_shape(4)?;
    let tt = TtMatrix::random(&shape, &mut rng)?;
    // dense baseline with the same logical size (4096 x 25088, stored
    // (out, in) like the Dense layer)
    let w = Tensor::randn(&[4096, 25088], 0.01, &mut rng);

    let bencher = if quick { Bencher::quick() } else { Bencher::default() };
    let mut rows = Vec::new();

    for &batch in &[1usize, 100] {
        let x = Tensor::randn(&[batch, 25088], 1.0, &mut rng);

        let m_fc = bencher.run(&format!("FC 25088x4096 batch={batch}"), || {
            black_box(matmul_bt(&x, &w).unwrap());
        });
        rows.push(Table3Row {
            kind: "FC".into(),
            batch,
            mean_ms: m_fc.mean_ms(),
            mem_bytes: fc_forward_bytes(),
        });

        let mut scratch = MatvecScratch::default();
        let m_tt = bencher.run(&format!("TT4 25088x4096 batch={batch}"), || {
            black_box(tt.matvec_with(&x, &mut scratch).unwrap());
        });
        rows.push(Table3Row {
            kind: "TT4".into(),
            batch,
            mean_ms: m_tt.mean_ms(),
            mem_bytes: tt_forward_bytes(4),
        });
    }

    if verbose {
        for r in &rows {
            println!(
                "{:<4} batch={:<4} {:>9.3} ms   mem {:>12} bytes",
                r.kind, r.batch, r.mean_ms, r.mem_bytes
            );
        }
        let speedup_b1 = rows[0].mean_ms / rows[1].mean_ms;
        let speedup_b100 = rows[2].mean_ms / rows[3].mean_ms;
        println!("speedup at batch 1:   {speedup_b1:.1}x (paper: 13.4x on CPU)");
        println!("speedup at batch 100: {speedup_b100:.1}x (paper: 1.03x on CPU)");
        println!(
            "memory ratio: {:.0}x (paper: 392MB / 0.766MB = 512x)",
            fc_forward_bytes() as f64 / tt_forward_bytes(4) as f64
        );
    }
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn memory_accounting_matches_paper_scale() {
        // paper: 392 MB for FC, 0.766 MB for TT
        let fc_mb = fc_forward_bytes() as f64 / (1024.0 * 1024.0);
        let tt_mb = tt_forward_bytes(4) as f64 / (1024.0 * 1024.0);
        assert!((fc_mb - 392.0).abs() < 5.0, "FC {fc_mb} MB");
        assert!(tt_mb < 1.0, "TT {tt_mb} MB");
        let ratio = fc_mb / tt_mb;
        assert!(ratio > 300.0, "ratio {ratio}");
    }
}
