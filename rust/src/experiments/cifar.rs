//! E4 — §6.2 CIFAR-10 Quick: freeze a feature extractor (the paper keeps
//! the conv part fixed), replace the FC tail by a TT-layer with 3125
//! hidden units (5^5), and compare against the original 64-hidden-unit FC
//! tail.  Paper: TT tail 23.13% vs FC tail 23.25% error, 4160 TT params.

use crate::data::{global_contrast_normalize, synth_cifar, ZcaWhitener};
use crate::error::Result;
use crate::nn::{
    Dense, Frozen, Layer, Relu, SgdConfig, Sequential, TrainConfig, Trainer, TtLinear,
};
use crate::tt::TtShape;
use crate::util::rng::Rng;

/// One configuration's outcome.
#[derive(Clone, Debug)]
pub struct CifarResult {
    pub label: String,
    pub tail_params: usize,
    pub test_error: f32,
}

/// Frozen "conv part" stand-in: fixed random projection 3072 -> 1024 +
/// ReLU (the paper freezes its trained conv features; any fixed map
/// preserves the train-only-the-tail setup — DESIGN.md §Substitutions).
fn frozen_features(rng: &mut Rng) -> Frozen<Sequential> {
    Frozen(Sequential::new(vec![
        Box::new(Dense::new(3072, 1024, rng)),
        Box::new(Relu::new()),
    ]))
}

/// Run TT tail (1024 -> 3125, ranks 8) vs FC tail (1024 -> 64).
pub fn run_cifar(quick: bool, verbose: bool) -> Result<Vec<CifarResult>> {
    let (n_train, n_test, epochs, zca_k) =
        if quick { (1200, 500, 3, 64) } else { (5000, 2000, 8, 256) };
    let seed = 0x4349_4641u64;
    let mut all = synth_cifar(n_train + n_test, seed)?;
    global_contrast_normalize(&mut all.x)?;
    // paper §6.2 preprocessing: GCN + ZCA whitening
    let mut rng = Rng::new(seed);
    let zca = ZcaWhitener::fit(&all.x, zca_k, 1e-2, 8, &mut rng)?;
    zca.apply(&mut all.x)?;
    let (train, test) = all.split(n_train)?;
    let trainer = Trainer::new(TrainConfig {
        epochs,
        batch_size: 32,
        sgd: SgdConfig::with_lr(0.03),
        lr_decay: 0.85,
        log_every: 0,
        seed,
    });

    let mut results = Vec::new();

    // TT tail: 1024 -> 3125 (4^5 -> 5^5), rank 8 => 4160 core params
    {
        let mut rng = Rng::new(seed ^ 0x1);
        let shape = TtShape::uniform(&[5; 5], &[4; 5], 8)?;
        let tt = TtLinear::new(&shape, &mut rng)?;
        let tt_core_params = tt.tt().num_params();
        assert_eq!(tt_core_params, 4160, "paper's §6.2 TT parameter count");
        let tail_params = tt.num_params() + 3125 * 10 + 10;
        let mut net = Sequential::new(vec![
            Box::new(frozen_features(&mut rng)),
            Box::new(tt),
            Box::new(Relu::new()),
            Box::new(Dense::new(3125, 10, &mut rng)),
        ]);
        trainer.fit(&mut net, &train, None)?;
        let eval = trainer.evaluate(&mut net, &test)?;
        let r = CifarResult {
            label: "TT(1024->3125, r=8) tail".into(),
            tail_params,
            test_error: eval.error,
        };
        if verbose {
            println!("{:<28} params={:<8} err={:.3}", r.label, r.tail_params, r.test_error);
        }
        results.push(r);
    }

    // FC tail: the original CIFAR-10 Quick 1024 -> 64 -> 10
    {
        let mut rng = Rng::new(seed ^ 0x2);
        let tail_params = 1024 * 64 + 64 + 64 * 10 + 10;
        let mut net = Sequential::new(vec![
            Box::new(frozen_features(&mut rng)),
            Box::new(Dense::new(1024, 64, &mut rng)),
            Box::new(Relu::new()),
            Box::new(Dense::new(64, 10, &mut rng)),
        ]);
        trainer.fit(&mut net, &train, None)?;
        let eval = trainer.evaluate(&mut net, &test)?;
        let r = CifarResult {
            label: "FC(1024->64) tail (baseline)".into(),
            tail_params,
            test_error: eval.error,
        };
        if verbose {
            println!("{:<28} params={:<8} err={:.3}", r.label, r.tail_params, r.test_error);
        }
        results.push(r);
    }

    Ok(results)
}
