//! Experiment drivers (DESIGN.md §5): one module per paper table/figure,
//! shared by the CLI launcher, the examples and the benches so every
//! number in EXPERIMENTS.md regenerates from a single code path.

mod cifar;
mod fig1;
mod hashednet;
mod perf;
mod table2;
mod table3;
mod wide;

pub use cifar::{run_cifar, CifarResult};
pub use fig1::{fig1_table, run_fig1, Fig1Point, Fig1Spec};
pub use hashednet::{run_hashednet, HashedNetRow};
// model builders moved to nn::zoo (the coordinator's serving registry
// uses them, so they cannot live in the driver layer); re-exported here
// so `experiments::tt_classifier`-style paths keep working
pub use crate::nn::{mnist_fc_baseline, mnist_tensornet, mr_classifier, tt_classifier};
pub use perf::{
    bench_conv_serving, bench_coordinator, bench_mixed_serving, bench_native_serving,
    bench_remote_serving, bench_tt_matvec, bench_ttsvd, default_matvec_cases, drive_clients,
    drive_mixed_clients, drive_remote_clients, report, run_bench_suite, write_report,
    MatvecCase, RemoteDrive,
};
pub use table2::{run_table2, Table2Row, VggFcGeometry};
pub use table3::{run_table3, Table3Row};
pub use wide::{run_wide, WideResult};
