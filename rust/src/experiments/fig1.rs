//! E1 — Figure 1: test error vs parameter count of the first (compressed)
//! 1024x1024 layer on (synthetic) MNIST, comparing TT reshapes against the
//! matrix-rank baseline.

use crate::data::{global_contrast_normalize, synth_mnist, Dataset};
use crate::error::Result;
use crate::nn::{mr_classifier, tt_classifier};
use crate::nn::{SgdConfig, TrainConfig, Trainer};
use crate::util::rng::Rng;

/// One curve point: a trained configuration.
#[derive(Clone, Debug)]
pub struct Fig1Point {
    pub family: String, // e.g. "TT 4x4x4x4x4" or "MR"
    pub rank: usize,
    pub layer1_params: usize,
    pub test_error: f32,
    pub train_loss: f32,
}

/// Sweep specification.
#[derive(Clone, Debug)]
pub struct Fig1Spec {
    pub n_train: usize,
    pub n_test: usize,
    pub epochs: usize,
    pub tt_reshapes: Vec<(Vec<usize>, Vec<usize>)>,
    pub tt_ranks: Vec<usize>,
    pub mr_ranks: Vec<usize>,
    pub seed: u64,
}

impl Fig1Spec {
    /// Fast smoke configuration (CI-sized).
    pub fn quick() -> Self {
        Fig1Spec {
            n_train: 1500,
            n_test: 600,
            epochs: 3,
            tt_reshapes: vec![
                (vec![4, 4, 4, 4, 4], vec![4, 4, 4, 4, 4]),
                (vec![32, 32], vec![32, 32]),
            ],
            tt_ranks: vec![2, 8],
            mr_ranks: vec![2, 8],
            seed: 20150407,
        }
    }

    /// The full sweep (paper Fig. 1's four reshape families).
    pub fn full() -> Self {
        Fig1Spec {
            n_train: 6000,
            n_test: 2000,
            epochs: 8,
            tt_reshapes: vec![
                (vec![4, 4, 4, 4, 4], vec![4, 4, 4, 4, 4]),
                (vec![8, 4, 4, 8], vec![8, 4, 4, 8]),
                (vec![32, 32], vec![32, 32]),
                (vec![2; 10], vec![2; 10]),
            ],
            tt_ranks: vec![1, 2, 4, 8, 16],
            mr_ranks: vec![1, 2, 4, 8, 16, 32],
            seed: 20150407,
        }
    }
}

fn family_name(ms: &[usize]) -> String {
    format!("TT {}", ms.iter().map(|m| m.to_string()).collect::<Vec<_>>().join("x"))
}

/// Prepare the (synthetic) MNIST train/test split with GCN.
pub fn fig1_data(spec: &Fig1Spec) -> Result<(Dataset, Dataset)> {
    let mut all = synth_mnist(spec.n_train + spec.n_test, spec.seed)?;
    global_contrast_normalize(&mut all.x)?;
    all.split(spec.n_train)
}

/// Run the sweep; returns all curve points.
pub fn run_fig1(spec: &Fig1Spec, verbose: bool) -> Result<Vec<Fig1Point>> {
    let (train, test) = fig1_data(spec)?;
    let trainer = Trainer::new(TrainConfig {
        epochs: spec.epochs,
        batch_size: 32,
        sgd: SgdConfig::with_lr(0.03),
        lr_decay: 0.85,
        log_every: 0,
        seed: spec.seed ^ 0x1,
    });
    let mut points = Vec::new();

    for (ms, ns) in &spec.tt_reshapes {
        // d=2 reshapes cannot hold rank > min mode product meaningfully;
        // sweep all requested ranks anyway (rank caps just saturate)
        for &r in &spec.tt_ranks {
            let mut rng = Rng::new(spec.seed ^ (r as u64) << 8);
            let (mut net, layer1) = tt_classifier(ms, ns, r, 10, &mut rng)?;
            let hist = trainer.fit(&mut net, &train, None)?;
            let eval = trainer.evaluate(&mut net, &test)?;
            let p = Fig1Point {
                family: family_name(ms),
                rank: r,
                layer1_params: layer1,
                test_error: eval.error,
                train_loss: hist.final_loss(),
            };
            if verbose {
                println!(
                    "{:<18} r={:<3} params={:<8} err={:.3}",
                    p.family, p.rank, p.layer1_params, p.test_error
                );
            }
            points.push(p);
        }
    }
    for &r in &spec.mr_ranks {
        let mut rng = Rng::new(spec.seed ^ 0xA000 ^ (r as u64));
        let (mut net, layer1) = mr_classifier(1024, 1024, r, 10, &mut rng)?;
        let hist = trainer.fit(&mut net, &train, None)?;
        let eval = trainer.evaluate(&mut net, &test)?;
        let p = Fig1Point {
            family: "MR".into(),
            rank: r,
            layer1_params: layer1,
            test_error: eval.error,
            train_loss: hist.final_loss(),
        };
        if verbose {
            println!(
                "{:<18} r={:<3} params={:<8} err={:.3}",
                p.family, p.rank, p.layer1_params, p.test_error
            );
        }
        points.push(p);
    }
    Ok(points)
}

/// Render points as the EXPERIMENTS.md table rows.
pub fn fig1_table(points: &[Fig1Point]) -> Vec<Vec<String>> {
    points
        .iter()
        .map(|p| {
            vec![
                p.family.clone(),
                p.rank.to_string(),
                p.layer1_params.to_string(),
                format!("{:.3}", p.test_error),
                format!("{:.3}", p.train_loss),
            ]
        })
        .collect()
}
