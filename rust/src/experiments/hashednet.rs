//! E2 — §6.1 HashedNet comparison: both FC layers of the 2-layer MNIST
//! net replaced by TT-layers at ranks 8 and 6; report total parameters and
//! test error (paper: 12 602 params / 1.6% and 7 698 params / 1.9%,
//! vs HashedNet's 12 720 params / 2.79% at compression 64).

use crate::data::{global_contrast_normalize, synth_mnist};
use crate::error::Result;
use crate::nn::{Dense, Layer, Relu, SgdConfig, Sequential, TrainConfig, Trainer, TtLinear};
use crate::tt::TtShape;
use crate::util::rng::Rng;

/// One table row.
#[derive(Clone, Debug)]
pub struct HashedNetRow {
    pub label: String,
    pub total_params: usize,
    pub test_error: f32,
    pub compression_vs_dense: f64,
}

/// Both layers TT: `TT(1024->1024, r) -> ReLU -> TT(1024->10, r)`.
fn both_tt(rank: usize, rng: &mut Rng) -> Result<Sequential> {
    let l1 = TtLinear::new(&TtShape::uniform(&[4; 5], &[4; 5], rank)?, rng)?;
    // 10 outputs factored as 10x1x1x1x1 over the 4^5 input modes
    let l2 = TtLinear::new(&TtShape::uniform(&[10, 1, 1, 1, 1], &[4; 5], rank)?, rng)?;
    Ok(Sequential::new(vec![Box::new(l1), Box::new(Relu::new()), Box::new(l2)]))
}

/// Run ranks {8, 6} plus the dense reference.
pub fn run_hashednet(quick: bool, verbose: bool) -> Result<Vec<HashedNetRow>> {
    let (n_train, n_test, epochs) = if quick { (1500, 600, 3) } else { (6000, 2000, 8) };
    let seed = 0x4861_7368u64;
    let mut all = synth_mnist(n_train + n_test, seed)?;
    global_contrast_normalize(&mut all.x)?;
    let (train, test) = all.split(n_train)?;
    let trainer = Trainer::new(TrainConfig {
        epochs,
        batch_size: 32,
        sgd: SgdConfig::with_lr(0.03),
        lr_decay: 0.85,
        log_every: 0,
        seed,
    });

    let dense_total = (1024 * 1024 + 1024 + 1024 * 10 + 10) as f64;
    let mut rows = Vec::new();

    for &rank in &[8usize, 6] {
        let mut rng = Rng::new(seed ^ rank as u64);
        let mut net = both_tt(rank, &mut rng)?;
        let params = net.num_params();
        trainer.fit(&mut net, &train, None)?;
        let eval = trainer.evaluate(&mut net, &test)?;
        let row = HashedNetRow {
            label: format!("TT{rank} TT{rank}"),
            total_params: params,
            test_error: eval.error,
            compression_vs_dense: dense_total / params as f64,
        };
        if verbose {
            println!(
                "{:<10} params={:<8} err={:.3} compr={:.0}x",
                row.label, row.total_params, row.test_error, row.compression_vs_dense
            );
        }
        rows.push(row);
    }

    // dense reference
    let mut rng = Rng::new(seed ^ 0xFF);
    let mut dense = Sequential::new(vec![
        Box::new(Dense::new(1024, 1024, &mut rng)),
        Box::new(Relu::new()),
        Box::new(Dense::new(1024, 10, &mut rng)),
    ]);
    let params = dense.num_params();
    trainer.fit(&mut dense, &train, None)?;
    let eval = trainer.evaluate(&mut dense, &test)?;
    let row = HashedNetRow {
        label: "FC FC (dense)".into(),
        total_params: params,
        test_error: eval.error,
        compression_vs_dense: 1.0,
    };
    if verbose {
        println!(
            "{:<10} params={:<8} err={:.3} compr=1x",
            row.label, row.total_params, row.test_error
        );
    }
    rows.push(row);
    Ok(rows)
}
