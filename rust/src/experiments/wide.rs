//! E5 — §6.2.1 wide & shallow TensorNet: TT(3072 -> 262144) -> ReLU ->
//! TT(262144 -> 4096) -> ReLU -> FC(4096 -> 10).  A quarter-million
//! hidden units whose weight "matrices" would hold 1.9e9 parameters
//! densely; in TT they fit in a few hundred KB and train on a laptop.
//! Paper: 31.47% CIFAR-10 error — best known non-convolutional net.

use crate::data::{global_contrast_normalize, synth_cifar};
use crate::error::Result;
use crate::nn::{Dense, Layer, Relu, SgdConfig, Sequential, TrainConfig, Trainer, TtLinear};
use crate::tt::TtShape;
use crate::util::rng::Rng;

/// Outcome of the wide-net run.
#[derive(Clone, Debug)]
pub struct WideResult {
    pub hidden_units: usize,
    pub total_params: usize,
    pub dense_equivalent: usize,
    pub test_error: f32,
    pub initial_error: f32,
}

/// Build the §6.2.1 architecture.
pub fn wide_net(rank: usize, rng: &mut Rng) -> Result<(Sequential, usize, usize)> {
    // 3072 = 4^5 * 3, 262144 = 8^6, 4096 = 4^6
    let s1 = TtShape::uniform(&[8; 6], &[4, 4, 4, 4, 4, 3], rank)?;
    let s2 = TtShape::uniform(&[4; 6], &[8; 6], rank)?;
    assert_eq!(s1.n_total(), 3072);
    assert_eq!(s1.m_total(), 262_144);
    assert_eq!(s2.m_total(), 4096);
    let dense_equiv = s1.dense_params() + s2.dense_params();
    let l1 = TtLinear::new(&s1, rng)?;
    let l2 = TtLinear::new(&s2, rng)?;
    let net = Sequential::new(vec![
        Box::new(l1),
        Box::new(Relu::new()),
        Box::new(l2),
        Box::new(Relu::new()),
        Box::new(Dense::new(4096, 10, rng)),
    ]);
    let total = net.num_params();
    Ok((net, total, dense_equiv))
}

/// Train briefly on synthetic CIFAR; the claim being reproduced is that a
/// 262 144-unit layer is *trainable at all* at this parameter budget.
pub fn run_wide(quick: bool, verbose: bool) -> Result<WideResult> {
    let (n_train, n_test, epochs, rank) = if quick { (300, 150, 1, 4) } else { (1500, 600, 3, 8) };
    let seed = 0x5769_6465u64;
    let mut all = synth_cifar(n_train + n_test, seed)?;
    global_contrast_normalize(&mut all.x)?;
    let (train, test) = all.split(n_train)?;
    let mut rng = Rng::new(seed);
    let (mut net, total, dense_equiv) = wide_net(rank, &mut rng)?;
    let trainer = Trainer::new(TrainConfig {
        epochs,
        batch_size: 16,
        sgd: SgdConfig::with_lr(0.02),
        lr_decay: 0.9,
        log_every: 0,
        seed,
    });
    let before = trainer.evaluate(&mut net, &test)?;
    trainer.fit(&mut net, &train, None)?;
    let after = trainer.evaluate(&mut net, &test)?;
    let result = WideResult {
        hidden_units: 262_144,
        total_params: total,
        dense_equivalent: dense_equiv,
        test_error: after.error,
        initial_error: before.error,
    };
    if verbose {
        println!(
            "wide net: {} hidden units, {} params (dense equivalent {} = {:.0}x compression)",
            result.hidden_units,
            result.total_params,
            result.dense_equivalent,
            result.dense_equivalent as f64 / result.total_params as f64
        );
        println!(
            "error {:.3} -> {:.3} (must improve over chance 0.9)",
            result.initial_error, result.test_error
        );
    }
    Ok(result)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wide_net_param_budget() {
        let mut rng = Rng::new(1);
        let (net, total, dense_equiv) = wide_net(8, &mut rng).unwrap();
        // dense equivalent is ~1.9e9; TT holds it under 600k params
        assert!(dense_equiv > 1_800_000_000);
        assert!(total < 600_000, "total {total}");
        assert!(net.num_params() == total);
    }
}
