//! The perf-baseline suite behind `tensornet bench` (EXPERIMENTS.md
//! §Perf): the paper-relevant microbenches — TT matvec vs dense GEMM over
//! the Table-3 regime of (rank, batch) configurations, TT-SVD
//! decomposition, and coordinator throughput/latency — emitted as
//! machine-readable `BENCH_tt_matvec.json` / `BENCH_coordinator.json`
//! (echo policy sweep + native-TT, mixed-model and remote-TT serving
//! sweeps) so
//! every future PR is judged against a recorded trajectory instead of
//! anecdotes.  Built on `util::bench` (runner) and `util::json` (writer);
//! no dependencies, like everything else in the crate.

use crate::coordinator::{
    AdmissionConfig, BatchPolicy, Client, EchoExecutor, ModelInfo, ModelRegistry,
    NativeExecutor, NetServer, QueueMode, RouterConfig, Server, ServerConfig, ShardRouter,
};
use crate::error::{Error, Result};
use crate::metrics::Histogram;
use crate::tensor::{matmul_bt, simd_name, Tensor};
use crate::tt::{MatvecScratch, TtMatrix, TtShape};
use crate::util::bench::{black_box, Bencher};
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::threads::{num_threads, thread_budget};
use std::collections::{BTreeMap, VecDeque};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// One dense-vs-TT matvec configuration (a Table-3-style cell).
#[derive(Clone, Debug)]
pub struct MatvecCase {
    pub label: String,
    pub ms: Vec<usize>,
    pub ns: Vec<usize>,
    pub rank: usize,
    pub batch: usize,
}

impl MatvecCase {
    fn new(label: &str, ms: &[usize], ns: &[usize], rank: usize, batch: usize) -> Self {
        MatvecCase {
            label: label.to_string(),
            ms: ms.to_vec(),
            ns: ns.to_vec(),
            rank,
            batch,
        }
    }
}

/// The default (rank, batch) grid.  `quick` keeps everything at the MNIST
/// 1024x1024 geometry; the full grid adds the paper's vgg fc6 shape
/// (25088 -> 4096, rank 4 — the Table 3 row) whose dense baseline
/// allocates a 411 MB weight matrix.
pub fn default_matvec_cases(quick: bool) -> Vec<MatvecCase> {
    let mut cases = vec![
        MatvecCase::new("mnist 1024x1024 r2 b1", &[4; 5], &[4; 5], 2, 1),
        MatvecCase::new("mnist 1024x1024 r8 b1", &[4; 5], &[4; 5], 8, 1),
        MatvecCase::new("mnist 1024x1024 r8 b32", &[4; 5], &[4; 5], 8, 32),
        MatvecCase::new("mnist 1024x1024 r8 b100", &[4; 5], &[4; 5], 8, 100),
    ];
    if !quick {
        cases.push(MatvecCase::new(
            "vgg 4096x25088 r4 b1",
            &[4; 6],
            &[2, 7, 8, 8, 7, 4],
            4,
            1,
        ));
        cases.push(MatvecCase::new(
            "vgg 4096x25088 r4 b100",
            &[4; 6],
            &[2, 7, 8, 8, 7, 4],
            4,
            100,
        ));
    }
    cases
}

fn num(x: f64) -> Json {
    Json::Num(x)
}

/// Measure dense GEMM vs TT matvec for each case.  Returns the JSON
/// entries (one object per case, dense and TT timings side by side).
pub fn bench_tt_matvec(
    bencher: &Bencher,
    cases: &[MatvecCase],
    verbose: bool,
) -> Result<Vec<Json>> {
    let mut entries = Vec::new();
    for case in cases {
        let shape = TtShape::uniform(&case.ms, &case.ns, case.rank)?;
        let (m_total, n_total) = (shape.m_total(), shape.n_total());
        let mut rng = Rng::new(0xBE9C_0000 ^ case.rank as u64 ^ ((case.batch as u64) << 16));
        let tt = TtMatrix::random(&shape, &mut rng)?;
        // dense baseline with the same logical size, stored (out, in) like
        // the Dense layer; values don't affect timing, only shapes do
        let w = Tensor::randn(&[m_total, n_total], 0.01, &mut rng);
        let x = Tensor::randn(&[case.batch, n_total], 1.0, &mut rng);

        let m_dense = bencher.run(&format!("dense {}", case.label), || {
            black_box(matmul_bt(&x, &w).unwrap());
        });
        let mut scratch = MatvecScratch::default();
        let m_tt = bencher.run(&format!("tt    {}", case.label), || {
            black_box(tt.matvec_with(&x, &mut scratch).unwrap());
        });
        let speedup = m_dense.mean_ms() / m_tt.mean_ms().max(1e-9);

        let mut obj = BTreeMap::new();
        obj.insert("label".to_string(), Json::Str(case.label.clone()));
        obj.insert("m".to_string(), num(m_total as f64));
        obj.insert("n".to_string(), num(n_total as f64));
        obj.insert("rank".to_string(), num(case.rank as f64));
        obj.insert("batch".to_string(), num(case.batch as f64));
        obj.insert("tt_params".to_string(), num(shape.num_params() as f64));
        obj.insert("dense_params".to_string(), num(shape.dense_params() as f64));
        // kernel provenance: which dispatch path ran and how many threads
        // the parallel helpers were allowed — without these, a trajectory
        // diff cannot tell an ISA regression from a thread-budget change
        obj.insert("simd".to_string(), Json::Str(simd_name().to_string()));
        obj.insert("kernel_threads".to_string(), num(thread_budget() as f64));
        obj.insert("dense".to_string(), m_dense.to_json());
        obj.insert("tt".to_string(), m_tt.to_json());
        obj.insert("speedup".to_string(), num(speedup));
        entries.push(Json::Obj(obj));

        // Bencher::run already printed each measurement's timing line;
        // only the derived ratio is worth an extra line here
        if verbose {
            println!("  -> {:<26} speedup {speedup:.2}x (dense/tt)", case.label);
        }
    }
    Ok(entries)
}

/// TT-SVD decomposition timings (256x256 as 4^4 modes, two rank caps).
pub fn bench_ttsvd(bencher: &Bencher, verbose: bool) -> Result<Vec<Json>> {
    let mut rng = Rng::new(0x7753_5644);
    let w = Tensor::randn(&[256, 256], 1.0, &mut rng);
    let mut entries = Vec::new();
    for rank in [4usize, 8] {
        let m = bencher.run(&format!("tt-svd 256x256 (4^4) rank<={rank}"), || {
            black_box(TtMatrix::from_dense(&w, &[4; 4], &[4; 4], Some(rank), 0.0).unwrap());
        });
        let mut obj = BTreeMap::new();
        obj.insert("label".to_string(), Json::Str(format!("ttsvd 256x256 r{rank}")));
        obj.insert("rank_cap".to_string(), num(rank as f64));
        obj.insert("measurement".to_string(), m.to_json());
        entries.push(Json::Obj(obj));
        if verbose {
            println!("  tt-svd rank<={rank}: {:.3} ms", m.mean_ms());
        }
    }
    Ok(entries)
}

/// Fire exactly `n_requests` random-normal inputs at `model` from
/// `clients` concurrent threads (the remainder is distributed across
/// clients), ignoring per-request failures — those surface in
/// [`crate::coordinator::ServerStats::errors`].  Returns the wall-clock
/// seconds of the run.  Shared by `tensornet serve`, the native serving
/// bench and `examples/serve_tt.rs` so the driven workload cannot drift
/// between the CLI and the perf trajectory.
pub fn drive_clients(
    server: &Server,
    model: &str,
    dim: usize,
    n_requests: usize,
    clients: usize,
) -> f64 {
    drive_mixed_clients(server, &[(model.to_string(), dim)], n_requests, clients)
}

/// Multi-model counterpart of [`drive_clients`]: each client thread
/// strictly interleaves `models` round-robin (1:1:…), so consecutive
/// arrivals at the batcher almost always switch models — the workload
/// the per-model batch groups exist for (a single-group assembler
/// collapses it to batch-size ~1).  Clients start phase-shifted so the
/// in-flight mix stays balanced across models.
pub fn drive_mixed_clients(
    server: &Server,
    models: &[(String, usize)],
    n_requests: usize,
    clients: usize,
) -> f64 {
    assert!(!models.is_empty(), "drive_mixed_clients needs at least one model");
    let clients = clients.max(1);
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for c in 0..clients {
            let mine = n_requests / clients + usize::from(c < n_requests % clients);
            s.spawn(move || {
                let mut rng = Rng::new(0xD21F_E000 ^ c as u64);
                for i in 0..mine {
                    let (model, dim) = &models[(c + i) % models.len()];
                    let x: Vec<f32> = (0..*dim).map(|_| rng.normal_f32(1.0)).collect();
                    let _ = server.infer(model, x);
                }
            });
        }
    });
    t0.elapsed().as_secs_f64()
}

/// What [`drive_remote_clients`] observed, from the client's side of the
/// wire: true end-to-end latency including both network hops.
pub struct RemoteDrive {
    pub wall_seconds: f64,
    pub completed: u64,
    /// `Busy` replies (server-side load shedding; retryable, not failures)
    pub busy: u64,
    /// transport or execution failures
    pub failed: u64,
    /// client-observed send → reply latency
    pub e2e: Histogram,
}

/// Fire exactly `n_requests` random-normal inputs over TCP from
/// `connections` independent [`Client`] connections, each keeping up to
/// `pipeline` requests in flight and interleaving `models` round-robin
/// (1:1:… — one entry means single-model traffic).  The remote
/// counterpart of [`drive_mixed_clients`], shared by `tensornet
/// client`, the `remote_tt` bench sweep and `examples/serve_tt.rs` so
/// the driven workload cannot drift between the CLI and the perf
/// trajectory.
///
/// `timeout` (when `Some`) bounds both connection establishment and
/// every reply wait; a timed-out connection is abandoned — the framing
/// state is unknown mid-stream, so its unanswered and unsent requests
/// all count as failed rather than risking misattributed replies.
///
/// A `Busy` shed reply throttles the connection instead of hot-looping:
/// the client sleeps the server's `retry_after_ms` hint, doubled per
/// consecutive shed and capped at 100ms, and resets on any completion.
/// Under overload the offered rate therefore decays toward what the
/// server can actually admit (the client half of the admission-control
/// contract in DESIGN.md §14).
pub fn drive_remote_clients(
    addr: &str,
    models: &[(String, usize)],
    n_requests: usize,
    connections: usize,
    pipeline: usize,
    timeout: Option<Duration>,
) -> RemoteDrive {
    assert!(!models.is_empty(), "drive_remote_clients needs at least one model");
    let connections = connections.max(1);
    let pipeline = pipeline.max(1);
    let completed = AtomicU64::new(0);
    let busy = AtomicU64::new(0);
    let failed = AtomicU64::new(0);
    let e2e = Histogram::new();
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for c in 0..connections {
            let mine = n_requests / connections + usize::from(c < n_requests % connections);
            let (completed, busy, failed, e2e) = (&completed, &busy, &failed, &e2e);
            s.spawn(move || {
                let connected = match timeout {
                    Some(t) => Client::connect_timeout(addr, t),
                    None => Client::connect(addr),
                };
                let mut client = match connected {
                    Ok(cl) => cl,
                    Err(e) => {
                        eprintln!("client {c}: {e}");
                        failed.fetch_add(mine as u64, Ordering::Relaxed);
                        return;
                    }
                };
                let mut rng = Rng::new(0x4E37_0000 ^ c as u64);
                let mut sent_at: VecDeque<Instant> = VecDeque::new();
                let mut sent = 0usize;
                let mut done = 0usize;
                let mut consecutive_busy = 0u32;
                while done < mine {
                    while sent < mine && sent_at.len() < pipeline {
                        let (model, dim) = &models[(c + sent) % models.len()];
                        let x: Vec<f32> = (0..*dim).map(|_| rng.normal_f32(1.0)).collect();
                        if let Err(e) = client.send(model, &x) {
                            eprintln!("client {c}: {e}");
                            // the connection is gone: everything unanswered
                            // plus everything unsent fails
                            failed.fetch_add((mine - done) as u64, Ordering::Relaxed);
                            return;
                        }
                        sent_at.push_back(Instant::now());
                        sent += 1;
                    }
                    let sent_instant = sent_at.pop_front().expect("pipeline is non-empty");
                    match client.recv() {
                        Ok(_) => {
                            e2e.record(sent_instant.elapsed());
                            completed.fetch_add(1, Ordering::Relaxed);
                            consecutive_busy = 0;
                        }
                        Err(Error::Busy { retry_after_ms, .. }) => {
                            busy.fetch_add(1, Ordering::Relaxed);
                            let hint = u64::from(retry_after_ms.max(1));
                            let delay =
                                hint.saturating_mul(1 << consecutive_busy.min(10)).min(100);
                            std::thread::sleep(Duration::from_millis(delay));
                            consecutive_busy = consecutive_busy.saturating_add(1);
                        }
                        Err(e @ Error::Net(_)) => {
                            // transport dead or reply timed out: the
                            // connection's framing state is unknown, so
                            // abandon it — everything unanswered plus
                            // everything unsent fails
                            eprintln!("client {c}: {e}");
                            failed.fetch_add((mine - done) as u64, Ordering::Relaxed);
                            return;
                        }
                        Err(e) => {
                            eprintln!("client {c}: {e}");
                            failed.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                    done += 1;
                }
            });
        }
    });
    RemoteDrive {
        wall_seconds: t0.elapsed().as_secs_f64(),
        completed: completed.into_inner(),
        busy: busy.into_inner(),
        failed: failed.into_inner(),
        e2e,
    }
}

/// Coordinator throughput/latency over the echo backend (isolates
/// coordination overhead from model compute) for a small policy sweep.
pub fn bench_coordinator(
    n_requests: usize,
    clients: usize,
    verbose: bool,
) -> Result<Vec<Json>> {
    let dim = 64usize;
    let mut entries = Vec::new();
    for (max_batch, delay_us) in [(1usize, 0u64), (32, 500), (32, 2000)] {
        let cfg = ServerConfig {
            policy: BatchPolicy {
                max_batch,
                max_delay: Duration::from_micros(delay_us),
            },
            queue_capacity: 4096,
            batch_queue_capacity: 16,
            executor_threads: 1,
            kernel_threads: 0,
            ..Default::default()
        };
        let server = Server::start(cfg, move || Ok(EchoExecutor { dim, scale: 1.0 }))?;
        // NOT drive_clients: this sweep's baseline was recorded with a
        // constant input vector (client-side RNG cost would skew the
        // pure-coordination numbers against the near-free echo backend)
        let clients = clients.max(1);
        let t0 = Instant::now();
        std::thread::scope(|s| {
            for c in 0..clients {
                // distribute the remainder so exactly n_requests are sent
                let mine = n_requests / clients + usize::from(c < n_requests % clients);
                let server = &server;
                s.spawn(move || {
                    let x = vec![1.0f32; dim];
                    for _ in 0..mine {
                        let _ = server.infer("m", x.clone());
                    }
                });
            }
        });
        let wall = t0.elapsed().as_secs_f64().max(1e-9);
        let st = server.stats();
        let mut obj = BTreeMap::new();
        obj.insert("max_batch".to_string(), num(max_batch as f64));
        obj.insert("max_delay_us".to_string(), num(delay_us as f64));
        obj.insert("clients".to_string(), num(clients as f64));
        obj.insert("completed".to_string(), num(st.completed.get() as f64));
        obj.insert("errors".to_string(), num(st.errors.get() as f64));
        obj.insert("req_per_s".to_string(), num(st.completed.get() as f64 / wall));
        obj.insert("mean_batch".to_string(), num(st.mean_batch_size()));
        obj.insert("p50_us".to_string(), num(st.e2e.quantile_us(0.5)));
        obj.insert("p99_us".to_string(), num(st.e2e.quantile_us(0.99)));
        if verbose {
            println!(
                "  max_batch={max_batch:<4} delay={delay_us:>5}µs  {:>9.0} req/s  mean batch {:.1}  p50 {:.0}µs p99 {:.0}µs",
                st.completed.get() as f64 / wall,
                st.mean_batch_size(),
                st.e2e.quantile_us(0.5),
                st.e2e.quantile_us(0.99),
            );
        }
        entries.push(Json::Obj(obj));
    }
    Ok(entries)
}

/// Native-TT serving sweep: the real `TtMatrix::matvec_with` behind the
/// batcher (model `tt_layer`, the paper's 1024x1024 Table-3 shape), swept
/// over `(executor_threads, max_batch)`.  Unlike the echo sweep above —
/// which isolates coordination overhead — this finally measures model
/// execution through the serving spine, so the perf trajectory captures
/// how throughput scales from 1 to N executor workers.
pub fn bench_native_serving(
    n_requests: usize,
    clients: usize,
    verbose: bool,
) -> Result<Vec<Json>> {
    let registry = ModelRegistry::standard();
    let model = "tt_layer";
    let dim = registry.input_dim(model)?;
    let sweep = [(1usize, 1usize), (1, 32), (2, 32), (4, 32)];
    let mut entries = Vec::new();
    for (threads, max_batch) in sweep {
        let cfg = ServerConfig {
            policy: BatchPolicy { max_batch, max_delay: Duration::from_micros(500) },
            queue_capacity: 4096,
            batch_queue_capacity: 16,
            executor_threads: threads,
            kernel_threads: 0,
            ..Default::default()
        };
        let kernel_threads = cfg.effective_kernel_threads();
        let reg = registry.clone();
        let server = Server::start(cfg, move || Ok(NativeExecutor::new(reg.clone())))?;
        // warm the lazily-built model out of the timed region (one worker;
        // the rest pay the tiny core build on their first batch).  The
        // warmup's latency does land in the e2e histogram — one sample
        // out of n_requests+1, which cannot move p50/p99 at the ≥1000
        // request counts the suite uses — but it is excluded from
        // `completed` and `req_per_s` below.
        server.infer(model, vec![0.0; dim])?;
        let wall = drive_clients(&server, model, dim, n_requests, clients).max(1e-9);
        let st = server.stats();
        let served = st.completed.get().saturating_sub(1); // minus warmup
        let mut obj = BTreeMap::new();
        obj.insert("model".to_string(), Json::Str(model.to_string()));
        obj.insert("executor_threads".to_string(), num(threads as f64));
        obj.insert("kernel_threads".to_string(), num(kernel_threads as f64));
        obj.insert("simd".to_string(), Json::Str(simd_name().to_string()));
        obj.insert("max_batch".to_string(), num(max_batch as f64));
        obj.insert("clients".to_string(), num(clients as f64));
        obj.insert("completed".to_string(), num(served as f64));
        obj.insert("errors".to_string(), num(st.errors.get() as f64));
        // load-shedding and pool degradation are part of the trajectory:
        // a policy change that silently starts rejecting would otherwise
        // look like a latency win
        obj.insert("rejected".to_string(), num(st.rejected.get() as f64));
        obj.insert("failed_workers".to_string(), num(st.failed_workers.get() as f64));
        obj.insert("req_per_s".to_string(), num(served as f64 / wall));
        obj.insert("mean_batch".to_string(), num(st.mean_batch_size()));
        obj.insert("p50_us".to_string(), num(st.e2e.quantile_us(0.5)));
        obj.insert("p99_us".to_string(), num(st.e2e.quantile_us(0.99)));
        if verbose {
            println!(
                "  workers={threads}  max_batch={max_batch:<4} {:>9.0} req/s  mean batch {:.1}  p50 {:.0}µs p99 {:.0}µs",
                served as f64 / wall,
                st.mean_batch_size(),
                st.e2e.quantile_us(0.5),
                st.e2e.quantile_us(0.99),
            );
        }
        entries.push(Json::Obj(obj));
    }
    Ok(entries)
}

/// New-model-family serving sweep (`conv_tt`): `conv_mnist` (TT-format
/// convolution via the Garipov reshape), `bt_layer` (block-term
/// decomposition) and the original `tt_layer` driven one at a time
/// through the same in-process serving spine at one fixed policy.  One
/// entry per model with its weight-storage `family` recorded, so the
/// trajectory reads as relative serving cost across the three
/// compression families at identical coordination settings — a conv or
/// BT kernel regression shows up here even when `native_tt` is flat.
pub fn bench_conv_serving(
    n_requests: usize,
    clients: usize,
    verbose: bool,
) -> Result<Vec<Json>> {
    let registry = ModelRegistry::standard();
    // (model, weight-storage family) — tt_layer rides along as the
    // cross-family baseline at this sweep's policy
    let sweep = [("conv_mnist", "tt_conv"), ("bt_layer", "bt"), ("tt_layer", "tt")];
    let (executor_threads, max_batch) = (2usize, 32usize);
    let mut entries = Vec::new();
    for (model, family) in sweep {
        let dim = registry.input_dim(model)?;
        let cfg = ServerConfig {
            policy: BatchPolicy { max_batch, max_delay: Duration::from_micros(500) },
            queue_capacity: 4096,
            batch_queue_capacity: 16,
            executor_threads,
            kernel_threads: 0,
            ..Default::default()
        };
        let kernel_threads = cfg.effective_kernel_threads();
        let reg = registry.clone();
        let server = Server::start(cfg, move || Ok(NativeExecutor::new(reg.clone())))?;
        // warm the lazily-built model out of the timed region (same
        // rationale and accounting as the native sweep)
        server.infer(model, vec![0.0; dim])?;
        let wall = drive_clients(&server, model, dim, n_requests, clients).max(1e-9);
        let st = server.stats();
        let served = st.completed.get().saturating_sub(1); // minus warmup
        let mut obj = BTreeMap::new();
        obj.insert("model".to_string(), Json::Str(model.to_string()));
        obj.insert("family".to_string(), Json::Str(family.to_string()));
        obj.insert("executor_threads".to_string(), num(executor_threads as f64));
        obj.insert("kernel_threads".to_string(), num(kernel_threads as f64));
        obj.insert("simd".to_string(), Json::Str(simd_name().to_string()));
        obj.insert("max_batch".to_string(), num(max_batch as f64));
        obj.insert("clients".to_string(), num(clients as f64));
        obj.insert("completed".to_string(), num(served as f64));
        obj.insert("errors".to_string(), num(st.errors.get() as f64));
        obj.insert("rejected".to_string(), num(st.rejected.get() as f64));
        obj.insert("failed_workers".to_string(), num(st.failed_workers.get() as f64));
        obj.insert("req_per_s".to_string(), num(served as f64 / wall));
        obj.insert("mean_batch".to_string(), num(st.mean_batch_size()));
        obj.insert("p50_us".to_string(), num(st.e2e.quantile_us(0.5)));
        obj.insert("p99_us".to_string(), num(st.e2e.quantile_us(0.99)));
        if verbose {
            println!(
                "  {model:<12} family={family:<8} {:>9.0} req/s  mean batch {:.1}  p50 {:.0}µs p99 {:.0}µs",
                served as f64 / wall,
                st.mean_batch_size(),
                st.e2e.quantile_us(0.5),
                st.e2e.quantile_us(0.99),
            );
        }
        entries.push(Json::Obj(obj));
    }
    Ok(entries)
}

/// Mixed-model serving sweep (`mixed_tt`): interleaved
/// tt_layer/fc_mnist/mnist_net traffic through one server, swept over
/// (models, clients, max_batch), reporting per-model mean batch size.
/// The regression this pins: the old single-group assembler flushed its
/// pending batch on every model switch, so a 1:1 two-model interleave
/// collapsed to mean batch ~1.0 no matter the policy; the per-model
/// assembler must hold each model's mean batch near
/// min(clients / models, max_batch).
pub fn bench_mixed_serving(n_requests: usize, verbose: bool) -> Result<Vec<Json>> {
    let registry = ModelRegistry::standard();
    let lineups: [&[&str]; 2] =
        [&["tt_layer", "fc_mnist"], &["tt_layer", "fc_mnist", "mnist_net"]];
    // (lineup, clients, max_batch): two-model interleave at two policies,
    // then the full three-model mix
    let sweep = [(0usize, 16usize, 8usize), (0, 16, 32), (1, 24, 8)];
    let mut entries = Vec::new();
    for (li, clients, max_batch) in sweep {
        let names = lineups[li];
        let models: Vec<(String, usize)> = names
            .iter()
            .map(|n| Ok((n.to_string(), registry.input_dim(n)?)))
            .collect::<Result<_>>()?;
        let cfg = ServerConfig {
            policy: BatchPolicy { max_batch, max_delay: Duration::from_millis(2) },
            queue_capacity: 4096,
            batch_queue_capacity: 16,
            executor_threads: 2,
            kernel_threads: 0,
            ..Default::default()
        };
        let kernel_threads = cfg.effective_kernel_threads();
        let reg = registry.clone();
        let server = Server::start(cfg, move || Ok(NativeExecutor::new(reg.clone())))?;
        // warm every model's lazy build out of the timed region (one
        // request each — one worker per model; the other pays its tiny
        // build on first traffic, as in the native sweep).  The warmup
        // is subtracted from the counters below so completed / batches /
        // rows / mean_batch reflect only the driven interleave; its e2e
        // sample (which includes the model build) cannot be removed from
        // the histogram — it surfaces only in max, and in p99 only when
        // a model sees fewer than ~100 requests, far below this suite's
        // request counts.
        for (name, dim) in &models {
            server.infer(name, vec![0.0; *dim])?;
        }
        let wall = drive_mixed_clients(&server, &models, n_requests, clients).max(1e-9);
        let st = server.stats();
        let served = st.completed.get().saturating_sub(models.len() as u64);
        let mut per_model = Vec::new();
        for (name, m) in st.per_model() {
            // minus this model's warmup: 1 completed request = 1
            // batch-of-1 (it ran alone, before the drive started)
            let completed = m.completed.get().saturating_sub(1);
            let batches = m.batches.get().saturating_sub(1);
            let rows = m.batched_rows.get().saturating_sub(1);
            let mut mo = BTreeMap::new();
            mo.insert("model".to_string(), Json::Str(name));
            mo.insert("completed".to_string(), num(completed as f64));
            mo.insert("errors".to_string(), num(m.errors.get() as f64));
            mo.insert("shed".to_string(), num(m.shed.get() as f64));
            mo.insert("batches".to_string(), num(batches as f64));
            mo.insert("rows".to_string(), num(rows as f64));
            mo.insert(
                "mean_batch".to_string(),
                num(if batches == 0 { 0.0 } else { rows as f64 / batches as f64 }),
            );
            mo.insert("p50_us".to_string(), num(m.e2e.quantile_us(0.5)));
            mo.insert("p99_us".to_string(), num(m.e2e.quantile_us(0.99)));
            per_model.push(Json::Obj(mo));
        }
        let mut obj = BTreeMap::new();
        obj.insert(
            "models".to_string(),
            Json::Arr(names.iter().map(|n| Json::Str(n.to_string())).collect()),
        );
        obj.insert("clients".to_string(), num(clients as f64));
        obj.insert("max_batch".to_string(), num(max_batch as f64));
        obj.insert("kernel_threads".to_string(), num(kernel_threads as f64));
        obj.insert("simd".to_string(), Json::Str(simd_name().to_string()));
        obj.insert("completed".to_string(), num(served as f64));
        obj.insert("errors".to_string(), num(st.errors.get() as f64));
        obj.insert("rejected".to_string(), num(st.rejected.get() as f64));
        obj.insert("req_per_s".to_string(), num(served as f64 / wall));
        // same warmup adjustment as the per-model numbers (one batch of
        // one row per model), so aggregate rows/batches reconcile with
        // the per_model entries in this same object
        let agg_batches = st.batches.get().saturating_sub(models.len() as u64);
        let agg_rows = st.batched_rows.get().saturating_sub(models.len() as u64);
        obj.insert(
            "mean_batch".to_string(),
            num(if agg_batches == 0 { 0.0 } else { agg_rows as f64 / agg_batches as f64 }),
        );
        obj.insert("per_model".to_string(), Json::Arr(per_model));
        // admission provenance: how the controller behaved during the
        // drive — at this sweep's defaults (no latency target, no
        // quotas) every field must read as "fixed capacity, no flips,
        // no sheds", and a regression that starts shedding or flipping
        // shows up in the trajectory JSON, not just in a failing test
        let adm = server.admission().snapshot();
        let mut ao = BTreeMap::new();
        ao.insert("capacity_final".to_string(), num(adm.capacity as f64));
        ao.insert("capacity_min".to_string(), num(adm.capacity_min as f64));
        ao.insert("capacity_max".to_string(), num(adm.capacity_max as f64));
        ao.insert("mode_flips".to_string(), num(adm.mode_flips as f64));
        ao.insert("quota_shed".to_string(), num(st.quota_shed.get() as f64));
        obj.insert("admission".to_string(), Json::Obj(ao));
        if verbose {
            let batches: Vec<String> = st
                .per_model()
                .iter()
                .map(|(n, m)| format!("{n} {:.1}", m.mean_batch_size()))
                .collect();
            println!(
                "  models={:<28} clients={clients:<3} max_batch={max_batch:<4} {:>9.0} req/s  mean batch per model: {}",
                names.join("+"),
                served as f64 / wall,
                batches.join("  "),
            );
        }
        entries.push(Json::Obj(obj));
    }
    Ok(entries)
}

/// Remote-TT serving sweep: the same native `tt_layer` model behind the
/// batcher, but reached over loopback TCP through the wire protocol —
/// swept over `(connections, max_batch, io_threads)`.  Against the
/// in-process `native_tt` sweep above, the delta is pure transport cost
/// (framing + two loopback hops + the reactor sweep), which is exactly
/// what EXPERIMENTS.md §Perf tracks for remote serving.  The high-fan-in
/// tail of the sweep (64 and 256 connections on 1–2 I/O threads) is the
/// regime the reactor exists for: the old thread-pair transport spent
/// 2×connections OS threads there, the reactor spends `io_threads` + 1
/// regardless — `transport_threads` is recorded in each entry so the
/// scaling is visible in `BENCH_coordinator.json`.
pub fn bench_remote_serving(n_requests: usize, verbose: bool) -> Result<Vec<Json>> {
    let registry = ModelRegistry::standard();
    let model = "tt_layer";
    let dim = registry.input_dim(model)?;
    let pipeline = 4usize;
    let sweep = [
        (1usize, 1usize, 1usize),
        (2, 32, 1),
        (4, 32, 1),
        (8, 32, 1),
        (64, 32, 1),
        (64, 32, 2),
        (256, 32, 1),
        (256, 32, 2),
    ];
    let mut entries = Vec::new();
    for (connections, max_batch, io_threads) in sweep {
        let cfg = ServerConfig {
            policy: BatchPolicy { max_batch, max_delay: Duration::from_micros(500) },
            queue_capacity: 4096,
            batch_queue_capacity: 16,
            executor_threads: 2,
            kernel_threads: 0,
            ..Default::default()
        };
        let kernel_threads = cfg.effective_kernel_threads();
        let reg = registry.clone();
        let server =
            Arc::new(Server::start(cfg, move || Ok(NativeExecutor::new(reg.clone())))?);
        let net = NetServer::start_with(
            server.clone(),
            "127.0.0.1:0",
            vec![ModelInfo {
                name: model.to_string(),
                input_dim: dim as u32,
                output_dim: dim as u32,
            }],
            io_threads,
        )?;
        let addr = net.local_addr().to_string();
        let transport_threads = net.transport_threads();
        // warm the lazily-built model out of the timed region (same
        // rationale as the native sweep; the warmup rides its own
        // connection so the timed clients start clean)
        Client::connect(&addr)?.infer(model, &vec![0.0; dim])?;
        let drive = drive_remote_clients(
            &addr,
            &[(model.to_string(), dim)],
            n_requests,
            connections,
            pipeline,
            None,
        );
        let st = server.stats();
        let mean_batch = st.mean_batch_size();
        net.shutdown();
        let failed_workers = server.stats().failed_workers.get();
        drop(server); // last Arc: joins batcher + executor pool
        let wall = drive.wall_seconds.max(1e-9);
        let mut obj = BTreeMap::new();
        obj.insert("model".to_string(), Json::Str(model.to_string()));
        obj.insert("connections".to_string(), num(connections as f64));
        obj.insert("max_batch".to_string(), num(max_batch as f64));
        obj.insert("pipeline".to_string(), num(pipeline as f64));
        obj.insert("io_threads".to_string(), num(io_threads as f64));
        obj.insert("transport_threads".to_string(), num(transport_threads as f64));
        obj.insert("kernel_threads".to_string(), num(kernel_threads as f64));
        obj.insert("simd".to_string(), Json::Str(simd_name().to_string()));
        obj.insert("completed".to_string(), num(drive.completed as f64));
        obj.insert("busy".to_string(), num(drive.busy as f64));
        obj.insert("failed".to_string(), num(drive.failed as f64));
        obj.insert("failed_workers".to_string(), num(failed_workers as f64));
        obj.insert("req_per_s".to_string(), num(drive.completed as f64 / wall));
        obj.insert("mean_batch".to_string(), num(mean_batch));
        // client-observed e2e: includes framing + both loopback hops
        obj.insert("p50_us".to_string(), num(drive.e2e.quantile_us(0.5)));
        obj.insert("p99_us".to_string(), num(drive.e2e.quantile_us(0.99)));
        if verbose {
            println!(
                "  conns={connections:<4} max_batch={max_batch:<4} io={io_threads} {:>9.0} req/s  mean batch {:.1}  p50 {:.0}µs p99 {:.0}µs  busy {}",
                drive.completed as f64 / wall,
                mean_batch,
                drive.e2e.quantile_us(0.5),
                drive.e2e.quantile_us(0.99),
                drive.busy,
            );
        }
        entries.push(Json::Obj(obj));
    }
    Ok(entries)
}

/// Sharded serving sweep: N independent shard stacks (each a full
/// `Server` + `NetServer` on its own loopback port — separate batcher,
/// admission queue and executor pool, i.e. everything that makes a
/// process a process short of the address-space boundary) behind one
/// [`ShardRouter`], driven through the router over loopback TCP.
/// Swept over `(shards, connections, max_batch)` with the 1-shard row
/// as the router-overhead baseline: against the same `(connections,
/// max_batch)` row of `remote_tt`, its delta is the router hop; against
/// the 2- and 4-shard rows, the scaling curve is the tentpole claim —
/// aggregate req/s growing near-linearly with shard count once the
/// offered load (connections × pipeline) saturates a single shard.
/// Each entry records per-shard provenance (placement, forwarded /
/// completed counts, failovers) from [`ShardRouter::shard_snapshots`],
/// so a skewed dispatch or a mid-run failover is visible in the JSON,
/// not just in the aggregate.
pub fn bench_sharded_serving(n_requests: usize, verbose: bool) -> Result<Vec<Json>> {
    let registry = ModelRegistry::standard();
    let model = "tt_layer";
    let dim = registry.input_dim(model)?;
    let pipeline = 4usize;
    let lineup = vec![ModelInfo {
        name: model.to_string(),
        input_dim: dim as u32,
        output_dim: dim as u32,
    }];
    // (shards, connections, max_batch): the 16-connection column is the
    // scaling read 1 -> 2 -> 4; the 64-connection rows probe the
    // high-fan-in regime where the router's single reactor thread fronts
    // every downstream connection
    let sweep = [
        (1usize, 16usize, 32usize),
        (2, 16, 32),
        (4, 16, 32),
        (1, 64, 32),
        (4, 64, 32),
    ];
    let mut entries = Vec::new();
    for (n_shards, connections, max_batch) in sweep {
        let mut shards = Vec::with_capacity(n_shards);
        for _ in 0..n_shards {
            let cfg = ServerConfig {
                policy: BatchPolicy { max_batch, max_delay: Duration::from_micros(500) },
                queue_capacity: 4096,
                batch_queue_capacity: 16,
                executor_threads: 2,
                kernel_threads: 0,
                ..Default::default()
            };
            let reg = registry.clone();
            let server =
                Arc::new(Server::start(cfg, move || Ok(NativeExecutor::new(reg.clone())))?);
            let net = NetServer::start_with(server.clone(), "127.0.0.1:0", lineup.clone(), 1)?;
            shards.push((server, net));
        }
        let shard_addrs: Vec<String> =
            shards.iter().map(|(_, net)| net.local_addr().to_string()).collect();
        // warm every shard's lazily-built model out of the timed region
        for addr in &shard_addrs {
            Client::connect(addr)?.infer(model, &vec![0.0; dim])?;
        }
        let router = ShardRouter::start(
            RouterConfig {
                shards: shard_addrs,
                replicas: 0,
                io_threads: 1,
                connect_timeout: Duration::from_secs(5),
            },
            "127.0.0.1:0",
        )?;
        let addr = router.local_addr().to_string();
        let drive = drive_remote_clients(
            &addr,
            &[(model.to_string(), dim)],
            n_requests,
            connections,
            pipeline,
            None,
        );
        let router_stats = router.remote_stats();
        let snaps = router.shard_snapshots();
        router.shutdown();
        for (server, net) in shards {
            net.shutdown();
            drop(server); // last Arc: joins batcher + executor pool
        }
        let wall = drive.wall_seconds.max(1e-9);
        let mut obj = BTreeMap::new();
        obj.insert("model".to_string(), Json::Str(model.to_string()));
        obj.insert("shards".to_string(), num(n_shards as f64));
        obj.insert("connections".to_string(), num(connections as f64));
        obj.insert("max_batch".to_string(), num(max_batch as f64));
        obj.insert("pipeline".to_string(), num(pipeline as f64));
        obj.insert("simd".to_string(), Json::Str(simd_name().to_string()));
        obj.insert("completed".to_string(), num(drive.completed as f64));
        obj.insert("busy".to_string(), num(drive.busy as f64));
        obj.insert("failed".to_string(), num(drive.failed as f64));
        obj.insert("router_errors".to_string(), num(router_stats.errors as f64));
        obj.insert("req_per_s".to_string(), num(drive.completed as f64 / wall));
        obj.insert("p50_us".to_string(), num(drive.e2e.quantile_us(0.5)));
        obj.insert("p99_us".to_string(), num(drive.e2e.quantile_us(0.99)));
        // per-shard provenance: who was placed where and how the load
        // actually split
        let shard_entries: Vec<Json> = snaps
            .iter()
            .map(|s| {
                let mut so = BTreeMap::new();
                so.insert("addr".to_string(), Json::Str(s.addr.clone()));
                so.insert("models".to_string(), Json::Str(s.models.join(",")));
                so.insert("replicas_of".to_string(), num(s.models.len() as f64));
                so.insert("forwarded".to_string(), num(s.forwarded as f64));
                so.insert("completed".to_string(), num(s.completed as f64));
                so.insert("errors".to_string(), num(s.errors as f64));
                so.insert("busy".to_string(), num(s.busy as f64));
                so.insert("failovers".to_string(), num(s.failovers as f64));
                so.insert("healthy".to_string(), Json::Bool(s.healthy));
                Json::Obj(so)
            })
            .collect();
        obj.insert("shard_stats".to_string(), Json::Arr(shard_entries));
        if verbose {
            println!(
                "  shards={n_shards} conns={connections:<4} max_batch={max_batch:<4} {:>9.0} req/s  p50 {:.0}µs p99 {:.0}µs  busy {}",
                drive.completed as f64 / wall,
                drive.e2e.quantile_us(0.5),
                drive.e2e.quantile_us(0.99),
                drive.busy,
            );
        }
        entries.push(Json::Obj(obj));
    }
    Ok(entries)
}

/// Overload / fairness sweep (`overload_tt`): one hot tenant
/// (`tt_layer`) pushed at 1x/4x/16x its baseline offered load against
/// two background tenants (`fc_mnist`, `mnist_net`) that stay inside
/// their reserved quotas, all through one admission-controlled server
/// over loopback TCP.  The fairness claim this pins: the hot tenant's
/// excess is absorbed as typed shed — its reservation and the free
/// pool exhaust while other models' reservations stay untouchable —
/// so every background request completes at every multiplier
/// (capacity never resizes below Σ reservations).  Each entry records
/// per-tenant client-side counters, the server's per-model shed
/// counts, and the admission controller's provenance (capacity
/// min/max/final, queue-mode flips, quota sheds), so
/// `BENCH_coordinator.json` shows not just that fairness held but
/// what the controller did to hold it.
pub fn bench_overload_serving(n_requests: usize, verbose: bool) -> Result<Vec<Json>> {
    let registry = ModelRegistry::standard();
    let hot = "tt_layer";
    let tenants = [hot, "fc_mnist", "mnist_net"];
    // hot reserves 4 tickets, each background 8; capacity 32 leaves a
    // 12-ticket free pool the hot tenant may borrow before it sheds
    let capacity = 32usize;
    let quotas: Vec<(String, usize)> =
        vec![(hot.into(), 4), ("fc_mnist".into(), 8), ("mnist_net".into(), 8)];
    let mut lineup = Vec::with_capacity(tenants.len());
    for name in tenants {
        let spec = registry.spec(name)?;
        lineup.push(ModelInfo {
            name: name.to_string(),
            input_dim: spec.input_dim() as u32,
            output_dim: spec.output_dim() as u32,
        });
    }
    let mut entries = Vec::new();
    for hot_mult in [1usize, 4, 16] {
        let cfg = ServerConfig {
            policy: BatchPolicy { max_batch: 8, max_delay: Duration::from_micros(500) },
            queue_capacity: capacity,
            batch_queue_capacity: 16,
            executor_threads: 2,
            kernel_threads: 0,
            admission: AdmissionConfig {
                latency_target_ms: 50,
                quotas: quotas.clone(),
                ..Default::default()
            },
        };
        let kernel_threads = cfg.effective_kernel_threads();
        let reg = registry.clone();
        let server =
            Arc::new(Server::start(cfg, move || Ok(NativeExecutor::new(reg.clone())))?);
        let net = NetServer::start_with(server.clone(), "127.0.0.1:0", lineup.clone(), 1)?;
        let addr = net.local_addr().to_string();
        // warm every model's lazy build out of the timed region
        for m in &lineup {
            Client::connect(&addr)?.infer(&m.name, &vec![0.0; m.input_dim as usize])?;
        }
        // hot tenant: offered in-flight (connections × pipeline) scales
        // with the multiplier; backgrounds: 2 connections × 2 in flight
        // = 4 concurrent, well inside their 8-ticket reservations, so
        // every one of their requests must admit and complete
        let (hot_conns, hot_pipeline) = (4usize, 2 * hot_mult);
        let (bg_conns, bg_pipeline) = (2usize, 2usize);
        // (model, dim, requests, connections, pipeline) per tenant
        let plan: Vec<(String, usize, usize, usize, usize)> = lineup
            .iter()
            .map(|m| {
                let dim = m.input_dim as usize;
                if m.name == hot {
                    (m.name.clone(), dim, n_requests * hot_mult, hot_conns, hot_pipeline)
                } else {
                    (m.name.clone(), dim, n_requests, bg_conns, bg_pipeline)
                }
            })
            .collect();
        let t0 = Instant::now();
        let drives: Vec<RemoteDrive> = std::thread::scope(|s| {
            let handles: Vec<_> = plan
                .iter()
                .map(|(model, dim, reqs, conns, pipe)| {
                    let addr = &addr;
                    s.spawn(move || {
                        drive_remote_clients(
                            addr,
                            &[(model.clone(), *dim)],
                            *reqs,
                            *conns,
                            *pipe,
                            None,
                        )
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("tenant driver panicked")).collect()
        });
        let wall = t0.elapsed().as_secs_f64().max(1e-9);
        let st = server.stats();
        let adm = server.admission().snapshot();
        let quota_shed = st.quota_shed.get();
        let shed_by_model: Vec<(String, u64)> =
            st.per_model().iter().map(|(n, m)| (n.clone(), m.shed.get())).collect();
        net.shutdown();
        drop(server); // last Arc: joins batcher + executor pool
        let mut total_completed = 0u64;
        let mut tenant_entries = Vec::new();
        for ((model, _dim, reqs, conns, pipe), drive) in plan.iter().zip(&drives) {
            let shed = shed_by_model
                .iter()
                .find(|(n, _)| n == model)
                .map(|(_, s)| *s)
                .unwrap_or(0);
            let mut to = BTreeMap::new();
            to.insert("model".to_string(), Json::Str(model.clone()));
            to.insert(
                "role".to_string(),
                Json::Str(if model == hot { "hot" } else { "background" }.to_string()),
            );
            to.insert("requests".to_string(), num(*reqs as f64));
            to.insert("connections".to_string(), num(*conns as f64));
            to.insert("pipeline".to_string(), num(*pipe as f64));
            to.insert("completed".to_string(), num(drive.completed as f64));
            to.insert("busy".to_string(), num(drive.busy as f64));
            to.insert("failed".to_string(), num(drive.failed as f64));
            // server-side shed for this model (client `busy` seen from
            // the other end of the wire; the two agree when no
            // connection died mid-drive)
            to.insert("shed".to_string(), num(shed as f64));
            to.insert("p50_us".to_string(), num(drive.e2e.quantile_us(0.5)));
            to.insert("p99_us".to_string(), num(drive.e2e.quantile_us(0.99)));
            total_completed += drive.completed;
            tenant_entries.push(Json::Obj(to));
        }
        let mut obj = BTreeMap::new();
        obj.insert("hot_mult".to_string(), num(hot_mult as f64));
        obj.insert("hot_model".to_string(), Json::Str(hot.to_string()));
        obj.insert("capacity".to_string(), num(capacity as f64));
        obj.insert(
            "quotas".to_string(),
            Json::Str("tt_layer=4,fc_mnist=8,mnist_net=8".to_string()),
        );
        obj.insert("latency_target_ms".to_string(), num(50.0));
        obj.insert("max_batch".to_string(), num(8.0));
        obj.insert("kernel_threads".to_string(), num(kernel_threads as f64));
        obj.insert("simd".to_string(), Json::Str(simd_name().to_string()));
        obj.insert("req_per_s".to_string(), num(total_completed as f64 / wall));
        obj.insert("tenants".to_string(), Json::Arr(tenant_entries));
        let mut ao = BTreeMap::new();
        ao.insert("capacity_final".to_string(), num(adm.capacity as f64));
        ao.insert("capacity_min".to_string(), num(adm.capacity_min as f64));
        ao.insert("capacity_max".to_string(), num(adm.capacity_max as f64));
        ao.insert("mode_flips".to_string(), num(adm.mode_flips as f64));
        ao.insert(
            "mode_final".to_string(),
            Json::Str(
                match adm.mode {
                    QueueMode::Fifo => "fifo",
                    QueueMode::Lifo => "lifo",
                }
                .to_string(),
            ),
        );
        ao.insert("quota_shed".to_string(), num(quota_shed as f64));
        obj.insert("admission".to_string(), Json::Obj(ao));
        if verbose {
            let hot_drive = &drives[0]; // plan order follows `tenants`: hot first
            println!(
                "  hot x{hot_mult:<3} {:>9.0} req/s total  hot completed {} busy {}  quota_shed {}  capacity [{}..{}] flips {}",
                total_completed as f64 / wall,
                hot_drive.completed,
                hot_drive.busy,
                quota_shed,
                adm.capacity_min,
                adm.capacity_max,
                adm.mode_flips,
            );
        }
        entries.push(Json::Obj(obj));
    }
    Ok(entries)
}

/// Wrap entries in the report envelope: suite name + environment.
pub fn report(suite: &str, quick: bool, sections: Vec<(&str, Vec<Json>)>) -> Json {
    let mut obj = BTreeMap::new();
    obj.insert("bench".to_string(), Json::Str(suite.to_string()));
    obj.insert("quick".to_string(), Json::Bool(quick));
    obj.insert("threads".to_string(), num(num_threads() as f64));
    obj.insert("simd".to_string(), Json::Str(simd_name().to_string()));
    let unix = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    obj.insert("unix_time".to_string(), num(unix as f64));
    for (name, entries) in sections {
        obj.insert(name.to_string(), Json::Arr(entries));
    }
    Json::Obj(obj)
}

/// Write one report to `<dir>/<file>` (compact JSON + trailing newline).
pub fn write_report(dir: &Path, file: &str, json: &Json) -> Result<PathBuf> {
    let path = dir.join(file);
    std::fs::write(&path, json.to_string() + "\n")?;
    Ok(path)
}

/// The `tensornet bench` entry point: run every suite and emit
/// `BENCH_tt_matvec.json` + `BENCH_coordinator.json` into `out_dir`.
/// Returns the written paths.
pub fn run_bench_suite(quick: bool, out_dir: &Path, verbose: bool) -> Result<Vec<PathBuf>> {
    // fail on an unwritable destination BEFORE spending minutes measuring
    std::fs::create_dir_all(out_dir)?;
    let bencher = if quick { Bencher::quick() } else { Bencher::default() };
    let cases = default_matvec_cases(quick);
    let (n_requests, clients) = if quick { (2_000, 8) } else { (10_000, 8) };

    if verbose {
        println!("== TT matvec vs dense GEMM ({} configurations)", cases.len());
    }
    let matvec = bench_tt_matvec(&bencher, &cases, verbose)?;
    if verbose {
        println!("== TT-SVD decomposition");
    }
    let ttsvd = bench_ttsvd(&bencher, verbose)?;
    let tt_report = report(
        "tt_matvec",
        quick,
        vec![("entries", matvec), ("ttsvd", ttsvd)],
    );

    if verbose {
        println!("== coordinator policy sweep (echo backend, {clients} clients)");
    }
    let coord = bench_coordinator(n_requests, clients, verbose)?;
    if verbose {
        println!("== native TT serving sweep (executor_threads x max_batch, {clients} clients)");
    }
    let native_requests = if quick { 1_000 } else { 5_000 };
    let native = bench_native_serving(native_requests, clients, verbose)?;
    if verbose {
        println!("== model-family serving sweep (tt_conv / bt / tt through one policy)");
    }
    // smaller count: the conv and BT models do real per-row work (im2col
    // + TT contraction; three matmuls per block), unlike the bare matvec
    let conv_requests = if quick { 400 } else { 2_000 };
    let conv = bench_conv_serving(conv_requests, clients, verbose)?;
    if verbose {
        println!("== mixed-model serving sweep (models x clients x max_batch, interleaved)");
    }
    let mixed = bench_mixed_serving(native_requests, verbose)?;
    if verbose {
        println!("== remote TT serving sweep (connections x max_batch x io_threads, loopback TCP)");
    }
    let remote = bench_remote_serving(native_requests, verbose)?;
    if verbose {
        println!("== sharded TT serving sweep (shards x connections x max_batch, router tier)");
    }
    let sharded = bench_sharded_serving(native_requests, verbose)?;
    if verbose {
        println!("== overload fairness sweep (hot tenant at 1x/4x/16x vs quota'd background)");
    }
    // smaller base count: the hot tenant multiplies it up to 16x, and
    // under shed-then-backoff each connection deliberately paces itself
    let overload_requests = if quick { 300 } else { 1_000 };
    let overload = bench_overload_serving(overload_requests, verbose)?;
    let coord_report = report(
        "coordinator",
        quick,
        vec![
            ("entries", coord),
            ("native_tt", native),
            ("conv_tt", conv),
            ("mixed_tt", mixed),
            ("remote_tt", remote),
            ("sharded_tt", sharded),
            ("overload_tt", overload),
        ],
    );

    let paths = vec![
        write_report(out_dir, "BENCH_tt_matvec.json", &tt_report)?,
        write_report(out_dir, "BENCH_coordinator.json", &coord_report)?,
    ];
    Ok(paths)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn tiny_bencher() -> Bencher {
        Bencher {
            warmup: Duration::from_millis(0),
            target_time: Duration::from_millis(1),
            min_iters: 1,
            max_iters: 3,
        }
    }

    fn tiny_cases() -> Vec<MatvecCase> {
        vec![
            MatvecCase::new("tiny r1 b1", &[2, 2], &[2, 2], 1, 1),
            MatvecCase::new("tiny r2 b2", &[2, 2], &[2, 2], 2, 2),
            MatvecCase::new("tiny r2 b4", &[2, 2], &[2, 2], 2, 4),
        ]
    }

    #[test]
    fn matvec_entries_have_dense_and_tt_timings() {
        let entries = bench_tt_matvec(&tiny_bencher(), &tiny_cases(), false).unwrap();
        assert_eq!(entries.len(), 3);
        for e in &entries {
            assert!(e.get("dense").unwrap().get("mean_ms").unwrap().as_f64().unwrap() >= 0.0);
            assert!(e.get("tt").unwrap().get("mean_ms").unwrap().as_f64().unwrap() >= 0.0);
            assert!(e.get("rank").unwrap().as_usize().is_some());
            assert!(e.get("batch").unwrap().as_usize().is_some());
            // kernel provenance: every entry records which dispatch path
            // produced it and the thread budget the helpers saw
            let simd = e.get("simd").unwrap().as_str().unwrap();
            assert!(simd == "avx2+fma" || simd == "scalar", "{simd}");
            assert!(e.get("kernel_threads").unwrap().as_usize().unwrap() >= 1);
        }
        // the three (rank, batch) configurations are distinct
        let keys: Vec<(usize, usize)> = entries
            .iter()
            .map(|e| {
                (
                    e.get("rank").unwrap().as_usize().unwrap(),
                    e.get("batch").unwrap().as_usize().unwrap(),
                )
            })
            .collect();
        let mut dedup = keys.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), keys.len());
    }

    #[test]
    fn report_envelope_roundtrips() {
        let entries = bench_ttsvd(&tiny_bencher(), false).unwrap();
        let r = report("tt_matvec", true, vec![("ttsvd", entries)]);
        let text = r.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.get("bench").unwrap().as_str(), Some("tt_matvec"));
        assert!(back.get("ttsvd").unwrap().as_arr().unwrap().len() == 2);
        let simd = back.get("simd").unwrap().as_str().unwrap().to_string();
        assert!(simd == "avx2+fma" || simd == "scalar", "{simd}");
    }

    #[test]
    fn native_serving_sweep_covers_thread_scaling() {
        let entries = bench_native_serving(24, 3, false).unwrap();
        assert_eq!(entries.len(), 4);
        let threads: Vec<usize> = entries
            .iter()
            .map(|e| e.get("executor_threads").unwrap().as_usize().unwrap())
            .collect();
        assert!(threads.contains(&1) && threads.iter().any(|&t| t > 1), "{threads:?}");
        for e in &entries {
            assert_eq!(e.get("errors").unwrap().as_usize(), Some(0));
            assert_eq!(e.get("completed").unwrap().as_usize(), Some(24));
            assert!(e.get("req_per_s").unwrap().as_f64().unwrap() > 0.0);
            assert_eq!(e.get("model").unwrap().as_str(), Some("tt_layer"));
            // load-shedding visibility: every entry carries the counters
            assert_eq!(e.get("rejected").unwrap().as_usize(), Some(0));
            assert_eq!(e.get("failed_workers").unwrap().as_usize(), Some(0));
            // kernel provenance: budget >= 1 always, and the auto split
            // never hands one worker more than the whole machine
            let kt = e.get("kernel_threads").unwrap().as_usize().unwrap();
            assert!((1..=num_threads()).contains(&kt), "{kt}");
            assert!(e.get("simd").unwrap().as_str().is_some());
        }
    }

    #[test]
    fn conv_family_sweep_records_family_provenance() {
        let entries = bench_conv_serving(12, 3, false).unwrap();
        assert_eq!(entries.len(), 3);
        let families: Vec<String> = entries
            .iter()
            .map(|e| e.get("family").unwrap().as_str().unwrap().to_string())
            .collect();
        assert_eq!(families, vec!["tt_conv", "bt", "tt"], "one entry per storage family");
        for e in &entries {
            assert_eq!(e.get("errors").unwrap().as_usize(), Some(0));
            assert_eq!(e.get("completed").unwrap().as_usize(), Some(12));
            assert_eq!(e.get("rejected").unwrap().as_usize(), Some(0));
            assert_eq!(e.get("failed_workers").unwrap().as_usize(), Some(0));
            assert!(e.get("req_per_s").unwrap().as_f64().unwrap() > 0.0);
            assert!(e.get("model").unwrap().as_str().is_some());
            // same provenance contract as every serving sweep
            let kt = e.get("kernel_threads").unwrap().as_usize().unwrap();
            assert!((1..=num_threads()).contains(&kt), "{kt}");
            assert!(e.get("simd").unwrap().as_str().is_some());
        }
    }

    #[test]
    fn mixed_serving_sweep_reports_per_model_batch_sizes() {
        let entries = bench_mixed_serving(48, false).unwrap();
        assert_eq!(entries.len(), 3);
        for e in &entries {
            let names = e.get("models").unwrap().as_arr().unwrap();
            assert!(names.len() >= 2, "mixed sweep must interleave >= 2 models");
            assert_eq!(e.get("errors").unwrap().as_usize(), Some(0));
            assert_eq!(e.get("rejected").unwrap().as_usize(), Some(0));
            assert_eq!(e.get("completed").unwrap().as_usize(), Some(48));
            assert!(e.get("req_per_s").unwrap().as_f64().unwrap() > 0.0);
            let per_model = e.get("per_model").unwrap().as_arr().unwrap();
            assert_eq!(per_model.len(), names.len());
            assert!(e.get("kernel_threads").unwrap().as_usize().unwrap() >= 1);
            assert!(e.get("simd").unwrap().as_str().is_some());
            let mut completed_sum = 0usize;
            for m in per_model {
                assert!(m.get("model").unwrap().as_str().is_some());
                completed_sum += m.get("completed").unwrap().as_usize().unwrap();
                assert_eq!(m.get("errors").unwrap().as_usize(), Some(0));
                assert_eq!(m.get("shed").unwrap().as_usize(), Some(0));
                assert!(m.get("mean_batch").unwrap().as_f64().unwrap() > 0.0);
                assert!(m.get("batches").unwrap().as_usize().unwrap() >= 1);
            }
            assert_eq!(completed_sum, 48, "per-model completions must cover the drive");
            // admission provenance at this sweep's defaults: the
            // controller must be indistinguishable from the old fixed
            // bounded queue — constant capacity, no flips, no sheds
            let adm = e.get("admission").unwrap();
            assert_eq!(adm.get("capacity_final").unwrap().as_usize(), Some(4096));
            assert_eq!(adm.get("capacity_min").unwrap().as_usize(), Some(4096));
            assert_eq!(adm.get("capacity_max").unwrap().as_usize(), Some(4096));
            assert_eq!(adm.get("mode_flips").unwrap().as_usize(), Some(0));
            assert_eq!(adm.get("quota_shed").unwrap().as_usize(), Some(0));
        }
        // the lineup grows across the sweep (2, 2, 3 models)
        let sizes: Vec<usize> = entries
            .iter()
            .map(|e| e.get("models").unwrap().as_arr().unwrap().len())
            .collect();
        assert!(sizes.contains(&2) && sizes.contains(&3), "{sizes:?}");
    }

    #[test]
    fn remote_serving_sweep_covers_connection_scaling() {
        let entries = bench_remote_serving(24, false).unwrap();
        assert_eq!(entries.len(), 8);
        let conns: Vec<usize> = entries
            .iter()
            .map(|e| e.get("connections").unwrap().as_usize().unwrap())
            .collect();
        // the sweep must reach the high-fan-in regime the reactor is for
        assert!(conns.contains(&1) && conns.contains(&256), "{conns:?}");
        for e in &entries {
            assert_eq!(e.get("failed").unwrap().as_usize(), Some(0));
            assert_eq!(e.get("failed_workers").unwrap().as_usize(), Some(0));
            // every request either completed or was load-shed with Busy
            let done = e.get("completed").unwrap().as_usize().unwrap()
                + e.get("busy").unwrap().as_usize().unwrap();
            assert_eq!(done, 24);
            assert!(e.get("completed").unwrap().as_usize().unwrap() > 0);
            assert!(e.get("req_per_s").unwrap().as_f64().unwrap() > 0.0);
            assert!(e.get("p99_us").unwrap().as_f64().unwrap() > 0.0);
            // thread accounting: the transport spends io_threads + accept,
            // never 2x connections
            let io = e.get("io_threads").unwrap().as_usize().unwrap();
            assert!(io >= 1);
            assert_eq!(e.get("transport_threads").unwrap().as_usize(), Some(io + 1));
            assert!(e.get("kernel_threads").unwrap().as_usize().unwrap() >= 1);
            assert!(e.get("simd").unwrap().as_str().is_some());
        }
    }

    #[test]
    fn sharded_serving_sweep_records_shard_provenance() {
        let entries = bench_sharded_serving(24, false).unwrap();
        assert_eq!(entries.len(), 5);
        let shard_counts: Vec<usize> =
            entries.iter().map(|e| e.get("shards").unwrap().as_usize().unwrap()).collect();
        // the sweep must cover the 1 -> 2 -> 4 scaling read
        assert!(
            shard_counts.contains(&1) && shard_counts.contains(&2) && shard_counts.contains(&4),
            "{shard_counts:?}"
        );
        for e in &entries {
            let n_shards = e.get("shards").unwrap().as_usize().unwrap();
            assert_eq!(e.get("failed").unwrap().as_usize(), Some(0));
            assert_eq!(e.get("router_errors").unwrap().as_usize(), Some(0));
            // every request either completed or was load-shed upstream
            let done = e.get("completed").unwrap().as_usize().unwrap()
                + e.get("busy").unwrap().as_usize().unwrap();
            assert_eq!(done, 24);
            assert!(e.get("completed").unwrap().as_usize().unwrap() > 0);
            assert!(e.get("req_per_s").unwrap().as_f64().unwrap() > 0.0);
            // per-shard provenance: one block per shard, placement
            // recorded, counts consistent with the drive
            let shard_stats = e.get("shard_stats").unwrap().as_arr().unwrap();
            assert_eq!(shard_stats.len(), n_shards);
            let mut forwarded_sum = 0usize;
            for s in shard_stats {
                assert!(s.get("addr").unwrap().as_str().is_some());
                assert!(
                    s.get("models").unwrap().as_str().unwrap().contains("tt_layer"),
                    "every shard advertises the zoo, so every shard is placed"
                );
                forwarded_sum += s.get("forwarded").unwrap().as_usize().unwrap();
                assert_eq!(s.get("failovers").unwrap().as_usize(), Some(0));
                assert_eq!(s.get("healthy").unwrap().as_bool(), Some(true));
            }
            assert_eq!(forwarded_sum, done, "shard forwards must cover the drive");
        }
    }

    #[test]
    fn overload_sweep_keeps_background_tenants_whole() {
        let entries = bench_overload_serving(8, false).unwrap();
        assert_eq!(entries.len(), 3);
        let mults: Vec<usize> =
            entries.iter().map(|e| e.get("hot_mult").unwrap().as_usize().unwrap()).collect();
        assert_eq!(mults, vec![1, 4, 16]);
        for e in &entries {
            assert!(e.get("req_per_s").unwrap().as_f64().unwrap() > 0.0);
            let tenants = e.get("tenants").unwrap().as_arr().unwrap();
            assert_eq!(tenants.len(), 3);
            for t in tenants {
                let requests = t.get("requests").unwrap().as_usize().unwrap();
                let completed = t.get("completed").unwrap().as_usize().unwrap();
                let busy = t.get("busy").unwrap().as_usize().unwrap();
                assert_eq!(t.get("failed").unwrap().as_usize(), Some(0));
                // every request either completed or was typed-shed
                assert_eq!(completed + busy, requests);
                if t.get("role").unwrap().as_str() == Some("background") {
                    // the fairness claim: reservations keep background
                    // tenants whole no matter how hard the hot one pushes
                    assert_eq!(completed, requests, "background tenant was shed");
                    assert_eq!(t.get("shed").unwrap().as_usize(), Some(0));
                }
            }
            // admission provenance travels with every entry; capacity
            // never resizes below Σ reservations (4 + 8 + 8) and never
            // above the 4x auto ceiling
            let adm = e.get("admission").unwrap();
            assert!(adm.get("capacity_min").unwrap().as_usize().unwrap() >= 20);
            assert!(adm.get("capacity_max").unwrap().as_usize().unwrap() <= 128);
            let mode = adm.get("mode_final").unwrap().as_str().unwrap();
            assert!(mode == "fifo" || mode == "lifo", "{mode}");
        }
        // at 16x the hot tenant must actually shed, typed against its
        // quota (it exhausted its reservation plus the free pool)
        let tenants16 = entries[2].get("tenants").unwrap().as_arr().unwrap();
        let hot16 = &tenants16[0];
        assert_eq!(hot16.get("role").unwrap().as_str(), Some("hot"));
        assert!(hot16.get("busy").unwrap().as_usize().unwrap() > 0, "16x overload must shed");
        assert!(hot16.get("shed").unwrap().as_usize().unwrap() > 0);
        let adm16 = entries[2].get("admission").unwrap();
        assert!(adm16.get("quota_shed").unwrap().as_usize().unwrap() > 0);
    }

    #[test]
    fn coordinator_bench_small_sweep() {
        let entries = bench_coordinator(60, 3, false).unwrap();
        assert_eq!(entries.len(), 3);
        for e in &entries {
            assert_eq!(e.get("errors").unwrap().as_usize(), Some(0));
            assert!(e.get("req_per_s").unwrap().as_f64().unwrap() > 0.0);
        }
    }

    #[test]
    fn write_report_emits_parseable_file() {
        let dir = std::env::temp_dir()
            .join(format!("tensornet_bench_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let entries = bench_tt_matvec(&tiny_bencher(), &tiny_cases(), false).unwrap();
        let r = report("tt_matvec", true, vec![("entries", entries)]);
        let path = write_report(&dir, "BENCH_tt_matvec.json", &r).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let parsed = Json::parse(text.trim()).unwrap();
        assert!(parsed.get("entries").unwrap().as_arr().unwrap().len() >= 3);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn default_grid_covers_three_rank_batch_configs() {
        // the acceptance bar: >= 3 (rank, batch) configurations, both
        // quick and full
        assert!(default_matvec_cases(true).len() >= 3);
        assert!(default_matvec_cases(false).len() > default_matvec_cases(true).len());
    }
}
