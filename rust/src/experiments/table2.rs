//! E6 — Table 2: vgg-16/19 compression from substituting fc6 (and fc7)
//! with TT-layers, vs matrix-rank (MR) baselines.
//!
//! The compression columns are exact arithmetic over the published vgg
//! architectures and are reproduced exactly.  The accuracy columns need
//! ImageNet; we run the same architectures' *tails* on a 1/4-scale
//! synthetic fc6-feature proxy (DESIGN.md §Substitutions) and report the
//! error *ordering*, which is the transferable claim (TT4 ≲ TT2 < TT1 ≪
//! MR at matched compression).

use crate::data::{synth_features, FeatureSpec};
use crate::error::Result;
use crate::nn::{low_rank_pair, Dense, Relu, SgdConfig, Sequential, TrainConfig, Trainer, TtLinear};
use crate::tt::TtShape;
use crate::util::rng::Rng;

/// Published vgg FC-part geometry (both networks share it).
#[derive(Clone, Copy, Debug)]
pub struct VggFcGeometry {
    pub fc6: (usize, usize), // 25088 -> 4096
    pub fc7: (usize, usize), // 4096 -> 4096
    pub fc8: (usize, usize), // 4096 -> 1000
}

pub const VGG_FC: VggFcGeometry =
    VggFcGeometry { fc6: (25088, 4096), fc7: (4096, 4096), fc8: (4096, 1000) };

/// Conv-part parameter counts from the published architectures.
pub fn vgg_conv_params(layers19: bool) -> usize {
    let cfg16: &[(usize, usize)] = &[
        (3, 64),
        (64, 64),
        (64, 128),
        (128, 128),
        (128, 256),
        (256, 256),
        (256, 256),
        (256, 512),
        (512, 512),
        (512, 512),
        (512, 512),
        (512, 512),
        (512, 512),
    ];
    let cfg19: &[(usize, usize)] = &[
        (3, 64),
        (64, 64),
        (64, 128),
        (128, 128),
        (128, 256),
        (256, 256),
        (256, 256),
        (256, 256),
        (256, 512),
        (512, 512),
        (512, 512),
        (512, 512),
        (512, 512),
        (512, 512),
        (512, 512),
        (512, 512),
    ];
    let cfg = if layers19 { cfg19 } else { cfg16 };
    cfg.iter().map(|&(i, o)| 3 * 3 * i * o + o).sum()
}

fn fc_params((n, m): (usize, usize)) -> usize {
    n * m + m
}

/// The paper's fc6 TT reshape (§6.3).
pub fn fc6_tt_shape(rank: usize) -> Result<TtShape> {
    TtShape::uniform(&[4, 4, 4, 4, 4, 4], &[2, 7, 8, 8, 7, 4], rank)
}

/// fc7 (4096 x 4096) TT reshape used for the "TT4 TT4 FC" row.
pub fn fc7_tt_shape(rank: usize) -> Result<TtShape> {
    TtShape::uniform(&[4; 6], &[4; 6], rank)
}

/// One Table-2 row.
#[derive(Clone, Debug)]
pub struct Table2Row {
    pub arch: String,
    /// compression of the substituted matrices (paper col 2)
    pub layer_compression: f64,
    pub vgg16_compression: f64,
    pub vgg19_compression: f64,
    /// proxy test error (ordering is the reproducible claim), NaN if the
    /// accuracy pass was skipped
    pub proxy_error: f32,
}

/// Compression columns (exact; independent of any data).
pub fn compression_rows() -> Result<Vec<Table2Row>> {
    let dense6 = (VGG_FC.fc6.0 * VGG_FC.fc6.1) as f64;
    let dense7 = (VGG_FC.fc7.0 * VGG_FC.fc7.1) as f64;
    let full_fc: usize =
        fc_params(VGG_FC.fc6) + fc_params(VGG_FC.fc7) + fc_params(VGG_FC.fc8);
    let total16 = vgg_conv_params(false) + full_fc;
    let total19 = vgg_conv_params(true) + full_fc;

    let net_compr = |replaced_fc6: usize, replaced_fc7: Option<usize>| -> (f64, f64) {
        let new_fc = replaced_fc6
            + VGG_FC.fc6.1 // fc6 bias stays
            + replaced_fc7.unwrap_or(VGG_FC.fc7.0 * VGG_FC.fc7.1)
            + VGG_FC.fc7.1
            + fc_params(VGG_FC.fc8);
        (
            total16 as f64 / (vgg_conv_params(false) + new_fc) as f64,
            total19 as f64 / (vgg_conv_params(true) + new_fc) as f64,
        )
    };

    let mut rows = Vec::new();
    rows.push(Table2Row {
        arch: "FC FC FC".into(),
        layer_compression: 1.0,
        vgg16_compression: 1.0,
        vgg19_compression: 1.0,
        proxy_error: f32::NAN,
    });
    for &r in &[4usize, 2, 1] {
        let tt = fc6_tt_shape(r)?;
        let (c16, c19) = net_compr(tt.num_params(), None);
        rows.push(Table2Row {
            arch: format!("TT{r} FC FC"),
            layer_compression: dense6 / tt.num_params() as f64,
            vgg16_compression: c16,
            vgg19_compression: c19,
            proxy_error: f32::NAN,
        });
    }
    {
        let t6 = fc6_tt_shape(4)?;
        let t7 = fc7_tt_shape(4)?;
        let (c16, c19) = net_compr(t6.num_params(), Some(t7.num_params()));
        rows.push(Table2Row {
            arch: "TT4 TT4 FC".into(),
            layer_compression: (dense6 + dense7) / (t6.num_params() + t7.num_params()) as f64,
            vgg16_compression: c16,
            vgg19_compression: c19,
            proxy_error: f32::NAN,
        });
    }
    for &r in &[1usize, 5, 50] {
        let mr = r * (VGG_FC.fc6.0 + VGG_FC.fc6.1);
        let (c16, c19) = net_compr(mr, None);
        rows.push(Table2Row {
            arch: format!("MR{r} FC FC"),
            layer_compression: dense6 / mr as f64,
            vgg16_compression: c16,
            vgg19_compression: c19,
            proxy_error: f32::NAN,
        });
    }
    Ok(rows)
}

/// Proxy accuracy pass at 1/4 scale: input 6272 = 2·7·8·8·7·1·(1/4 of
/// 25088), hidden 1024 = 4^5·1 (1/4 of 4096), same rank settings.
pub fn run_table2(quick: bool, with_accuracy: bool, verbose: bool) -> Result<Vec<Table2Row>> {
    let mut rows = compression_rows()?;
    if !with_accuracy {
        return Ok(rows);
    }
    let (n_train, n_test, epochs) = if quick { (600, 300, 2) } else { (2500, 1000, 5) };
    let seed = 0x5461_626cu64;
    let spec = FeatureSpec { dim: 6272, n_classes: 10, density: 0.05, signal: 1.2 };
    let all = synth_features(n_train + n_test, spec, seed)?;
    let (train, test) = all.split(n_train)?;
    let trainer = Trainer::new(TrainConfig {
        epochs,
        batch_size: 32,
        sgd: SgdConfig::with_lr(0.01),
        lr_decay: 0.9,
        log_every: 0,
        seed,
    });
    // proxy geometry: 6272 = 2·7·8·8·7·1 -> 1024 = 4·4·4·4·4·1
    let proxy_ns = [2usize, 7, 8, 8, 7, 1];
    let proxy_ms = [4usize, 4, 4, 4, 4, 1];
    let hidden = 1024usize;

    let mut errors: Vec<(String, f32)> = Vec::new();
    // FC reference tail
    {
        let mut rng = Rng::new(seed ^ 0x10);
        let mut net = Sequential::new(vec![
            Box::new(Dense::new(6272, hidden, &mut rng)),
            Box::new(Relu::new()),
            Box::new(Dense::new(hidden, 10, &mut rng)),
        ]);
        trainer.fit(&mut net, &train, None)?;
        errors.push(("FC FC FC".into(), trainer.evaluate(&mut net, &test)?.error));
    }
    for &r in &[4usize, 2, 1] {
        let mut rng = Rng::new(seed ^ 0x20 ^ r as u64);
        let shape = TtShape::uniform(&proxy_ms, &proxy_ns, r)?;
        let mut net = Sequential::new(vec![
            Box::new(TtLinear::new(&shape, &mut rng)?),
            Box::new(Relu::new()),
            Box::new(Dense::new(hidden, 10, &mut rng)),
        ]);
        trainer.fit(&mut net, &train, None)?;
        errors.push((format!("TT{r} FC FC"), trainer.evaluate(&mut net, &test)?.error));
    }
    {
        // TT4 TT4: second layer 1024 -> 1024 TT as the fc7 proxy
        let mut rng = Rng::new(seed ^ 0x30);
        let s6 = TtShape::uniform(&proxy_ms, &proxy_ns, 4)?;
        let s7 = TtShape::uniform(&[4; 6], &[4, 4, 4, 4, 4, 1], 4)?;
        let s7_out: usize = 4096;
        let mut net = Sequential::new(vec![
            Box::new(TtLinear::new(&s6, &mut rng)?),
            Box::new(Relu::new()),
            Box::new(TtLinear::new(&s7, &mut rng)?),
            Box::new(Relu::new()),
            Box::new(Dense::new(s7_out, 10, &mut rng)),
        ]);
        trainer.fit(&mut net, &train, None)?;
        errors.push(("TT4 TT4 FC".into(), trainer.evaluate(&mut net, &test)?.error));
    }
    for &r in &[1usize, 5, 50] {
        let mut rng = Rng::new(seed ^ 0x40 ^ r as u64);
        let mut net = Sequential::new(vec![
            Box::new(low_rank_pair(6272, hidden, r, &mut rng)?),
            Box::new(Relu::new()),
            Box::new(Dense::new(hidden, 10, &mut rng)),
        ]);
        trainer.fit(&mut net, &train, None)?;
        errors.push((format!("MR{r} FC FC"), trainer.evaluate(&mut net, &test)?.error));
    }

    for row in rows.iter_mut() {
        if let Some((_, e)) = errors.iter().find(|(l, _)| *l == row.arch) {
            row.proxy_error = *e;
        }
        if verbose {
            println!(
                "{:<12} layer x{:<9.0} vgg16 x{:<4.1} vgg19 x{:<4.1} proxy err {}",
                row.arch,
                row.layer_compression,
                row.vgg16_compression,
                row.vgg19_compression,
                if row.proxy_error.is_nan() {
                    "-".to_string()
                } else {
                    format!("{:.3}", row.proxy_error)
                }
            );
        }
    }
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compression_matches_paper_columns() {
        let rows = compression_rows().unwrap();
        let get = |arch: &str| rows.iter().find(|r| r.arch == arch).unwrap().clone();
        // paper: TT4 -> 50972, TT2 -> 194622, TT1 -> 713614 (layer ratios)
        assert!((get("TT4 FC FC").layer_compression - 50972.0).abs() / 50972.0 < 0.01);
        assert!((get("TT2 FC FC").layer_compression - 194622.0).abs() / 194622.0 < 0.01);
        assert!((get("TT1 FC FC").layer_compression - 713614.0).abs() / 713614.0 < 0.01);
        // whole-network ratios: ~3.9 / ~3.5 one layer, ~7.4 / ~6 two layers
        assert!((get("TT4 FC FC").vgg16_compression - 3.9).abs() < 0.3);
        assert!((get("TT4 FC FC").vgg19_compression - 3.5).abs() < 0.3);
        assert!((get("TT4 TT4 FC").vgg16_compression - 7.4).abs() < 0.6);
        assert!((get("TT4 TT4 FC").vgg19_compression - 6.0).abs() < 0.6);
        // MR row ratios: 3521 / 704 / 70 ish
        assert!((get("MR1 FC FC").layer_compression - 3521.0).abs() / 3521.0 < 0.02);
        assert!((get("MR50 FC FC").layer_compression - 70.0).abs() / 70.0 < 0.03);
    }

    #[test]
    fn vgg_conv_param_scale() {
        // known ballparks: vgg16 convs ~14.7M, vgg19 convs ~20.0M
        let p16 = vgg_conv_params(false);
        let p19 = vgg_conv_params(true);
        assert!((14_000_000..15_500_000).contains(&p16), "{p16}");
        assert!((19_500_000..21_000_000).contains(&p19), "{p19}");
    }
}
