//! E3 — Table 1: asymptotic complexity of the TT layer vs the dense FC
//! layer.  Measures forward and backward wall-clock across layer sizes
//! M = N in {256, 1024, 4096} at fixed d-ish mode structure and rank, and
//! fits the growth exponent in N: FC must scale ~quadratically (O(MN) =
//! O(N^2)), TT ~linearly (O(d r^2 m max(M,N))).
//!
//! Run: `cargo bench --bench table1_complexity` (QUICK=1 to shorten).

use tensornet::nn::{Dense, Layer, TtLinear};
use tensornet::tensor::Tensor;
use tensornet::tt::TtShape;
use tensornet::util::bench::{black_box, print_table, Bencher};
use tensornet::util::rng::Rng;

struct Case {
    n: usize,
    modes: Vec<usize>,
}

fn main() {
    let quick = std::env::var("QUICK").is_ok();
    let bencher = if quick { Bencher::quick() } else { Bencher::default() };
    let rank = 8usize;
    let batch = 16usize;
    let cases = [
        Case { n: 256, modes: vec![4; 4] },
        Case { n: 1024, modes: vec![4; 5] },
        Case { n: 4096, modes: vec![4; 6] },
    ];

    let mut rows = Vec::new();
    let mut tt_fwd_ms = Vec::new();
    let mut fc_fwd_ms = Vec::new();
    let mut tt_bwd_ms = Vec::new();
    let mut fc_bwd_ms = Vec::new();

    for case in &cases {
        let mut rng = Rng::new(case.n as u64);
        let n = case.n;
        let shape = TtShape::uniform(&case.modes, &case.modes, rank).unwrap();
        let mut tt = TtLinear::new(&shape, &mut rng).unwrap();
        let mut fc = Dense::new(n, n, &mut rng);
        let x = Tensor::randn(&[batch, n], 1.0, &mut rng);
        let g = Tensor::randn(&[batch, n], 1.0, &mut rng);

        let m_tt_f = bencher.run(&format!("TT  fwd  {n}x{n} r{rank} b{batch}"), || {
            black_box(tt.forward(&x, false).unwrap());
        });
        let m_fc_f = bencher.run(&format!("FC  fwd  {n}x{n} b{batch}"), || {
            black_box(fc.forward(&x, false).unwrap());
        });
        let m_tt_b = bencher.run(&format!("TT  f+b  {n}x{n} r{rank} b{batch}"), || {
            let _ = tt.forward(&x, true).unwrap();
            black_box(tt.backward(&g).unwrap());
            tt.zero_grads();
        });
        let m_fc_b = bencher.run(&format!("FC  f+b  {n}x{n} b{batch}"), || {
            let _ = fc.forward(&x, true).unwrap();
            black_box(fc.backward(&g).unwrap());
            fc.zero_grads();
        });

        tt_fwd_ms.push(m_tt_f.mean_ms());
        fc_fwd_ms.push(m_fc_f.mean_ms());
        tt_bwd_ms.push(m_tt_b.mean_ms());
        fc_bwd_ms.push(m_fc_b.mean_ms());
        rows.push(vec![
            format!("{n}"),
            format!("{:.3}", m_tt_f.mean_ms()),
            format!("{:.3}", m_fc_f.mean_ms()),
            format!("{:.3}", m_tt_b.mean_ms()),
            format!("{:.3}", m_fc_b.mean_ms()),
            format!("{}", shape.num_params()),
            format!("{}", n * n),
        ]);
    }

    print_table(
        "Table 1 — measured time (ms) and parameter storage",
        &["N=M", "TT fwd", "FC fwd", "TT f+b", "FC f+b", "TT params", "FC params"],
        &rows,
    );

    // growth exponents between N=1024 and N=4096 (factor 4 in N)
    let exp = |a: f64, b: f64| (b / a).log2() / 2.0; // log_4
    println!("growth exponent in N (1024 -> 4096; FC expects ~2, TT expects ~1):");
    println!("  TT fwd: {:.2}   FC fwd: {:.2}", exp(tt_fwd_ms[1], tt_fwd_ms[2]), exp(fc_fwd_ms[1], fc_fwd_ms[2]));
    println!("  TT f+b: {:.2}   FC f+b: {:.2}", exp(tt_bwd_ms[1], tt_bwd_ms[2]), exp(fc_bwd_ms[1], fc_bwd_ms[2]));
}
