//! Coordinator throughput/latency vs batching policy (echo backend, so
//! this isolates coordination overhead from model compute).
//!
//! Run: `cargo bench --bench coordinator_bench` (QUICK=1 to shorten).

use std::sync::Arc;
use std::time::{Duration, Instant};
use tensornet::coordinator::{BatchPolicy, EchoExecutor, Server, ServerConfig};
use tensornet::util::bench::print_table;

fn main() {
    let quick = std::env::var("QUICK").is_ok();
    let n_requests: usize = if quick { 2_000 } else { 20_000 };
    let clients = 8usize;
    let dim = 64usize;

    let mut rows = Vec::new();
    for (max_batch, delay_us) in [(1usize, 0u64), (8, 200), (32, 500), (32, 2000), (128, 2000)] {
        let cfg = ServerConfig {
            policy: BatchPolicy {
                max_batch,
                max_delay: Duration::from_micros(delay_us),
            },
            queue_capacity: 4096,
            batch_queue_capacity: 16,
            executor_threads: 1,
            kernel_threads: 0,
            ..Default::default()
        };
        let server = Arc::new(
            Server::start(cfg, move || Ok(EchoExecutor { dim, scale: 1.0 })).unwrap(),
        );
        let t0 = Instant::now();
        std::thread::scope(|s| {
            for _ in 0..clients {
                let server = server.clone();
                s.spawn(move || {
                    let x = vec![1.0f32; dim];
                    for _ in 0..n_requests / clients {
                        server.infer("m", x.clone()).unwrap();
                    }
                });
            }
        });
        let wall = t0.elapsed().as_secs_f64();
        let st = server.stats();
        rows.push(vec![
            max_batch.to_string(),
            format!("{delay_us}"),
            format!("{:.0}", st.completed.get() as f64 / wall),
            format!("{:.1}", st.mean_batch_size()),
            format!("{:.0}", st.e2e.quantile_us(0.5)),
            format!("{:.0}", st.e2e.quantile_us(0.99)),
        ]);
    }
    print_table(
        "coordinator: batching policy sweep (echo backend, 8 clients)",
        &["max_batch", "max_delay (µs)", "req/s", "mean batch", "p50 (µs)", "p99 (µs)"],
        &rows,
    );
}
