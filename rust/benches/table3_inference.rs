//! E7 — Table 3: 25088 -> 4096 inference, dense vs TT rank-4, batch 1 and
//! 100 — the native hot paths.  (The PJRT serving path is exercised by
//! `examples/serve_tt.rs`; the artifact executables measure the same
//! computation through XLA.)
//!
//! Run: `cargo bench --bench table3_inference` (QUICK=1 to shorten).

use tensornet::experiments::run_table3;
use tensornet::util::bench::print_table;

fn main() {
    let quick = std::env::var("QUICK").is_ok();
    let rows = run_table3(quick, false).expect("table3");
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.kind.clone(),
                r.batch.to_string(),
                format!("{:.3} ms", r.mean_ms),
                format!("{:.3} MB", r.mem_bytes as f64 / 1048576.0),
            ]
        })
        .collect();
    print_table(
        "Table 3 — 25088x4096: paper CPU FC 16.1/97.2 ms, TT 1.2/94.7 ms (b1/b100); mem 392 vs 0.766 MB",
        &["layer", "batch", "mean time", "fwd memory"],
        &table,
    );
    let b1 = rows[0].mean_ms / rows[1].mean_ms;
    let b100 = rows[2].mean_ms / rows[3].mean_ms;
    println!("FC/TT speedup: batch1 {b1:.1}x (paper 13.4x), batch100 {b100:.2}x (paper 1.03x)");
}
